"""Multi-device behaviour via subprocesses (device count must be set before
jax init, so these cannot run in the main pytest process)."""
import os
import subprocess
import sys

import pytest

# Multi-process module: slow tier (see pytest.ini)
pytestmark = pytest.mark.slow

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_py(code, ndev=8):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={ndev}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=420)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"
    return r.stdout


def test_distributed_sketched_lstsq_matches_truth():
    out = run_py("""
import jax
jax.config.update("jax_enable_x64", True)
import jax.numpy as jnp
from repro.core import generate_problem, sketched_lstsq
from repro.core.distributed import shard_rows
mesh = jax.make_mesh((8,), ("data",))
prob = generate_problem(jax.random.key(0), 4096, 48, cond=1e8, beta=1e-10)
A, b = shard_rows(mesh, ("data",), prob.A, prob.b)
res = sketched_lstsq(A, b, jax.random.key(1), mesh=mesh)
err = float(jnp.linalg.norm(res.x - prob.x_true))
assert err < 1e-5, err
print("ok", err)
""")
    assert "ok" in out


def test_dp_train_with_sketched_compression():
    """CountSketch-compressed DP all-reduce.

    Verifies: (a) the reconstruction correlates with g at the 1/√ratio
    noise regime and carries the contractive 1/ratio gain; (b) exact
    error-feedback bookkeeping; (c) EF stays bounded over training (the
    raw unsketch is NOT contractive — without the 1/ratio scaling EF
    grows ~√(ratio−1)× per step); (d) compressed training *converges* on
    the bigram task with 4× smaller all-reduce payloads."""
    out = run_py("""
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.optim import CompressionConfig
from repro.optim.compression import sketched_psum_grads
from repro.sharding import shard_map_compat

mesh = jax.make_mesh((4,), ("data",))
cfg = CompressionConfig(ratio=4, min_size=1)
g = jax.random.normal(jax.random.key(0), (65536,)) + 0.5
ef = jnp.zeros((65536,))
def f(t, e):
    out, ne = sketched_psum_grads(cfg, {"w": t}, {"w": e}, ("data",), step=0)
    return out["w"], ne["w"]
r, ne = shard_map_compat(f, mesh=mesh, in_specs=(P(), P()),
                         out_specs=(P(), P()))(g, ef)
corr = float(jnp.corrcoef(g, r)[0, 1])
assert 0.3 < corr < 0.7, corr                      # 1/sqrt(ratio) regime
assert abs(float(r.mean()/g.mean()) - 1/cfg.ratio) < 0.05  # contractive gain
assert float(jnp.abs(g - r - ne).max()) < 1e-5     # exact EF bookkeeping

# step-varying sketches keep EF bounded and training finite
from repro.configs import smoke_config
from repro.data import SyntheticConfig, batch_at
from repro.optim import AdamWConfig, compress_state_init
from repro.train import init_train_state, make_dp_train_step
mcfg = smoke_config("llama3.2-1b").replace(n_periods=2)
dcfg = SyntheticConfig(vocab=mcfg.vocab, seq_len=64, global_batch=8, kind="bigram")
ocfg = AdamWConfig(lr=5e-3, warmup_steps=2, total_steps=50)
comp = CompressionConfig(ratio=4, min_size=4096)
state = init_train_state(mcfg, jax.random.key(0))
efs = compress_state_init(comp, state.params)
step = jax.jit(make_dp_train_step(mcfg, ocfg, mesh, compression=comp))
losses = []
for i in range(40):
    (state, efs), m = step(state, efs, batch_at(dcfg, i))
    losses.append(float(m["loss"]))
    assert jnp.isfinite(m["loss"]), (i, m)
ef_norm = sum(float(jnp.sum(e**2)) for e in jax.tree.leaves(efs) if e is not None)
assert ef_norm < 1e3, ef_norm      # bounded error feedback (contraction)
assert losses[-1] < losses[0] - 0.05, (losses[0], losses[-1])  # converges
print("ok", corr, losses[0], "->", losses[-1])
""", ndev=4)
    assert "ok" in out


def test_fsdp_tp_train_step_2d_mesh():
    """2D-sharded (FSDP x TP) train step on a 2x4 mesh: runs + loss finite."""
    out = run_py("""
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding
from repro.configs import smoke_config
from repro.data import SyntheticConfig, batch_at
from repro.optim import AdamWConfig
from repro.train import init_train_state, make_train_step
from repro.train.step import state_pspecs, batch_pspec
cfg = smoke_config("mixtral-8x7b").replace(n_periods=2)
mesh = jax.make_mesh((2, 4), ("data", "model"))
dcfg = SyntheticConfig(vocab=cfg.vocab, seq_len=64, global_batch=4, kind="bigram")
state = init_train_state(cfg, jax.random.key(0))
sspec = state_pspecs(cfg, mesh)
state = jax.tree.map(
    lambda x, s: jax.device_put(x, NamedSharding(mesh, s)), state, sspec,
    is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
batch = jax.tree.map(
    lambda x: jax.device_put(x, NamedSharding(mesh, batch_pspec(mesh))),
    batch_at(dcfg, 0))
step = jax.jit(make_train_step(cfg, AdamWConfig(), n_micro=2), donate_argnums=0)
with mesh:
    state, m = step(state, batch)
assert jnp.isfinite(m["loss"]), m
print("ok", float(m["loss"]))
""", ndev=8)
    assert "ok" in out


def test_elastic_restore_to_smaller_mesh(tmp_path):
    """Save on a (4,) mesh, restore onto (2,) — elastic re-mesh."""
    out = run_py(f"""
import jax, jax.numpy as jnp
from repro.configs import smoke_config
from repro.train import init_train_state, save
from repro.train.elastic import restore_elastic
cfg = smoke_config("qwen3-0.6b").replace(n_periods=2)
state = init_train_state(cfg, jax.random.key(0))
save(r"{tmp_path}", 5, state)
mesh = jax.make_mesh((2, 2), ("data", "model"))
restored, step = restore_elastic(r"{tmp_path}", cfg, mesh)
assert step == 5
leaf = jax.tree.leaves(restored.params)[0]
assert len(leaf.sharding.device_set) >= 1
print("ok elastic", step)
""", ndev=4)
    assert "ok elastic" in out


def test_moe_shard_map_matches_gspmd():
    """EP shard_map MoE must produce identical outputs to the GSPMD path."""
    out = run_py("""
import jax, jax.numpy as jnp
from repro.configs import smoke_config
from repro.models import init_params
from repro.models.moe import moe_apply
import dataclasses

for arch, tp in [("mixtral-8x7b", 4), ("deepseek-v2-236b", 2)]:
    cfg = smoke_config(arch)
    cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, capacity_factor=4.0))
    mesh = jax.make_mesh((8 // tp, tp), ("data", "model"))
    params = init_params(cfg, jax.random.key(0))
    p0 = jax.tree.map(lambda a: a[0], params["pattern"][0]["ffn"])
    x = jax.random.normal(jax.random.key(1), (8, 16, cfg.d_model), jnp.float32)
    ref = moe_apply(p0, x, cfg.replace(moe_impl="gspmd"))
    with mesh:
        got = jax.jit(lambda p, x: moe_apply(p, x, cfg.replace(moe_impl="shard_map")))(p0, x)
    err = float(jnp.abs(got - ref).max())
    assert err < 1e-4, (arch, err)
    print("ok", arch, err)
""", ndev=8)
    assert out.count("ok") == 2
