"""MicroBatcher release rules + shape-bucket padding exactness (S4).

The padding theorem under test: embedding (A, b) block-diagonally as
A_pad = [[A, 0], [0, I]], b_pad = [b, 0] decouples the padded problem, so
its minimizer is exactly [x*, 0] — also under ridge, and also through a
SKETCHED solve, because every sketch family embeds the padded column
space as well as the original.  The vmapped bucket solves must therefore
match unbatched ``lstsq`` per problem to tight rtol for all six sketch
kinds.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.core import lstsq
from repro.serve import MicroBatcher, bucket_shape, pad_problem, solve_bucket

SKETCH_KINDS = (
    "gaussian", "uniform_dense", "srht", "clarkson_woodruff",
    "sparse_sign", "uniform_sparse",
)


# ---------------------------------------------------------------- batcher


def test_size_triggered_release():
    mb = MicroBatcher(max_batch=3, max_delay_s=100.0)
    for i in range(7):
        mb.add("k", i, now=0.0)
    out = mb.ready(now=0.0)
    assert [(k, len(v)) for k, v in out] == [("k", 3), ("k", 3)]
    assert mb.pending == 1  # remainder stays queued, too young to release


def test_age_triggered_release():
    mb = MicroBatcher(max_batch=64, max_delay_s=0.010)
    mb.add("k", "a", now=0.0)
    assert mb.ready(now=0.005) == []
    out = mb.ready(now=0.011)
    assert out == [("k", ["a"])]
    assert mb.pending == 0


def test_drain_releases_everything():
    mb = MicroBatcher(max_batch=64, max_delay_s=100.0)
    mb.add("a", 1, now=0.0)
    mb.add("b", 2, now=0.0)
    out = dict(mb.ready(now=0.0, drain=True))
    assert out == {"a": [1], "b": [2]}


def test_keys_do_not_coalesce_across():
    mb = MicroBatcher(max_batch=2, max_delay_s=100.0)
    mb.add("a", 1, now=0.0)
    mb.add("b", 2, now=0.0)
    mb.add("a", 3, now=0.0)
    out = mb.ready(now=0.0)
    assert out == [("a", [1, 3])]


def test_occupancy_accounting():
    mb = MicroBatcher(max_batch=4, max_delay_s=0.0)
    for i in range(6):
        mb.add("k", i, now=0.0)
    mb.ready(now=1.0)
    assert mb.batch_sizes == [4, 2]
    assert mb.mean_occupancy == pytest.approx(6 / 8)


# ------------------------------------------------------------ shape buckets


def test_bucket_shape_geometric():
    assert bucket_shape(60, 7) == (64, 8)
    assert bucket_shape(64, 7) == (128, 8)  # identity rows need the room
    assert bucket_shape(100, 3) == (128, 8)  # min_n floor
    m_pad, n_pad = bucket_shape(1000, 17)
    assert m_pad >= 1000 + (n_pad - 17) and n_pad == 32


def test_bucket_shape_bounds_compile_count():
    shapes = {bucket_shape(m, n) for m in range(40, 200) for n in (3, 5, 9)}
    assert len(shapes) <= 6  # O(log) buckets for 160x3 distinct shapes


def test_pad_problem_structure():
    A = jax.random.normal(jax.random.PRNGKey(0), (10, 3))
    b = jax.random.normal(jax.random.PRNGKey(1), (10,))
    A_pad, b_pad = pad_problem(A, b, 16, 8)
    assert A_pad.shape == (16, 8) and b_pad.shape == (16,)
    assert jnp.array_equal(A_pad[:10, :3], A)
    assert jnp.array_equal(A_pad[10:15, 3:8], jnp.eye(5))
    assert float(jnp.abs(b_pad[10:]).max()) == 0.0


def _stack_padded(problems, m_pad, n_pad):
    pads = [pad_problem(A, b, m_pad, n_pad) for A, b, _ in problems]
    return (
        jnp.stack([p[0] for p in pads]),
        jnp.stack([p[1] for p in pads]),
        jnp.asarray([lam for _, _, lam in problems]),
    )


def _mixed_problems(key, k=4, n=5):
    """k problems of DIFFERENT shapes that share one (m_pad, n_pad) bucket."""
    problems = []
    for i in range(k):
        kA, kb, key = jax.random.split(key, 3)
        m = 40 + 7 * i
        A = jax.random.normal(kA, (m, n))
        b = jax.random.normal(kb, (m,))
        lam = 0.25 if i % 2 else 0.0  # ridge and plain share the bucket
        problems.append((A, b, lam))
    return problems


def test_bucket_direct_matches_unbatched_lstsq():
    problems = _mixed_problems(jax.random.PRNGKey(0))
    m_pad, n_pad = bucket_shape(40 + 7 * 3 , 5)
    A_stack, b_stack, lam = _stack_padded(problems, m_pad, n_pad)
    out = solve_bucket(A_stack, b_stack, lam, certify=True)
    for i, (A, b, l) in enumerate(problems):
        n = A.shape[1]
        x_ref = lstsq(A, b, jax.random.PRNGKey(1), method="direct",
                      reg=l or None).x
        x = out["x"][i, :n]
        assert float(jnp.linalg.norm(x - x_ref)) <= 1e-10 * max(
            1.0, float(jnp.linalg.norm(x_ref))
        )
        # padded coordinates are exactly decoupled -> driven to zero
        assert float(jnp.abs(out["x"][i, n:]).max()) <= 1e-12
        assert float(out["error_bound"][i]) < 1e-10


@pytest.mark.parametrize("kind", SKETCH_KINDS)
def test_padded_vmapped_batch_matches_unbatched(kind):
    """S4: one vmapped sketched batch over the padded stack, per kind.

    ``saa_sas_batch`` problem-batch mode shares ONE S draw and vmaps the
    whole factor+solve over the stack — exactly the bucket execution
    model; every per-problem answer must match its own unbatched direct
    solve.
    """
    from repro.core import saa_sas_batch

    problems = [(A, b, 0.0) for A, b, _ in _mixed_problems(jax.random.PRNGKey(2))]
    m_pad, n_pad = bucket_shape(40 + 7 * 3, 5)
    A_stack, b_stack, _ = _stack_padded(problems, m_pad, n_pad)
    res = saa_sas_batch(
        A_stack, b_stack, jax.random.PRNGKey(3), sketch=kind, iter_lim=80,
    )
    for i, (A, b, _) in enumerate(problems):
        x_ref = lstsq(A, b, jax.random.PRNGKey(4), method="direct").x
        n = A.shape[1]
        rel = float(jnp.linalg.norm(res.x[i, :n] - x_ref)) / max(
            1.0, float(jnp.linalg.norm(x_ref))
        )
        assert rel <= 1e-8, f"{kind}: padded vmapped solve off by {rel:.2e}"
        # padded coordinates decouple and are driven to (numerical) zero
        assert float(jnp.abs(res.x[i, n:]).max()) <= 1e-8


@pytest.mark.parametrize("kind", SKETCH_KINDS)
def test_padded_ridge_solve_matches_unbatched(kind):
    """S4 (ridge): padding exactness survives λ > 0 through the sketched
    path — the √λI tail rides the structured AugmentedSketch, never the
    random block."""
    problems = _mixed_problems(jax.random.PRNGKey(5))
    m_pad, n_pad = bucket_shape(40 + 7 * 3, 5)
    A_stack, b_stack, lam = _stack_padded(problems, m_pad, n_pad)
    for i, (A, b, _) in enumerate(problems):
        reg = float(lam[i]) or None
        x_pad = lstsq(
            A_stack[i], b_stack[i], jax.random.PRNGKey(6), method="saa",
            sketch=kind, reg=reg, iter_lim=80,
        ).x
        x_ref = lstsq(A, b, jax.random.PRNGKey(7), method="direct",
                      reg=reg).x
        n = A.shape[1]
        rel = float(jnp.linalg.norm(x_pad[:n] - x_ref)) / max(
            1.0, float(jnp.linalg.norm(x_ref))
        )
        assert rel <= 1e-8, f"{kind}: padded ridge solve off by {rel:.2e}"
        assert float(jnp.abs(x_pad[n:]).max()) <= 1e-8


def test_solve_bucket_validates_shapes():
    with pytest.raises(ValueError, match="A_stack"):
        solve_bucket(jnp.zeros((2, 8, 4)), jnp.zeros((2, 7)))
