"""The unified lstsq() driver: auto-selection + one SolveResult for all."""
import jax
import jax.numpy as jnp
import pytest

from repro.core import METHODS, SolveResult, generate_problem, lstsq, select_method


@pytest.fixture(scope="module")
def prob():
    return generate_problem(jax.random.key(0), 4000, 64, cond=1e10, beta=1e-10)


def relerr(x, xt):
    return float(jnp.linalg.norm(x - xt) / jnp.linalg.norm(xt))


@pytest.mark.parametrize("method", METHODS)
def test_every_method_returns_solveresult(prob, method):
    res = lstsq(prob.A, prob.b, jax.random.key(1), method=method)
    assert isinstance(res, SolveResult)
    assert res.method == method
    for field in ("istop", "itn", "rnorm", "arnorm", "used_fallback"):
        assert getattr(res, field).shape == ()
    if method != "lsqr":  # plain LSQR legitimately stalls at cond=1e10
        assert relerr(res.x, prob.x_true) < 1e-4


def test_auto_small_problem_is_direct(prob):
    res = lstsq(prob.A, prob.b, jax.random.key(1))  # 4000x64: QR is free
    assert res.method == "direct"
    assert relerr(res.x, prob.x_true) < 1e-5


def test_auto_selection_rules():
    # Large strongly-overdetermined + key: accuracy tier picks the solver.
    assert select_method(200000, 100) == "iterative"
    assert select_method(200000, 100, accuracy="fast") == "saa"
    assert select_method(200000, 100, accuracy="high") == "fossils"
    # No key: deterministic paths only.
    assert select_method(200000, 100, has_key=False) == "lsqr"
    assert select_method(500, 100, has_key=False) == "direct"
    # Not overdetermined enough for the sketch to shrink anything.
    assert select_method(3000, 1000) == "direct"
    with pytest.raises(ValueError):
        select_method(1000, 10, accuracy="wat")


def test_sketched_methods_need_key(prob):
    with pytest.raises(ValueError, match="needs a PRNG key"):
        lstsq(prob.A, prob.b, method="saa")


def test_unknown_method_raises(prob):
    with pytest.raises(ValueError, match="unknown method"):
        lstsq(prob.A, prob.b, jax.random.key(1), method="cholesky")


def test_method_alias(prob):
    res = lstsq(prob.A, prob.b, jax.random.key(1), method="iterative_sketching")
    assert res.method == "iterative"


def test_history_passthrough(prob):
    res = lstsq(prob.A, prob.b, jax.random.key(1), method="saa", history=True)
    assert res.history is not None
    assert bool(jnp.isfinite(res.history[0]))


def test_tolerance_passthrough(prob):
    res = lstsq(prob.A, prob.b, jax.random.key(1), method="saa", iter_lim=3,
                atol=0.0, btol=0.0)
    assert int(res.itn) <= 3


def test_direct_result_is_exact(prob):
    res = lstsq(prob.A, prob.b, method="direct")
    assert int(res.itn) == 0
    assert res.converged
    # rnorm/arnorm are the true residual quantities.
    r = prob.b - prob.A @ res.x
    assert float(res.rnorm) == pytest.approx(float(jnp.linalg.norm(r)))


@pytest.mark.parametrize("method", ("direct", "iterative", "lsqr"))
def test_ridge_matches_normal_equations(method):
    """lstsq(reg=λ) must reproduce the closed-form ridge solution
    (AᵀA + λI)⁻¹Aᵀb on a small well-conditioned problem."""
    m, n, lam = 600, 12, 0.7
    k1, k2, key = jax.random.split(jax.random.key(3), 3)
    A = jax.random.normal(k1, (m, n))
    b = jax.random.normal(k2, (m,))
    x_ridge = jnp.linalg.solve(A.T @ A + lam * jnp.eye(n), A.T @ b)
    res = lstsq(A, b, key, method=method, reg=lam)
    assert float(jnp.linalg.norm(res.x - x_ridge) / jnp.linalg.norm(x_ridge)) < 1e-8
    # diagnostics are reported for the ORIGINAL system: the ridge gradient
    # Aᵀ(b − Ax) − λx vanishes at the ridge optimum, unlike Aᵀr itself.
    r = b - A @ res.x
    assert float(res.rnorm) == pytest.approx(float(jnp.linalg.norm(r)), rel=1e-9)
    assert float(res.arnorm) < 1e-8 * float(jnp.linalg.norm(b))
    assert float(jnp.linalg.norm(A.T @ r)) > 1e-3  # plain lstsq gradient ≠ 0


def test_ridge_increases_with_lambda(prob):
    """Sanity: larger λ shrinks ‖x‖ monotonically."""
    key = jax.random.key(4)
    norms = [
        float(jnp.linalg.norm(lstsq(prob.A, prob.b, key, method="direct",
                                    reg=lam).x))
        for lam in (0.0, 1.0, 100.0)
    ]
    assert norms[0] > norms[1] > norms[2]
