"""Hypothesis property tests on system invariants.

Skipped (not errored) when hypothesis isn't installed, so a bare
environment can still collect and run the rest of the tier-1 suite;
``pip install -r requirements-dev.txt`` provides it.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import SKETCH_KINDS, sample_sketch
from repro.core.lsqr import lsqr_dense
from repro.kernels import countsketch_apply, countsketch_ref

dims = st.tuples(
    st.integers(min_value=3, max_value=120),  # m
    st.integers(min_value=1, max_value=9),    # n
    st.integers(min_value=2, max_value=50),   # d
)

ALL_KINDS = sorted(set(SKETCH_KINDS) - {"clarkson_woodruff"})


@settings(max_examples=8, deadline=None)
@given(st.sampled_from(ALL_KINDS), dims, st.integers(0, 2**30))
def test_sketch_adjoint_consistency(kind, mnd, seed):
    """⟨S x, y⟩ == ⟨x, Sᵀ y⟩ for every operator kind — the apply and the
    materialized S/Sᵀ must realize the same linear map and its adjoint.
    (sparse_sign and uniform_sparse previously had no such coverage.)"""
    m, _, d = mnd
    op = sample_sketch(kind, jax.random.key(seed), d, m)
    x = jax.random.normal(jax.random.key(seed + 1), (m,))
    y = jax.random.normal(jax.random.key(seed + 2), (d,))
    lhs = jnp.vdot(op.apply(x), y)
    rhs = jnp.vdot(x, op.as_dense_t() @ y)
    assert jnp.allclose(lhs, rhs, rtol=1e-9, atol=1e-9)


@settings(max_examples=8, deadline=None)
@given(st.sampled_from(ALL_KINDS), dims, st.integers(0, 2**30))
def test_sketch_apply_matches_dense_property(kind, mnd, seed):
    """apply(A) == as_dense() @ A on random shapes for every kind."""
    m, n, d = mnd
    op = sample_sketch(kind, jax.random.key(seed), d, m)
    A = jax.random.normal(jax.random.key(seed + 3), (m, n))
    assert jnp.allclose(op.apply(A), op.as_dense() @ A, atol=1e-9)


@settings(max_examples=15, deadline=None)
@given(dims, st.integers(0, 2**30))
def test_countsketch_linearity(mnd, seed):
    """S is linear: S(aA + bB) == a·SA + b·SB exactly."""
    m, n, d = mnd
    op = sample_sketch("countsketch", jax.random.key(seed), d, m)
    A = jax.random.normal(jax.random.key(seed + 1), (m, n))
    B = jax.random.normal(jax.random.key(seed + 2), (m, n))
    lhs = op.apply(2.5 * A - 1.25 * B)
    rhs = 2.5 * op.apply(A) - 1.25 * op.apply(B)
    assert jnp.allclose(lhs, rhs, atol=1e-9)


@settings(max_examples=15, deadline=None)
@given(dims, st.integers(0, 2**30))
def test_countsketch_column_mass(mnd, seed):
    """Signed column sums are preserved: 1ᵀ(SA) == (signs)ᵀA."""
    m, n, d = mnd
    op = sample_sketch("countsketch", jax.random.key(seed), d, m)
    A = jax.random.normal(jax.random.key(seed + 3), (m, n))
    assert jnp.allclose(op.apply(A).sum(0), op.signs @ A, atol=1e-9)


@settings(max_examples=10, deadline=None)
@given(dims, st.integers(0, 2**30))
def test_kernel_matches_oracle_any_shape(mnd, seed):
    m, n, d = mnd
    A = jax.random.normal(jax.random.key(seed), (m, n), jnp.float32)
    h = jax.random.randint(jax.random.key(seed + 1), (m,), 0, d, dtype=jnp.int32)
    s = jax.random.rademacher(jax.random.key(seed + 2), (m,), jnp.float32)
    got = countsketch_apply(A, h, s, d, interpret=True)
    want = countsketch_ref(A, h, s, d)
    assert jnp.allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(max_examples=8, deadline=None)
@given(st.integers(2, 40), st.integers(1, 6), st.integers(0, 2**30))
def test_lsqr_satisfies_normal_equations(m_extra, n, seed):
    """For well-conditioned A, LSQR's x satisfies Aᵀ(Ax − b) ≈ 0."""
    m = n + m_extra
    A = jax.random.normal(jax.random.key(seed), (m, n))
    b = jax.random.normal(jax.random.key(seed + 1), (m,))
    res = lsqr_dense(A, b, atol=1e-12, btol=1e-12, iter_lim=200)
    g = A.T @ (A @ res.x - b)
    assert float(jnp.linalg.norm(g)) < 1e-6 * (1 + float(jnp.linalg.norm(b)))
