"""Content fingerprints: equality, sensitivity, memoization, tokens."""
import jax
import jax.numpy as jnp
import pytest
from jax.experimental import sparse as jsparse

from repro.core import linop
from repro.serve import Fingerprint, digest_array, fingerprint


def _A(seed=0, shape=(50, 7)):
    return jax.random.normal(jax.random.PRNGKey(seed), shape)


def test_same_content_same_fingerprint():
    A = _A()
    B = jnp.array(A)  # distinct object, identical bytes
    assert fingerprint(A) == fingerprint(B)
    assert hash(fingerprint(A)) == hash(fingerprint(B))


def test_content_sensitivity():
    A = _A()
    B = A.at[3, 4].add(1e-12)
    assert fingerprint(A) != fingerprint(B)


def test_config_sensitivity():
    A = _A()
    base = fingerprint(A)
    assert fingerprint(A, reg=0.1) != base
    assert fingerprint(A, sketch="gaussian") != base
    assert fingerprint(A, sketch_size=32) != base
    assert fingerprint(A.astype(jnp.float32)) != base


def test_digest_memo_hits_by_identity():
    A = _A()
    d1 = digest_array(A)
    d2 = digest_array(A)
    assert d1 == d2
    assert digest_array(jnp.array(A)) == d1  # same bytes, fresh object


def test_inplace_mutation_changes_fingerprint():
    """A writable numpy A mutated in place must NOT hit a stale memo —
    the old digest would serve the old matrix's cached factor."""
    import numpy as np

    A = np.asarray(_A()).copy()
    fp1 = fingerprint(A)
    A[0, 0] += 1.0
    fp2 = fingerprint(A)
    assert fp1 != fp2
    A[0, 0] -= 1.0
    assert fingerprint(A) == fp1


def test_readonly_numpy_is_memoized():
    import numpy as np

    A = np.asarray(_A()).copy()
    A.setflags(write=False)
    assert digest_array(A) == digest_array(A)
    assert fingerprint(A) == fingerprint(A)


def test_tenant_namespaces_tokens():
    A, A2 = _A(), _A(seed=1)
    # Same token from two tenants: PRIVATE namespaces, no collision even
    # for different matrices of the same shape/dtype/config.
    fa = fingerprint(A, token="v1", tenant="alice")
    fb = fingerprint(A2, token="v1", tenant="bob")
    assert fa != fb
    assert fingerprint(A, token="v1", tenant="alice") == fa
    # tenant= without a token is a no-op: content digests stay shared.
    assert fingerprint(A, tenant="alice") == fingerprint(A)
    # the operator path namespaces too
    op = linop.CustomOperator(
        matvec_fn=lambda x: A @ x, rmatvec_fn=lambda y: A.T @ y,
        op_shape=A.shape, op_dtype=A.dtype,
    )
    assert (fingerprint(op, token="v1", tenant="alice")
            != fingerprint(op, token="v1", tenant="bob"))


def test_bcoo_fingerprint():
    A = _A()
    M = jsparse.BCOO.fromdense(jnp.where(jnp.abs(A) > 1.0, A, 0.0))
    fp = fingerprint(M)
    assert fp.kind == "bcoo"
    M2 = jsparse.BCOO.fromdense(jnp.where(jnp.abs(A) > 1.0, A + 2.0, 0.0))
    assert fingerprint(M2) != fp


def test_operator_requires_token():
    A = _A()
    op = linop.CustomOperator(
        matvec_fn=lambda x: A @ x, rmatvec_fn=lambda y: A.T @ y,
        op_shape=A.shape, op_dtype=A.dtype,
    )
    with pytest.raises(ValueError, match="token"):
        fingerprint(op)
    fp = fingerprint(op, token="model-v3")
    assert fp.kind == "operator"
    assert fingerprint(op, token="model-v3") == fp
    assert fingerprint(op, token="model-v4") != fp


def test_token_overrides_digest_for_arrays():
    A = _A()
    assert fingerprint(A, token="t1") == fingerprint(_A(seed=1), token="t1")


def test_short_is_human_readable():
    s = fingerprint(_A(), reg=0.5).short()
    assert "50x7" in s and "reg=0.5" in s


def test_fingerprint_is_frozen():
    fp = fingerprint(_A())
    assert isinstance(fp, Fingerprint)
    with pytest.raises(Exception):
        fp.kind = "other"
