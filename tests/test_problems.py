"""§5.1 problem-generator invariants."""
import jax
import jax.numpy as jnp

from repro.core import generate_problem


def test_residual_orthogonal_and_scaled():
    prob = generate_problem(jax.random.key(0), 1000, 30, cond=1e8, beta=1e-6)
    # r ⟂ range(A) certifies x_true as the LS minimizer
    assert float(jnp.linalg.norm(prob.A.T @ prob.r_true)) < 1e-12
    assert abs(float(jnp.linalg.norm(prob.r_true)) - 1e-6) < 1e-12
    assert jnp.allclose(prob.b, prob.A @ prob.x_true + prob.r_true)


def test_condition_number():
    prob = generate_problem(jax.random.key(1), 500, 20, cond=1e6, beta=1e-8)
    sv = jnp.linalg.svd(prob.A, compute_uv=False)
    ratio = float(sv.max() / sv.min())
    assert 1e5 < ratio < 1e7


def test_unit_solution_norm():
    prob = generate_problem(jax.random.key(2), 200, 10)
    assert abs(float(jnp.linalg.norm(prob.x_true)) - 1.0) < 1e-12
