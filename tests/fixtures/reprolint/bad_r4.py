"""R4 true positives: broken pytree registrations."""
import dataclasses

import jax
from jax.tree_util import register_dataclass


@dataclasses.dataclass  # FINDING: registration below @dataclass —
@register_dataclass     # registers the bare class, flatten sees nothing
class WrongOrder:
    value: float
    step: int


@register_dataclass(data_fields=["value"], meta_fields=["step"])
@dataclasses.dataclass
class DroppedField:
    value: float
    hidden: float  # FINDING: in neither field list — vanishes on tree_map
    step: int


@dataclasses.dataclass
class Unregistered:
    value: float


@jax.jit
def make(x):
    return Unregistered(value=x)  # FINDING: unregistered dataclass in jit
