"""R2 true positives: host side effects in jit-reachable code."""
import numpy as np
import jax
import jax.numpy as jnp
from jax import lax


def leaky_norm(x):
    print("solving", x.shape)  # FINDING: print under jit
    h = np.linalg.norm(x)  # FINDING: host numpy op under jit
    return jnp.asarray(h)


@jax.jit
def solve(x):
    return leaky_norm(x) + x.sum()


def loop(x):
    def body(v):
        s = v.sum().item()  # FINDING: .item() host sync in while_loop body
        return v * s

    return lax.while_loop(lambda v: v.sum() > 0, body, x)
