"""R4 negatives: complete, correctly-ordered pytree registrations."""
import dataclasses
from typing import NamedTuple

import jax
from jax.tree_util import register_dataclass, register_pytree_node


@register_dataclass
@dataclasses.dataclass
class Complete:
    value: float
    step: int


@register_dataclass(data_fields=["value"], meta_fields=["step"])
@dataclasses.dataclass
class CompleteExplicit:
    value: float
    step: int


class AsTuple(NamedTuple):  # NamedTuples flatten completely by design
    value: float
    step: int


@dataclasses.dataclass
class ViaCall:
    value: float


register_pytree_node(
    ViaCall,
    lambda t: ((t.value,), None),
    lambda _, ch: ViaCall(*ch),
)


@jax.jit
def make(x):
    return Complete(value=x, step=0), AsTuple(value=x, step=1), ViaCall(x)
