"""R1 negatives: guarded writes, @guarded_by helpers, a justified
suppression — reprolint must report nothing here."""
import threading

from repro.analysis.annotations import guarded_by


class Engine:
    GUARDED_BY = {"stats": "_lock", "jobs": "_lock"}
    GUARDED_READS = frozenset({"jobs"})

    def __init__(self):
        self._lock = threading.RLock()
        self.stats = {"tiles": 0}  # __init__ is pre-sharing: exempt
        self.jobs: list = []

    def bump(self):
        with self._lock:
            self.stats["tiles"] += 1

    @guarded_by("_lock")
    def _bump_locked(self):
        self.stats["tiles"] += 1  # caller holds the lock by contract

    def bump_via_helper(self):
        with self._lock:
            self._bump_locked()

    def monitor_only(self):
        # reprolint: ignore[R1]: only the monitor thread ever writes this
        self.stats["tiles"] += 1

    def snapshot(self):
        with self._lock:
            return list(self.jobs)
