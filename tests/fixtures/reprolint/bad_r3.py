"""R3 true positives: leaked non-daemon threads."""
import threading


class Pool:
    def __init__(self):
        # FINDING: non-daemon, never joined anywhere in the class
        self.worker = threading.Thread(target=self._loop)
        self.worker.start()

    def _loop(self):
        pass


def fire_and_forget(fn):
    t = threading.Thread(target=fn)  # FINDING: local, not joined
    t.start()
    return None
