"""R1 lock-order cycle: two paths take the same two locks in opposite
orders — the canonical ABBA deadlock, visible statically."""
import threading


class Pair:
    def __init__(self):
        self._a = threading.Lock()
        self._b = threading.Lock()

    def forward(self):
        with self._a:
            with self._b:
                return 1

    def backward(self):
        with self._b:
            with self._a:  # FINDING: inverts forward()'s order
                return 2
