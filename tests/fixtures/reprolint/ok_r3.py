"""R3 negatives: daemonized or provably joined threads."""
import threading


class Pool:
    def __init__(self):
        self.pump = threading.Thread(target=self._loop, daemon=True)
        self.worker = threading.Thread(target=self._loop)
        self.late = threading.Thread(target=self._loop)
        self.late.daemon = True

    def _loop(self):
        pass

    def close(self):
        self.worker.join()  # joined on the teardown path: ok


def scoped(fn):
    t = threading.Thread(target=fn)
    t.start()
    t.join()
    return None
