"""R1 true positives: unguarded writes to GUARDED_BY attributes."""
import threading


class Engine:
    GUARDED_BY = {"stats": "_lock", "jobs": "_lock"}
    GUARDED_READS = frozenset({"jobs"})

    def __init__(self):
        self._lock = threading.Lock()
        self.stats = {"tiles": 0}
        self.jobs: list = []

    def bump_unlocked(self):
        self.stats["tiles"] += 1  # FINDING: write outside the lock

    def append_unlocked(self):
        self.jobs.append("x")  # FINDING: mutator call outside the lock

    def read_unlocked(self):
        return len(self.jobs)  # FINDING: guarded READ outside the lock

    def closure_escape(self):
        with self._lock:
            def later():
                self.stats["tiles"] += 1  # FINDING: closure outlives guard
            return later
