"""R2 negatives: guarded / self-guarding effects in jit-reachable code."""
import jax

from jax.core import trace_state_clean


def span(name):
    # Self-guarding tracer entry point: consults trace_state_clean
    # itself, like repro.obs.trace.span — calls to it are exempt.
    if not trace_state_clean():
        return None
    return name


def report(x):
    if trace_state_clean():
        print("shape", x.shape)  # guarded: only runs outside tracing


@jax.jit
def solve(x):
    span("solve")
    report(x)
    y = x.sum()
    # reprolint: ignore[R2]: debug aid, removed before the jit wrapper lands
    print("never traced in production")
    return y
