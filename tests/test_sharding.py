"""Logical-axis sharding rules."""
import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.sharding import DEFAULT_RULES, OPT_RULES, logical_to_spec


@pytest.fixture(scope="module")
def mesh():
    return jax.make_mesh((1,), ("data",))


def test_missing_axes_dropped(mesh):
    # 'model' and 'pod' absent from this mesh -> replicated
    assert logical_to_spec(("batch", "heads"), mesh) == P("data", None)


def test_divisibility_guard(mesh):
    assert logical_to_spec(("batch",), mesh, shape=(7,)) == P("data")  # 7 % 1 == 0
    spec = logical_to_spec(("vocab",), mesh, shape=(50280,))
    assert spec == P(None)  # 'model' absent


def test_opt_rules_add_pod():
    assert OPT_RULES["embed"] == ("pod", "data")
    assert DEFAULT_RULES["embed"] == "data"
