"""FactorCache: LRU + byte budget, counters, drift-aware invalidation."""
import jax
import jax.numpy as jnp
import pytest

from repro.core.session import SketchedSolver
from repro.serve import FactorCache, fingerprint, session_nbytes

M, N = 400, 12


def _problem(seed=0, m=M, n=N):
    kA, kb = jax.random.split(jax.random.PRNGKey(seed))
    A = jax.random.normal(kA, (m, n))
    b = jax.random.normal(kb, (m,))
    return A, b


def _build(A, seed=0, **kw):
    return lambda: SketchedSolver(A, jax.random.PRNGKey(100 + seed), **kw)


def test_hit_miss_counters_and_lru():
    cache = FactorCache()
    A, _ = _problem()
    fp = fingerprint(A)
    assert cache.get(fp) is None
    s1, hit = cache.get_or_build(fp, _build(A))
    assert not hit
    s2, hit = cache.get_or_build(fp, _build(A))
    assert hit and s2 is s1
    st = cache.stats()
    assert st["hits"] == 1 and st["misses"] == 2
    assert st["hit_rate"] == pytest.approx(1 / 3)
    assert st["entries"] == 1


def test_byte_budget_evicts_lru():
    A0, _ = _problem(0)
    one_session = _build(A0)()
    budget = int(session_nbytes(one_session) * 2.5)  # fits 2, not 3
    cache = FactorCache(max_bytes=budget)
    fps = []
    for seed in range(3):
        A, _ = _problem(seed)
        fp = fingerprint(A)
        fps.append(fp)
        cache.get_or_build(fp, _build(A, seed))
    assert len(cache) == 2
    assert fps[0] not in cache  # LRU evicted
    assert fps[2] in cache
    assert cache.evictions == 1
    assert cache.bytes <= budget


def test_oversized_entry_still_admitted():
    A, _ = _problem()
    cache = FactorCache(max_bytes=1)  # everything is oversized
    fp = fingerprint(A)
    cache.get_or_build(fp, _build(A))
    assert fp in cache and len(cache) == 1


def test_invalidate_and_clear():
    cache = FactorCache()
    A, _ = _problem()
    fp = fingerprint(A)
    cache.get_or_build(fp, _build(A))
    assert cache.invalidate(fp)
    assert not cache.invalidate(fp)
    assert cache.bytes == 0 and len(cache) == 0


def test_update_rows_rekeys_under_new_fingerprint():
    cache = FactorCache()
    A, b = _problem()
    fp = fingerprint(A)
    solver, _ = cache.get_or_build(fp, _build(A))
    x_before = solver.solve(b).x

    idx = jnp.arange(5)
    rows = jax.random.normal(jax.random.PRNGKey(9), (5, N))
    new_fp = cache.update_rows(fp, idx, rows)
    assert new_fp is not None and new_fp != fp
    assert fp not in cache and new_fp in cache
    # the re-key must match what a fresh fingerprint of the new data gives
    assert new_fp == fingerprint(solver.A.A)
    # and the cached session actually solves the UPDATED problem
    x_after = cache.get(new_fp).solve(b).x
    A_new = A.at[idx].set(rows)
    x_ref = jnp.linalg.lstsq(A_new, b)[0]
    assert float(jnp.linalg.norm(x_after - x_ref)) <= 1e-6 * float(
        jnp.linalg.norm(x_ref)
    )
    assert float(jnp.linalg.norm(x_after - x_before)) > 1e-8


def test_update_rows_missing_entry_raises():
    cache = FactorCache()
    A, _ = _problem()
    with pytest.raises(KeyError):
        cache.update_rows(fingerprint(A), jnp.arange(2), jnp.zeros((2, N)))


def test_session_nbytes_counts_owned_artifacts():
    A, _ = _problem()
    solver = _build(A)()
    # exactly the session-owned artifacts: B, the QR factor, Y — never A
    expected = (
        solver._B.nbytes + solver.factor.Q.nbytes + solver.factor.R.nbytes
        + solver._Y.A.nbytes
    )
    assert session_nbytes(solver) == expected
