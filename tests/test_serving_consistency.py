"""THE serving correctness test: for every architecture family,
prefill(S tokens) + decode_step must reproduce forward()'s next-token
logits — exercising KV caches, ring buffers, MLA latent absorption and
SSM/RG-LRU state threading."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import smoke_config
from repro.models import decode_step, forward, init_params, prefill

# Model-zoo / multi-process / long-sweep module: slow tier (see pytest.ini)
pytestmark = pytest.mark.slow

ARCHS = [
    "llama3.2-1b",        # dense GQA, tied embeddings
    "qwen3-0.6b",         # qk-norm
    "mixtral-8x7b",       # SWA ring cache + MoE
    "deepseek-v2-236b",   # MLA absorbed decode + shared experts
    "mamba2-2.7b",        # SSD state
    "recurrentgemma-9b",  # RG-LRU + local attn hybrid
    "musicgen-medium",    # frames frontend
    "llama-3.2-vision-11b",  # cross-attention
]


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_matches_forward(arch):
    cfg = smoke_config(arch)
    if cfg.moe is not None:
        # capacity drops are a *batch-level* effect: the batched forward
        # may drop assignments that the 2-token decode step keeps.  Test
        # logit equivalence in the drop-free regime (serving uses high
        # capacity factors for exactly this reason).
        import dataclasses
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    params = init_params(cfg, jax.random.key(0))
    B, S = 2, 64
    batch = {}
    if cfg.frontend == "frames":
        embeds = jax.random.normal(jax.random.key(1), (B, S + 1, cfg.d_model),
                                   jnp.float32)
        batch["embeds"] = embeds[:, :S]
        full_batch = {"embeds": embeds}
    else:
        toks = jax.random.randint(jax.random.key(1), (B, S + 1), 0, cfg.vocab)
        batch["tokens"] = toks[:, :S]
        full_batch = {"tokens": toks}
    img = None
    if cfg.frontend == "vision":
        img = jax.random.normal(jax.random.key(2), (B, cfg.n_patches, cfg.d_model),
                                jnp.float32)
        batch["image_embeds"] = img
        full_batch["image_embeds"] = img

    # reference: full forward over S tokens; logits at position S-1
    ref_logits = forward(cfg, params, batch)[:, -1]

    # serving: prefill S, compare last-token logits
    logits_pre, cache = prefill(cfg, params, batch, S_cache=S + 8)
    assert jnp.allclose(logits_pre, ref_logits, rtol=2e-3, atol=2e-3), (
        f"{arch}: prefill logits diverge "
        f"(max {jnp.abs(logits_pre - ref_logits).max():.2e})"
    )

    # decode one more token; compare against forward over S+1
    ref_logits2 = forward(cfg, params, full_batch)[:, -1]
    if cfg.frontend == "frames":
        logits_dec, _ = decode_step(
            cfg, params, cache, None, jnp.asarray(S, jnp.int32),
            embeds=embeds[:, S],
        )
    else:
        logits_dec, _ = decode_step(
            cfg, params, cache, toks[:, S], jnp.asarray(S, jnp.int32), img=img
        )
    assert jnp.allclose(logits_dec, ref_logits2, rtol=2e-3, atol=2e-3), (
        f"{arch}: decode logits diverge "
        f"(max {jnp.abs(logits_dec - ref_logits2).max():.2e})"
    )
