"""TSQR + fused sketch→QR pipeline (repro.kernels.tsqr).

Covers the PR's acceptance criteria:

- both TSQR modes (binary-tree R-merge, shifted-CholeskyQR3) agree with
  ``jnp.linalg.qr`` up to column signs, including at cond 1e10 where
  plain CholeskyQR is long dead;
- the fused Pallas gram kernels (interpret mode here) return B = SA and
  G = BᵀB consistent with the unfused reference applies;
- ``sketch_qr`` produces the same R (up to signs) as the seed pipeline
  ``op.apply_op`` → Householder QR, for every fusable sketch kind.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.core import linop
from repro.core import sketch as sketch_lib
from repro.kernels.tsqr import (
    cholqr_finish,
    panel_gram,
    sketch_qr,
    tsqr,
)
from repro.kernels.tsqr import fused as fused_lib

FUSABLE_KINDS = ("countsketch", "gaussian", "uniform_dense", "srht")


def _conditioned(key, m, n, cond, dtype=jnp.float64):
    """Random (m, n) matrix with prescribed 2-norm condition number."""
    k1, k2, k3 = jax.random.split(key, 3)
    U, _ = jnp.linalg.qr(jax.random.normal(k1, (m, n), dtype))
    V, _ = jnp.linalg.qr(jax.random.normal(k2, (n, n), dtype))
    sv = jnp.logspace(0, -jnp.log10(cond), n, dtype=dtype)
    return (U * sv) @ V.T


def _r_agrees(R, R_ref, tol):
    """R factors agree up to column-sign convention."""
    diff = jnp.linalg.norm(jnp.abs(R) - jnp.abs(R_ref))
    assert float(diff / jnp.linalg.norm(R_ref)) < tol


@pytest.mark.parametrize("mode", ["tree", "cholqr"])
@pytest.mark.parametrize("cond", [1e2, 1e10])
def test_tsqr_matches_householder(mode, cond):
    B = _conditioned(jax.random.key(0), 2048, 40, cond)
    Q, R = tsqr(B, mode=mode, interpret=True)
    _, R_ref = jnp.linalg.qr(B, mode="reduced")
    _r_agrees(R, R_ref, 1e-10)
    # Householder-grade orthogonality and reconstruction
    n = B.shape[1]
    orth = jnp.linalg.norm(Q.T @ Q - jnp.eye(n, dtype=B.dtype))
    assert float(orth) < 1e-12
    recon = jnp.linalg.norm(Q @ R - B) / jnp.linalg.norm(B)
    assert float(recon) < 1e-12


def test_tsqr_positive_diag():
    B = jax.random.normal(jax.random.key(1), (512, 32), jnp.float64)
    for mode in ("tree", "cholqr"):
        _, R = tsqr(B, mode=mode, interpret=True)
        assert bool(jnp.all(jnp.diag(R) >= 0))


def test_panel_gram_matches_ref():
    B = jax.random.normal(jax.random.key(2), (700, 48), jnp.float32)
    G = panel_gram(B, block_rows=256, interpret=True)
    G_ref = B.T @ B
    assert float(jnp.linalg.norm(G - G_ref) / jnp.linalg.norm(G_ref)) < 1e-5


def test_cholqr_finish_rebuilds_factor():
    B = _conditioned(jax.random.key(3), 1024, 32, 1e8)
    Q, R = cholqr_finish(B, B.T @ B)
    n = B.shape[1]
    assert float(jnp.linalg.norm(Q.T @ Q - jnp.eye(n, dtype=B.dtype))) < 1e-12
    _, R_ref = jnp.linalg.qr(B, mode="reduced")
    _r_agrees(R, R_ref, 1e-10)


@pytest.mark.parametrize("kind", ["countsketch", "uniform_dense", "gaussian"])
def test_fused_gram_kernels_match_reference(kind):
    """Interpret-mode fused kernels: B matches the reference apply, G = BᵀB."""
    m, n, d = 512, 32, 128
    A = jax.random.normal(jax.random.key(4), (m, n), jnp.float32)
    op = sketch_lib.sample(kind, jax.random.key(5), d, m, dtype=jnp.float32)
    if kind == "countsketch":
        B, G = fused_lib.countsketch_gram(
            A, op.buckets, op.signs, d, block_m=256, block_d=128, interpret=True
        )
    elif kind == "uniform_dense":
        B, G = fused_lib.matmul_gram(op.S, A, block_m=256, block_d=128,
                                     interpret=True)
    else:
        B, G = fused_lib.gaussian_gram(
            A, op.key, d, block_m=256, block_d=128, interpret=True
        )
    B_ref = op.apply(A, backend="reference")
    if kind == "gaussian":
        # in-kernel PRNG regenerates S with a kernel-specific stream: B is
        # a valid draw of the same sketch family, not bit-equal to the
        # reference draw — check the embedding moments instead
        assert B.shape == B_ref.shape
        col = jnp.linalg.norm(A, axis=0)
        col_s = jnp.linalg.norm(B, axis=0)
        assert float(jnp.max(jnp.abs(col_s - col) / col)) < 0.5
    else:
        assert float(
            jnp.linalg.norm(B - B_ref) / jnp.linalg.norm(B_ref)
        ) < 1e-5
    G_self = B.T @ B
    assert float(jnp.linalg.norm(G - G_self) / jnp.linalg.norm(G_self)) < 1e-4


@pytest.mark.parametrize("kind", FUSABLE_KINDS)
def test_sketch_qr_matches_seed_pipeline(kind):
    """Fused sketch_qr R == (up to signs) apply → Householder QR R."""
    m, n, d = 3000, 36, 144
    A = _conditioned(jax.random.key(6), m, n, 1e6)
    op = sketch_lib.sample(kind, jax.random.key(7), d, m, dtype=A.dtype)
    Q, R, B = sketch_qr(op, A, backend="reference")
    B_ref = op.apply_op(linop.as_operator(A), backend="reference")
    assert float(jnp.linalg.norm(B - B_ref) / jnp.linalg.norm(B_ref)) < 1e-12
    _, R_ref = jnp.linalg.qr(B_ref, mode="reduced")
    _r_agrees(R, R_ref, 1e-9)
    assert float(
        jnp.linalg.norm(Q.T @ Q - jnp.eye(n, dtype=A.dtype))
    ) < 1e-11


def test_sketch_qr_is_jittable():
    """The fused pipeline compiles as ONE computation (the bench contract)."""
    m, n, d = 1024, 24, 96
    A = jax.random.normal(jax.random.key(8), (m, n), jnp.float64)
    op = sketch_lib.sample("countsketch", jax.random.key(9), d, m,
                           dtype=A.dtype)

    @jax.jit
    def fused(A):
        _, R, _ = sketch_qr(op, A, backend="reference")
        return R

    _, R_ref = jnp.linalg.qr(
        op.apply_op(linop.as_operator(A), backend="reference"), mode="reduced"
    )
    _r_agrees(fused(A), R_ref, 1e-10)
