"""Streaming sketch engine: accumulators, sources, two-pass solvers.

The load-bearing invariant: with the same key the streamed operator IS the
monolithic operator (bit-identical S), and streamed accumulation over any
row tiling reproduces the monolithic apply — exactly for the scatter kinds
and SRHT (in-order scatter folds / placement + one finalize transform),
and to accumulation-order rounding for the dense-GEMM kinds (whose S
blocks are still bit-identical; only the fp addition grouping of the
block products differs from one big GEMM).
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import SKETCH_KINDS, lstsq, qr_solve, sample_sketch
from repro.core import sketch as sketch_lib
from repro.core.precond import SketchedFactor
from repro.streaming import (
    ArraySource,
    CallbackSource,
    GeneratorSource,
    MemmapSource,
    ShardedSource,
    StreamingSolver,
    accumulate_source,
    as_source,
    make_accumulator,
    merge_all,
    sharded_sketch,
    stream_lstsq,
    stream_sketch,
)

ALL_KINDS = sorted(set(SKETCH_KINDS) - {"clarkson_woodruff"})
# Streamed == monolithic bitwise for these; the dense-GEMM kinds
# (gaussian, uniform_dense) agree to accumulation-order rounding.
EXACT_KINDS = ("countsketch", "sparse_sign", "uniform_sparse", "srht")

M_ROWS, N_COLS = 1800, 20


@pytest.fixture(scope="module")
def prob():
    k1, k2 = jax.random.split(jax.random.key(0))
    A = jax.random.normal(k1, (M_ROWS, N_COLS))
    b = jax.random.normal(k2, (M_ROWS,))
    return A, b, qr_solve(A, b)


def relerr(x, ref):
    return float(jnp.linalg.norm(x - ref) / jnp.linalg.norm(ref))


# ---------------------------------------------------------------------------
# accumulators
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind", ALL_KINDS)
def test_streamed_accumulation_matches_monolithic(prob, kind):
    A, _, _ = prob
    op = sample_sketch(kind, jax.random.key(1), 4 * N_COLS, M_ROWS)
    src = ArraySource(A, tile_rows=500)
    B = accumulate_source(op, src).finalize()
    mono = op.apply(A)
    if kind in EXACT_KINDS:
        assert jnp.array_equal(B, mono)
    else:
        assert jnp.allclose(B, mono, rtol=0, atol=1e-13)


@pytest.mark.parametrize("kind", ALL_KINDS)
def test_merge_combines_disjoint_partials(prob, kind):
    A, _, _ = prob
    op = sample_sketch(kind, jax.random.key(2), 3 * N_COLS, M_ROWS)
    cuts = [0, 311, 900, 901, M_ROWS]
    accs = []
    for a, b_ in zip(cuts[:-1], cuts[1:]):
        acc = make_accumulator(op, N_COLS)
        acc.update(A[a:b_], a)
        accs.append(acc)
    merged = merge_all(accs)
    assert merged.rows_seen == M_ROWS
    assert jnp.allclose(merged.finalize(), op.apply(A), rtol=0, atol=1e-12)


def test_finalize_refuses_partial_coverage(prob):
    A, _, _ = prob
    op = sample_sketch("countsketch", jax.random.key(3), 64, M_ROWS)
    acc = make_accumulator(op, N_COLS)
    acc.update(A[:100], 0)
    with pytest.raises(ValueError, match="covered 100 of"):
        acc.finalize()
    with pytest.raises(ValueError, match="outside"):
        acc.update(A[:100], M_ROWS - 50)


def test_merge_rejects_mismatched_draws(prob):
    A, _, _ = prob
    op1 = sample_sketch("countsketch", jax.random.key(4), 64, M_ROWS)
    op2 = sample_sketch("gaussian", jax.random.key(4), 64, M_ROWS)
    a1 = make_accumulator(op1, N_COLS)
    a2 = make_accumulator(op2, N_COLS)
    with pytest.raises(ValueError, match="same operator draw"):
        a1.merge(a2)
    # same kind and SHAPE but a different draw must be rejected too — the
    # sum of two different sketches is a silently corrupted B
    for kind in ("countsketch", "gaussian"):
        x = sample_sketch(kind, jax.random.key(5), 64, M_ROWS)
        y = sample_sketch(kind, jax.random.key(6), 64, M_ROWS)
        with pytest.raises(ValueError, match="same operator draw"):
            make_accumulator(x, N_COLS).merge(make_accumulator(y, N_COLS))
    # ... while an equal draw from a distinct object merges fine
    x = sample_sketch("gaussian", jax.random.key(5), 64, M_ROWS)
    y = sample_sketch("gaussian", jax.random.key(5), 64, M_ROWS)
    ax = make_accumulator(x, N_COLS).update(A[:900], 0)
    ay = make_accumulator(y, N_COLS).update(A[900:], 900)
    assert jnp.allclose(
        ax.merge(ay).finalize(), x.apply(A), rtol=0, atol=1e-12
    )


@pytest.mark.slow
def test_sharded_sketch_psum_merge(prob):
    """The shard_map + psum assembly equals the monolithic apply (the
    collective form of the accumulator merge), for every additive kind."""
    A, _, _ = prob
    mesh = jax.make_mesh((1,), ("data",))
    for kind in ("countsketch", "sparse_sign", "uniform_sparse",
                 "gaussian", "uniform_dense"):
        op = sample_sketch(kind, jax.random.key(5), 3 * N_COLS, M_ROWS)
        B = sharded_sketch(A, op, mesh=mesh)
        assert jnp.allclose(B, op.apply(A), atol=1e-11), kind
    srht = sample_sketch("srht", jax.random.key(5), 3 * N_COLS, M_ROWS)
    with pytest.raises(ValueError, match="stream_semantics"):
        sharded_sketch(A, srht, mesh=mesh)


def test_gaussian_streams_without_materializing_s(prob):
    """The streaming draw keeps S unmaterialized (S=None) and regenerates
    bit-identical column blocks from the key's counter stream."""
    A, _, _ = prob
    lazy = sketch_lib.GaussianSketch.sample(
        jax.random.key(6), 64, M_ROWS, materialize=False
    )
    assert lazy.S is None
    stored = sketch_lib.GaussianSketch.sample(jax.random.key(6), 64, M_ROWS)
    assert jnp.array_equal(lazy.as_dense(), stored.S)
    assert jnp.array_equal(
        lazy.apply_rows(A[300:700], 300), stored.S[:, 300:700] @ A[300:700]
    )
    _, op, _ = stream_sketch(ArraySource(A, tile_rows=256),
                             jax.random.key(6), sketch="gaussian")
    assert op.S is None


# ---------------------------------------------------------------------------
# sources
# ---------------------------------------------------------------------------


def test_sources_agree(prob, tmp_path):
    """Memmap, callback, generator and sharded sources all produce the
    same sketch as the in-memory array source (identical tiles ⇒
    identical accumulation)."""
    A, _, _ = prob
    op = sample_sketch("countsketch", jax.random.key(7), 64, M_ROWS)
    ref = accumulate_source(op, ArraySource(A, tile_rows=499)).finalize()

    path = tmp_path / "a.npy"
    np.save(path, np.asarray(A))
    mm = MemmapSource(path, tile_rows=499)
    assert mm.shape == (M_ROWS, N_COLS)
    assert jnp.array_equal(accumulate_source(op, mm).finalize(), ref)

    cb = CallbackSource(lambda o, t: A[o : o + t], A.shape, A.dtype,
                        tile_rows=499)
    assert jnp.array_equal(accumulate_source(op, cb).finalize(), ref)

    gen = GeneratorSource(
        lambda: (np.asarray(A[o : o + 499]) for o in range(0, M_ROWS, 499)),
        A.shape, A.dtype,
    )
    # re-streamable: consume twice (the two-pass solvers rely on this)
    assert jnp.array_equal(accumulate_source(op, gen).finalize(), ref)
    assert jnp.array_equal(accumulate_source(op, gen).finalize(), ref)

    sh = ShardedSource([ArraySource(A[:700], tile_rows=499),
                        ArraySource(A[700:], tile_rows=499)])
    assert sh.shape == (M_ROWS, N_COLS)
    assert sh.shard_offsets == [0, 700]
    assert jnp.array_equal(accumulate_source(op, sh).finalize(), ref)
    # per-shard partials with global offsets merge to the same sketch
    # (merge SUMS partial states — associative, but a different fp fold
    # grouping than the sequential stream, hence allclose not array_equal)
    parts = [
        accumulate_source(op, s, base_offset=o)
        for s, o in zip(sh.shards, sh.shard_offsets)
    ]
    assert jnp.allclose(merge_all(parts).finalize(), ref, rtol=0, atol=1e-12)


def test_generator_source_validates_coverage(prob):
    A, _, _ = prob
    op = sample_sketch("countsketch", jax.random.key(8), 64, M_ROWS)
    short = GeneratorSource(lambda: iter([np.asarray(A[:100])]),
                            A.shape, A.dtype)
    with pytest.raises(ValueError, match="covered 100 of m"):
        accumulate_source(op, short)


def test_as_source_coercion(prob, tmp_path):
    A, _, _ = prob
    src = as_source(A, tile_rows=256)
    assert isinstance(src, ArraySource) and src.tile_rows == 256
    path = tmp_path / "a.npy"
    np.save(path, np.asarray(A))
    assert isinstance(as_source(str(path)), MemmapSource)
    assert as_source(src) is src
    with pytest.raises(ValueError, match="tile_rows cannot override"):
        as_source(src, tile_rows=128)
    with pytest.raises(TypeError, match="cannot make a RowSource"):
        as_source(object())


# ---------------------------------------------------------------------------
# two-pass solvers
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind", ALL_KINDS)
def test_stream_lstsq_matches_monolithic(prob, kind):
    """Acceptance: streamed solve == in-memory lstsq to machine precision
    for every sketch kind (same key ⇒ bit-identical S)."""
    A, b, x_qr = prob
    key = jax.random.key(9)
    src = ArraySource(A, tile_rows=431)
    rs = stream_lstsq(src, b, key, method="saa", sketch=kind)
    rm = lstsq(A, b, key, method="saa", sketch=kind)
    assert relerr(rs.x, x_qr) < 1e-10
    assert relerr(rm.x, x_qr) < 1e-10
    assert relerr(rs.x, rm.x) < 1e-9
    assert rs.method == "stream_saa"


def test_stream_iterative_matches_monolithic(prob):
    A, b, x_qr = prob
    key = jax.random.key(10)
    rs = stream_lstsq(ArraySource(A, tile_rows=500), b, key,
                      method="iterative", history=True)
    rm = lstsq(A, b, key, method="iterative")
    assert relerr(rs.x, x_qr) < 1e-10
    assert relerr(rs.x, rm.x) < 1e-9
    assert rs.method == "stream_iterative"
    assert rs.history.shape[0] == int(rs.itn)
    # diagnostics are recomputed from a final fused pass
    r = b - A @ rs.x
    assert float(rs.rnorm) == pytest.approx(float(jnp.linalg.norm(r)), rel=1e-9)


def test_stream_single_pass(prob):
    """sketch_and_solve is pass-1 only: the x̂ = R⁻¹Qᵀ(Sb) estimate with no
    second stream, hence nan diagnostics."""
    A, _, _ = prob
    # small residual: sketch-and-solve error is O(ε·‖r‖), so keep ‖r‖ tiny
    # to see the estimate land near the minimizer in one pass
    x_true = jax.random.normal(jax.random.key(20), (N_COLS,))
    b = A @ x_true + 1e-6 * jax.random.normal(jax.random.key(21), (M_ROWS,))
    x_qr = qr_solve(A, b)
    key = jax.random.key(11)
    res = stream_lstsq(A, b, key, method="sketch_and_solve", tile_rows=300)
    assert int(res.itn) == 0
    assert jnp.isnan(res.rnorm) and jnp.isnan(res.arnorm)
    assert relerr(res.x, x_qr) < 1e-5
    # identical to the monolithic sketch-and-solve with the same S
    factor, op = SketchedFactor.build(A, key)
    x_mono = factor.sketch_and_solve(op.apply(b))
    assert relerr(res.x, x_mono) < 1e-12


def test_stream_lstsq_ridge(prob):
    A, b, _ = prob
    lam = 0.7
    x_ridge = jnp.linalg.solve(
        A.T @ A + lam * jnp.eye(N_COLS), A.T @ b
    )
    for method in ("saa", "iterative"):
        res = stream_lstsq(A, b, jax.random.key(12), reg=lam, method=method,
                           tile_rows=512)
        assert relerr(res.x, x_ridge) < 1e-8, method
        # diagnostics are for the ORIGINAL system, matching lstsq(reg=...)
        r = b - A @ res.x
        g = A.T @ r - lam * res.x
        assert float(res.rnorm) == pytest.approx(
            float(jnp.linalg.norm(r)), rel=1e-9
        )
        assert float(res.arnorm) == pytest.approx(
            float(jnp.linalg.norm(g)), rel=1e-6, abs=1e-12
        )


def test_lstsq_accepts_row_source(prob):
    """The one-call driver routes RowSource inputs to the streaming path."""
    A, b, x_qr = prob
    res = lstsq(ArraySource(A, tile_rows=600), b, jax.random.key(13))
    assert res.method == "stream_iterative"
    assert relerr(res.x, x_qr) < 1e-10
    with pytest.raises(ValueError, match="unknown streaming method"):
        lstsq(ArraySource(A, tile_rows=600), b, jax.random.key(13),
              method="direct")


def test_stream_lstsq_validation(prob):
    A, b, _ = prob
    with pytest.raises(ValueError, match="needs a PRNG key"):
        stream_lstsq(A, b, tile_rows=500)
    with pytest.raises(ValueError, match="b must have shape"):
        stream_lstsq(A, b[:-1], jax.random.key(0), tile_rows=500)


def test_build_streaming_factor_parity(prob):
    """SketchedFactor.build_streaming == SketchedFactor.build (same key):
    the streamed sketch is the SAME B, so the QR factor is identical."""
    A, _, _ = prob
    f_st, op_st = SketchedFactor.build_streaming(
        ArraySource(A, tile_rows=700), jax.random.key(14)
    )
    f_mono, op_mono = SketchedFactor.build(A, jax.random.key(14))
    assert jnp.array_equal(f_st.R, f_mono.R)
    assert jnp.array_equal(op_st.buckets, op_mono.buckets)


# ---------------------------------------------------------------------------
# session
# ---------------------------------------------------------------------------


def test_streaming_solver_amortizes(prob):
    A, b, x_qr = prob
    solver = StreamingSolver(ArraySource(A, tile_rows=600),
                             jax.random.key(15))
    assert solver.stats["sketches"] == 1
    assert solver.stats["qr_factorizations"] == 1
    assert solver.stats["passes"] == 1  # pass 1 only at build time
    for i, method in enumerate(("saa", "iterative", "sketch_and_solve")):
        res = solver.solve(b, method=method)
        assert solver.stats["solves"] == i + 1
    # no re-sketch, no re-factor, whatever the solve method
    assert solver.stats["sketches"] == 1
    assert solver.stats["qr_factorizations"] == 1
    assert relerr(solver.solve(b).x, x_qr) < 1e-10


def test_streaming_solver_solve_many(prob):
    A, b, _ = prob
    solver = StreamingSolver(ArraySource(A, tile_rows=600),
                             jax.random.key(16))
    B = jnp.stack([b, -0.5 * b, b + 0.1], axis=1)
    passes_before = solver.stats["passes"]
    res = solver.solve_many(B)
    assert res.x.shape == (N_COLS, 3)
    for j in range(3):
        assert relerr(res.x[:, j], qr_solve(A, B[:, j])) < 1e-9, j
    assert solver.stats["solves"] == 3
    # the batched LSQR shares every stream across the k columns: the pass
    # count is set by the iteration count (2 streams/iter + setup +
    # diagnostics), not by k
    assert solver.stats["passes"] - passes_before <= 2 * int(res.itn) + 4
    with pytest.raises(ValueError, match="solve_many needs B"):
        solver.solve_many(b)


def test_streaming_solver_ridge(prob):
    A, b, _ = prob
    lam = 0.4
    x_ridge = jnp.linalg.solve(A.T @ A + lam * jnp.eye(N_COLS), A.T @ b)
    solver = StreamingSolver(A, jax.random.key(17), reg=lam, tile_rows=512)
    assert relerr(solver.solve(b).x, x_ridge) < 1e-8
    assert relerr(solver.solve(b, method="iterative").x, x_ridge) < 1e-8


# ---------------------------------------------------------------------------
# random tilings (satellite property test)
#
# The property itself is checked on deterministic pseudo-random tilings so
# a bare environment still runs it; when hypothesis is installed
# (requirements-dev / CI) the same property additionally runs under
# hypothesis-driven generation.
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - CI installs hypothesis
    HAVE_HYPOTHESIS = False


def _check_streamed_equals_monolithic(kind, m, cuts, seed):
    """For every kind, streamed accumulation over an arbitrary tiling
    (uneven tiles, single-row tiles, uneven final tile) equals the
    monolithic apply — EXACTLY for the scatter kinds and SRHT; for the
    dense-GEMM kinds the streamed S is still bit-identical and only the
    block-product fp addition order differs (checked at ≤ 1e-13)."""
    n = 1 + seed % 5
    d = 2 + seed % 17
    op = sample_sketch(kind, jax.random.key(seed), d, m)
    A = jax.random.normal(jax.random.key(seed + 1), (m, n))
    src = ArraySource(A, boundaries=cuts)
    B = accumulate_source(op, src).finalize()
    mono = op.apply(A)
    if kind in EXACT_KINDS:
        assert jnp.array_equal(B, mono)
    else:
        scale = max(float(jnp.abs(mono).max()), 1.0)
        assert jnp.allclose(B, mono, rtol=0, atol=1e-13 * scale)
        # the streamed operator itself IS the monolithic operator: streaming
        # the identity recovers S bit-for-bit (placement, no summation)
        S = accumulate_source(
            op, ArraySource(jnp.eye(m, dtype=A.dtype), boundaries=cuts)
        ).finalize()
        assert jnp.array_equal(S, op.as_dense().astype(A.dtype))


def _random_tiling(rng):
    m = int(rng.integers(5, 200))
    cuts = sorted(set(rng.integers(1, m, size=int(rng.integers(0, 9))).tolist()))
    return m, cuts


@pytest.mark.slow
@pytest.mark.parametrize("kind", ALL_KINDS)
@pytest.mark.parametrize("case", range(4))
def test_streamed_equals_monolithic_random_tiling(kind, case):
    # deterministic seed (hash() is PYTHONHASHSEED-salted → unreproducible)
    rng = np.random.default_rng(1000 * case + ALL_KINDS.index(kind))
    m, cuts = _random_tiling(rng)
    if case == 1:
        cuts = list(range(1, m))  # degenerate: every tile is one row
    _check_streamed_equals_monolithic(kind, m, cuts, int(rng.integers(2**30)))


if HAVE_HYPOTHESIS:

    @st.composite
    def tilings(draw):
        """(m, boundaries) with uneven tiles, single-row tiles and an
        uneven final tile."""
        m = draw(st.integers(min_value=5, max_value=200))
        n_cuts = draw(st.integers(min_value=0, max_value=8))
        cuts = draw(
            st.lists(st.integers(min_value=1, max_value=m - 1),
                     min_size=n_cuts, max_size=n_cuts)
        )
        return m, sorted(set(cuts))

    @settings(max_examples=6, deadline=None)
    @given(st.sampled_from(ALL_KINDS), tilings(), st.integers(0, 2**30))
    def test_streamed_equals_monolithic_any_tiling(kind, m_cuts, seed):
        m, cuts = m_cuts
        _check_streamed_equals_monolithic(kind, m, cuts, seed)

    @settings(max_examples=4, deadline=None)
    @given(st.integers(5, 150), st.integers(0, 2**30))
    def test_single_row_tiles_exact(m, seed):
        """Degenerate tiling: every tile is one row."""
        op = sample_sketch("countsketch", jax.random.key(seed), 7, m)
        A = jax.random.normal(jax.random.key(seed + 1), (m, 3))
        src = ArraySource(A, boundaries=list(range(1, m)))
        assert src.tile_rows == 1
        B = accumulate_source(op, src).finalize()
        assert jnp.array_equal(B, op.apply(A))


def _examples_dir():
    return os.path.join(os.path.dirname(__file__), os.pardir, "examples")


def test_streaming_example_exists():
    """CI smoke-runs examples/streaming_lstsq.py; keep the path stable."""
    assert os.path.exists(
        os.path.join(_examples_dir(), "streaming_lstsq.py")
    )
