"""repro.cluster: sharding, fault injection, and the coordinator engine.

The load-bearing claims, in test order:

- row-range partitioning is tile-aligned, balanced and deterministic, and
  ownership reassignment after a death is too;
- a :class:`RowRangeSource` yields exactly the parent's global-grid tiles
  restricted to its window, for random-access and sequential parents;
- the cluster engine's pass-1 sketch equals the single-stream sketch
  (allclose at merge-grouping rounding for the additive kinds, bit-equal
  for SRHT whose placement never sums across ranges), and its pass-2
  products equal the dense ones;
- a worker killed mid-pass is recovered from its accumulator checkpoint
  and the faulted run's merged sketch is BIT-EQUAL to the unfaulted
  cluster run's — resume adds no rounding;
- zombie/duplicate submissions are deduped, heartbeat-stale workers are
  evicted, and the recovery budget is enforced;
- ``stream_lstsq`` / ``StreamingSolver`` / ``lstsq`` route through the
  pool via ``cluster=``.

The full kill-and-resume memmap solve (the ISSUE acceptance demo) is the
``slow``-marked test at the bottom.
"""
import os
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cluster import (
    ClusterEngine,
    ClusterFailure,
    ClusterSpec,
    DelayWorker,
    DuplicateMerge,
    FaultPlan,
    KillWorker,
    OwnershipMap,
    RowRange,
    RowRangeSource,
    partition_rows,
    split_range,
)
from repro.core import generate_problem, lstsq, qr_solve
from repro.streaming import (
    ArraySource,
    GeneratorSource,
    MemmapSource,
    StreamingSolver,
    stream_lstsq,
    stream_sketch,
)

M, N = 600, 12
TILE = 50


@pytest.fixture(scope="module")
def prob():
    key = jax.random.key(0)
    A = jnp.asarray(
        np.asarray(jax.random.normal(key, (M, N)), np.float64)
    )
    b = jnp.asarray(
        np.asarray(jax.random.normal(jax.random.fold_in(key, 1), (M,)),
                   np.float64)
    )
    return A, b


def make_engine(A, *, workers=3, faults=None, ckpt_dir=None,
                checkpoint_every=1, **kw):
    spec = ClusterSpec(num_workers=workers, faults=faults, ckpt_dir=ckpt_dir,
                       checkpoint_every=checkpoint_every, **kw)
    return ClusterEngine(ArraySource(np.asarray(A), tile_rows=TILE), spec)


# ---------------------------------------------------------------------------
# sharding arithmetic
# ---------------------------------------------------------------------------


def test_partition_rows_tile_aligned_and_balanced():
    ranges = partition_rows(1000, 3, 128)  # 8 tiles over 3 workers: 3/3/2
    assert [r.tiles(128) for r in ranges] == [3, 3, 2]
    assert ranges[0].start == 0 and ranges[-1].stop == 1000
    for a, b in zip(ranges[:-1], ranges[1:]):
        assert a.stop == b.start  # contiguous
        assert a.stop % 128 == 0  # on the grid
    # more workers than tiles: the surplus idles on empty ranges
    ranges = partition_rows(100, 4, 64)
    assert [r.rows for r in ranges] == [64, 36, 0, 0]
    with pytest.raises(ValueError, match="need >= 1 worker"):
        partition_rows(100, 0, 64)


def test_split_range_reassignment_arithmetic():
    rng = RowRange(128, 1000)
    parts = split_range(rng, 2, 128)
    assert parts[0].start == 128 and parts[-1].stop == 1000
    assert sum(p.tiles(128) for p in parts) == rng.tiles(128)
    for p in parts[:-1]:
        assert p.stop % 128 == 0
    assert split_range(RowRange(5, 5), 3, 2) == []
    # never more pieces than tiles
    assert len(split_range(RowRange(0, 100), 8, 50)) == 2


def test_ownership_reassign_least_loaded_deterministic():
    own = OwnershipMap.initial(1000, [0, 1, 2], 128)
    assert own.remaining_tiles(0) == 3 and own.remaining_tiles(2) == 2
    moved = own.reassign(0, [1, 2])
    # worker 2 had the least work, so it takes the dead worker's range
    assert moved == [(2, RowRange(0, 384))]
    assert own.owner_of(RowRange(0, 384)) == 2
    assert 0 not in own.assignments
    with pytest.raises(RuntimeError, match="no live workers"):
        own.reassign(1, [])


def test_row_range_source_random_access(prob, tmp_path):
    A, _ = prob
    path = tmp_path / "a.npy"
    np.save(path, np.asarray(A))
    parent = MemmapSource(path, tile_rows=TILE)
    sub = RowRangeSource(parent, 75, 300, tile_rows=TILE)
    assert sub.shape == (225, N)
    offs, tiles = zip(*sub.tiles())
    # windows follow the PARENT grid: first a partial tile up to the next
    # grid edge, then whole tiles, local offsets relative to start=75
    assert list(offs) == [0, 25, 75, 125, 175]
    assert np.array_equal(np.concatenate(tiles), np.asarray(A[75:300]))
    assert np.array_equal(sub.read_rows(10, 5), np.asarray(A[85:90]))
    with pytest.raises(ValueError, match="outside"):
        sub.read_rows(220, 10)
    with pytest.raises(ValueError, match="outside the parent"):
        RowRangeSource(parent, 100, M + 1)


def test_row_range_source_sequential_fallback(prob):
    A, _ = prob
    An = np.asarray(A)
    parent = GeneratorSource(
        lambda: (An[o : o + TILE] for o in range(0, M, TILE)),
        A.shape, A.dtype, tile_rows=TILE,
    )
    assert not parent.supports_random_access
    sub = RowRangeSource(parent, 75, 300, tile_rows=TILE)
    offs, tiles = zip(*sub.tiles())
    assert list(offs) == [0, 25, 75, 125, 175]
    assert np.array_equal(np.concatenate(tiles), An[75:300])
    with pytest.raises(TypeError, match="random access"):
        sub.read_rows(0, 5)


def test_fault_plan_take_is_thread_safe():
    """A fire-once event polled concurrently from many worker threads
    must fire exactly once (the check-then-append is locked)."""
    plan = FaultPlan(DuplicateMerge(worker=0))
    n_threads = 8
    barrier = threading.Barrier(n_threads)
    results = []

    def poll():
        barrier.wait()
        results.append(plan.duplicate_submission(0))

    threads = [threading.Thread(target=poll) for _ in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert sum(results) == 1
    assert len(plan.fired) == 1


def test_fault_plan_fire_once_bookkeeping():
    plan = FaultPlan(KillWorker(worker=1, at_tile=2), DuplicateMerge(worker=0))
    plan.before_tile(1, "sketch", 0)  # no trigger
    plan.before_tile(1, "matvec", 2)  # wrong phase
    assert plan.fired == []
    with pytest.raises(Exception, match="injected kill"):
        plan.before_tile(1, "sketch", 2)
    plan.before_tile(1, "sketch", 2)  # fire-once: second call is a no-op
    assert plan.duplicate_submission(0) is True
    assert plan.duplicate_submission(0) is False
    assert len(plan.fired) == 2


# ---------------------------------------------------------------------------
# engine parity (no faults)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind", ["countsketch", "srht", "gaussian"])
def test_cluster_sketch_matches_single_stream(prob, tmp_path, kind):
    A, b = prob
    serial = ArraySource(np.asarray(A), tile_rows=TILE)
    B0, op0, c0 = stream_sketch(serial, jax.random.key(7), sketch=kind,
                                sketch_size=128, rhs=b)
    eng = make_engine(A, ckpt_dir=str(tmp_path))
    B1, op1, c1 = stream_sketch(eng, jax.random.key(7), sketch=kind,
                                sketch_size=128, rhs=b)
    eng.close()
    if kind == "srht":
        # placement semantics: ranges write disjoint buffer rows, the
        # merge adds exact zeros — bit-equal even across the fan-out
        assert jnp.array_equal(B0, B1) and jnp.array_equal(c0, c1)
    else:
        assert jnp.allclose(B0, B1, rtol=0, atol=1e-12)
        assert jnp.allclose(c0, c1, rtol=0, atol=1e-12)
    assert eng.stats["passes"] == 1
    assert eng.stats["tiles"] == M // TILE


def test_cluster_pass2_products_match_dense(prob):
    A, b = prob
    eng = make_engine(A, checkpoint_every=0)
    x = jnp.asarray(np.linspace(0.0, 1.0, N))
    u = jnp.asarray(np.linspace(0.0, 1.0, M))
    assert jnp.allclose(eng.matvec(x), A @ x, rtol=0, atol=1e-12)
    assert jnp.allclose(eng.rmatvec(u), A.T @ u, rtol=0, atol=1e-12)
    rn2, g = eng.residual_grad(b, x)
    r = b - A @ x
    assert jnp.allclose(jnp.sqrt(rn2), jnp.linalg.norm(r), rtol=1e-12)
    assert jnp.allclose(g, A.T @ r, rtol=0, atol=1e-10)
    eng.close()


# ---------------------------------------------------------------------------
# fault tolerance
# ---------------------------------------------------------------------------


def _cluster_sketch(A, b, tmp, *, faults=None, workers=3,
                    checkpoint_every=1, **kw):
    eng = make_engine(A, workers=workers, faults=faults, ckpt_dir=tmp,
                      checkpoint_every=checkpoint_every, **kw)
    B, _, c = stream_sketch(eng, jax.random.key(7), sketch_size=128, rhs=b)
    eng.close()
    return B, c, eng.stats


def test_kill_recovers_from_checkpoint_bit_equal(prob, tmp_path):
    """Worker killed mid-pass: its range resumes from the accumulator
    checkpoint on a surviving worker and the merged sketch is BIT-EQUAL
    to the unfaulted cluster run (resume adds no arithmetic)."""
    A, b = prob
    B0, c0, st0 = _cluster_sketch(A, b, str(tmp_path / "clean"))
    plan = FaultPlan(KillWorker(worker=1, at_tile=2))
    B1, c1, st1 = _cluster_sketch(A, b, str(tmp_path / "kill"), faults=plan)
    assert plan.fired, "the kill must actually have triggered"
    assert st1["recoveries"] == 1
    assert st1["reassignments"] == 1
    assert st1["restores"] == 1, "recovery must resume from the checkpoint"
    assert jnp.array_equal(B0, B1)
    assert jnp.array_equal(c0, c1)


def test_kill_without_checkpoints_restarts_range(prob, tmp_path):
    A, b = prob
    B0, c0, _ = _cluster_sketch(A, b, str(tmp_path / "clean"),
                                checkpoint_every=0)
    B1, c1, st = _cluster_sketch(
        A, b, str(tmp_path / "kill"), checkpoint_every=0,
        faults=[KillWorker(worker=0, at_tile=1)],
    )
    assert st["recoveries"] == 1 and st["restores"] == 0
    assert jnp.array_equal(B0, B1) and jnp.array_equal(c0, c1)


def test_duplicate_submission_deduped(prob, tmp_path):
    A, b = prob
    B0, c0, _ = _cluster_sketch(A, b, str(tmp_path / "clean"))
    B1, c1, st = _cluster_sketch(A, b, str(tmp_path / "dup"),
                                 faults=[DuplicateMerge(worker=0)])
    assert st["duplicates_dropped"] == 1
    assert jnp.array_equal(B0, B1) and jnp.array_equal(c0, c1)


def test_heartbeat_eviction_of_stalled_worker(prob, tmp_path):
    """A stalled (not dead) worker goes heartbeat-stale, is evicted, and
    its range is recomputed elsewhere; the zombie's eventual submission
    must not corrupt the merge."""
    A, b = prob
    B0, c0, _ = _cluster_sketch(A, b, str(tmp_path / "clean"))
    B1, c1, st = _cluster_sketch(
        A, b, str(tmp_path / "slow"),
        faults=[DelayWorker(worker=2, seconds=1.5, at_tile=1)],
        heartbeat_timeout=0.25, poll_interval=0.02,
    )
    assert st["heartbeat_evictions"] >= 1
    assert st["recoveries"] >= 1
    assert jnp.array_equal(B0, B1) and jnp.array_equal(c0, c1)


def test_recovery_budget_enforced(prob, tmp_path):
    A, b = prob
    eng = make_engine(
        A, workers=2, ckpt_dir=str(tmp_path),
        faults=[KillWorker(worker=0, at_tile=0)], max_recoveries=0,
    )
    with pytest.raises(ClusterFailure, match="recovery budget"):
        stream_sketch(eng, jax.random.key(7), sketch_size=128, rhs=b)
    eng.close()


def test_all_workers_dead_respawns(prob, tmp_path):
    """Killing every pool member forces a respawned replacement worker."""
    A, b = prob
    B0, c0, _ = _cluster_sketch(A, b, str(tmp_path / "clean"), workers=2)
    B1, c1, st = _cluster_sketch(
        A, b, str(tmp_path / "wipe"), workers=2,
        faults=[KillWorker(worker=0, at_tile=1),
                KillWorker(worker=1, at_tile=1),
                # replacement workers get fresh ids 2, 3, ...
                ],
        max_recoveries=4,
    )
    assert st["recoveries"] == 2
    assert st["respawns"] >= 1
    assert jnp.array_equal(B0, B1) and jnp.array_equal(c0, c1)


def test_idle_pool_is_not_heartbeat_evicted(prob, tmp_path):
    """A healthy pool that sat idle longer than heartbeat_timeout —
    before its first pass and between passes — must NOT be evicted:
    staleness is measured from task dispatch, not pool construction."""
    A, b = prob
    eng = make_engine(A, ckpt_dir=str(tmp_path),
                      heartbeat_timeout=0.25, poll_interval=0.02)
    time.sleep(0.5)  # idle before the first pass
    B1, _, c1 = stream_sketch(eng, jax.random.key(7), sketch_size=128, rhs=b)
    time.sleep(0.5)  # idle between passes (a session between solves)
    x = jnp.asarray(np.linspace(0.0, 1.0, N))
    y = eng.matvec(x)
    eng.close()
    assert eng.stats["heartbeat_evictions"] == 0
    assert eng.stats["recoveries"] == 0
    serial = ArraySource(np.asarray(A), tile_rows=TILE)
    B0, _, c0 = stream_sketch(serial, jax.random.key(7), sketch_size=128,
                              rhs=b)
    assert jnp.allclose(B0, B1, rtol=0, atol=1e-12)
    assert jnp.allclose(c0, c1, rtol=0, atol=1e-12)
    assert jnp.allclose(y, A @ x, rtol=0, atol=1e-12)


def test_recovery_budget_is_per_pass(prob, tmp_path):
    """One death per pass across two passes must fit max_recoveries=1:
    the budget guards a single fan-out, not the engine lifetime (a
    long-lived session would otherwise accumulate to certain failure)."""
    A, b = prob
    eng = make_engine(
        A, ckpt_dir=str(tmp_path), max_recoveries=1,
        faults=[KillWorker(worker=0, at_tile=1, phase="sketch"),
                KillWorker(worker=1, at_tile=0, phase="matvec")],
    )
    B1, _, c1 = stream_sketch(eng, jax.random.key(7), sketch_size=128, rhs=b)
    x = jnp.asarray(np.linspace(0.0, 1.0, N))
    y = eng.matvec(x)  # second pass, second (budgeted-apart) death
    eng.close()
    assert eng.stats["recoveries"] == 2  # lifetime stat still accumulates
    serial = ArraySource(np.asarray(A), tile_rows=TILE)
    B0, _, c0 = stream_sketch(serial, jax.random.key(7), sketch_size=128,
                              rhs=b)
    assert jnp.allclose(B0, B1, rtol=0, atol=1e-12)
    assert jnp.allclose(c0, c1, rtol=0, atol=1e-12)
    assert jnp.allclose(y, A @ x, rtol=0, atol=1e-12)


def test_stale_checkpoints_never_poison_a_new_run(prob, tmp_path):
    """Leftover checkpoints in a persistent ckpt_dir: a rerun with the
    SAME draw resumes from them; a rerun with a DIFFERENT draw starts
    fresh (different namespace) instead of raising CheckpointMismatch;
    a successful pass clears its own namespace."""
    A, b = prob
    serial = ArraySource(np.asarray(A), tile_rows=TILE)
    ckpt = str(tmp_path)

    # abort a run mid-pass, stranding mid-range checkpoints on disk
    eng = make_engine(A, ckpt_dir=ckpt, max_recoveries=0,
                      faults=[KillWorker(worker=0, at_tile=2)])
    with pytest.raises(ClusterFailure):
        stream_sketch(eng, jax.random.key(7), sketch_size=128, rhs=b)
    eng.close()
    assert any(d.startswith("pass1-") for d in os.listdir(ckpt))

    # same draw + rhs: the rerun resumes from the stranded checkpoints
    eng = make_engine(A, ckpt_dir=ckpt)
    B1, _, c1 = stream_sketch(eng, jax.random.key(7), sketch_size=128, rhs=b)
    eng.close()
    assert eng.stats["restores"] >= 1
    B0, _, c0 = stream_sketch(serial, jax.random.key(7), sketch_size=128,
                              rhs=b)
    assert jnp.allclose(B0, B1, rtol=0, atol=1e-12)
    assert jnp.allclose(c0, c1, rtol=0, atol=1e-12)

    # a different draw lands in a different namespace: fresh start, no
    # CheckpointMismatch surfacing as a task error
    eng = make_engine(A, ckpt_dir=ckpt)
    B2, _, c2 = stream_sketch(eng, jax.random.key(8), sketch_size=128, rhs=b)
    eng.close()
    assert eng.stats["restores"] == 0
    B0b, _, c0b = stream_sketch(serial, jax.random.key(8), sketch_size=128,
                                rhs=b)
    assert jnp.allclose(B0b, B2, rtol=0, atol=1e-12)
    assert jnp.allclose(c0b, c2, rtol=0, atol=1e-12)

    # both successful passes cleaned their namespaces up behind them
    assert not any(d.startswith("pass1-") for d in os.listdir(ckpt))


def _live_cluster_threads(before):
    return [
        t for t in threading.enumerate()
        if t.name.startswith("repro-cluster-w") and t.is_alive()
        and t not in before
    ]


# ---------------------------------------------------------------------------
# routing: stream_lstsq / StreamingSolver / lstsq
# ---------------------------------------------------------------------------


def test_stream_lstsq_cluster_matches_serial(prob, tmp_path):
    A, b = prob
    res0 = stream_lstsq(ArraySource(np.asarray(A), tile_rows=TILE), b,
                        jax.random.key(3), method="saa", sketch_size=128)
    spec = ClusterSpec(num_workers=3, ckpt_dir=str(tmp_path),
                       faults=[KillWorker(worker=0, at_tile=1)])
    res1 = stream_lstsq(ArraySource(np.asarray(A), tile_rows=TILE), b,
                        jax.random.key(3), method="saa", sketch_size=128,
                        cluster=spec)
    assert jnp.allclose(res0.x, res1.x, rtol=0, atol=1e-9)
    assert res1.method == "stream_saa"


def test_lstsq_cluster_coerces_plain_arrays(prob):
    A, b = prob
    x_qr = qr_solve(A, b)
    res = lstsq(A, b, jax.random.key(3), method="saa", sketch_size=128,
                cluster=ClusterSpec(num_workers=2, checkpoint_every=0))
    assert res.method == "stream_saa"
    assert float(jnp.linalg.norm(res.x - x_qr) / jnp.linalg.norm(x_qr)) < 1e-8


def test_streaming_solver_cluster_session(prob, tmp_path):
    A, b = prob
    spec = ClusterSpec(num_workers=2, ckpt_dir=str(tmp_path),
                       checkpoint_every=2)
    solver = StreamingSolver(ArraySource(np.asarray(A), tile_rows=TILE),
                             jax.random.key(3), sketch_size=128, cluster=spec)
    serial = StreamingSolver(ArraySource(np.asarray(A), tile_rows=TILE),
                             jax.random.key(3), sketch_size=128)
    r0, r1 = serial.solve(b), solver.solve(b)
    assert jnp.allclose(r0.x, r1.x, rtol=0, atol=1e-9)
    # the engine's counters hook feeds the session's cost model
    assert solver.stats["passes"] >= 2  # sketch + iteration streams
    assert solver.stats["tiles"] >= 2 * (M // TILE)
    assert solver.stats["solves"] == 1
    solver.close()


def test_stream_lstsq_closes_engines_it_built(prob, monkeypatch):
    """An engine built internally from a ClusterSpec must be torn down
    when the solve returns: no leaked worker threads, no leaked temp
    checkpoint dir (repeated solves would otherwise grow both forever)."""
    import tempfile as tempfile_mod

    A, b = prob
    made = []
    real_mkdtemp = tempfile_mod.mkdtemp

    def recording_mkdtemp(*a, **kw):
        d = real_mkdtemp(*a, **kw)
        made.append(d)
        return d

    monkeypatch.setattr(tempfile_mod, "mkdtemp", recording_mkdtemp)
    before = set(threading.enumerate())
    res = stream_lstsq(
        ArraySource(np.asarray(A), tile_rows=TILE), b, jax.random.key(3),
        method="saa", sketch_size=128,
        cluster=ClusterSpec(num_workers=2, checkpoint_every=2),
    )
    assert res.method == "stream_saa"
    assert _live_cluster_threads(before) == []
    assert made, "the spec path should have made a temp ckpt dir"
    assert not any(os.path.exists(d) for d in made)


def test_stream_lstsq_keeps_caller_engine_open(prob, tmp_path):
    """A prebuilt engine passed via cluster= survives the solve for
    reuse; its caller-provided ckpt_dir survives its own close()."""
    A, b = prob
    eng = make_engine(A, workers=2, ckpt_dir=str(tmp_path),
                      checkpoint_every=0)
    src = ArraySource(np.asarray(A), tile_rows=TILE)
    r1 = stream_lstsq(src, b, jax.random.key(3), method="saa",
                      sketch_size=128, cluster=eng)
    r2 = stream_lstsq(src, b, jax.random.key(3), method="saa",
                      sketch_size=128, cluster=eng)  # still open: reusable
    assert jnp.allclose(r1.x, r2.x, rtol=0, atol=1e-12)
    eng.close()
    eng.close()  # idempotent
    assert os.path.isdir(str(tmp_path))  # caller's dir is not the engine's


def test_streaming_solver_close_releases_owned_engine(prob):
    A, b = prob
    before = set(threading.enumerate())
    with StreamingSolver(
        ArraySource(np.asarray(A), tile_rows=TILE), jax.random.key(3),
        sketch_size=128,
        cluster=ClusterSpec(num_workers=2, checkpoint_every=0),
    ) as solver:
        res = solver.solve(b)
        assert jnp.isfinite(res.rnorm)
    solver.close()  # second close is a no-op
    assert _live_cluster_threads(before) == []


# ---------------------------------------------------------------------------
# the acceptance demo: out-of-core memmap, kill mid-pass, certified answer
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_kill_and_resume_certified_memmap_solve(tmp_path):
    """A memmapped problem larger than any single worker's tile budget,
    solved across 4 workers with a worker killed mid-pass-1: the engine
    restores the dead worker's accumulator checkpoint, reassigns the
    remaining range, the merged sketch is bit-equal to the uninterrupted
    cluster run's (scatter kind), and the final solution matches the
    uninterrupted run's certificate-passing answer."""
    m, n, tile = 12000, 40, 250
    prob = generate_problem(jax.random.key(11), m, n, cond=1e6, beta=1e-4)
    path = tmp_path / "A.npy"
    np.save(path, np.asarray(prob.A))
    b = prob.b

    def solve(ckpt, faults):
        eng = ClusterEngine(
            MemmapSource(path, tile_rows=tile),
            ClusterSpec(num_workers=4, ckpt_dir=str(ckpt), faults=faults,
                        checkpoint_every=3),
        )
        # sketch first: the injected kill fires HERE, so the compared
        # sketch is the one that went through kill-and-resume (the later
        # lstsq pass simply runs on the surviving pool)
        B, _, c = stream_sketch(eng, jax.random.key(5), sketch_size=8 * n,
                                rhs=b)
        res = lstsq(eng, b, jax.random.key(5), accuracy="certified",
                    sketch_size=8 * n)
        eng.close()
        return res, B, c, eng.stats

    res0, B0, c0, st0 = solve(tmp_path / "clean", None)
    plan = FaultPlan(KillWorker(worker=2, at_tile=5))
    res1, B1, c1, st1 = solve(tmp_path / "faulted", plan)

    # each worker held ~1/4 of the tiles; the problem exceeds any single
    # worker's budget by construction
    assert m // tile > 4
    assert plan.fired and st1["recoveries"] == 1 and st1["restores"] == 1
    # the sketch after kill-and-resume is bit-equal (scatter kind)
    assert jnp.array_equal(B0, B1) and jnp.array_equal(c0, c1)
    # both certificates pass and the answers agree
    assert res0.certificate is not None and bool(res0.certificate.passed)
    assert res1.certificate is not None and bool(res1.certificate.passed)
    assert jnp.allclose(res0.x, res1.x, rtol=0, atol=1e-9)
    err = float(jnp.linalg.norm(res1.x - prob.x_true)
                / jnp.linalg.norm(prob.x_true))
    assert err < max(float(res1.certificate.rel_error_bound), 1e-6)


if __name__ == "__main__":
    import sys

    sys.exit(pytest.main([__file__, "-v"] + sys.argv[1:]))
