"""Mixed-precision sketching (precision="mixed") + tile autotuner.

Covers the PR's acceptance criteria:

- for ALL six sketch kinds, a bf16-sketched certified solve on the
  cond=1e8 problem reaches the SAME certified forward-error target as the
  fp32/f64 run — ``Certificate.passed`` both ways at an identical
  ``certified_rtol`` (the driver is allowed to escalate precision to get
  there; the certificate records whether it had to);
- at moderate conditioning the mixed run certifies WITHOUT escalating
  (``escalations == 0``, ``certificate.precision == "mixed"``) — the
  regime where the cheap sketch is free;
- kernel dtype contract: low-precision inputs come back in the f32
  accumulator dtype (never silently downcast);
- forcing a non-sketched method with precision="mixed" raises;
- the autotuner returns feasible block choices and the env kill-switch
  empties them.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.core import generate_problem, qr_solve
from repro.core import backend as backend_lib
from repro.core.lstsq import PRECISION_SUPPORT, lstsq
from repro.kernels import countsketch_apply, sketch_matmul
from repro.kernels.autotune import KINDS, best_blocks, predict_cost

ALL_KINDS = (
    "gaussian",
    "uniform_dense",
    "srht",
    "countsketch",
    "sparse_sign",
    "uniform_sparse",
)

RTOL = 1e-6  # shared certified target for the full-vs-mixed comparison


@pytest.mark.slow
@pytest.mark.parametrize("kind", ALL_KINDS)
def test_mixed_certifies_at_full_precision_rtol(kind):
    """bf16 sketch + fp32 refinement reaches the fp64 certified floor."""
    prob = generate_problem(
        jax.random.key(0), 2048, 32, cond=1e8, beta=1e-10, method="fast"
    )
    A, b = prob.A, prob.b
    x_qr = qr_solve(A, b)
    key = jax.random.key(1)
    results = {}
    for precision in ("full", "mixed"):
        res = lstsq(
            A, b, key, accuracy="certified", sketch=kind,
            precision=precision, certified_rtol=RTOL,
        )
        cert = res.certificate
        assert cert is not None
        assert bool(cert.passed), (
            f"{kind}/{precision}: bound={float(cert.rel_error_bound):.3e}"
        )
        assert float(cert.rel_error_bound) <= RTOL
        # the posterior bound is backed by the TRUE error
        err = float(jnp.linalg.norm(res.x - x_qr) / jnp.linalg.norm(x_qr))
        assert err <= RTOL
        results[precision] = cert
    # the mixed run may have repaired itself back to full precision — the
    # certificate must SAY so rather than silently passing
    assert results["full"].precision == "full"
    assert results["mixed"].precision in ("mixed", "full")


def test_mixed_moderate_cond_stays_mixed():
    """Where bf16 rounding is harmless, no escalation happens at all."""
    prob = generate_problem(
        jax.random.key(2), 2048, 32, cond=1e3, beta=1e-8, method="fast"
    )
    res = lstsq(
        prob.A, prob.b, jax.random.key(3), accuracy="certified",
        precision="mixed",
    )
    cert = res.certificate
    assert bool(cert.passed)
    assert int(cert.escalations) == 0
    assert cert.precision == "mixed"


def test_forced_unsupported_method_raises():
    A = jnp.ones((64, 4))
    b = jnp.ones(64)
    with pytest.raises(ValueError, match="precision"):
        lstsq(A, b, jax.random.key(0), method="lsqr", precision="mixed")
    assert "lsqr" not in PRECISION_SUPPORT


def test_kernels_keep_accumulator_dtype():
    """bf16 inputs return f32 (the mixed contract: no silent downcast)."""
    m, n, d = 512, 32, 128
    A = jax.random.normal(jax.random.key(4), (m, n), jnp.bfloat16)
    buckets = jax.random.randint(jax.random.key(5), (m,), 0, d)
    signs = jax.random.rademacher(jax.random.key(6), (m,), jnp.bfloat16)
    out = countsketch_apply(A, buckets, signs, d, interpret=True)
    assert out.dtype == jnp.float32
    S = jax.random.normal(jax.random.key(7), (d, m), jnp.bfloat16)
    out2 = sketch_matmul(S, A, interpret=True)
    assert out2.dtype == jnp.float32


def test_precisions_registry():
    assert backend_lib.PRECISIONS == ("full", "mixed")
    with pytest.raises(ValueError, match="precision"):
        lstsq(jnp.ones((8, 2)), jnp.ones(8), jax.random.key(0),
              precision="half")


# --------------------------------------------------------------------------
# Autotuner
# --------------------------------------------------------------------------


@pytest.mark.parametrize("kind", sorted(KINDS))
def test_best_blocks_feasible(kind):
    """Winners exist, carry exactly the kind's knobs, and cost finitely."""
    blocks = best_blocks(kind, 16384, 128, 512, "float32", device="TPU_v5e")
    assert set(blocks) == set(KINDS[kind])
    assert all(isinstance(v, int) and v > 0 for v in blocks.values())
    cost = predict_cost(kind, 16384, 128, 512, "float32", blocks)
    assert 0 < cost < float("inf")


def test_best_blocks_alias_and_cache_consistency():
    a = best_blocks("uniform_dense", 8192, 64, 256, "float32",
                    device="TPU_v5e")
    b = best_blocks("sketch_matmul", 8192, 64, 256, "float32",
                    device="TPU_v5e")
    assert a == b


def test_best_blocks_cache_miss_warns_once(caplog):
    """An unseen key falls back to model blocks with ONE warning naming
    them; repeats stay silent, and the fallback equals the cost model."""
    from repro.kernels import autotune

    # an off-sweep shape no committed cache will ever contain
    args = ("countsketch", 12345, 67, 321, "float32")
    key = autotune._key(*args, device="nonexistent_device")
    autotune._MISS_WARNED.discard(key)
    with caplog.at_level("WARNING", logger="repro.kernels.autotune"):
        blocks = best_blocks(*args, device="nonexistent_device")
    hits = [r for r in caplog.records if key in r.getMessage()]
    assert len(hits) == 1
    assert "fallback" in hits[0].getMessage() or "falling back" in hits[0].getMessage()
    assert str(blocks) in hits[0].getMessage()
    assert blocks == dict(autotune._model_best(*args[:4], "float32"))

    caplog.clear()
    with caplog.at_level("WARNING", logger="repro.kernels.autotune"):
        again = best_blocks(*args, device="nonexistent_device")
    assert again == blocks
    assert not [r for r in caplog.records if key in r.getMessage()]


def test_best_blocks_cache_hit_does_not_warn(caplog):
    """Committed-cache hits never touch the warning path."""
    from repro.kernels import autotune

    cached = autotune._load_cache()
    if not cached:
        pytest.skip("no committed autotune cache in this checkout")
    key = next(iter(cached))
    kind, m, n, d, dtype, device = key.split("|")
    m, n, d = (int(s.split("=")[1]) for s in (m, n, d))
    with caplog.at_level("WARNING", logger="repro.kernels.autotune"):
        best_blocks(kind, m, n, d, dtype, device=device)
    assert not [r for r in caplog.records if "cache miss" in r.getMessage()]


def test_kernel_blocks_env_kill_switch(monkeypatch):
    monkeypatch.setenv("REPRO_AUTOTUNE", "0")
    assert backend_lib.kernel_blocks("countsketch", 4096, 64, 256,
                                     "float32") == {}
    monkeypatch.delenv("REPRO_AUTOTUNE", raising=False)
    blocks = backend_lib.kernel_blocks("countsketch", 4096, 64, 256,
                                       "float32")
    assert isinstance(blocks, dict)


def test_resolve_fused_env(monkeypatch):
    monkeypatch.delenv("REPRO_FUSED_QR", raising=False)
    assert backend_lib.resolve_fused(None) is False
    assert backend_lib.resolve_fused(True) is True
    assert backend_lib.resolve_fused(False) is False
    monkeypatch.setenv("REPRO_FUSED_QR", "1")
    assert backend_lib.resolve_fused(None) is True
