"""SketchedSolver: one sketch + QR amortized over many right-hand sides."""
import jax
import jax.numpy as jnp
import pytest
from jax.experimental.sparse import BCOO

from repro.core import (
    SketchedSolver,
    SolveResult,
    linop,
    qr_solve,
)
from repro.core import precond as precond_lib
from repro.core import sketch as sketch_lib

M_ROWS, N_COLS = 1500, 24


@pytest.fixture(scope="module")
def prob():
    k1, k2 = jax.random.split(jax.random.key(0))
    A = jax.random.normal(k1, (M_ROWS, N_COLS))
    b = jax.random.normal(k2, (M_ROWS,))
    return A, b, qr_solve(A, b)


def relerr(x, ref):
    return float(jnp.linalg.norm(x - ref) / jnp.linalg.norm(ref))


def test_k_solves_one_sketch_one_qr(prob, monkeypatch):
    """Acceptance: serving k right-hand sides performs EXACTLY one
    sketch of A and one QR factorization — counted at the call sites, not
    via the session's own bookkeeping."""
    A, b, _ = prob
    counts = {"sample": 0, "qr": 0}
    real_sample = sketch_lib.sample
    real_from_sketch = precond_lib.SketchedFactor.from_sketch.__func__

    def counting_sample(*a, **kw):
        counts["sample"] += 1
        return real_sample(*a, **kw)

    def counting_from_sketch(cls, B):
        counts["qr"] += 1
        return real_from_sketch(cls, B)

    monkeypatch.setattr(sketch_lib, "sample", counting_sample)
    monkeypatch.setattr(
        precond_lib.SketchedFactor,
        "from_sketch",
        classmethod(counting_from_sketch),
    )

    solver = SketchedSolver(A, jax.random.key(1))
    assert counts == {"sample": 1, "qr": 1}
    k = 6
    for i in range(k):
        solver.solve(b + 0.01 * i)
    solver.solve_many(jnp.stack([b, -b], axis=1))
    assert counts == {"sample": 1, "qr": 1}  # nothing rebuilt per solve
    assert solver.stats["sketches"] == 1
    assert solver.stats["qr_factorizations"] == 1
    assert solver.stats["solves"] == k + 2


def test_solve_matches_direct(prob):
    A, b, x_qr = prob
    solver = SketchedSolver(A, jax.random.key(2))
    res = solver.solve(b)
    assert isinstance(res, SolveResult)
    assert res.method == "session"
    assert relerr(res.x, x_qr) < 1e-8


def test_solve_many_matches_columnwise(prob):
    A, b, _ = prob
    solver = SketchedSolver(A, jax.random.key(3))
    B = jnp.stack([b, 0.5 * b + 0.1, -2.0 * b], axis=1)
    res = solver.solve_many(B)
    assert res.x.shape == (N_COLS, 3)
    for j in range(3):
        x_ref = qr_solve(A, B[:, j])
        assert relerr(res.x[:, j], x_ref) < 1e-8, j
    with pytest.raises(ValueError, match="solve_many needs B"):
        solver.solve_many(b)


def test_rhs_validation_up_front(prob):
    """Shape/dtype mismatches fail fast with a clear message, never as an
    XLA shape error deep inside the jitted solve."""
    A, b, _ = prob
    solver = SketchedSolver(A, jax.random.key(11))
    with pytest.raises(ValueError, match="solve needs b of shape"):
        solver.solve(b[:-1])
    with pytest.raises(ValueError, match="solve needs b of shape"):
        solver.solve(jnp.stack([b, b], axis=1))
    with pytest.raises(ValueError, match="solve_many needs B"):
        solver.solve_many(jnp.stack([b, b], axis=1)[:-1])
    # wrong leading dim with the right ndim: still the clear message
    with pytest.raises(ValueError, match="solve_many needs B"):
        solver.solve_many(jnp.zeros((M_ROWS - 3, 2), A.dtype))


def test_rhs_dtype_policy(prob):
    """Safe upcast is taken explicitly; silent promotion is an error."""
    A, b, x_qr = prob
    solver = SketchedSolver(A, jax.random.key(12))  # f64 session
    # f32 RHS fits f64: cast explicitly, solve normally
    res = solver.solve(b.astype(jnp.float32))
    assert res.x.dtype == A.dtype
    assert relerr(res.x, x_qr) < 1e-5  # b was rounded to f32, not the solve
    resm = solver.solve_many(jnp.stack([b, -b], axis=1).astype(jnp.float32))
    assert resm.x.dtype == A.dtype
    # a promoting RHS (complex against a real factor) is refused
    with pytest.raises(TypeError, match="promote"):
        solver.solve(b.astype(jnp.complex128))
    with pytest.raises(TypeError, match="promote"):
        solver.solve_many(jnp.stack([b, b], axis=1).astype(jnp.complex128))
    # and an f32 SESSION refuses an f64 RHS (would silently promote)
    solver32 = SketchedSolver(A.astype(jnp.float32), jax.random.key(13))
    with pytest.raises(TypeError, match="promote"):
        solver32.solve(b)


def test_accepts_sparse_and_operator_inputs(prob):
    A, b, x_qr = prob
    sp = SketchedSolver(BCOO.fromdense(A), jax.random.key(4))
    assert relerr(sp.solve(b).x, x_qr) < 1e-8
    custom = linop.CustomOperator(
        matvec_fn=lambda v: A @ v,
        rmatvec_fn=lambda u: A.T @ u,
        op_shape=tuple(A.shape),
        op_dtype=A.dtype,
    )
    cu = SketchedSolver(custom, jax.random.key(4))
    assert relerr(cu.solve(b).x, x_qr) < 1e-8


def test_update_rows_delta_sketch(prob):
    """Row updates refresh the factor WITHOUT a second full sketch, and the
    updated sketch equals re-sketching the new A with the same S."""
    A, b, _ = prob
    solver = SketchedSolver(A, jax.random.key(5))
    idx = jnp.array([0, 17, 900, M_ROWS - 1])
    rows = jax.random.normal(jax.random.key(6), (4, N_COLS))
    solver.update_rows(idx, rows)
    assert solver.stats["sketches"] == 1  # delta path, no re-sketch
    assert solver.stats["qr_factorizations"] == 2

    A_new = A.at[idx].set(rows)
    B_fresh = solver._sketch_op.apply(A_new)
    assert jnp.allclose(solver._B, B_fresh, atol=1e-9)
    assert relerr(solver.solve(b).x, qr_solve(A_new, b)) < 1e-8


def test_update_rows_srht_resketches_with_same_s(prob):
    """SRHT columns couple through the Hadamard transform — no cheap
    restriction, so update_rows re-sketches (same S, no new draw)."""
    A, b, _ = prob
    solver = SketchedSolver(A, jax.random.key(7), sketch="srht")
    idx = jnp.array([1, 2])
    rows = jax.random.normal(jax.random.key(8), (2, N_COLS))
    solver.update_rows(idx, rows)
    assert solver.stats["sketches"] == 2  # full re-sketch, still one draw
    A_new = A.at[idx].set(rows)
    assert relerr(solver.solve(b).x, qr_solve(A_new, b)) < 1e-8


@pytest.mark.parametrize(
    "kind,sketches_after_update",
    [
        ("countsketch", 1),
        ("sparse_sign", 1),
        ("uniform_sparse", 1),
        ("gaussian", 1),
        ("uniform_dense", 1),
        ("srht", 2),  # the ONE kind without restrict_cols: full re-sketch
    ],
)
def test_update_rows_stats_pinned_per_kind(prob, kind, sketches_after_update):
    """Regression pin for the documented asymmetry: every kind with a
    column restriction (``op.restrict_cols``) refreshes the factor via the
    O(|idx|·n) delta-sketch (``sketches`` stays 1); SRHT — whose columns
    couple through the Hadamard transform — is the only full re-sketch
    (``sketches`` → 2, still no new operator draw).  If a kind silently
    loses its restriction (or SRHT silently gains a wrong one), these
    counters move."""
    A, b, _ = prob
    solver = SketchedSolver(A, jax.random.key(11), sketch=kind)
    assert solver.stats == {"sketches": 1, "qr_factorizations": 1, "solves": 0}
    idx = jnp.array([2, 71, M_ROWS - 3])
    rows = jax.random.normal(jax.random.key(12), (3, N_COLS))
    solver.update_rows(idx, rows)
    assert solver.stats["sketches"] == sketches_after_update
    assert solver.stats["qr_factorizations"] == 2  # always just the small QR
    # either path must land on the sketch of the UPDATED matrix
    A_new = A.at[idx].set(rows)
    assert jnp.allclose(
        solver._B, solver._sketch_op.apply(A_new), atol=1e-9
    )
    assert relerr(solver.solve(b).x, qr_solve(A_new, b)) < 1e-8


def test_update_rows_validation(prob):
    A, b, _ = prob
    solver = SketchedSolver(A, jax.random.key(9))
    with pytest.raises(ValueError, match="rows must have shape"):
        solver.update_rows(jnp.array([0]), jnp.zeros((2, N_COLS)))
    with pytest.raises(ValueError, match="unique row indices"):
        # duplicates would double-count in the delta-sketch (last-write-wins
        # row rewrite vs additive sketch update)
        solver.update_rows(jnp.array([3, 3]), jnp.zeros((2, N_COLS)))
    sp = SketchedSolver(BCOO.fromdense(A), jax.random.key(9))
    with pytest.raises(TypeError, match="dense A"):
        sp.update_rows(jnp.array([0]), jnp.zeros((1, N_COLS)))


def test_session_ridge(prob):
    A, b, _ = prob
    lam = 0.8
    x_ridge = jnp.linalg.solve(
        A.T @ A + lam * jnp.eye(N_COLS), A.T @ b
    )
    solver = SketchedSolver(A, jax.random.key(10), reg=lam)
    res1 = solver.solve(b)
    assert relerr(res1.x, x_ridge) < 1e-8
    # diagnostics are for the ORIGINAL system (like lstsq(reg=...)), not
    # the augmented one whose residual is inflated by the λ‖x‖² penalty
    r = b - A @ res1.x
    assert float(res1.rnorm) == pytest.approx(float(jnp.linalg.norm(r)), rel=1e-9)
    assert float(res1.arnorm) < 1e-8 * float(jnp.linalg.norm(b))
    res = solver.solve_many(jnp.stack([b, -b], axis=1))
    assert relerr(res.x[:, 0], x_ridge) < 1e-8
    assert float(res.rnorm[0]) == pytest.approx(float(jnp.linalg.norm(r)), rel=1e-9)
