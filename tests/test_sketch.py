"""Sketching-operator unit + property tests (paper §2)."""
import jax
import jax.numpy as jnp
import pytest

from repro.core import SKETCH_KINDS, fwht, sample_sketch

KINDS = sorted(set(SKETCH_KINDS) - {"clarkson_woodruff"})


@pytest.mark.parametrize("kind", KINDS)
@pytest.mark.parametrize("m,n,d", [(200, 5, 64), (513, 1, 100), (100, 17, 40)])
def test_apply_matches_dense(kind, m, n, d):
    op = sample_sketch(kind, jax.random.key(0), d, m)
    A = jax.random.normal(jax.random.key(1), (m, n) if n > 1 else (m,))
    got = op.apply(A)
    want = op.as_dense() @ (A if A.ndim == 2 else A)
    assert got.shape == ((d, n) if A.ndim == 2 else (d,))
    assert jnp.allclose(got, want, atol=1e-10)


@pytest.mark.parametrize("kind", KINDS)
def test_isometry_in_expectation(kind):
    """E[SᵀS] = I — averaged over draws, diagonal ~1, off-diagonal ~0."""
    m, d, reps = 64, 256, 20
    acc = jnp.zeros((m, m))
    for r in range(reps):
        op = sample_sketch(kind, jax.random.key(r), d, m)
        S = op.as_dense()
        acc = acc + S.T @ S
    G = acc / reps
    # uniform-valued operators have Var[v²] = 4/5 per entry (vs 0 for ±1
    # signs), so their diagonal concentrates ~√0.8/reps slower.
    diag_tol = 0.65 if kind in ("uniform_sparse", "uniform_dense", "gaussian") else 0.25
    assert jnp.abs(jnp.diag(G) - 1).max() < diag_tol
    off = G - jnp.diag(jnp.diag(G))
    assert jnp.abs(off).max() < 0.3


@pytest.mark.parametrize("kind", KINDS)
def test_subspace_embedding(kind):
    """singular values of S·Q stay in a (generous) [0.5, 1.5] band at d=8n."""
    m, n = 2048, 16
    d = 8 * n
    Q, _ = jnp.linalg.qr(jax.random.normal(jax.random.key(2), (m, n)))
    op = sample_sketch(kind, jax.random.key(3), d, m)
    sv = jnp.linalg.svd(op.apply(Q), compute_uv=False)
    assert sv.min() > 0.5 and sv.max() < 1.5


def test_fwht_involution_and_orthogonality():
    x = jax.random.normal(jax.random.key(0), (64, 3))
    assert jnp.allclose(fwht(fwht(x)) / 64, x, atol=1e-12)
    H = fwht(jnp.eye(8))
    assert jnp.allclose(H @ H.T, 8 * jnp.eye(8))
