"""Sketching-operator unit + property tests (paper §2)."""
import jax
import jax.numpy as jnp
import pytest

from repro.core import SKETCH_KINDS, fwht, sample_sketch

KINDS = sorted(set(SKETCH_KINDS) - {"clarkson_woodruff"})


@pytest.mark.parametrize("kind", KINDS)
@pytest.mark.parametrize("m,n,d", [(200, 5, 64), (513, 1, 100), (100, 17, 40)])
def test_apply_matches_dense(kind, m, n, d):
    op = sample_sketch(kind, jax.random.key(0), d, m)
    A = jax.random.normal(jax.random.key(1), (m, n) if n > 1 else (m,))
    got = op.apply(A)
    want = op.as_dense() @ (A if A.ndim == 2 else A)
    assert got.shape == ((d, n) if A.ndim == 2 else (d,))
    assert jnp.allclose(got, want, atol=1e-10)


@pytest.mark.parametrize("kind", KINDS)
def test_isometry_in_expectation(kind):
    """E[SᵀS] = I — averaged over draws, diagonal ~1, off-diagonal ~0."""
    m, d, reps = 64, 256, 20
    acc = jnp.zeros((m, m))
    for r in range(reps):
        op = sample_sketch(kind, jax.random.key(r), d, m)
        S = op.as_dense()
        acc = acc + S.T @ S
    G = acc / reps
    # uniform-valued operators have Var[v²] = 4/5 per entry (vs 0 for ±1
    # signs), so their diagonal concentrates ~√0.8/reps slower.
    diag_tol = 0.65 if kind in ("uniform_sparse", "uniform_dense", "gaussian") else 0.25
    assert jnp.abs(jnp.diag(G) - 1).max() < diag_tol
    off = G - jnp.diag(jnp.diag(G))
    assert jnp.abs(off).max() < 0.3


@pytest.mark.parametrize("kind", KINDS)
def test_subspace_embedding(kind):
    """singular values of S·Q stay in a (generous) [0.5, 1.5] band at d=8n."""
    m, n = 2048, 16
    d = 8 * n
    Q, _ = jnp.linalg.qr(jax.random.normal(jax.random.key(2), (m, n)))
    op = sample_sketch(kind, jax.random.key(3), d, m)
    sv = jnp.linalg.svd(op.apply(Q), compute_uv=False)
    assert sv.min() > 0.5 and sv.max() < 1.5


@pytest.mark.parametrize("kind", sorted(SKETCH_KINDS))
def test_as_dense_matches_apply_all_kinds(kind):
    """as_dense() and apply() must realize the SAME linear map S — for all
    six operator kinds (plus the clarkson_woodruff alias), matrix and
    vector operands alike."""
    m, n, d = 150, 6, 48
    op = sample_sketch(kind, jax.random.key(10), d, m)
    S = op.as_dense()
    assert S.shape == (d, m)
    A = jax.random.normal(jax.random.key(11), (m, n))
    v = jax.random.normal(jax.random.key(12), (m,))
    assert jnp.allclose(op.apply(A), S @ A, atol=1e-10)
    assert jnp.allclose(op.apply(v), S @ v, atol=1e-10)


@pytest.mark.parametrize("m,d", [(100, 200), (513, 2048), (64, 65)])
def test_srht_oversampling_with_replacement(m, d):
    """d > m_pad triggers the with-replacement row-sample fallback of
    SRHTSketch.sample — the oversampling SRHT variant must still be a
    well-formed, correctly scaled operator."""
    op = sample_sketch("srht", jax.random.key(20), d, m)
    assert d > op.m_pad  # this parametrization must exercise the fallback
    assert op.rows.shape == (d,)
    assert int(op.rows.min()) >= 0 and int(op.rows.max()) < op.m_pad
    # with-replacement sampling must actually repeat rows (pigeonhole)
    assert len(set(op.rows.tolist())) <= op.m_pad

    A = jax.random.normal(jax.random.key(21), (m, 3))
    got = op.apply(A)
    assert got.shape == (d, 3)
    assert jnp.allclose(got, op.as_dense() @ A, atol=1e-10)
    # every column of S has d entries of ±1/sqrt(d) => unit column norm,
    # so the Frobenius mass ‖S‖_F² = m exactly, replacement or not.
    S = op.as_dense()
    assert jnp.allclose(jnp.linalg.norm(S) ** 2, m, rtol=1e-9)


def test_srht_undersampled_rows_are_distinct():
    """d <= m_pad keeps the without-replacement path: rows are unique."""
    op = sample_sketch("srht", jax.random.key(22), 64, 200)
    assert len(set(op.rows.tolist())) == 64


def test_fwht_involution_and_orthogonality():
    x = jax.random.normal(jax.random.key(0), (64, 3))
    assert jnp.allclose(fwht(fwht(x)) / 64, x, atol=1e-12)
    H = fwht(jnp.eye(8))
    assert jnp.allclose(H @ H.T, 8 * jnp.eye(8))
