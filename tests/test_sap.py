"""Dedicated SAP-SAS convergence tests (beyond the backend parity check).

SAP now threads the sketch-and-solve warm start z0 = Qt(Sb) through the
preconditioned LSQR call (via the shared SketchedFactor), so it converges
in O(10) iterations like SAA-SAS; ``warm_start=False`` reproduces the
paper's original zero-initialized negative result.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.core import SolveResult, generate_problem, qr_solve, sap_sas


@pytest.fixture(scope="module")
def prob():
    return generate_problem(jax.random.key(0), 4000, 64, cond=1e10, beta=1e-10)


def relerr(x, xt):
    return float(jnp.linalg.norm(x - xt) / jnp.linalg.norm(xt))


def test_sap_converges_with_warm_start(prob):
    res = sap_sas(prob.A, prob.b, jax.random.key(1))
    assert isinstance(res, SolveResult)
    assert res.converged
    assert int(res.itn) < 40
    e_qr = relerr(qr_solve(prob.A, prob.b), prob.x_true)
    assert relerr(res.x, prob.x_true) < 100 * max(e_qr, 1e-12)


def test_sap_warm_start_beats_cold(prob):
    warm = sap_sas(prob.A, prob.b, jax.random.key(2))
    cold = sap_sas(prob.A, prob.b, jax.random.key(2), warm_start=False)
    # Zero init on a whitened-but-full-dimension system stalls at its
    # numerical floor far from the solution (the paper's negative result);
    # the warm start removes that failure mode entirely.
    assert relerr(warm.x, prob.x_true) < relerr(cold.x, prob.x_true) / 100


@pytest.mark.parametrize("kind", ["gaussian", "srht", "sparse_sign"])
def test_sap_with_other_sketches(prob, kind):
    res = sap_sas(prob.A, prob.b, jax.random.key(3), sketch=kind)
    assert relerr(res.x, prob.x_true) < 1e-4


def test_sap_history(prob):
    res = sap_sas(prob.A, prob.b, jax.random.key(4), history=True)
    assert res.history.shape == (200,)  # default iter_lim
    valid = res.history[: int(res.itn)]
    assert bool(jnp.all(jnp.isfinite(valid)))


def test_sap_sketch_size_override(prob):
    res = sap_sas(prob.A, prob.b, jax.random.key(5), sketch_size=8 * 64)
    assert res.converged
    assert relerr(res.x, prob.x_true) < 1e-5
