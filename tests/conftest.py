"""Test config: f64 for the numerics of the paper's solvers.

NOTE: XLA_FLAGS device-count forcing is deliberately NOT set here — smoke
tests see 1 device; multi-device behaviour is tested via subprocesses
(test_multidevice.py) and the dry-run.
"""
import jax

jax.config.update("jax_enable_x64", True)
