"""Training substrate: microbatch equivalence, loss decreases, checkpoints."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.data import SyntheticConfig, batch_at
from repro.optim import AdamWConfig
from repro.train import (
    TrainState, init_train_state, make_train_step, restore, save, train_loop,
)
from repro.train.checkpoint import AsyncCheckpointer, gc_checkpoints, latest_step
from repro.train.elastic import rebalance_microbatch

# Model-zoo / multi-process / long-sweep module: slow tier (see pytest.ini)
pytestmark = pytest.mark.slow


@pytest.fixture(scope="module")
def tiny():
    cfg = smoke_config("llama3.2-1b").replace(n_periods=2)
    dcfg = SyntheticConfig(vocab=cfg.vocab, seq_len=64, global_batch=8, kind="bigram")
    ocfg = AdamWConfig(lr=5e-3, warmup_steps=5, total_steps=100)
    return cfg, dcfg, ocfg


def test_microbatch_equivalence(tiny):
    """n_micro=1 and n_micro=4 take (nearly) the same step."""
    cfg, dcfg, ocfg = tiny
    batch = batch_at(dcfg, 0)
    s1 = init_train_state(cfg, jax.random.key(0))
    s2 = init_train_state(cfg, jax.random.key(0))
    st1, m1 = jax.jit(make_train_step(cfg, ocfg, n_micro=1))(s1, batch)
    st4, m4 = jax.jit(make_train_step(cfg, ocfg, n_micro=4))(s2, batch)
    assert abs(float(m1["loss"]) - float(m4["loss"])) < 1e-4
    d = jax.tree.map(
        lambda a, b: float(jnp.abs(a - b).max()), st1.params, st4.params
    )
    assert max(jax.tree.leaves(d)) < 1e-5


def test_loss_decreases(tiny, tmp_path):
    cfg, dcfg, ocfg = tiny
    state, losses = train_loop(cfg, dcfg, ocfg, steps=30, log_every=5,
                               ckpt_dir=str(tmp_path), ckpt_every=10)
    assert losses[-1][1] < losses[0][1]


def test_checkpoint_roundtrip(tiny, tmp_path):
    cfg, dcfg, ocfg = tiny
    state = init_train_state(cfg, jax.random.key(1))
    save(str(tmp_path), 7, state)
    assert latest_step(str(tmp_path)) == 7
    restored, step = restore(str(tmp_path), state)
    assert step == 7
    same = jax.tree.map(
        lambda a, b: bool((np.asarray(a) == np.asarray(b)).all()), state, restored
    )
    assert all(jax.tree.leaves(same))


def test_resume_continues_stream(tiny, tmp_path):
    """Train 20; train 10+resume(10->20): identical final loss (exact resume)."""
    cfg, dcfg, ocfg = tiny
    d1, d2 = str(tmp_path / "a"), str(tmp_path / "b")
    _, l_full = train_loop(cfg, dcfg, ocfg, steps=20, ckpt_dir=d1,
                           ckpt_every=100, log_every=20)
    train_loop(cfg, dcfg, ocfg, steps=10, ckpt_dir=d2, ckpt_every=10, log_every=10)
    _, l_res = train_loop(cfg, dcfg, ocfg, steps=20, ckpt_dir=d2,
                          ckpt_every=10, log_every=20)
    assert abs(l_full[-1][1] - l_res[-1][1]) < 1e-4


def test_gc_keep_n(tmp_path):
    for s in (1, 2, 3, 4, 5):
        save(str(tmp_path), s, {"x": jnp.ones(3)})
    gc_checkpoints(str(tmp_path), keep_n=2)
    assert latest_step(str(tmp_path)) == 5
    assert sorted(os.listdir(tmp_path)) == ["step_4", "step_5"]


def test_gc_orphan_tmps(tmp_path):
    """A crashed writer's tmp.<step>.<pid> staging dir is swept by the
    next save(); a live writer's in-flight tmp is left alone."""
    # forge an orphan: a pid that is guaranteed dead
    dead_pid = os.getpid()
    while True:
        dead_pid += 7919
        try:
            os.kill(dead_pid, 0)
        except ProcessLookupError:
            break
        except PermissionError:
            continue
    orphan = tmp_path / f"tmp.3.{dead_pid}"
    orphan.mkdir()
    (orphan / "arrays.npz").write_bytes(b"partial garbage")
    live = tmp_path / f"tmp.4.{os.getpid()}"  # "in-flight" by this process
    live.mkdir()
    save(str(tmp_path), 5, {"x": jnp.ones(3)})
    names = sorted(os.listdir(tmp_path))
    assert orphan.name not in names, "dead writer's staging dir must be GCed"
    assert live.name in names, "live writer's staging dir must survive"
    assert latest_step(str(tmp_path)) == 5


def test_async_checkpointer(tmp_path):
    w = AsyncCheckpointer(str(tmp_path), keep_n=2)
    for s in (10, 20, 30):
        w.submit(s, {"a": jnp.full((4,), s)})
    w.finalize()
    assert latest_step(str(tmp_path)) == 30
    got, _ = restore(str(tmp_path), {"a": jnp.zeros(4)})
    assert float(got["a"][0]) == 30


def test_rebalance_microbatch():
    # 256 global, dp 16->8 after losing half the data axis
    new = rebalance_microbatch(256, old_dp=16, old_micro=16, new_dp=8)
    assert 256 % (8 * new) == 0
