"""The matrix-free operator protocol (repro.core.linop) and its threading
through the sketches and every solver reachable from lstsq()."""
import jax
import jax.numpy as jnp
import pytest
from jax.experimental.sparse import BCOO

from repro.core import (
    SKETCH_KINDS,
    estimate_2norm,
    linop,
    lstsq,
    qr_solve,
    sample_sketch,
    select_method,
)

M_ROWS, N_COLS = 2000, 32


@pytest.fixture(scope="module")
def prob():
    """A sparse-patterned (but exactly representable densely) test problem,
    so dense / BCOO / custom inputs are the SAME matrix."""
    k1, k2, k3 = jax.random.split(jax.random.key(0), 3)
    mask = jax.random.uniform(k1, (M_ROWS, N_COLS)) < 0.05
    A = jnp.where(mask, jax.random.normal(k2, (M_ROWS, N_COLS)), 0.0)
    A = A.at[jnp.arange(N_COLS), jnp.arange(N_COLS)].add(1.0)  # full rank
    x_true = jnp.arange(1.0, N_COLS + 1.0)
    b = A @ x_true + 1e-9 * jax.random.normal(k3, (M_ROWS,))
    return A, b, qr_solve(A, b)


def _custom(A):
    return linop.CustomOperator(
        matvec_fn=lambda v: A @ v,
        rmatvec_fn=lambda u: A.T @ u,
        op_shape=tuple(A.shape),
        op_dtype=A.dtype,
    )


def _variants(A):
    return {
        "dense": linop.as_operator(A),
        "bcoo": linop.as_operator(BCOO.fromdense(A)),
        "custom": _custom(A),
    }


# ---------------------------------------------------------------- protocol


def test_operator_products_match_dense(prob):
    A, _, _ = prob
    x = jax.random.normal(jax.random.key(1), (N_COLS,))
    u = jax.random.normal(jax.random.key(2), (M_ROWS,))
    X = jax.random.normal(jax.random.key(3), (N_COLS, 3))
    U = jax.random.normal(jax.random.key(4), (M_ROWS, 2))
    for name, op in _variants(A).items():
        assert op.shape == (M_ROWS, N_COLS), name
        assert op.dtype == A.dtype, name
        assert jnp.allclose(op.matvec(x), A @ x, atol=1e-10), name
        assert jnp.allclose(op.rmatvec(u), A.T @ u, atol=1e-10), name
        assert jnp.allclose(op.matmat(X), A @ X, atol=1e-10), name
        assert jnp.allclose(op.rmatmat(U), A.T @ U, atol=1e-10), name
        assert jnp.allclose(op @ x, A @ x, atol=1e-10), name


def test_operators_are_pytrees(prob):
    """Operators must pass through jit as arguments (solvers are jitted)."""
    A, _, _ = prob
    x = jax.random.normal(jax.random.key(1), (N_COLS,))

    @jax.jit
    def f(op, v):
        return op.matvec(v)

    for name, op in _variants(A).items():
        assert jnp.allclose(f(op, x), A @ x, atol=1e-10), name


def test_materialize(prob):
    A, _, _ = prob
    assert linop.as_operator(A).materialize() is A  # no copy
    assert jnp.allclose(linop.as_operator(BCOO.fromdense(A)).materialize(), A)
    op = _custom(A)
    assert not op.materializable
    with pytest.raises(TypeError, match="materialized"):
        op.materialize()


def test_as_operator_coercion(prob):
    A, _, _ = prob
    op = linop.as_operator(A)
    assert isinstance(op, linop.DenseOperator)
    assert linop.as_operator(op) is op  # idempotent
    assert isinstance(linop.as_operator(BCOO.fromdense(A)), linop.SparseOperator)
    # SciPy-style duck typing
    duck = linop.as_operator(_custom(A))
    assert isinstance(duck, linop.CustomOperator)
    with pytest.raises(ValueError, match="2-D"):
        linop.as_operator(jnp.ones(5))


def test_tikhonov_augmented(prob):
    A, b, _ = prob
    lam = 0.3
    t = linop.TikhonovAugmented.wrap(A, lam)
    assert t.shape == (M_ROWS + N_COLS, N_COLS)
    Ad = t.materialize()
    assert Ad.shape == t.shape
    x = jax.random.normal(jax.random.key(5), (N_COLS,))
    u = jax.random.normal(jax.random.key(6), (M_ROWS + N_COLS,))
    assert jnp.allclose(t.matvec(x), Ad @ x, atol=1e-10)
    assert jnp.allclose(t.rmatvec(u), Ad.T @ u, atol=1e-10)
    assert jnp.allclose(t.augment_rhs(b), jnp.concatenate([b, jnp.zeros(N_COLS)]))
    # over a non-materializable core, the augmentation isn't either
    t2 = linop.TikhonovAugmented.wrap(_custom(A), lam)
    assert not t2.materializable


def test_ensure_dense(prob):
    A, _, _ = prob
    assert linop.ensure_dense(A) is A
    assert jnp.allclose(linop.ensure_dense(BCOO.fromdense(A)), A)
    with pytest.raises(TypeError, match="materializable"):
        linop.ensure_dense(_custom(A), who="test")


def test_estimate_2norm_accepts_all_forms(prob):
    A, _, _ = prob
    true = float(jnp.linalg.norm(A, 2))
    for name, op in _variants(A).items():
        est = float(estimate_2norm(op, jax.random.key(7)))
        assert est == pytest.approx(true, rel=1e-2), name
    # raw array too (the coercing entry point)
    assert float(estimate_2norm(A, jax.random.key(7))) == pytest.approx(
        true, rel=1e-2
    )


# ------------------------------------------------------- sketch × operator

SKETCH_TEST_KINDS = sorted(set(SKETCH_KINDS) - {"clarkson_woodruff"})


@pytest.mark.parametrize("kind", SKETCH_TEST_KINDS)
def test_apply_op_matches_dense_sketch(kind):
    """op.apply_op must realize S·A for dense, BCOO and matrix-free A."""
    m, n, d = 300, 9, 64
    key = jax.random.key(10)
    A = jnp.where(
        jax.random.uniform(key, (m, n)) < 0.2,
        jax.random.normal(jax.random.key(11), (m, n)),
        0.0,
    )
    op = sample_sketch(kind, jax.random.key(12), d, m)
    want = op.as_dense() @ A
    for name, Aop in _variants(A).items():
        got = op.apply_op(Aop)
        assert got.shape == (d, n), (kind, name)
        assert jnp.allclose(got, want, atol=1e-9), (kind, name)


@pytest.mark.parametrize("kind", SKETCH_TEST_KINDS)
def test_as_dense_t_matches_transpose(kind):
    op = sample_sketch(kind, jax.random.key(13), 48, 200)
    assert jnp.allclose(op.as_dense_t(), op.as_dense().T, atol=1e-10)


def test_apply_op_tikhonov():
    m, n, d, lam = 220, 7, 40, 0.5
    A = jax.random.normal(jax.random.key(14), (m, n))
    t = linop.TikhonovAugmented.wrap(A, lam)
    op = sample_sketch("countsketch", jax.random.key(15), d, m + n)
    want = op.as_dense() @ t.materialize()
    assert jnp.allclose(op.apply_op(t), want, atol=1e-9)
    # matrix-free core takes the generic rmatmat path
    t2 = linop.TikhonovAugmented.wrap(_custom(A), lam)
    assert jnp.allclose(op.apply_op(t2), want, atol=1e-9)


# ----------------------------------------------------- solvers × operator


@pytest.mark.parametrize("method", ("saa", "sap", "iterative", "fossils", "lsqr"))
@pytest.mark.parametrize("form", ("bcoo", "custom"))
def test_every_solver_accepts_every_input_form(prob, method, form):
    """Acceptance: every solver reachable from lstsq() takes dense, BCOO and
    custom operators and agrees with the dense path to solver tolerance."""
    A, b, x_qr = prob
    key = jax.random.key(20)
    Ain = _variants(A)[form]
    res = lstsq(Ain, b, key, method=method)
    res_dense = lstsq(A, b, key, method=method)
    norm = jnp.linalg.norm(x_qr)
    assert float(jnp.linalg.norm(res.x - x_qr) / norm) < 1e-6, (method, form)
    # same sketch draw ⇒ operator and dense paths agree much tighter than
    # the solver tolerance (identical math, different product order)
    assert float(jnp.linalg.norm(res.x - res_dense.x) / norm) < 1e-8


def test_direct_materializes_bcoo_but_rejects_custom(prob):
    A, b, x_qr = prob
    res = lstsq(BCOO.fromdense(A), b, method="direct")
    assert jnp.allclose(res.x, x_qr, atol=1e-8)
    with pytest.raises(TypeError, match="materializable"):
        lstsq(_custom(A), b, method="direct")


def test_auto_selection_matrix_free(prob):
    A, b, _ = prob
    # sparse input, key: sketched solver; never 'direct' even when small
    assert select_method(2000, 32, matrix_free=True) == "iterative"
    assert select_method(2000, 32, matrix_free=True, accuracy="fast") == "saa"
    assert select_method(2000, 32, matrix_free=True, has_key=False) == "lsqr"
    # outside the sketching regime matrix-free falls back to lsqr
    assert select_method(100, 60, matrix_free=True) == "lsqr"
    res = lstsq(BCOO.fromdense(A), b, jax.random.key(21))
    assert res.method == "iterative"
    res = lstsq(_custom(A), b)
    assert res.method == "lsqr"


def test_saa_operator_form_disables_fallback(prob):
    """Matrix-free SAA cannot take the dense perturbation fallback."""
    A, b, _ = prob
    res = lstsq(BCOO.fromdense(A), b, jax.random.key(22), method="saa")
    assert not bool(res.used_fallback)
