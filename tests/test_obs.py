"""repro.obs: the metrics registry, the span tracer, and the exporters.

Three layers of coverage:

- unit: instruments (counter/gauge/histogram), the StatsDict mirror, span
  nesting/depth bookkeeping, activation semantics (env flag aside);
- integration: ``lstsq(..., trace=True)`` / ``stream_lstsq`` / a cluster
  solve with an injected kill / a ``SolveService`` batch each produce a
  complete, valid Chrome-trace timeline;
- contracts: thread-safety under concurrent submit, tracing-disabled
  overhead within noise of a fully stripped build (the hard ≤1.05x gate
  lives in benchmarks/perf_gate.py — here we only pin "same order").
"""
import json
import pickle
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cluster import ClusterSpec
from repro.cluster.faults import FaultPlan, KillWorker
from repro.core.lstsq import lstsq
from repro.obs import trace as obs_trace
from repro.obs.export import json_snapshot, prometheus_text, save_chrome_trace
from repro.obs.metrics import MetricsRegistry
from repro.serve import SolveService
from repro.streaming.solve import stream_lstsq


@pytest.fixture(autouse=True)
def _no_tracer_leak():
    """Every test starts and ends with tracing off."""
    obs_trace.disable()
    yield
    obs_trace.disable()


@pytest.fixture
def key():
    return jax.random.PRNGKey(0)


def _problem(m=256, n=16, seed=0, dtype=np.float64):
    rng = np.random.default_rng(seed)
    A = jnp.asarray(rng.standard_normal((m, n)).astype(dtype))
    b = jnp.asarray(rng.standard_normal(m).astype(dtype))
    return A, b


# ---------------------------------------------------------------------------
# metrics


def test_counter_gauge_histogram():
    reg = MetricsRegistry(enabled=True)
    c = reg.counter("t.c")
    c.inc()
    c.inc(4)
    assert c.value == 5
    with pytest.raises(ValueError):
        c.inc(-1)
    g = reg.gauge("t.g")
    g.set(7)
    g.inc(-2)
    assert g.value == 5
    h = reg.histogram("t.h")
    h.observe(2e-4)   # second bucket (3e-4)
    h.observe(1e9)    # +inf overflow
    snap = h.snapshot()
    assert snap["count"] == 2
    assert snap["counts"][1] == 1
    assert snap["counts"][-1] == 1
    assert snap["sum"] == pytest.approx(2e-4 + 1e9)


def test_registry_get_or_create_is_stable():
    reg = MetricsRegistry(enabled=True)
    assert reg.counter("x") is reg.counter("x")
    assert reg.gauge("y") is reg.gauge("y")
    snap = reg.snapshot()
    assert "x" in snap["counters"] and "y" in snap["gauges"]


def test_disabled_registry_hands_out_nulls():
    reg = MetricsRegistry(enabled=False)
    c = reg.counter("nope")
    c.inc(10)
    assert c.value == 0
    assert reg.snapshot() == {"counters": {}, "gauges": {}, "histograms": {}}


def test_stats_dict_is_a_plain_dict_to_tests():
    reg = MetricsRegistry(enabled=True)
    d = reg.stats_dict("ns", {"a": 0, "b": 0})
    d["a"] += 3
    d["b"] = 2
    assert d == {"a": 3, "b": 2}          # exact-equality pins keep working
    assert sorted(d) == ["a", "b"]
    assert reg.counter("ns.a").value == 3
    assert reg.gauge("ns.a.last").value == 3
    # two instances aggregate into the SAME registry counter
    d2 = reg.stats_dict("ns", {"a": 0})
    d2["a"] += 1
    assert reg.counter("ns.a").value == 4
    # pickles as a plain dict (cluster checkpoints must not drag the
    # registry through pickle)
    back = pickle.loads(pickle.dumps(d))
    assert type(back) is dict and back == {"a": 3, "b": 2}


def test_metrics_thread_safety():
    reg = MetricsRegistry(enabled=True)
    c = reg.counter("mt.c")
    d = reg.stats_dict("mt", {"hits": 0})
    lock = threading.Lock()

    def work():
        for _ in range(1000):
            c.inc()
            with lock:  # dict += is not atomic; the registry mirror is
                d["hits"] += 1

    threads = [threading.Thread(target=work) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert c.value == 8000
    assert d["hits"] == 8000
    assert reg.counter("mt.hits").value == 8000


# ---------------------------------------------------------------------------
# trace core


def test_span_is_noop_when_disabled():
    assert not obs_trace.enabled()
    sp = obs_trace.span("anything", a=1)
    assert not sp  # falsy → call sites skip attr extraction
    with sp as s:
        s.set(b=2)  # must not raise
    obs_trace.instant("nothing")  # must not raise
    assert obs_trace.current() is None


def test_span_nesting_depth_and_order():
    with obs_trace.tracing() as tr:
        with obs_trace.span("outer", k=1) as outer:
            with obs_trace.span("inner"):
                obs_trace.instant("tick", v=2)
            outer.set(done=True)
    spans = {e["name"]: e for e in tr.events if e.get("ph") == "X"}
    assert spans["outer"]["depth"] == 0
    assert spans["inner"]["depth"] == 1
    assert spans["outer"]["args"] == {"k": 1, "done": True}
    # inner is contained in outer's [ts, ts+dur] window
    o, i = spans["outer"], spans["inner"]
    assert o["ts"] <= i["ts"]
    assert i["ts"] + i["dur"] <= o["ts"] + o["dur"] + 1e-3
    (tick,) = [e for e in tr.events if e.get("ph") == "i"]
    assert tick["name"] == "tick" and tick["depth"] == 2
    assert not obs_trace.enabled()  # tracing() deactivated on exit


def test_tracing_joins_active_tracer():
    with obs_trace.tracing() as tr1:
        with obs_trace.tracing() as tr2:
            assert tr2 is tr1
        assert obs_trace.enabled()  # inner exit must not deactivate
    assert not obs_trace.enabled()


def test_chrome_trace_json_is_valid():
    with obs_trace.tracing() as tr:
        with obs_trace.span("a", shape=(3, 4)):
            obs_trace.instant("b")
    obj = tr.chrome_trace()
    text = json.dumps(obj)  # must be serializable (tuples etc. included)
    parsed = json.loads(text)
    assert parsed["displayTimeUnit"] == "ms"
    events = parsed["traceEvents"]
    assert any(e["ph"] == "M" for e in events)  # thread_name metadata
    for e in events:
        assert {"name", "ph", "pid", "tid"} <= set(e)
        if e["ph"] == "X":
            assert e["dur"] >= 0 and e["ts"] >= 0


def test_solve_scope_semantics():
    # flag=True owns and deactivates
    sc = obs_trace.solve_scope(True)
    with sc:
        assert obs_trace.enabled()
        with obs_trace.span("s"):
            pass
    assert not obs_trace.enabled()
    # flag=None observes an enclosing tracer without owning it
    with obs_trace.tracing():
        with obs_trace.solve_scope(None) as sc2:
            with obs_trace.span("t"):
                pass
        assert obs_trace.enabled()
        res = sc2.attach(_FakeRes())
        assert res.timeline is not None
        assert "t" in res.timeline.names()
    # flag=None with nothing active: attach is a no-op
    with obs_trace.solve_scope(None) as sc3:
        pass
    r = _FakeRes()
    assert sc3.attach(r) is r


class _FakeRes:
    timeline = None

    def _replace(self, **kw):
        out = _FakeRes()
        out.timeline = kw.get("timeline")
        return out


def test_stripped_swaps_and_restores():
    real_span = obs_trace.span
    with obs_trace.stripped():
        assert obs_trace.span is not real_span
        with obs_trace.tracing() as tr:
            with obs_trace.span("invisible"):
                pass
        assert tr.events == [] or all(
            e["ph"] == "M" for e in tr.events
        )
    assert obs_trace.span is real_span


def test_threads_get_distinct_tids():
    with obs_trace.tracing() as tr:
        def work():
            with obs_trace.span("child_thread"):
                pass
        t = threading.Thread(target=work, name="obs-test-worker")
        t.start()
        t.join()
        with obs_trace.span("main_thread"):
            pass
    spans = {e["name"]: e for e in tr.events if e.get("ph") == "X"}
    assert spans["child_thread"]["tid"] != spans["main_thread"]["tid"]
    names = {
        e["args"]["name"] for e in tr.events if e.get("ph") == "M"
    }
    assert "obs-test-worker" in names


# ---------------------------------------------------------------------------
# integration: solver


def test_lstsq_untraced_has_no_timeline(key):
    A, b = _problem()
    res = lstsq(A, b, key)
    assert res.timeline is None
    assert not obs_trace.enabled()


def test_lstsq_traced_attaches_nested_timeline(key):
    A, b = _problem()
    res = lstsq(A, b, key, trace=True)
    tl = res.timeline
    assert tl is not None
    names = tl.names()
    assert names[-1] == "lstsq"  # complete events close outermost-last
    assert "lstsq.select" in names and "lstsq.solve" in names
    root = [s for s in tl.spans() if s["name"] == "lstsq"][0]
    assert root["depth"] == 0 and root["args"]["method"] == res.method
    solve = [s for s in tl.spans() if s["name"] == "lstsq.solve"][0]
    assert solve["depth"] == 1 and "itn" in solve["args"]
    json.loads(json.dumps(tl.chrome_trace()))  # valid chrome trace
    assert "lstsq" in str(tl)  # renders
    assert not obs_trace.enabled()  # per-call scope released the tracer


def test_certified_trace_shows_rungs_and_probes(key):
    A, b = _problem(m=512, n=8)
    res = lstsq(A, b, key, accuracy="certified", trace=True)
    names = res.timeline.names()
    assert "certified.rung" in names
    assert "certify.probe" in names
    assert "factor.build" in names  # built eagerly, outside jit
    rungs = [s for s in res.timeline.spans() if s["name"] == "certified.rung"]
    assert all("passed" in r["args"] for r in rungs)
    assert rungs[-1]["args"]["passed"] is True


# ---------------------------------------------------------------------------
# integration: streaming + cluster


def test_streamed_trace_has_pass_structure(key):
    A, b = _problem(m=512, n=8)
    res = stream_lstsq(np.asarray(A), np.asarray(b), key, tile_rows=128,
                       trace=True)
    names = set(res.timeline.names())
    assert {"stream_lstsq", "stream.pass1", "stream.tile",
            "factor.qr", "stream.solve"} <= names
    tiles = [s for s in res.timeline.spans() if s["name"] == "stream.tile"]
    assert len(tiles) == 4  # 512 rows / 128-row tiles
    assert not obs_trace.enabled()


def test_cluster_kill_trace_shows_recovery(key, tmp_path):
    A, b = _problem(m=512, n=8)
    plan = FaultPlan(KillWorker(worker=1, at_tile=1))
    spec = ClusterSpec(num_workers=3, tile_rows=64, checkpoint_every=1,
                       ckpt_dir=str(tmp_path), faults=plan)
    res = stream_lstsq(np.asarray(A), np.asarray(b), key, tile_rows=64,
                       cluster=spec, trace=True)
    assert plan.fired
    names = set(res.timeline.names())
    assert {"cluster.pass1", "cluster.task", "cluster.merge",
            "cluster.recover", "cluster.reassign",
            "cluster.restore"} <= names
    # the kill's task range was restored from its checkpoint watermark
    (restore,) = [e for e in res.timeline.instants()
                  if e["name"] == "cluster.restore"]
    assert restore["args"]["watermark"] > restore["args"]["start"]
    # worker tasks land on their worker threads, not the caller's
    task_tids = {s["tid"] for s in res.timeline.spans()
                 if s["name"] == "cluster.task"}
    assert len(task_tids) >= 2
    json.loads(json.dumps(res.timeline.chrome_trace()))


# ---------------------------------------------------------------------------
# integration: serve


def test_serve_batch_trace_and_counter_consistency(key):
    A, b = _problem(m=768, n=12)
    svc = SolveService(key, max_delay_s=0.0, default_rtol=1e-8)
    n_req = 12
    errs = []

    with obs_trace.tracing() as tr:
        def submit_some(seed):
            rng = np.random.default_rng(seed)
            try:
                for _ in range(n_req // 4):
                    svc.submit(A, jnp.asarray(rng.standard_normal(768)),
                               mode="session")
            except Exception as e:  # pragma: no cover - surfaced below
                errs.append(e)

        threads = [threading.Thread(target=submit_some, args=(s,))
                   for s in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert not errs
        svc.flush()

    names = {e["name"] for e in tr.events}
    assert {"serve.submit", "serve.dispatch.session",
            "serve.solve", "serve.certify"} <= names
    submits = [e for e in tr.events if e["name"] == "serve.submit"]
    assert len(submits) == n_req
    st = svc.stats()
    assert st["requests"] == n_req
    assert st["ok"] + st["rejected"] == n_req  # consistent snapshot
    assert st["pending"] == 0
    # queue-vs-dispatch breakdown: every dispatch span nests solve+certify
    disp = [e for e in tr.events if e["name"] == "serve.dispatch.session"]
    solve = [e for e in tr.events if e["name"] == "serve.solve"]
    assert disp and solve
    assert min(s["depth"] for s in solve) > min(d["depth"] for d in disp)


def test_serve_stats_snapshot_under_concurrent_load(key):
    """stats() polled while submits and pumps race stays self-consistent."""
    A, b = _problem(m=768, n=12)
    svc = SolveService(key, max_delay_s=0.0, default_rtol=1e-8)
    svc.start()
    bad = []
    stop = threading.Event()

    def poll():
        while not stop.is_set():
            st = svc.stats()
            if st["ok"] + st["rejected"] > st["requests"]:
                bad.append(dict(st))
            time.sleep(0.0002)

    poller = threading.Thread(target=poll)
    poller.start()
    try:
        rng = np.random.default_rng(1)
        futs = [svc.submit(A, jnp.asarray(rng.standard_normal(768)),
                           mode="session")
                for _ in range(24)]
        for f in futs:
            f.result(timeout=60)
    finally:
        stop.set()
        poller.join()
        svc.stop()
    assert not bad, f"inconsistent stats snapshots: {bad[:3]}"
    st = svc.stats()
    assert st["requests"] == 24 and st["ok"] + st["rejected"] == 24


# ---------------------------------------------------------------------------
# exporters


def test_prometheus_text_format():
    reg = MetricsRegistry(enabled=True)
    reg.counter("unit.requests").inc(3)
    reg.gauge("unit.depth").set(2)
    h = reg.histogram("unit.lat_s")
    for v in (2e-4, 5e-3, 99.0):
        h.observe(v)
    txt = prometheus_text(reg)
    lines = txt.strip().splitlines()
    assert "# TYPE repro_unit_requests counter" in lines
    assert "repro_unit_requests 3" in lines
    assert "repro_unit_depth 2" in lines
    # cumulative buckets end at the total count, +Inf line included
    assert 'repro_unit_lat_s_bucket{le="+Inf"} 3' in lines
    cums = [int(ln.rsplit(" ", 1)[1]) for ln in lines
            if ln.startswith("repro_unit_lat_s_bucket")]
    assert cums == sorted(cums)
    assert "repro_unit_lat_s_count 3" in lines


def test_json_snapshot_and_save_chrome_trace(tmp_path):
    reg = MetricsRegistry(enabled=True)
    reg.counter("snap.n").inc()
    snap = json_snapshot(reg)
    assert snap["counters"]["snap.n"] == 1 and "ts_unix" in snap
    with obs_trace.tracing() as tr:
        with obs_trace.span("saved"):
            pass
    p = save_chrome_trace(tr, str(tmp_path / "trace.json"))
    loaded = json.load(open(p))
    assert any(e["name"] == "saved" for e in loaded["traceEvents"])


# ---------------------------------------------------------------------------
# overhead contract (loose here; the 1.05x machine gate is in benchmarks)


def test_disabled_span_overhead_same_order():
    """The disabled path (global check + shared no-op) must stay within
    small constant factors of a fully stripped build.  The tight ≤1.05x
    end-to-end gate runs on real solves in benchmarks/perf_gate.py; this
    guards against the disabled path growing real work (allocation,
    locks, formatting)."""
    N = 50_000

    def disabled_loop():
        t0 = time.perf_counter()
        for _ in range(N):
            with obs_trace.span("x", a=1):
                pass
        return time.perf_counter() - t0

    def stripped_loop():
        with obs_trace.stripped():
            t0 = time.perf_counter()
            for _ in range(N):
                with obs_trace.span("x", a=1):
                    pass
            return time.perf_counter() - t0

    disabled = min(disabled_loop() for _ in range(3))
    stripped_t = min(stripped_loop() for _ in range(3))
    # per-call cost of the disabled path, in ns — the real contract
    per_call_ns = (disabled / N) * 1e9
    assert per_call_ns < 2000, f"disabled span costs {per_call_ns:.0f}ns/call"
    assert disabled < max(stripped_t * 10, 0.05), (
        f"disabled={disabled:.4f}s stripped={stripped_t:.4f}s"
    )
