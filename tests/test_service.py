"""SolveService: routing, coalescing, SLOs, rejection, stats."""
import threading
import time

import jax
import jax.numpy as jnp
import pytest

from repro.serve import SolveService

M, N = 600, 12


@pytest.fixture(scope="module")
def tenant():
    kA, kx, kr = jax.random.split(jax.random.PRNGKey(0), 3)
    A = jax.random.normal(kA, (M, N))
    X = jax.random.normal(kx, (N, 8))
    X = X / jnp.linalg.norm(X, axis=0)
    B = A @ X + 1e-8 * jax.random.normal(kr, (M, 8))
    return A, B


def _service(**kw):
    kw.setdefault("max_delay_s", 0.001)
    return SolveService(jax.random.PRNGKey(42), **kw)


def test_coalesced_batch_all_certified(tenant):
    A, B = tenant
    svc = _service()
    futs = [svc.submit(A, B[:, j], certified_rtol=1e-6, mode="session")
            for j in range(8)]
    assert svc.stats()["pending"] == 8
    svc.flush()
    x_ref = jnp.linalg.lstsq(A, B)[0]
    for j, f in enumerate(futs):
        r = f.result(timeout=0)
        assert r.ok and r.path == "session" and r.batch_size == 8
        assert bool(r.certificate.passed)
        assert float(r.certificate.target) == 1e-6
        rel = float(jnp.linalg.norm(r.x - x_ref[:, j])) / float(
            jnp.linalg.norm(x_ref[:, j])
        )
        assert rel <= 1e-6
    c = svc.counters
    assert c["session_batches"] == 1 and c["ok"] == 8 and c["rejected"] == 0


def test_cache_hit_on_second_wave(tenant):
    A, B = tenant
    svc = _service()
    svc.solve(A, B[:, 0], mode="session")
    r = svc.solve(A, B[:, 1], mode="session")
    assert r.cache_hit
    assert svc.stats()["cache"]["entries"] == 1


def test_tenants_do_not_share_sessions(tenant):
    A, B = tenant
    A2 = A + 1.0
    svc = _service()
    svc.solve(A, B[:, 0], mode="session")
    svc.solve(A2, B[:, 0], mode="session")
    assert svc.stats()["cache"]["entries"] == 2


def test_default_rtol_is_the_service_slo(tenant):
    A, B = tenant
    svc = _service(default_rtol=1e-5)
    r = svc.solve(A, B[:, 0], mode="session")
    assert r.ok and float(r.certificate.target) == 1e-5


def test_expired_deadline_rejected(tenant):
    A, B = tenant
    svc = _service()
    fut = svc.submit(A, B[:, 0], mode="session", deadline_s=-1.0)
    svc.flush()
    r = fut.result(timeout=0)
    assert not r.ok and "deadline" in r.reason
    assert r.x is None and r.certificate is None
    assert svc.counters["rejected"] == 1


def test_unattainable_rtol_rejected_with_reason(tenant):
    A, B = tenant
    svc = _service()
    r = svc.solve(A, B[:, 0], certified_rtol=1e-308, mode="session")
    assert not r.ok
    assert "unattainable" in r.reason
    assert svc.counters["slow_path"] == 1


def test_auto_routing_by_problem_size(tenant):
    A, B = tenant  # 600 x 12 -> m n^2 tiny -> bucket
    svc = _service()
    r = svc.solve(A, B[:, 0])
    assert r.path == "bucket"
    big = jax.random.normal(jax.random.PRNGKey(1), (9000, 90))
    r2 = svc.solve(big, big @ jnp.ones((90,)))
    assert r2.path == "session"


def test_bucket_coalesces_shapes_into_buckets():
    svc = _service()
    futs = []
    for i in range(4):
        A = jax.random.normal(jax.random.PRNGKey(10 + i), (50 + i, 7))
        b = jax.random.normal(jax.random.PRNGKey(20 + i), (50 + i,))
        futs.append(svc.submit(A, b, certified_rtol=1e-8))
    svc.flush()
    for i, f in enumerate(futs):
        r = f.result(timeout=0)
        assert r.ok and r.path == "bucket" and bool(r.certificate.passed)
        assert r.x.shape == (7,)
    # 50..53 rows with n_pad=8 all land in the (64, 8) bucket: ONE compile
    assert svc.stats()["bucket_executables"] == 1
    assert svc.counters["bucket_batches"] == 1


def test_bucket_rejects_matrix_free(tenant):
    from repro.core import linop

    A, B = tenant
    op = linop.CustomOperator(
        matvec_fn=lambda x: A @ x, rmatvec_fn=lambda y: A.T @ y,
        op_shape=A.shape, op_dtype=A.dtype,
    )
    svc = _service()
    with pytest.raises(ValueError, match="bucket"):
        svc.submit(op, B[:, 0], mode="bucket", token="t")
    # session mode works, with the mandatory token
    r = svc.solve(op, B[:, 0], mode="session", token="tenant-op-v1")
    assert r.ok and r.path == "session"


def test_submit_validates_rhs_and_mode(tenant):
    A, B = tenant
    svc = _service()
    with pytest.raises(ValueError, match="right-hand side"):
        svc.submit(A, B)  # 2-D b
    with pytest.raises(ValueError, match="mode"):
        svc.submit(A, B[:, 0], mode="warp")


def test_submit_rejects_promoting_rhs_dtype(tenant):
    """A promoting b (f64 against an f32 session) must fail AT SUBMIT, in
    the caller's thread — not blow up mid-dispatch inside a shared batch."""
    A, B = tenant
    A32 = A.astype(jnp.float32)
    with pytest.raises(TypeError, match="dtype"):
        _service().submit(A32, B[:, 0].astype(jnp.float64), mode="session")
    # a safely-representable RHS is cast, solved and certified normally
    svc = _service()
    r = svc.solve(A, B[:, 0].astype(jnp.float32), mode="session")
    assert r.ok and r.x.dtype == A.dtype


def test_dispatch_exception_rejects_batch_not_service(tenant, monkeypatch):
    """An internal dispatch failure must resolve THAT batch's futures with
    a reasoned rejection and leave the pump thread serving everyone else
    — the review scenario was a service-wide hang on one bad batch."""
    A, B = tenant
    svc = _service()
    calls = {"n": 0}
    orig = svc.cache.get_or_build

    def flaky(fp, builder):
        calls["n"] += 1
        if calls["n"] == 1:
            raise RuntimeError("kaboom")
        return orig(fp, builder)

    monkeypatch.setattr(svc.cache, "get_or_build", flaky)
    svc.start(poll_s=1e-4)
    try:
        r1 = svc.submit(A, B[:, 0], mode="session").result(timeout=60.0)
        r2 = svc.submit(A, B[:, 1], mode="session").result(timeout=60.0)
    finally:
        svc.stop()
    assert not r1.ok and "internal error" in r1.reason and "kaboom" in r1.reason
    assert r2.ok and bool(r2.certificate.passed)
    assert svc.counters["rejected"] == 1 and svc.counters["ok"] == 1


def test_queued_vs_compute_breakdown(tenant):
    """queued_s is submit → dispatch; the solve itself must land in
    latency_s − queued_s, not be double-counted as queueing."""
    A, B = tenant
    svc = _service()
    fut = svc.submit(A, B[:, 0], mode="session")
    time.sleep(0.05)  # request sits in the queue
    svc.flush()
    r = fut.result(timeout=0)
    assert r.ok
    assert 0.04 <= r.queued_s <= r.latency_s
    # the session build + solve + certification takes real time
    assert r.latency_s - r.queued_s > 0.0


def test_submit_does_not_block_during_dispatch(tenant, monkeypatch):
    """Clients must keep enqueueing while the pump computes a batch."""
    A, B = tenant
    svc = _service()
    entered, release = threading.Event(), threading.Event()
    orig = svc._dispatch_session

    def slow(fp, reqs):
        entered.set()
        release.wait(timeout=30.0)
        return orig(fp, reqs)

    monkeypatch.setattr(svc, "_dispatch_session", slow)
    svc.start(poll_s=1e-4)
    try:
        f1 = svc.submit(A, B[:, 0], mode="session")
        assert entered.wait(timeout=30.0)
        t0 = time.monotonic()
        f2 = svc.submit(A, B[:, 1], mode="session")
        dt = time.monotonic() - t0
        release.set()
        assert f1.result(timeout=60.0).ok and f2.result(timeout=60.0).ok
    finally:
        release.set()
        svc.stop()
    assert dt < 0.2, f"submit blocked {dt:.3f}s behind an in-flight dispatch"


def test_tenant_scoped_tokens_do_not_collide(tenant):
    """Two tenants both calling their (different) data 'v1' must get their
    own factors and their own answers."""
    A, B = tenant
    A2 = A + 1.0
    svc = _service()
    r1 = svc.solve(A, B[:, 0], mode="session", token="v1", tenant="alice")
    r2 = svc.solve(A2, B[:, 0], mode="session", token="v1", tenant="bob")
    assert r1.ok and r2.ok
    assert svc.stats()["cache"]["entries"] == 2
    x1 = jnp.linalg.lstsq(A, B[:, 0])[0]
    x2 = jnp.linalg.lstsq(A2, B[:, 0])[0]
    assert float(jnp.linalg.norm(r1.x - x1) / jnp.linalg.norm(x1)) <= 1e-6
    assert float(jnp.linalg.norm(r2.x - x2) / jnp.linalg.norm(x2)) <= 1e-6


def test_prewarm_makes_first_request_a_hit(tenant):
    A, B = tenant
    svc = _service()
    svc.prewarm(A)
    r = svc.solve(A, B[:, 0], mode="session")
    assert r.ok and r.cache_hit


def test_background_pump_thread(tenant):
    A, B = tenant
    svc = _service()
    svc.start(poll_s=1e-4)
    try:
        futs = [svc.submit(A, B[:, j], mode="session") for j in range(4)]
        resps = [f.result(timeout=30.0) for f in futs]
    finally:
        svc.stop()
    assert all(r.ok for r in resps)
    assert all(r.latency_s >= 0 for r in resps)


def test_batch_padding_keeps_answers_exact(tenant):
    """3 requests pad to the 4-wide ladder rung; answers stay per-request."""
    A, B = tenant
    svc = _service()
    futs = [svc.submit(A, B[:, j], certified_rtol=1e-6, mode="session")
            for j in range(3)]
    svc.flush()
    x_ref = jnp.linalg.lstsq(A, B[:, :3])[0]
    for j, f in enumerate(futs):
        r = f.result(timeout=0)
        assert r.ok and r.batch_size == 3
        rel = float(jnp.linalg.norm(r.x - x_ref[:, j])) / float(
            jnp.linalg.norm(x_ref[:, j])
        )
        assert rel <= 1e-6


def test_stats_shape(tenant):
    A, B = tenant
    svc = _service()
    svc.solve(A, B[:, 0], mode="session")
    st = svc.stats()
    for key in ("requests", "ok", "rejected", "slow_path", "pending",
                "session_occupancy", "bucket_occupancy", "cache"):
        assert key in st
    assert st["cache"]["entries"] == 1
    assert 0.0 < st["session_occupancy"] <= 1.0
