"""SolveService: routing, coalescing, SLOs, rejection, stats."""
import jax
import jax.numpy as jnp
import pytest

from repro.serve import SolveService

M, N = 600, 12


@pytest.fixture(scope="module")
def tenant():
    kA, kx, kr = jax.random.split(jax.random.PRNGKey(0), 3)
    A = jax.random.normal(kA, (M, N))
    X = jax.random.normal(kx, (N, 8))
    X = X / jnp.linalg.norm(X, axis=0)
    B = A @ X + 1e-8 * jax.random.normal(kr, (M, 8))
    return A, B


def _service(**kw):
    kw.setdefault("max_delay_s", 0.001)
    return SolveService(jax.random.PRNGKey(42), **kw)


def test_coalesced_batch_all_certified(tenant):
    A, B = tenant
    svc = _service()
    futs = [svc.submit(A, B[:, j], certified_rtol=1e-6, mode="session")
            for j in range(8)]
    assert svc.stats()["pending"] == 8
    svc.flush()
    x_ref = jnp.linalg.lstsq(A, B)[0]
    for j, f in enumerate(futs):
        r = f.result(timeout=0)
        assert r.ok and r.path == "session" and r.batch_size == 8
        assert bool(r.certificate.passed)
        assert float(r.certificate.target) == 1e-6
        rel = float(jnp.linalg.norm(r.x - x_ref[:, j])) / float(
            jnp.linalg.norm(x_ref[:, j])
        )
        assert rel <= 1e-6
    c = svc.counters
    assert c["session_batches"] == 1 and c["ok"] == 8 and c["rejected"] == 0


def test_cache_hit_on_second_wave(tenant):
    A, B = tenant
    svc = _service()
    svc.solve(A, B[:, 0], mode="session")
    r = svc.solve(A, B[:, 1], mode="session")
    assert r.cache_hit
    assert svc.stats()["cache"]["entries"] == 1


def test_tenants_do_not_share_sessions(tenant):
    A, B = tenant
    A2 = A + 1.0
    svc = _service()
    svc.solve(A, B[:, 0], mode="session")
    svc.solve(A2, B[:, 0], mode="session")
    assert svc.stats()["cache"]["entries"] == 2


def test_default_rtol_is_the_service_slo(tenant):
    A, B = tenant
    svc = _service(default_rtol=1e-5)
    r = svc.solve(A, B[:, 0], mode="session")
    assert r.ok and float(r.certificate.target) == 1e-5


def test_expired_deadline_rejected(tenant):
    A, B = tenant
    svc = _service()
    fut = svc.submit(A, B[:, 0], mode="session", deadline_s=-1.0)
    svc.flush()
    r = fut.result(timeout=0)
    assert not r.ok and "deadline" in r.reason
    assert r.x is None and r.certificate is None
    assert svc.counters["rejected"] == 1


def test_unattainable_rtol_rejected_with_reason(tenant):
    A, B = tenant
    svc = _service()
    r = svc.solve(A, B[:, 0], certified_rtol=1e-308, mode="session")
    assert not r.ok
    assert "unattainable" in r.reason
    assert svc.counters["slow_path"] == 1


def test_auto_routing_by_problem_size(tenant):
    A, B = tenant  # 600 x 12 -> m n^2 tiny -> bucket
    svc = _service()
    r = svc.solve(A, B[:, 0])
    assert r.path == "bucket"
    big = jax.random.normal(jax.random.PRNGKey(1), (9000, 90))
    r2 = svc.solve(big, big @ jnp.ones((90,)))
    assert r2.path == "session"


def test_bucket_coalesces_shapes_into_buckets():
    svc = _service()
    futs = []
    for i in range(4):
        A = jax.random.normal(jax.random.PRNGKey(10 + i), (50 + i, 7))
        b = jax.random.normal(jax.random.PRNGKey(20 + i), (50 + i,))
        futs.append(svc.submit(A, b, certified_rtol=1e-8))
    svc.flush()
    for i, f in enumerate(futs):
        r = f.result(timeout=0)
        assert r.ok and r.path == "bucket" and bool(r.certificate.passed)
        assert r.x.shape == (7,)
    # 50..53 rows with n_pad=8 all land in the (64, 8) bucket: ONE compile
    assert svc.stats()["bucket_executables"] == 1
    assert svc.counters["bucket_batches"] == 1


def test_bucket_rejects_matrix_free(tenant):
    from repro.core import linop

    A, B = tenant
    op = linop.CustomOperator(
        matvec_fn=lambda x: A @ x, rmatvec_fn=lambda y: A.T @ y,
        op_shape=A.shape, op_dtype=A.dtype,
    )
    svc = _service()
    with pytest.raises(ValueError, match="bucket"):
        svc.submit(op, B[:, 0], mode="bucket", token="t")
    # session mode works, with the mandatory token
    r = svc.solve(op, B[:, 0], mode="session", token="tenant-op-v1")
    assert r.ok and r.path == "session"


def test_submit_validates_rhs_and_mode(tenant):
    A, B = tenant
    svc = _service()
    with pytest.raises(ValueError, match="right-hand side"):
        svc.submit(A, B)  # 2-D b
    with pytest.raises(ValueError, match="mode"):
        svc.submit(A, B[:, 0], mode="warp")


def test_prewarm_makes_first_request_a_hit(tenant):
    A, B = tenant
    svc = _service()
    svc.prewarm(A)
    r = svc.solve(A, B[:, 0], mode="session")
    assert r.ok and r.cache_hit


def test_background_pump_thread(tenant):
    A, B = tenant
    svc = _service()
    svc.start(poll_s=1e-4)
    try:
        futs = [svc.submit(A, B[:, j], mode="session") for j in range(4)]
        resps = [f.result(timeout=30.0) for f in futs]
    finally:
        svc.stop()
    assert all(r.ok for r in resps)
    assert all(r.latency_s >= 0 for r in resps)


def test_batch_padding_keeps_answers_exact(tenant):
    """3 requests pad to the 4-wide ladder rung; answers stay per-request."""
    A, B = tenant
    svc = _service()
    futs = [svc.submit(A, B[:, j], certified_rtol=1e-6, mode="session")
            for j in range(3)]
    svc.flush()
    x_ref = jnp.linalg.lstsq(A, B[:, :3])[0]
    for j, f in enumerate(futs):
        r = f.result(timeout=0)
        assert r.ok and r.batch_size == 3
        rel = float(jnp.linalg.norm(r.x - x_ref[:, j])) / float(
            jnp.linalg.norm(x_ref[:, j])
        )
        assert rel <= 1e-6


def test_stats_shape(tenant):
    A, B = tenant
    svc = _service()
    svc.solve(A, B[:, 0], mode="session")
    st = svc.stats()
    for key in ("requests", "ok", "rejected", "slow_path", "pending",
                "session_occupancy", "bucket_occupancy", "cache"):
        assert key in st
    assert st["cache"]["entries"] == 1
    assert 0.0 < st["session_occupancy"] <= 1.0
