"""LSQR solver tests (paper §3.1 baseline)."""
import jax
import jax.numpy as jnp
import pytest

from repro.core import generate_problem, lsqr_dense, lsqr_solve, qr_solve


def test_well_conditioned_exact():
    prob = generate_problem(jax.random.key(0), 500, 20, cond=10.0, beta=1e-12)
    res = lsqr_dense(prob.A, prob.b)
    assert res.converged
    assert jnp.linalg.norm(res.x - prob.x_true) < 1e-6


def test_operator_form_matches_dense():
    prob = generate_problem(jax.random.key(1), 300, 10, cond=100.0, beta=1e-8)
    A = prob.A
    r1 = lsqr_dense(A, prob.b)
    r2 = lsqr_solve(lambda x: A @ x, lambda u: A.T @ u, prob.b, n=10)
    assert jnp.allclose(r1.x, r2.x, atol=1e-10)


def test_warm_start_keeps_original_scale_tests():
    """x0 near the solution must not make stopping tests unreachable."""
    prob = generate_problem(jax.random.key(2), 400, 15, cond=10.0, beta=1e-10)
    x_ref = qr_solve(prob.A, prob.b)
    x0 = x_ref * (1 + 1e-6)
    res = lsqr_dense(prob.A, prob.b, x0=x0)
    assert res.converged
    assert int(res.itn) < 15
    assert jnp.linalg.norm(res.x - prob.x_true) < 1e-6


def test_steptol_stops_at_floor():
    prob = generate_problem(jax.random.key(3), 400, 15, cond=1e4, beta=1e-10)
    res = lsqr_dense(prob.A, prob.b, atol=0.0, btol=0.0, steptol=1e-13,
                     iter_lim=500)
    assert int(res.istop) == 8
    assert int(res.itn) < 200


def test_zero_rhs():
    A = jax.random.normal(jax.random.key(4), (50, 5))
    res = lsqr_dense(A, jnp.zeros(50))
    assert jnp.allclose(res.x, 0.0)
