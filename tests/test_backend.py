"""Backend policy + reference/pallas parity, tested THROUGH the solvers.

Per-kernel allclose lives in test_kernels.py; these tests assert the thing
the paper's speedup claim actually needs — that swapping the sketch-apply
backend under ``saa_sas`` / ``sap_sas`` / ``sketched_lstsq`` leaves the
solve unchanged to solver tolerance (backend numerics exercised end-to-end,
not per-kernel).  On this CPU container "pallas" runs in interpret mode.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.core import (
    generate_problem,
    saa_sas,
    saa_sas_batch,
    sap_sas,
    sample_sketch,
    sketched_lstsq,
)
from repro.core.backend import (
    BACKENDS,
    KERNEL_BACKED_KINDS,
    default_interpret,
    kernel_backed,
    resolve,
)
from repro.core.distributed import shard_rows

# Every kind whose apply dispatches to a Pallas kernel (alias included once).
KERNEL_KINDS = sorted(KERNEL_BACKED_KINDS - {"clarkson_woodruff"})


@pytest.fixture(scope="module")
def prob():
    # m a power of two keeps the SRHT pad-free; modest size keeps the
    # interpret-mode kernels fast.
    return generate_problem(jax.random.key(0), 1024, 24, cond=1e8, beta=1e-10)


def relerr(x, ref):
    return float(jnp.linalg.norm(x - ref) / jnp.linalg.norm(ref))


# --------------------------------------------------------------------------
# Policy
# --------------------------------------------------------------------------


def test_resolve_policy(monkeypatch):
    monkeypatch.delenv("REPRO_SKETCH_BACKEND", raising=False)
    assert resolve("auto", platform="tpu").name == "pallas"
    assert not resolve("auto", platform="tpu").interpret
    assert resolve("auto", platform="cpu").name == "reference"
    assert resolve("pallas", platform="cpu").interpret
    assert resolve("pallas", platform="tpu").interpret is False
    assert resolve("reference", platform="cpu").name == "reference"
    with pytest.raises(ValueError):
        resolve("numpy")


def test_resolve_env_override(monkeypatch):
    monkeypatch.setenv("REPRO_SKETCH_BACKEND", "pallas")
    assert resolve("auto", platform="cpu").name == "pallas"
    # explicit knob beats the env var
    assert resolve("reference", platform="cpu").name == "reference"
    monkeypatch.setenv("REPRO_SKETCH_BACKEND", "nope")
    with pytest.raises(ValueError):
        resolve("auto", platform="cpu")


def test_default_interpret():
    assert default_interpret("cpu") and default_interpret("gpu")
    assert not default_interpret("tpu")


def test_kernel_backed_partition():
    assert kernel_backed("countsketch") and kernel_backed("clarkson_woodruff")
    assert kernel_backed("srht") and kernel_backed("gaussian")
    assert not kernel_backed("sparse_sign") and not kernel_backed("uniform_sparse")
    assert "auto" in BACKENDS and "reference" in BACKENDS and "pallas" in BACKENDS


# --------------------------------------------------------------------------
# Operator-level parity (the same linear map S on both backends)
# --------------------------------------------------------------------------


@pytest.mark.parametrize("kind", KERNEL_KINDS)
def test_operator_apply_backend_parity(kind):
    m, n, d = 300, 9, 64
    op = sample_sketch(kind, jax.random.key(3), d, m)
    A = jax.random.normal(jax.random.key(4), (m, n))
    ref = op.apply(A, backend="reference")
    pal = op.apply(A, backend="pallas")
    assert jnp.allclose(ref, pal, rtol=1e-9, atol=1e-9)
    # vector apply (the Sb path of the solvers)
    b = jax.random.normal(jax.random.key(5), (m,))
    assert jnp.allclose(
        op.apply(b, backend="reference"), op.apply(b, backend="pallas"),
        rtol=1e-9, atol=1e-9,
    )


@pytest.mark.parametrize("kind", ["sparse_sign", "uniform_sparse"])
def test_kernel_less_kinds_fall_back(kind):
    """Kinds without a kernel accept backend="pallas" (reference fallback)."""
    op = sample_sketch(kind, jax.random.key(6), 32, 200)
    A = jax.random.normal(jax.random.key(7), (200, 4))
    assert jnp.array_equal(op.apply(A, backend="pallas"), op.apply(A, backend="reference"))


# --------------------------------------------------------------------------
# Solver-level parity (ISSUE acceptance: through the full solve)
# --------------------------------------------------------------------------


@pytest.mark.parametrize("kind", KERNEL_KINDS)
def test_saa_backend_parity(prob, kind):
    r_ref = saa_sas(prob.A, prob.b, jax.random.key(1), sketch=kind, backend="reference")
    r_pal = saa_sas(prob.A, prob.b, jax.random.key(1), sketch=kind, backend="pallas")
    assert r_ref.converged and r_pal.converged
    assert relerr(r_ref.x, prob.x_true) < 1e-6
    assert relerr(r_pal.x, prob.x_true) < 1e-6
    assert relerr(r_pal.x, r_ref.x) < 1e-6


@pytest.mark.parametrize("kind", ["countsketch", "srht"])
def test_saa_backend_parity_operator_form(prob, kind):
    """materialize_y=False (the at-scale form) must show the same parity."""
    kw = dict(sketch=kind, materialize_y=False)
    r_ref = saa_sas(prob.A, prob.b, jax.random.key(1), backend="reference", **kw)
    r_pal = saa_sas(prob.A, prob.b, jax.random.key(1), backend="pallas", **kw)
    assert r_ref.converged and r_pal.converged
    assert relerr(r_pal.x, r_ref.x) < 1e-6


def test_sap_accepts_backend(prob):
    # SAP is the paper's negative result: no dimension reduction, so the
    # preconditioned-but-full-size LSQR amplifies accumulation-order noise
    # between backends ~κ-fold.  Parity here is necessarily looser than
    # SAA's (which whitens before iterating).
    r_ref = sap_sas(prob.A, prob.b, jax.random.key(2), backend="reference")
    r_pal = sap_sas(prob.A, prob.b, jax.random.key(2), backend="pallas")
    assert relerr(r_pal.x, r_ref.x) < 1e-2


def test_sketched_lstsq_accepts_backend(prob):
    """Distributed solver on a 1-device mesh: backend parity in-process
    (multi-device correctness is covered by test_multidevice.py)."""
    mesh = jax.make_mesh((1,), ("data",))
    A, b = shard_rows(mesh, ("data",), prob.A, prob.b)
    r_ref = sketched_lstsq(A, b, jax.random.key(1), mesh=mesh, backend="reference")
    r_pal = sketched_lstsq(A, b, jax.random.key(1), mesh=mesh, backend="pallas")
    assert relerr(r_ref.x, prob.x_true) < 1e-6
    assert relerr(r_pal.x, r_ref.x) < 1e-6
    # both solvers converge to the LS solution (their sketch draws differ:
    # saa_sas derives its sketch key via split(key, 3))
    r_saa = saa_sas(
        prob.A, prob.b, jax.random.key(1), materialize_y=False, backend="reference"
    )
    assert relerr(r_ref.x, r_saa.x) < 1e-6


# --------------------------------------------------------------------------
# Batched front-end
# --------------------------------------------------------------------------


def test_saa_batch_multi_rhs_matches_single(prob):
    k = 4
    noise = jax.random.normal(jax.random.key(8), (prob.b.shape[0], k - 1))
    B_rhs = jnp.concatenate([prob.b[:, None], prob.b[:, None] + 0.1 * noise], axis=1)
    res = saa_sas_batch(prob.A, B_rhs, jax.random.key(1))
    assert res.x.shape == (prob.A.shape[1], k)
    assert res.istop.shape == (k,)
    for j in range(k):
        single = saa_sas(prob.A, B_rhs[:, j], jax.random.key(1), use_fallback=False)
        assert relerr(res.x[:, j], single.x) < 1e-6


def test_saa_batch_multi_rhs_operator_form(prob):
    B_rhs = jnp.stack([prob.b, 2.0 * prob.b], axis=1)
    r_mat = saa_sas_batch(prob.A, B_rhs, jax.random.key(1), materialize_y=True)
    r_op = saa_sas_batch(prob.A, B_rhs, jax.random.key(1), materialize_y=False)
    assert relerr(r_op.x, r_mat.x) < 1e-5


def test_saa_batch_problem_batch(prob):
    A3 = jnp.stack([prob.A, 1.5 * prob.A])
    b2 = jnp.stack([prob.b, prob.b])
    res = saa_sas_batch(A3, b2, jax.random.key(1))
    assert res.x.shape == (2, prob.A.shape[1])
    assert relerr(res.x[0], prob.x_true) < 1e-6
    # scaling A by c scales the LS solution by 1/c
    assert relerr(res.x[1] * 1.5, prob.x_true) < 1e-6


def test_saa_batch_backend_parity(prob):
    B_rhs = jnp.stack([prob.b, 0.5 * prob.b], axis=1)
    r_ref = saa_sas_batch(prob.A, B_rhs, jax.random.key(1), backend="reference")
    r_pal = saa_sas_batch(prob.A, B_rhs, jax.random.key(1), backend="pallas")
    assert relerr(r_pal.x, r_ref.x) < 1e-6


def test_saa_batch_shape_validation(prob):
    with pytest.raises(ValueError):
        saa_sas_batch(prob.A, prob.b, jax.random.key(1))  # b must be (m, k)
    with pytest.raises(ValueError):
        saa_sas_batch(prob.A[None], prob.b[None, :100], jax.random.key(1))
