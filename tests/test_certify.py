"""Certification + adaptive escalation (repro.core.certify, certified lstsq).

Covers the PR's acceptance criteria:

- the probed embedding distortion tracks the TRUE whitened-spectrum
  distortion within a constant factor, for all six sketch kinds;
- ``extend_rows`` exactness: the incrementally extended sketch is
  bit-equal to applying the escalated operator from scratch (mirroring
  the streaming merge-exactness contract);
- ``lstsq(accuracy="certified")`` on a cond=1e10 problem returns a
  certificate whose forward-error bound holds against QR ground truth,
  and escalates sketch size + method from an adversarially small initial
  sketch WITHOUT re-sketching A (sketch-apply count pinned);
- the ridge auto-selection regression (selection on the data shape, not
  the augmented one) and the explicit tolerance-forwarding audit.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.core import (
    SketchedFactor,
    SketchedSolver,
    generate_problem,
    lstsq,
    qr_solve,
    select_method,
)
from repro.core import certify as certify_lib
from repro.core import linop
from repro.core import sketch as sketch_lib
from repro.core.precond import default_sketch_size

ALL_KINDS = (
    "gaussian",
    "uniform_dense",
    "srht",
    "countsketch",
    "sparse_sign",
    "uniform_sparse",
)


def true_subspace_distortion(op, A):
    """max(σ_max(SU) − 1, 1 − σ_min(SU)) over an orthonormal basis U of
    range(A) — the quantity the probe estimates from below."""
    U, _ = jnp.linalg.qr(A)
    sv = jnp.linalg.svd(op.apply(U, backend="reference"), compute_uv=False)
    return float(jnp.maximum(sv[0] - 1.0, 1.0 - sv[-1]))


# --------------------------------------------------------------------------
# Estimators
# --------------------------------------------------------------------------


@pytest.mark.parametrize("kind", ALL_KINDS)
def test_probed_distortion_tracks_truth(kind):
    """ε̂ never exceeds the true whitened-spectrum distortion (the probe is
    a lower estimate by construction) and stays within a constant factor
    of it — for every sketch kind, at an aggressive size where the
    distortion is large enough to matter."""
    m, n, s = 1024, 16, 48
    A = jax.random.normal(jax.random.key(0), (m, n))
    op = sketch_lib.sample(kind, jax.random.key(1), s, m)
    factor = SketchedFactor.from_sketch(op.apply(A, backend="reference"))
    eps_true = true_subspace_distortion(op, A)
    eps_hat = float(
        certify_lib.probe_distortion(A, factor, jax.random.key(2), n_probes=16)
    )
    assert eps_hat <= eps_true * (1.0 + 1e-9), (eps_hat, eps_true)
    assert eps_hat >= eps_true / 4.0, (eps_hat, eps_true)


def test_error_bound_is_valid_posterior_bound():
    """bound ≥ ‖x̂ − x⋆‖ for a deliberately sloppy solution, using the
    TRUE distortion (the bound's hypothesis)."""
    m, n, s = 2048, 24, 96
    prob = generate_problem(jax.random.key(3), m, n, cond=1e6, beta=1e-4)
    op = sketch_lib.sample("countsketch", jax.random.key(4), s, m)
    factor = SketchedFactor.from_sketch(op.apply(prob.A, backend="reference"))
    x_star = qr_solve(prob.A, prob.b)
    # sloppy estimate: plain sketch-and-solve, O(ε·‖r‖) off the optimum
    x_hat = factor.sketch_and_solve(op.apply(prob.b, backend="reference"))
    eps_true = true_subspace_distortion(op, prob.A)
    _, _, bound = certify_lib.error_bound(
        prob.A, prob.b, x_hat, factor, eps_true
    )
    err = float(jnp.linalg.norm(x_hat - x_star))
    assert err <= float(bound) * (1.0 + 1e-9)
    # and not vacuous: within a few orders of the actual error
    assert float(bound) <= 1e4 * max(err, 1e-300)


def test_cond_estimate_tracks_condition_number():
    m, n = 2048, 16
    prob = generate_problem(jax.random.key(5), m, n, cond=1e8, beta=1e-8)
    factor, _ = SketchedFactor.build(prob.A, jax.random.key(6))
    _, _, cond_R = certify_lib.factor_spectrum(factor)
    assert 1e7 < float(cond_R) < 1e9  # κ(R) ≈ κ(A) up to (1±ε) factors


# --------------------------------------------------------------------------
# extend_rows exactness (the escalation primitive)
# --------------------------------------------------------------------------


@pytest.mark.parametrize("kind", ALL_KINDS)
def test_extend_rows_bit_equal_to_scratch(kind):
    """Extending a stored B = SA appends ONLY the new rows, yet produces
    bit-for-bit the sketch the escalated operator computes from scratch —
    including a second, nested escalation."""
    m, n, d, extra = 640, 12, 48, 48
    A = jax.random.normal(jax.random.key(7), (m, n))
    op = sketch_lib.sample(kind, jax.random.key(8), d, m)
    B = op.apply_op(A)
    op2 = op.extend_rows(jax.random.key(9), extra)
    assert op2.d == d + extra and op2.m == m
    B2 = op2.extend_sketch(B, A)
    assert jnp.array_equal(B2, op2.apply_op(A))
    # nested escalation keeps the contract
    op3 = op2.extend_rows(jax.random.key(10), 2 * extra)
    assert jnp.array_equal(op3.extend_sketch(B2, A), op3.apply_op(A))
    # the stacked operator still is an expectation-isometry: E[SᵀS] = I
    # (spot-check the dense matrix's column norms statistically)
    Sd = op3.as_dense()
    col_sq = jnp.sum(Sd * Sd, axis=0)
    assert float(jnp.abs(jnp.mean(col_sq) - 1.0)) < 0.2


def test_extend_rows_improves_embedding():
    """Escalation must actually buy distortion: the stacked operator at
    2d rows embeds like a fresh 2d-row sketch, not like the d-row one."""
    m, n, d = 2048, 32, 40  # aggressive: d barely above n
    A = jax.random.normal(jax.random.key(11), (m, n))
    op = sketch_lib.sample("countsketch", jax.random.key(12), d, m)
    eps_before = true_subspace_distortion(op, A)
    op2 = op.extend_rows(jax.random.key(13), 3 * d)
    eps_after = true_subspace_distortion(op2, A)
    assert eps_after < 0.75 * eps_before


def test_factor_extend_ridge_augmented():
    """Ridge escalation extends the DATA block of blockdiag(S, I) and
    moves the exact √λ·I tail down unchanged — still bit-equal to the
    escalated operator applied from scratch."""
    m, n, lam = 800, 10, 0.3
    A = jax.random.normal(jax.random.key(14), (m, n))
    A_aug = linop.TikhonovAugmented.wrap(A, lam)
    factor, op, B = SketchedFactor.build_full(A_aug, jax.random.key(15))
    factor2, op2, B2 = factor.extend(A_aug, op, jax.random.key(16), op.inner.d, B=B)
    assert jnp.array_equal(B2, op2.apply_op(A_aug))
    assert factor2.sketch_size == B2.shape[0]
    # the exact identity tail is preserved verbatim at the bottom
    assert jnp.array_equal(B2[-n:], B[-n:])


def test_factor_extend_without_stored_b():
    """B=None reconstructs Q·R — exact to rounding, same escalated op."""
    m, n = 600, 8
    A = jax.random.normal(jax.random.key(17), (m, n))
    factor, op, B = SketchedFactor.build_full(A, jax.random.key(18))
    f_a, op_a, B_a = factor.extend(A, op, jax.random.key(19), 16, B=B)
    f_b, op_b, B_b = factor.extend(A, op, jax.random.key(19), 16, B=None)
    assert jnp.allclose(B_a, B_b, atol=1e-12)


# --------------------------------------------------------------------------
# The certified adaptive driver
# --------------------------------------------------------------------------


@pytest.fixture(scope="module")
def hard_prob():
    return generate_problem(jax.random.key(20), 4000, 64, cond=1e10, beta=1e-10)


def test_certified_bound_holds_vs_qr(hard_prob):
    """Acceptance: cond=1e10 → certificate passes and its forward-error
    bound holds against QR ground truth within 10x."""
    x_qr = qr_solve(hard_prob.A, hard_prob.b)
    res = lstsq(hard_prob.A, hard_prob.b, jax.random.key(21),
                accuracy="certified")
    cert = res.certificate
    assert cert is not None and bool(cert.passed)
    true_err = float(jnp.linalg.norm(res.x - x_qr))
    bound = float(cert.error_bound)
    # the bound holds (up to the 10x slack the probe-based ε̂ may cost)
    assert true_err <= 10.0 * bound
    # and certifies genuinely high accuracy on this κ=1e10 problem
    assert bound / float(jnp.linalg.norm(res.x)) < 1e-4
    assert float(jnp.linalg.norm(res.x - hard_prob.x_true)) < 1e-4
    assert float(cert.cond_R) > 1e9  # the certificate exposes the danger


def test_certified_escalates_without_resketching(hard_prob, monkeypatch):
    """Acceptance: an adversarially small initial sketch must escalate
    sketch size AND method, and A must be sketched exactly once per
    escalation (never re-sketched from scratch) — pinned by counting the
    2-D (matrix) sketch applies at the operator layer."""
    matrix_applies = []
    real_apply = sketch_lib.CountSketch.apply

    def counting_apply(self, M, *, backend="auto"):
        if getattr(M, "ndim", 1) == 2:
            matrix_applies.append(M.shape)
        return real_apply(self, M, backend=backend)

    monkeypatch.setattr(sketch_lib.CountSketch, "apply", counting_apply)

    n = hard_prob.A.shape[1]
    res = lstsq(hard_prob.A, hard_prob.b, jax.random.key(22),
                accuracy="certified", sketch_size=n + 2)
    cert = res.certificate
    assert bool(cert.passed)
    assert cert.escalations >= 1  # the tiny sketch could not certify
    assert cert.sketch_rows > n + 2  # grew
    assert res.method != "saa"  # climbed the ladder
    # one initial sketch of A + exactly one extra-rows sketch per
    # escalation; any full re-sketch would add one more 2-D apply
    assert len(matrix_applies) == 1 + cert.escalations
    # every post-initial apply sketched the full row space through a
    # FRESH block, never by re-running the stacked operator end to end
    assert all(shape[0] == hard_prob.A.shape[0] for shape in matrix_applies)


def test_certified_rejects_forced_method(hard_prob):
    with pytest.raises(ValueError, match="certified"):
        lstsq(hard_prob.A, hard_prob.b, jax.random.key(23),
              accuracy="certified", method="saa")
    with pytest.raises(ValueError, match="PRNG key"):
        lstsq(hard_prob.A, hard_prob.b, accuracy="certified")


def test_certified_explicit_slo_target():
    """An explicit certified_rtol acts as the accuracy SLO: loose targets
    certify the first rung, absurd ones fail with passed=False rather
    than looping forever."""
    A = jax.random.normal(jax.random.key(24), (2000, 16))
    b = jax.random.normal(jax.random.key(25), (2000,))
    res = lstsq(A, b, jax.random.key(26), accuracy="certified",
                certified_rtol=1e-3)
    assert bool(res.certificate.passed)
    assert res.method == "saa"  # first rung suffices for a loose SLO
    res2 = lstsq(A, b, jax.random.key(27), accuracy="certified",
                 certified_rtol=1e-300)
    assert res2.certificate is not None and not bool(res2.certificate.passed)


def test_certified_never_densifies_sparse_inputs():
    """The dense-QR fallback rung is for dense inputs only: BCOO is
    *materializable* but an out-of-core todense() is not a fallback —
    sparse/matrix-free ladders stop at the fossils rung."""
    from jax.experimental.sparse import BCOO

    A = jax.random.normal(jax.random.key(50), (2000, 16))
    b = jax.random.normal(jax.random.key(51), (2000,))
    res = lstsq(BCOO.fromdense(A), b, jax.random.key(52),
                accuracy="certified", certified_rtol=1e-300)
    assert res.method != "direct"  # exhausted the ladder without densifying
    assert not bool(res.certificate.passed)


# --------------------------------------------------------------------------
# Ridge auto-selection regression + near-square routing (satellite bugfixes)
# --------------------------------------------------------------------------


def test_ridge_selection_uses_data_shape():
    """m=3n sits below the m ≥ 4n sketching regime, so auto must pick
    ``direct`` — but the augmented ridge shape (m+n = 4n) used to sneak
    past the regime test and pick a sketched solver."""
    m, n = 864, 288  # big enough to clear the flop cutoff
    assert select_method(m, n) == "direct"
    assert select_method(m + n, n) != "direct"  # the pre-fix misroute
    A = jax.random.normal(jax.random.key(28), (m, n))
    b = jax.random.normal(jax.random.key(29), (m,))
    res = lstsq(A, b, jax.random.key(30), reg=0.7)
    assert res.method == "direct"
    x_ridge = jnp.linalg.solve(A.T @ A + 0.7 * jnp.eye(n), A.T @ b)
    assert float(jnp.linalg.norm(res.x - x_ridge) / jnp.linalg.norm(x_ridge)) < 1e-8


def test_default_sketch_size_clamped_to_m():
    assert default_sketch_size(64, 64) == 64  # used to return 65 > m
    assert default_sketch_size(100, 90) == 90  # underdetermined: s ≤ m
    assert default_sketch_size(64, 4000) == 256  # regular regime unchanged


def test_near_square_routes_to_direct_or_lsqr():
    # square / nearly-square: no sketch can shrink the row space
    assert select_method(4096, 4096) == "direct"
    assert select_method(4096, 4095) == "direct"
    assert select_method(4096, 4096, has_key=False) == "lsqr"
    assert select_method(4096, 4096, matrix_free=True) == "lsqr"


# --------------------------------------------------------------------------
# Tolerance-forwarding audit (satellite bugfix)
# --------------------------------------------------------------------------


def test_forced_method_rejects_unsupported_tolerances():
    A = jax.random.normal(jax.random.key(31), (200, 8))
    b = jax.random.normal(jax.random.key(32), (200,))
    key = jax.random.key(33)
    with pytest.raises(ValueError, match="does not consume"):
        lstsq(A, b, key, method="direct", atol=1e-8)
    with pytest.raises(ValueError, match="does not consume"):
        lstsq(A, b, key, method="fossils", iter_lim=5)
    with pytest.raises(ValueError, match="does not consume"):
        lstsq(A, b, key, method="fossils", atol=1e-8, btol=1e-8)
    # the supported subsets still flow through (and solve accurately)
    x_qr = qr_solve(A, b)

    def relerr(res):
        return float(jnp.linalg.norm(res.x - x_qr) / jnp.linalg.norm(x_qr))

    assert relerr(lstsq(A, b, key, method="fossils", steptol=1e-12)) < 1e-8
    assert relerr(lstsq(A, b, key, method="sap", steptol=1e-12)) < 1e-8


def test_auto_selection_drops_unsupported_tolerances():
    """Under method='auto' the selected solver may not consume every knob;
    they are dropped explicitly instead of raising (or being silently
    absorbed, as before the audit)."""
    A = jax.random.normal(jax.random.key(34), (200, 8))
    b = jax.random.normal(jax.random.key(35), (200,))
    res = lstsq(A, b, jax.random.key(36), atol=1e-8, iter_lim=7)
    assert res.method == "direct"  # small problem; knobs were dropped
    assert int(res.itn) == 0


# --------------------------------------------------------------------------
# Session + streaming trust layer
# --------------------------------------------------------------------------


def test_session_certify_and_solution_bound():
    k1, k2 = jax.random.split(jax.random.key(37))
    A = jax.random.normal(k1, (1500, 24))
    b = jax.random.normal(k2, (1500,))
    solver = SketchedSolver(A, jax.random.key(38))
    cert = solver.certify()
    assert bool(cert.passed) and jnp.isnan(cert.error_bound)
    assert solver.certificate is cert
    res = solver.solve(b)
    full = solver.certify(b, res)
    err = float(jnp.linalg.norm(res.x - qr_solve(A, b)))
    assert err <= 10.0 * float(full.error_bound)
    with pytest.raises(ValueError, match="together"):
        solver.certify(b)


def test_session_update_rows_invalidates_and_recertifies():
    k1, k2 = jax.random.split(jax.random.key(39))
    A = jax.random.normal(k1, (1200, 16))
    rows = jax.random.normal(k2, (3, 16))
    idx = jnp.array([0, 7, 1100])

    plain = SketchedSolver(A, jax.random.key(40))
    plain.certify()
    plain.update_rows(idx, rows)
    assert plain.certificate is None  # drifted: trust must be re-established

    auto = SketchedSolver(A, jax.random.key(41), auto_recertify=True)
    auto.update_rows(idx, rows)
    assert auto.recertifications >= 1
    assert auto.certificate is not None and bool(auto.certificate.passed)


def test_session_recertify_escalates_on_adversarial_drift():
    """Rewriting rows with a much heavier-tailed distribution degrades a
    too-small embedding; auto-recertify must escalate the sketch in place
    (stats move, no new draw) until the probe passes again."""
    m, n = 2048, 32
    A = jax.random.normal(jax.random.key(42), (m, n))
    solver = SketchedSolver(
        A, jax.random.key(43), sketch_size=n + 2, auto_recertify=True
    )
    idx = jnp.arange(64)
    rows = 1e3 * jax.random.normal(jax.random.key(44), (64, n))
    solver.update_rows(idx, rows)
    assert solver.certificate is not None
    if solver.escalations:  # tiny sketches fail the probe and must grow
        assert solver.sketch_size > n + 2
        assert isinstance(solver._sketch_op, sketch_lib.StackedSketch)
        # the escalated factor still matches a from-scratch sketch
        A_new = A.at[idx].set(rows)
        assert jnp.allclose(
            solver._B, solver._sketch_op.apply_op(A_new), atol=1e-9
        )
        assert float(
            jnp.linalg.norm(
                solver.solve(jnp.ones(m)).x - qr_solve(A_new, jnp.ones(m))
            )
        ) < 1e-6


def test_streaming_certified_reuses_pass1_sketch(monkeypatch):
    from repro.streaming import solve as stream_solve
    from repro.streaming.sources import as_source

    sketch_calls = []
    real = stream_solve.stream_sketch

    def counting_stream_sketch(*a, **kw):
        sketch_calls.append(1)
        return real(*a, **kw)

    monkeypatch.setattr(stream_solve, "stream_sketch", counting_stream_sketch)

    k1, k2 = jax.random.split(jax.random.key(45))
    A = jax.random.normal(k1, (1500, 24))
    b = jax.random.normal(k2, (1500,))
    res = lstsq(as_source(A, 256), b, jax.random.key(46), accuracy="certified")
    cert = res.certificate
    assert cert is not None and bool(cert.passed)
    assert len(sketch_calls) == 1  # certification reused the pass-1 sketch
    err = float(jnp.linalg.norm(res.x - qr_solve(A, b)))
    assert err <= 10.0 * max(float(cert.error_bound), 1e-300)

    # single-pass mode: the certificate's fused pass fills the
    # diagnostics that are otherwise nan
    res2 = stream_solve.stream_lstsq(
        as_source(A, 256), b, jax.random.key(47),
        method="sketch_and_solve", certify=True,
    )
    assert res2.certificate is not None
    assert bool(jnp.isfinite(res2.rnorm)) and bool(jnp.isfinite(res2.arnorm))

    # accuracy is validated BEFORE the stream delegation — a typo must not
    # silently return an uncertified result
    with pytest.raises(ValueError, match="unknown accuracy"):
        lstsq(as_source(A, 256), b, jax.random.key(48), accuracy="certifed")
