"""Per-kernel allclose vs ref.py oracles — shape/dtype sweeps, interpret mode."""
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import (
    countsketch_apply, countsketch_ref,
    fused_gaussian_ref, fused_gaussian_sketch, gaussian_matrix_ref,
    hadamard_transform, sketch_matmul, sketch_matmul_ref, srht_apply,
)
from repro.kernels.srht.ref import hadamard_ref, srht_ref


def _tol(dtype):
    return dict(rtol=5e-2, atol=5e-2) if dtype == jnp.bfloat16 else dict(rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("m,n,d", [(1000, 100, 64), (513, 7, 200),
                                   (4096, 256, 512), (300, 1, 33), (8, 128, 8)])
def test_countsketch(m, n, d, dtype):
    A = jax.random.normal(jax.random.key(1), (m, n), dtype)
    h = jax.random.randint(jax.random.key(2), (m,), 0, d, dtype=jnp.int32)
    s = jax.random.rademacher(jax.random.key(3), (m,), dtype)
    got = countsketch_apply(A, h, s, d, interpret=True).astype(jnp.float32)
    want = countsketch_ref(A.astype(jnp.float32), h, s.astype(jnp.float32), d)
    assert got.shape == want.shape
    assert jnp.allclose(got, want, **_tol(dtype))


@pytest.mark.parametrize("m", [8, 64, 512, 2048, 8192])
@pytest.mark.parametrize("n", [1, 5, 130])
def test_hadamard(m, n):
    x = jax.random.normal(jax.random.key(m + n), (m, n), jnp.float32)
    got = hadamard_transform(x, interpret=True)
    want = hadamard_ref(x)
    assert jnp.allclose(got, want, rtol=2e-4, atol=2e-3 * m ** 0.5)


@pytest.mark.parametrize("m,n,d", [(1000, 37, 256), (4096, 128, 512)])
def test_srht(m, n, d):
    m_pad = 1 << (m - 1).bit_length()
    A = jax.random.normal(jax.random.key(0), (m, n), jnp.float32)
    signs = jax.random.rademacher(jax.random.key(1), (m_pad,), jnp.float32)
    rows = jax.random.choice(jax.random.key(2), m_pad, (d,), replace=False)
    got = srht_apply(A, signs, rows, d, interpret=True)
    want = srht_ref(A, signs, rows, d)
    assert jnp.allclose(got, want, rtol=1e-4, atol=1e-3)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("d,m,n", [(64, 1000, 100), (200, 513, 7), (128, 2048, 1)])
def test_sketch_matmul(d, m, n, dtype):
    S = jax.random.normal(jax.random.key(1), (d, m), dtype)
    A = jax.random.normal(jax.random.key(2), (m, n), dtype)
    got = sketch_matmul(S, A, interpret=True).astype(jnp.float32)
    want = sketch_matmul_ref(S.astype(jnp.float32), A.astype(jnp.float32))
    tol = dict(rtol=5e-2, atol=2.0) if dtype == jnp.bfloat16 else dict(rtol=1e-4, atol=1e-3)
    assert jnp.allclose(got, want, **tol)


@pytest.mark.parametrize("d,m,n", [(64, 500, 33), (256, 1024, 130), (33, 100, 1)])
def test_fused_gaussian_bitwise_prng(d, m, n):
    """The in-kernel threefry must generate the SAME S as the jnp oracle."""
    A = jax.random.normal(jax.random.key(3), (m, n), jnp.float32)
    key = jax.random.key(42)
    got = fused_gaussian_sketch(A, key, d, interpret=True)
    want = fused_gaussian_ref(A, key, d)
    assert jnp.allclose(got, want, rtol=1e-4, atol=1e-4)


def test_fused_gaussian_statistics():
    G = gaussian_matrix_ref(jax.random.key(7), 512, 2048)
    assert abs(float(G.mean())) < 0.01
    assert abs(float(G.std()) - 1.0) < 0.01
