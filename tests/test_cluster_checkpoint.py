"""Checkpointable sketch state: save → restore → continue → finalize.

The claim under test, for EVERY sketch kind: interrupting a streaming
accumulation at a tile boundary, checkpointing, restoring (possibly on a
different worker), and continuing over the remaining tiles produces a
sketch BIT-EQUAL to the uninterrupted stream.  ``np.savez`` round-trips
the state arrays bitwise and the remaining fold performs identical
arithmetic from an identical partial state — there is no "close enough"
here, and the tests assert exact equality for all six kinds (including
SRHT's host-side placement buffer and the unmaterialized Gaussian
regenerated from its counter stream).

Also covered: restore into a DIFFERENT worker count (a 1-range
checkpoint finished by two workers via ``split_range`` + merge), and the
refusal paths — wrong operator draw, wrong range metadata.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cluster import (
    CheckpointMismatch,
    RowRangeSource,
    latest_watermark,
    op_digest,
    restore_accumulator,
    save_accumulator,
    split_range,
)
from repro.core import SKETCH_KINDS, sample_sketch
from repro.streaming import ArraySource, make_accumulator, merge_all

M, N, TILE, S_ROWS = 600, 12, 50, 128

ALL_KINDS = list(SKETCH_KINDS)


@pytest.fixture(scope="module")
def data():
    key = jax.random.key(42)
    A = jnp.asarray(np.asarray(jax.random.normal(key, (M, N)), np.float64))
    return A


def _op(kind):
    kw = {"materialize": False} if kind == "gaussian" else {}
    return sample_sketch(kind, jax.random.key(9), S_ROWS, M, **kw)


def _feed(acc, A, lo, hi):
    """Stream grid tiles of A[lo:hi) into acc at global offsets."""
    for o in range(lo, hi, TILE):
        acc.update(A[o : o + TILE], o)
    return acc


@pytest.mark.parametrize("kind", ALL_KINDS)
def test_save_restore_continue_bit_equal(data, tmp_path, kind):
    A = data
    op = _op(kind)

    # uninterrupted reference stream
    ref = _feed(make_accumulator(op, N, dtype=A.dtype), A, 0, M).finalize()

    # interrupted: 4 tiles -> checkpoint -> restore -> remaining tiles
    cut = 4 * TILE
    acc = _feed(make_accumulator(op, N, dtype=A.dtype), A, 0, cut)
    save_accumulator(str(tmp_path), acc, cut, range_start=0, range_stop=M)
    assert latest_watermark(str(tmp_path), 0, M) == cut

    restored, wm = restore_accumulator(
        str(tmp_path), op, N, range_start=0, range_stop=M, dtype=A.dtype
    )
    assert wm == cut
    assert restored.rows_seen == acc.rows_seen
    # the persisted partial state round-trips bitwise
    assert np.array_equal(np.asarray(restored.state), np.asarray(acc.state))

    out = _feed(restored, A, wm, M).finalize()
    assert jnp.array_equal(out, ref), (
        f"{kind}: resume after checkpoint must be bit-equal to the "
        "uninterrupted stream"
    )


@pytest.mark.parametrize("kind", ["countsketch", "srht"])
def test_restore_into_different_worker_count(data, tmp_path, kind):
    """A checkpoint written by ONE worker is finished by TWO: the restored
    partial plus two fresh sub-range partials merge to the same sketch
    (exact for SRHT placement; merge-grouping rounding for additive
    kinds, matching the documented ShardedSource semantics)."""
    A = data
    op = _op(kind)
    ref = _feed(make_accumulator(op, N, dtype=A.dtype), A, 0, M).finalize()

    cut = 4 * TILE
    acc = _feed(make_accumulator(op, N, dtype=A.dtype), A, 0, cut)
    save_accumulator(str(tmp_path), acc, cut, range_start=0, range_stop=M)
    restored, wm = restore_accumulator(
        str(tmp_path), op, N, range_start=0, range_stop=M, dtype=A.dtype
    )

    # the dead worker's remainder [wm, M) split across two new workers
    from repro.cluster import RowRange

    halves = split_range(RowRange(wm, M), 2, TILE)
    assert len(halves) == 2 and halves[0].start == wm and halves[1].stop == M
    parts = [restored]
    for h in halves:
        sub = RowRangeSource(ArraySource(np.asarray(A), tile_rows=TILE),
                             h.start, h.stop, tile_rows=TILE)
        p = make_accumulator(op, N, dtype=A.dtype)
        for local_o, tile in sub.tiles():
            p.update(jnp.asarray(tile), h.start + local_o)
        parts.append(p)
    out = merge_all(parts).finalize()
    if kind == "srht":
        assert jnp.array_equal(out, ref)
    else:
        assert jnp.allclose(out, ref, rtol=0, atol=1e-12)


def test_restore_refuses_wrong_operator_draw(data, tmp_path):
    A = data
    op = _op("countsketch")
    acc = _feed(make_accumulator(op, N, dtype=A.dtype), A, 0, 2 * TILE)
    save_accumulator(str(tmp_path), acc, 2 * TILE, range_start=0, range_stop=M)

    other = sample_sketch("countsketch", jax.random.key(10), S_ROWS, M)
    assert op_digest(other) != op_digest(op)
    with pytest.raises(CheckpointMismatch, match="different operator draw"):
        restore_accumulator(str(tmp_path), other, N,
                            range_start=0, range_stop=M, dtype=A.dtype)
    # the matching draw still restores
    got = restore_accumulator(str(tmp_path), op, N,
                              range_start=0, range_stop=M, dtype=A.dtype)
    assert got is not None


def test_restore_missing_range_returns_none(tmp_path):
    op = _op("countsketch")
    assert restore_accumulator(str(tmp_path), op, N,
                               range_start=0, range_stop=M) is None
    assert latest_watermark(str(tmp_path), 0, M) is None


def test_op_digest_distinguishes_draws_not_objects():
    op1 = _op("sparse_sign")
    op2 = _op("sparse_sign")  # same key -> same draw, distinct objects
    assert op_digest(op1) == op_digest(op2)
    op3 = sample_sketch("sparse_sign", jax.random.key(10), S_ROWS, M)
    assert op_digest(op1) != op_digest(op3)


def test_srht_restore_keeps_writable_host_buffer(data, tmp_path):
    """SRHT's accumulator mutates its placement buffer in place — the
    restored state must be writable host memory, not a jax array."""
    A = data
    op = _op("srht")
    acc = _feed(make_accumulator(op, N, dtype=A.dtype), A, 0, 2 * TILE)
    save_accumulator(str(tmp_path), acc, 2 * TILE, range_start=0, range_stop=M)
    restored, wm = restore_accumulator(
        str(tmp_path), op, N, range_start=0, range_stop=M, dtype=A.dtype
    )
    assert isinstance(restored.state, np.ndarray)
    assert restored.state.flags.writeable
    _feed(restored, A, wm, M)  # in-place updates must not raise
