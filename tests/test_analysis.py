"""reprolint: the analyzer itself, the fixtures, the live-repo gate and
the runtime lock-order watchdog.

Layout mirrors the rule catalog: per-rule positive/negative fixture
pairs under ``tests/fixtures/reprolint/``, the lock-order graph's
acceptance edges, suppression grammar, the seeded-violation gate proof
(copy ``src/`` + drop a bad fixture in → CLI must fail), and the
meta-test that the live repo is clean against the committed baseline.
"""
import shutil
import subprocess
import sys
import threading
from pathlib import Path

import pytest

from repro.analysis import run_analysis
from repro.analysis.cli import main as cli_main
from repro.analysis.findings import scan_suppressions
from repro.obs import lockcheck

REPO = Path(__file__).resolve().parent.parent
FIX = REPO / "tests" / "fixtures" / "reprolint"
SRC = REPO / "src"
BASELINE = REPO / "reprolint-baseline.json"


def rules_of(result):
    return {f.rule for f in result.findings}


def analyze(*paths, **kw):
    kw.setdefault("root", REPO)
    kw.setdefault("baseline_path", None)
    return run_analysis([str(p) for p in paths], **kw)


# ---------------------------------------------------------------- per rule
class TestRuleFixtures:
    def test_r1_positives(self):
        res = analyze(FIX / "bad_r1.py")
        msgs = [f.message for f in res.findings]
        assert rules_of(res) == {"R1"}
        assert sum("write to guarded" in m for m in msgs) == 3
        assert sum("read of guarded" in m for m in msgs) == 1
        # the closure write is attributed to the nested function
        assert any(f.context == "Engine.later" for f in res.findings)

    def test_r1_negative_guarded_and_suppressed(self):
        res = analyze(FIX / "ok_r1.py")
        assert res.findings == []
        assert len(res.suppressed) == 1
        assert res.suppressed[0][1].justification.startswith("only the")

    def test_r1_lock_cycle(self):
        res = analyze(FIX / "bad_lock_cycle.py")
        assert any("cycle" in f.message for f in res.findings)
        assert any("Pair._a" in f.message and "Pair._b" in f.message
                   for f in res.findings)

    def test_r2_positives(self):
        res = analyze(FIX / "bad_r2.py")
        assert rules_of(res) == {"R2"}
        msgs = " | ".join(f.message for f in res.findings)
        assert "print()" in msgs
        assert "np.linalg.norm" in msgs
        assert ".item()" in msgs  # reached through lax.while_loop body

    def test_r2_negative_guards(self):
        res = analyze(FIX / "ok_r2.py")
        assert res.findings == []
        assert len(res.suppressed) == 1

    def test_r3_positives(self):
        res = analyze(FIX / "bad_r3.py")
        assert rules_of(res) == {"R3"}
        assert len(res.findings) == 2

    def test_r3_negatives(self):
        res = analyze(FIX / "ok_r3.py")
        assert res.findings == []

    def test_r4_positives(self):
        res = analyze(FIX / "bad_r4.py")
        assert rules_of(res) == {"R4"}
        msgs = " | ".join(f.message for f in res.findings)
        assert "ABOVE @dataclass" in msgs
        assert "'hidden'" in msgs
        assert "Unregistered" in msgs

    def test_r4_negatives(self):
        res = analyze(FIX / "ok_r4.py")
        assert res.findings == []


# ----------------------------------------------------------- suppressions
class TestSuppressions:
    def test_missing_justification_is_a_finding(self):
        table, bad = scan_suppressions(
            "x = 1  # reprolint: ignore[R1]\n"
        )
        assert table == {}
        assert len(bad) == 1 and "justification" in bad[0][1]

    def test_unknown_rule_is_a_finding(self):
        _, bad = scan_suppressions("x = 1  # reprolint: ignore[R9]: because\n")
        assert len(bad) == 1 and "unknown rule" in bad[0][1]

    def test_valid_suppression_parses(self):
        table, bad = scan_suppressions(
            "x = 1  # reprolint: ignore[R1,R2]: spelled-out reason\n"
        )
        assert bad == []
        assert table[1].covers("R1") and table[1].covers("R2")
        assert not table[1].covers("R3")

    def test_previous_line_covers(self, tmp_path):
        f = tmp_path / "prev.py"
        f.write_text(
            "import threading\n\n\n"
            "class C:\n"
            "    GUARDED_BY = {'n': '_mu'}\n\n"
            "    def __init__(self):\n"
            "        self._mu = threading.Lock()\n"
            "        self.n = 0\n\n"
            "    def bump(self):\n"
            "        # reprolint: ignore[R1]: single-threaded test helper\n"
            "        self.n += 1\n"
        )
        res = analyze(f, root=tmp_path)
        assert res.findings == []
        assert len(res.suppressed) == 1


# -------------------------------------------------------------- lock graph
class TestLockGraph:
    def test_acceptance_edges_and_acyclicity(self):
        res = analyze(SRC)
        g = res.lock_graph
        # serve ordering: dispatch lock is taken first, the submission
        # lock (and the cache's) inside it — never the other way round
        assert "SolveService._lock" in g.edges["SolveService._dispatch_lock"]
        assert "FactorCache._mu" in g.edges["SolveService._dispatch_lock"]
        assert "MicroBatcher._mu" in g.edges["SolveService._lock"]
        # cluster coordinator/checkpoint locks are in the graph
        assert {"ClusterEngine._lock", "ClusterEngine._ckpt_lock"} <= g.nodes
        assert g.cycles() == []

    def test_render_mentions_leaves(self):
        res = analyze(SRC)
        out = res.lock_graph.render()
        assert "Tracer._mu" in out


# ------------------------------------------------------- the gate, end-to-end
class TestGate:
    def test_live_repo_clean_against_committed_baseline(self):
        res = run_analysis(
            [str(SRC)],
            baseline_path=str(BASELINE) if BASELINE.exists() else None,
            root=REPO,
        )
        assert res.findings == [], "\n".join(f.render() for f in res.findings)

    def test_every_suppression_in_src_has_rule_and_justification(self):
        res = analyze(SRC)
        for _, sup in res.suppressed:
            assert sup.rules and "*" not in sup.rules
            assert len(sup.justification) >= 8

    @pytest.mark.parametrize(
        "fixture", ["bad_r1.py", "bad_r2.py", "bad_r3.py", "bad_r4.py",
                    "bad_lock_cycle.py"]
    )
    def test_seeded_violation_fails_the_gate(self, tmp_path, fixture):
        seeded = tmp_path / "src"
        shutil.copytree(SRC, seeded)
        shutil.copy(FIX / fixture, seeded / "repro" / f"seeded_{fixture}")
        res = run_analysis([str(seeded)], baseline_path=None, root=tmp_path)
        assert res.findings, f"seeding {fixture} must fail the gate"

    def test_cli_exit_codes(self, tmp_path):
        assert cli_main([str(SRC), "--no-baseline", "--root", str(REPO)]) == 0
        assert cli_main([str(FIX / "bad_r1.py"), "--no-baseline",
                         "--root", str(REPO)]) == 1
        assert cli_main([str(SRC), "--rules", "R7"]) == 2

    def test_cli_subprocess_matches_ci_invocation(self):
        # Exactly what the CI analysis job runs, minus the fixtures proof.
        proc = subprocess.run(
            [sys.executable, "-m", "repro.analysis", "src/"],
            cwd=REPO, capture_output=True, text=True,
            env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"},
        )
        assert proc.returncode == 0, proc.stdout + proc.stderr
        assert "OK" in proc.stdout

    def test_write_baseline_roundtrip(self, tmp_path):
        bl = tmp_path / "baseline.json"
        bad = FIX / "bad_r3.py"
        assert cli_main([str(bad), "--baseline", str(bl),
                         "--write-baseline", "--root", str(REPO)]) == 0
        # same findings now tolerated via the baseline
        assert cli_main([str(bad), "--baseline", str(bl),
                         "--root", str(REPO)]) == 0
        # but a different rule's violations still fail
        assert cli_main([str(FIX / "bad_r1.py"), "--baseline", str(bl),
                         "--root", str(REPO)]) == 1


# ------------------------------------------------------------ the watchdog
class TestLockWatchdog:
    @pytest.fixture(autouse=True)
    def _clean(self):
        lockcheck.enable()
        lockcheck.reset_observations()
        yield
        lockcheck.disable()
        lockcheck.reset_observations()

    def test_disabled_returns_plain_locks(self):
        lockcheck.disable()
        lk = lockcheck.make_lock("X")
        assert not isinstance(lk, lockcheck.OrderedLock)
        assert lockcheck.make_rlock("Y") is not None

    def test_inversion_raises_on_second_ordering(self):
        a = lockcheck.make_lock("A")
        b = lockcheck.make_lock("B")
        with a:
            with b:
                pass
        with pytest.raises(lockcheck.LockOrderError, match="inversion"):
            with b:
                with a:
                    pass

    def test_transitive_inversion_detected(self):
        a = lockcheck.make_lock("A")
        b = lockcheck.make_lock("B")
        c = lockcheck.make_lock("C")
        with a:
            with b:
                pass
        with b:
            with c:
                pass
        with pytest.raises(lockcheck.LockOrderError):
            with c:
                with a:
                    pass

    def test_rlock_reentry_ignored(self):
        r = lockcheck.make_rlock("R")
        with r:
            with r:
                pass  # no self-edge, no error

    def test_same_name_pairs_unordered(self):
        m1 = lockcheck.make_lock("M._mu")
        m2 = lockcheck.make_lock("M._mu")
        with m1:
            with m2:
                pass
        with m2:
            with m1:
                pass  # two instances of one class: never ordered

    def test_edges_recorded_across_threads(self):
        a = lockcheck.make_lock("A")
        b = lockcheck.make_lock("B")

        def use():
            with a:
                with b:
                    pass

        t = threading.Thread(target=use, daemon=True)
        t.start()
        t.join()
        assert "B" in lockcheck.observed_edges().get("A", {})

    def test_serve_stack_runs_clean_under_watchdog(self):
        import jax
        import jax.numpy as jnp
        from repro.serve import SolveService

        svc = SolveService(jax.random.PRNGKey(0), max_delay_s=0.0)
        A = jax.random.normal(jax.random.PRNGKey(1), (80, 6))
        x = jnp.ones((6,))
        resp = svc.solve(A, A @ x)
        assert resp.status == "ok"
        edges = lockcheck.observed_edges()
        held_first = edges.get("SolveService._dispatch_lock", {})
        assert "SolveService._lock" in held_first
