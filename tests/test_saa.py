"""SAA-SAS (Algorithm 1) system tests — the paper's headline claims."""
import jax
import jax.numpy as jnp
import pytest

from repro.core import generate_problem, lsqr_dense, qr_solve, saa_sas, sap_sas


@pytest.fixture(scope="module")
def prob():
    return generate_problem(jax.random.key(0), 4000, 64, cond=1e10, beta=1e-10)


def relerr(x, xt):
    return float(jnp.linalg.norm(x - xt) / jnp.linalg.norm(xt))


def test_saa_matches_qr_accuracy(prob):
    """Paper Fig. 4: SAA error comparable to direct methods at κ=1e10."""
    res = saa_sas(prob.A, prob.b, jax.random.key(1))
    assert res.converged
    e_saa = relerr(res.x, prob.x_true)
    e_qr = relerr(qr_solve(prob.A, prob.b), prob.x_true)
    assert e_saa < 1e-5
    assert e_saa < 100 * max(e_qr, 1e-12)


def test_saa_beats_plain_lsqr_accuracy(prob):
    """Plain LSQR stalls on κ=1e10; SAA-SAS does not."""
    res = saa_sas(prob.A, prob.b, jax.random.key(1))
    rl = lsqr_dense(prob.A, prob.b, iter_lim=128)
    assert relerr(res.x, prob.x_true) < relerr(rl.x, prob.x_true) / 100


def test_saa_iteration_count_small(prob):
    """Whitened system converges in O(10) iterations independent of κ."""
    res = saa_sas(prob.A, prob.b, jax.random.key(1))
    assert int(res.itn) < 40


def test_operator_form_matches_materialized(prob):
    r1 = saa_sas(prob.A, prob.b, jax.random.key(2), materialize_y=True)
    r2 = saa_sas(prob.A, prob.b, jax.random.key(2), materialize_y=False)
    assert relerr(r1.x, r2.x + 1e-300) < 1e-4


def test_fallback_branch_executes(prob):
    """Force non-convergence (iter_lim=1) -> perturbation branch runs."""
    res = saa_sas(prob.A, prob.b, jax.random.key(3), iter_lim=1)
    assert bool(res.used_fallback)


@pytest.mark.parametrize("kind", ["gaussian", "srht", "sparse_sign"])
def test_saa_with_other_sketches(prob, kind):
    res = saa_sas(prob.A, prob.b, jax.random.key(4), sketch=kind)
    assert relerr(res.x, prob.x_true) < 1e-4


def test_sap_documented_instability(prob):
    """Paper §4: SAP with zero init is not competitive on severely
    ill-conditioned problems — we reproduce that finding via
    ``warm_start=False`` (the default now threads the SAA warm start
    through the shared SketchedFactor and converges; see test_sap.py)."""
    rs = sap_sas(prob.A, prob.b, jax.random.key(5), warm_start=False)
    ra = saa_sas(prob.A, prob.b, jax.random.key(5))
    assert relerr(ra.x, prob.x_true) < relerr(rs.x, prob.x_true)
