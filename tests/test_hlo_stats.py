"""HLO collective parser unit tests."""
from repro.launch.hlo_stats import collective_stats, parse_shape_bytes


def test_parse_shape_bytes():
    assert parse_shape_bytes("f32[16,128]") == 16 * 128 * 4
    assert parse_shape_bytes("bf16[8]") == 16
    assert parse_shape_bytes("(f32[4], bf16[2,2])") == 16 + 8


def test_all_gather_line():
    line = ("%all-gather = f32[256,128]{1,0} all-gather(%param.1), channel_id=1, "
            "replica_groups=[4,4]<=[4,4]T(1,0), dimensions={0}, use_global_device_ids=true")
    st = collective_stats(line)
    assert st["by_kind"]["all-gather"]["count"] == 1
    expect = 256 * 128 * 4 * (3 / 4)
    assert abs(st["total_bytes"] - expect) < 1


def test_all_reduce_and_permute():
    text = """
  %all-reduce.3 = bf16[1024]{0} all-reduce(%x), replica_groups={{0,1,2,3,4,5,6,7}}, to_apply=%add
  %collective-permute.1 = f32[64,64]{1,0} collective-permute(%y), source_target_pairs={{0,1},{1,0}}
"""
    st = collective_stats(text)
    ar = st["by_kind"]["all-reduce"]["bytes"]
    cp = st["by_kind"]["collective-permute"]["bytes"]
    assert abs(ar - 2 * 1024 * 2 * (7 / 8)) < 1
    assert cp == 64 * 64 * 4


def test_reduce_scatter():
    line = ("%reduce-scatter = f32[32,16]{1,0} reduce-scatter(%z), "
            "replica_groups=[1,8]<=[8], dimensions={1}, to_apply=%add")
    st = collective_stats(line)
    assert abs(st["total_bytes"] - 32 * 16 * 4 * 7) < 1


def test_ignores_non_collectives():
    text = "%add.5 = f32[128]{0} add(%a, %b)\n%dot = f32[8,8]{1,0} dot(%c, %d)"
    assert collective_stats(text)["total_bytes"] == 0
