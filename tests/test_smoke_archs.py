"""Per-arch smoke: reduced config, one forward + one train gradient step on
CPU, asserting output shapes and no NaNs (assignment requirement)."""
import math

import jax
import jax.numpy as jnp
import pytest

from repro.configs import cells, list_archs, smoke_config
from repro.models import init_params, loss_fn, forward

# Model-zoo / multi-process / long-sweep module: slow tier (see pytest.ini)
pytestmark = pytest.mark.slow


@pytest.mark.parametrize("arch", list_archs())
def test_smoke_forward_and_grads(arch):
    cfg = smoke_config(arch)
    params = init_params(cfg, jax.random.key(0))
    B, S = 2, 64
    batch = {}
    if cfg.frontend == "frames":
        batch["embeds"] = jax.random.normal(
            jax.random.key(1), (B, S, cfg.d_model), jnp.float32
        )
    else:
        batch["tokens"] = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab)
    if cfg.frontend == "vision":
        batch["image_embeds"] = jax.random.normal(
            jax.random.key(2), (B, cfg.n_patches, cfg.d_model), jnp.float32
        )
    batch["labels"] = jax.random.randint(jax.random.key(3), (B, S), 0, cfg.vocab)

    logits = forward(cfg, params, batch)
    assert logits.shape == (B, S, cfg.vocab)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: NaN/inf logits"

    (loss, metrics), grads = jax.value_and_grad(
        lambda p: loss_fn(cfg, p, batch), has_aux=True
    )(params)
    assert bool(jnp.isfinite(loss)), f"{arch}: NaN loss"
    assert 0.5 * math.log(cfg.vocab) < float(loss) < 3 * math.log(cfg.vocab) + 1
    gnorm = jnp.sqrt(
        sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in jax.tree.leaves(grads))
    )
    assert bool(jnp.isfinite(gnorm)), f"{arch}: NaN grads"


def test_all_archs_have_cells():
    for a in list_archs():
        cs = cells(a)
        assert {"train_4k", "prefill_32k", "decode_32k"} <= set(cs)
