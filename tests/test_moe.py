"""MoE dispatch: capacity-based sort dispatch vs dense per-token oracle."""
import jax
import jax.numpy as jnp

from repro.configs import smoke_config
from repro.models import init_params
from repro.models.moe import moe_apply


def dense_oracle(p, x, cfg):
    """Compute every expert for every token; combine by gates (no drops)."""
    from repro.models.common import rms_norm, activation

    m = cfg.moe
    h_in = rms_norm(x, p["ln"], cfg.norm_eps)
    h = h_in.reshape(-1, x.shape[-1])
    logits = h.astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    vals, idx = jax.lax.top_k(probs, m.top_k)
    if m.norm_topk:
        vals = vals / vals.sum(-1, keepdims=True)
    up = jnp.einsum("td,edf->tef", h, p["w_in"])
    act = activation(cfg.act, up, jnp.einsum("td,edf->tef", h, p["w_gate"]))
    ye = jnp.einsum("tef,efd->ted", act, p["w_out"])  # (T, E, D)
    gates = jnp.zeros(probs.shape).at[
        jnp.arange(h.shape[0])[:, None], idx
    ].set(vals)
    y = jnp.einsum("ted,te->td", ye, gates.astype(ye.dtype))
    if m.n_shared:
        s_act = activation(cfg.act, h @ p["shared_in"], h @ p["shared_gate"])
        y = y + s_act @ p["shared_out"]
    return x + y.reshape(x.shape).astype(x.dtype)


def test_moe_matches_dense_oracle_without_drops():
    import dataclasses

    cfg = smoke_config("mixtral-8x7b")
    # capacity_factor big enough that nothing drops
    cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, capacity_factor=4.0))
    params = init_params(cfg, jax.random.key(0))
    p = params["pattern"][0]["ffn"]
    p0 = jax.tree.map(lambda a: a[0], p)  # first period's params
    x = jax.random.normal(jax.random.key(1), (2, 16, cfg.d_model), jnp.float32)
    got = moe_apply(p0, x, cfg)
    want = dense_oracle(p0, x, cfg)
    assert jnp.allclose(got, want, rtol=1e-4, atol=1e-4)


def test_moe_aux_loss_range():
    cfg = smoke_config("deepseek-v2-236b")
    params = init_params(cfg, jax.random.key(0))
    p0 = jax.tree.map(lambda a: a[0], params["pattern"][0]["ffn"])
    x = jax.random.normal(jax.random.key(1), (2, 16, cfg.d_model), jnp.float32)
    out, aux = moe_apply(p0, x, cfg, return_aux=True)
    assert out.shape == x.shape
    # balanced would be aux_weight * 1.0; allow wide slack at init
    assert 0.0 < float(aux) < 10.0
