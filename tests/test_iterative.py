"""Forward-stable solvers (iterative sketching, FOSSILS) — Epperly/EMN 2024.

The headline assertions mirror benchmarks/error_comparison.py: on a κ=1e10
problem with a non-trivial residual, the operator-form SAA path (the
at-scale configuration) stagnates >10x above the QR forward error, while
both forward-stable solvers stay within 10x of QR.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.core import (
    SolveResult,
    damping_momentum,
    fossils,
    generate_problem,
    iterative_sketching,
    qr_solve,
    saa_sas,
)


@pytest.fixture(scope="module")
def prob():
    """Small κ=1e10 problem (tiny residual) for accuracy/parity tests."""
    return generate_problem(jax.random.key(0), 4000, 64, cond=1e10, beta=1e-10)


@pytest.fixture(scope="module")
def stab_prob():
    """Benchmark-shape κ=1e10 problem with β=1e-5 — the forward-stability
    regime where rounding in the solve dominates the residual floor.

    The draw is pinned (seed 7, β=1e-5) so the SAA/QR forward-error gap
    asserted by ``test_forward_stability_gap`` is comfortably above its
    10x threshold: the previous draw (seed 4, β=1e-6) sat at 8–26x across
    sketch keys, flaking right at the margin, while this one holds 17–26x
    with the stable solvers still well inside 10x of QR.
    """
    return generate_problem(jax.random.key(7), 20000, 100, cond=1e10, beta=1e-5)


def relerr(x, xt):
    return float(jnp.linalg.norm(x - xt) / jnp.linalg.norm(xt))


def test_iterative_sketching_matches_qr(prob):
    res = iterative_sketching(prob.A, prob.b, jax.random.key(1))
    assert isinstance(res, SolveResult)
    assert res.converged
    e = relerr(res.x, prob.x_true)
    e_qr = relerr(qr_solve(prob.A, prob.b), prob.x_true)
    assert e < 10 * max(e_qr, 1e-12)


def test_fossils_matches_qr(prob):
    res = fossils(prob.A, prob.b, jax.random.key(1))
    assert isinstance(res, SolveResult)
    assert res.converged
    e = relerr(res.x, prob.x_true)
    e_qr = relerr(qr_solve(prob.A, prob.b), prob.x_true)
    assert e < 10 * max(e_qr, 1e-12)


def test_forward_stability_gap(stab_prob):
    """Acceptance demo: iterative/FOSSILS within 10x of QR; plain SAA-SAS in
    its operator form (the at-scale path used by repro.core.distributed) is
    not."""
    A, b, xt = stab_prob.A, stab_prob.b, stab_prob.x_true
    e_qr = relerr(qr_solve(A, b), xt)
    key = jax.random.key(104)
    e_saa = relerr(saa_sas(A, b, key, materialize_y=False).x, xt)
    e_it = relerr(iterative_sketching(A, b, key).x, xt)
    e_fo = relerr(fossils(A, b, key).x, xt)
    assert e_saa > 10 * e_qr, f"saa_op={e_saa:.3e} qr={e_qr:.3e}"
    assert e_it < 10 * e_qr, f"iter={e_it:.3e} qr={e_qr:.3e}"
    assert e_fo < 10 * e_qr, f"fossils={e_fo:.3e} qr={e_qr:.3e}"


def test_residual_history_monotone(prob):
    res = iterative_sketching(prob.A, prob.b, jax.random.key(2), history=True)
    hist = res.history
    assert hist.shape == (100,)  # default iter_lim, fixed shape
    valid = hist[: int(res.itn)]
    assert bool(jnp.all(jnp.isfinite(valid)))
    assert bool(jnp.all(jnp.isnan(hist[int(res.itn):])))
    # Residual norms decrease to the floor (small slack for floor wobble).
    assert bool(jnp.all(valid[1:] <= valid[:-1] * 1.05))
    assert float(valid[-1]) <= float(valid[0])


def test_fossils_history_decreases(prob):
    res = fossils(prob.A, prob.b, jax.random.key(2), history=True)
    hist = res.history
    assert hist.shape == (3,)  # refine_steps + 1 outer residuals
    assert float(hist[-1]) <= float(hist[0]) * 1.05


@pytest.mark.parametrize("solver", [iterative_sketching, fossils])
def test_backend_parity(solver):
    """reference and pallas (interpret) backends realize the same solve."""
    prob = generate_problem(jax.random.key(3), 1024, 24, cond=1e8, beta=1e-10)
    r_ref = solver(prob.A, prob.b, jax.random.key(5), backend="reference")
    r_pal = solver(prob.A, prob.b, jax.random.key(5), backend="pallas")
    assert relerr(r_ref.x, r_pal.x + 1e-300) < 1e-6
    assert relerr(r_ref.x, prob.x_true) < 1e-5


def test_damping_momentum_formula():
    # s = 4n -> distortion 1/2 -> alpha = (1 - 1/4)^2, beta = 1/4.
    alpha, beta = damping_momentum(256, 64)
    assert alpha == pytest.approx(0.5625)
    assert beta == pytest.approx(0.25)


def test_custom_coefficients_still_converge(prob):
    res = iterative_sketching(
        prob.A, prob.b, jax.random.key(6), damping=0.5, momentum=0.2,
        iter_lim=200,
    )
    assert relerr(res.x, prob.x_true) < 1e-5


def test_iterative_other_sketches(prob):
    res = iterative_sketching(prob.A, prob.b, jax.random.key(7), sketch="gaussian")
    assert relerr(res.x, prob.x_true) < 1e-5
