"""Blockwise flash attention vs naive softmax attention oracle."""
import jax
import jax.numpy as jnp
import pytest

from repro.models.attention import decode_attention, flash_attention


def naive(q, k, v, causal=True, window=None):
    B, Hq, Sq, dk = q.shape
    Hkv = k.shape[1]
    G = Hq // Hkv
    qg = q.reshape(B, Hkv, G, Sq, dk)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, k) / jnp.sqrt(dk * 1.0)
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(k.shape[2])[None, :]
    mask = jnp.ones((Sq, k.shape[2]), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p, v)
    return o.reshape(B, Hq, Sq, v.shape[-1])


@pytest.mark.parametrize("window", [None, 16, 64])
@pytest.mark.parametrize("gqa", [1, 4])
def test_flash_matches_naive(window, gqa):
    B, Hkv, S, dk, dv = 2, 2, 128, 16, 24
    q = jax.random.normal(jax.random.key(0), (B, Hkv * gqa, S, dk), jnp.float32)
    k = jax.random.normal(jax.random.key(1), (B, Hkv, S, dk), jnp.float32)
    v = jax.random.normal(jax.random.key(2), (B, Hkv, S, dv), jnp.float32)
    got = flash_attention(q, k, v, causal=True, window=window,
                          q_block=32, kv_block=32)
    want = naive(q, k, v, causal=True, window=window)
    assert jnp.allclose(got, want, rtol=1e-4, atol=1e-4)


def test_flash_cross_attention():
    B, H, Sq, P, dk = 2, 3, 64, 40, 16
    q = jax.random.normal(jax.random.key(0), (B, H, Sq, dk), jnp.float32)
    k = jax.random.normal(jax.random.key(1), (B, H, P, dk), jnp.float32)
    v = jax.random.normal(jax.random.key(2), (B, H, P, dk), jnp.float32)
    got = flash_attention(q, k, v, causal=False, q_block=16, kv_block=8)
    want = naive(q, k, v, causal=False)
    assert jnp.allclose(got, want, rtol=1e-4, atol=1e-4)


def test_decode_matches_last_row_of_full():
    B, Hkv, S, dk = 2, 2, 64, 16
    q = jax.random.normal(jax.random.key(0), (B, 4, S, dk), jnp.float32)
    k = jax.random.normal(jax.random.key(1), (B, Hkv, S, dk), jnp.float32)
    v = jax.random.normal(jax.random.key(2), (B, Hkv, S, dk), jnp.float32)
    full = naive(q, k, v, causal=True)
    valid = jnp.ones((B, S), bool)
    one = decode_attention(q[:, :, -1], k, v, valid)
    assert jnp.allclose(one, full[:, :, -1], rtol=1e-4, atol=1e-4)
