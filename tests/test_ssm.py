"""Mamba2 SSD: chunked dual form vs naive sequential recurrence."""
import jax
import jax.numpy as jnp
import pytest

from repro.models.ssm import ssd_chunked

# Model-zoo / multi-process / long-sweep module: slow tier (see pytest.ini)
pytestmark = pytest.mark.slow


def naive_recurrence(x, dt, A, B, C):
    """h_{t} = exp(dt_t A) h_{t-1} + dt_t x_t B_tᵀ;  y_t = C_t h_t."""
    b, s, h, p = x.shape
    n = B.shape[-1]

    def step(hstate, inp):
        xt, dtt, Bt, Ct = inp  # (b,h,p), (b,h), (b,n), (b,n)
        decay = jnp.exp(dtt * A)[..., None, None]  # (b,h,1,1)
        hstate = hstate * decay + jnp.einsum(
            "bhp,bn->bhpn", xt * dtt[..., None], Bt
        )
        y = jnp.einsum("bhpn,bn->bhp", hstate, Ct)
        return hstate, y

    h0 = jnp.zeros((b, h, p, n))
    xs = (jnp.moveaxis(x, 1, 0), jnp.moveaxis(dt, 1, 0),
          jnp.moveaxis(B, 1, 0), jnp.moveaxis(C, 1, 0))
    hT, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1), hT


@pytest.mark.parametrize("chunk", [4, 16, 64])
def test_chunked_matches_naive(chunk):
    b, s, h, p, n = 2, 64, 3, 4, 8
    key = jax.random.key(0)
    x = jax.random.normal(jax.random.key(1), (b, s, h, p))
    dt = jax.random.uniform(jax.random.key(2), (b, s, h), minval=0.01, maxval=0.2)
    A = -jax.random.uniform(jax.random.key(3), (h,), minval=0.5, maxval=2.0)
    B = jax.random.normal(jax.random.key(4), (b, s, n))
    C = jax.random.normal(jax.random.key(5), (b, s, n))
    y_naive, h_naive = naive_recurrence(x, dt, A, B, C)
    y_chunk, h_chunk = ssd_chunked(x, dt, A, B, C, chunk)
    assert jnp.allclose(y_chunk, y_naive, rtol=1e-4, atol=1e-5)
    assert jnp.allclose(h_chunk, h_naive, rtol=1e-4, atol=1e-5)


def test_initial_state_threading():
    b, s, h, p, n = 1, 32, 2, 4, 8
    x = jax.random.normal(jax.random.key(1), (b, s, h, p))
    dt = jax.random.uniform(jax.random.key(2), (b, s, h), minval=0.01, maxval=0.2)
    A = -jnp.ones((h,))
    B = jax.random.normal(jax.random.key(4), (b, s, n))
    C = jax.random.normal(jax.random.key(5), (b, s, n))
    # run full 32 vs two halves with state threading
    y_full, h_full = ssd_chunked(x, dt, A, B, C, 8)
    y1, h1 = ssd_chunked(x[:, :16], dt[:, :16], A, B[:, :16], C[:, :16], 8)
    y2, h2 = ssd_chunked(x[:, 16:], dt[:, 16:], A, B[:, 16:], C[:, 16:], 8, h0=h1)
    assert jnp.allclose(jnp.concatenate([y1, y2], 1), y_full, rtol=1e-4, atol=1e-5)
    assert jnp.allclose(h2, h_full, rtol=1e-4, atol=1e-5)
