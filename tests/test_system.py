"""End-to-end behaviour tests for the paper's system: the full
sketch-and-solve pipeline reproduces the paper's claims (see also
benchmarks/ for the figure-level reproductions)."""
import jax
import jax.numpy as jnp

from repro.core import generate_problem, lsqr_dense, qr_solve, saa_sas


def test_paper_headline_claim():
    """SAA-SAS: LSQR-beating runtime-per-accuracy on ill-conditioned LSQ.

    At κ=1e10 the whitened inner solve converges in O(10) iterations to
    direct-QR forward error, while plain LSQR stalls for hundreds of
    iterations at O(1) error — the paper's Fig. 3+4 in one assertion.
    """
    prob = generate_problem(jax.random.key(0), 8000, 96, cond=1e10, beta=1e-10)
    saa = saa_sas(prob.A, prob.b, jax.random.key(1))
    lsqr = lsqr_dense(prob.A, prob.b, iter_lim=192)
    qr = qr_solve(prob.A, prob.b)

    def err(x):
        return float(jnp.linalg.norm(x - prob.x_true))

    assert saa.converged and int(saa.itn) < 40
    assert err(saa.x) < 1e-5
    assert err(saa.x) < 50 * max(err(qr), 1e-9)
    assert err(lsqr.x) > 100 * err(saa.x)


def test_sparse_beats_dense_sketch_cost():
    """Paper §2.3: CW sketch applies in O(nnz) — it must not be slower than
    the dense Gaussian apply at equal sketch size (semantic check: both
    produce valid embeddings; the cost claim is covered by benchmarks)."""
    from repro.core import sample_sketch
    m, n, d = 4096, 32, 256
    A = jax.random.normal(jax.random.key(0), (m, n))
    for kind in ("countsketch", "gaussian"):
        op = sample_sketch(kind, jax.random.key(1), d, m)
        sv = jnp.linalg.svd(
            op.apply(jnp.linalg.qr(A)[0]), compute_uv=False
        )
        assert 0.4 < float(sv.min()) and float(sv.max()) < 1.6
