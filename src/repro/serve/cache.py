"""LRU factor cache — live ``SketchedSolver`` sessions under a byte budget.

The service's economics: a session build costs one sketch + one QR
(O(mn + sn²)); a cached solve costs whitened LSQR iterations only.  The
cache therefore holds *sessions*, not solutions — the artifact whose
rebuild is expensive and whose marginal use is cheap.

Policy and accounting:

- **LRU by fingerprint.**  ``get_or_build(fp, builder)`` returns the live
  session on a hit (and refreshes recency), builds + inserts on a miss.
- **Byte budget.**  Each entry is charged the bytes of the artifacts the
  session *owns* — the stored sketch B, the QR factor (Q, R) and the
  materialized whitened Y when present.  (The data matrix A is pinned by
  the session but owned by the caller; charging it would double-count
  every tenant's own data.)  Inserting past ``max_bytes`` evicts LRU
  entries until the new entry fits; a single entry larger than the whole
  budget is still admitted (the service could not run otherwise) and
  simply evicts everything else.
- **Counters.**  ``hits`` / ``misses`` / ``evictions`` / ``bytes`` are
  live attributes; ``stats()`` snapshots them plus per-entry hit counts.
- **Drift-aware invalidation.**  ``update_rows(fp, idx, rows)`` routes a
  data update *through* the cached session (O(|idx|·n) delta-sketch, no
  rebuild), re-keys the entry under the updated matrix's fingerprint and
  — for sessions built with ``auto_recertify`` — lets the session's
  recertification escalate the drifted embedding.  If recertification
  exhausts its escalation room without a passing certificate the entry is
  dropped: serving from a factor known to be bad is worse than a rebuild.
"""
from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict
from typing import Callable

import jax

from ..analysis.annotations import guarded_by
from ..core.session import SketchedSolver
from ..obs import trace as obs_trace
from ..obs.lockcheck import make_rlock
from ..obs.metrics import REGISTRY
from .fingerprint import Fingerprint, fingerprint

__all__ = ["FactorCache", "CacheEntry", "session_nbytes"]


def session_nbytes(solver: SketchedSolver) -> int:
    """Bytes of the session-owned artifacts: B, the QR factor, Y."""
    leaves = jax.tree_util.tree_leaves(
        (solver._B, tuple(solver.factor), solver._Y)
    )
    return int(sum(getattr(leaf, "nbytes", 0) for leaf in leaves))


@dataclasses.dataclass
class CacheEntry:
    solver: SketchedSolver
    fp: Fingerprint
    nbytes: int
    hits: int = 0
    built_s: float = 0.0  # wall seconds the builder spent


class FactorCache:
    """LRU cache of live :class:`SketchedSolver` sessions, byte-budgeted.

    Thread-safe: every public method holds an internal lock, so the
    service's pump thread, a synchronous ``flush()`` caller and a
    ``stats()`` poller can touch the cache concurrently.  Session
    *builds* run outside the lock (they can take seconds); a racing
    build of the same fingerprint is resolved first-put-wins.
    """

    GUARDED_BY = {
        "_entries": "_mu",
        "bytes": "_mu",
        "hits": "_mu",
        "misses": "_mu",
        "evictions": "_mu",
    }
    GUARDED_READS = frozenset({"_entries"})

    def __init__(self, max_bytes: int = 256 * 1024 * 1024):
        self.max_bytes = int(max_bytes)
        self._entries: "OrderedDict[Fingerprint, CacheEntry]" = OrderedDict()
        self._mu = make_rlock("FactorCache._mu")
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.bytes = 0
        self._m_hits = REGISTRY.counter("cache.hits")
        self._m_misses = REGISTRY.counter("cache.misses")
        self._m_evictions = REGISTRY.counter("cache.evictions")
        self._m_bytes = REGISTRY.gauge("cache.bytes")
        self._m_entries = REGISTRY.gauge("cache.entries")
        self._m_build_s = REGISTRY.histogram("cache.build_s")

    @guarded_by("_mu")
    def _sync_gauges(self) -> None:
        self._m_bytes.set(self.bytes)
        self._m_entries.set(len(self._entries))

    # ------------------------------------------------------------- lookups
    def __len__(self) -> int:
        with self._mu:
            return len(self._entries)

    def __contains__(self, fp: Fingerprint) -> bool:
        with self._mu:
            return fp in self._entries

    def get(self, fp: Fingerprint) -> SketchedSolver | None:
        """Hit → the live session (recency refreshed); miss → None."""
        with self._mu:
            entry = self._entries.get(fp)
            if entry is None:
                self.misses += 1
                self._m_misses.inc()
                return None
            self._entries.move_to_end(fp)
            entry.hits += 1
            self.hits += 1
            self._m_hits.inc()
            return entry.solver

    def get_or_build(
        self, fp: Fingerprint, builder: Callable[[], SketchedSolver]
    ) -> tuple[SketchedSolver, bool]:
        """``(session, was_hit)`` — the service's single entry point."""
        solver = self.get(fp)
        if solver is not None:
            return solver, True
        t0 = time.perf_counter()
        with obs_trace.span("cache.build", fp=fp.short()):
            solver = builder()  # outside the lock: builds can take seconds
        built_s = time.perf_counter() - t0
        self._m_build_s.observe(built_s)
        with self._mu:
            entry = self._entries.get(fp)
            if entry is not None:
                # another thread's build landed first: use THAT live
                # session (it may already hold compiled ladders / drift
                # state) and drop ours on the floor.
                self._entries.move_to_end(fp)
                entry.hits += 1
                self.hits += 1
                self._m_hits.inc()
                return entry.solver, True
            self.put(fp, solver, built_s=built_s)
        return solver, False

    # ------------------------------------------------------------- updates
    def put(
        self, fp: Fingerprint, solver: SketchedSolver, *, built_s: float = 0.0
    ) -> CacheEntry:
        with self._mu:
            if fp in self._entries:
                self._drop(fp)
            entry = CacheEntry(
                solver=solver, fp=fp, nbytes=session_nbytes(solver),
                built_s=built_s,
            )
            self._entries[fp] = entry
            self.bytes += entry.nbytes
            self._evict_to_budget(keep=fp)
            self._sync_gauges()
            return entry

    @guarded_by("_mu")
    def _drop(self, fp: Fingerprint) -> CacheEntry | None:
        entry = self._entries.pop(fp, None)
        if entry is not None:
            self.bytes -= entry.nbytes
        return entry

    def invalidate(self, fp: Fingerprint) -> bool:
        """Explicitly drop an entry (counted as an eviction)."""
        with self._mu:
            if self._drop(fp) is None:
                return False
            self.evictions += 1
            self._m_evictions.inc()
            obs_trace.instant("cache.eviction", fp=fp.short(), kind="explicit")
            self._sync_gauges()
            return True

    def clear(self) -> None:
        with self._mu:
            dropped = len(self._entries)
            self.evictions += dropped
            self._m_evictions.inc(dropped)
            self._entries.clear()
            self.bytes = 0
            self._sync_gauges()

    @guarded_by("_mu")
    def _evict_to_budget(self, keep: Fingerprint) -> None:
        # Evict LRU-first until under budget; the just-touched entry is
        # exempt so one oversized tenant degrades to cache-of-one rather
        # than thrashing itself out.
        while self.bytes > self.max_bytes and len(self._entries) > 1:
            lru_fp = next(iter(self._entries))
            if lru_fp == keep:
                self._entries.move_to_end(lru_fp)
                lru_fp = next(iter(self._entries))
            self._drop(lru_fp)
            self.evictions += 1
            self._m_evictions.inc()
            obs_trace.instant("cache.eviction", fp=lru_fp.short(),
                              kind="budget")

    # ------------------------------------------------------ drift handling
    def update_rows(self, fp: Fingerprint, idx, rows) -> Fingerprint | None:
        """Apply ``A[idx] ← rows`` through the cached session and re-key.

        Returns the UPDATED matrix's fingerprint (the old key is dead —
        its data no longer exists anywhere), or ``None`` when the entry
        had to be dropped because the drifted embedding could not be
        recertified within the session's escalation room.  Cache misses
        raise ``KeyError``: there is nothing to update.
        """
        with self._mu:
            entry = self._entries.get(fp)
            if entry is None:
                raise KeyError(f"no cached session for {fp.short()}")
            solver = entry.solver
            solver.update_rows(idx, rows)  # delta-sketch + small QR in-session
            if solver.auto_recertify and solver.certificate is not None:
                if not bool(solver.certificate.passed):
                    # escalation room exhausted without a passing certificate:
                    # this factor is KNOWN bad for the new data — drop it.
                    self.invalidate(fp)
                    return None
            new_fp = fingerprint(
                solver.A.A, reg=fp.reg, sketch=fp.sketch,
                sketch_size=fp.sketch_size,
            )
            self._drop(fp)
            entry.fp = new_fp
            entry.nbytes = session_nbytes(solver)  # escalation may have grown B
            self._entries[new_fp] = entry
            self.bytes += entry.nbytes
            self._evict_to_budget(keep=new_fp)
            self._sync_gauges()
            return new_fp

    # ------------------------------------------------------------- reports
    def stats(self) -> dict:
        with self._mu:
            total = self.hits + self.misses
            return {
                "entries": len(self._entries),
                "bytes": self.bytes,
                "max_bytes": self.max_bytes,
                "hits": self.hits,
                "misses": self.misses,
                "evictions": self.evictions,
                "hit_rate": (self.hits / total) if total else 0.0,
                "per_entry": {
                    e.fp.short(): {"hits": e.hits, "nbytes": e.nbytes,
                                   "built_s": e.built_s}
                    for e in self._entries.values()
                },
            }
