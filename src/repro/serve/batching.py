"""Request coalescing: continuous micro-batching and padded shape buckets.

Two traffic shapes, two batching strategies:

- **Same-fingerprint traffic** (many right-hand sides against one cached
  factor) coalesces into ONE ``solve_many`` call: the sketch of the RHS
  block, the vmapped whitened LSQR and the blocked back-substitution all
  amortize, and the per-request marginal cost drops to a few gemm rows.
- **Many-small-problem traffic** (each request carries its own tiny A)
  can't share a factor, but it CAN share a compiled executable: problems
  are padded into geometric *shape buckets* ``(m_pad, n_pad)`` (next
  power of two per axis) and solved under one ``vmap``-ped direct QR per
  bucket.  XLA therefore compiles O(#buckets) executables, not
  O(#distinct shapes) — the classic padded-bucketing trade of a few
  wasted flops for a bounded compile cache.

Padding preserves exactness: a problem (A, b) lands in its bucket as

    A_pad = [[A, 0], [0, I_extra]],   b_pad = [b, 0]

block-diagonal, so the padded least-squares problem decouples —
``x_pad = [x*, 0]`` with x* the original minimizer (the identity block
keeps A_pad full column rank; the extra coordinates are driven to zero
by their zero right-hand side, also under ridge).  Per-problem ridge is
appended as ``√λᵢ·I`` rows inside the same bucket (λᵢ is data, not
shape: λ = 0 rows are zero rows and change nothing, so regularized and
plain problems share one executable).

:class:`MicroBatcher` is the queue policy shared by both paths: per-key
FIFO queues released when they reach ``max_batch`` or when their oldest
request has waited ``max_delay_s`` (the continuous-batching window), plus
occupancy accounting for the load harness.
"""
from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict
from functools import partial
from typing import Any, Hashable

import jax
import jax.numpy as jnp

from ..obs.lockcheck import make_lock

__all__ = [
    "MicroBatcher",
    "bucket_shape",
    "pad_problem",
    "solve_bucket",
]


# ---------------------------------------------------------------------------
# micro-batch queue


@dataclasses.dataclass
class _Queue:
    items: list
    oldest: float  # enqueue time of the head request


class MicroBatcher:
    """Per-key FIFO queues with a continuous micro-batching release rule.

    A key's queue is released as a batch when it holds ``max_batch``
    requests (size-triggered) or when its oldest request has aged past
    ``max_delay_s`` (latency-triggered — the knob bounding the queueing
    delay a lone request can suffer).  ``drain=True`` releases everything
    regardless of age, the flush path.

    Thread-safe on its own lock: the service pump, racing submitters and
    a stats() poll can all touch one batcher without relying on the
    caller's locking (the service still serializes pops for dispatch
    consistency, but the batcher's counters can't be torn either way).
    """

    GUARDED_BY = {"_queues": "_mu", "batch_sizes": "_mu", "enqueued": "_mu"}
    GUARDED_READS = frozenset({"_queues"})

    def __init__(self, max_batch: int = 64, max_delay_s: float = 0.002):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        self.max_batch = int(max_batch)
        self.max_delay_s = float(max_delay_s)
        self._mu = make_lock("MicroBatcher._mu")
        self._queues: "OrderedDict[Hashable, _Queue]" = OrderedDict()
        self.batch_sizes: list[int] = []  # every released batch's occupancy
        self.enqueued = 0

    def add(self, key: Hashable, item: Any, now: float | None = None) -> None:
        now = time.monotonic() if now is None else now
        with self._mu:
            q = self._queues.get(key)
            if q is None:
                self._queues[key] = _Queue(items=[item], oldest=now)
            else:
                q.items.append(item)
            self.enqueued += 1

    @property
    def pending(self) -> int:
        with self._mu:
            return sum(len(q.items) for q in self._queues.values())

    def ready(
        self, now: float | None = None, *, drain: bool = False
    ) -> list[tuple[Hashable, list]]:
        """Pop and return every batch the release rule fires for."""
        now = time.monotonic() if now is None else now
        out: list[tuple[Hashable, list]] = []
        with self._mu:
            for key in list(self._queues):
                q = self._queues[key]
                while len(q.items) >= self.max_batch:
                    out.append((key, q.items[: self.max_batch]))
                    q.items = q.items[self.max_batch:]
                    q.oldest = now
                if q.items and (drain or (now - q.oldest) >= self.max_delay_s):
                    out.append((key, q.items))
                    q.items = []
                if not q.items:
                    del self._queues[key]
            for _, items in out:
                self.batch_sizes.append(len(items))
        return out

    @property
    def mean_occupancy(self) -> float:
        """Mean released-batch size / max_batch ∈ (0, 1]."""
        with self._mu:
            if not self.batch_sizes:
                return 0.0
            return sum(self.batch_sizes) / (
                len(self.batch_sizes) * self.max_batch
            )


# ---------------------------------------------------------------------------
# shape buckets


def _next_pow2(x: int) -> int:
    return 1 << max(0, int(x) - 1).bit_length()


def bucket_shape(m: int, n: int, *, min_n: int = 8) -> tuple[int, int]:
    """The padded bucket a raw (m, n) problem lands in.

    ``n_pad`` is the next power of two (≥ ``min_n``); ``m_pad`` the next
    power of two that also leaves room for the ``n_pad − n`` identity
    rows the column padding needs.  Geometric rounding ⇒ the number of
    distinct buckets grows with log(m)·log(n), not with the number of
    distinct request shapes.
    """
    if m < 1 or n < 1:
        raise ValueError(f"need m, n >= 1, got ({m}, {n})")
    n_pad = _next_pow2(max(n, min_n))
    m_pad = _next_pow2(max(m + (n_pad - n), n_pad))
    return m_pad, n_pad


def pad_problem(
    A: jax.Array, b: jax.Array, m_pad: int, n_pad: int
) -> tuple[jax.Array, jax.Array]:
    """Embed (A, b) block-diagonally into the (m_pad, n_pad) bucket."""
    m, n = A.shape
    extra = n_pad - n
    if m + extra > m_pad or extra < 0:
        raise ValueError(
            f"problem ({m}, {n}) does not fit bucket ({m_pad}, {n_pad})"
        )
    A_pad = jnp.zeros((m_pad, n_pad), A.dtype)
    A_pad = A_pad.at[:m, :n].set(A)
    if extra:
        A_pad = A_pad.at[m + jnp.arange(extra), n + jnp.arange(extra)].set(1.0)
    b_pad = jnp.zeros((m_pad,), A.dtype).at[:m].set(jnp.asarray(b, A.dtype))
    return A_pad, b_pad


@partial(jax.jit, static_argnames=("certify",))
def _solve_bucket_direct(A_stack, b_stack, lam, *, certify: bool):
    """One compiled executable per bucket: vmapped QR over the batch.

    Ridge rides along as exact ``√λᵢ·I`` rows appended per problem
    (λᵢ = 0 appends zero rows — a no-op, so one executable serves both).
    With ``certify=True`` the QR's own R yields a rigorous posterior
    bound per problem: Y = A_aug R⁻¹ = Q is *exactly* orthonormal here
    (S = I, zero distortion), so ‖x̂ − x⋆‖ ≤ ‖R⁻ᵀ A_augᵀ r̂‖ / σ_min(R)
    with no probabilistic qualifier.
    """
    k, m_pad, n_pad = A_stack.shape
    eye = jnp.eye(n_pad, dtype=A_stack.dtype)

    def one(A_i, b_i, lam_i):
        A_aug = jnp.concatenate([A_i, jnp.sqrt(lam_i) * eye], axis=0)
        b_aug = jnp.concatenate([b_i, jnp.zeros((n_pad,), b_i.dtype)])
        Q, R = jnp.linalg.qr(A_aug, mode="reduced")
        x = jax.scipy.linalg.solve_triangular(R, Q.T @ b_aug, lower=False)
        r = b_aug - A_aug @ x
        rnorm = jnp.linalg.norm(r)
        if not certify:
            z = jnp.asarray(jnp.nan, A_stack.dtype)
            return x, rnorm, z, z, z, z
        wg = jax.scipy.linalg.solve_triangular(
            R, A_aug.T @ r, trans=1, lower=False
        )
        svals = jnp.linalg.svd(R, compute_uv=False)
        tiny = jnp.finfo(R.dtype).tiny
        smax, smin = svals[0], svals[-1]
        wg_norm = jnp.linalg.norm(wg)
        bound = wg_norm / jnp.maximum(smin, tiny)
        cond = smax / jnp.maximum(smin, tiny)
        return x, rnorm, wg_norm, bound, cond, smax

    return jax.vmap(one)(A_stack, b_stack, lam)


def solve_bucket(
    A_stack: jax.Array,
    b_stack: jax.Array,
    lam: jax.Array | None = None,
    *,
    certify: bool = False,
) -> dict:
    """Solve a stacked bucket of padded problems under one vmapped QR.

    ``A_stack (k, m_pad, n_pad)``, ``b_stack (k, m_pad)``, ``lam (k,)``
    per-problem ridge (``None`` → all zero).  Returns a dict of
    per-problem columns: ``x (k, n_pad)``, ``rnorm``, and with
    ``certify=True`` the posterior pieces ``whitened_arnorm`` /
    ``error_bound`` / ``cond`` / ``smax`` the service assembles
    :class:`~repro.core.certify.Certificate` objects from.
    """
    if A_stack.ndim != 3 or b_stack.shape != A_stack.shape[:2]:
        raise ValueError(
            f"need A_stack (k, m_pad, n_pad) and matching b_stack, got "
            f"{A_stack.shape} / {b_stack.shape}"
        )
    if lam is None:
        lam = jnp.zeros((A_stack.shape[0],), A_stack.dtype)
    x, rnorm, wg, bound, cond, smax = _solve_bucket_direct(
        A_stack, b_stack, jnp.asarray(lam, A_stack.dtype), certify=certify
    )
    return {
        "x": x, "rnorm": rnorm, "whitened_arnorm": wg,
        "error_bound": bound, "cond": cond, "smax": smax,
    }
