"""``SolveService`` — the async multi-tenant front-end over the solver stack.

The request path, end to end:

1. ``submit(A, b, ...)`` fingerprints the problem (``serve.fingerprint``),
   routes it — big A → the *session* path (cached factor, coalesced
   ``solve_many``), tiny A → the *bucket* path (padded vmapped QR) — and
   returns a ``concurrent.futures.Future`` immediately.
2. ``pump()`` releases ready micro-batches (``serve.batching``): for each
   same-fingerprint batch it fetches the live ``SketchedSolver`` from the
   LRU factor cache (``serve.cache``; builds + certifies on a miss),
   sketches the stacked right-hand sides ONCE and runs one vmapped
   whitened LSQR; for each shape bucket it runs the padded batch QR.
3. Every response carries a posterior ``Certificate`` for its requested
   ``certified_rtol`` (``None`` → the service-level SLO
   ``default_rtol``).  The batch is certified in ONE blocked pass — the
   embedding-level distortion/spectrum are cached per factor, so the
   per-request cost is a couple of gemm rows.
4. Requests whose certificate fails get the *slow path* — a per-request
   ``lstsq(accuracy="certified")`` with its full escalation ladder — and
   are gracefully REJECTED with a reason when even that cannot meet the
   SLO, or when their deadline expired (the certificate-vs-budget trade
   the SLO semantics promise: you get the accuracy you asked for, or an
   honest refusal, never a silently degraded answer).

Synchronous callers use ``solve()`` (submit + flush); load generators
call ``start()`` to run the pump on a background thread (continuous
micro-batching: batches release on size OR age, so tail latency is
bounded by ``max_delay_s`` even at low arrival rates).  The pump is
exception-isolated per batch — an internal failure rejects that batch's
futures with the error as the reason and keeps serving — and holds the
submission lock only while popping queues, so clients enqueue freely
while a batch computes.

This module is the serving refactor of the seed's ``launch/serve.py`` /
``train/serve.py`` loop skeleton onto the least-squares stack: same
batched front-end shape (queue → coalesce → one compiled batch step),
with the LM decode step swapped for ``solve_many`` against a cached
sketch→QR factor.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import OrderedDict
from concurrent.futures import Future

import jax
import jax.numpy as jnp
import numpy as np

from ..core import certify as certify_lib
from ..core import linop
from ..core.lstsq import lstsq
from ..core.precond import default_sketch_size
from ..core.result import SolveResult
from ..core.session import SketchedSolver
from ..obs import trace as obs_trace
from ..obs.lockcheck import make_rlock
from ..obs.metrics import REGISTRY
from .batching import (
    MicroBatcher,
    _next_pow2,
    bucket_shape,
    pad_problem,
    solve_bucket,
)
from .cache import FactorCache
from .fingerprint import Fingerprint, fingerprint

__all__ = ["SolveService", "SolveResponse"]

# Route problems below this m·n² flop count to the padded-bucket direct
# path: same cutoff the lstsq auto-selector uses for "QR is free".
SMALL_PROBLEM_FLOPS = 1 << 26


@jax.jit
def _certify_block(op, factor, B_aug, X, distortion, smin, floor):
    """Blocked posterior pieces for a whole RHS batch in one compile:
    residuals, whitened gradients ‖R⁻ᵀAᵀr̂‖ and the certified bounds
    ‖x̂ − x⋆‖ ≤ ‖Yᵀr̂‖ / (σ_w² σ_min(R)) per column."""
    dtype = factor.R.dtype
    tiny = jnp.finfo(dtype).tiny
    Rres = B_aug - op.matmat(X)
    WG = factor.rt_solve(op.rmatmat(Rres))
    wg = jnp.linalg.norm(WG, axis=0)
    rn = jnp.linalg.norm(Rres, axis=0)
    xn = jnp.linalg.norm(X, axis=0)
    eps = jnp.clip(distortion, 0.0, 0.999)
    sigma_w = jnp.maximum(jnp.minimum(1.0 - eps, floor), tiny)
    bounds = wg / (sigma_w**2 * jnp.maximum(smin, tiny))
    rels = bounds / jnp.maximum(xn, tiny)
    return wg, rn, bounds, rels


@dataclasses.dataclass
class SolveResponse:
    """What a request's future resolves to — answer or honest refusal."""

    status: str  # "ok" | "rejected"
    x: jax.Array | None
    result: SolveResult | None
    certificate: object | None  # repro.core.certify.Certificate
    reason: str | None  # rejection reason ("rejected" only)
    path: str  # "session" | "bucket" | "slow"
    cache_hit: bool
    batch_size: int
    queued_s: float  # submit → dispatch
    latency_s: float  # submit → response

    @property
    def ok(self) -> bool:
        return self.status == "ok"


@dataclasses.dataclass
class _Request:
    future: Future
    A: object  # raw user input (array / BCOO / operator)
    b: jax.Array
    reg: float | None
    rtol: float  # resolved SLO (never None inside the service)
    deadline: float | None  # absolute time.monotonic() deadline
    t_submit: float
    t_dispatch: float | None = None  # stamped when the batch is popped
    fp: Fingerprint | None = None  # session path only
    raw_shape: tuple[int, int] = (0, 0)  # bucket path: pre-pad shape


class SolveService:
    """Multi-tenant least-squares serving: cached factors + micro-batching.

    Parameters
    ----------
    key : PRNG key seeding every session build and slow-path solve.
    cache_bytes : byte budget of the LRU factor cache.
    max_batch / max_delay_s : the continuous micro-batching window.
    default_rtol : the service-level accuracy SLO — the ``certified_rtol``
        a request gets when it doesn't name one.  Session LSQR tolerances
        are derived from it (``atol = btol = default_rtol * tol_margin``)
        so solves stop as soon as the certificate can pass, not at the
        machine floor; requests demanding much tighter rtol than the
        service class fall through to the slow path.
    sketch / sketch_size_factor : the embedding the cached sessions are
        built with.  Serving wants a *larger* sketch than one-shot solves
        (default 8n vs 4n): the build is amortized anyway, and the lower
        distortion ε ≈ √(n/s) cuts every request's LSQR iteration count.
    small_problem_flops : m·n² below which requests take the bucket path.
    """

    # Checked by reprolint R1: these attrs may only be written under
    # ``with self._lock:``.  The dispatch-side state (cache, sessions'
    # internals) is guarded by the objects' own locks, not listed here.
    GUARDED_BY = {
        "counters": "_lock",
        "_session_counter": "_lock",
        "_bucket_keys": "_lock",
    }

    def __init__(
        self,
        key: jax.Array,
        *,
        cache_bytes: int = 256 * 1024 * 1024,
        max_batch: int = 64,
        max_delay_s: float = 0.002,
        default_rtol: float = 1e-6,
        tol_margin: float = 0.02,
        sketch: str = "clarkson_woodruff",
        sketch_size_factor: int = 8,
        iter_lim: int = 100,
        small_problem_flops: int = SMALL_PROBLEM_FLOPS,
        max_distortion: float = certify_lib.DEFAULT_MAX_DISTORTION,
    ):
        self._key = key
        self._session_counter = 0
        self.cache = FactorCache(max_bytes=cache_bytes)
        self.sessions = MicroBatcher(max_batch=max_batch, max_delay_s=max_delay_s)
        self.buckets = MicroBatcher(max_batch=max_batch, max_delay_s=max_delay_s)
        self.default_rtol = float(default_rtol)
        self.session_tol = float(default_rtol) * float(tol_margin)
        self.sketch = sketch
        self.sketch_size_factor = int(sketch_size_factor)
        self.iter_lim = int(iter_lim)
        self.small_problem_flops = int(small_problem_flops)
        self.max_distortion = float(max_distortion)
        self.counters = REGISTRY.stats_dict("serve", {
            "requests": 0, "ok": 0, "rejected": 0, "slow_path": 0,
            "session_batches": 0, "bucket_batches": 0,
        })
        self._h_latency = REGISTRY.histogram("serve.latency_s")
        self._h_queued = REGISTRY.histogram("serve.queued_s")
        self._bucket_keys: set = set()
        # _lock guards the queues/counters only and is held for
        # microseconds; _dispatch_lock serializes the dispatchers (pump
        # thread vs. a concurrent flush()) so sessions, spectrum caches
        # and the XLA compile ladder stay single-threaded.  submit()
        # never touches _dispatch_lock — clients keep enqueueing while a
        # batch computes.
        self._lock = make_rlock("SolveService._lock")
        self._dispatch_lock = make_rlock("SolveService._dispatch_lock")
        self._thread: threading.Thread | None = None
        self._stop = threading.Event()

    # ------------------------------------------------------------ submission
    def _resolve_sketch_size(self, m: int, n: int) -> int:
        s = self.sketch_size_factor * n
        if m // 2 <= n + 1:
            return default_sketch_size(n, m)
        return max(n + 1, min(s, m // 2))

    def submit(
        self,
        A,
        b,
        *,
        reg: float | None = None,
        certified_rtol: float | None = None,
        deadline_s: float | None = None,
        token: str | None = None,
        tenant: str | None = None,
        mode: str = "auto",
    ) -> Future:
        """Enqueue one solve; resolves to a :class:`SolveResponse`.

        ``certified_rtol=None`` inherits the service SLO ``default_rtol``;
        ``deadline_s`` is a relative latency budget — a request whose
        certificate cannot be met before it expires is rejected with a
        reason rather than answered late or loosely.  ``token`` names the
        content of matrix-free operators and ``tenant`` scopes tokens per
        caller so independent tenants' version strings cannot collide on
        one cache entry (see ``serve.fingerprint``).  ``mode`` forces the
        ``"session"`` or ``"bucket"`` path (``"auto"`` routes by problem
        size).

        Validation is front-loaded here, in the CALLER's thread: a b of
        the wrong shape or a dtype that would promote past A's precision
        raises immediately instead of poisoning the shared batch its
        fingerprint would coalesce into.
        """
        if mode not in ("auto", "session", "bucket"):
            raise ValueError(f"unknown mode {mode!r}")
        op = linop.as_operator(A)
        m, n = (int(op.shape[0]), int(op.shape[1]))
        b = jnp.asarray(b)
        if b.ndim != 1 or b.shape[0] != m:
            raise ValueError(
                f"submit needs a single right-hand side of shape ({m},), "
                f"got {b.shape}"
            )
        dtype = jnp.dtype(op.dtype)
        if b.dtype != dtype:
            # Same policy as SketchedSolver._check_rhs, enforced at the
            # service door: a promoting RHS is the CALLER's error and must
            # not surface mid-dispatch inside someone else's batch.
            if jnp.result_type(b.dtype, dtype) != dtype:
                raise TypeError(
                    f"right-hand side dtype {b.dtype} does not fit A's "
                    f"{dtype}: solving would silently promote past the "
                    f"precision the cached factor is built at — cast b "
                    f"(or submit A at {b.dtype}) explicitly"
                )
            b = b.astype(dtype)
        now = time.monotonic()
        req = _Request(
            future=Future(),
            A=A,
            b=b,
            reg=None if reg is None else float(reg),
            rtol=(
                self.default_rtol
                if certified_rtol is None
                else float(certified_rtol)
            ),
            deadline=None if deadline_s is None else now + float(deadline_s),
            t_submit=now,
            raw_shape=(m, n),
        )
        if mode == "auto":
            small = m * n * n <= self.small_problem_flops
            mode = (
                "bucket"
                if small and isinstance(op, linop.DenseOperator)
                else "session"
            )
        with self._lock:
            self.counters["requests"] += 1
            if mode == "bucket":
                if not isinstance(op, linop.DenseOperator):
                    raise ValueError(
                        "the bucket path pads dense arrays; got "
                        f"{type(op).__name__} — use mode='session'"
                    )
                key = (*bucket_shape(m, n), str(jnp.dtype(op.dtype)))
                self._bucket_keys.add(key)
                self.buckets.add(key, req, now=now)
            else:
                req.fp = fingerprint(
                    A, reg=req.reg, sketch=self.sketch,
                    sketch_size=self._resolve_sketch_size(m, n), token=token,
                    tenant=tenant,
                )
                self.sessions.add(req.fp, req, now=now)
        obs_trace.instant("serve.submit", mode=mode, m=m, n=n)
        return req.future

    def solve(self, A, b, **kw) -> SolveResponse:
        """Synchronous convenience: submit + flush (or wait on the pump)."""
        fut = self.submit(A, b, **kw)
        if self._thread is None:
            self.flush()
        return fut.result()

    # -------------------------------------------------------------- pumping
    def pump(self, *, drain: bool = False) -> int:
        """Dispatch every ready micro-batch; returns #requests completed.

        The queue pop is the only work under ``_lock`` — the popped
        request lists are private, so the dispatches (session builds, XLA
        compiles, solves, certification) run with submissions flowing
        freely.  Each batch dispatch is exception-isolated: an internal
        failure rejects THAT batch's futures with the error as the reason
        and the pump keeps serving everyone else — one bad batch must
        never hang the service.
        """
        with self._lock:
            ready = self.sessions.ready(drain=drain)
            ready_b = self.buckets.ready(drain=drain)
            self.counters["session_batches"] += len(ready)
            self.counters["bucket_batches"] += len(ready_b)
        now = time.monotonic()
        for _, reqs in (*ready, *ready_b):
            for r in reqs:
                r.t_dispatch = now
        done = 0
        with self._dispatch_lock:
            for fp, reqs in ready:
                done += self._dispatch_guarded(
                    self._dispatch_session, fp, reqs, "session"
                )
            for key, reqs in ready_b:
                done += self._dispatch_guarded(
                    self._dispatch_bucket, key, reqs, "bucket"
                )
        return done

    def _dispatch_guarded(self, dispatch, key, reqs, path: str) -> int:
        try:
            with obs_trace.span(f"serve.dispatch.{path}", batch=len(reqs)):
                return dispatch(key, reqs)
        except Exception as e:  # noqa: BLE001 — the pump must survive
            for r in reqs:
                if not r.future.done():
                    self._reject(
                        r,
                        f"internal error during {path} dispatch: {e!r}",
                        path, False, len(reqs),
                    )
            return len(reqs)

    def flush(self) -> int:
        """Drain every queue (the synchronous caller's barrier)."""
        total = 0
        while True:
            n = self.pump(drain=True)
            total += n
            with self._lock:
                if self.sessions.pending + self.buckets.pending == 0:
                    return total

    def start(self, poll_s: float = 0.0005) -> None:
        """Run the pump on a daemon thread (open-loop serving mode)."""
        if self._thread is not None:
            return
        self._stop.clear()

        def loop():
            while not self._stop.is_set():
                if self.pump() == 0:
                    time.sleep(poll_s)

        self._thread = threading.Thread(target=loop, daemon=True)
        self._thread.start()

    def stop(self) -> None:
        if self._thread is None:
            return
        self._stop.set()
        self._thread.join()
        self._thread = None
        self.flush()

    def prewarm(self, A, *, reg: float | None = None,
                token: str | None = None,
                tenant: str | None = None) -> None:
        """The serving warmup request: build + certify A's session and
        compile the whole batch-width ladder before real traffic lands,
        so no tenant's first requests eat a session build or an XLA
        compile as tail latency."""
        m, n = (int(jnp.shape(A)[0]), int(jnp.shape(A)[1]))
        fp = fingerprint(
            A, reg=reg, sketch=self.sketch,
            sketch_size=self._resolve_sketch_size(m, n), token=token,
            tenant=tenant,
        )
        with self._dispatch_lock:
            session, _ = self.cache.get_or_build(
                fp, lambda: self._build_session(A, fp)
            )
            self._ensure_certified_embedding(session)
            self._spectrum(session)
            b = session.A.matvec(jnp.ones((n,), session.A.dtype))
            res = session.solve(b)
            self._certify_columns(session, b[:, None], res.x[:, None],
                                  [self.default_rtol])
            w = 2
            while w <= self.sessions.max_batch:
                B = jnp.tile(b[:, None], (1, w))
                res = session.solve_many(B)
                self._certify_columns(session, B, res.x,
                                      [self.default_rtol] * w)
                w *= 2

    # ------------------------------------------------------------- sessions
    def _next_key(self) -> jax.Array:
        with self._lock:
            self._session_counter += 1
            counter = self._session_counter
        return jax.random.fold_in(self._key, counter)

    def _build_session(self, A, fp: Fingerprint) -> SketchedSolver:
        return SketchedSolver(
            A, self._next_key(), sketch=fp.sketch,
            sketch_size=fp.sketch_size, reg=fp.reg,
            atol=self.session_tol, btol=self.session_tol,
            iter_lim=self.iter_lim, max_distortion=self.max_distortion,
        )

    def _ensure_certified_embedding(self, session: SketchedSolver) -> bool:
        """Embedding-level certificate, escalating in place on failure."""
        if session.certificate is None:
            session._recertify_after_update()
        return bool(session.certificate.passed)

    def _spectrum(self, session: SketchedSolver):
        """(smax, smin, cond, floor) of the CURRENT factor, cached on it."""
        cached = getattr(session, "_serve_spectrum", None)
        if cached is not None and cached[0] is session.factor:
            return cached[1:]
        smax, smin, cond = certify_lib.factor_spectrum(session.factor)
        floor = certify_lib.probe_spectrum_floor(
            session._solve_op, session.factor
        )
        session._serve_spectrum = (session.factor, smax, smin, cond, floor)
        return smax, smin, cond, floor

    def _certify_columns(self, session: SketchedSolver, B, X, rtols):
        """Per-column Certificates from ONE blocked posterior pass.

        The embedding pieces (distortion probe, spectrum, floor) are
        cached per factor; only ‖Yᵀr̂‖ is per-request, and the whole
        batch shares one jitted matmat/rmatmat/triangular-solve trio.
        ``rtols`` may be shorter than B's width (padding columns get no
        certificate).  Everything lands on the host in ONE transfer and
        the Certificate assembly is pure numpy — per-request dispatch
        overhead is what an eager version of this loop would spend.
        """
        emb = session.certificate
        smax, smin, cond, floor = self._spectrum(session)
        if session.reg is not None:
            n = session.A.shape[1]
            B = jnp.concatenate([B, jnp.zeros((n, B.shape[1]), B.dtype)], 0)
        wg, rn, bounds, rels = _certify_block(
            session._solve_op, session.factor, B, X, emb.distortion,
            smin, floor,
        )
        wg, rn, bounds, rels, distortion, cond = jax.device_get(
            (wg, rn, bounds, rels, emb.distortion, cond)
        )
        emb_ok = bool(emb.passed)
        certs = []
        for j, rtol in enumerate(rtols):
            rel = rels[j]
            certs.append(certify_lib.Certificate(
                distortion=distortion, cond_R=cond, rnorm=rn[j],
                whitened_arnorm=wg[j], error_bound=bounds[j],
                rel_error_bound=rel, target=rtol,
                passed=emb_ok and bool(np.isfinite(rel)) and rel <= rtol,
                sketch_rows=session.sketch_size,
                escalations=session.escalations,
            ))
        return certs

    def _dispatch_session(self, fp: Fingerprint, reqs: list[_Request]) -> int:
        now = time.monotonic()
        live = []
        for r in reqs:
            if r.deadline is not None and now > r.deadline:
                self._reject(r, "deadline expired while queued", "session",
                             False, len(reqs))
            else:
                live.append(r)
        if not live:
            return len(reqs)
        session, hit = self.cache.get_or_build(
            fp, lambda: self._build_session(live[0].A, fp)
        )
        emb_ok = self._ensure_certified_embedding(session)
        k = len(live)
        # Pad the RHS block up the power-of-two ladder (duplicating the
        # last column): the vmapped solve compiles per batch WIDTH, so
        # without padding every distinct coalesced size k is a fresh XLA
        # compile — a multi-second tail-latency spike the first time each
        # size appears.  The ladder bounds compiles at O(log max_batch)
        # per problem shape; the duplicate columns ride the same gemms
        # nearly for free and are sliced off before certification.
        k_pad = min(_next_pow2(k), self.sessions.max_batch)
        with obs_trace.span("serve.solve", k=k, k_pad=k_pad, cache_hit=hit):
            if k_pad == 1:
                res = session.solve(live[0].b)
                B_full = live[0].b[:, None]
                X = res.x[:, None]
            else:
                B_full = jnp.stack(
                    [r.b for r in live] + [live[-1].b] * (k_pad - k), axis=1
                )
                res = session.solve_many(B_full)
                X = res.x
            obs_trace.maybe_block(X)
        # Certify the PADDED width (duplicate columns certify redundantly
        # for free) so the jitted certify block shares the solve's
        # compile ladder instead of compiling per coalesced size.
        with obs_trace.span("serve.certify", k=k):
            certs = self._certify_columns(
                session, B_full, X, [r.rtol for r in live]
            )
        X_host = np.asarray(X)
        host = jax.device_get((res.istop, res.itn, res.rnorm, res.arnorm,
                               res.used_fallback))
        for j, r in enumerate(live):
            cert = certs[j]
            res_j = self._slice_result(res, host, X_host, j, k_pad)._replace(
                certificate=cert
            )
            if bool(cert.passed):
                self._resolve(r, res_j, cert, "session", hit, k)
                continue
            if not emb_ok:
                reason = (
                    "embedding could not be certified even at the maximum "
                    f"sketch size (distortion {float(cert.distortion):.3f})"
                )
            else:
                reason = None
            self._retry_slow(r, fp, reason, batch_size=k, cache_hit=hit,
                             fast_cert=cert)
        return len(reqs)

    def _slice_result(self, res, host, X_host, j, k_pad) -> SolveResult:
        if k_pad == 1:
            return res
        istop, itn, rnorm, arnorm, fb = host
        pick = lambda v: v[..., j] if getattr(v, "ndim", 0) else v  # noqa: E731
        return res._replace(
            x=X_host[:, j], istop=pick(istop), itn=pick(itn),
            rnorm=pick(rnorm), arnorm=pick(arnorm), used_fallback=pick(fb),
        )

    def _retry_slow(
        self, r: _Request, fp: Fingerprint, forced_reason: str | None,
        *, batch_size: int, cache_hit: bool, fast_cert,
    ):
        """Fast-path certificate failed: per-request certified lstsq, with
        deadline-aware graceful rejection."""
        if forced_reason is not None:
            self._reject(r, forced_reason, "session", cache_hit, batch_size)
            return
        now = time.monotonic()
        if r.deadline is not None and now > r.deadline:
            self._reject(
                r,
                f"certificate for rtol={r.rtol:.1e} not met in deadline "
                f"(best bound {float(fast_cert.rel_error_bound):.2e})",
                "session", cache_hit, batch_size,
            )
            return
        with self._lock:
            self.counters["slow_path"] += 1
        with obs_trace.span("serve.slow_path", rtol=r.rtol):
            res = lstsq(
                r.A, r.b, self._next_key(), accuracy="certified",
                certified_rtol=r.rtol, reg=r.reg, sketch=fp.sketch,
            )
        cert = res.certificate
        if cert is not None and bool(cert.passed):
            self._resolve(r, res, cert, "slow", cache_hit, batch_size)
        else:
            bound = (
                float(cert.rel_error_bound) if cert is not None else float("nan")
            )
            self._reject(
                r,
                f"certificate for rtol={r.rtol:.1e} unattainable (full "
                f"escalation ladder exhausted; best bound {bound:.2e})",
                "slow", cache_hit, batch_size,
            )

    # -------------------------------------------------------------- buckets
    def _dispatch_bucket(self, key, reqs: list[_Request]) -> int:
        m_pad, n_pad, _ = key
        now = time.monotonic()
        live = []
        for r in reqs:
            if r.deadline is not None and now > r.deadline:
                self._reject(r, "deadline expired while queued", "bucket",
                             False, len(reqs))
            else:
                live.append(r)
        if not live:
            return len(reqs)
        pads = [
            pad_problem(linop.as_operator(r.A).A, r.b, m_pad, n_pad)
            for r in live
        ]
        A_stack = jnp.stack([p[0] for p in pads])
        b_stack = jnp.stack([p[1] for p in pads])
        lam = jnp.asarray([r.reg or 0.0 for r in live], A_stack.dtype)
        with obs_trace.span("serve.solve", k=len(live), method="bucket"):
            out = solve_bucket(A_stack, b_stack, lam, certify=True)
            obs_trace.maybe_block(out["x"])
        k = len(live)
        dtype = A_stack.dtype
        for j, r in enumerate(live):
            n = r.raw_shape[1]
            x = out["x"][j, :n]
            xn = jnp.maximum(
                jnp.linalg.norm(out["x"][j]), jnp.finfo(dtype).tiny
            )
            rel = out["error_bound"][j] / xn
            # Direct QR answers certify with ZERO embedding distortion —
            # R here is A_aug's own triangular factor, so the bound is
            # deterministic (module docstring of serve.batching).
            cert = certify_lib.Certificate(
                distortion=jnp.asarray(0.0, dtype),
                cond_R=out["cond"][j], rnorm=out["rnorm"][j],
                whitened_arnorm=out["whitened_arnorm"][j],
                error_bound=out["error_bound"][j],
                rel_error_bound=rel,
                target=jnp.asarray(r.rtol, dtype),
                passed=jnp.isfinite(rel) & (rel <= r.rtol),
                sketch_rows=m_pad + n_pad, escalations=0,
            )
            res = SolveResult(
                x=x, istop=jnp.asarray(1, jnp.int32),
                itn=jnp.asarray(0, jnp.int32), rnorm=out["rnorm"][j],
                arnorm=jnp.asarray(jnp.nan, dtype),
                used_fallback=jnp.asarray(False), method="bucket_direct",
                certificate=cert,
            )
            if bool(cert.passed):
                self._resolve(r, res, cert, "bucket", False, k)
            else:
                self._reject(
                    r,
                    f"rtol={r.rtol:.1e} is below direct-QR attainable "
                    f"accuracy for this problem (posterior bound "
                    f"{float(rel):.2e}); no tighter method exists",
                    "bucket", False, k,
                )
        return len(reqs)

    # ------------------------------------------------------------ responses
    def _queued_s(self, r, now: float) -> float:
        # Queue wait = submit → the pump popping the request's batch; a
        # request answered without ever being popped (rejected at submit
        # follow-up paths) charges its whole life to the queue.
        t_dispatch = r.t_dispatch if r.t_dispatch is not None else now
        return max(0.0, t_dispatch - r.t_submit)

    def _resolve(self, r, res, cert, path, hit, batch):
        now = time.monotonic()
        with self._lock:
            self.counters["ok"] += 1
        queued_s = self._queued_s(r, now)
        latency_s = now - r.t_submit
        self._h_queued.observe(queued_s)
        self._h_latency.observe(latency_s)
        r.future.set_result(SolveResponse(
            status="ok", x=res.x, result=res, certificate=cert, reason=None,
            path=path, cache_hit=hit, batch_size=batch,
            queued_s=queued_s, latency_s=latency_s,
        ))

    def _reject(self, r, reason, path, hit, batch):
        now = time.monotonic()
        with self._lock:
            self.counters["rejected"] += 1
        queued_s = self._queued_s(r, now)
        latency_s = now - r.t_submit
        self._h_queued.observe(queued_s)
        self._h_latency.observe(latency_s)
        obs_trace.instant("serve.reject", path=path, reason=reason)
        r.future.set_result(SolveResponse(
            status="rejected", x=None, result=None, certificate=None,
            reason=reason, path=path, cache_hit=hit, batch_size=batch,
            queued_s=queued_s, latency_s=latency_s,
        ))

    # ---------------------------------------------------------------- stats
    def stats(self) -> dict:
        # ONE consistent snapshot: the counters dict, both batchers'
        # occupancy/pending and the bucket-key census are all read under a
        # single acquisition of the service lock, so a stats() poll racing
        # the pump never sees a batch counted in ``session_batches`` whose
        # requests are still missing from ``ok``/``rejected``.  The cache
        # keeps its own lock and is snapshotted after — its counters are
        # internally consistent, just potentially a tick newer.
        with self._lock:
            counters = dict(self.counters)
            occ = OrderedDict(
                session_occupancy=self.sessions.mean_occupancy,
                bucket_occupancy=self.buckets.mean_occupancy,
            )
            pending = self.sessions.pending + self.buckets.pending
            bucket_executables = len(self._bucket_keys)
        return {
            **counters,
            **occ,
            "pending": pending,
            "bucket_executables": bucket_executables,
            "cache": self.cache.stats(),
        }
