"""Multi-tenant least-squares serving on top of the solver stack.

The serving thesis, straight from the paper's economics: one sketch + QR
is expensive, every subsequent right-hand side against it is cheap.  This
package caches the expensive artifact (live ``SketchedSolver`` sessions
keyed by content fingerprint) and micro-batches the cheap one (coalesced
``solve_many`` calls; padded shape buckets for small-problem traffic),
with per-request certified-accuracy SLOs and deadlines on top.

- :mod:`~repro.serve.fingerprint` — content fingerprints (the cache key)
- :mod:`~repro.serve.cache` — byte-budgeted LRU cache of live sessions
- :mod:`~repro.serve.batching` — micro-batch queue + padded shape buckets
- :mod:`~repro.serve.service` — the async ``SolveService`` front-end
"""
from .batching import MicroBatcher, bucket_shape, pad_problem, solve_bucket
from .cache import CacheEntry, FactorCache, session_nbytes
from .fingerprint import Fingerprint, digest_array, fingerprint
from .service import SolveResponse, SolveService

__all__ = [
    "CacheEntry",
    "FactorCache",
    "Fingerprint",
    "MicroBatcher",
    "SolveResponse",
    "SolveService",
    "bucket_shape",
    "digest_array",
    "fingerprint",
    "pad_problem",
    "session_nbytes",
    "solve_bucket",
]
