"""Content fingerprints — the cache key of the multi-tenant solve service.

The expensive artifact the service amortizes is a ``SketchedSolver``
session (one sketch + QR of A).  Two requests may share that artifact iff
they would build the *same* session: same data matrix, same dtype, same
ridge parameter and same sketch configuration.  A :class:`Fingerprint`
names that equivalence class as a small frozen value object, hashable and
usable as a dict key.

What goes into the key:

- ``kind``   — the structural input family (``dense`` / ``bcoo`` /
  ``operator``): a dense A and a BCOO A with identical entries build
  different sessions (different apply paths), so they must not collide.
- ``shape``/``dtype`` — trace-level identity.
- ``reg``    — the ridge λ (a different λ is a different factor: the
  augmented [A; √λI] operator is sketched through a different embedding).
- ``sketch``/``sketch_size`` — the embedding configuration the session
  would be built with.
- ``digest`` — the content hash.  For dense arrays and BCOO matrices this
  is a real digest of the numerical payload (BLAKE2b over the raw bytes —
  O(bytes) once per *distinct immutable object*; repeated submissions of
  the same ``jax.Array`` hit a memo and skip the hash, while writable
  numpy arrays are re-digested every time — an in-place mutation must
  change the fingerprint).  Matrix-free operators have no inspectable
  payload, so they REQUIRE an explicit user ``token``: the caller asserts
  "this token names this operator's content" and the fingerprint is
  structural (type, shape, dtype) + token.  Passing a token for array
  inputs overrides the byte digest — the escape hatch for callers who
  already version their data.

Tokens live in ONE namespace per service by default: two callers using
the same token string (say ``"v1"``) for *different* content of the same
shape/dtype/config would collide on one fingerprint and be served each
other's cached factor.  In a multi-tenant deployment, pass ``tenant=``
to scope tokens per caller — the tenant id is mixed into the token's
digest (content digests are deliberately NOT tenant-scoped: identical
bytes SHOULD share a factor; that sharing is the cache's whole point).

``fingerprint`` is pure bookkeeping — it never touches the accelerator
beyond a device→host copy of the payload being digested.
"""
from __future__ import annotations

import dataclasses
import hashlib
import weakref

import numpy as np

from ..core import linop

__all__ = ["Fingerprint", "fingerprint", "digest_array"]

# Digest memo keyed on id(buffer).  A weakref.finalize on the owning object
# evicts the entry when the buffer dies, so a recycled id() can never serve
# a stale digest.  Only IMMUTABLE buffers are memoized (jax.Array,
# read-only numpy views): a writable numpy array can be mutated in place
# under the same id, so memoizing it would let a caller resubmit a changed
# matrix and be served the factor of the old bytes.  Objects that refuse
# weakrefs just get re-digested.
_DIGEST_MEMO: dict[int, str] = {}


def _memo_evict(obj_id: int) -> None:
    _DIGEST_MEMO.pop(obj_id, None)


def digest_array(x) -> str:
    """BLAKE2b-128 hex digest of an array's raw bytes (+ shape/dtype).

    Works for ``jax.Array`` and ``numpy`` inputs; the device→host copy and
    the hash are paid once per distinct *immutable* object (memoized by
    identity, with a weakref finalizer guarding against id reuse).
    Writable numpy arrays skip the memo entirely — in-place mutation
    changes the content under the same object identity, and serving a
    stale digest would mean serving a stale cached factor.
    """
    mutable = isinstance(x, np.ndarray) and x.flags.writeable
    obj_id = id(x)
    if not mutable:
        hit = _DIGEST_MEMO.get(obj_id)
        if hit is not None:
            return hit
    host = np.asarray(x)
    h = hashlib.blake2b(digest_size=16)
    h.update(str(host.shape).encode())
    h.update(str(host.dtype).encode())
    h.update(np.ascontiguousarray(host).tobytes())
    digest = h.hexdigest()
    if not mutable:
        try:
            weakref.finalize(x, _memo_evict, obj_id)
            _DIGEST_MEMO[obj_id] = digest
        except TypeError:
            pass  # not weakref-able: skip the memo, never risk staleness
    return digest


@dataclasses.dataclass(frozen=True)
class Fingerprint:
    """Hashable identity of a solve problem's expensive artifact."""

    kind: str  # "dense" | "bcoo" | "operator"
    shape: tuple[int, int]
    dtype: str
    reg: float | None
    sketch: str
    sketch_size: int | None
    digest: str

    def short(self) -> str:
        """Human-readable cache-log form."""
        r = "" if self.reg is None else f"|reg={self.reg:g}"
        return (
            f"{self.kind}{self.shape[0]}x{self.shape[1]}:{self.dtype}"
            f"{r}|{self.sketch}|{self.digest[:10]}"
        )


def fingerprint(
    A,
    *,
    reg: float | None = None,
    sketch: str = "clarkson_woodruff",
    sketch_size: int | None = None,
    token: str | None = None,
    tenant: str | None = None,
) -> Fingerprint:
    """Fingerprint a problem: ``jax.Array | BCOO | LinearOperator``.

    ``token`` is REQUIRED for matrix-free operators (nothing to digest)
    and optional for array/BCOO inputs (overrides the byte digest with a
    caller-asserted content name).  ``tenant`` scopes the token: tokens
    are caller-asserted names, so without a tenant id two independent
    callers both naming their data ``"v1"`` would silently share one
    cache entry — with one, each tenant owns a private token namespace.
    ``tenant`` without a token is a no-op: content digests are shared by
    design (identical bytes = identical factor).
    ``reg``/``sketch``/``sketch_size`` must match the session
    configuration the cache would build — the service threads its own
    knobs through here.
    """
    op = linop.as_operator(A)
    shape = (int(op.shape[0]), int(op.shape[1]))
    dtype = str(np.dtype(op.dtype))
    reg_f = None if reg is None else float(reg)
    if token is not None and tenant is not None:
        token = f"{tenant}\x1f{token}"  # \x1f: no crafted-string collisions
    if isinstance(op, linop.DenseOperator):
        kind = "dense"
        digest = token if token is not None else digest_array(op.A)
    elif isinstance(op, linop.SparseOperator):
        kind = "bcoo"
        if token is not None:
            digest = token
        else:
            digest = (
                digest_array(op.M.data)[:16] + digest_array(op.M.indices)[:16]
            )
    else:
        kind = "operator"
        if token is None:
            raise ValueError(
                "matrix-free operators have no inspectable payload to "
                "digest — pass an explicit token= naming this operator's "
                "content (the caller owns its versioning)"
            )
        digest = f"{type(op).__name__}:{token}"
    return Fingerprint(
        kind=kind, shape=shape, dtype=dtype, reg=reg_f,
        sketch=sketch, sketch_size=sketch_size, digest=digest,
    )
