"""Shared model-building blocks: param-spec machinery, norms, rope, acts.

Params are nested dicts of arrays.  Every init site declares a ``PSpec``
(shape + logical axes + initializer); one traversal materializes arrays,
another produces PartitionSpecs — so dry-run sharding never needs a real
allocation and params/shardings can't drift apart.
"""
from __future__ import annotations

import math
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from ..sharding import constrain

__all__ = [
    "unrolled_scans",
    "maybe_scan",
    "PSpec",
    "init_tree",
    "axes_tree",
    "shape_tree",
    "rms_norm",
    "make_rope",
    "apply_rope",
    "activation",
    "constrain",
    "DTYPES",
]

DTYPES = {
    "bfloat16": jnp.bfloat16,
    "float32": jnp.float32,
    "float16": jnp.float16,
}

# When True, every model-internal lax.scan is fully unrolled.  The dry-run's
# *cost artifacts* set this: XLA's cost analysis counts while-loop bodies
# once regardless of trip count, so loop-free HLO is the only way to read
# true flops/bytes/collectives out of the compiled module.  Production
# artifacts keep scans rolled (small HLO, fast compiles).
_UNROLL = False


class unrolled_scans:
    def __enter__(self):
        global _UNROLL
        self._prev = _UNROLL
        _UNROLL = True

    def __exit__(self, *exc):
        global _UNROLL
        _UNROLL = self._prev


def scans_unrolled() -> bool:
    return _UNROLL


def maybe_scan(body, init, xs, length=None):
    """lax.scan that honours the dry-run unroll flag."""
    import jax.lax as lax

    return lax.scan(body, init, xs, length=length, unroll=True if _UNROLL else 1)


class PSpec(NamedTuple):
    """Declarative parameter spec: shape, logical axes, init, dtype."""

    shape: tuple
    axes: tuple
    init: str = "fan_in"  # 'fan_in' | 'zeros' | 'ones' | 'normal' | 'embed'
    dtype: Any = None  # None -> model dtype


def _is_pspec(x):
    return isinstance(x, PSpec)


def init_tree(specs, key: jax.Array, default_dtype):
    """Materialize a PSpec tree into arrays (single key fold-in per leaf)."""
    leaves, treedef = jax.tree.flatten(specs, is_leaf=_is_pspec)
    out = []
    for i, spec in enumerate(leaves):
        dtype = spec.dtype or default_dtype
        k = jax.random.fold_in(key, i)
        if spec.init == "zeros":
            arr = jnp.zeros(spec.shape, dtype)
        elif spec.init == "ones":
            arr = jnp.ones(spec.shape, dtype)
        elif spec.init == "normal":
            arr = (jax.random.normal(k, spec.shape, jnp.float32) * 0.02).astype(dtype)
        elif spec.init == "embed":
            # 0.02-std (GPT/llama convention) — also keeps tied-embedding
            # logits at an O(1) scale at init.
            arr = (jax.random.normal(k, spec.shape, jnp.float32) * 0.02).astype(dtype)
        elif spec.init == "fan_in":
            fan_in = spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1]
            std = 1.0 / math.sqrt(max(fan_in, 1))
            arr = (jax.random.normal(k, spec.shape, jnp.float32) * std).astype(dtype)
        elif spec.init == "rglru_lambda":
            # Griffin: a = sigmoid(Λ) uniform in [0.9, 0.999] -> Λ = logit(a)
            u = jax.random.uniform(k, spec.shape, jnp.float32, 0.9, 0.999)
            arr = jnp.log(u / (1 - u)).astype(jnp.float32)
        elif spec.init == "ssm_a_log":
            # Mamba2: A in [1, 16] -> log
            u = jax.random.uniform(k, spec.shape, jnp.float32, 1.0, 16.0)
            arr = jnp.log(u).astype(jnp.float32)
        elif spec.init == "ssm_dt_bias":
            # softplus^-1 of dt in [1e-3, 1e-1]
            u = jax.random.uniform(k, spec.shape, jnp.float32, 1e-3, 1e-1)
            arr = (u + jnp.log(-jnp.expm1(-u))).astype(jnp.float32)
        else:
            raise ValueError(f"unknown init {spec.init!r}")
        out.append(arr)
    return jax.tree.unflatten(treedef, out)


def axes_tree(specs):
    """PSpec tree -> logical-axes tree (same structure)."""
    return jax.tree.map(lambda s: s.axes, specs, is_leaf=_is_pspec)


def shape_tree(specs, default_dtype):
    """PSpec tree -> ShapeDtypeStruct tree (for eval_shape-free dry runs)."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype or default_dtype),
        specs,
        is_leaf=_is_pspec,
    )


def rms_norm(x, scale, eps=1e-6):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(dt) * (1.0 + scale.astype(dt))


def make_rope(positions, dim: int, theta: float, dtype=jnp.float32):
    """positions (...,) -> (cos, sin) of shape (..., dim//2)."""
    half = dim // 2
    freqs = jnp.exp(
        -math.log(theta) * jnp.arange(0, half, dtype=jnp.float32) / half
    )
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang).astype(dtype), jnp.sin(ang).astype(dtype)


def apply_rope(x, cos, sin):
    """x (..., S, d); cos/sin (S, d//2) or broadcastable.  Rotate-half form."""
    d = x.shape[-1]
    x1, x2 = x[..., : d // 2], x[..., d // 2 :]
    while cos.ndim < x1.ndim:
        cos, sin = cos[None], sin[None]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    ).astype(x.dtype)


def activation(kind: str, h, g=None):
    """Apply activation; ``g`` is the gate branch for GLU variants."""
    if kind == "silu_glu":
        return jax.nn.silu(h) * g
    if kind == "gelu_glu":
        return jax.nn.gelu(h) * g
    if kind == "sq_relu":
        r = jax.nn.relu(h)
        return r * r
    if kind == "gelu":
        return jax.nn.gelu(h)
    raise ValueError(f"unknown activation {kind!r}")
