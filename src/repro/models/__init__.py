"""LM substrate: the ten assigned architectures on one pattern-scan stack."""
from . import attention, common, mlp, moe, rglru, ssm, transformer
from .transformer import (backbone, cache_axes, cache_shapes, decode_step,
                          forward, init_cache, init_params, loss_fn,
                          model_specs, params_axes, params_shapes, prefill)

__all__ = ["attention", "common", "mlp", "moe", "rglru", "ssm", "transformer",
           "backbone", "cache_axes", "cache_shapes", "decode_step", "forward",
           "init_cache", "init_params", "loss_fn", "model_specs",
           "params_axes", "params_shapes", "prefill"]
