"""Mixture-of-Experts FFN: top-k routing with capacity-based dispatch.

Two implementations, selected by ``cfg.moe_impl``:

**GSPMD path** (`'gspmd'`; the paper-faithful/naive baseline): one global
sort-based dispatch — flatten (token, choice) assignments, rank within
expert via segment-cumsum over the sorted order, drop beyond capacity,
gather into a dense [E, C, D] buffer for grouped matmuls.  Compiles under
bare jit anywhere, but at 32k contexts GSPMD must replicate the token
array across devices to partition the global sort/gather (≈10 GB/device
at deepseek-v2 prefill) — measured in EXPERIMENTS.md §Perf as the
baseline.

**shard_map expert-parallel path** (`'shard_map'`, auto-selected under a
mesh with a 'model' axis): dispatch runs *locally* per data shard — no
global sort, no token replication.  Expert weights are sharded over
'model' on the expert axis (or on the FFN axis when E < model-axis size,
e.g. mixtral's 8 experts on 16-way TP), FSDP-gathered over 'data'
explicitly, and each device computes only its expert (or FFN) slice; a
single psum over 'model' combines contributions — Megatron-style EP with
explicit collectives.

Both support shared experts (DeepSeek-V2) and top-k renormalization
(Mixtral); router in f32; Switch-style load-balance aux loss.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from ..configs.base import ModelConfig, MoEConfig
from .common import PSpec, activation, constrain, rms_norm
from .mlp import GATED


def moe_specs(cfg: ModelConfig) -> dict:
    D = cfg.d_model
    m: MoEConfig = cfg.moe
    E, F = m.n_experts, m.d_expert
    specs = {
        "ln": PSpec((D,), ("embed",), "zeros"),
        "router": PSpec((D, E), ("embed", None), dtype=jnp.float32),
        "w_in": PSpec((E, D, F), ("experts", "embed", "expert_mlp")),
        "w_out": PSpec((E, F, D), ("experts", "expert_mlp", "embed")),
    }
    if cfg.act in GATED:
        specs["w_gate"] = PSpec((E, D, F), ("experts", "embed", "expert_mlp"))
    if m.n_shared:
        Fs = m.n_shared * m.d_expert
        specs["shared_in"] = PSpec((D, Fs), ("embed", "mlp"))
        specs["shared_out"] = PSpec((Fs, D), ("mlp", "embed"))
        if cfg.act in GATED:
            specs["shared_gate"] = PSpec((D, Fs), ("embed", "mlp"))
    return specs


def _capacity(T: int, m: MoEConfig) -> int:
    c = int(m.capacity_factor * T * m.top_k / m.n_experts)
    return max(8, -(-c // 8) * 8)  # multiple of 8 for tiling


def _route(p_router, h, m: MoEConfig):
    logits = h.astype(jnp.float32) @ p_router
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, m.top_k)
    if m.norm_topk:
        gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)
    return probs, gate_vals, gate_idx


def _rank_in_expert(flat_e, E):
    """Stable rank of each assignment within its target expert."""
    A = flat_e.shape[0]
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    counts = jax.ops.segment_sum(jnp.ones((A,), jnp.int32), sorted_e, E)
    starts = jnp.cumsum(counts) - counts
    rank_sorted = jnp.arange(A, dtype=jnp.int32) - starts[sorted_e]
    return jnp.zeros((A,), jnp.int32).at[order].set(rank_sorted)


def _expert_ffn(xe, p, cfg, f_slice=None):
    """xe (E?, C, D) -> (E?, C, D) through the (possibly F-sliced) experts."""
    w_in, w_out = p["w_in"], p["w_out"]
    up = jnp.einsum("ecd,edf->ecf", xe, w_in)
    if cfg.act in GATED:
        act = activation(cfg.act, up, jnp.einsum("ecd,edf->ecf", xe, p["w_gate"]))
    else:
        act = activation(cfg.act, up)
    return jnp.einsum("ecf,efd->ecd", act, w_out)


def _shared_ffn(h, p, cfg):
    s_up = h @ p["shared_in"]
    if cfg.act in GATED:
        s_act = activation(cfg.act, s_up, h @ p["shared_gate"])
    else:
        s_act = activation(cfg.act, s_up)
    return s_act @ p["shared_out"]


def _aux_loss(probs, flat_e, m: MoEConfig):
    A = flat_e.shape[0]
    frac = jax.ops.segment_sum(
        jnp.ones((A,), jnp.float32) / A, flat_e, num_segments=m.n_experts
    )
    return m.aux_weight * m.n_experts * jnp.sum(frac * probs.mean(0))


# ===========================================================================
# GSPMD (global-dispatch) path — the measured baseline
# ===========================================================================


def _moe_gspmd(p, x, cfg: ModelConfig, return_aux: bool):
    m: MoEConfig = cfg.moe
    orig_shape = x.shape
    squeeze = x.ndim == 3
    h_in = rms_norm(x, p["ln"], cfg.norm_eps)
    h = h_in.reshape(-1, orig_shape[-1])
    T, D = h.shape
    E, K = m.n_experts, m.top_k
    C = _capacity(T, m)

    probs, gate_vals, gate_idx = _route(p["router"], h, m)

    A = T * K
    flat_e = gate_idx.reshape(A)
    token_of = jnp.arange(A, dtype=jnp.int32) // K
    rank = _rank_in_expert(flat_e, E)
    keep = rank < C
    dest = jnp.where(keep, flat_e * C + rank, E * C)

    slot_src = jnp.full((E * C + 1,), T, jnp.int32).at[dest].set(token_of)
    h_pad = jnp.concatenate([h, jnp.zeros((1, D), h.dtype)], axis=0)
    xe = h_pad[slot_src[:-1]].reshape(E, C, D)
    xe = constrain(xe, ("act_experts", "cap", None))

    ye = _expert_ffn(xe, p, cfg)
    ye = constrain(ye, ("act_experts", "cap", None))

    ye_flat = jnp.concatenate(
        [ye.reshape(E * C, D), jnp.zeros((1, D), ye.dtype)], axis=0
    )
    y_assign = ye_flat[dest] * (
        gate_vals.reshape(A, 1).astype(ye.dtype) * keep[:, None]
    )
    y = jax.ops.segment_sum(y_assign, token_of, num_segments=T)

    if m.n_shared:
        y = y + _shared_ffn(h, p, cfg)

    y = y.reshape(orig_shape).astype(x.dtype)
    out = x + (constrain(y, ("batch", "seq", "act_embed")) if squeeze else y)
    if not return_aux:
        return out
    return out, _aux_loss(probs, flat_e, m)


# ===========================================================================
# shard_map expert-parallel path
# ===========================================================================


def _current_mesh():
    try:
        from jax._src.mesh import thread_resources

        mesh = thread_resources.env.physical_mesh
        return None if mesh.empty else mesh
    except Exception:
        return None


def _moe_shard_map(p, x, cfg: ModelConfig, mesh, return_aux: bool):
    m: MoEConfig = cfg.moe
    E, K, D = m.n_experts, m.top_k, cfg.d_model
    names = mesh.axis_names
    dp_axes = tuple(a for a in ("pod", "data") if a in names)
    tp = "model"
    tp_size = dict(zip(names, mesh.devices.shape))[tp]
    expert_mode = E % tp_size == 0 and E >= tp_size
    E_loc = E // tp_size if expert_mode else E
    gated = cfg.act in GATED

    def body(x_loc, p_loc):
        # ---- FSDP-gather weights over the data axis (explicit) ----------
        def gather_embed(w, axis):
            return lax.all_gather(w, "data", axis=axis, tiled=True) if "data" in names else w

        ln = gather_embed(p_loc["ln"], 0)
        router = gather_embed(p_loc["router"], 0)
        w = {
            "w_in": gather_embed(p_loc["w_in"], 1),
            "w_out": gather_embed(p_loc["w_out"], 2),
        }
        if gated:
            w["w_gate"] = gather_embed(p_loc["w_gate"], 1)

        B_loc, S, _ = x_loc.shape
        h_in = rms_norm(x_loc, ln, cfg.norm_eps)
        h = h_in.reshape(-1, D)
        T_loc = h.shape[0]
        C = _capacity(T_loc, m)

        probs, gate_vals, gate_idx = _route(router, h, m)
        A = T_loc * K
        flat_e = gate_idx.reshape(A)
        token_of = jnp.arange(A, dtype=jnp.int32) // K
        rank = _rank_in_expert(flat_e, E)
        keep = rank < C

        if expert_mode:
            # keep only assignments targeting MY experts
            e0 = lax.axis_index(tp) * E_loc
            mine = (flat_e >= e0) & (flat_e < e0 + E_loc) & keep
            dest = jnp.where(mine, (flat_e - e0) * C + rank, E_loc * C)
        else:
            dest = jnp.where(keep, flat_e * C + rank, E_loc * C)

        slot_src = jnp.full((E_loc * C + 1,), T_loc, jnp.int32).at[dest].set(token_of)
        h_pad = jnp.concatenate([h, jnp.zeros((1, D), h.dtype)], axis=0)
        xe = h_pad[slot_src[:-1]].reshape(E_loc, C, D)

        ye = _expert_ffn(xe, w, cfg)

        ye_flat = jnp.concatenate(
            [ye.reshape(E_loc * C, D), jnp.zeros((1, D), ye.dtype)], axis=0
        )
        y_assign = ye_flat[dest] * (
            gate_vals.reshape(A, 1).astype(ye.dtype)
            * (mine if expert_mode else keep)[:, None]
        )
        y = jax.ops.segment_sum(y_assign, token_of, num_segments=T_loc)

        if m.n_shared:
            ws = {
                "shared_in": gather_embed(p_loc["shared_in"], 0),
                "shared_out": gather_embed(p_loc["shared_out"], 1),
            }
            if gated:
                ws["shared_gate"] = gather_embed(p_loc["shared_gate"], 0)
            # shared FFN dim is model-sharded -> contribution is partial too
            y = y + _shared_ffn(h, ws, cfg)

        # one combine psum over the model axis
        y = lax.psum(y, tp)
        out = x_loc + y.reshape(x_loc.shape).astype(x_loc.dtype)

        aux = _aux_loss(probs, flat_e, m)
        if dp_axes:
            aux = lax.pmean(aux, dp_axes)
        return out, aux

    # ---- specs ------------------------------------------------------------
    xspec = P(dp_axes if dp_axes else None, None, None)
    d_fsdp = "data" if "data" in names else None
    pspecs = {
        "ln": P(d_fsdp),
        "router": P(d_fsdp, None),
    }
    if expert_mode:
        pspecs["w_in"] = P(tp, d_fsdp, None)
        pspecs["w_out"] = P(tp, None, d_fsdp)
        if gated:
            pspecs["w_gate"] = P(tp, d_fsdp, None)
    else:
        pspecs["w_in"] = P(None, d_fsdp, tp)
        pspecs["w_out"] = P(None, tp, d_fsdp)
        if gated:
            pspecs["w_gate"] = P(None, d_fsdp, tp)
    if m.n_shared:
        pspecs["shared_in"] = P(d_fsdp, tp)
        pspecs["shared_out"] = P(tp, d_fsdp)
        if gated:
            pspecs["shared_gate"] = P(d_fsdp, tp)
    p_in = {k: p[k] for k in pspecs}

    from ..sharding import shard_map_compat

    fn = shard_map_compat(
        body,
        mesh=mesh,
        in_specs=(xspec, pspecs),
        out_specs=(xspec, P()),
    )
    out, aux = fn(x, p_in)
    if return_aux:
        return out, aux
    return out


def _ffn_shardable(cfg, tp_size):
    m = cfg.moe
    ok_expert = m.n_experts % tp_size == 0 and m.n_experts >= tp_size
    ok_ffn = m.d_expert % tp_size == 0
    return ok_expert or ok_ffn


def moe_apply(p, x, cfg: ModelConfig, return_aux: bool = False):
    """x (B, S, D) or (T, D).  Returns y (+ aux loss if requested)."""
    impl = cfg.moe_impl
    if impl in ("auto", "shard_map") and x.ndim == 3:
        mesh = _current_mesh()
        if mesh is not None and "model" in mesh.axis_names:
            tp_size = dict(zip(mesh.axis_names, mesh.devices.shape))["model"]
            if _ffn_shardable(cfg, tp_size):
                return _moe_shard_map(p, x, cfg, mesh, return_aux)
        if impl == "shard_map":
            raise RuntimeError("moe_impl='shard_map' requires a mesh with a 'model' axis")
    return _moe_gspmd(p, x, cfg, return_aux)
