"""Attention mixers: blockwise flash attention (GQA / MQA / sliding-window /
cross), qk-norm, and DeepSeek-style MLA with the weight-absorbed decode path.

The train/prefill path is an online-softmax blockwise attention (flash
attention expressed in jnp + lax.scan): O(qb·kvb) live scores instead of
O(S²).  For sliding windows the inner scan runs over a *static* number of
kv blocks selected with dynamic_slice — true sub-quadratic flops, which is
what makes mixtral/recurrentgemma long_500k decode cells viable.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from ..configs.base import MLAConfig, ModelConfig
from .common import PSpec, apply_rope, make_rope, maybe_scan, rms_norm, constrain

NEG_INF = -1e30


def _pick_block(size: int, want: int) -> int:
    b = min(want, size)
    while size % b:
        b -= 1
    return max(b, 1)


def flash_attention(
    q: jax.Array,  # (B, Hq, Sq, dk)
    k: jax.Array,  # (B, Hkv, Skv, dk)
    v: jax.Array,  # (B, Hkv, Skv, dv)
    *,
    causal: bool = True,
    window: int | None = None,
    q_offset: int = 0,
    q_block: int = 512,
    kv_block: int = 1024,
) -> jax.Array:
    """Blockwise online-softmax attention.  Returns (B, Hq, Sq, dv)."""
    B, Hq, Sq, dk = q.shape
    _, Hkv, Skv, _ = k.shape
    dv = v.shape[-1]
    G = Hq // Hkv
    scale = 1.0 / math.sqrt(dk)

    qb = _pick_block(Sq, q_block)
    kvb = _pick_block(Skv, kv_block)
    nq, nkv = Sq // qb, Skv // kvb

    qg = q.reshape(B, Hkv, G, Sq, dk)
    # scan over q blocks: (nq, B, Hkv, G, qb, dk)
    qs = jnp.moveaxis(qg.reshape(B, Hkv, G, nq, qb, dk), 3, 0)

    if window is not None:
        n_win = min(nkv, -(-(window + qb) // kvb) + 1)
    else:
        n_win = nkv

    kv_pos_base = jnp.arange(kvb)
    q_pos_base = jnp.arange(qb)

    def q_block_body(_, qi_and_q):
        qi, q_i = qi_and_q
        q_start = qi * qb + q_offset  # absolute position of q row 0

        if window is not None:
            first_needed = jnp.maximum(q_start - window + 1, 0) // kvb
            start_blk = jnp.minimum(first_needed, nkv - n_win)
        else:
            start_blk = jnp.asarray(0, jnp.int32)

        def kv_body(carry, j):
            m, l, acc = carry
            blk = start_blk + j
            k_j = lax.dynamic_slice_in_dim(k, blk * kvb, kvb, axis=2)
            v_j = lax.dynamic_slice_in_dim(v, blk * kvb, kvb, axis=2)
            s = jnp.einsum(
                "bhgqd,bhkd->bhgqk", q_i, k_j, preferred_element_type=jnp.float32
            ) * scale
            q_pos = q_start + q_pos_base  # (qb,)
            kv_pos = blk * kvb + kv_pos_base  # (kvb,)
            mask = jnp.ones((qb, kvb), bool)
            if causal:
                mask &= kv_pos[None, :] <= q_pos[:, None]
            if window is not None:
                mask &= kv_pos[None, :] > q_pos[:, None] - window
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhgqk,bhkd->bhgqd",
                p.astype(v_j.dtype),
                v_j,
                preferred_element_type=jnp.float32,
            )
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, Hkv, G, qb), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, qb), jnp.float32)
        a0 = jnp.zeros((B, Hkv, G, qb, dv), jnp.float32)
        (m, l, acc), _ = maybe_scan(
            kv_body, (m0, l0, a0), jnp.arange(n_win, dtype=jnp.int32)
        )
        out = acc / jnp.where(l == 0, 1.0, l)[..., None]
        return None, out

    _, outs = maybe_scan(
        q_block_body, None, (jnp.arange(nq, dtype=jnp.int32), qs)
    )
    # (nq, B, Hkv, G, qb, dv) -> (B, Hq, Sq, dv)
    out = jnp.moveaxis(outs, 0, 3).reshape(B, Hkv, G, Sq, dv)
    return out.reshape(B, Hq, Sq, dv).astype(v.dtype)


def decode_attention(q, k_cache, v_cache, valid_mask):
    """One-token attention.  q (B,Hq,dk); caches (B,Hkv,S,d*); mask (B,S)."""
    B, Hq, dk = q.shape
    Hkv = k_cache.shape[1]
    G = Hq // Hkv
    qg = q.reshape(B, Hkv, G, dk)
    s = jnp.einsum(
        "bhgd,bhsd->bhgs", qg, k_cache, preferred_element_type=jnp.float32
    ) / math.sqrt(dk)
    s = jnp.where(valid_mask[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum(
        "bhgs,bhsd->bhgd",
        p.astype(v_cache.dtype),
        v_cache,
        preferred_element_type=jnp.float32,
    )
    return out.reshape(B, Hq, v_cache.shape[-1]).astype(v_cache.dtype)


# ===========================================================================
# GQA self-attention block
# ===========================================================================


def gqa_specs(cfg: ModelConfig) -> dict:
    D, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    specs = {
        "ln": PSpec((D,), ("embed",), "zeros"),
        "wq": PSpec((D, H * hd), ("embed", "heads")),
        "wk": PSpec((D, KV * hd), ("embed", "kv_heads")),
        "wv": PSpec((D, KV * hd), ("embed", "kv_heads")),
        "wo": PSpec((H * hd, D), ("heads", "embed")),
    }
    if cfg.qk_norm:
        specs["q_norm"] = PSpec((hd,), ("head_dim",), "zeros")
        specs["k_norm"] = PSpec((hd,), ("head_dim",), "zeros")
    return specs


def _project_qkv(p, x, cfg: ModelConfig, positions):
    B, S, D = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = (x @ p["wq"]).reshape(B, S, H, hd).transpose(0, 2, 1, 3)
    k = (x @ p["wk"]).reshape(B, S, KV, hd).transpose(0, 2, 1, 3)
    v = (x @ p["wv"]).reshape(B, S, KV, hd).transpose(0, 2, 1, 3)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    cos, sin = make_rope(positions, hd, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    return q, k, v


def gqa_apply(p, x, cfg: ModelConfig, *, window=None, pos_offset=0):
    """Full-sequence self-attention block (pre-norm, residual)."""
    B, S, D = x.shape
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    positions = pos_offset + jnp.arange(S)
    q, k, v = _project_qkv(p, h, cfg, positions)
    q = constrain(q, ("batch", "act_heads", "seq", None))
    o = flash_attention(
        q, k, v,
        causal=True, window=window, q_offset=0,
        q_block=cfg.attn_q_block, kv_block=cfg.attn_kv_block,
    )
    o = o.transpose(0, 2, 1, 3).reshape(B, S, cfg.n_heads * cfg.head_dim)
    return x + constrain(o @ p["wo"], ("batch", "seq", "act_embed"))


def gqa_init_cache(cfg: ModelConfig, B: int, S: int, window, dtype):
    L = min(S, window) if window else S
    KV, hd = cfg.n_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((B, KV, L, hd), dtype),
        "v": jnp.zeros((B, KV, L, hd), dtype),
    }


def gqa_cache_axes():
    return {
        "k": ("batch", "kv_heads", "cache_seq", "head_dim"),
        "v": ("batch", "kv_heads", "cache_seq", "head_dim"),
    }


def gqa_decode(p, x, cache, step, cfg: ModelConfig, *, window=None):
    """x (B, D), one token at absolute position ``step``."""
    B, D = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    q = (h @ p["wq"]).reshape(B, H, hd)
    k = (h @ p["wk"]).reshape(B, KV, hd)
    v = (h @ p["wv"]).reshape(B, KV, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    cos, sin = make_rope(step[None], hd, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)

    L = cache["k"].shape[2]
    slot = step % L if window else jnp.minimum(step, L - 1)
    k_cache = lax.dynamic_update_slice_in_dim(cache["k"], k[:, :, None], slot, axis=2)
    v_cache = lax.dynamic_update_slice_in_dim(cache["v"], v[:, :, None], slot, axis=2)
    slots = jnp.arange(L)
    valid = jnp.broadcast_to((slots <= step) | (step >= L), (B, L))
    o = decode_attention(q, k_cache, v_cache, valid)
    o = o.reshape(B, H * hd)
    return x + o @ p["wo"], {"k": k_cache, "v": v_cache}


# ===========================================================================
# Cross-attention block (VLM): text queries attend to image patch embeddings
# ===========================================================================


def cross_specs(cfg: ModelConfig) -> dict:
    D, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    return {
        "ln": PSpec((D,), ("embed",), "zeros"),
        "wq": PSpec((D, H * hd), ("embed", "heads")),
        "wk": PSpec((D, KV * hd), ("embed", "kv_heads")),
        "wv": PSpec((D, KV * hd), ("embed", "kv_heads")),
        "wo": PSpec((H * hd, D), ("heads", "embed")),
        "gate": PSpec((1,), (None,), "zeros"),  # tanh gate (llama-vision)
        "k_norm": PSpec((hd,), ("head_dim",), "zeros"),
        "q_norm": PSpec((hd,), ("head_dim",), "zeros"),
    }


def cross_apply(p, x, img, cfg: ModelConfig):
    """x (B,S,D) text; img (B,P,D) precomputed patch embeddings (stub)."""
    B, S, D = x.shape
    P_img = img.shape[1]
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    q = (h @ p["wq"]).reshape(B, S, H, hd).transpose(0, 2, 1, 3)
    k = (img @ p["wk"]).reshape(B, P_img, KV, hd).transpose(0, 2, 1, 3)
    v = (img @ p["wv"]).reshape(B, P_img, KV, hd).transpose(0, 2, 1, 3)
    q = rms_norm(q, p["q_norm"], cfg.norm_eps)
    k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    o = flash_attention(
        q, k, v, causal=False,
        q_block=cfg.attn_q_block, kv_block=cfg.attn_kv_block,
    )
    o = o.transpose(0, 2, 1, 3).reshape(B, S, H * hd)
    return x + jnp.tanh(p["gate"]).astype(x.dtype) * (o @ p["wo"])


def cross_decode(p, x, img, cfg: ModelConfig):
    """One-token cross attention; img acts as a fixed kv cache."""
    B, D = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    q = rms_norm((h @ p["wq"]).reshape(B, H, hd), p["q_norm"], cfg.norm_eps)
    k = rms_norm(
        (img @ p["wk"]).reshape(B, -1, KV, hd).transpose(0, 2, 1, 3),
        p["k_norm"],
        cfg.norm_eps,
    )
    v = (img @ p["wv"]).reshape(B, -1, KV, hd).transpose(0, 2, 1, 3)
    valid = jnp.ones((B, k.shape[2]), bool)
    o = decode_attention(q, k, v, valid).reshape(B, H * hd)
    return x + jnp.tanh(p["gate"]).astype(x.dtype) * (o @ p["wo"])


# ===========================================================================
# MLA (DeepSeek-V2 multi-head latent attention)
# ===========================================================================


def mla_specs(cfg: ModelConfig) -> dict:
    D, H = cfg.d_model, cfg.n_heads
    m: MLAConfig = cfg.mla
    dq = m.qk_nope_dim + m.qk_rope_dim
    return {
        "ln": PSpec((D,), ("embed",), "zeros"),
        "wq_a": PSpec((D, m.q_lora), ("embed", "lora")),
        "q_ln": PSpec((m.q_lora,), ("lora",), "zeros"),
        "wq_b": PSpec((m.q_lora, H * dq), ("lora", "heads")),
        "wkv_a": PSpec((D, m.kv_lora + m.qk_rope_dim), ("embed", "lora")),
        "kv_ln": PSpec((m.kv_lora,), ("lora",), "zeros"),
        "wkv_b": PSpec(
            (m.kv_lora, H * (m.qk_nope_dim + m.v_dim)), ("lora", "heads")
        ),
        "wo": PSpec((H * m.v_dim, D), ("heads", "embed")),
    }


def _mla_qkv(p, h, cfg: ModelConfig, positions):
    B, S, D = h.shape
    H = cfg.n_heads
    m: MLAConfig = cfg.mla
    dn, dr, dv = m.qk_nope_dim, m.qk_rope_dim, m.v_dim

    q = rms_norm(h @ p["wq_a"], p["q_ln"], cfg.norm_eps) @ p["wq_b"]
    q = q.reshape(B, S, H, dn + dr).transpose(0, 2, 1, 3)
    q_nope, q_rope = q[..., :dn], q[..., dn:]

    kv_a = h @ p["wkv_a"]  # (B, S, kv_lora + dr)
    latent = rms_norm(kv_a[..., : m.kv_lora], p["kv_ln"], cfg.norm_eps)
    k_rope = kv_a[..., m.kv_lora :][:, None]  # (B, 1, S, dr) shared head

    cos, sin = make_rope(positions, dr, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)
    k_rope = apply_rope(k_rope, cos, sin)
    return q_nope, q_rope, latent, k_rope


def mla_apply(p, x, cfg: ModelConfig, *, pos_offset=0):
    B, S, D = x.shape
    H = cfg.n_heads
    m: MLAConfig = cfg.mla
    dn, dr, dv = m.qk_nope_dim, m.qk_rope_dim, m.v_dim
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    positions = pos_offset + jnp.arange(S)
    q_nope, q_rope, latent, k_rope = _mla_qkv(p, h, cfg, positions)

    # Expand latent -> per-head k_nope, v (prefill/train path).
    kv = (latent @ p["wkv_b"]).reshape(B, S, H, dn + dv).transpose(0, 2, 1, 3)
    k_nope, v = kv[..., :dn], kv[..., dn:]
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (B, H, S, dr))], axis=-1
    )
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    q = constrain(q, ("batch", "act_heads", "seq", None))
    o = flash_attention(
        q, k, v, causal=True,
        q_block=cfg.attn_q_block, kv_block=cfg.attn_kv_block,
    )
    o = o.transpose(0, 2, 1, 3).reshape(B, S, H * dv)
    return x + constrain(o @ p["wo"], ("batch", "seq", "act_embed"))


def mla_init_cache(cfg: ModelConfig, B: int, S: int, dtype):
    m: MLAConfig = cfg.mla
    return {
        "latent": jnp.zeros((B, S, m.kv_lora), dtype),
        "k_rope": jnp.zeros((B, S, m.qk_rope_dim), dtype),
    }


def mla_cache_axes():
    return {
        "latent": ("batch", "cache_seq", "lora"),
        "k_rope": ("batch", "cache_seq", "head_dim"),
    }


def mla_decode(p, x, cache, step, cfg: ModelConfig):
    """Weight-absorbed MLA decode: attention runs in latent space.

    q̃ = q_nopeᵀ W_uk  (B,H,kv_lora);  scores = q̃·latentᵀ + q_rope·k_ropeᵀ;
    ctx = attn·latent;  out_h = ctx·W_uv — per-step flops O(B·H·S·kv_lora)
    instead of O(B·H·S·(dn+dv)·kv_lora/S...) of naive re-expansion.
    """
    B, D = x.shape
    H = cfg.n_heads
    m: MLAConfig = cfg.mla
    dn, dr, dv = m.qk_nope_dim, m.qk_rope_dim, m.v_dim
    h = rms_norm(x, p["ln"], cfg.norm_eps)

    q = rms_norm(h @ p["wq_a"], p["q_ln"], cfg.norm_eps) @ p["wq_b"]
    q = q.reshape(B, H, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    cos, sin = make_rope(step[None], dr, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)

    kv_a = h @ p["wkv_a"]
    latent_new = rms_norm(kv_a[..., : m.kv_lora], p["kv_ln"], cfg.norm_eps)
    k_rope_new = apply_rope(kv_a[None, ..., m.kv_lora :], cos, sin)[0]

    S = cache["latent"].shape[1]
    slot = jnp.minimum(step, S - 1)
    latent = lax.dynamic_update_slice_in_dim(
        cache["latent"], latent_new[:, None], slot, axis=1
    )
    k_rope = lax.dynamic_update_slice_in_dim(
        cache["k_rope"], k_rope_new[:, None], slot, axis=1
    )

    wkv_b = p["wkv_b"].reshape(m.kv_lora, H, dn + dv)
    w_uk = wkv_b[..., :dn]  # (kv_lora, H, dn)
    w_uv = wkv_b[..., dn:]  # (kv_lora, H, dv)

    q_abs = jnp.einsum("bhd,lhd->bhl", q_nope, w_uk)  # (B, H, kv_lora)
    s = (
        jnp.einsum("bhl,bsl->bhs", q_abs, latent, preferred_element_type=jnp.float32)
        + jnp.einsum("bhr,bsr->bhs", q_rope, k_rope, preferred_element_type=jnp.float32)
    ) / math.sqrt(dn + dr)
    valid = jnp.broadcast_to(jnp.arange(S) <= step, (B, S))
    s = jnp.where(valid[:, None], s, NEG_INF)
    probs = jax.nn.softmax(s, axis=-1)
    ctx = jnp.einsum("bhs,bsl->bhl", probs.astype(latent.dtype), latent)
    o = jnp.einsum("bhl,lhd->bhd", ctx, w_uv).reshape(B, H * dv)
    return x + o @ p["wo"], {"latent": latent, "k_rope": k_rope}
