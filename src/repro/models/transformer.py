"""Unified decoder stack: pattern-scan over stacked layer params.

A model = optional unrolled ``prefix`` layers + ``n_periods`` repetitions of
a layer ``pattern`` (params stacked on a leading axis, executed via
``lax.scan`` — HLO stays O(|pattern|) regardless of depth, which keeps
512-device SPMD compiles tractable) + optional unrolled ``suffix``.

Public entry points:
  model_specs / init_params / params_axes / params_shapes
  forward          — full-sequence logits-producing pass (train/eval)
  loss_fn          — forward + seq-chunked softmax-xent (logits never
                     materialized at full (B,S,V))
  prefill          — forward that also builds the serving cache
  decode_step      — one-token step updating the cache
  init_cache / cache_axes / cache_shapes
"""
from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from ..configs.base import LayerSpec, ModelConfig
from . import attention as attn
from . import mlp as mlp_mod
from . import moe as moe_mod
from . import rglru as rglru_mod
from . import ssm as ssm_mod
from .common import (
    DTYPES,
    PSpec,
    axes_tree,
    constrain,
    init_tree,
    maybe_scan,
    rms_norm,
    shape_tree,
)

# ===========================================================================
# Param specs
# ===========================================================================


def layer_specs(cfg: ModelConfig, spec: LayerSpec) -> dict:
    if spec.mixer == "attn":
        d = {"mixer": attn.gqa_specs(cfg)}
    elif spec.mixer == "mla":
        d = {"mixer": attn.mla_specs(cfg)}
    elif spec.mixer == "cross_attn":
        d = {"mixer": attn.cross_specs(cfg)}
    elif spec.mixer == "ssd":
        d = {"mixer": ssm_mod.ssd_specs(cfg)}
    elif spec.mixer == "rglru":
        d = {"mixer": rglru_mod.rglru_specs(cfg)}
    else:
        raise ValueError(f"unknown mixer {spec.mixer!r}")
    if spec.ffn:
        d["ffn"] = moe_mod.moe_specs(cfg) if spec.moe else mlp_mod.mlp_specs(cfg)
    return d


def _stack_specs(specs, n: int):
    return jax.tree.map(
        lambda s: PSpec((n,) + s.shape, ("layers",) + s.axes, s.init, s.dtype),
        specs,
        is_leaf=lambda x: isinstance(x, PSpec),
    )


def model_specs(cfg: ModelConfig) -> dict:
    D, V = cfg.d_model, cfg.vocab
    specs: dict[str, Any] = {}
    if cfg.frontend == "token" or cfg.frontend == "vision":
        specs["embed"] = PSpec((V, D), ("vocab", "embed"), "embed")
    # 'frames' frontend: inputs arrive as precomputed (B,S,D) embeddings (stub)
    specs["prefix"] = [layer_specs(cfg, s) for s in cfg.prefix]
    specs["pattern"] = [
        _stack_specs(layer_specs(cfg, s), cfg.n_periods) for s in cfg.pattern
    ]
    specs["suffix"] = [layer_specs(cfg, s) for s in cfg.suffix]
    specs["final_ln"] = PSpec((D,), ("embed",), "zeros")
    if not cfg.tie_embeddings:
        specs["head"] = PSpec((D, V), ("embed", "vocab"))
    return specs


def init_params(cfg: ModelConfig, key: jax.Array):
    return init_tree(model_specs(cfg), key, DTYPES[cfg.dtype])


def params_axes(cfg: ModelConfig):
    return axes_tree(model_specs(cfg))


def params_shapes(cfg: ModelConfig):
    return shape_tree(model_specs(cfg), DTYPES[cfg.dtype])


# ===========================================================================
# Layer application
# ===========================================================================


def apply_layer(p, x, cfg: ModelConfig, spec: LayerSpec, img=None, pos_offset=0):
    """Returns (x, aux)."""
    aux = jnp.zeros((), jnp.float32)
    if spec.mixer == "attn":
        x = attn.gqa_apply(p["mixer"], x, cfg, window=spec.window, pos_offset=pos_offset)
    elif spec.mixer == "mla":
        x = attn.mla_apply(p["mixer"], x, cfg, pos_offset=pos_offset)
    elif spec.mixer == "cross_attn":
        x = attn.cross_apply(p["mixer"], x, img, cfg)
    elif spec.mixer == "ssd":
        x = ssm_mod.ssd_apply(p["mixer"], x, cfg)
    elif spec.mixer == "rglru":
        x = rglru_mod.rglru_apply(p["mixer"], x, cfg)
    if spec.ffn:
        if spec.moe:
            x, aux = moe_mod.moe_apply(p["ffn"], x, cfg, return_aux=True)
        else:
            x = mlp_mod.mlp_apply(p["ffn"], x, cfg)
    return x, aux


def _remat(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        )
    return jax.checkpoint(fn)  # 'full': save only block inputs


# ===========================================================================
# Forward (train / eval)
# ===========================================================================


def _embed_inputs(cfg: ModelConfig, params, batch):
    dtype = DTYPES[cfg.dtype]
    if cfg.frontend == "frames":
        x = batch["embeds"].astype(dtype)
    else:
        x = jnp.take(params["embed"], batch["tokens"], axis=0).astype(dtype)
    img = batch.get("image_embeds")
    if img is not None:
        img = img.astype(dtype)
    return constrain(x, ("batch", "seq", "act_embed")), img


def backbone(cfg: ModelConfig, params, x, img=None):
    """Embeddings -> final hidden states.  Returns (x, total_aux)."""
    aux_total = jnp.zeros((), jnp.float32)
    for spec, p in zip(cfg.prefix, params["prefix"]):
        x, aux = apply_layer(p, x, cfg, spec, img=img)
        aux_total += aux

    if cfg.n_periods:
        def period_body(carry, period_params):
            h, aux_acc = carry
            for i, spec in enumerate(cfg.pattern):
                h, aux = apply_layer(period_params[i], h, cfg, spec, img=img)
                aux_acc += aux
            h = constrain(h, ("batch", "seq", "act_embed"))
            return (h, aux_acc), None

        body = _remat(period_body, cfg)
        (x, aux_total), _ = maybe_scan(body, (x, aux_total), params["pattern"])

    for spec, p in zip(cfg.suffix, params["suffix"]):
        x, aux = apply_layer(p, x, cfg, spec, img=img)
        aux_total += aux
    x = rms_norm(x, params["final_ln"], cfg.norm_eps)
    return x, aux_total


def _head_weight(cfg: ModelConfig, params):
    if cfg.tie_embeddings:
        return params["embed"].T
    return params["head"]


def forward(cfg: ModelConfig, params, batch):
    """Full logits (careful: (B,S,V) — use loss_fn for training)."""
    x, img = _embed_inputs(cfg, params, batch)
    x, _ = backbone(cfg, params, x, img)
    return (x @ _head_weight(cfg, params)).astype(jnp.float32)


def loss_fn(cfg: ModelConfig, params, batch):
    """Seq-chunked softmax cross-entropy.  Returns (loss, metrics)."""
    x, img = _embed_inputs(cfg, params, batch)
    x, aux = backbone(cfg, params, x, img)
    w = _head_weight(cfg, params)
    labels = batch["labels"]
    B, S = labels.shape

    chunk = cfg.loss_chunk or S
    chunk = min(chunk, S)
    while S % chunk:
        chunk -= 1
    nc = S // chunk

    xc = jnp.moveaxis(x.reshape(B, nc, chunk, -1), 1, 0)
    lc = jnp.moveaxis(labels.reshape(B, nc, chunk), 1, 0)

    def chunk_loss(carry, sl):
        xs, ls = sl
        logits = (xs @ w).astype(jnp.float32)  # (B, chunk, V)
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, ls[..., None], axis=-1)[..., 0]
        return carry + jnp.sum(lse - ll), None

    total, _ = maybe_scan(jax.checkpoint(chunk_loss), jnp.zeros((), jnp.float32), (xc, lc))
    loss = total / (B * S) + aux
    return loss, {"ce": total / (B * S), "aux": aux}


# ===========================================================================
# Serving: prefill + decode
# ===========================================================================


def _layer_cache_shape(cfg: ModelConfig, spec: LayerSpec, B: int, S: int, dtype):
    if spec.mixer == "attn":
        return attn.gqa_init_cache(cfg, B, S, spec.window, dtype)
    if spec.mixer == "mla":
        return attn.mla_init_cache(cfg, B, S, dtype)
    if spec.mixer == "ssd":
        return ssm_mod.ssd_init_cache(cfg, B, dtype)
    if spec.mixer == "rglru":
        return rglru_mod.rglru_init_cache(cfg, B, dtype)
    if spec.mixer == "cross_attn":
        return {}  # image embeds act as the (static) cache
    raise ValueError(spec.mixer)


def _layer_cache_axes(spec: LayerSpec):
    if spec.mixer == "attn":
        return attn.gqa_cache_axes()
    if spec.mixer == "mla":
        return attn.mla_cache_axes()
    if spec.mixer == "ssd":
        return ssm_mod.ssd_cache_axes()
    if spec.mixer == "rglru":
        return rglru_mod.rglru_cache_axes()
    if spec.mixer == "cross_attn":
        return {}
    raise ValueError(spec.mixer)


def init_cache(cfg: ModelConfig, B: int, S: int):
    dtype = DTYPES[cfg.dtype]
    stack = lambda tree: jax.tree.map(
        lambda a: jnp.broadcast_to(a, (cfg.n_periods,) + a.shape), tree
    )
    return {
        "prefix": [_layer_cache_shape(cfg, s, B, S, dtype) for s in cfg.prefix],
        "pattern": [
            stack(_layer_cache_shape(cfg, s, B, S, dtype)) for s in cfg.pattern
        ],
        "suffix": [_layer_cache_shape(cfg, s, B, S, dtype) for s in cfg.suffix],
    }


def cache_axes(cfg: ModelConfig):
    stack = lambda tree: jax.tree.map(
        lambda axes: ("layers",) + axes,
        tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(isinstance(a, (str, type(None))) for a in x),
    )
    return {
        "prefix": [_layer_cache_axes(s) for s in cfg.prefix],
        "pattern": [stack(_layer_cache_axes(s)) for s in cfg.pattern],
        "suffix": [_layer_cache_axes(s) for s in cfg.suffix],
    }


def cache_shapes(cfg: ModelConfig, B: int, S: int):
    return jax.eval_shape(lambda: init_cache(cfg, B, S))


def _decode_layer(p, x, c, step, cfg: ModelConfig, spec: LayerSpec, img=None):
    aux = jnp.zeros((), jnp.float32)
    if spec.mixer == "attn":
        x, c = attn.gqa_decode(p["mixer"], x, c, step, cfg, window=spec.window)
    elif spec.mixer == "mla":
        x, c = attn.mla_decode(p["mixer"], x, c, step, cfg)
    elif spec.mixer == "ssd":
        x, c = ssm_mod.ssd_decode(p["mixer"], x, c, step, cfg)
    elif spec.mixer == "rglru":
        x, c = rglru_mod.rglru_decode(p["mixer"], x, c, step, cfg)
    elif spec.mixer == "cross_attn":
        x = attn.cross_decode(p["mixer"], x, img, cfg)
    if spec.ffn:
        if spec.moe:
            x, aux = moe_mod.moe_apply(p["ffn"], x, cfg, return_aux=True)
        else:
            x = mlp_mod.mlp_apply(p["ffn"], x, cfg)
    del aux
    return x, c


def decode_step(cfg: ModelConfig, params, cache, tokens, step, embeds=None, img=None):
    """One decoding step.

    tokens (B,) int32 (or ``embeds`` (B,D) for the frames frontend);
    ``step`` scalar int32 = absolute position being written.
    Returns (logits (B,V) f32, new_cache).
    """
    dtype = DTYPES[cfg.dtype]
    if cfg.frontend == "frames":
        x = embeds.astype(dtype)
    else:
        x = jnp.take(params["embed"], tokens, axis=0).astype(dtype)

    new_prefix = []
    for spec, p, c in zip(cfg.prefix, params["prefix"], cache["prefix"]):
        x, c2 = _decode_layer(p, x, c, step, cfg, spec, img=img)
        new_prefix.append(c2)

    new_pattern = cache["pattern"]
    if cfg.n_periods:
        def period_body(h, pc):
            period_params, period_cache = pc
            new_c = []
            for i, spec in enumerate(cfg.pattern):
                h, c2 = _decode_layer(
                    period_params[i], h, period_cache[i], step, cfg, spec, img=img
                )
                new_c.append(c2)
            return h, new_c

        x, new_pattern = maybe_scan(
            period_body, x, (params["pattern"], cache["pattern"])
        )

    new_suffix = []
    for spec, p, c in zip(cfg.suffix, params["suffix"], cache["suffix"]):
        x, c2 = _decode_layer(p, x, c, step, cfg, spec, img=img)
        new_suffix.append(c2)

    x = rms_norm(x, params["final_ln"], cfg.norm_eps)
    logits = (x @ _head_weight(cfg, params)).astype(jnp.float32)
    new_cache = {"prefix": new_prefix, "pattern": new_pattern, "suffix": new_suffix}
    return logits, new_cache


def _prefill_layer(p, x, cfg, spec, S_cache, img=None):
    """Apply layer over the full prompt and build its cache entry."""
    dtype = DTYPES[cfg.dtype]
    B, S, D = x.shape
    if spec.mixer in ("attn", "mla"):
        # Run the standard layer, then recompute cache projections (cheap
        # relative to attention itself; keeps the blockwise path untouched).
        if spec.mixer == "attn":
            h = rms_norm(x, p["mixer"]["ln"], cfg.norm_eps)
            positions = jnp.arange(S)
            k, v = attn._project_qkv(p["mixer"], h, cfg, positions)[1:]
            L = min(S_cache, spec.window) if spec.window else S_cache
            c = attn.gqa_init_cache(cfg, B, S_cache, spec.window, dtype)
            take = min(S, L)
            idx = (jnp.arange(S - take, S)) % L
            c["k"] = c["k"].at[:, :, idx].set(k[:, :, S - take :].astype(dtype))
            c["v"] = c["v"].at[:, :, idx].set(v[:, :, S - take :].astype(dtype))
            x = attn.gqa_apply(p["mixer"], x, cfg, window=spec.window)
        else:
            h = rms_norm(x, p["mixer"]["ln"], cfg.norm_eps)
            kv_a = h @ p["mixer"]["wkv_a"]
            m = cfg.mla
            latent = rms_norm(kv_a[..., : m.kv_lora], p["mixer"]["kv_ln"], cfg.norm_eps)
            cos, sin = attn.make_rope(jnp.arange(S), m.qk_rope_dim, cfg.rope_theta)
            k_rope = attn.apply_rope(kv_a[:, None, :, m.kv_lora :], cos, sin)[:, 0]
            c = attn.mla_init_cache(cfg, B, S_cache, dtype)
            take = min(S, S_cache)
            c["latent"] = c["latent"].at[:, :take].set(latent[:, :take].astype(dtype))
            c["k_rope"] = c["k_rope"].at[:, :take].set(k_rope[:, :take].astype(dtype))
            x = attn.mla_apply(p["mixer"], x, cfg)
    elif spec.mixer == "ssd":
        x, (state, tail) = ssm_mod.ssd_apply(p["mixer"], x, cfg, return_state=True)
        c = {
            "state": state,
            "conv_x": tail["x"].astype(dtype),
            "conv_B": tail["B"].astype(dtype),
            "conv_C": tail["C"].astype(dtype),
        }
    elif spec.mixer == "rglru":
        x, (hstate, tail) = rglru_mod.rglru_apply(p["mixer"], x, cfg, return_state=True)
        c = {"h": hstate, "conv": tail.astype(dtype)}
    elif spec.mixer == "cross_attn":
        x = attn.cross_apply(p["mixer"], x, img, cfg)
        c = {}
    aux = jnp.zeros((), jnp.float32)
    if spec.ffn:
        if spec.moe:
            x, aux = moe_mod.moe_apply(p["ffn"], x, cfg, return_aux=True)
        else:
            x = mlp_mod.mlp_apply(p["ffn"], x, cfg)
    del aux
    return x, c


def prefill(cfg: ModelConfig, params, batch, S_cache: int | None = None):
    """Process the prompt; returns (last-token logits (B,V), cache)."""
    x, img = _embed_inputs(cfg, params, batch)
    B, S, _ = x.shape
    S_cache = S_cache or S

    new_prefix = []
    for spec, p in zip(cfg.prefix, params["prefix"]):
        x, c = _prefill_layer(p, x, cfg, spec, S_cache, img=img)
        new_prefix.append(c)

    new_pattern = []
    if cfg.n_periods:
        def period_body(h, period_params):
            cs = []
            for i, spec in enumerate(cfg.pattern):
                h, c = _prefill_layer(period_params[i], h, cfg, spec, S_cache, img=img)
                cs.append(c)
            h = constrain(h, ("batch", "seq", "act_embed"))
            return h, cs

        x, new_pattern = maybe_scan(
            _remat(period_body, cfg) if cfg.remat != "none" else period_body,
            x,
            params["pattern"],
        )

    new_suffix = []
    for spec, p in zip(cfg.suffix, params["suffix"]):
        x, c = _prefill_layer(p, x, cfg, spec, S_cache, img=img)
        new_suffix.append(c)

    x = rms_norm(x, params["final_ln"], cfg.norm_eps)
    logits = (x[:, -1] @ _head_weight(cfg, params)).astype(jnp.float32)
    cache = {"prefix": new_prefix, "pattern": new_pattern, "suffix": new_suffix}
    return logits, cache
