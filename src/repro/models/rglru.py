"""RG-LRU recurrent block (Griffin / RecurrentGemma).

Block: x -> [W_main -> causal conv -> RG-LRU] ⊙ GeLU(W_gate x) -> W_out.
RG-LRU: r_t = σ(W_a u_t), i_t = σ(W_x u_t),
        log a_t = -c · softplus(Λ) · r_t,
        h_t = a_t h_{t-1} + √(1 − a_t²) · (i_t ⊙ u_t).

Training uses an associative scan over (a_t, b_t) pairs — O(S log S) work,
O(1)-state decode; this is why recurrentgemma runs the long_500k cell.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from ..configs.base import ModelConfig
from .common import PSpec, constrain, rms_norm


def rglru_specs(cfg: ModelConfig) -> dict:
    D = cfg.d_model
    R = cfg.d_rnn
    W = cfg.rglru.conv_width
    return {
        "ln": PSpec((D,), ("embed",), "zeros"),
        "w_main": PSpec((D, R), ("embed", "rnn")),
        "w_gate": PSpec((D, R), ("embed", "rnn")),
        "conv_w": PSpec((W, R), ("conv", "rnn")),
        "conv_b": PSpec((R,), ("rnn",), "zeros"),
        "rg_wa": PSpec((R, R), ("rnn", None)),
        "rg_ba": PSpec((R,), (None,), "zeros"),
        "rg_wx": PSpec((R, R), ("rnn", None)),
        "rg_bx": PSpec((R,), (None,), "zeros"),
        "lam": PSpec((R,), (None,), "rglru_lambda", jnp.float32),
        "w_out": PSpec((R, D), ("rnn", "embed")),
    }


def _causal_conv(x, w, b):
    W = w.shape[0]
    out = jnp.zeros_like(x)
    for i in range(W):
        shift = W - 1 - i
        xi = jnp.pad(x, ((0, 0), (shift, 0), (0, 0)))[:, : x.shape[1]]
        out = out + xi * w[i]
    return out + b


def _gates(p, u, cfg: ModelConfig):
    """u (..., R) -> (log_a, scaled_input) in f32."""
    r = jax.nn.sigmoid((u @ p["rg_wa"]).astype(jnp.float32) + p["rg_ba"])
    i = jax.nn.sigmoid((u @ p["rg_wx"]).astype(jnp.float32) + p["rg_bx"])
    log_a = -cfg.rglru.c * jax.nn.softplus(p["lam"]) * r
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12))
    b = beta * (i * u.astype(jnp.float32))
    return a, b


def rglru_apply(p, x, cfg: ModelConfig, *, return_state=False, state0=None):
    """Full-sequence Griffin recurrent block.  x (B, S, D)."""
    B, S, D = x.shape
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    u = _causal_conv(h @ p["w_main"], p["conv_w"], p["conv_b"])
    u = constrain(u, ("batch", "seq", "act_ff"))
    gate = jax.nn.gelu(h @ p["w_gate"])

    a, b = _gates(p, u, cfg)  # (B,S,R) f32
    if state0 is not None:
        b = b.at[:, 0].add(a[:, 0] * state0)

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, a2 * b1 + b2

    _, hseq = lax.associative_scan(combine, (a, b), axis=1)
    y = (hseq.astype(x.dtype) * gate) @ p["w_out"]
    out = x + constrain(y, ("batch", "seq", "act_embed"))
    if return_state:
        conv_tail = (h @ p["w_main"])[:, -(cfg.rglru.conv_width - 1):]
        return out, (hseq[:, -1], conv_tail)
    return out


def rglru_init_cache(cfg: ModelConfig, B: int, dtype):
    R, W = cfg.d_rnn, cfg.rglru.conv_width
    return {
        "h": jnp.zeros((B, R), jnp.float32),
        "conv": jnp.zeros((B, W - 1, R), dtype),
    }


def rglru_cache_axes():
    return {"h": ("batch", "rnn"), "conv": ("batch", "conv", "rnn")}


def rglru_decode(p, x, cache, step, cfg: ModelConfig):
    """One-token recurrent update.  x (B, D)."""
    B, D = x.shape
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    pre = h @ p["w_main"]
    buf = jnp.concatenate([cache["conv"], pre[:, None]], axis=1)
    u = jnp.einsum("bwc,wc->bc", buf, p["conv_w"]) + p["conv_b"]
    gate = jax.nn.gelu(h @ p["w_gate"])

    a, b = _gates(p, u, cfg)
    h_new = a * cache["h"] + b
    y = (h_new.astype(x.dtype) * gate) @ p["w_out"]
    return x + y, {"h": h_new, "conv": buf[:, 1:]}
