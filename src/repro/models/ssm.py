"""Mamba2 (SSD — state-space duality) mixer.

Train/prefill uses the chunked dual form: quadratic attention-like matmuls
*within* chunks (MXU-friendly) and a parallel associative scan over chunk
states — O(S·l) total instead of O(S²), which is what makes the 500k-token
cell lowerable.  Decode carries the (B, H, P, N) recurrent state and a
width-(w-1) conv tail — O(1) per token, no KV cache at all.

Validated against a naive sequential recurrence oracle in tests.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from ..configs.base import ModelConfig, SSMConfig
from .common import PSpec, constrain, rms_norm


def _dims(cfg: ModelConfig):
    s: SSMConfig = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = d_inner // s.head_dim
    return s, d_inner, n_heads


def ssd_specs(cfg: ModelConfig) -> dict:
    s, d_inner, nh = _dims(cfg)
    D, N, W = cfg.d_model, s.d_state, s.d_conv
    return {
        "ln": PSpec((D,), ("embed",), "zeros"),
        "w_z": PSpec((D, d_inner), ("embed", "inner")),
        "w_x": PSpec((D, d_inner), ("embed", "inner")),
        "w_B": PSpec((D, N), ("embed", "state")),
        "w_C": PSpec((D, N), ("embed", "state")),
        "w_dt": PSpec((D, nh), ("embed", None)),
        "conv_x": PSpec((W, d_inner), ("conv", "inner")),
        "conv_B": PSpec((W, N), ("conv", "state")),
        "conv_C": PSpec((W, N), ("conv", "state")),
        "conv_b_x": PSpec((d_inner,), ("inner",), "zeros"),
        "conv_b_B": PSpec((N,), ("state",), "zeros"),
        "conv_b_C": PSpec((N,), ("state",), "zeros"),
        "A_log": PSpec((nh,), (None,), "ssm_a_log", jnp.float32),
        "D_skip": PSpec((nh,), (None,), "ones", jnp.float32),
        "dt_bias": PSpec((nh,), (None,), "ssm_dt_bias", jnp.float32),
        "norm": PSpec((d_inner,), ("inner",), "zeros"),
        "w_out": PSpec((d_inner, D), ("inner", "embed")),
    }


def _causal_conv(x, w, b):
    """Depthwise causal conv along seq: x (B,S,C), w (W,C)."""
    W = w.shape[0]
    out = jnp.zeros_like(x)
    for i in range(W):
        shift = W - 1 - i
        xi = jnp.pad(x, ((0, 0), (shift, 0), (0, 0)))[:, : x.shape[1]]
        out = out + xi * w[i]
    return jax.nn.silu(out + b)


def _segsum(dA):
    """dA (..., l) -> (..., l, l): sum_{j<k<=i} dA_k, -inf above diagonal."""
    l = dA.shape[-1]
    cs = jnp.cumsum(dA, -1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((l, l), bool))
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x, dt, A, B, C, chunk: int, h0=None):
    """SSD dual form.

    x (b,s,h,p)·dt-discretized inputs; dt (b,s,h); A (h,) negative;
    B, C (b,s,n) shared across heads (n_groups=1).
    Returns y (b,s,h,p) and the final state (b,h,p,n).
    """
    b, s, h, p = x.shape
    n = B.shape[-1]
    l = min(chunk, s)
    while s % l:
        l -= 1
    nc = s // l

    x_dt = x * dt[..., None]  # (b,s,h,p)
    dA = dt * A  # (b,s,h)

    xc = x_dt.reshape(b, nc, l, h, p)
    dAc = dA.reshape(b, nc, l, h)
    Bc = B.reshape(b, nc, l, n)
    Cc = C.reshape(b, nc, l, n)

    # ---- intra-chunk (quadratic within chunk) ----
    L = jnp.exp(_segsum(jnp.moveaxis(dAc, -1, -2)))  # (b,nc,h,l,l)
    G = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)  # (b,nc,l,l)
    M = G[:, :, None] * L  # (b,nc,h,l,l)
    y_diag = jnp.einsum("bchij,bcjhp->bcihp", M, xc)

    # ---- chunk states ----
    dA_cum = jnp.cumsum(dAc, axis=2)  # (b,nc,l,h)
    total = dA_cum[:, :, -1:]  # (b,nc,1,h)
    decay_out = jnp.exp(total - dA_cum)  # decay from pos i to chunk end
    states = jnp.einsum("bcln,bclh,bclhp->bchpn", Bc, decay_out, xc)

    # ---- inter-chunk associative scan: H_{c+1} = e^{total_c} H_c + S_c ----
    chunk_decay = jnp.exp(total[:, :, 0])  # (b,nc,h)
    if h0 is None:
        h0 = jnp.zeros((b, h, p, n), states.dtype)

    def combine(e1, e2):
        a1, s1 = e1
        a2, s2 = e2
        return a1 * a2, a2[..., None, None] * s1 + s2

    a_all, s_all = lax.associative_scan(
        combine, (jnp.moveaxis(chunk_decay, 1, 0), jnp.moveaxis(states, 1, 0))
    )
    # state *entering* chunk c = scanned value of chunk c-1 (shift by one)
    h_final = a_all[-1][..., None, None] * h0 + s_all[-1]
    s_in = jnp.concatenate(
        [h0[None], a_all[:-1, ..., None, None] * h0[None] + s_all[:-1]], axis=0
    )
    s_in = jnp.moveaxis(s_in, 0, 1)  # (b,nc,h,p,n)

    # ---- off-diagonal: contribution of the entering state ----
    decay_in = jnp.exp(dA_cum)  # decay from chunk start to pos i
    y_off = jnp.einsum("bcln,bchpn,bclh->bclhp", Cc, s_in, decay_in)

    y = (y_diag + y_off).reshape(b, s, h, p)
    return y, h_final


def ssd_apply(p, x, cfg: ModelConfig, *, return_state: bool = False, h0=None):
    """Full-sequence Mamba2 block (pre-norm, residual)."""
    s_cfg, d_inner, nh = _dims(cfg)
    B_, S, D = x.shape
    h = rms_norm(x, p["ln"], cfg.norm_eps)

    z = h @ p["w_z"]
    xin = _causal_conv(h @ p["w_x"], p["conv_x"], p["conv_b_x"])
    Bv = _causal_conv(h @ p["w_B"], p["conv_B"], p["conv_b_B"])
    Cv = _causal_conv(h @ p["w_C"], p["conv_C"], p["conv_b_C"])
    dt = jax.nn.softplus(
        (h @ p["w_dt"]).astype(jnp.float32) + p["dt_bias"]
    )  # (B,S,nh)
    A = -jnp.exp(p["A_log"])  # (nh,)

    xh = xin.reshape(B_, S, nh, s_cfg.head_dim).astype(jnp.float32)
    xh = constrain(xh, ("batch", "seq", "act_heads", None))
    dt = constrain(dt, ("batch", "seq", "act_heads"))
    y, h_fin = ssd_chunked(
        xh, dt, A, Bv.astype(jnp.float32), Cv.astype(jnp.float32),
        s_cfg.chunk, h0=h0,
    )
    y = y + p["D_skip"][None, None, :, None] * xh
    y = y.reshape(B_, S, d_inner).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = x + constrain(y @ p["w_out"], ("batch", "seq", "act_embed"))
    if return_state:
        conv_tail = {
            "x": (h @ p["w_x"])[:, -(s_cfg.d_conv - 1):],
            "B": (h @ p["w_B"])[:, -(s_cfg.d_conv - 1):],
            "C": (h @ p["w_C"])[:, -(s_cfg.d_conv - 1):],
        }
        return out, (h_fin, conv_tail)
    return out


def ssd_init_cache(cfg: ModelConfig, B: int, dtype):
    s, d_inner, nh = _dims(cfg)
    W = s.d_conv
    return {
        "state": jnp.zeros((B, nh, s.head_dim, s.d_state), jnp.float32),
        "conv_x": jnp.zeros((B, W - 1, d_inner), dtype),
        "conv_B": jnp.zeros((B, W - 1, s.d_state), dtype),
        "conv_C": jnp.zeros((B, W - 1, s.d_state), dtype),
    }


def ssd_cache_axes():
    return {
        "state": ("batch", None, "head_dim", "state"),
        "conv_x": ("batch", "conv", "inner"),
        "conv_B": ("batch", "conv", "state"),
        "conv_C": ("batch", "conv", "state"),
    }


def _conv_step(tail, new, w, b):
    """tail (B, W-1, C) history; new (B, C).  Returns (out, new_tail)."""
    buf = jnp.concatenate([tail, new[:, None]], axis=1)  # (B, W, C)
    out = jax.nn.silu(jnp.einsum("bwc,wc->bc", buf, w) + b)
    return out, buf[:, 1:]


def ssd_decode(p, x, cache, step, cfg: ModelConfig):
    """One-token recurrent update.  x (B, D)."""
    s_cfg, d_inner, nh = _dims(cfg)
    B_, D = x.shape
    h = rms_norm(x, p["ln"], cfg.norm_eps)

    z = h @ p["w_z"]
    xin, t_x = _conv_step(cache["conv_x"], h @ p["w_x"], p["conv_x"], p["conv_b_x"])
    Bv, t_B = _conv_step(cache["conv_B"], h @ p["w_B"], p["conv_B"], p["conv_b_B"])
    Cv, t_C = _conv_step(cache["conv_C"], h @ p["w_C"], p["conv_C"], p["conv_b_C"])
    dt = jax.nn.softplus((h @ p["w_dt"]).astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])

    xh = xin.reshape(B_, nh, s_cfg.head_dim).astype(jnp.float32)
    dA = jnp.exp(dt * A)  # (B, nh)
    state = cache["state"] * dA[..., None, None] + jnp.einsum(
        "bh,bhp,bn->bhpn", dt, xh, Bv.astype(jnp.float32)
    )
    y = jnp.einsum("bhpn,bn->bhp", state, Cv.astype(jnp.float32))
    y = y + p["D_skip"][None, :, None] * xh
    y = y.reshape(B_, d_inner).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    new_cache = {"state": state, "conv_x": t_x, "conv_B": t_B, "conv_C": t_C}
    return x + y @ p["w_out"], new_cache
