"""Dense FFN block (gated-GLU / squared-ReLU variants)."""
from __future__ import annotations

import jax.numpy as jnp

from ..configs.base import ModelConfig
from .common import PSpec, activation, constrain, rms_norm

GATED = {"silu_glu", "gelu_glu"}


def mlp_specs(cfg: ModelConfig, d_ff: int | None = None) -> dict:
    D = cfg.d_model
    F = d_ff or cfg.d_ff
    specs = {
        "ln": PSpec((D,), ("embed",), "zeros"),
        "w_in": PSpec((D, F), ("embed", "mlp")),
        "w_out": PSpec((F, D), ("mlp", "embed")),
    }
    if cfg.act in GATED:
        specs["w_gate"] = PSpec((D, F), ("embed", "mlp"))
    return specs


def mlp_apply(p, x, cfg: ModelConfig):
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    up = h @ p["w_in"]
    up = constrain(up, ("batch", "seq", "act_ff")) if up.ndim == 3 else up
    if cfg.act in GATED:
        act = activation(cfg.act, up, h @ p["w_gate"])
    else:
        act = activation(cfg.act, up)
    out = act @ p["w_out"]
    out = constrain(out, ("batch", "seq", "act_embed")) if out.ndim == 3 else out
    return x + out
