# The host-platform device count must be pinned before ANY jax import —
# jax locks the device topology on first initialization.
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

DOC = """Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell we build three artifacts:

  full  — the production config (all periods, full grad-accumulation):
          ``.lower().compile()`` success proves the sharding is coherent;
          ``memory_analysis()`` proves it fits per-device HBM.
  c1/c2 — 1-period and 2-period reductions (single microbatch): XLA counts
          while-loop bodies once, so per-period costs are obtained by
          differencing (c2 − c1) and scaled analytically:

            total = outer · (base + n_periods · per_period),
            base  = c1 − per_period,   outer = n_micro (train) else 1.

          The same differencing applies to the HLO-parsed collective bytes.

Results land in JSON (one file per cell) consumed by the roofline report.
"""

import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import ModelConfig, ShapeConfig, get_config, get_shape, registry
from ..models import transformer as tfm
from ..models.common import unrolled_scans
from ..optim import AdamWConfig
from ..sharding import OPT_RULES, logical_to_spec, tree_pspecs
from ..train.step import make_train_step, state_pspecs, state_shapes
from .hlo_stats import collective_stats
from .mesh import HW, make_production_mesh

# ---------------------------------------------------------------------------


def dp_size(mesh) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return sizes.get("data", 1) * sizes.get("pod", 1)


def pick_micro(shape: ShapeConfig, mesh) -> int:
    if shape.kind != "train" or not shape.microbatch:
        return 1
    return max(1, min(shape.microbatch, shape.global_batch // dp_size(mesh)))


def _sds(shape, dtype, spec, mesh):
    return jax.ShapeDtypeStruct(
        shape, dtype, sharding=NamedSharding(mesh, spec)
    )


def input_specs(cfg: ModelConfig, shape: ShapeConfig, mesh, batch_size=None):
    """ShapeDtypeStruct stand-ins for the step inputs (no allocation)."""
    B = batch_size or shape.global_batch
    S = shape.seq_len
    bspec = logical_to_spec(("batch", "seq"), mesh, shape=(B, S))
    especs = logical_to_spec(("batch", "seq", "act_embed"), mesh, shape=(B, S, cfg.d_model))
    batch = {}
    if cfg.frontend == "frames":
        batch["embeds"] = _sds((B, S, cfg.d_model), jnp.bfloat16, especs, mesh)
    else:
        batch["tokens"] = _sds((B, S), jnp.int32, bspec, mesh)
    if cfg.frontend == "vision":
        ispec = logical_to_spec(
            ("batch", "patches", "act_embed"), mesh, shape=(B, cfg.n_patches, cfg.d_model)
        )
        batch["image_embeds"] = _sds(
            (B, cfg.n_patches, cfg.d_model), jnp.bfloat16, ispec, mesh
        )
    if shape.kind == "train":
        batch["labels"] = _sds((B, S), jnp.int32, bspec, mesh)
    return batch


def _sharded_shapes(tree_shapes, tree_axes, mesh):
    pspecs = tree_pspecs(tree_axes, mesh, shapes_tree=tree_shapes)
    return jax.tree.map(
        lambda sds, spec: jax.ShapeDtypeStruct(
            sds.shape, sds.dtype, sharding=NamedSharding(mesh, spec)
        ),
        tree_shapes,
        pspecs,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
    )


def build_lowerable(cfg: ModelConfig, shape: ShapeConfig, mesh, n_micro: int):
    """Returns (jitted_fn, example_args_SDS) for this cell."""
    if shape.kind == "train":
        step_fn = make_train_step(cfg, AdamWConfig(), n_micro=n_micro)
        sshapes = state_shapes(cfg)
        saxes = jax.tree.map(lambda _: None, sshapes)  # placeholder
        # params/opt sharded by logical axes; step replicated
        axes = tfm.params_axes(cfg)
        pshapes = tfm.params_shapes(cfg)
        pspecs = tree_pspecs(axes, mesh, shapes_tree=pshapes)
        shard = lambda tree: jax.tree.map(
            lambda sds, spec: jax.ShapeDtypeStruct(
                sds.shape, sds.dtype, sharding=NamedSharding(mesh, spec)
            ),
            tree,
            pspecs,
            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
        )
        params_s = shard(pshapes)
        ospecs = tree_pspecs(axes, mesh, OPT_RULES, shapes_tree=pshapes)
        from ..models.common import DTYPES

        def opt_sds(dtype):
            return jax.tree.map(
                lambda sds, spec: jax.ShapeDtypeStruct(
                    sds.shape, dtype, sharding=NamedSharding(mesh, spec)
                ),
                pshapes, ospecs,
                is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
            )

        mdt = DTYPES[cfg.opt_moments_dtype]
        state = {
            "step": jax.ShapeDtypeStruct((), jnp.int32),
            "params": params_s,
            "opt": {"master": opt_sds(jnp.float32), "m": opt_sds(mdt), "v": opt_sds(mdt)},
        }
        from ..train.step import TrainState

        state = TrainState(step=state["step"], params=state["params"], opt=state["opt"])
        batch = input_specs(cfg, shape, mesh)
        fn = jax.jit(step_fn, donate_argnums=(0,))
        return fn, (state, batch)

    if shape.kind == "prefill":
        def prefill_fn(params, batch):
            return tfm.prefill(cfg, params, batch)

        pshapes = tfm.params_shapes(cfg)
        params_s = _sharded_shapes(pshapes, tfm.params_axes(cfg), mesh)
        batch = input_specs(cfg, shape, mesh)
        return jax.jit(prefill_fn), (params_s, batch)

    # decode
    def decode_fn(params, cache, tokens, step, embeds, img):
        return tfm.decode_step(
            cfg, params, cache, tokens, step, embeds=embeds, img=img
        )

    B, S = shape.global_batch, shape.seq_len
    pshapes = tfm.params_shapes(cfg)
    params_s = _sharded_shapes(pshapes, tfm.params_axes(cfg), mesh)
    cshapes = tfm.cache_shapes(cfg, B, S)
    caxes = tfm.cache_axes(cfg)
    cache_s = _sharded_shapes(cshapes, caxes, mesh)
    bspec = logical_to_spec(("batch",), mesh, shape=(B,))
    tokens = _sds((B,), jnp.int32, bspec, mesh)
    step = jax.ShapeDtypeStruct((), jnp.int32)
    embeds = (
        _sds((B, cfg.d_model), jnp.bfloat16,
             logical_to_spec(("batch", "act_embed"), mesh, shape=(B, cfg.d_model)), mesh)
        if cfg.frontend == "frames" else None
    )
    img = (
        _sds((B, cfg.n_patches, cfg.d_model), jnp.bfloat16,
             logical_to_spec(("batch", "patches", "act_embed"), mesh,
                             shape=(B, cfg.n_patches, cfg.d_model)), mesh)
        if cfg.frontend == "vision" else None
    )
    return jax.jit(decode_fn, donate_argnums=(1,)), (
        params_s, cache_s, tokens, step, embeds, img,
    )


def _compile_cell(cfg, shape, mesh, n_micro):
    fn, args = build_lowerable(cfg, shape, mesh, n_micro)
    t0 = time.time()
    with mesh:
        lowered = fn.lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
    ca = compiled.cost_analysis() or {}
    ma = compiled.memory_analysis()
    try:
        text = compiled.as_text()
    except Exception:
        text = lowered.as_text()
    coll = collective_stats(text)
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
        "coll_bytes": coll["total_bytes"],
        "coll_by_kind": coll["by_kind"],
        "t_lower_s": t_lower,
        "t_compile_s": t_compile,
        "memory": None
        if ma is None
        else {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
        },
    }


def n_params(cfg: ModelConfig) -> tuple[float, float]:
    """(total, active) parameter counts from the spec tree."""
    shapes = jax.tree.leaves(tfm.params_shapes(cfg))
    total = sum(float(np.prod(s.shape)) for s in shapes)
    active = total
    if cfg.moe is not None:
        m = cfg.moe
        # routed-expert params activated: top_k of n_experts
        expert = 0.0
        for path, s in jax.tree_util.tree_flatten_with_path(tfm.params_shapes(cfg))[0]:
            kp = jax.tree_util.keystr(path)
            if "w_in" in kp or "w_out" in kp or "w_gate" in kp:
                if "'ffn'" in kp and f"{m.n_experts}" in str(s.shape):
                    expert += float(np.prod(s.shape))
        active = total - expert * (1 - m.top_k / m.n_experts)
    return total, active


def _window_max(cfg: ModelConfig) -> int:
    w = 0
    for spec in cfg.prefix + cfg.pattern + cfg.suffix:
        if spec.mixer == "attn" and spec.window:
            w = max(w, spec.window)
    return w


def _cost_compile(cfg, shape, mesh):
    with unrolled_scans():
        return _compile_cell(cfg, shape, mesh, 1)


def _derive_costs(cfg, shape, mesh, n_micro, rec):
    keys = ("flops", "bytes", "coll_bytes")

    if shape.kind == "decode":
        c1 = _cost_compile(cfg.replace(n_periods=1), shape, mesh)
        c2 = _cost_compile(cfg.replace(n_periods=2), shape, mesh)
        rec["cost_artifacts"] = {"c1": c1, "c2": c2}
        out = {}
        for k in keys:
            per = max(c2[k] - c1[k], 0.0)
            base = max(c1[k] - per, 0.0)
            out[k] = base + cfg.n_periods * per
            out[f"{k}_per_period"] = per
            out[f"{k}_base"] = base
        return out

    # train / prefill: two sequence lengths, minimal batch, linear B scaling
    S = shape.seq_len
    w = _window_max(cfg)
    S_a = min(max(2048, 2 * w), S)
    S_b = min(2 * S_a, S)
    if S_b == S_a:
        S_a = max(S_b // 2, 512)

    if shape.kind == "train":
        B_full = shape.global_batch // n_micro  # per-microbatch tokens
        outer = n_micro
    else:
        B_full = shape.global_batch
        outer = 1
    B_cost = max(dp_size(mesh), 1)
    while B_full % B_cost:
        B_cost += 1
    b_scale = B_full / B_cost

    pts = {}
    arts = {}
    for S_c in sorted({S_a, S_b}):
        cost_shape = dataclasses.replace(
            shape, seq_len=S_c, global_batch=B_cost, microbatch=1
        )
        p1 = _cost_compile(cfg.replace(n_periods=1), cost_shape, mesh)
        p2 = _cost_compile(cfg.replace(n_periods=2), cost_shape, mesh)
        arts[f"S{S_c}"] = {"c1": p1, "c2": p2}
        pts[S_c] = (p1, p2)
    rec["cost_artifacts"] = arts
    rec["cost_fit"] = {"S_a": S_a, "S_b": S_b, "B_cost": B_cost, "b_scale": b_scale}

    out = {}
    for k in keys:
        def fit(vals):  # vals: {S: v}; v(S) = alpha*S + beta*S^2
            (s1, v1), (s2, v2) = sorted(vals.items())
            det = s1 * s2 * s2 - s2 * s1 * s1
            beta = (v2 * s1 - v1 * s2) / det
            alpha = (v1 - beta * s1 * s1) / s1
            return alpha * S + beta * S * S

        per_v = {s_c: max(p2[k] - p1[k], 0.0) for s_c, (p1, p2) in pts.items()}
        base_v = {
            s_c: max(p1[k] - max(p2[k] - p1[k], 0.0), 0.0)
            for s_c, (p1, p2) in pts.items()
        }
        per_full = max(fit(per_v), 0.0)
        base_full = max(fit(base_v), 0.0)
        out[k] = outer * b_scale * (base_full + cfg.n_periods * per_full)
        out[f"{k}_per_period"] = b_scale * per_full
        out[f"{k}_base"] = b_scale * base_full
    return out


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: str,
             force=False, overrides=None, micro=None):
    mesh_name = "multi" if multi_pod else "single"
    out_path = os.path.join(out_dir, mesh_name, f"{arch}__{shape_name}.json")
    if os.path.exists(out_path) and not force:
        print(f"[skip] {out_path} exists")
        return json.load(open(out_path))
    os.makedirs(os.path.dirname(out_path), exist_ok=True)

    cfg = get_config(arch)
    if overrides:
        typed = {}
        for k, v in overrides.items():
            cur = getattr(cfg, k)
            typed[k] = type(cur)(v) if cur is not None and not isinstance(cur, str) else v
        cfg = cfg.replace(**typed)
    shape = get_shape(shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_micro = micro if micro else pick_micro(shape, mesh)
    n_chips = int(np.prod(mesh.devices.shape))
    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "chips": n_chips,
        "n_micro": n_micro,
        "n_layers": cfg.n_layers,
        "overrides": overrides or {},
        "status": "error",
    }
    try:
        # ---- full artifact: compile proof + memory ----
        full = _compile_cell(cfg, shape, mesh, n_micro)
        rec["full"] = full
        print(f"[{arch}/{shape_name}/{mesh_name}] full compile OK "
              f"({full['t_compile_s']:.1f}s) mem={full['memory']}")

        # ---- cost artifacts (single-pod only; roofline table is single-pod).
        # XLA's cost analysis counts while bodies once, so cost artifacts run
        # with every scan UNROLLED.  Per-period costs come from 1-vs-2-period
        # differencing; train/prefill costs are measured at two sequence
        # lengths and reconstructed as per_period(S) = α·S + β·S² (exact for
        # the op mix we emit: attention quadratic + everything-else linear;
        # S_a is chosen above 2·window so windowed attention sits in its
        # linear regime).  Batch scales exactly linearly (no cross-batch
        # ops), so cost artifacts run at the minimal shardable batch.
        if multi_pod:
            rec["roofline"] = None
            rec["status"] = "ok"
            with open(out_path, "w") as f:
                json.dump(rec, f, indent=1, default=str)
            return rec

        derived = _derive_costs(cfg, shape, mesh, n_micro, rec)
        rec["derived"] = derived

        # ---- roofline terms (per chip; cost_analysis is per-program =
        #      per-device for SPMD modules) ----
        total, active = n_params(cfg)
        tokens = shape.global_batch * shape.seq_len if shape.kind == "train" else (
            shape.global_batch * shape.seq_len if shape.kind == "prefill"
            else shape.global_batch
        )
        model_flops = (6.0 if shape.kind == "train" else 2.0) * active * tokens
        t_comp = derived["flops"] / HW["peak_flops_bf16"]
        t_mem = derived["bytes"] / HW["hbm_bw"]
        # 2D/3D torus: ~3 usable link pairs per chip on v5e -> treat the
        # per-chip ICI budget as 3 links x 50 GB/s aggregated.
        t_coll = derived["coll_bytes"] / (3 * HW["ici_bw"])
        rec["roofline"] = {
            "params_total": total,
            "params_active": active,
            "model_flops_global": model_flops,
            "model_flops_per_chip": model_flops / n_chips,
            "hlo_flops_per_chip": derived["flops"],
            "useful_flops_ratio": (model_flops / n_chips) / max(derived["flops"], 1.0),
            "t_compute_s": t_comp,
            "t_memory_s": t_mem,
            "t_collective_s": t_coll,
            "bottleneck": max(
                [("compute", t_comp), ("memory", t_mem), ("collective", t_coll)],
                key=lambda kv: kv[1],
            )[0],
        }
        rec["status"] = "ok"
    except Exception as e:
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()
        print(f"[{arch}/{shape_name}/{mesh_name}] FAILED: {rec['error']}")
    with open(out_path, "w") as f:
        json.dump(rec, f, indent=1, default=str)
    return rec


def main():
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="single")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--override", action="append", default=[],
                    help="ModelConfig overrides, e.g. moe_impl=shard_map")
    ap.add_argument("--micro", type=int, default=None,
                    help="override gradient-accumulation microbatch count")
    args = ap.parse_args()
    overrides = dict(kv.split("=", 1) for kv in args.override)

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    if args.all:
        cells = registry.all_cells()
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    failures = 0
    for mesh_name in meshes:
        for arch, shape in cells:
            rec = run_cell(arch, shape, mesh_name == "multi", args.out,
                           args.force, overrides, micro=args.micro)
            failures += rec["status"] != "ok"
    print(f"done; {failures} failures")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
