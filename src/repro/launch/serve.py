"""Serving launcher: batched prefill + greedy decode for any --arch.

    python -m repro.launch.serve --arch mixtral-8x7b --smoke --batch 4
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from ..configs import get_config, smoke_config
from ..models import init_params
from ..train.serve import generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if cfg.frontend != "token":
        raise SystemExit(f"{args.arch}: stub frontend — serve a token arch")
    params = init_params(cfg, jax.random.key(0))
    prompts = jax.random.randint(
        jax.random.key(1), (args.batch, args.prompt_len), 0, cfg.vocab
    )
    out = generate(cfg, params, prompts, max_new=args.max_new)
    for i in range(args.batch):
        print(f"[{i}] {' '.join(map(str, out[i].tolist()))}")
    print(f"served batch={args.batch} prompt={args.prompt_len} new={args.max_new}")


if __name__ == "__main__":
    main()
