"""Parse compiled HLO text for per-device collective traffic.

``cost_analysis()`` does not report collective bytes, so we walk the
optimized HLO and apply ring-algorithm byte formulas per op.  In optimized
HLO operands are name references (no inline shapes), so all formulas are
**result-shape based**:

  all-reduce          2·B_res·(P−1)/P      (result == operand size)
  all-gather          B_res·(P−1)/P        (result is the gathered array)
  reduce-scatter      B_res·(P−1)          (operand = result·P)
  all-to-all          B_res·(P−1)/P
  collective-permute  B_res

Group size P comes from ``replica_groups=[G,P]<=[...]`` (iota form) or an
explicit group list.  Tuple-shaped results (async -start forms) use the
largest element (the output buffer); equal-sized tuple elements (variadic
all-reduce) are summed.

NOTE: while-loop bodies appear once in HLO text — the dry-run avoids loops
in cost artifacts entirely (scans unrolled) and scales analytically.
"""
from __future__ import annotations

import re
from collections import defaultdict

__all__ = ["collective_stats", "parse_shape_bytes"]

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"=\s*(?P<res>\([^)]*\)|[a-z0-9]+\[[^\]]*\]\S*)\s*"
    r"(?P<kind>all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?P<start>-start)?\("
)
_DONE_RE = re.compile(r"-(done|update)\(")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes_list(text: str) -> list[int]:
    out = []
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        out.append(n * _DTYPE_BYTES[dtype])
    return out


def parse_shape_bytes(text: str) -> int:
    return sum(_shape_bytes_list(text))


def _group_size(line: str) -> int:
    m = _IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 2  # conservative default


def collective_stats(hlo_text: str) -> dict:
    """Returns {'total_bytes', 'by_kind': {kind: {'count','bytes'}}}.

    Bytes are per-device ICI traffic estimates under ring algorithms.
    """
    by_kind: dict = defaultdict(lambda: {"count": 0, "bytes": 0.0})
    total = 0.0
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        kind = m.group("kind")
        p = _group_size(line)
        frac = (p - 1) / p if p > 1 else 0.0
        shapes = _shape_bytes_list(m.group("res"))
        if not shapes:
            continue
        if len(shapes) == 1:
            b_res = shapes[0]
        elif len(set(shapes)) == 1:
            b_res = sum(shapes)  # variadic: tuple of equal tensors
        else:
            b_res = max(shapes)  # -start form: (input, output) buffers

        if kind == "all-reduce":
            b = 2.0 * b_res * frac
        elif kind == "all-gather":
            b = b_res * frac
        elif kind == "reduce-scatter":
            b = b_res * (p - 1)
        elif kind == "all-to-all":
            b = b_res * frac
        else:  # collective-permute
            b = float(b_res)
        by_kind[kind]["count"] += 1
        by_kind[kind]["bytes"] += b
        total += b
    return {"total_bytes": total, "by_kind": dict(by_kind)}
