"""Production meshes.

Defined as functions (not module constants) so importing never touches jax
device state.  Production target: TPU v5e pods — 16×16 = 256 chips per pod,
2 pods = 512 chips for the multi-pod dry-run.
"""
from __future__ import annotations

import jax
import numpy as np

__all__ = ["make_production_mesh", "make_mesh", "HW"]


# v5e hardware constants for the roofline model.
HW = {
    "peak_flops_bf16": 197e12,  # per chip
    "hbm_bw": 819e9,  # bytes/s per chip
    "ici_bw": 50e9,  # bytes/s per link (~per direction)
    "hbm_bytes": 16e9,  # capacity per chip
}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_mesh(shape, axes):
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devices)} "
            "(dry-run must set XLA_FLAGS=--xla_force_host_platform_device_count "
            "before any jax import)"
        )
    dev = np.asarray(devices[:n]).reshape(shape)
    return jax.sharding.Mesh(dev, axes)
