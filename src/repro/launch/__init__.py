"""Launchers: production meshes, the multi-pod dry-run, roofline report,
and train/serve entry points."""
from .hlo_stats import collective_stats, parse_shape_bytes
from .mesh import HW, make_mesh, make_production_mesh

__all__ = ["collective_stats", "parse_shape_bytes", "HW", "make_mesh",
           "make_production_mesh"]
