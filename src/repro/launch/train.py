"""Production training launcher.

    python -m repro.launch.train --arch qwen3-0.6b --smoke \\
        --mesh 1x1 --steps 50 --ckpt /tmp/ck

On real fleets: one process per host, jax.distributed.initialize() picks
up the pod topology, ``--mesh 16x16`` / ``--mesh 2x16x16`` selects the
production mesh; elastic restart = same command after rescheduling (the
checkpoint restores onto whatever mesh the surviving slice supports, see
repro.train.elastic).  On this CPU container use --smoke + a 1x1/2x2 mesh
with XLA_FLAGS device forcing.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from ..configs import get_config, smoke_config
from ..data import SyntheticConfig, batch_at
from ..optim import AdamWConfig
from ..sharding import logical_to_spec
from ..train import checkpoint as ckpt_lib
from ..train.elastic import restore_elastic
from ..train.step import batch_pspec, init_train_state, jit_train_step, state_pspecs
from .mesh import make_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", help="reduced config")
    ap.add_argument("--mesh", default="1x1", help="e.g. 16x16 or 2x16x16")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--micro", type=int, default=1)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--lr", type=float, default=3e-3)
    args = ap.parse_args()

    dims = tuple(int(d) for d in args.mesh.split("x"))
    names = ("pod", "data", "model")[-len(dims):] if len(dims) > 1 else ("data",)
    mesh = make_mesh(dims, names)

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    dcfg = SyntheticConfig(vocab=cfg.vocab, seq_len=args.seq,
                           global_batch=args.batch, kind="bigram")
    ocfg = AdamWConfig(lr=args.lr, warmup_steps=10, total_steps=args.steps)

    start = 0
    if args.ckpt and ckpt_lib.latest_step(args.ckpt) is not None:
        state, start = restore_elastic(args.ckpt, cfg, mesh)
        print(f"[resume] step {start} onto mesh {dims}")
    else:
        state = init_train_state(cfg, jax.random.key(0))
        sspec = state_pspecs(cfg, mesh)
        state = jax.tree.map(
            lambda x, s: jax.device_put(x, NamedSharding(mesh, s)),
            state, sspec,
            is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec),
        )

    step_fn = jit_train_step(cfg, ocfg, mesh, n_micro=args.micro)
    writer = ckpt_lib.AsyncCheckpointer(args.ckpt) if args.ckpt else None
    bspec = NamedSharding(mesh, batch_pspec(mesh))
    with mesh:
        for step in range(start, args.steps):
            batch = jax.tree.map(lambda x: jax.device_put(x, bspec), batch_at(dcfg, step))
            state, metrics = step_fn(state, batch)
            if (step + 1) % 10 == 0 or step + 1 == args.steps:
                print(f"step {step+1:5d} loss {float(metrics['loss']):.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f}")
            if writer and (step + 1) % args.ckpt_every == 0:
                writer.submit(step + 1, state)
    if writer:
        writer.submit(args.steps, state)
        writer.finalize()
    print("done")


if __name__ == "__main__":
    main()
