"""Sketched gradient compression for data-parallel training.

The paper's CountSketch operator applied to the framework's own
collective bottleneck: instead of all-reducing full gradients over the DP
axis, each worker sketches large gradient tensors into a fixed s-bucket
space (CountSketch is linear, so psum-of-sketches == sketch-of-psum),
all-reduces the sketches, and unsketches with the transpose (SᵀS has unit
diagonal; E[SᵀSx] = x).  The unsketch error is kept *local* via standard
error feedback (the residual is added to the next step's gradient), so
compression changes the optimization trajectory only transiently.

Collective-bytes reduction: ratio = numel / sketch_size per tensor.
Small tensors (norms, biases) bypass compression.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["CompressionConfig", "compress_state_init", "sketched_psum_grads"]


class CompressionConfig(NamedTuple):
    ratio: int = 8  # sketch_size = numel // ratio
    min_size: int = 65536  # tensors smaller than this go uncompressed
    error_feedback: bool = True
    seed: int = 17


def _buckets_signs(key, numel, s):
    kb, ks = jax.random.split(key)
    buckets = jax.random.randint(kb, (numel,), 0, s, dtype=jnp.int32)
    signs = jax.random.rademacher(ks, (numel,), jnp.float32)
    return buckets, signs


def compress_state_init(cfg: CompressionConfig, params):
    """Error-feedback residual buffers (zeros, like-sharded with params)."""
    def init(p):
        if p.size < cfg.min_size:
            return None
        return jnp.zeros(p.shape, jnp.float32)

    return jax.tree.map(init, params)


def sketched_psum_grads(
    cfg: CompressionConfig,
    grads,
    ef_state,
    axis_names,
    step=0,
):
    """psum gradients over ``axis_names`` with CountSketch compression.

    Must be called inside shard_map/pmap context where ``axis_names`` are
    bound.  Returns (avg_grads, new_ef_state).

    ``step`` MUST vary per call (fresh sketch per step).

    The applied reconstruction is **SᵀS(g+e)/ratio**: the raw unsketch is
    unbiased but has ‖x − SᵀSx‖ ≈ √(ratio−1)·‖x‖ > ‖x‖ — NOT a
    contraction, so error feedback amplifies geometrically (measured:
    ‖e‖² → 1e8 in 12 steps).  Scaling by 1/ratio gives
    ‖x − C(x)‖² ≈ (1 − 1/ratio)·‖x‖² — contractive with δ = 1/ratio, the
    standard EF treatment of unbiased high-variance compressors; the
    1/ratio gain is recovered over ~ratio steps through the feedback.
    """
    n_dev = 1
    for ax in axis_names:
        # jax.lax.axis_size is newer-JAX only; psum(1, ax) is equivalent
        # (and constant-folded) on every version.
        if hasattr(jax.lax, "axis_size"):
            n_dev *= jax.lax.axis_size(ax)
        else:
            n_dev *= jax.lax.psum(1, ax)

    flat, treedef = jax.tree.flatten(grads)
    flat_ef = treedef.flatten_up_to(ef_state) if ef_state is not None else [None] * len(flat)
    out, out_ef = [], []
    for i, (g, ef) in enumerate(zip(flat, flat_ef)):
        if g.size < cfg.min_size:
            out.append(jax.lax.psum(g, axis_names) / n_dev)
            out_ef.append(ef)
            continue
        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.key(cfg.seed), i), step
        )
        numel = g.size
        s = max(numel // cfg.ratio, 1)
        buckets, signs = _buckets_signs(key, numel, s)

        gf = g.astype(jnp.float32).reshape(-1)
        if cfg.error_feedback and ef is not None:
            gf = gf + ef.reshape(-1)
        sk = jax.ops.segment_sum(signs * gf, buckets, num_segments=s)
        sk_global = jax.lax.psum(sk, axis_names) / n_dev
        recon = (signs * sk_global[buckets]).astype(jnp.float32) / cfg.ratio
        if cfg.error_feedback and ef is not None:
            # local error: my contribution minus what the global recon
            # carries of it (same 1/ratio scaling -> contraction)
            local_recon = (signs * sk[buckets]) / cfg.ratio
            new_ef = (gf - local_recon).reshape(g.shape)
            out_ef.append(new_ef)
        else:
            out_ef.append(ef)
        out.append(recon.reshape(g.shape).astype(g.dtype))

    new_ef = treedef.unflatten(out_ef) if ef_state is not None else None
    return treedef.unflatten(out), new_ef
