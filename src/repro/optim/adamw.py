"""AdamW with f32 master weights / moments, decoupled weight decay,
global-norm clipping and linear-warmup cosine schedule.  Pure-pytree
functional (no optax dependency); optimizer state inherits each param's
sharding (FSDP over 'data', TP over 'model') so the memory analysis of the
dry-run covers the optimizer too.
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "lr_at"]


class AdamWConfig(NamedTuple):
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1
    # REFUTED OPTIMIZATION (kept for the §Perf log): lax.map-chunking the
    # update was predicted to bound f32 transients, but it breaks XLA's
    # donation aliasing of the stacked tensors — measured temp went UP
    # 19.7 -> 32.9 GB on deepseek train_4k.  Disabled by default.
    chunked_update_numel: int = 2**62


def lr_at(cfg: AdamWConfig, step):
    warm = jnp.minimum(step / max(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = 0.5 * (1 + jnp.cos(math.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def adamw_init(params, moments_dtype=jnp.float32):
    """(master f32 copy, m, v) — all sharded like params (opt rules add
    ZeRO-1 sharding over the pod axis on multi-pod meshes)."""
    # copy=True: when params are already f32, astype would alias the same
    # buffer and break donation (same buffer donated twice).
    f32 = lambda p: jnp.array(p, dtype=jnp.float32, copy=True)
    zeros = lambda p: jnp.zeros(p.shape, moments_dtype)
    return {
        "master": jax.tree.map(f32, params),
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
    }


def global_norm(tree):
    return jnp.sqrt(
        sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in jax.tree.leaves(tree))
    )


def adamw_update(cfg: AdamWConfig, grads, opt_state, step):
    """Returns (new_params_in_model_dtype, new_opt_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = lr_at(cfg, step)
    t = step + 1
    bc1 = 1 - cfg.b1 ** t.astype(jnp.float32)
    bc2 = 1 - cfg.b2 ** t.astype(jnp.float32)

    def upd(g, master, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g * g
        update = (m32 / bc1) / (jnp.sqrt(v32 / bc2) + cfg.eps)
        new_master = master - lr * (update + cfg.weight_decay * master)
        return new_master, m32.astype(m.dtype), v32.astype(v.dtype)

    flat_g, treedef = jax.tree.flatten(grads)
    flat_ma = treedef.flatten_up_to(opt_state["master"])
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])

    def upd_maybe_chunked(g, ma, m, v):
        if g.ndim >= 3 and g.size >= cfg.chunked_update_numel:
            return jax.lax.map(lambda a: upd(*a), (g, ma, m, v))
        return upd(g, ma, m, v)

    out = [
        upd_maybe_chunked(g, ma, m, v)
        for g, ma, m, v in zip(flat_g, flat_ma, flat_m, flat_v)
    ]
    new_master = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    new_state = {"master": new_master, "m": new_m, "v": new_v}
    return new_state, {"grad_norm": gnorm, "lr": lr}


def cast_params(opt_state, dtype):
    return jax.tree.map(lambda p: p.astype(dtype), opt_state["master"])
