from .adamw import AdamWConfig, adamw_init, adamw_update, cast_params, global_norm, lr_at
from .compression import CompressionConfig, compress_state_init, sketched_psum_grads

__all__ = [
    "AdamWConfig", "adamw_init", "adamw_update", "cast_params", "global_norm",
    "lr_at", "CompressionConfig", "compress_state_init", "sketched_psum_grads",
]
