"""``SketchedSolver`` — a reusable sketch-and-solve session.

Every sketched solver pays the same precompute: draw S, sketch B = SA,
QR-factor B.  For serving-style workloads (many right-hand sides against
one design matrix, the ROADMAP's heavy-repeated-traffic scenario) that
precompute dominates, and redoing it per call — which the functional
``lstsq``/``saa_sas`` API forces — throws the amortization away.

``SketchedSolver(A, key)`` builds the :class:`repro.core.precond
.SketchedFactor` ONCE and then serves:

- ``solve(b)``        — one right-hand side against the stored factor;
- ``solve_many(B)``   — k stacked right-hand sides, LSQR vmapped over
  columns, still one factor;
- ``update_rows(idx, rows)`` — row update of A with an O(|idx|·n)
  *delta-sketch*: S is linear in the rows of A, so
  SA′ = SA + S[:, idx]·(A′[idx] − A[idx]); only the small s×n QR is redone,
  never the full sketch (SRHT has no cheap column restriction and falls
  back to re-sketching with the SAME S — still no new operator draw).

``A`` may be a dense array, a BCOO matrix or a ``repro.core.linop``
operator (``update_rows`` needs dense, since it rewrites rows in place).
``reg=λ`` serves ridge solves through the augmented operator.  ``stats``
counts the expensive events (``sketches``, ``qr_factorizations``,
``solves``) so amortization is observable — the whole point of the
session API is that ``sketches`` stays at 1 while ``solves`` grows.

Trust layer (``repro.core.certify``): ``certify()`` issues a posterior
:class:`~repro.core.certify.Certificate` for the stored factor — and,
given a solve's ``(b, result)``, a forward-error bound for that answer.
Row updates DRIFT the embedding: S was drawn obliviously to the original
A, and enough rewritten rows can degrade its quality for the new
range(A) without any bookkeeping going stale (the delta-sketch itself is
exact).  ``auto_recertify=True`` re-probes after every ``update_rows``
and, when the probe fails, escalates the sketch in place
(``SketchedFactor.extend`` — appended rows, stored B reused) until it
certifies again or the sketch reaches the data row count.

The per-call work is one sketch of b (O(m) for CountSketch), the whitened
LSQR iterations (κ-independent count) and one n×n back substitution —
exactly the marginal cost of a query in ``saa_sas_batch``, but without
needing all right-hand sides up front.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from . import certify as certify_lib
from . import linop
from . import sketch as sketch_lib
from ..obs import trace as obs_trace
from ..obs.metrics import REGISTRY
from .backend import resolve as resolve_backend
from .lsqr import lsqr
from .precond import SketchedFactor, default_sketch_size
from .result import SolveResult

__all__ = ["SketchedSolver"]

# key-derivation constant for the session's certification probe stream
# (disjoint from the sketch draw made with the constructor key itself)
_CERTIFY_SALT = 0x6CE7


_SOLVE_STATICS = ("atol", "btol", "steptol", "iter_lim", "backend", "history")


@partial(jax.jit, static_argnames=_SOLVE_STATICS)
def _solve_one(
    A, Y, factor, sk_op, b, *, atol, btol, steptol, iter_lim, backend, history
):
    """One RHS against a prebuilt factor (Y = None → operator-form mv/rmv)."""
    c = sk_op.apply(b, backend=backend)
    z0 = factor.warm_start(c)
    if Y is not None:
        mv, rmv = Y.matvec, Y.rmatvec
    else:
        mv = partial(factor.whiten_mv, A)
        rmv = partial(factor.whiten_rmv, A)
    res = lsqr(
        mv, rmv, b, x0=z0, n=factor.n, atol=atol, btol=btol,
        iter_lim=iter_lim, steptol=steptol, history=history,
    )
    return res._replace(
        x=factor.precondition(res.x), used_fallback=jnp.asarray(False)
    )


@partial(jax.jit, static_argnames=_SOLVE_STATICS)
def _solve_many(
    A, Y, factor, sk_op, B, *, atol, btol, steptol, iter_lim, backend, history
):
    """k stacked RHS columns, LSQR vmapped, one shared factor."""
    del history  # per-column histories are not exposed
    C = sk_op.apply(B, backend=backend)  # (s, k)
    Z0 = factor.warm_start(C)  # (n, k)
    if Y is not None:
        mv, rmv = Y.matvec, Y.rmatvec
    else:
        mv = partial(factor.whiten_mv, A)
        rmv = partial(factor.whiten_rmv, A)

    def solve_col(b_i, z0_i):
        return lsqr(
            mv, rmv, b_i, x0=z0_i, n=factor.n, atol=atol, btol=btol,
            iter_lim=iter_lim, steptol=steptol,
        )

    res = jax.vmap(solve_col, in_axes=(1, 1))(B, Z0)
    X = factor.precondition(res.x.T)  # (n, k)
    return res._replace(x=X, used_fallback=jnp.zeros(B.shape[1], bool))


class SketchedSolver:
    """One sketch + QR, amortized over arbitrarily many solves.

    Parameters mirror ``saa_sas`` (sketch kind/size, tolerances, backend);
    ``materialize_y=None`` resolves to True for dense A (fast matmul LSQR)
    and False otherwise (operator form, A never densified).  ``reg=λ``
    builds the factor for the Tikhonov-augmented operator and zero-pads
    each right-hand side transparently.
    """

    def __init__(
        self,
        A,
        key: jax.Array,
        *,
        sketch: str = "clarkson_woodruff",
        sketch_size: int | None = None,
        reg: float | jax.Array | None = None,
        atol: float = 0.0,
        btol: float = 0.0,
        steptol: float | None = None,
        iter_lim: int = 100,
        materialize_y: bool | None = None,
        backend: str = "auto",
        auto_recertify: bool = False,
        max_distortion: float = certify_lib.DEFAULT_MAX_DISTORTION,
        certify_probes: int = 8,
    ):
        self.A = linop.as_operator(A)
        self.reg = reg
        self._solve_op = (
            linop.TikhonovAugmented.wrap(self.A, reg) if reg is not None else self.A
        )
        m, n = self.A.shape  # sketch size is set by the DATA rows
        self.sketch_size = (
            sketch_size if sketch_size is not None else default_sketch_size(n, m)
        )
        self.backend = resolve_backend(backend).name
        if steptol is None:
            steptol = 32 * float(jnp.finfo(self.A.dtype).eps)
        self._kw = dict(
            atol=atol, btol=btol, steptol=steptol, iter_lim=iter_lim,
            backend=self.backend,
        )
        if materialize_y is None:
            materialize_y = isinstance(self.A, linop.DenseOperator)
        self._materialize_y = materialize_y

        inner = sketch_lib.sample(
            sketch, key, self.sketch_size, m, dtype=self.A.dtype
        )
        # Ridge: structured blockdiag(S, I) embedding — the identity block
        # of [A; √λI] must be kept exact (see sketch.AugmentedSketch).
        self._sketch_op = (
            sketch_lib.AugmentedSketch(inner=inner, tail=n)
            if reg is not None
            else inner
        )
        self.auto_recertify = auto_recertify
        self.max_distortion = float(max_distortion)
        self.certify_probes = int(certify_probes)
        self._certify_key = jax.random.fold_in(key, _CERTIFY_SALT)
        self._certify_calls = 0
        self.certificate = None  # embedding-level cert of the CURRENT factor
        self.recertifications = 0  # auto-recertify probes taken so far
        self.escalations = 0  # sketch extensions taken by recertification

        self.stats = REGISTRY.stats_dict(
            "session", {"sketches": 0, "qr_factorizations": 0, "solves": 0}
        )
        with obs_trace.span("session.build", rows=self.sketch_size):
            with obs_trace.span("sketch.apply", kind=sketch):
                self._B = self._sketch_op.apply_op(
                    self._solve_op, backend=self.backend
                )
                obs_trace.maybe_block(self._B)
            self.stats["sketches"] += 1
            self._refactor()

    # ------------------------------------------------------------------ build
    def _refactor(self):
        """(Re)build the QR factor — and Y, if materialized — from self._B."""
        with obs_trace.span("factor.qr", shape=tuple(self._B.shape)):
            self.factor = SketchedFactor.from_sketch(self._B)
            obs_trace.maybe_block(self.factor.R)
        self._after_refactor()

    def _after_refactor(self):
        """Bookkeeping shared by every path that replaced the factor."""
        self.stats["qr_factorizations"] += 1
        self._Y = (
            linop.DenseOperator(self.factor.materialize_whitened(self._solve_op))
            if self._materialize_y
            else None
        )

    @property
    def shape(self) -> tuple[int, int]:
        return self.A.shape

    def _rhs(self, b):
        if self.reg is None:
            return b
        return self._solve_op.augment_rhs(b)

    def _set_matrix(self, A_new: jax.Array):
        """Point the session at updated dense data (rewraps the ridge op)."""
        self.A = linop.DenseOperator(A_new)
        self._solve_op = (
            linop.TikhonovAugmented.wrap(self.A, self.reg)
            if self.reg is not None
            else self.A
        )

    def _ridge_diagnostics(self, b, res: SolveResult) -> SolveResult:
        """Report rnorm/arnorm of the ORIGINAL ridge problem, matching
        lstsq(reg=...): the solvers see the augmented system, whose
        residual is inflated by the λ‖x‖² penalty term."""
        if self.reg is None:
            return res
        lam = jnp.asarray(self.reg, self.A.dtype)
        if res.x.ndim == 1:
            r = b - self.A.matvec(res.x)
            g = self.A.rmatvec(r) - lam * res.x
            axis = None
        else:  # (n, k) solve_many result, b is the original (m, k) block
            r = b - self.A.matmat(res.x)
            g = self.A.rmatmat(r) - lam * res.x
            axis = 0
        return res._replace(
            rnorm=jnp.linalg.norm(r, axis=axis),
            arnorm=jnp.linalg.norm(g, axis=axis),
        )

    # ------------------------------------------------------- certification
    def _next_probe_key(self):
        self._certify_calls += 1
        return jax.random.fold_in(self._certify_key, self._certify_calls)

    def _random_rows(self) -> int:
        """Rows of the random part of S (ridge sessions exclude the exact
        √λ·I tail — it is not part of the embedding)."""
        op = self._sketch_op
        if isinstance(op, sketch_lib.AugmentedSketch):
            return op.inner.d
        return op.d

    def certify(self, b=None, result=None, *, n_probes=None, target=None):
        """Posterior :class:`~repro.core.certify.Certificate` for the
        stored factor — or, given one solve's ``(b, result)``, for that
        specific answer (forward-error bound included).

        The embedding-level form (no arguments) is cached on
        ``self.certificate`` and is what ``auto_recertify`` refreshes
        after row updates.  Cost: ``certify_probes`` matvecs with A plus
        one n×n SVD; nothing is re-sketched.
        """
        if (b is None) != (result is None):
            raise ValueError("pass b and result together (or neither)")
        x = None
        b_solve = None
        if b is not None:
            x = result.x
            if x.ndim != 1:
                raise ValueError(
                    "certify takes one right-hand side at a time; "
                    "certify solve_many columns individually"
                )
            b_solve = self._rhs(jnp.asarray(b, self.A.dtype))
        with obs_trace.span("session.certify", with_solution=x is not None):
            cert = certify_lib.certify(
                self._solve_op, b_solve, x, self.factor,
                self._next_probe_key(),
                n_probes=(
                    self.certify_probes if n_probes is None else int(n_probes)
                ),
                target=target, max_distortion=self.max_distortion,
                sketch_rows=self._random_rows(),
                escalations=self.escalations,
            )
        if x is None:
            self.certificate = cert
        return cert

    def _escalate(self, extra: int):
        """Append ``extra`` fresh rows to S and re-QR — the stored sketch
        is extended (never recomputed), exactly the certified driver's
        escalation move."""
        with obs_trace.span("session.escalate", extra=extra):
            self.factor, self._sketch_op, self._B = self.factor.extend(
                self._solve_op, self._sketch_op, self._next_probe_key(),
                extra, B=self._B, backend=self.backend,
            )
        # extend() sketched the new rows and re-QRed internally
        self.stats["sketches"] += 1
        self._after_refactor()
        self.sketch_size = self._random_rows()
        self.escalations += 1

    def _recertify_after_update(self):
        """Probe the drifted embedding; escalate until it certifies again
        (or the sketch reaches the data row count)."""
        m = self.A.shape[0]
        cert = self.certify()
        self.recertifications += 1
        while not bool(cert.passed):
            s = self._random_rows()
            extra = min(s, m - s)
            if extra <= 0:
                break
            self._escalate(extra)
            cert = self.certify()
            self.recertifications += 1

    # ----------------------------------------------------------------- solves
    def _check_rhs(self, b: jax.Array, *, many: bool) -> jax.Array:
        """Validate a right-hand side up front — shape and dtype.

        Shape mismatches raise here with the session's expectation spelled
        out instead of surfacing as an XLA dot-dimension failure deep in
        the jitted solve.  Dtype policy: a RHS that would *promote* the
        solve away from A's dtype (f64 b against an f32 session, complex
        against real) is an error — silent promotion would recompile the
        cached executables and lie about the precision the factor was
        built at; a safely-representable RHS (f32 b, f64 A) is cast to
        A's dtype explicitly.
        """
        b = jnp.asarray(b)
        m = self.A.shape[0]
        if many:
            if b.ndim != 2 or b.shape[0] != m:
                raise ValueError(
                    f"solve_many needs B of shape ({m}, k), got {b.shape}"
                )
        else:
            if b.ndim != 1 or b.shape[0] != m:
                raise ValueError(
                    f"solve needs b of shape ({m},) matching A's row count, "
                    f"got {b.shape}"
                )
        dtype = self.A.dtype
        if b.dtype != dtype:
            if jnp.result_type(b.dtype, dtype) != dtype:
                raise TypeError(
                    f"right-hand side dtype {b.dtype} does not fit the "
                    f"session's {dtype} factor: solving would silently "
                    f"promote past the precision A was sketched at — cast "
                    f"b (or rebuild the session at {b.dtype}) explicitly"
                )
            b = b.astype(dtype)
        return b

    def solve(self, b: jax.Array, *, history: bool = False) -> SolveResult:
        """min‖Ax − b‖ against the stored factor (one whitened LSQR run)."""
        b = self._check_rhs(b, many=False)
        with obs_trace.span("session.solve") as sp:
            res = _solve_one(
                self._solve_op, self._Y, self.factor, self._sketch_op,
                self._rhs(b), history=history, **self._kw,
            )
            obs_trace.maybe_block(res.x)
            if sp:
                sp.set(itn=int(res.itn))
        self.stats["solves"] += 1
        return self._ridge_diagnostics(b, res)._replace(method="session")

    def solve_many(self, B: jax.Array) -> SolveResult:
        """k stacked right-hand sides (m, k) → x of shape (n, k).

        One sketch of B, k vmapped LSQR runs, one blocked back
        substitution — the factor is shared by construction.  (vmap-of-
        while semantics: all columns iterate until the slowest converges.)
        """
        B = self._check_rhs(B, many=True)
        B_orig = B
        if self.reg is not None:
            n = self.A.shape[1]
            B = jnp.concatenate([B, jnp.zeros((n, B.shape[1]), B.dtype)], axis=0)
        with obs_trace.span("session.solve_many", k=int(B.shape[1])):
            res = _solve_many(
                self._solve_op, self._Y, self.factor, self._sketch_op, B,
                history=False, **self._kw,
            )
            obs_trace.maybe_block(res.x)
        self.stats["solves"] += int(B.shape[1])
        return self._ridge_diagnostics(B_orig, res)._replace(method="session")

    # ---------------------------------------------------------------- updates
    def update_rows(self, idx, rows: jax.Array) -> None:
        """Replace rows ``A[idx] ← rows`` and refresh the factor in
        O(|idx|·n) sketch work + one s×n QR (no full re-sketch).

        ``idx`` must contain unique row indices.  Dense A only: the row
        rewrite itself needs entry access.
        """
        if not isinstance(self.A, linop.DenseOperator):
            raise TypeError(
                "update_rows needs a dense A (rows are rewritten in place); "
                f"got {type(self.A).__name__} — rebuild the session instead"
            )
        idx = jnp.asarray(idx)
        rows = jnp.asarray(rows, self.A.dtype)
        if rows.shape != (idx.shape[0], self.A.shape[1]):
            raise ValueError(
                f"rows must have shape ({idx.shape[0]}, {self.A.shape[1]}), "
                f"got {rows.shape}"
            )
        if int(jnp.unique(idx).shape[0]) != int(idx.shape[0]):
            # duplicates would double-count in the delta-sketch while the
            # row rewrite is last-write-wins — the stored B would silently
            # stop matching S·A and poison every later solve
            raise ValueError("idx must contain unique row indices")
        A_new = self.A.A.at[idx].set(rows)
        with obs_trace.span("session.update_rows", rows=int(idx.shape[0])):
            # Ridge sessions sketch through blockdiag(S, I); the updated
            # rows all live in the data block, so restrict the INNER sketch
            # and pad the delta-sketch with zero rows for the untouched
            # identity block.
            sk_op = self._sketch_op
            tail = 0
            if isinstance(sk_op, sketch_lib.AugmentedSketch):
                sk_op, tail = sk_op.inner, sk_op.tail
            # The sub-sketch S[:, idx] (shared with the streaming
            # accumulators and the distributed per-shard assembly); None
            # for SRHT.
            sub = sk_op.restrict_cols(idx)
            if sub is None:
                # SRHT: no column restriction — re-sketch with the SAME S.
                self._set_matrix(A_new)
                self._B = self._sketch_op.apply_op(
                    self._solve_op, backend=self.backend
                )
                self.stats["sketches"] += 1
            else:
                delta = rows - self.A.A[idx]
                d_sk = sub.apply(delta, backend=self.backend)
                if tail:
                    d_sk = jnp.concatenate(
                        [d_sk, jnp.zeros((tail, d_sk.shape[1]), d_sk.dtype)],
                        axis=0,
                    )
                self._B = self._B + d_sk
                self._set_matrix(A_new)
            self._refactor()
        # The delta-sketch is exact, but S itself was drawn obliviously to
        # the ORIGINAL rows — its embedding quality for the new range(A)
        # must be re-established, not assumed.
        self.certificate = None
        if self.auto_recertify:
            self._recertify_after_update()
