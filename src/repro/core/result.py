"""Unified solver result type.

Every least-squares solver in ``repro.core`` — direct, LSQR, SAA-SAS,
SAP-SAS, iterative sketching, FOSSILS, and the distributed driver — returns
this one :class:`SolveResult`, superseding the old ``SAAResult`` /
``LSQRResult`` duality so callers (and the ``lstsq()`` driver) can switch
methods without touching downstream code.

Fields that a method does not track are filled with neutral values
(``arnorm = nan`` where no AᵀR estimate exists, ``used_fallback = False``
where there is no fallback path).  ``history``, when requested via the
solvers' ``history=True`` static flag, is a fixed-length ``(iter_lim,)``
array of per-iteration residual norms padded with ``nan`` past the final
iteration — fixed-shape so it is jit/while_loop/vmap-native.  ``method`` is
filled in by :func:`repro.core.lstsq` *outside* jit (strings are not valid
jit outputs) and is ``None`` when a solver is called directly.
"""
from __future__ import annotations

from typing import NamedTuple

import jax

__all__ = ["SolveResult", "ISTOP_MEANING"]

# istop follows SciPy's LSQR convention, extended with our step-floor code.
ISTOP_MEANING = {
    0: "x = 0 is the exact solution",
    1: "residual-level convergence (btol/atol)",
    2: "least-squares convergence (Aᵀr small)",
    3: "condition-number limit reached",
    4: "residual-level convergence at machine precision",
    5: "least-squares convergence at machine precision",
    6: "condition-number limit at machine precision",
    7: "iteration limit",
    8: "step-size floor (converged to the numerical floor)",
}


class SolveResult(NamedTuple):
    """What every ``repro.core`` least-squares solver returns."""

    x: jax.Array
    istop: jax.Array  # int32, see ISTOP_MEANING
    itn: jax.Array  # int32, iterations taken (0 for direct methods)
    rnorm: jax.Array  # ‖b − Ax‖
    arnorm: jax.Array  # ‖Aᵀ(b − Ax)‖ estimate (nan if untracked)
    used_fallback: jax.Array  # bool; only SAA-SAS's perturbation path sets it
    history: jax.Array | None = None  # (iter_lim,) residual norms, nan-padded
    method: str | None = None  # set by lstsq() outside jit
    # Posterior trust report (repro.core.certify.Certificate) — attached by
    # the certified/adaptive paths outside jit; None everywhere else.
    certificate: object | None = None
    # Per-solve span tree (repro.obs.trace.Timeline) — attached by the
    # drivers outside jit when tracing is active; None otherwise.
    timeline: object | None = None

    @property
    def converged(self):
        return (self.istop > 0) & (self.istop != 7)
