"""Sketch-and-Precondition (SAP-SAS) baseline — paper §4's negative result.

Blendenpik-style: sketch, QR-factor the sketch, then run LSQR on the
right-preconditioned operator A R⁻¹ *without* reducing the problem's row
dimension.  The paper reports this is not competitive (precompute cost, no
dimensionality reduction); we implement it so the comparison is reproducible.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.scipy.linalg import solve_triangular

from . import sketch as sketch_lib
from .backend import resolve_backend_arg
from .lsqr import lsqr
from .saa import SAAResult, default_sketch_size

__all__ = ["sap_sas"]


@resolve_backend_arg
@partial(
    jax.jit,
    static_argnames=(
        "sketch", "sketch_size", "iter_lim", "atol", "btol", "steptol", "backend"
    ),
)
def sap_sas(
    A: jax.Array,
    b: jax.Array,
    key: jax.Array,
    *,
    sketch: str = "clarkson_woodruff",
    sketch_size: int | None = None,
    atol: float = 0.0,
    btol: float = 0.0,
    steptol: float | None = None,
    iter_lim: int = 200,
    backend: str = "auto",
) -> SAAResult:
    m, n = A.shape
    s = sketch_size if sketch_size is not None else default_sketch_size(n, m)
    if steptol is None:
        steptol = 32 * float(jnp.finfo(A.dtype).eps)
    op = sketch_lib.sample(sketch, key, s, m, dtype=A.dtype)
    B = op.apply(A, backend=backend)
    _, R = jnp.linalg.qr(B, mode="reduced")

    def mv(z):
        return A @ solve_triangular(R, z, lower=False)

    def rmv(u):
        return solve_triangular(R, A.T @ u, trans=1, lower=False)

    res = lsqr(mv, rmv, b, n=n, atol=atol, btol=btol, iter_lim=iter_lim, steptol=steptol)
    x = solve_triangular(R, res.x, lower=False)
    return SAAResult(
        x=x,
        istop=res.istop,
        itn=res.itn,
        rnorm=res.rnorm,
        used_fallback=jnp.asarray(False),
    )
