"""Sketch-and-Precondition (SAP-SAS) baseline — paper §4.

Blendenpik-style: sketch, QR-factor the sketch, then run LSQR on the
right-preconditioned operator A R⁻¹ *without* reducing the problem's row
dimension.  The paper reports this is not competitive (precompute cost, no
dimensionality reduction); we implement it so the comparison is reproducible.

Built on the shared :class:`repro.core.precond.SketchedFactor`.  The solve
now threads the sketch-and-solve warm start ``z₀ = Qᵀ(Sb)`` through the
preconditioned LSQR call — previously SAP started from zero while SAA-SAS
warm-started, which conflated "no dimension reduction" with "no warm start"
in the comparison.  With the warm start SAP converges in O(10) iterations
like SAA; its remaining disadvantage (each iteration touches all m rows of A
through the preconditioner, and an extra sketch of b) is exactly the effect
the paper's runtime comparison measures.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from . import linop
from .backend import resolve_backend_arg
from .lsqr import lsqr
from .precond import SketchedFactor
from .result import SolveResult

__all__ = ["sap_sas"]


@resolve_backend_arg
@partial(
    jax.jit,
    static_argnames=(
        "sketch", "sketch_size", "iter_lim", "atol", "btol", "steptol",
        "backend", "warm_start", "history",
    ),
)
def sap_sas(
    A,
    b: jax.Array,
    key: jax.Array,
    *,
    sketch: str = "clarkson_woodruff",
    sketch_size: int | None = None,
    atol: float = 0.0,
    btol: float = 0.0,
    steptol: float | None = None,
    iter_lim: int = 200,
    warm_start: bool = True,
    backend: str = "auto",
    history: bool = False,
) -> SolveResult:
    """Solve min‖Ax − b‖ by sketch-and-precondition (LSQR on A R⁻¹).

    ``warm_start=False`` restores the zero-initialized historical variant
    (kept for reproducing the paper's original negative result).

    ``A`` may be a dense array, a BCOO sparse matrix or a
    ``repro.core.linop`` operator (the preconditioned LSQR iteration only
    takes products with A).
    """
    A = linop.as_operator(A)
    if steptol is None:
        steptol = 32 * float(jnp.finfo(A.dtype).eps)
    factor, op = SketchedFactor.build(
        A, key, sketch=sketch, sketch_size=sketch_size, backend=backend
    )
    z0 = factor.warm_start(op.apply(b, backend=backend)) if warm_start else None
    res = lsqr(
        partial(factor.whiten_mv, A),
        partial(factor.whiten_rmv, A),
        b,
        x0=z0,
        n=factor.n,
        atol=atol,
        btol=btol,
        iter_lim=iter_lim,
        steptol=steptol,
        history=history,
    )
    x = factor.precondition(res.x)
    return res._replace(x=x, used_fallback=jnp.asarray(False))
