"""Backend policy for the sketch applies.

One place decides which implementation of each sketch apply runs:

- ``"reference"`` — the pure-jnp paths in ``repro.core.sketch`` (segment_sum
  CountSketch, recursive FWHT SRHT, materialized-S matmuls).  Always
  available, always exact, the oracle every other backend is tested against.
- ``"pallas"``   — the TPU Pallas kernels in ``repro.kernels``
  (``countsketch_apply``, ``srht_apply``, ``fused_gaussian_sketch``,
  ``sketch_matmul``).  Off-TPU these run in ``interpret=True`` mode, so CPU
  containers exercise the exact kernel semantics (same tiling, same
  accumulation order, same in-kernel PRNG) without a TPU.
- ``"auto"``     — resolve per platform: ``"pallas"`` on TPU, ``"reference"``
  everywhere else.

``resolve`` is called at trace time (``backend`` is a static argument of the
solvers), so the choice costs nothing at runtime.  The environment variable
``REPRO_SKETCH_BACKEND`` overrides ``"auto"`` — useful for flipping a whole
benchmark run without touching call sites.

Sketch kinds without a matching kernel (``sparse_sign``, ``uniform_sparse``)
fall back to the reference path under ``"pallas"``; ``kernel_backed`` tells
you which kinds actually dispatch.
"""
from __future__ import annotations

import dataclasses
import functools
import os

import jax

__all__ = [
    "BACKENDS",
    "KERNEL_BACKED_KINDS",
    "PRECISIONS",
    "ResolvedBackend",
    "resolve",
    "resolve_backend_arg",
    "resolve_fused",
    "default_interpret",
    "kernel_backed",
    "kernel_blocks",
]

BACKENDS = ("auto", "reference", "pallas")

# Working precisions for the sketch/factor stage.  "full" runs everything in
# the data dtype; "mixed" rounds the data matrix to bf16 for the sketch apply
# (accumulating in >= f32) and leaves all refinement at full precision — the
# certified driver escalates mixed -> full automatically when a certificate
# fails (see core/lstsq.py).
PRECISIONS = ("full", "mixed")

# Sketch kinds whose apply has a Pallas kernel behind it.
KERNEL_BACKED_KINDS = frozenset(
    {"gaussian", "uniform_dense", "srht", "countsketch", "clarkson_woodruff"}
)


@dataclasses.dataclass(frozen=True)
class ResolvedBackend:
    """A concrete backend decision: which path, and interpret mode or not."""

    name: str  # "reference" | "pallas"
    interpret: bool  # pallas interpret mode (True off-TPU)

    @property
    def use_pallas(self) -> bool:
        return self.name == "pallas"


def default_interpret(platform: str | None = None) -> bool:
    """Pallas interpret mode default: real Mosaic on TPU, interpret elsewhere."""
    if platform is None:
        platform = jax.default_backend()
    return platform != "tpu"


def resolve(backend: str = "auto", platform: str | None = None) -> ResolvedBackend:
    """Resolve a ``backend`` knob to a concrete :class:`ResolvedBackend`.

    ``platform`` defaults to ``jax.default_backend()``; pass it explicitly to
    test the policy without that platform attached.
    """
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; have {BACKENDS}")
    if platform is None:
        platform = jax.default_backend()
    if backend == "auto":
        backend = os.environ.get("REPRO_SKETCH_BACKEND", "auto")
        if backend not in BACKENDS:
            raise ValueError(
                f"REPRO_SKETCH_BACKEND={backend!r} invalid; have {BACKENDS}"
            )
    if backend == "auto":
        backend = "pallas" if platform == "tpu" else "reference"
    return ResolvedBackend(name=backend, interpret=default_interpret(platform))


def kernel_backed(kind: str) -> bool:
    """True if ``kind``'s apply dispatches to a Pallas kernel under "pallas"."""
    return kind in KERNEL_BACKED_KINDS


def kernel_blocks(kind: str, m: int, n: int, d: int, dtype) -> dict:
    """Autotuned block-shape kwargs for a kernel dispatch site.

    Consults ``repro.kernels.autotune`` (committed cache first, roofline cost
    model on miss) and returns kwargs splat-able into the kernel wrapper —
    ``{}`` means "use the kernel's hand-tuned defaults", which is also the
    answer whenever the tuner is disabled (``REPRO_AUTOTUNE=0``) or
    unavailable.  Never raises: tuning is advisory, dispatch must not fail.
    """
    if os.environ.get("REPRO_AUTOTUNE", "1") == "0":
        return {}
    try:
        from ..kernels.autotune import best_blocks

        return best_blocks(kind, m, n, d, dtype)
    except Exception:
        return {}


def resolve_fused(fused: bool | None) -> bool:
    """Resolve the fused sketch->QR knob.  ``None`` reads ``REPRO_FUSED_QR``
    (default off, preserving the seed pipeline's exact numerics)."""
    if fused is None:
        return os.environ.get("REPRO_FUSED_QR", "0") not in ("0", "", "false")
    return bool(fused)


def resolve_backend_arg(fn):
    """Resolve a solver's ``backend=`` kwarg to a concrete name BEFORE jit.

    ``backend`` is a static jit argument; if the literal string "auto"
    reached the cache key, the platform/env resolution would be baked in at
    first trace and later ``REPRO_SKETCH_BACKEND`` flips silently ignored.
    Resolving at python-call time keeps the cache keyed on the concrete
    backend ("reference"/"pallas") and re-reads the policy every call.
    """

    @functools.wraps(fn)
    def wrapper(*args, backend: str = "auto", **kw):
        return fn(*args, backend=resolve(backend).name, **kw)

    return wrapper
