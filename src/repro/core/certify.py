"""Posterior certification of sketched least-squares solutions.

Every accuracy claim in this package rests on the sketch S being a good
subspace embedding for range(A) — a property that holds w.h.p. but was
never *checked*.  This module is the trust layer (after Epperly 2024,
"Fast and forward stable randomized algorithms for linear least-squares
problems", and Epperly–Meier–Nakatsukasa 2024): cheap posterior
quantities computed AFTER a solve that certify — or refute — the
returned solution, and that power the adaptive escalation ladder of
``lstsq(accuracy="certified")``.

Estimators (all O(mn·n_probes + n³): a handful of products with A plus
one SVD of the n×n triangular factor — never a second sketch, never a
dense S):

- **Embedding distortion**, :func:`probe_distortion`.  For any probe
  w ∈ Rⁿ, ``‖S·A·R⁻¹w‖ = ‖Qw‖ = ‖w‖`` exactly (B = SA = QR), so if S is
  an ε-embedding for range(A) then ``‖w‖ / ‖A R⁻¹ w‖ ∈ [1−ε, 1+ε]``.
  k whitened Gaussian probes therefore estimate ε from below at the cost
  of k matvecs with A.  A ratio far from 1 is PROOF the embedding failed
  (the converse holds only w.h.p. — see the property tests, which pin
  the probe within a constant factor of the true whitened-spectrum
  distortion).
- **Condition estimate**, :func:`factor_spectrum`.  κ₂(R) = κ₂(SA) lies
  within (1±ε) factors of κ₂(A); its σ_min is also exactly the ‖R⁻¹‖₂
  the error bound needs.
- **Spectrum-floor probe**, :func:`probe_spectrum_floor`.  Gaussian
  probes dilute a SINGLE collapsed direction of Y = A R⁻¹ by its
  subspace fraction — exactly the failure mode of a noise-floored
  sketch (a bf16 ``precision="mixed"`` apply at high cond).  Probing
  R's own k weakest left singular vectors instead finds that collapse
  deterministically: the corrupted directions of A land in R's trailing
  subspace by construction.  Returns σ̂ ≥ σ_min(Y), sharp in the
  collapse case.
- **Forward-error bound**, :func:`error_bound`.  With Y = A R⁻¹:
  x̂ − x⋆ = R⁻¹(ẑ − z⋆) and Yᵀ(b − Y ẑ) = (YᵀY)(z⋆ − ẑ), so

      ‖x̂ − x⋆‖ ≤ ‖Yᵀ(b − A x̂)‖ / (σ_min(Y)² · σ_min(R)) ,

  one matvec + one rmatvec + one triangular solve, with σ_min(Y)
  estimated as min(1 − ε̂, σ̂) from both probes.  This is a rigorous
  bound given a true σ_min(Y); with probed estimates it inherits their
  w.h.p. qualifier (the floor probe removes the single-direction blind
  spot that qualifier used to hide).

:class:`Certificate` is a small pytree attached to
``SolveResult.certificate``; ``passed`` folds the distortion test and
the (optionally adaptive) relative-error target into one bool that the
escalation driver, the serving session (``SketchedSolver.certify``) and
the streaming certified mode all share.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from . import linop
from ..obs import trace as obs_trace
from .precond import SketchedFactor

__all__ = [
    "Certificate",
    "probe_distortion",
    "probe_spectrum_floor",
    "factor_spectrum",
    "error_bound",
    "certify",
    "build_certificate",
    "DEFAULT_MAX_DISTORTION",
]

# A healthy default sketch (s = 4n) has a-priori distortion ε ≈ √(n/s) =
# 0.5; probed values beyond that mean the embedding is no better than the
# most aggressive sketch the solvers' damping/momentum coefficients are
# derived for — treat it as failed and escalate.
DEFAULT_MAX_DISTORTION = 0.5


class Certificate(NamedTuple):
    """Posterior trust report for one sketched factor (+ optional solve).

    Solution-independent fields (``distortion``, ``cond_R``) certify the
    EMBEDDING; the rest certify a specific solution x̂ and are ``nan``
    when the certificate was issued without one (e.g. the session's
    factor-level recertification).
    """

    distortion: jax.Array  # probed embedding distortion ε̂ (lower estimate)
    cond_R: jax.Array  # κ₂(R) ≈ κ₂(A) up to (1±ε) factors
    rnorm: jax.Array  # ‖b − A x̂‖ of the certified system
    whitened_arnorm: jax.Array  # ‖Yᵀ(b − A x̂)‖ = ‖R⁻ᵀ Aᵀ r̂‖
    error_bound: jax.Array  # posterior bound on ‖x̂ − x⋆‖
    rel_error_bound: jax.Array  # error_bound / ‖x̂‖
    target: jax.Array  # relative tolerance certified against (nan = none)
    passed: jax.Array  # bool: distortion ok AND bound within target
    sketch_rows: int = 0  # rows of S when the certificate was issued
    escalations: int = 0  # escalation steps taken before this certificate
    precision: str = "full"  # sketch precision the certified factor was built at


def probe_distortion(
    A, factor: SketchedFactor, key: jax.Array, *, n_probes: int = 8
) -> jax.Array:
    """Probed embedding distortion ε̂ = max_j |‖w_j‖ / ‖A R⁻¹ w_j‖ − 1|.

    Whitened probes sample range(A) through R⁻¹, where the sketch's
    action is known exactly (‖S A R⁻¹ w‖ = ‖w‖); each probe costs one
    matvec with A and the k probes share one blocked product.  The
    estimate only ever *under*-reports the true subspace distortion, so a
    failing probe is conclusive.
    """
    A = linop.as_operator(A)
    W = jax.random.normal(key, (factor.n, int(n_probes)), A.dtype)
    Yw = A.matmat(factor.precondition(W))
    wn = jnp.linalg.norm(W, axis=0)
    yn = jnp.linalg.norm(Yw, axis=0)
    ratios = wn / jnp.maximum(yn, jnp.finfo(A.dtype).tiny)
    return jnp.max(jnp.abs(ratios - 1.0))


def probe_spectrum_floor(A, factor: SketchedFactor, *, k: int = 4):
    """σ̂ = min_j ‖A R⁻¹ u_j‖ over R's k weakest left singular vectors.

    A deterministic UPPER estimate of σ_min(A R⁻¹) that is sharp exactly
    where Gaussian probes are blind: a factor whose weakness is confined
    to a few directions.  That is the signature of a noise-floored
    sketch — e.g. a ``precision="mixed"`` bf16 apply whose rounding noise
    exceeds A's trailing singular values: every such direction of A
    collapses onto R's own trailing subspace, so probing R's smallest
    singular vectors finds the damage with probability one, while an
    isotropic probe dilutes it by the subspace fraction.  For a healthy
    factor the probed directions behave like any other: σ̂ ∈
    [1/(1+ε), 1/(1−ε)], no false alarm.  Cost: one n×n SVD + k matvecs.
    """
    A = linop.as_operator(A)
    n = factor.n
    kk = max(1, min(int(k), n))
    U, _, _ = jnp.linalg.svd(factor.R)  # descending singular values
    W = U[:, n - kk:]
    Yw = A.matmat(factor.precondition(W))
    return jnp.min(jnp.linalg.norm(Yw, axis=0))


def factor_spectrum(factor: SketchedFactor):
    """(σ_max, σ_min, κ₂) of R — one SVD of the n×n triangular factor.

    σ_min(R)⁻¹ = ‖R⁻¹‖₂ is the amplification the error bound pays to map
    whitened coordinates back to x-space; κ₂(R) estimates κ₂(A) up to the
    embedding's (1±ε) factors.
    """
    svals = jnp.linalg.svd(factor.R, compute_uv=False)
    smax, smin = svals[0], svals[-1]
    tiny = jnp.finfo(factor.R.dtype).tiny
    return smax, smin, smax / jnp.maximum(smin, tiny)


def error_bound(A, b, x, factor: SketchedFactor, distortion) -> tuple:
    """Posterior ``(rnorm, whitened_arnorm, bound)`` at a solution x̂.

    ``bound ≥ ‖x̂ − x⋆‖`` whenever the σ_min(Y) estimate it rests on is
    not an over-estimate (see module docstring).  σ_min(Y) is estimated
    as ``min(1 − distortion, probe_spectrum_floor(A, factor))`` — the
    isotropic probe's view AND the deterministic trailing-subspace
    probe's, so single-direction collapse (the mixed-precision failure
    mode) is priced in instead of diluted away.  Cost: one matvec, one
    rmatvec, one triangular solve, two n×n SVDs, k floor matvecs.
    """
    A = linop.as_operator(A)
    _, smin, _ = factor_spectrum(factor)
    floor = probe_spectrum_floor(A, factor)
    return _error_bound_parts(A, b, x, factor, distortion, smin, floor)


def _error_bound_parts(A, b, x, factor, distortion, smin, sigma_floor=None):
    r = b - A.matvec(x)
    rnorm = jnp.linalg.norm(r)
    wg = factor.rt_solve(A.rmatvec(r))
    wg_norm = jnp.linalg.norm(wg)
    tiny = jnp.finfo(factor.R.dtype).tiny
    eps = jnp.clip(distortion, 0.0, 0.999)
    # ‖x̂−x⋆‖ = ‖R⁻¹(YᵀY)⁻¹Yᵀr̂‖ ≤ ‖Yᵀr̂‖ / (σ_min(Y)² σ_min(R)); both
    # σ_min(Y) estimates are upper estimates, take the sharper one.
    sigma_w = 1.0 - eps
    if sigma_floor is not None:
        sigma_w = jnp.minimum(sigma_w, sigma_floor)
    sigma_w = jnp.maximum(sigma_w, tiny)
    bound = wg_norm / (sigma_w**2 * jnp.maximum(smin, tiny))
    return rnorm, wg_norm, bound


def _adaptive_target(dtype, cond_R, rnorm, smax, xnorm):
    """Default relative-error target: 100x the attainable QR-level error.

    The classical least-squares perturbation floor is
    ε_mach·(κ + κ²·‖r‖/(‖A‖‖x‖)); no solver — including Householder QR —
    beats it, so certifying tighter than a multiple of it can never
    succeed.  Clipped to [64·ε_mach, 1e-2].
    """
    eps_mach = jnp.finfo(dtype).eps
    tiny = jnp.finfo(dtype).tiny
    kappa_term = cond_R + cond_R**2 * rnorm / jnp.maximum(smax * xnorm, tiny)
    return jnp.clip(100.0 * eps_mach * kappa_term, 64.0 * eps_mach, 1e-2)


def certify(
    A,
    b,
    x,
    factor: SketchedFactor,
    key: jax.Array,
    *,
    n_probes: int = 8,
    target: float | None = None,
    max_distortion: float = DEFAULT_MAX_DISTORTION,
    sketch_rows: int | None = None,
    escalations: int = 0,
    precision: str = "full",
) -> Certificate:
    """Issue a :class:`Certificate` for ``x ≈ argmin‖Ax − b‖`` (or, with
    ``b = x = None``, for the embedding alone).

    ``target=None`` resolves to the adaptive default — 100x the classical
    attainable-accuracy floor ε_mach·(κ + κ²‖r‖/(‖A‖‖x‖)), so "certified"
    means "as accurate as a direct method could be", scale-free across
    conditioning.  Pass an explicit relative tolerance to certify against
    an accuracy SLO instead.  ``passed`` requires the probed distortion
    ≤ ``max_distortion`` AND (when a solution is given) the relative
    error bound ≤ the target.
    """
    A = linop.as_operator(A)
    dtype = factor.R.dtype
    with obs_trace.span("certify.probe", n_probes=n_probes):
        eps_hat = probe_distortion(A, factor, key, n_probes=n_probes)
        smax, smin, cond_R = factor_spectrum(factor)
        obs_trace.maybe_block(eps_hat)
    nan = jnp.asarray(jnp.nan, dtype)
    emb_ok = (eps_hat <= max_distortion) & jnp.isfinite(cond_R)

    if x is None:
        return Certificate(
            distortion=eps_hat, cond_R=cond_R, rnorm=nan,
            whitened_arnorm=nan, error_bound=nan, rel_error_bound=nan,
            target=nan, passed=emb_ok,
            sketch_rows=int(sketch_rows or factor.sketch_size),
            escalations=int(escalations), precision=precision,
        )

    with obs_trace.span("certify.floor", precision=precision):
        if precision == "mixed":
            # Sampling probes cannot price a low-precision sketch: rounding
            # noise floors R's trailing subspace, hiding A's weak directions
            # in a span no O(1) probe set covers (isotropic probes dilute the
            # collapse, R-aligned probes see only the noise).  Certifying a
            # mixed factor therefore pays ONE exact whitened-spectrum pass —
            # σ_min(A R⁻¹) by SVD, O(mn²), the same order as the full-
            # precision apply the bf16 sketch skipped.  That is the honest
            # price of trusting a cheap sketch at high cond; at moderate cond
            # the check passes and the mixed saving stands.
            Y = factor.materialize_whitened(A)
            floor = jnp.linalg.svd(Y, compute_uv=False)[-1]
        else:
            floor = probe_spectrum_floor(A, factor)
        obs_trace.maybe_block(floor)
    rnorm, wg_norm, bound = _error_bound_parts(
        A, b, x, factor, eps_hat, smin, floor
    )
    xnorm = jnp.linalg.norm(x)
    rel = bound / jnp.maximum(xnorm, jnp.finfo(dtype).tiny)
    if target is None:
        tgt = _adaptive_target(dtype, cond_R, rnorm, smax, xnorm)
    else:
        tgt = jnp.asarray(target, dtype)
    passed = emb_ok & jnp.isfinite(bound) & (rel <= tgt)
    return Certificate(
        distortion=eps_hat, cond_R=cond_R, rnorm=rnorm,
        whitened_arnorm=wg_norm, error_bound=bound, rel_error_bound=rel,
        target=tgt, passed=passed,
        sketch_rows=int(sketch_rows or factor.sketch_size),
        escalations=int(escalations), precision=precision,
    )


def build_certificate(
    factor: SketchedFactor,
    *,
    distortion,
    rnorm,
    whitened_arnorm,
    xnorm,
    target: float | None = None,
    max_distortion: float = DEFAULT_MAX_DISTORTION,
    sketch_rows: int | None = None,
    escalations: int = 0,
) -> Certificate:
    """Assemble a :class:`Certificate` from externally-computed pieces.

    The streaming certified mode computes the probe ratios and the
    residual/gradient norms with its own fused passes over the row
    source (A is never an operator there); this helper applies the same
    bound, adaptive target and pass rule to those pieces so every layer
    certifies identically.
    """
    dtype = factor.R.dtype
    smax, smin, cond_R = factor_spectrum(factor)
    tiny = jnp.finfo(dtype).tiny
    eps = jnp.clip(distortion, 0.0, 0.999)
    # no A here (streaming computes its probes in its own passes), so the
    # σ_min(Y) estimate is the isotropic probe's 1 − ε̂ alone
    bound = whitened_arnorm / ((1.0 - eps) ** 2 * jnp.maximum(smin, tiny))
    rel = bound / jnp.maximum(xnorm, tiny)
    if target is None:
        tgt = _adaptive_target(dtype, cond_R, rnorm, smax, xnorm)
    else:
        tgt = jnp.asarray(target, dtype)
    passed = (
        (distortion <= max_distortion)
        & jnp.isfinite(cond_R)
        & jnp.isfinite(bound)
        & (rel <= tgt)
    )
    return Certificate(
        distortion=distortion, cond_R=cond_R, rnorm=rnorm,
        whitened_arnorm=whitened_arnorm, error_bound=bound,
        rel_error_bound=rel, target=tgt, passed=passed,
        sketch_rows=int(sketch_rows or factor.sketch_size),
        escalations=int(escalations),
    )
