"""SAA-SAS — Sketch-and-Apply (paper Algorithm 1).

  1. Draw S ∈ R^{s×m} (Clarkson–Woodruff by default, the paper's choice).
  2. B = SA, c = Sb.
  3. Householder QR of B (jnp.linalg.qr is Householder-based).
  4. Y = A R⁻¹ via triangular substitution (the "apply" step).
  5. Warm start z₀ = Qᵀ c.
  6. LSQR on min‖Y z − b‖ (Y has cond ≈ O(1) w.h.p. — fast convergence).
  7. Converged → x = R⁻¹ z (back substitution).
  8. Fallback (paper lines 10–17): perturb Ã = A + σG/√m with σ = 10‖A‖₂u,
     re-sketch, re-factor and re-solve.  (The paper's line 12 literally says
     "B = SA"; we sketch the perturbed Ã, which is the mathematically
     consistent reading — noted in DESIGN.md.)

The sketch apply (step 2) is the compute hot path and dispatches through
``repro.core.backend``: ``backend="reference"`` runs the pure-jnp operator
paths, ``backend="pallas"`` the TPU Pallas kernels in ``repro.kernels``
(interpret mode off-TPU), ``backend="auto"`` resolves per platform.
``backend`` is a static argument, so each choice compiles its own
executable and the dispatch is free at runtime.

``materialize_y=False`` gives the operator-form variant (computes R⁻¹v on the
fly inside LSQR) — same math, O(mn) less memory; this is the at-scale path
used by ``repro.core.distributed``.

``saa_sas_batch`` is the serving front-end: one operator draw + one QR
factor amortized across stacked right-hand sides (A (m,n), b (m,k)) or
across a batch of equally-shaped problems (A (batch,m,n), b (batch,m)).
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.scipy.linalg import solve_triangular

from . import sketch as sketch_lib
from .backend import resolve_backend_arg
from .lsqr import LSQRResult, lsqr

__all__ = ["saa_sas", "saa_sas_batch", "SAAResult", "default_sketch_size"]


class SAAResult(NamedTuple):
    x: jax.Array
    istop: jax.Array
    itn: jax.Array
    rnorm: jax.Array
    used_fallback: jax.Array  # bool

    @property
    def converged(self):
        return (self.istop > 0) & (self.istop != 7)


def default_sketch_size(n: int, m: int) -> int:
    """Paper regime: m ≫ s > n.  s = 4n is the usual CW sweet spot."""
    return int(min(max(4 * n, n + 16), max(m // 2, n + 1)))


def _estimate_2norm(A, key, iters: int = 25):
    """Power iteration on AᵀA for σ_max(A) (used by the fallback's σ)."""
    v = jax.random.normal(key, (A.shape[1],), A.dtype)
    v = v / jnp.linalg.norm(v)

    def body(_, v):
        w = A.T @ (A @ v)
        return w / jnp.maximum(jnp.linalg.norm(w), jnp.finfo(A.dtype).tiny)

    v = lax.fori_loop(0, iters, body, v)
    return jnp.linalg.norm(A @ v)


def _solve_with_factor(A, b, B, c, *, materialize_y, atol, btol, iter_lim, steptol):
    """Steps 3–6 of Algorithm 1 given the sketched pair (B, c)."""
    Q, R = jnp.linalg.qr(B, mode="reduced")  # HHQR
    z0 = Q.T @ c
    if materialize_y:
        # Y = A R⁻¹  ⇔  Rᵀ Yᵀ = Aᵀ (forward substitution on lower-tri Rᵀ).
        Y = solve_triangular(R, A.T, trans=1, lower=False).T
        res = lsqr(
            lambda z: Y @ z,
            lambda u: Y.T @ u,
            b,
            x0=z0,
            atol=atol,
            btol=btol,
            iter_lim=iter_lim,
            steptol=steptol,
        )
    else:
        # Operator form: Yz = A(R⁻¹z); Yᵀu = R⁻ᵀ(Aᵀu).
        def mv(z):
            return A @ solve_triangular(R, z, lower=False)

        def rmv(u):
            return solve_triangular(R, A.T @ u, trans=1, lower=False)

        res = lsqr(mv, rmv, b, x0=z0, atol=atol, btol=btol, iter_lim=iter_lim, steptol=steptol)
    x = solve_triangular(R, res.x, lower=False)  # back substitution
    return x, res


@resolve_backend_arg
@partial(
    jax.jit,
    static_argnames=(
        "sketch",
        "sketch_size",
        "materialize_y",
        "iter_lim",
        "use_fallback",
        "steptol",
        "atol",
        "btol",
        "backend",
    ),
)
def saa_sas(
    A: jax.Array,
    b: jax.Array,
    key: jax.Array,
    *,
    sketch: str = "clarkson_woodruff",
    sketch_size: int | None = None,
    atol: float = 0.0,
    btol: float = 0.0,
    steptol: float | None = None,
    iter_lim: int = 100,
    materialize_y: bool = True,
    use_fallback: bool = True,
    backend: str = "auto",
) -> SAAResult:
    """Solve min‖Ax − b‖ by Sketch-and-Apply (paper Algorithm 1)."""
    m, n = A.shape
    s = sketch_size if sketch_size is not None else default_sketch_size(n, m)
    if steptol is None:
        # z-space numerical floor of the whitened system (see lsqr docstring)
        steptol = 32 * float(jnp.finfo(A.dtype).eps)
    k_sketch, k_pert, k_norm = jax.random.split(key, 3)

    op = sketch_lib.sample(sketch, k_sketch, s, m, dtype=A.dtype)
    B = op.apply(A, backend=backend)
    c = op.apply(b, backend=backend)
    x, res = _solve_with_factor(
        A, b, B, c, materialize_y=materialize_y, atol=atol, btol=btol,
        iter_lim=iter_lim, steptol=steptol,
    )
    converged = (res.istop > 0) & (res.istop != 7)

    if not use_fallback:
        return SAAResult(
            x=x,
            istop=res.istop,
            itn=res.itn,
            rnorm=res.rnorm,
            used_fallback=jnp.asarray(False),
        )

    def ok_branch(_):
        return SAAResult(
            x=x,
            istop=res.istop,
            itn=res.itn,
            rnorm=res.rnorm,
            used_fallback=jnp.asarray(False),
        )

    def fallback_branch(_):
        # Lines 10–17: Ã = A + σ G/√m, σ = 10‖A‖₂u.
        u_round = jnp.asarray(jnp.finfo(A.dtype).eps / 2, A.dtype)
        sigma = 10.0 * _estimate_2norm(A, k_norm) * u_round
        G = jax.random.normal(k_pert, A.shape, A.dtype)
        A_t = A + sigma * G / jnp.sqrt(jnp.asarray(m, A.dtype))
        B2 = op.apply(A_t, backend=backend)
        x2, res2 = _solve_with_factor(
            A_t,
            b,
            B2,
            c,
            materialize_y=materialize_y,
            atol=atol,
            btol=btol,
            iter_lim=iter_lim,
            steptol=steptol,
        )
        return SAAResult(
            x=x2,
            istop=res2.istop,
            itn=res2.itn,
            rnorm=res2.rnorm,
            used_fallback=jnp.asarray(True),
        )

    return lax.cond(converged, ok_branch, fallback_branch, operand=None)


@resolve_backend_arg
@partial(
    jax.jit,
    static_argnames=(
        "sketch",
        "sketch_size",
        "materialize_y",
        "iter_lim",
        "steptol",
        "atol",
        "btol",
        "backend",
    ),
)
def saa_sas_batch(
    A: jax.Array,
    b: jax.Array,
    key: jax.Array,
    *,
    sketch: str = "clarkson_woodruff",
    sketch_size: int | None = None,
    atol: float = 0.0,
    btol: float = 0.0,
    steptol: float | None = None,
    iter_lim: int = 100,
    materialize_y: bool = True,
    backend: str = "auto",
) -> SAAResult:
    """Batched SAA-SAS: one operator draw amortized over many solves.

    Two layouts (the serving-style multi-query front-ends):

    - ``A (m, n), b (m, k)`` — one design matrix, k stacked right-hand
      sides.  The sketch, QR factor and (if ``materialize_y``) the whitened
      Y = A R⁻¹ are computed ONCE and shared; only the LSQR iterations run
      per-query (vmapped over columns of b).  Returns x of shape (n, k) and
      per-column istop/itn/rnorm.
    - ``A (batch, m, n), b (batch, m)`` — a batch of equally-shaped
      problems sharing ONE operator draw S.  The whole factor+solve is
      vmapped over the batch.  Returns x of shape (batch, n).

    The perturbation fallback of ``saa_sas`` is a per-problem control-flow
    feature and is not taken here (``used_fallback`` is always False);
    batch callers should re-solve non-converged lanes individually.  Note
    vmap-of-while semantics: all lanes keep iterating until every lane's
    stopping test fires (extra LSQR iterations past convergence are benign —
    the whitened system's updates just stall at the numerical floor).
    """
    if steptol is None:
        steptol = 32 * float(jnp.finfo(A.dtype).eps)
    kw = dict(atol=atol, btol=btol, iter_lim=iter_lim, steptol=steptol)

    if A.ndim == 2:
        if b.ndim != 2 or b.shape[0] != A.shape[0]:
            raise ValueError(
                f"multi-RHS mode needs b of shape ({A.shape[0]}, k), got {b.shape}"
            )
        m, n = A.shape
        s = sketch_size if sketch_size is not None else default_sketch_size(n, m)
        op = sketch_lib.sample(sketch, key, s, m, dtype=A.dtype)
        B = op.apply(A, backend=backend)
        C = op.apply(b, backend=backend)  # (s, k)
        Q, R = jnp.linalg.qr(B, mode="reduced")
        Z0 = Q.T @ C  # (n, k) warm starts

        if materialize_y:
            Y = solve_triangular(R, A.T, trans=1, lower=False).T

            def mv(z):
                return Y @ z

            def rmv(u):
                return Y.T @ u

        else:

            def mv(z):
                return A @ solve_triangular(R, z, lower=False)

            def rmv(u):
                return solve_triangular(R, A.T @ u, trans=1, lower=False)

        def solve_one(b_i, z0_i):
            return lsqr(mv, rmv, b_i, x0=z0_i, **kw)

        res = jax.vmap(solve_one, in_axes=(1, 1))(b, Z0)
        X = solve_triangular(R, res.x.T, lower=False)  # (n, k)
        return SAAResult(
            x=X,
            istop=res.istop,
            itn=res.itn,
            rnorm=res.rnorm,
            used_fallback=jnp.zeros(b.shape[1], bool),
        )

    if A.ndim == 3:
        if b.ndim != 2 or b.shape[0] != A.shape[0] or b.shape[1] != A.shape[1]:
            raise ValueError(
                f"problem-batch mode needs b of shape {A.shape[:2]}, got {b.shape}"
            )
        batch, m, n = A.shape
        s = sketch_size if sketch_size is not None else default_sketch_size(n, m)
        op = sketch_lib.sample(sketch, key, s, m, dtype=A.dtype)

        def solve_one(A_i, b_i):
            B = op.apply(A_i, backend=backend)
            c = op.apply(b_i, backend=backend)
            x, res = _solve_with_factor(
                A_i, b_i, B, c, materialize_y=materialize_y, **kw
            )
            return x, res.istop, res.itn, res.rnorm

        x, istop, itn, rnorm = jax.vmap(solve_one)(A, b)
        return SAAResult(
            x=x,
            istop=istop,
            itn=itn,
            rnorm=rnorm,
            used_fallback=jnp.zeros(batch, bool),
        )

    raise ValueError(f"A must be (m, n) or (batch, m, n), got shape {A.shape}")
