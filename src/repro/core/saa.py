"""SAA-SAS — Sketch-and-Apply (paper Algorithm 1).

  1. Draw S ∈ R^{s×m} (Clarkson–Woodruff by default, the paper's choice).
  2. B = SA, c = Sb.
  3. Householder QR of B (jnp.linalg.qr is Householder-based).
  4. Y = A R⁻¹ via triangular substitution (the "apply" step).
  5. Warm start z₀ = Qᵀ c.
  6. LSQR on min‖Y z − b‖ (Y has cond ≈ O(1) w.h.p. — fast convergence).
  7. Converged → x = R⁻¹ z (back substitution).
  8. Fallback (paper lines 10–17): perturb Ã = A + σG/√m with σ = 10‖A‖₂u,
     re-sketch, re-factor and re-solve.  (The paper's line 12 literally says
     "B = SA"; we sketch the perturbed Ã, which is the mathematically
     consistent reading — noted in DESIGN.md.)

Steps 2–5 and 7 are the shared :class:`repro.core.precond.SketchedFactor`:
``build`` (sketch + QR), ``warm_start`` (z₀ = Qᵀc), ``whiten_mv/rmv`` or
``materialize_whitened`` (the apply step), ``precondition`` (x = R⁻¹z).

The sketch apply (step 2) is the compute hot path and dispatches through
``repro.core.backend``: ``backend="reference"`` runs the pure-jnp operator
paths, ``backend="pallas"`` the TPU Pallas kernels in ``repro.kernels``
(interpret mode off-TPU), ``backend="auto"`` resolves per platform.
``backend`` is a static argument, so each choice compiles its own
executable and the dispatch is free at runtime.

``materialize_y=False`` gives the operator-form variant (computes R⁻¹v on the
fly inside LSQR) — same math, O(mn) less memory; this is the at-scale path
used by ``repro.core.distributed``.

``saa_sas_batch`` is the serving front-end: one operator draw + one QR
factor amortized across stacked right-hand sides (A (m,n), b (m,k)) or
across a batch of equally-shaped problems (A (batch,m,n), b (batch,m)).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from . import linop
from . import sketch as sketch_lib
from .backend import resolve_backend_arg
from .linop import estimate_2norm
from .lsqr import lsqr
from .precond import SketchedFactor, default_sketch_size
from .result import SolveResult

__all__ = ["saa_sas", "saa_sas_batch", "SAAResult", "default_sketch_size"]

# Superseded by the unified result type.  The alias keeps attribute access
# (res.x, res.itn, ...) working; field ORDER changed (arnorm inserted), so
# positional unpacking of the old 5-tuple is not preserved.
SAAResult = SolveResult


def _solve_with_factor(
    A, b, factor: SketchedFactor, c, *,
    materialize_y, atol, btol, iter_lim, steptol, history=False,
):
    """Steps 4–7 of Algorithm 1 given the sketched factor and c = Sb."""
    z0 = factor.warm_start(c)
    if materialize_y:
        Y = factor.materialize_whitened(A)
        mv, rmv = (lambda z: Y @ z), (lambda u: Y.T @ u)
    else:
        mv = partial(factor.whiten_mv, A)
        rmv = partial(factor.whiten_rmv, A)
    res = lsqr(
        mv, rmv, b, x0=z0, atol=atol, btol=btol, iter_lim=iter_lim,
        steptol=steptol, history=history,
    )
    x = factor.precondition(res.x)  # back substitution
    return x, res


@resolve_backend_arg
@partial(
    jax.jit,
    static_argnames=(
        "sketch",
        "sketch_size",
        "materialize_y",
        "iter_lim",
        "use_fallback",
        "steptol",
        "atol",
        "btol",
        "backend",
        "precision",
        "fused",
        "history",
    ),
)
def saa_sas(
    A,
    b: jax.Array,
    key: jax.Array,
    *,
    sketch: str = "clarkson_woodruff",
    sketch_size: int | None = None,
    atol: float = 0.0,
    btol: float = 0.0,
    steptol: float | None = None,
    iter_lim: int = 100,
    materialize_y: bool | None = None,
    use_fallback: bool = True,
    backend: str = "auto",
    precision: str = "full",
    fused: bool | None = None,
    history: bool = False,
) -> SolveResult:
    """Solve min‖Ax − b‖ by Sketch-and-Apply (paper Algorithm 1).

    ``A`` may be a dense array, a BCOO sparse matrix or a
    ``repro.core.linop`` operator.  ``materialize_y=None`` resolves to True
    for dense inputs and False otherwise (the operator-form path never
    densifies A or Y).  The perturbation fallback (paper lines 10–17) adds
    dense Gaussian noise to A, so it only exists on the dense path; for
    matrix-free inputs the first solve's result is returned as-is.
    """
    A = linop.as_operator(A)
    dense_input = isinstance(A, linop.DenseOperator)
    if materialize_y is None:
        materialize_y = dense_input
    m, n = A.shape
    if steptol is None:
        # z-space numerical floor of the whitened system (see lsqr docstring)
        steptol = 32 * float(jnp.finfo(A.dtype).eps)
    k_sketch, k_pert, k_norm = jax.random.split(key, 3)
    kw = dict(
        materialize_y=materialize_y, atol=atol, btol=btol,
        iter_lim=iter_lim, steptol=steptol, history=history,
    )

    factor, op = SketchedFactor.build(
        A, k_sketch, sketch=sketch, sketch_size=sketch_size, backend=backend,
        precision=precision, fused=fused,
    )
    c = op.apply(b, backend=backend)
    x, res = _solve_with_factor(A, b, factor, c, **kw)
    converged = (res.istop > 0) & (res.istop != 7)

    if not (use_fallback and dense_input):
        return res._replace(x=x, used_fallback=jnp.asarray(False))

    def ok_branch(_):
        return res._replace(x=x, used_fallback=jnp.asarray(False))

    def fallback_branch(_):
        # Lines 10–17: Ã = A + σ G/√m, σ = 10‖A‖₂u.
        u_round = jnp.asarray(jnp.finfo(A.dtype).eps / 2, A.dtype)
        sigma = 10.0 * estimate_2norm(A, k_norm) * u_round
        G = jax.random.normal(k_pert, A.shape, A.dtype)
        A_t = A.A + sigma * G / jnp.sqrt(jnp.asarray(m, A.dtype))
        factor2 = SketchedFactor.from_sketch(op.apply(A_t, backend=backend))
        x2, res2 = _solve_with_factor(A_t, b, factor2, c, **kw)
        return res2._replace(x=x2, used_fallback=jnp.asarray(True))

    return lax.cond(converged, ok_branch, fallback_branch, operand=None)


@resolve_backend_arg
@partial(
    jax.jit,
    static_argnames=(
        "sketch",
        "sketch_size",
        "materialize_y",
        "iter_lim",
        "steptol",
        "atol",
        "btol",
        "backend",
    ),
)
def saa_sas_batch(
    A,
    b: jax.Array,
    key: jax.Array,
    *,
    sketch: str = "clarkson_woodruff",
    sketch_size: int | None = None,
    atol: float = 0.0,
    btol: float = 0.0,
    steptol: float | None = None,
    iter_lim: int = 100,
    materialize_y: bool | None = None,
    backend: str = "auto",
) -> SolveResult:
    """Batched SAA-SAS: one operator draw amortized over many solves.

    Two layouts (the serving-style multi-query front-ends):

    - ``A (m, n), b (m, k)`` — one design matrix, k stacked right-hand
      sides.  The sketch, QR factor and (if ``materialize_y``) the whitened
      Y = A R⁻¹ are computed ONCE and shared; only the LSQR iterations run
      per-query (vmapped over columns of b).  Returns x of shape (n, k) and
      per-column istop/itn/rnorm.
    - ``A (batch, m, n), b (batch, m)`` — a batch of equally-shaped
      problems sharing ONE operator draw S.  The whole factor+solve is
      vmapped over the batch (``SketchedFactor`` is a pytree, so the factor
      itself vmaps).  Returns x of shape (batch, n).

    The perturbation fallback of ``saa_sas`` is a per-problem control-flow
    feature and is not taken here (``used_fallback`` is always False);
    batch callers should re-solve non-converged lanes individually.  Note
    vmap-of-while semantics: all lanes keep iterating until every lane's
    stopping test fires (extra LSQR iterations past convergence are benign —
    the whitened system's updates just stall at the numerical floor).
    """
    if steptol is None:
        steptol = 32 * float(jnp.finfo(A.dtype).eps)
    kw = dict(atol=atol, btol=btol, iter_lim=iter_lim, steptol=steptol)

    if getattr(A, "ndim", 2) == 2:
        # Multi-RHS mode accepts dense, BCOO or linop-operator design
        # matrices (the problem-batch mode below stays array-only).
        A = linop.as_operator(A)
        if materialize_y is None:
            materialize_y = isinstance(A, linop.DenseOperator)
        if b.ndim != 2 or b.shape[0] != A.shape[0]:
            raise ValueError(
                f"multi-RHS mode needs b of shape ({A.shape[0]}, k), got {b.shape}"
            )
        factor, op = SketchedFactor.build(
            A, key, sketch=sketch, sketch_size=sketch_size, backend=backend
        )
        C = op.apply(b, backend=backend)  # (s, k)
        Z0 = factor.warm_start(C)  # (n, k) warm starts

        if materialize_y:
            Y = factor.materialize_whitened(A)
            mv, rmv = (lambda z: Y @ z), (lambda u: Y.T @ u)
        else:
            mv = partial(factor.whiten_mv, A)
            rmv = partial(factor.whiten_rmv, A)

        def solve_one(b_i, z0_i):
            return lsqr(mv, rmv, b_i, x0=z0_i, **kw)

        res = jax.vmap(solve_one, in_axes=(1, 1))(b, Z0)
        X = factor.precondition(res.x.T)  # (n, k)
        return res._replace(x=X, used_fallback=jnp.zeros(b.shape[1], bool))

    if A.ndim == 3:
        if materialize_y is None:
            materialize_y = True
        if b.ndim != 2 or b.shape[0] != A.shape[0] or b.shape[1] != A.shape[1]:
            raise ValueError(
                f"problem-batch mode needs b of shape {A.shape[:2]}, got {b.shape}"
            )
        batch, m, n = A.shape
        s = sketch_size if sketch_size is not None else default_sketch_size(n, m)
        op = sketch_lib.sample(sketch, key, s, m, dtype=A.dtype)

        def solve_one(A_i, b_i):
            factor = SketchedFactor.from_sketch(op.apply(A_i, backend=backend))
            c = op.apply(b_i, backend=backend)
            x, res = _solve_with_factor(
                A_i, b_i, factor, c, materialize_y=materialize_y, **kw
            )
            return res._replace(x=x)

        res = jax.vmap(solve_one)(A, b)
        return res._replace(used_fallback=jnp.zeros(batch, bool))

    raise ValueError(f"A must be (m, n) or (batch, m, n), got shape {A.shape}")
