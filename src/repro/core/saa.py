"""SAA-SAS — Sketch-and-Apply (paper Algorithm 1).

  1. Draw S ∈ R^{s×m} (Clarkson–Woodruff by default, the paper's choice).
  2. B = SA, c = Sb.
  3. Householder QR of B (jnp.linalg.qr is Householder-based).
  4. Y = A R⁻¹ via triangular substitution (the "apply" step).
  5. Warm start z₀ = Qᵀ c.
  6. LSQR on min‖Y z − b‖ (Y has cond ≈ O(1) w.h.p. — fast convergence).
  7. Converged → x = R⁻¹ z (back substitution).
  8. Fallback (paper lines 10–17): perturb Ã = A + σG/√m with σ = 10‖A‖₂u,
     re-sketch, re-factor and re-solve.  (The paper's line 12 literally says
     "B = SA"; we sketch the perturbed Ã, which is the mathematically
     consistent reading — noted in DESIGN.md.)

``materialize_y=False`` gives the operator-form variant (computes R⁻¹v on the
fly inside LSQR) — same math, O(mn) less memory; this is the at-scale path
used by ``repro.core.distributed``.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.scipy.linalg import solve_triangular

from . import sketch as sketch_lib
from .lsqr import LSQRResult, lsqr

__all__ = ["saa_sas", "SAAResult", "default_sketch_size"]


class SAAResult(NamedTuple):
    x: jax.Array
    istop: jax.Array
    itn: jax.Array
    rnorm: jax.Array
    used_fallback: jax.Array  # bool

    @property
    def converged(self):
        return (self.istop > 0) & (self.istop != 7)


def default_sketch_size(n: int, m: int) -> int:
    """Paper regime: m ≫ s > n.  s = 4n is the usual CW sweet spot."""
    return int(min(max(4 * n, n + 16), max(m // 2, n + 1)))


def _estimate_2norm(A, key, iters: int = 25):
    """Power iteration on AᵀA for σ_max(A) (used by the fallback's σ)."""
    v = jax.random.normal(key, (A.shape[1],), A.dtype)
    v = v / jnp.linalg.norm(v)

    def body(_, v):
        w = A.T @ (A @ v)
        return w / jnp.maximum(jnp.linalg.norm(w), jnp.finfo(A.dtype).tiny)

    v = lax.fori_loop(0, iters, body, v)
    return jnp.linalg.norm(A @ v)


def _solve_with_factor(A, b, B, c, *, materialize_y, atol, btol, iter_lim, steptol):
    """Steps 3–6 of Algorithm 1 given the sketched pair (B, c)."""
    Q, R = jnp.linalg.qr(B, mode="reduced")  # HHQR
    z0 = Q.T @ c
    if materialize_y:
        # Y = A R⁻¹  ⇔  Rᵀ Yᵀ = Aᵀ (forward substitution on lower-tri Rᵀ).
        Y = solve_triangular(R, A.T, trans=1, lower=False).T
        res = lsqr(
            lambda z: Y @ z,
            lambda u: Y.T @ u,
            b,
            x0=z0,
            atol=atol,
            btol=btol,
            iter_lim=iter_lim,
            steptol=steptol,
        )
    else:
        # Operator form: Yz = A(R⁻¹z); Yᵀu = R⁻ᵀ(Aᵀu).
        def mv(z):
            return A @ solve_triangular(R, z, lower=False)

        def rmv(u):
            return solve_triangular(R, A.T @ u, trans=1, lower=False)

        res = lsqr(mv, rmv, b, x0=z0, atol=atol, btol=btol, iter_lim=iter_lim, steptol=steptol)
    x = solve_triangular(R, res.x, lower=False)  # back substitution
    return x, res


@partial(
    jax.jit,
    static_argnames=(
        "sketch",
        "sketch_size",
        "materialize_y",
        "iter_lim",
        "use_fallback",
        "steptol",
        "atol",
        "btol",
    ),
)
def saa_sas(
    A: jax.Array,
    b: jax.Array,
    key: jax.Array,
    *,
    sketch: str = "clarkson_woodruff",
    sketch_size: int | None = None,
    atol: float = 0.0,
    btol: float = 0.0,
    steptol: float | None = None,
    iter_lim: int = 100,
    materialize_y: bool = True,
    use_fallback: bool = True,
) -> SAAResult:
    """Solve min‖Ax − b‖ by Sketch-and-Apply (paper Algorithm 1)."""
    m, n = A.shape
    s = sketch_size if sketch_size is not None else default_sketch_size(n, m)
    if steptol is None:
        # z-space numerical floor of the whitened system (see lsqr docstring)
        steptol = 32 * float(jnp.finfo(A.dtype).eps)
    k_sketch, k_pert, k_norm = jax.random.split(key, 3)

    op = sketch_lib.sample(sketch, k_sketch, s, m, dtype=A.dtype)
    B = op.apply(A)
    c = op.apply(b)
    x, res = _solve_with_factor(
        A, b, B, c, materialize_y=materialize_y, atol=atol, btol=btol,
        iter_lim=iter_lim, steptol=steptol,
    )
    converged = (res.istop > 0) & (res.istop != 7)

    if not use_fallback:
        return SAAResult(
            x=x,
            istop=res.istop,
            itn=res.itn,
            rnorm=res.rnorm,
            used_fallback=jnp.asarray(False),
        )

    def ok_branch(_):
        return SAAResult(
            x=x,
            istop=res.istop,
            itn=res.itn,
            rnorm=res.rnorm,
            used_fallback=jnp.asarray(False),
        )

    def fallback_branch(_):
        # Lines 10–17: Ã = A + σ G/√m, σ = 10‖A‖₂u.
        u_round = jnp.asarray(jnp.finfo(A.dtype).eps / 2, A.dtype)
        sigma = 10.0 * _estimate_2norm(A, k_norm) * u_round
        G = jax.random.normal(k_pert, A.shape, A.dtype)
        A_t = A + sigma * G / jnp.sqrt(jnp.asarray(m, A.dtype))
        B2 = op.apply(A_t)
        x2, res2 = _solve_with_factor(
            A_t,
            b,
            B2,
            c,
            materialize_y=materialize_y,
            atol=atol,
            btol=btol,
            iter_lim=iter_lim,
            steptol=steptol,
        )
        return SAAResult(
            x=x2,
            istop=res2.istop,
            itn=res2.itn,
            rnorm=res2.rnorm,
            used_fallback=jnp.asarray(True),
        )

    return lax.cond(converged, ok_branch, fallback_branch, operand=None)
