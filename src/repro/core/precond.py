"""The sketched QR factor — the one reusable object behind every solver.

The paper's speed/accuracy claims all rest on the same construction: draw a
subspace embedding S (s×m, s ≪ m), sketch B = SA, and take the (reduced,
Householder) QR factor B = QR.  The triangular R is then simultaneously

- a **right preconditioner**: A R⁻¹ has all singular values in
  [1/(1+ε), 1/(1−ε)] w.h.p., where ε is the embedding distortion — so any
  Krylov or gradient iteration on the *whitened* operator Y = A R⁻¹
  converges at a κ-independent rate; and
- a **coordinate change** back to x-space: x = R⁻¹ z.

Before this module the sketch → QR → triangular-solve plumbing was copied
near-identically into ``saa.py`` (twice), ``sap.py`` and ``distributed.py``.
:class:`SketchedFactor` names it once; SAA-SAS, SAP-SAS, the batched and
distributed drivers, and the forward-stable solvers in
``repro.core.iterative`` are all built on it.

``SketchedFactor`` is a NamedTuple of arrays, hence a JAX pytree: it can be
carried through ``jit``, ``vmap`` (the batched solver), ``lax.cond`` (the
SAA fallback) and ``shard_map`` (the distributed driver, which assembles the
sketch with a psum and then builds the factor replicated).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.scipy.linalg import solve_triangular

from . import backend as backend_lib
from . import linop
from . import sketch as sketch_lib
from ..obs import trace as obs_trace

__all__ = ["SketchedFactor", "default_sketch_size", "distortion"]


def _lowp_operator(A, use_pallas: bool):
    """bf16-rounded copy of a dense(-backed) operator for the mixed sketch.

    Under pallas the bf16 array feeds the kernels directly (they accumulate
    in f32 and now *return* f32 for half inputs); under the reference
    backend the data is rounded to bf16 then upcast so XLA matmuls also
    accumulate in ≥ f32.  Only dense data admits the cast — sparse/custom
    operators raise, and the caller (or the certified driver) falls back to
    ``precision="full"``.
    """

    def cast(arr):
        low = arr.astype(jnp.bfloat16)
        return low if use_pallas else low.astype(jnp.float32)

    if isinstance(A, linop.DenseOperator):
        return linop.DenseOperator(A=cast(A.A))
    if isinstance(A, linop.TikhonovAugmented) and isinstance(
        A.op, linop.DenseOperator
    ):
        return linop.TikhonovAugmented.wrap(cast(A.op.A), A.reg)
    raise ValueError(
        "precision='mixed' needs a dense data matrix (or Tikhonov-augmented "
        f"dense); got {type(A).__name__}"
    )


def _sketch_apply(op, A, *, backend: str, precision: str):
    """B = S·A honouring ``precision`` — the unfused sketch-apply stage.

    Mixed precision rounds the data to bf16 before the apply and returns B
    in A's working dtype: the *sketch* is cheap/low-precision, everything
    downstream (QR, refinement, certificates) stays full precision.
    """
    A = linop.as_operator(A)
    if precision == "mixed":
        rb = backend_lib.resolve(backend)
        B = op.apply_op(_lowp_operator(A, rb.use_pallas), backend=backend)
        return B.astype(A.dtype)
    return op.apply_op(A, backend=backend)


def default_sketch_size(n: int, m: int) -> int:
    """Paper regime: m ≫ s > n.  s = 4n is the usual CW sweet spot.

    Clamped to s ≤ m: for nearly-square or underdetermined shapes the
    ``max(m // 2, n + 1)`` branch used to exceed m, building an over-tall
    sketch that embeds nothing (``select_method`` routes such shapes to
    ``direct``/``lsqr`` — the regime test ``s ≥ n + 1`` can then only pass
    when the sketch genuinely shrinks the row space).
    """
    s = int(min(max(4 * n, n + 16), max(m // 2, n + 1)))
    return max(min(s, m), 1)


def distortion(sketch_size: int, n: int) -> float:
    """A-priori embedding distortion estimate ε ≈ √(n/s).

    For the dense and CountSketch-style embeddings at s = Θ(n) this is the
    right order for the subspace distortion w.h.p.; it is what the damping
    and momentum coefficients of ``repro.core.iterative`` are derived from
    (Epperly 2024).  Clipped away from 1 so downstream rate formulas stay
    finite even for aggressive (s ≈ n) sketches.
    """
    return min((n / float(sketch_size)) ** 0.5, 0.99)


class SketchedFactor(NamedTuple):
    """QR factor of a sketch SA: preconditioner, whitener and warm-starter.

    ``Q`` is (s, n) with orthonormal columns, ``R`` is (n, n) upper
    triangular with B = SA = QR.  All methods are linear-algebra one-liners;
    they exist so every solver spells the same operation the same way.
    """

    Q: jax.Array  # (s, n) orthonormal columns of the sketched matrix
    R: jax.Array  # (n, n) upper triangular

    # ---------------------------------------------------------------- build
    @classmethod
    def from_sketch(cls, B: jax.Array) -> "SketchedFactor":
        """Factor an already-assembled sketch B = SA (HHQR)."""
        Q, R = jnp.linalg.qr(B, mode="reduced")
        return cls(Q=Q, R=R)

    @classmethod
    def build(
        cls,
        A,
        key: jax.Array,
        *,
        sketch: str = "clarkson_woodruff",
        sketch_size: int | None = None,
        backend: str = "auto",
        precision: str = "full",
        fused: bool | None = None,
    ):
        """Draw S, sketch A and factor: returns ``(factor, op)``.

        ``A`` may be a dense array, a BCOO matrix or any
        ``repro.core.linop`` operator — the sketch applies through
        ``op.apply_op`` (sparse inputs are sketched without densifying).
        The sketch operator ``op`` is returned so callers can sketch the
        right-hand side (``op.apply(b)`` → warm start) or re-sketch a
        perturbed matrix (the SAA fallback) with the SAME S.

        ``precision="mixed"`` sketches a bf16-rounded copy of a *dense* A
        (accumulating in ≥ f32); the factor comes back in A's dtype for the
        refinement loops, which recover — and the certificates verify —
        full working accuracy.  ``fused`` routes the build through the
        fused ``sketch_qr`` pipeline (``None`` → ``REPRO_FUSED_QR`` env,
        default off).
        """
        factor, op, _ = cls.build_full(
            A, key, sketch=sketch, sketch_size=sketch_size, backend=backend,
            precision=precision, fused=fused,
        )
        return factor, op

    @classmethod
    def build_full(
        cls,
        A,
        key: jax.Array,
        *,
        sketch: str = "clarkson_woodruff",
        sketch_size: int | None = None,
        backend: str = "auto",
        precision: str = "full",
        fused: bool | None = None,
    ):
        """:meth:`build` that also returns the assembled sketch:
        ``(factor, op, B)``.  The adaptive certified driver keeps B so a
        later :meth:`extend` reuses the stored rows bit-for-bit instead of
        re-sketching A."""
        if precision not in backend_lib.PRECISIONS:
            raise ValueError(
                f"unknown precision {precision!r}; have {backend_lib.PRECISIONS}"
            )
        A = linop.as_operator(A)
        if isinstance(A, linop.TikhonovAugmented):
            # Structured embedding blockdiag(S, I): sketch the data rows,
            # keep the (maximally coherent) regularization rows exact —
            # see sketch.AugmentedSketch for why random bucketing of the
            # identity block destroys the embedding.
            m_in, n = A.op.shape
            s = (
                sketch_size
                if sketch_size is not None
                else default_sketch_size(n, m_in)
            )
            inner = sketch_lib.sample(sketch, key, s, m_in, dtype=A.dtype)
            op = sketch_lib.AugmentedSketch(inner=inner, tail=n)
        else:
            m, n = A.shape
            s = (
                sketch_size
                if sketch_size is not None
                else default_sketch_size(n, m)
            )
            op = sketch_lib.sample(sketch, key, s, m, dtype=A.dtype)
        if backend_lib.resolve_fused(fused):
            from ..kernels.tsqr import sketch_qr  # kernels import core

            with obs_trace.span("factor.build", sketch=sketch, rows=s,
                                fused=True):
                Q, R, B = sketch_qr(op, A, backend=backend,
                                    precision=precision)
                obs_trace.maybe_block(R)
            return cls(Q=Q, R=R), op, B
        with obs_trace.span("factor.build", sketch=sketch, rows=s,
                            fused=False):
            with obs_trace.span("sketch.apply", kind=sketch,
                                precision=precision):
                B = _sketch_apply(op, A, backend=backend,
                                  precision=precision)
                obs_trace.maybe_block(B)
            with obs_trace.span("factor.qr", shape=tuple(B.shape)):
                factor = cls.from_sketch(B)
                obs_trace.maybe_block(factor.R)
        return factor, op, B

    @classmethod
    def build_streaming(
        cls,
        source,
        key: jax.Array,
        *,
        sketch: str = "clarkson_woodruff",
        sketch_size: int | None = None,
        backend: str = "auto",
    ):
        """Build the factor from a row-streamed A: returns ``(factor, op)``.

        ``source`` is anything ``repro.streaming.sources.as_source``
        accepts (RowSource, array, ``.npy`` path).  One pass over the
        tiles assembles B = SA through the mergeable accumulators of
        ``repro.streaming.accumulate`` — A is never resident; with the
        same ``key`` the operator draw is bit-identical to :meth:`build`
        on the materialized matrix.
        """
        from ..streaming.solve import stream_sketch  # streaming imports us

        B, op, _ = stream_sketch(
            source, key, sketch=sketch, sketch_size=sketch_size,
            backend=backend,
        )
        return cls.from_sketch(B), op

    # ----------------------------------------------------------- escalation
    def extend(
        self,
        A,
        op,
        key: jax.Array,
        extra: int,
        *,
        B: jax.Array | None = None,
        backend: str = "auto",
    ):
        """Grow the sketch by ``extra`` appended rows and re-QR.

        The adaptive repair move of ``lstsq(accuracy="certified")``: when a
        certificate fails, the embedding is escalated by appending fresh
        rows to S (``op.extend_rows`` — a weighted stack that embeds like a
        from-scratch draw at the larger size) and only those new rows are
        ever applied to A.  ``B`` is the stored sketch this factor was
        built from (``build_full``); when omitted it is reconstructed as
        Q·R (exact to rounding — pass B for the bit-exact path).  Returns
        ``(factor, op_new, B_new)``; the cost is one ``extra``-row sketch
        apply plus one (d + extra) × n QR, never a full re-sketch.
        """
        A = linop.as_operator(A)
        with obs_trace.span("factor.extend", extra=extra):
            op_new = op.extend_rows(key, extra)
            if B is None:
                B = self.Q @ self.R
            B_new = op_new.extend_sketch(B, A, backend=backend)
            with obs_trace.span("factor.qr", shape=tuple(B_new.shape)):
                factor = SketchedFactor.from_sketch(B_new)
                obs_trace.maybe_block(factor.R)
        return factor, op_new, B_new

    # ------------------------------------------------------------ shape info
    @property
    def n(self) -> int:
        return self.R.shape[-1]

    @property
    def sketch_size(self) -> int:
        return self.Q.shape[-2]

    # ------------------------------------------------- triangular primitives
    def precondition(self, z: jax.Array) -> jax.Array:
        """x = R⁻¹ z — z-space (whitened) back to x-space (back substitution)."""
        return solve_triangular(self.R, z, lower=False)

    def rt_solve(self, v: jax.Array) -> jax.Array:
        """R⁻ᵀ v (forward substitution on the lower-triangular Rᵀ)."""
        return solve_triangular(self.R, v, trans=1, lower=False)

    # --------------------------------------------------- whitened operator Y
    def whiten_mv(self, A, z: jax.Array) -> jax.Array:
        """Y z = A (R⁻¹ z) — operator-form matvec of the whitened system.

        ``A`` may be an array, a BCOO matrix or a linop operator (so the
        whitened system is matrix-free whenever A is)."""
        return linop.as_operator(A).matvec(self.precondition(z))

    def whiten_rmv(self, A, u: jax.Array) -> jax.Array:
        """Yᵀ u = R⁻ᵀ (Aᵀ u) — operator-form rmatvec of the whitened system."""
        return self.rt_solve(linop.as_operator(A).rmatvec(u))

    def materialize_whitened(self, A) -> jax.Array:
        """Y = A R⁻¹ explicitly (one n×n triangular solve against Aᵀ).

        O(mn) extra memory; trades the two triangular solves per iteration
        of the operator form for plain matmuls (the fast path when Y fits).
        For non-dense operators Y is assembled as A·R⁻¹ (n matvecs' worth
        of work, e.g. one O(nnz·n) product for BCOO inputs).
        """
        A = linop.as_operator(A)
        if isinstance(A, linop.DenseOperator):
            return self.rt_solve(A.A.T).T
        r_inv = solve_triangular(
            self.R, jnp.eye(self.n, dtype=self.R.dtype), lower=False
        )
        return A.matmat(r_inv)

    # ------------------------------------------------------------ warm start
    def warm_start(self, c: jax.Array) -> jax.Array:
        """z₀ = Qᵀ c with c = Sb — the sketch-and-solve solution in z-space.

        This is the minimizer of the *sketched* problem min‖B z − c‖, an
        O(ε)-accurate starting point for any iteration on the whitened
        system; using it is what makes the preconditioned solve start a
        constant factor from optimal rather than from zero.
        """
        return self.Q.T @ c

    def sketch_and_solve(self, c: jax.Array) -> jax.Array:
        """x̂ = R⁻¹ Qᵀ c — the plain sketch-and-solve estimate in x-space."""
        return self.precondition(self.warm_start(c))

    # ------------------------------------------------------- normal equations
    def normal_solve(self, g: jax.Array) -> jax.Array:
        """(RᵀR)⁻¹ g = (SA)ᵀ(SA) \\ g — the sketched-normal-equations solve.

        One forward + one back substitution; this is the per-iteration step
        of iterative sketching (``repro.core.iterative``), where
        g = Aᵀ(b − Ax) is the true gradient and RᵀR ≈ AᵀA its sketched
        Hessian.
        """
        return self.precondition(self.rt_solve(g))
