"""The paper's primary contribution: sketch-and-solve least squares.

- ``sketch``      — the six sketching operators (paper §2)
- ``certify``     — posterior certification: distortion probe, cond(R),
                    forward-error bound, ``Certificate`` (trust layer)
- ``backend``     — sketch-apply backend policy (reference jnp vs Pallas)
- ``linop``       — matrix-free ``LinearOperator`` input protocol
                    (dense / BCOO-sparse / Tikhonov / custom)
- ``precond``     — the shared sketched-QR factor (preconditioner/whitener)
- ``result``      — the unified ``SolveResult`` every solver returns
- ``lsqr``        — operator-form LSQR baseline/inner solver (paper §3.1)
- ``saa``         — SAA-SAS, Algorithm 1 (paper §4) + batched front-end
- ``sap``         — sketch-and-precondition baseline (paper §4)
- ``iterative``   — forward-stable iterative sketching + FOSSILS
- ``direct``      — deterministic QR/SVD ground truth
- ``lstsq``       — one-call driver that auto-selects among all of the above
- ``session``     — ``SketchedSolver``: one sketch+QR amortized over many
                    right-hand sides (serving front-end)
- ``problems``    — §5.1 ill-conditioned problem generator
- ``distributed`` — multi-pod row-sharded SAA-SAS (shard_map + psum)

Out-of-core inputs live in the sibling ``repro.streaming`` package
(``RowSource`` tiles, mergeable sketch accumulators, two-pass solvers);
``stream_lstsq`` and ``StreamingSolver`` are re-exported here lazily, and
``lstsq`` itself accepts a ``RowSource`` in place of A.
"""
from . import (
    backend,
    certify,
    direct,
    distributed,
    iterative,
    linop,
    lsqr,
    precond,
    problems,
    sap,
    session,
    sketch,
)
from .backend import BACKENDS, ResolvedBackend, resolve as resolve_backend
from .certify import (
    Certificate,
    certify as certify_solution,
    error_bound,
    probe_distortion,
)
from .direct import normal_equations, qr_solve, svd_solve
from .distributed import DistributedLSQResult, sketched_lstsq
from .iterative import (
    damping_momentum,
    fossils,
    fossils_refine,
    heavy_ball_refine,
    iterative_sketching,
)
from .linop import (
    CustomOperator,
    DenseOperator,
    LinearOperator,
    SparseOperator,
    TikhonovAugmented,
    as_operator,
    estimate_2norm,
)
from .lsqr import LSQRResult, lsqr as lsqr_solve, lsqr_dense, lsqr_operator
from .lstsq import ACCURACIES, CERTIFIED_LADDER, METHODS, TOL_SUPPORT, lstsq, select_method
from .precond import SketchedFactor, default_sketch_size, distortion
from .problems import Problem, generate as generate_problem
from .result import SolveResult
from .saa import SAAResult, saa_sas, saa_sas_batch
from .sap import sap_sas
from .session import SketchedSolver
from .sketch import (
    AugmentedSketch,
    SKETCH_KINDS,
    StackedSketch,
    fwht,
    sample as sample_sketch,
)

__all__ = [
    "backend", "certify", "direct", "distributed", "iterative", "linop",
    "lsqr", "precond", "problems", "sap", "session", "sketch",
    "BACKENDS", "ResolvedBackend", "resolve_backend",
    "Certificate", "certify_solution", "error_bound", "probe_distortion",
    "normal_equations", "qr_solve", "svd_solve",
    "DistributedLSQResult", "sketched_lstsq",
    "damping_momentum", "fossils", "fossils_refine", "heavy_ball_refine",
    "iterative_sketching",
    "LinearOperator", "DenseOperator", "SparseOperator",
    "TikhonovAugmented", "CustomOperator", "as_operator", "estimate_2norm",
    "LSQRResult", "lsqr_solve", "lsqr_dense", "lsqr_operator",
    "ACCURACIES", "CERTIFIED_LADDER", "METHODS", "TOL_SUPPORT", "lstsq",
    "select_method",
    "SketchedFactor", "default_sketch_size", "distortion",
    "Problem", "generate_problem",
    "SolveResult",
    "SAAResult", "saa_sas", "saa_sas_batch",
    "sap_sas",
    "SketchedSolver",
    "AugmentedSketch", "SKETCH_KINDS", "StackedSketch", "fwht",
    "sample_sketch",
    "stream_lstsq", "StreamingSolver",
]


def __getattr__(name):
    # repro.streaming imports repro.core at module scope; these re-exports
    # must therefore resolve lazily (PEP 562) to avoid the import cycle.
    if name in ("stream_lstsq", "StreamingSolver"):
        from ..streaming import solve as _streaming_solve

        return getattr(_streaming_solve, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
