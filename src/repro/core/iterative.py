"""Forward-stable iterative solvers on the shared sketched factor.

Plain sketch-and-solve (and sketch-and-precondition with a sketch-and-solve
warm start) is *not* forward stable: on ill-conditioned problems with a
non-negligible residual its forward error stagnates a κ(A)-dependent factor
above what Householder QR delivers.  Epperly ("Fast and forward stable
randomized algorithms for linear least-squares problems", 2024) and
Epperly–Meier–Nakatsukasa ("Fast randomized least-squares solvers can be
just as accurate and stable as classical direct solvers", 2024) give two
fixes, both powered by the SAME :class:`repro.core.precond.SketchedFactor`
that SAA-SAS already computes:

- :func:`iterative_sketching` — heavy-ball iteration in x-space.  Each step
  solves the *sketched* normal equations (RᵀR) d = Aᵀ(b − Ax) (two
  triangular solves) and updates x with damping α = (1 − ε²)² and momentum
  β = ε², where ε ≈ √(n/s) is the embedding distortion.  These are the
  optimal Polyak coefficients for a spectrum in [1/(1+ε)², 1/(1−ε)²], the
  whitened operator's range — so the error contracts by ≈ ε per iteration
  independent of κ(A).
- :func:`fossils` — sketch-and-precondition with iterative refinement.
  Starting from the sketch-and-solve estimate, each refinement step solves
  the *residual* system min‖A d − r‖ in the whitened coordinates z = R d by
  the same damped/momentum iteration, then adds R⁻¹z back.  Two refinement
  steps recover direct-method forward error (the FOSSILS scheme).

Both are jit/while_loop-native like ``lsqr``, dispatch their sketch applies
through ``repro.core.backend``, and return the unified
:class:`repro.core.result.SolveResult` (``history=True`` records residual
norms for diagnostics).
"""
from __future__ import annotations

import math
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from . import linop
from .backend import resolve_backend_arg
from .precond import SketchedFactor, default_sketch_size, distortion
from .result import SolveResult

__all__ = [
    "iterative_sketching",
    "fossils",
    "damping_momentum",
    "heavy_ball_refine",
    "fossils_refine",
]


def damping_momentum(sketch_size: int, n: int) -> tuple[float, float]:
    """Optimal heavy-ball (damping, momentum) for distortion ε ≈ √(n/s).

    α = (1 − ε²)², β = ε² — Polyak's coefficients for an operator whose
    squared singular values lie in [1/(1+ε)², 1/(1−ε)²] (Epperly 2024).
    """
    eps = distortion(sketch_size, n)
    return (1.0 - eps**2) ** 2, eps**2


# The error contracts geometrically while the iteration makes progress, so a
# new step-norm minimum appears every couple of iterations (heavy-ball
# steps oscillate with period ~2).  Once the step size stops reaching new
# minima for this many iterations, the iterate is bouncing around its
# numerical floor — declare convergence (istop=8).  This matters because the
# floor of the UNwhitened x-space steps is κ-dependent and cannot be given a
# universal ``steptol`` the way lsqr's whitened z-steps can.  The minimum is
# tracked on the ABSOLUTE step ‖Δx‖: the relative step ‖Δx‖/‖x‖ is
# scale-confounded while ‖x‖ itself is still collapsing from a far-off warm
# start (both shrink geometrically, so their ratio plateaus mid-convergence).
_STALL_LIMIT = 10
_IMPROVE_FACTOR = 0.99  # a step must beat the running min by ≥1% to count


class _StepFloor(NamedTuple):
    """Carry for the two-signal step-floor test shared by both solvers:
    consecutive relative steps below ``steptol``, OR step-norm stagnation
    (no new minimum for ``_STALL_LIMIT`` iterations)."""

    n_small: jax.Array
    min_step: jax.Array
    n_stall: jax.Array

    @classmethod
    def init(cls, dtype) -> "_StepFloor":
        return cls(
            n_small=jnp.asarray(0, jnp.int32),
            min_step=jnp.asarray(jnp.inf, dtype),
            n_stall=jnp.asarray(0, jnp.int32),
        )

    def update(self, stepnorm, relstep, steptol):
        """Returns (next_state, floor_reached)."""
        n_small = jnp.where(
            (steptol > 0) & (relstep <= steptol), self.n_small + 1, 0
        ).astype(jnp.int32)
        improved = stepnorm < _IMPROVE_FACTOR * self.min_step
        min_step = jnp.minimum(self.min_step, stepnorm)
        n_stall = jnp.where(improved, 0, self.n_stall + 1).astype(jnp.int32)
        nxt = _StepFloor(n_small=n_small, min_step=min_step, n_stall=n_stall)
        return nxt, (n_small >= 3) | (n_stall >= _STALL_LIMIT)


class _IterState(NamedTuple):
    itn: jax.Array
    istop: jax.Array
    x: jax.Array
    x_prev: jax.Array
    rnorm: jax.Array
    arnorm: jax.Array
    floor: _StepFloor
    rhist: jax.Array  # (iter_lim,) or (0,)


@partial(
    jax.jit,
    static_argnames=("atol", "btol", "steptol", "iter_lim", "history"),
)
def heavy_ball_refine(
    A,
    b: jax.Array,
    factor: SketchedFactor,
    x0: jax.Array,
    alpha,
    beta,
    *,
    atol: float = 0.0,
    btol: float = 0.0,
    steptol: float,
    iter_lim: int = 100,
    history: bool = False,
) -> SolveResult:
    """The damped/momentum iteration of :func:`iterative_sketching` against
    a PREBUILT factor.

    Factoring this out of the one-shot solver lets the certified adaptive
    driver (``repro.core.lstsq``) re-run the refinement after escalating an
    existing factor — the sketch is extended, never redrawn, and only this
    loop repeats.  Same stopping semantics as ``iterative_sketching``.
    """
    A = linop.as_operator(A)
    dtype = A.dtype
    tiny = jnp.finfo(dtype).tiny
    bnorm = jnp.linalg.norm(b)
    anorm = jnp.linalg.norm(factor.R)  # ‖R‖_F = ‖SA‖_F ≈ ‖A‖_F

    init = _IterState(
        itn=jnp.asarray(0, jnp.int32),
        istop=jnp.asarray(0, jnp.int32),
        x=x0,
        x_prev=x0,
        rnorm=jnp.asarray(jnp.inf, dtype),
        arnorm=jnp.asarray(jnp.inf, dtype),
        floor=_StepFloor.init(dtype),
        rhist=jnp.full((iter_lim if history else 0,), jnp.nan, dtype),
    )

    def cond(st: _IterState):
        return (st.istop == 0) & (st.itn < iter_lim)

    def body(st: _IterState):
        itn = st.itn + 1
        r = b - A.matvec(st.x)
        rnorm = jnp.linalg.norm(r)
        g = A.rmatvec(r)  # true gradient (up to sign)
        arnorm = jnp.linalg.norm(g)
        d = factor.normal_solve(g)  # sketched-Hessian solve
        dx = alpha * d + beta * (st.x - st.x_prev)
        x = st.x + dx

        xnorm = jnp.linalg.norm(x)
        stepnorm = jnp.linalg.norm(dx)
        relstep = stepnorm / jnp.maximum(xnorm, tiny)
        floor, floor_reached = st.floor.update(stepnorm, relstep, steptol)

        test1 = rnorm / jnp.where(bnorm > 0, bnorm, 1.0)
        denom = jnp.where(anorm * rnorm > 0, anorm * rnorm, 1.0)
        test2 = arnorm / denom
        rtol = btol + atol * anorm * xnorm / jnp.where(bnorm > 0, bnorm, 1.0)

        istop = jnp.asarray(0, jnp.int32)
        istop = jnp.where(itn >= iter_lim, 7, istop)
        istop = jnp.where(floor_reached, 8, istop)
        istop = jnp.where(test2 <= atol, 2, istop)
        istop = jnp.where(test1 <= rtol, 1, istop)

        rhist = st.rhist.at[itn - 1].set(rnorm) if history else st.rhist
        return _IterState(
            itn=itn,
            istop=istop.astype(jnp.int32),
            x=x,
            x_prev=st.x,
            rnorm=rnorm,
            arnorm=arnorm,
            floor=floor,
            rhist=rhist,
        )

    final = lax.while_loop(cond, body, init)
    # Report the residual of the RETURNED iterate (the loop's rnorm/arnorm
    # lag one update behind final.x).
    r = b - A.matvec(final.x)
    g = A.rmatvec(r)
    return SolveResult(
        x=final.x,
        istop=jnp.where(bnorm == 0, 0, final.istop),
        itn=final.itn,
        rnorm=jnp.linalg.norm(r),
        arnorm=jnp.linalg.norm(g),
        used_fallback=jnp.asarray(False),
        history=final.rhist if history else None,
    )


@resolve_backend_arg
@partial(
    jax.jit,
    static_argnames=(
        "sketch", "sketch_size", "damping", "momentum", "atol", "btol",
        "steptol", "iter_lim", "backend", "precision", "fused", "history",
    ),
)
def iterative_sketching(
    A,
    b: jax.Array,
    key: jax.Array,
    *,
    sketch: str = "clarkson_woodruff",
    sketch_size: int | None = None,
    damping: float | None = None,
    momentum: float | None = None,
    atol: float = 0.0,
    btol: float = 0.0,
    steptol: float | None = None,
    iter_lim: int = 100,
    backend: str = "auto",
    precision: str = "full",
    fused: bool | None = None,
    history: bool = False,
) -> SolveResult:
    """Iterative sketching with damping + momentum (forward stable).

    x₀ = sketch-and-solve; then
    x_{i+1} = x_i + α (RᵀR)⁻¹ Aᵀ(b − A x_i) + β (x_i − x_{i−1}).

    Stops on the step floor (istop=8) — either three consecutive relative
    steps below ``steptol`` or the step-norm stagnation test (no new
    minimum for ``_STALL_LIMIT`` iterations; the gradient is computed from
    the TRUE residual each iteration, so stagnation means the numerical
    floor, not sketch bias) — on residual tolerances (istop=1/2, SciPy
    semantics), or at ``iter_lim`` (istop=7).

    ``A`` may be a dense array, a BCOO sparse matrix or a
    ``repro.core.linop`` operator — only products with A are ever taken,
    so the solve is fully matrix-free.
    """
    A = linop.as_operator(A)
    m, n = A.shape
    s = sketch_size if sketch_size is not None else default_sketch_size(n, m)
    if steptol is None:
        steptol = 32 * float(jnp.finfo(A.dtype).eps)
    alpha, beta = damping_momentum(s, n)
    if damping is not None:
        alpha = damping
    if momentum is not None:
        beta = momentum

    factor, op = SketchedFactor.build(
        A, key, sketch=sketch, sketch_size=s, backend=backend,
        precision=precision, fused=fused,
    )
    x0 = factor.sketch_and_solve(op.apply(b, backend=backend))
    return heavy_ball_refine(
        A, b, factor, x0, alpha, beta,
        atol=atol, btol=btol, steptol=steptol, iter_lim=iter_lim,
        history=history,
    )


class _InnerState(NamedTuple):
    itn: jax.Array
    done: jax.Array  # bool: step floor reached
    z: jax.Array
    z_prev: jax.Array
    floor: _StepFloor


def _whitened_heavy_ball(
    factor: SketchedFactor, A, r, z0, *, alpha, beta, iter_lim, steptol
):
    """Heavy ball on min‖Y z − r‖, Y = A R⁻¹: the FOSSILS inner solve.

    Returns (z, iterations, hit_floor).  Runs as a while_loop, stopping on
    the z-space step floor (``steptol``, whitened coordinates) or on step
    stagnation — the same two-signal test as ``iterative_sketching``.
    """
    dtype = r.dtype
    tiny = jnp.finfo(dtype).tiny

    init = _InnerState(
        itn=jnp.asarray(0, jnp.int32),
        done=jnp.asarray(False),
        z=z0,
        z_prev=z0,
        floor=_StepFloor.init(dtype),
    )

    def cond(st: _InnerState):
        return (~st.done) & (st.itn < iter_lim)

    def body(st: _InnerState):
        g = factor.whiten_rmv(A, r - factor.whiten_mv(A, st.z))
        dz = alpha * g + beta * (st.z - st.z_prev)
        z = st.z + dz
        stepnorm = jnp.linalg.norm(dz)
        relstep = stepnorm / jnp.maximum(jnp.linalg.norm(z), tiny)
        floor, floor_reached = st.floor.update(stepnorm, relstep, steptol)
        return _InnerState(
            itn=st.itn + 1,
            done=floor_reached,
            z=z,
            z_prev=st.z,
            floor=floor,
        )

    final = lax.while_loop(cond, body, init)
    return final.z, final.itn, final.done


@partial(
    jax.jit,
    static_argnames=(
        "refine_steps", "inner_iter_lim", "steptol", "backend", "history",
    ),
)
def fossils_refine(
    A,
    b: jax.Array,
    factor: SketchedFactor,
    op,
    x0: jax.Array,
    alpha,
    beta,
    *,
    refine_steps: int = 2,
    inner_iter_lim: int,
    steptol: float,
    backend: str = "auto",
    history: bool = False,
) -> SolveResult:
    """The FOSSILS refinement passes against a PREBUILT (factor, op) pair.

    The factor-reusing core of :func:`fossils`, exposed for the certified
    adaptive driver: after a sketch escalation the same refinement re-runs
    on the extended factor, warm-starting each residual solve with the
    SAME (extended) operator — no fresh draw, no full re-sketch.
    """
    A = linop.as_operator(A)
    x = x0
    itn_total = jnp.asarray(0, jnp.int32)
    # refine_steps=0 means the raw sketch-and-solve estimate goes out
    # unrefined — never certify that as converged-to-floor.
    hit_floor = jnp.asarray(refine_steps > 0)
    rhist = []
    for _ in range(refine_steps):  # static unroll (refine_steps is tiny)
        r = b - A.matvec(x)
        rhist.append(jnp.linalg.norm(r))
        z0 = factor.warm_start(op.apply(r, backend=backend))
        z, itn, done = _whitened_heavy_ball(
            factor, A, r, z0,
            alpha=alpha, beta=beta, iter_lim=inner_iter_lim, steptol=steptol,
        )
        x = x + factor.precondition(z)
        itn_total = itn_total + itn
        hit_floor = hit_floor & done

    r = b - A.matvec(x)
    rnorm = jnp.linalg.norm(r)
    rhist.append(rnorm)
    g = A.rmatvec(r)

    istop = jnp.where(hit_floor, 8, 7).astype(jnp.int32)
    istop = jnp.where(jnp.linalg.norm(b) == 0, 0, istop)
    return SolveResult(
        x=x,
        istop=istop,
        itn=itn_total,
        rnorm=rnorm,
        arnorm=jnp.linalg.norm(g),
        used_fallback=jnp.asarray(False),
        history=jnp.stack(rhist) if history else None,
    )


def default_inner_iter_lim(beta: float, dtype=jnp.float64) -> int:
    """FOSSILS inner-iteration budget: error contracts by ≈ √β per step;
    budget to the numerical floor, with margin for the stall detector to
    certify it (istop=8)."""
    eps_mach = float(jnp.finfo(dtype).eps)
    rate = max(math.sqrt(beta), 1e-3)
    return min(int(math.log(eps_mach) / math.log(rate)) + 30, 500)


@resolve_backend_arg
@partial(
    jax.jit,
    static_argnames=(
        "sketch", "sketch_size", "refine_steps", "inner_iter_lim", "damping",
        "momentum", "steptol", "backend", "precision", "fused", "history",
    ),
)
def fossils(
    A,
    b: jax.Array,
    key: jax.Array,
    *,
    sketch: str = "clarkson_woodruff",
    sketch_size: int | None = None,
    refine_steps: int = 2,
    inner_iter_lim: int | None = None,
    damping: float | None = None,
    momentum: float | None = None,
    steptol: float | None = None,
    backend: str = "auto",
    precision: str = "full",
    fused: bool | None = None,
    history: bool = False,
) -> SolveResult:
    """FOSSILS-style sketch-and-precondition with iterative refinement.

    x₀ = sketch-and-solve; each of the ``refine_steps`` refinement passes
    solves the residual system min‖A d − r‖ in whitened coordinates with the
    damped/momentum inner iteration (warm-started from the *sketched*
    residual system, z₀ = Qᵀ(Sr), reusing the same operator S), then updates
    x ← x + R⁻¹z.  Two passes give direct-method forward error.

    ``history=True`` records the outer residual norms — a
    ``(refine_steps + 1,)`` array, entry 0 being the sketch-and-solve
    residual.  ``itn`` counts total inner iterations.

    Accepts dense arrays, BCOO matrices and ``repro.core.linop`` operators
    (matrix-free: only products with A are taken).
    """
    A = linop.as_operator(A)
    m, n = A.shape
    s = sketch_size if sketch_size is not None else default_sketch_size(n, m)
    if steptol is None:
        steptol = 32 * float(jnp.finfo(A.dtype).eps)
    alpha, beta = damping_momentum(s, n)
    if damping is not None:
        alpha = damping
    if momentum is not None:
        beta = momentum
    if inner_iter_lim is None:
        inner_iter_lim = default_inner_iter_lim(beta, A.dtype)

    factor, op = SketchedFactor.build(
        A, key, sketch=sketch, sketch_size=s, backend=backend,
        precision=precision, fused=fused,
    )
    x0 = factor.sketch_and_solve(op.apply(b, backend=backend))
    return fossils_refine(
        A, b, factor, op, x0, alpha, beta,
        refine_steps=refine_steps, inner_iter_lim=inner_iter_lim,
        steptol=steptol, backend=backend, history=history,
    )
