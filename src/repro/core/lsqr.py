"""LSQR (Paige & Saunders 1982) in JAX.

Operator-form least-squares solver: minimizes ``‖Ax − b‖₂`` given
``matvec(x) = A x`` and ``rmatvec(u) = Aᵀ u``.  Runs under ``jax.jit`` via
``lax.while_loop`` and inside ``shard_map`` (all reductions go through an
injectable ``dot``/norm so the distributed driver can psum them).

Supports a warm start ``x0`` (used by SAA-SAS with ``z₀ = Qᵀc``) by solving
for the correction ``dx`` against the residual ``b − A x₀``.

Returns the unified :class:`repro.core.result.SolveResult`; ``history=True``
additionally records the per-iteration residual norms into a fixed-length
``(iter_lim,)`` array (nan-padded past the final iteration).

istop codes follow SciPy's convention:
  0 x=0 is the exact solution;  1 residual-level convergence (btol/atol);
  2 least-squares convergence (AᵀR small);  7 iteration limit;
  8 (ours) step-size floor — three consecutive relative updates below
    ``steptol``.  This is the right test for SAA-SAS's *whitened* inner
    system, where the residual saturates at ‖r_opt‖ = β immediately (test1
    fires spuriously) and ‖Yᵀr‖/(‖Y‖‖r‖) has a rounding floor ≫ atol
    (test2 never fires); forward error instead tracks the z-step size,
    which decays geometrically because Y is a near-isometry.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from .result import SolveResult

__all__ = ["lsqr", "lsqr_dense", "lsqr_operator", "LSQRResult"]

# Superseded by the unified result type.  The alias keeps attribute access
# for the shared fields working; the old anorm/acond/xnorm diagnostics and
# the old positional field order are gone.
LSQRResult = SolveResult


class _State(NamedTuple):
    itn: jax.Array
    istop: jax.Array
    x: jax.Array
    u: jax.Array
    v: jax.Array
    w: jax.Array
    alfa: jax.Array
    rhobar: jax.Array
    phibar: jax.Array
    anorm2: jax.Array  # running ‖A‖_F² estimate
    acond: jax.Array
    ddnorm: jax.Array
    xnorm: jax.Array
    arnorm: jax.Array
    n_small_steps: jax.Array  # consecutive relative steps below steptol
    rhist: jax.Array  # (iter_lim,) residual history, or (0,) when disabled


def _sym_ortho(a, b):
    """Stable Givens rotation (c, s, r) with r = hypot(a, b)."""
    r = jnp.hypot(a, b)
    safe = jnp.where(r == 0, 1.0, r)
    c = jnp.where(r == 0, 1.0, a / safe)
    s = jnp.where(r == 0, 0.0, b / safe)
    return c, s, r


def lsqr(
    matvec: Callable,
    rmatvec: Callable,
    b: jax.Array,
    *,
    x0: jax.Array | None = None,
    n: int | None = None,
    atol: float = 1e-8,
    btol: float = 1e-8,
    conlim: float = 1e8,
    iter_lim: int | None = None,
    steptol: float = 0.0,
    vdot: Callable = jnp.vdot,
    udot: Callable = jnp.vdot,
    history: bool = False,
) -> SolveResult:
    """Minimize ‖Ax − b‖₂.

    ``udot`` is the inner product for m-space vectors (u, b) and ``vdot`` for
    n-space vectors — the distributed driver overrides ``udot`` with a
    psum-reducing dot when u/b are sharded across devices.  ``history=True``
    records per-iteration residual norms (fixed ``(iter_lim,)`` shape).
    """
    dtype = b.dtype

    def unorm(u):
        return jnp.sqrt(udot(u, u))

    def vnorm(v):
        return jnp.sqrt(vdot(v, v))

    # Warm start: iterate on the correction dx against r0 = b − A x0, but
    # keep the ORIGINAL ‖b‖ and ‖x0 + dx‖ in the stopping tests (the residual
    # ‖A(x0+dx) − b‖ is identical, so test1/test2 keep their usual meaning —
    # shifting bnorm to ‖r0‖ would make relative tolerances unreachable when
    # the warm start is already good).
    bnorm = unorm(b)
    if x0 is not None:
        x_base = x0
        b = b - matvec(x0)
        n = x0.shape[0]
    else:
        x_base = None

    v0 = rmatvec(b)
    if n is None:
        n = v0.shape[0]
    if iter_lim is None:
        iter_lim = 2 * n

    eps = jnp.finfo(dtype).eps
    beta = unorm(b)
    u = b / jnp.where(beta > 0, beta, 1.0)
    v_raw = rmatvec(u)
    alfa = vnorm(v_raw)
    v = v_raw / jnp.where(alfa > 0, alfa, 1.0)

    init = _State(
        itn=jnp.asarray(0, jnp.int32),
        istop=jnp.asarray(0, jnp.int32),
        x=jnp.zeros_like(v),
        u=u,
        v=v,
        w=v,
        alfa=alfa,
        rhobar=alfa,
        phibar=beta,
        anorm2=jnp.asarray(0.0, dtype),
        acond=jnp.asarray(0.0, dtype),
        ddnorm=jnp.asarray(0.0, dtype),
        xnorm=jnp.asarray(0.0, dtype),
        arnorm=alfa * beta,
        n_small_steps=jnp.asarray(0, jnp.int32),
        rhist=jnp.full((iter_lim if history else 0,), jnp.nan, dtype),
    )
    ctol = 0.0 if conlim <= 0 else 1.0 / conlim

    def cond(s: _State):
        return (s.istop == 0) & (s.itn < iter_lim)

    def body(s: _State):
        itn = s.itn + 1
        # Golub–Kahan bidiagonalization step.
        u_raw = matvec(s.v) - s.alfa * s.u
        beta_k = unorm(u_raw)
        u = u_raw / jnp.where(beta_k > 0, beta_k, 1.0)
        anorm2 = s.anorm2 + s.alfa**2 + beta_k**2
        v_raw = rmatvec(u) - beta_k * s.v
        alfa_k = vnorm(v_raw)
        v = v_raw / jnp.where(alfa_k > 0, alfa_k, 1.0)

        # Givens rotation to zero out beta_k of the bidiagonal system.
        c, sn, rho = _sym_ortho(s.rhobar, beta_k)
        theta = sn * alfa_k
        rhobar = -c * alfa_k
        phi = c * s.phibar
        phibar = sn * s.phibar

        t1 = phi / jnp.where(rho == 0, 1.0, rho)
        t2 = -theta / jnp.where(rho == 0, 1.0, rho)
        x = s.x + t1 * s.w
        dk = s.w / jnp.where(rho == 0, 1.0, rho)
        ddnorm = s.ddnorm + vdot(dk, dk)
        w = v + t2 * s.w

        anorm = jnp.sqrt(anorm2)
        acond = anorm * jnp.sqrt(ddnorm)
        rnorm = phibar
        arnorm = alfa_k * jnp.abs(sn * s.phibar)  # ‖Aᵀr‖ estimate
        x_full = x if x_base is None else x + x_base
        xnorm = jnp.sqrt(vdot(x_full, x_full))

        # Stopping tests (SciPy-compatible).
        test1 = rnorm / jnp.where(bnorm > 0, bnorm, 1.0)
        denom = jnp.where(anorm * rnorm > 0, anorm * rnorm, 1.0)
        test2 = arnorm / denom
        test3 = 1.0 / jnp.where(acond > 0, acond, 1.0)
        rtol = btol + atol * anorm * xnorm / jnp.where(bnorm > 0, bnorm, 1.0)

        # Step-size floor test (istop=8): relative z-update below steptol
        # for three consecutive iterations.
        step = jnp.abs(t1) * jnp.sqrt(vdot(s.w, s.w))
        relstep = step / jnp.maximum(xnorm, jnp.finfo(dtype).tiny)
        n_small = jnp.where(
            (steptol > 0) & (relstep <= steptol), s.n_small_steps + 1, 0
        ).astype(jnp.int32)

        istop = jnp.asarray(0, jnp.int32)
        istop = jnp.where(itn >= iter_lim, 7, istop)
        istop = jnp.where(n_small >= 3, 8, istop)
        istop = jnp.where(1 + test3 <= 1, 6, istop)
        istop = jnp.where(1 + test2 <= 1, 5, istop)
        istop = jnp.where(1 + test1 <= 1, 4, istop)
        istop = jnp.where(test3 <= ctol, 3, istop)
        istop = jnp.where(test2 <= atol, 2, istop)
        istop = jnp.where(test1 <= rtol, 1, istop)

        rhist = s.rhist.at[itn - 1].set(rnorm) if history else s.rhist

        return _State(
            itn=itn,
            istop=istop.astype(jnp.int32),
            x=x,
            u=u,
            v=v,
            w=w,
            alfa=alfa_k,
            rhobar=rhobar,
            phibar=phibar,
            anorm2=anorm2,
            acond=acond,
            ddnorm=ddnorm,
            xnorm=xnorm,
            arnorm=arnorm,
            n_small_steps=n_small,
            rhist=rhist,
        )

    final = lax.while_loop(cond, body, init)
    istop = jnp.where((bnorm == 0) | (init.arnorm == 0), 0, final.istop)
    x_out = final.x if x_base is None else final.x + x_base
    return SolveResult(
        x=x_out,
        istop=istop,
        itn=final.itn,
        rnorm=final.phibar,
        arnorm=final.arnorm,
        used_fallback=jnp.asarray(False),
        history=final.rhist if history else None,
    )


def lsqr_operator(A, b: jax.Array, **kw) -> SolveResult:
    """LSQR on ``jax.Array | BCOO | linop.LinearOperator`` inputs.

    The Golub–Kahan recurrence only takes products with A, so this is the
    natural entry point for sparse and matrix-free problems (and the only
    sketch-free iterative path, hence ``lstsq``'s keyless fallback).
    """
    from . import linop  # local import: linop is dependency-free, lsqr is hot

    A = linop.as_operator(A)
    return lsqr(A.matvec, A.rmatvec, b, n=A.shape[1], **kw)


def lsqr_dense(A, b: jax.Array, **kw) -> SolveResult:
    """LSQR with an explicit A (the paper's baseline configuration).

    Historical name — accepts everything :func:`lsqr_operator` does."""
    return lsqr_operator(A, b, **kw)
