"""Deterministic direct least-squares solvers (ground truth for tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.scipy.linalg import solve_triangular

__all__ = ["qr_solve", "svd_solve", "normal_equations"]


@jax.jit
def qr_solve(A: jax.Array, b: jax.Array) -> jax.Array:
    """x = R⁻¹ Qᵀ b via reduced Householder QR of A."""
    Q, R = jnp.linalg.qr(A, mode="reduced")
    return solve_triangular(R, Q.T @ b, lower=False)


@jax.jit
def svd_solve(A: jax.Array, b: jax.Array, rcond: float | None = None) -> jax.Array:
    """Minimum-norm LS solution via SVD (most robust, most expensive)."""
    x, *_ = jnp.linalg.lstsq(A, b, rcond=rcond)
    return x


@jax.jit
def normal_equations(A: jax.Array, b: jax.Array) -> jax.Array:
    """Cholesky on AᵀA — fast, squares the condition number (for comparison)."""
    G = A.T @ A
    return jax.scipy.linalg.cho_solve(jax.scipy.linalg.cho_factor(G), A.T @ b)
