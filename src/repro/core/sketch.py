"""Sketching operators (paper §2) with backend-dispatched applies.

Dense:  Gaussian, uniform-dense, SRHT (subsampled randomized Hadamard).
Sparse: CountSketch (Clarkson–Woodruff), sparse-sign(k), uniform-sparse.

All operators are functional pytrees: ``sample(kind, key, d, m)`` draws the
operator, ``op.apply(A, backend=...)`` applies it to an (m,) vector or (m, n)
matrix along axis 0. Every operator is scaled so that ``E[SᵀS] = I`` (an
isometry in expectation), which is the normalization the sketch-and-solve
analysis assumes. ``op.as_dense()`` materializes S (testing / small problems
only) and is backend-independent.

Backend dispatch (see ``repro.core.backend``): every ``apply`` takes a
``backend`` knob — ``"reference"`` runs the pure-jnp path in this module;
``"pallas"`` routes kernel-backed kinds to the TPU Pallas ops in
``repro.kernels`` (``countsketch_apply`` for CountSketch, ``srht_apply`` for
SRHT, ``fused_gaussian_sketch`` for Gaussian, ``sketch_matmul`` for
uniform-dense), in ``interpret=True`` mode off-TPU; ``"auto"`` picks
``"pallas"`` on TPU and ``"reference"`` elsewhere. Both backends of an
operator realize the SAME linear map S (the Gaussian S is drawn with the
kernels' counter-based threefry + Box–Muller stream so the fused kernel
regenerates it bit-for-bit), so backends agree to accumulation-order
rounding and can be swapped under any solver. Kinds without a kernel
(sparse-sign, uniform-sparse) fall back to the reference path.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp

from . import backend as backend_lib

__all__ = [
    "sample",
    "fwht",
    "GaussianSketch",
    "UniformDenseSketch",
    "SRHTSketch",
    "CountSketch",
    "SparseSignSketch",
    "UniformSparseSketch",
    "SKETCH_KINDS",
]


def _static(default=None):
    return dataclasses.field(metadata=dict(static=True), default=default)


def _kernels():
    """Lazy kernel import: repro.kernels imports this module (srht oracle)."""
    from .. import kernels

    return kernels


def fwht(x: jax.Array, axis: int = 0) -> jax.Array:
    """Unnormalized fast Walsh–Hadamard transform along ``axis``.

    Length along ``axis`` must be a power of two.  O(m log m) adds.
    """
    x = jnp.moveaxis(x, axis, 0)
    m = x.shape[0]
    if m & (m - 1):
        raise ValueError(f"FWHT length must be a power of two, got {m}")
    tail = x.shape[1:]
    h = m // 2
    while h >= 1:
        x = x.reshape((-1, 2, h) + tail)
        a, b = x[:, 0], x[:, 1]
        x = jnp.concatenate([a + b, a - b], axis=1)
        x = x.reshape((m,) + tail)
        h //= 2
    return jnp.moveaxis(x, 0, axis)


def _next_pow2(m: int) -> int:
    p = 1
    while p < m:
        p *= 2
    return p


def _as_2d(A):
    """Canonicalize (m,) -> (m, 1); returns (A2d, was_vector)."""
    if A.ndim == 1:
        return A[:, None], True
    return A, False


def _maybe_squeeze(B, was_vector):
    return B[:, 0] if was_vector else B


# --------------------------------------------------------------------------
# Dense operators
# --------------------------------------------------------------------------


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class GaussianSketch:
    """S with iid N(0, 1/d) entries.

    S is drawn from the counter-based threefry2x32 + Box–Muller stream of
    ``repro.kernels.sketch_matmul`` (element (i, j) ← counter pair (i, j)),
    so the ``"pallas"`` backend's ``fused_gaussian_sketch`` regenerates the
    SAME matrix inside the kernel from ``key`` alone — the materialized S
    never has to leave HBM on that path.
    """

    S: jax.Array
    key: jax.Array  # PRNG key the fused kernel regenerates S from
    d: int = _static()
    m: int = _static()

    @classmethod
    def sample(cls, key, d, m, dtype=jnp.float64):
        from ..kernels.sketch_matmul import gaussian_matrix_ref

        scale = jnp.float32(1.0 / float(d) ** 0.5)
        S = (gaussian_matrix_ref(key, d, m, jnp.float32) * scale).astype(dtype)
        return cls(S=S, key=key, d=d, m=m)

    def apply(self, A, *, backend: str = "auto"):
        rb = backend_lib.resolve(backend)
        if rb.use_pallas:
            return _kernels().fused_gaussian_sketch(
                A, self.key, self.d, interpret=rb.interpret
            )
        A2, vec = _as_2d(A)
        return _maybe_squeeze(self.S @ A2, vec)

    def as_dense(self):
        return self.S


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class UniformDenseSketch:
    """S with iid U(-sqrt(3/d), sqrt(3/d)) entries (unit row variance /d)."""

    S: jax.Array
    d: int = _static()
    m: int = _static()

    @classmethod
    def sample(cls, key, d, m, dtype=jnp.float64):
        lim = jnp.sqrt(jnp.asarray(3.0 / d, dtype))
        S = jax.random.uniform(key, (d, m), dtype, minval=-lim, maxval=lim)
        return cls(S=S, d=d, m=m)

    def apply(self, A, *, backend: str = "auto"):
        rb = backend_lib.resolve(backend)
        if rb.use_pallas:
            return _kernels().sketch_matmul(self.S, A, interpret=rb.interpret)
        A2, vec = _as_2d(A)
        return _maybe_squeeze(self.S @ A2, vec)

    def as_dense(self):
        return self.S


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SRHTSketch:
    """Subsampled randomized Hadamard transform: S = (1/sqrt(d)) P H D.

    H is the (unnormalized, power-of-two padded) Hadamard matrix, D a random
    sign diagonal, P a uniform row sample of size d.  Apply cost
    O(m log m · n) via the FWHT (reference) or the two-stage blocked
    Hadamard kernel (pallas).
    """

    signs: jax.Array  # (m_pad,)
    rows: jax.Array  # (d,) int32 indices into m_pad
    d: int = _static()
    m: int = _static()
    m_pad: int = _static()

    @classmethod
    def sample(cls, key, d, m, dtype=jnp.float64):
        m_pad = _next_pow2(m)
        k1, k2 = jax.random.split(key)
        signs = jax.random.rademacher(k1, (m_pad,), dtype)
        # sampling without replacement needs d <= m_pad; fall back to
        # with-replacement for oversampling sketches (valid SRHT variant)
        rows = jax.random.choice(k2, m_pad, (d,), replace=d > m_pad)
        return cls(signs=signs, rows=rows, d=d, m=m, m_pad=m_pad)

    def apply(self, A, *, backend: str = "auto"):
        rb = backend_lib.resolve(backend)
        if rb.use_pallas:
            return _kernels().srht_apply(
                A, self.signs, self.rows, self.d, interpret=rb.interpret
            )
        A2, vec = _as_2d(A)
        dtype = A2.dtype
        if self.m_pad != self.m:
            pad = [(0, self.m_pad - self.m)] + [(0, 0)] * (A2.ndim - 1)
            A2 = jnp.pad(A2, pad)
        HDx = fwht(self.signs[:, None].astype(dtype) * A2)
        B = HDx[self.rows] / jnp.sqrt(jnp.asarray(self.d, dtype))
        return _maybe_squeeze(B, vec)

    def as_dense(self):
        eye = jnp.eye(self.m, dtype=self.signs.dtype)
        return self.apply(eye, backend="reference")


# --------------------------------------------------------------------------
# Sparse operators
# --------------------------------------------------------------------------


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class CountSketch:
    """Clarkson–Woodruff: one ±1 per column of S, at a random bucket.

    SA[k] = sum_{i : h(i)=k} s(i) · A[i]  — an exact isometry in expectation
    with no scaling.  Apply cost O(nnz(A)) via segment_sum (reference) or
    the blocked one-hot-matmul kernel (pallas).
    """

    buckets: jax.Array  # (m,) int32 in [0, d)
    signs: jax.Array  # (m,)
    d: int = _static()
    m: int = _static()

    @classmethod
    def sample(cls, key, d, m, dtype=jnp.float64):
        k1, k2 = jax.random.split(key)
        buckets = jax.random.randint(k1, (m,), 0, d, dtype=jnp.int32)
        signs = jax.random.rademacher(k2, (m,), dtype)
        return cls(buckets=buckets, signs=signs, d=d, m=m)

    def apply(self, A, *, backend: str = "auto"):
        rb = backend_lib.resolve(backend)
        if rb.use_pallas:
            return _kernels().countsketch_apply(
                A, self.buckets, self.signs, self.d, interpret=rb.interpret
            )
        A2, vec = _as_2d(A)
        contrib = self.signs[:, None].astype(A2.dtype) * A2
        B = jax.ops.segment_sum(contrib, self.buckets, num_segments=self.d)
        return _maybe_squeeze(B, vec)

    def as_dense(self):
        S = jnp.zeros((self.d, self.m), self.signs.dtype)
        return S.at[self.buckets, jnp.arange(self.m)].set(self.signs)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SparseSignSketch:
    """k nonzeros (±1/sqrt(k)) per column of S at iid random buckets.

    No Pallas kernel yet — ``backend="pallas"`` falls back to the reference
    path (see ``repro.core.backend.KERNEL_BACKED_KINDS``).
    """

    buckets: jax.Array  # (k, m) int32
    signs: jax.Array  # (k, m)
    d: int = _static()
    m: int = _static()
    k: int = _static(default=8)

    @classmethod
    def sample(cls, key, d, m, dtype=jnp.float64, k=8):
        k1, k2 = jax.random.split(key)
        buckets = jax.random.randint(k1, (k, m), 0, d, dtype=jnp.int32)
        signs = jax.random.rademacher(k2, (k, m), dtype)
        return cls(buckets=buckets, signs=signs, d=d, m=m, k=k)

    def apply(self, A, *, backend: str = "auto"):
        del backend  # no kernel for this kind — reference path only
        A2, vec = _as_2d(A)

        def one(h, s):
            return jax.ops.segment_sum(
                s[:, None].astype(A2.dtype) * A2, h, num_segments=self.d
            )

        B = jax.vmap(one)(self.buckets, self.signs).sum(0)
        B = B / jnp.sqrt(jnp.asarray(self.k, A2.dtype))
        return _maybe_squeeze(B, vec)

    def as_dense(self):
        S = jnp.zeros((self.d, self.m), self.signs.dtype)
        cols = jnp.broadcast_to(jnp.arange(self.m), (self.k, self.m))
        scale = 1.0 / jnp.sqrt(jnp.asarray(self.k, self.signs.dtype))
        return S.at[self.buckets, cols].add(self.signs * scale)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class UniformSparseSketch:
    """One U(-sqrt(3), sqrt(3)) entry per column at a random bucket.

    No Pallas kernel yet — ``backend="pallas"`` falls back to the reference
    path (see ``repro.core.backend.KERNEL_BACKED_KINDS``).
    """

    buckets: jax.Array
    values: jax.Array
    d: int = _static()
    m: int = _static()

    @classmethod
    def sample(cls, key, d, m, dtype=jnp.float64):
        k1, k2 = jax.random.split(key)
        buckets = jax.random.randint(k1, (m,), 0, d, dtype=jnp.int32)
        lim = jnp.sqrt(jnp.asarray(3.0, dtype))
        values = jax.random.uniform(k2, (m,), dtype, minval=-lim, maxval=lim)
        return cls(buckets=buckets, values=values, d=d, m=m)

    def apply(self, A, *, backend: str = "auto"):
        del backend  # no kernel for this kind — reference path only
        A2, vec = _as_2d(A)
        contrib = self.values[:, None].astype(A2.dtype) * A2
        B = jax.ops.segment_sum(contrib, self.buckets, num_segments=self.d)
        return _maybe_squeeze(B, vec)

    def as_dense(self):
        S = jnp.zeros((self.d, self.m), self.values.dtype)
        return S.at[self.buckets, jnp.arange(self.m)].set(self.values)


SKETCH_KINDS: dict[str, type] = {
    "gaussian": GaussianSketch,
    "uniform_dense": UniformDenseSketch,
    "srht": SRHTSketch,
    "countsketch": CountSketch,
    "clarkson_woodruff": CountSketch,  # alias — the paper's final choice
    "sparse_sign": SparseSignSketch,
    "uniform_sparse": UniformSparseSketch,
}


def sample(kind: str, key: jax.Array, d: int, m: int, dtype=jnp.float64, **kw):
    """Draw a sketching operator ``S : R^m -> R^d`` of the given kind."""
    try:
        cls = SKETCH_KINDS[kind]
    except KeyError:
        raise ValueError(f"unknown sketch kind {kind!r}; have {sorted(SKETCH_KINDS)}")
    return cls.sample(key, d, m, dtype=dtype, **kw)
