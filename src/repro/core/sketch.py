"""Sketching operators (paper §2) with backend-dispatched applies.

Dense:  Gaussian, uniform-dense, SRHT (subsampled randomized Hadamard).
Sparse: CountSketch (Clarkson–Woodruff), sparse-sign(k), uniform-sparse.

All operators are functional pytrees: ``sample(kind, key, d, m)`` draws the
operator, ``op.apply(A, backend=...)`` applies it to an (m,) vector or (m, n)
matrix along axis 0, and ``op.apply_op(A)`` sketches a
``repro.core.linop`` operator (dense, BCOO-sparse or fully matrix-free —
see :class:`_OperatorApply`). Every operator is scaled so that ``E[SᵀS] = I`` (an
isometry in expectation), which is the normalization the sketch-and-solve
analysis assumes. ``op.as_dense()`` materializes S (testing / small problems
only) and is backend-independent.

Backend dispatch (see ``repro.core.backend``): every ``apply`` takes a
``backend`` knob — ``"reference"`` runs the pure-jnp path in this module;
``"pallas"`` routes kernel-backed kinds to the TPU Pallas ops in
``repro.kernels`` (``countsketch_apply`` for CountSketch, ``srht_apply`` for
SRHT, ``fused_gaussian_sketch`` for Gaussian, ``sketch_matmul`` for
uniform-dense), in ``interpret=True`` mode off-TPU; ``"auto"`` picks
``"pallas"`` on TPU and ``"reference"`` elsewhere. Both backends of an
operator realize the SAME linear map S (the Gaussian S is drawn with the
kernels' counter-based threefry + Box–Muller stream so the fused kernel
regenerates it bit-for-bit), so backends agree to accumulation-order
rounding and can be swapped under any solver. Kinds without a kernel
(sparse-sign, uniform-sparse) fall back to the reference path.

Row streaming: every kind also exposes ``apply_rows(tile, row_offset)`` —
the restriction of S to a contiguous row tile of A — and (except SRHT)
``restrict_cols(idx)``, the sub-operator S[:, idx].  These are the
primitives behind the out-of-core accumulators of ``repro.streaming``, the
session's delta-sketch row updates and the distributed per-shard sketch;
see the streaming contract on ``_OperatorApply``.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp

from . import backend as backend_lib

__all__ = [
    "sample",
    "fwht",
    "GaussianSketch",
    "UniformDenseSketch",
    "SRHTSketch",
    "CountSketch",
    "SparseSignSketch",
    "UniformSparseSketch",
    "AugmentedSketch",
    "StackedSketch",
    "SKETCH_KINDS",
]


def _static(default=None):
    return dataclasses.field(metadata=dict(static=True), default=default)


def _kernels():
    """Lazy kernel import: repro.kernels imports this module (srht oracle)."""
    from .. import kernels

    return kernels


def _tuned_blocks(kind: str, A, d: int) -> dict:
    """Autotuned block kwargs for a pallas dispatch (``{}`` = kernel defaults)."""
    m = A.shape[0]
    n = A.shape[1] if A.ndim > 1 else 1
    return backend_lib.kernel_blocks(kind, m, n, d, A.dtype)


def fwht(x: jax.Array, axis: int = 0) -> jax.Array:
    """Unnormalized fast Walsh–Hadamard transform along ``axis``.

    Length along ``axis`` must be a power of two.  O(m log m) adds.
    """
    x = jnp.moveaxis(x, axis, 0)
    m = x.shape[0]
    if m & (m - 1):
        raise ValueError(f"FWHT length must be a power of two, got {m}")
    tail = x.shape[1:]
    h = m // 2
    while h >= 1:
        x = x.reshape((-1, 2, h) + tail)
        a, b = x[:, 0], x[:, 1]
        x = jnp.concatenate([a + b, a - b], axis=1)
        x = x.reshape((m,) + tail)
        h //= 2
    return jnp.moveaxis(x, 0, axis)


def _next_pow2(m: int) -> int:
    p = 1
    while p < m:
        p *= 2
    return p


def _as_2d(A):
    """Canonicalize (m,) -> (m, 1); returns (A2d, was_vector)."""
    if A.ndim == 1:
        return A[:, None], True
    return A, False


def _maybe_squeeze(B, was_vector):
    return B[:, 0] if was_vector else B


def _bcoo_coords(M):
    """(rows, cols, data) of an unbatched 2-D BCOO, or None for layouts the
    scatter paths don't handle (batched / dense-tail BCOO)."""
    if getattr(M, "n_batch", 0) or getattr(M, "n_dense", 0):
        return None
    return M.indices[:, 0], M.indices[:, 1], M.data


class _OperatorApply:
    """Operator-aware sketching shared by every kind: B = S·A for A given as
    a :mod:`repro.core.linop` operator (dense, BCOO-sparse, Tikhonov or
    fully matrix-free) without materializing A unless the math forces it.

    Dispatch, in order:

    - ``DenseOperator``   → the classical backend-dispatched ``apply``.
    - ``SparseOperator``  → the sparse kinds scatter-add straight off A's
      BCOO coordinates in O(nnz(A)) (never jax's sparse×sparse spdot,
      whose cost explodes combinatorially); dense-S kinds run one
      dense×BCOO product; SRHT is an inherently dense transform, so it
      densifies A (documented cost).
    - ``TikhonovAugmented`` over a dense core → materialize (the augmented
      matrix is barely bigger than A) and take the fast kernel path.
    - anything else (matrix-free) → B = (Aᵀ·Sᵀ)ᵀ via one blocked rmatmat
      against the d dense columns of Sᵀ — d = O(n) adjoint products, the
      generic price of sketching an operator known only through products.
    """

    def apply_op(self, A, *, backend: str = "auto"):
        from . import linop

        A = linop.as_operator(A)
        if isinstance(A, linop.DenseOperator):
            return self.apply(A.A, backend=backend)
        if isinstance(A, linop.SparseOperator):
            return self._apply_bcoo(A.M, backend=backend)
        if isinstance(A, linop.TikhonovAugmented) and isinstance(
            A.op, linop.DenseOperator
        ):
            return self.apply(A.materialize(), backend=backend)
        St = self.as_dense_t().astype(A.dtype)
        return A.rmatmat(St).T

    def _apply_bcoo(self, M, *, backend: str = "auto"):
        S = getattr(self, "S", None)
        if S is not None:  # dense-S kinds: one dense × BCOO product
            out = S.astype(M.dtype) @ M
            return out.todense() if hasattr(out, "todense") else out
        # SRHT: the Hadamard transform is dense no matter what — densify.
        return self.apply(M.todense(), backend=backend)

    def as_dense_t(self):
        """Sᵀ as a dense (m, d) array — the generic matrix-free sketch path
        feeds these columns to the operator's rmatmat."""
        return self.as_dense().T

    # ------------------------------------------------------ row streaming
    # S is linear in the rows of A, so SA decomposes over any row tiling:
    # SA = Σ_t S[:, o_t:o_t+len(t)] · A[o_t:o_t+len(t)].  ``apply_rows``
    # is that per-tile restriction — the primitive behind the out-of-core
    # accumulators in ``repro.streaming.accumulate``.  ``row_offset`` is a
    # static Python int (the tile boundaries are host-side loop state).
    #
    # Contract per kind (see ``stream_semantics``):
    # - "add"   (five kinds): returns the (d, ncols) additive contribution;
    #   summing the tiles in any order reconstructs SA.
    # - "place" (SRHT only): the Hadamard transform couples every row, so
    #   the restriction returns the D-signed tile (t, ncols) instead; the
    #   accumulator places it at rows [offset, offset+t) of the padded
    #   buffer and applies H, P and the 1/√d scale ONCE at finalize.

    stream_semantics: str = "add"

    def apply_rows(self, tile, row_offset: int, *, backend: str = "auto"):
        raise NotImplementedError(
            f"{type(self).__name__} does not support row streaming"
        )

    def restrict_cols(self, idx):
        """S[:, idx] as a same-protocol operator over ``len(idx)`` rows, or
        ``None`` for kinds without an independent column restriction (SRHT —
        its columns couple through the Hadamard transform).  Powers the
        session's O(|idx|·n) delta-sketch row updates and the per-shard
        restriction of the distributed/streaming sketch assembly."""
        return None

    # -------------------------------------------------------- escalation
    # A failed certificate (repro.core.certify) is repaired by GROWING the
    # embedding, not redrawing it: ``extend_rows`` appends ``extra`` fresh
    # rows as the weighted stack S′ = [√(d/(d+e))·S; √(e/(d+e))·S_e].
    # The weights keep E[S′ᵀS′] = I, and the variance of ‖S′x‖² matches a
    # fresh (d+e)-row draw exactly — so the escalated operator embeds like
    # a from-scratch sketch at the larger size, while the already-paid
    # sketch B = SA is reused verbatim (``StackedSketch.extend_sketch``).

    def _fresh_like(self, key, extra: int):
        """An independent draw of this kind with ``extra`` rows over the
        same m-row space — the new block of an escalated sketch."""
        raise NotImplementedError(
            f"{type(self).__name__} does not support extend_rows"
        )

    def extend_rows(self, key, extra: int) -> "StackedSketch":
        """Escalate to d + ``extra`` rows without touching the first d.

        Returns a :class:`StackedSketch` whose top block is THIS operator
        (reweighted) and whose bottom block is a fresh ``extra``-row draw
        from ``key``; a stored sketch B = SA extends through
        ``StackedSketch.extend_sketch`` by sketching only the new rows.
        """
        extra = int(extra)
        if extra <= 0:
            raise ValueError(f"extra must be a positive row count, got {extra}")
        d = self.d
        return StackedSketch(
            top=self,
            bottom=self._fresh_like(key, extra),
            w_top=math.sqrt(d / (d + extra)),
            w_bottom=math.sqrt(extra / (d + extra)),
        )


# --------------------------------------------------------------------------
# Dense operators
# --------------------------------------------------------------------------


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class GaussianSketch(_OperatorApply):
    """S with iid N(0, 1/d) entries.

    S is drawn from the counter-based threefry2x32 + Box–Muller stream of
    ``repro.kernels.sketch_matmul`` (element (i, j) ← counter pair (i, j)),
    so the ``"pallas"`` backend's ``fused_gaussian_sketch`` regenerates the
    SAME matrix inside the kernel from ``key`` alone — the materialized S
    never has to leave HBM on that path.

    ``sample(..., materialize=False)`` skips storing S entirely (S=None):
    every column block is regenerated on demand from ``key`` via the same
    counters, bitwise identical to slicing the stored matrix.  This is the
    streaming configuration — for out-of-core m the (d, m) matrix S is as
    unstorable as A itself, and ``apply_rows`` only ever needs the (d, t)
    block of the current tile.
    """

    S: jax.Array | None
    key: jax.Array  # PRNG key the fused kernel regenerates S from
    d: int = _static()
    m: int = _static()

    @classmethod
    def sample(cls, key, d, m, dtype=jnp.float64, materialize=True):
        S = cls._gen_cols(key, d, jnp.arange(m), dtype) if materialize else None
        return cls(S=S, key=key, d=d, m=m)

    @staticmethod
    def _gen_cols(key, d, cols, dtype):
        """Columns S[:, cols] from the kernel's counter stream (exact)."""
        from ..kernels.sketch_matmul import gaussian_cols_ref

        scale = jnp.float32(1.0 / float(d) ** 0.5)
        return (gaussian_cols_ref(key, d, cols, jnp.float32) * scale).astype(dtype)

    def _cols(self, cols, dtype):
        if self.S is not None:
            return self.S[:, cols]
        return self._gen_cols(self.key, self.d, cols, dtype)

    def apply(self, A, *, backend: str = "auto"):
        rb = backend_lib.resolve(backend)
        if rb.use_pallas:
            blocks = _tuned_blocks("gaussian", A, self.d)
            return _kernels().fused_gaussian_sketch(
                A, self.key, self.d, interpret=rb.interpret, **blocks
            )
        A2, vec = _as_2d(A)
        S = self.S if self.S is not None else self.as_dense().astype(A2.dtype)
        return _maybe_squeeze(S @ A2, vec)

    def apply_rows(self, tile, row_offset: int, *, backend: str = "auto"):
        del backend  # one (d, t) × (t, n) block product either way
        tile2, _ = _as_2d(tile)
        t = tile2.shape[0]
        if self.S is not None:
            St = self.S[:, row_offset : row_offset + t]
        else:
            St = self._gen_cols(
                self.key, self.d, row_offset + jnp.arange(t), tile2.dtype
            )
        return St.astype(tile2.dtype) @ tile2

    def restrict_cols(self, idx):
        S = self._cols(idx, jnp.float64)
        return UniformDenseSketch(S=S, d=self.d, m=S.shape[1])

    def _fresh_like(self, key, extra):
        return GaussianSketch.sample(
            key, extra, self.m,
            dtype=self.S.dtype if self.S is not None else jnp.float64,
            materialize=self.S is not None,
        )

    def as_dense(self):
        if self.S is not None:
            return self.S
        return self._gen_cols(self.key, self.d, jnp.arange(self.m), jnp.float64)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class UniformDenseSketch(_OperatorApply):
    """S with iid U(-sqrt(3/d), sqrt(3/d)) entries (unit row variance /d)."""

    S: jax.Array
    d: int = _static()
    m: int = _static()

    @classmethod
    def sample(cls, key, d, m, dtype=jnp.float64):
        lim = jnp.sqrt(jnp.asarray(3.0 / d, dtype))
        S = jax.random.uniform(key, (d, m), dtype, minval=-lim, maxval=lim)
        return cls(S=S, d=d, m=m)

    def apply(self, A, *, backend: str = "auto"):
        rb = backend_lib.resolve(backend)
        if rb.use_pallas:
            blocks = _tuned_blocks("sketch_matmul", A, self.d)
            return _kernels().sketch_matmul(
                self.S, A, interpret=rb.interpret, **blocks
            )
        A2, vec = _as_2d(A)
        return _maybe_squeeze(self.S @ A2, vec)

    def apply_rows(self, tile, row_offset: int, *, backend: str = "auto"):
        del backend
        tile2, _ = _as_2d(tile)
        St = self.S[:, row_offset : row_offset + tile2.shape[0]]
        return St.astype(tile2.dtype) @ tile2

    def restrict_cols(self, idx):
        return UniformDenseSketch(S=self.S[:, idx], d=self.d, m=len(idx))

    def _fresh_like(self, key, extra):
        return UniformDenseSketch.sample(key, extra, self.m, dtype=self.S.dtype)

    def as_dense(self):
        return self.S


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SRHTSketch(_OperatorApply):
    """Subsampled randomized Hadamard transform: S = (1/sqrt(d)) P H D.

    H is the (unnormalized, power-of-two padded) Hadamard matrix, D a random
    sign diagonal, P a uniform row sample of size d.  Apply cost
    O(m log m · n) via the FWHT (reference) or the two-stage blocked
    Hadamard kernel (pallas).
    """

    signs: jax.Array  # (m_pad,)
    rows: jax.Array  # (d,) int32 indices into m_pad
    d: int = _static()
    m: int = _static()
    m_pad: int = _static()

    @classmethod
    def sample(cls, key, d, m, dtype=jnp.float64):
        m_pad = _next_pow2(m)
        k1, k2 = jax.random.split(key)
        signs = jax.random.rademacher(k1, (m_pad,), dtype)
        # sampling without replacement needs d <= m_pad; fall back to
        # with-replacement for oversampling sketches (valid SRHT variant)
        rows = jax.random.choice(k2, m_pad, (d,), replace=d > m_pad)
        return cls(signs=signs, rows=rows, d=d, m=m, m_pad=m_pad)

    def apply(self, A, *, backend: str = "auto"):
        rb = backend_lib.resolve(backend)
        if rb.use_pallas:
            blocks = _tuned_blocks("srht", A, self.d)
            return _kernels().srht_apply(
                A, self.signs, self.rows, self.d, interpret=rb.interpret, **blocks
            )
        A2, vec = _as_2d(A)
        dtype = A2.dtype
        if self.m_pad != self.m:
            pad = [(0, self.m_pad - self.m)] + [(0, 0)] * (A2.ndim - 1)
            A2 = jnp.pad(A2, pad)
        HDx = fwht(self.signs[:, None].astype(dtype) * A2)
        B = HDx[self.rows] / jnp.sqrt(jnp.asarray(self.d, dtype))
        return _maybe_squeeze(B, vec)

    # SRHT streams by placement, not addition: H mixes every row, so the
    # per-tile restriction is the D-signed tile and the transform runs once
    # at finalize (see ``_OperatorApply`` and ``repro.streaming.accumulate``).
    stream_semantics = "place"

    def apply_rows(self, tile, row_offset: int, *, backend: str = "auto"):
        """The D-signed rows of the tile — NOT the (d, n) contribution.

        The streaming accumulator writes these at rows
        [row_offset, row_offset + t) of its (m_pad, n) buffer; the padded
        FWHT, the row subsample P and the 1/√d scale are applied once at
        ``finalize`` — bit-for-bit the reference ``apply``.
        """
        del backend
        tile2, _ = _as_2d(tile)
        t = tile2.shape[0]
        signs = self.signs[row_offset : row_offset + t]
        return signs[:, None].astype(tile2.dtype) * tile2

    def _fresh_like(self, key, extra):
        return SRHTSketch.sample(key, extra, self.m, dtype=self.signs.dtype)

    def as_dense(self):
        eye = jnp.eye(self.m, dtype=self.signs.dtype)
        return self.apply(eye, backend="reference")

    def as_dense_t(self):
        # Sᵀ = (1/√d) D H Pᵀ: the d columns are H[:, rows] (H symmetric),
        # built with ONE fwht of the (m_pad, d) selector — O(d·m log m),
        # versus O(m²·log m) for as_dense().T via apply(eye(m)).
        dtype = self.signs.dtype
        sel = jnp.zeros((self.m_pad, self.d), dtype)
        sel = sel.at[self.rows, jnp.arange(self.d)].set(1.0)
        St = self.signs[:, None] * fwht(sel) / jnp.sqrt(jnp.asarray(self.d, dtype))
        return St[: self.m]


# --------------------------------------------------------------------------
# Sparse operators
# --------------------------------------------------------------------------


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class CountSketch(_OperatorApply):
    """Clarkson–Woodruff: one ±1 per column of S, at a random bucket.

    SA[k] = sum_{i : h(i)=k} s(i) · A[i]  — an exact isometry in expectation
    with no scaling.  Apply cost O(nnz(A)) via segment_sum (reference) or
    the blocked one-hot-matmul kernel (pallas).
    """

    buckets: jax.Array  # (m,) int32 in [0, d)
    signs: jax.Array  # (m,)
    d: int = _static()
    m: int = _static()

    @classmethod
    def sample(cls, key, d, m, dtype=jnp.float64):
        k1, k2 = jax.random.split(key)
        buckets = jax.random.randint(k1, (m,), 0, d, dtype=jnp.int32)
        signs = jax.random.rademacher(k2, (m,), dtype)
        return cls(buckets=buckets, signs=signs, d=d, m=m)

    def apply(self, A, *, backend: str = "auto"):
        rb = backend_lib.resolve(backend)
        if rb.use_pallas:
            blocks = _tuned_blocks("countsketch", A, self.d)
            return _kernels().countsketch_apply(
                A, self.buckets, self.signs, self.d, interpret=rb.interpret, **blocks
            )
        A2, vec = _as_2d(A)
        contrib = self.signs[:, None].astype(A2.dtype) * A2
        B = jax.ops.segment_sum(contrib, self.buckets, num_segments=self.d)
        return _maybe_squeeze(B, vec)

    def apply_rows(self, tile, row_offset: int, *, backend: str = "auto"):
        t = tile.shape[0]
        return self.restrict_cols(
            slice(row_offset, row_offset + t)
        ).apply(tile, backend=backend)

    def restrict_cols(self, idx):
        buckets, signs = self.buckets[idx], self.signs[idx]
        return CountSketch(
            buckets=buckets, signs=signs, d=self.d, m=buckets.shape[0]
        )

    def _fresh_like(self, key, extra):
        return CountSketch.sample(key, extra, self.m, dtype=self.signs.dtype)

    def as_dense(self):
        S = jnp.zeros((self.d, self.m), self.signs.dtype)
        return S.at[self.buckets, jnp.arange(self.m)].set(self.signs)

    def _apply_bcoo(self, M, *, backend: str = "auto"):
        # Row i of A lands in bucket h(i) with sign s(i); in coordinate
        # form that is one O(nnz) scatter-add — A is never densified.
        coords = _bcoo_coords(M)
        if coords is None:
            return self.apply(M.todense(), backend=backend)
        rows, cols, data = coords
        out = jnp.zeros((self.d, M.shape[1]), M.dtype)
        return out.at[self.buckets[rows], cols].add(
            self.signs[rows].astype(M.dtype) * data
        )


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SparseSignSketch(_OperatorApply):
    """k nonzeros (±1/sqrt(k)) per column of S at iid random buckets.

    No Pallas kernel yet — ``backend="pallas"`` falls back to the reference
    path (see ``repro.core.backend.KERNEL_BACKED_KINDS``).
    """

    buckets: jax.Array  # (k, m) int32
    signs: jax.Array  # (k, m)
    d: int = _static()
    m: int = _static()
    k: int = _static(default=8)

    @classmethod
    def sample(cls, key, d, m, dtype=jnp.float64, k=8):
        k1, k2 = jax.random.split(key)
        buckets = jax.random.randint(k1, (k, m), 0, d, dtype=jnp.int32)
        signs = jax.random.rademacher(k2, (k, m), dtype)
        return cls(buckets=buckets, signs=signs, d=d, m=m, k=k)

    def apply(self, A, *, backend: str = "auto"):
        del backend  # no kernel for this kind — reference path only
        A2, vec = _as_2d(A)

        def one(h, s):
            return jax.ops.segment_sum(
                s[:, None].astype(A2.dtype) * A2, h, num_segments=self.d
            )

        B = jax.vmap(one)(self.buckets, self.signs).sum(0)
        B = B / jnp.sqrt(jnp.asarray(self.k, A2.dtype))
        return _maybe_squeeze(B, vec)

    def apply_rows(self, tile, row_offset: int, *, backend: str = "auto"):
        t = tile.shape[0]
        return self.restrict_cols(
            slice(row_offset, row_offset + t)
        ).apply(tile, backend=backend)

    def restrict_cols(self, idx):
        buckets, signs = self.buckets[:, idx], self.signs[:, idx]
        return SparseSignSketch(
            buckets=buckets, signs=signs, d=self.d, m=buckets.shape[1], k=self.k
        )

    def _fresh_like(self, key, extra):
        return SparseSignSketch.sample(
            key, extra, self.m, dtype=self.signs.dtype, k=self.k
        )

    def as_dense(self):
        S = jnp.zeros((self.d, self.m), self.signs.dtype)
        cols = jnp.broadcast_to(jnp.arange(self.m), (self.k, self.m))
        scale = 1.0 / jnp.sqrt(jnp.asarray(self.k, self.signs.dtype))
        return S.at[self.buckets, cols].add(self.signs * scale)

    def _apply_bcoo(self, M, *, backend: str = "auto"):
        # k scatter targets per row of A: one O(k·nnz) coordinate scatter.
        coords = _bcoo_coords(M)
        if coords is None:
            return self.apply(M.todense(), backend=backend)
        rows, cols, data = coords
        hb = self.buckets[:, rows]  # (k, nnz)
        contrib = self.signs[:, rows].astype(M.dtype) * data  # (k, nnz)
        cols_k = jnp.broadcast_to(cols, hb.shape)
        out = jnp.zeros((self.d, M.shape[1]), M.dtype)
        out = out.at[hb.ravel(), cols_k.ravel()].add(contrib.ravel())
        return out / jnp.sqrt(jnp.asarray(self.k, M.dtype))


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class UniformSparseSketch(_OperatorApply):
    """One U(-sqrt(3), sqrt(3)) entry per column at a random bucket.

    No Pallas kernel yet — ``backend="pallas"`` falls back to the reference
    path (see ``repro.core.backend.KERNEL_BACKED_KINDS``).
    """

    buckets: jax.Array
    values: jax.Array
    d: int = _static()
    m: int = _static()

    @classmethod
    def sample(cls, key, d, m, dtype=jnp.float64):
        k1, k2 = jax.random.split(key)
        buckets = jax.random.randint(k1, (m,), 0, d, dtype=jnp.int32)
        lim = jnp.sqrt(jnp.asarray(3.0, dtype))
        values = jax.random.uniform(k2, (m,), dtype, minval=-lim, maxval=lim)
        return cls(buckets=buckets, values=values, d=d, m=m)

    def apply(self, A, *, backend: str = "auto"):
        del backend  # no kernel for this kind — reference path only
        A2, vec = _as_2d(A)
        contrib = self.values[:, None].astype(A2.dtype) * A2
        B = jax.ops.segment_sum(contrib, self.buckets, num_segments=self.d)
        return _maybe_squeeze(B, vec)

    def apply_rows(self, tile, row_offset: int, *, backend: str = "auto"):
        t = tile.shape[0]
        return self.restrict_cols(
            slice(row_offset, row_offset + t)
        ).apply(tile, backend=backend)

    def restrict_cols(self, idx):
        buckets, values = self.buckets[idx], self.values[idx]
        return UniformSparseSketch(
            buckets=buckets, values=values, d=self.d, m=buckets.shape[0]
        )

    def _fresh_like(self, key, extra):
        return UniformSparseSketch.sample(
            key, extra, self.m, dtype=self.values.dtype
        )

    def as_dense(self):
        S = jnp.zeros((self.d, self.m), self.values.dtype)
        return S.at[self.buckets, jnp.arange(self.m)].set(self.values)

    def _apply_bcoo(self, M, *, backend: str = "auto"):
        coords = _bcoo_coords(M)
        if coords is None:
            return self.apply(M.todense(), backend=backend)
        rows, cols, data = coords
        out = jnp.zeros((self.d, M.shape[1]), M.dtype)
        return out.at[self.buckets[rows], cols].add(
            self.values[rows].astype(M.dtype) * data
        )


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class AugmentedSketch(_OperatorApply):
    """blockdiag(S, I_tail): the structured embedding for Tikhonov systems.

    The rows of the √λ·I regularization block of ``[A; √λI]`` are
    maximally coherent (one spike each), exactly the inputs oblivious
    sparse sketches are worst at — bucketing them randomly wrecks the
    subspace embedding (observed whitened σ_max ≈ 15 with CountSketch at
    s = 4n, vs the ≈ 2 the analysis needs) and the fixed-coefficient
    heavy-ball solvers then diverge.  The fix is structural: sketch only
    the data block with ``inner`` and keep the identity block EXACT, so
    B = [S·A; √λI] and BᵀB = (SA)ᵀSA + λI — the embedding quality for the
    augmented system is exactly the inner sketch's quality on A.

    ``SketchedFactor.build`` constructs this automatically for
    ``TikhonovAugmented`` inputs; it quacks like the other sketch
    operators (``apply``/``apply_op``/``as_dense``) with
    d = inner.d + tail rows.
    """

    inner: object  # sketch operator over the data rows
    tail: int = _static()  # identity block size (= n of the augmented op)

    @property
    def d(self) -> int:
        return self.inner.d + self.tail

    @property
    def m(self) -> int:
        return self.inner.m + self.tail

    def apply(self, A, *, backend: str = "auto"):
        mi = self.inner.m
        top = self.inner.apply(A[:mi], backend=backend)
        return jnp.concatenate([top, A[mi:]], axis=0)

    def apply_op(self, A, *, backend: str = "auto"):
        from . import linop

        A = linop.as_operator(A)
        if isinstance(A, linop.TikhonovAugmented):
            top = self.inner.apply_op(A.op, backend=backend)
            eye = jnp.eye(self.tail, A.op.shape[1], dtype=top.dtype)
            return jnp.concatenate(
                [top, A._sqrt_reg.astype(top.dtype) * eye], axis=0
            )
        return super().apply_op(A, backend=backend)

    def as_dense(self):
        Sd = self.inner.as_dense()
        top = jnp.concatenate(
            [Sd, jnp.zeros((self.inner.d, self.tail), Sd.dtype)], axis=1
        )
        bot = jnp.concatenate(
            [
                jnp.zeros((self.tail, self.inner.m), Sd.dtype),
                jnp.eye(self.tail, dtype=Sd.dtype),
            ],
            axis=1,
        )
        return jnp.concatenate([top, bot], axis=0)

    def extend_rows(self, key, extra: int) -> "AugmentedSketch":
        """Escalate the DATA block only — the exact identity tail needs no
        growing (it is not a random embedding), so ridge escalation appends
        rows to the inner sketch and keeps blockdiag structure."""
        return AugmentedSketch(
            inner=self.inner.extend_rows(key, extra), tail=self.tail
        )

    def extend_sketch(self, B_top, A, *, backend: str = "auto"):
        """Incremental extension of a stored augmented sketch [S·A; √λI]:
        the data rows extend through the stacked inner operator, the exact
        tail rows move down unchanged.  Bit-equal to ``apply_op(A)`` of the
        escalated operator recomputed from scratch."""
        from . import linop

        if not isinstance(self.inner, StackedSketch):
            raise TypeError(
                "extend_sketch needs an operator produced by extend_rows; "
                f"inner is {type(self.inner).__name__}"
            )
        A = linop.as_operator(A)
        if not isinstance(A, linop.TikhonovAugmented):
            raise TypeError(
                "AugmentedSketch.extend_sketch sketches the data block of a "
                f"TikhonovAugmented operator, got {type(A).__name__}"
            )
        d_prev = self.inner.top.d
        B_data, B_tail = B_top[:d_prev], B_top[d_prev:]
        top = self.inner.extend_sketch(B_data, A.op, backend=backend)
        return jnp.concatenate([top, B_tail], axis=0)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class StackedSketch(_OperatorApply):
    """Weighted stack [w_t·S_top; w_b·S_bot] — the escalated sketch.

    Produced by ``op.extend_rows(key, extra)`` with w_t = √(d/(d+e)),
    w_b = √(e/(d+e)) so that E[SᵀS] = w_t²·I + w_b²·I = I stays an exact
    expectation-isometry and Var[‖Sx‖²] matches a fresh (d+e)-row draw of
    the same kind — escalation buys the full statistical benefit of the
    larger sketch.  The payoff is :meth:`extend_sketch`: a stored
    B = S_top·A extends to the (d+e)-row sketch by sketching ONLY the new
    rows (one ``extra``-row apply), bit-equal to applying the stacked
    operator to A from scratch — the escalation analogue of the streaming
    accumulators' merge-exactness contract.

    Nested escalations stack recursively (``top`` is the previous stack);
    ``_fresh_like`` always draws the ORIGINAL kind, so an escalated
    CountSketch stays a union of CountSketch blocks.
    """

    top: object  # the pre-escalation operator (d_top, m), reweighted
    bottom: object  # the fresh block (extra, m), independent draw
    w_top: float = _static()
    w_bottom: float = _static()

    @property
    def d(self) -> int:
        return self.top.d + self.bottom.d

    @property
    def m(self) -> int:
        return self.top.m

    def apply(self, A, *, backend: str = "auto"):
        top = self.top.apply(A, backend=backend)
        bot = self.bottom.apply(A, backend=backend)
        return jnp.concatenate([self.w_top * top, self.w_bottom * bot], axis=0)

    def apply_op(self, A, *, backend: str = "auto"):
        top = self.top.apply_op(A, backend=backend)
        bot = self.bottom.apply_op(A, backend=backend)
        return jnp.concatenate([self.w_top * top, self.w_bottom * bot], axis=0)

    def extend_sketch(self, B_top, A, *, backend: str = "auto"):
        """[w_t·B_top; w_b·(S_bot·A)] — extend a STORED sketch.

        ``B_top`` must be the sketch the top operator produced for this
        same A (``top.apply_op(A)``); only the ``bottom.d`` new rows are
        sketched.  Deterministic recomputation makes the result bit-equal
        to ``self.apply_op(A)`` from scratch (pinned in tests).
        """
        B_top = jnp.asarray(B_top)
        if B_top.shape[0] != self.top.d:
            raise ValueError(
                f"B_top has {B_top.shape[0]} rows, the pre-escalation "
                f"operator has d={self.top.d}"
            )
        bot = self.bottom.apply_op(A, backend=backend)
        return jnp.concatenate(
            [self.w_top * B_top, self.w_bottom * bot], axis=0
        )

    # both blocks must stream additively for the stack to stream at all
    # (an SRHT block streams by placement — route those through their own
    # accumulators and merge instead)
    @property
    def stream_semantics(self) -> str:  # type: ignore[override]
        both_add = (
            self.top.stream_semantics == "add"
            and self.bottom.stream_semantics == "add"
        )
        return "add" if both_add else "place"

    def apply_rows(self, tile, row_offset: int, *, backend: str = "auto"):
        if self.stream_semantics != "add":
            raise NotImplementedError(
                "a stacked sketch with an SRHT block streams by placement; "
                "accumulate the blocks separately"
            )
        top = self.top.apply_rows(tile, row_offset, backend=backend)
        bot = self.bottom.apply_rows(tile, row_offset, backend=backend)
        return jnp.concatenate([self.w_top * top, self.w_bottom * bot], axis=0)

    def restrict_cols(self, idx):
        top = self.top.restrict_cols(idx)
        bot = self.bottom.restrict_cols(idx)
        if top is None or bot is None:
            return None
        return StackedSketch(
            top=top, bottom=bot, w_top=self.w_top, w_bottom=self.w_bottom
        )

    def _fresh_like(self, key, extra):
        # nested escalation keeps drawing the ORIGINAL kind
        return self.top._fresh_like(key, extra)

    def as_dense(self):
        top = self.top.as_dense()
        bot = self.bottom.as_dense()
        return jnp.concatenate(
            [self.w_top * top, self.w_bottom * bot.astype(top.dtype)], axis=0
        )


SKETCH_KINDS: dict[str, type] = {
    "gaussian": GaussianSketch,
    "uniform_dense": UniformDenseSketch,
    "srht": SRHTSketch,
    "countsketch": CountSketch,
    "clarkson_woodruff": CountSketch,  # alias — the paper's final choice
    "sparse_sign": SparseSignSketch,
    "uniform_sparse": UniformSparseSketch,
}


def sample(kind: str, key: jax.Array, d: int, m: int, dtype=jnp.float64, **kw):
    """Draw a sketching operator ``S : R^m -> R^d`` of the given kind."""
    try:
        cls = SKETCH_KINDS[kind]
    except KeyError:
        raise ValueError(f"unknown sketch kind {kind!r}; have {sorted(SKETCH_KINDS)}")
    return cls.sample(key, d, m, dtype=dtype, **kw)
