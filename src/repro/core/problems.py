"""Ill-conditioned least-squares problem generator (paper §5.1, after [1]).

  A = U₁ Σ Vᵀ with Haar U₁ ∈ R^{m×n}, Haar V ∈ R^{n×n},
  Σ log-equispaced in [1, 1/κ];  x = w/‖w‖;  r ⟂ range(A), ‖r‖ = β;
  b = A x + r.   Then x is exactly argmin‖Ax−b‖ with residual norm β.

``method='haar'`` draws U₁ via QR of a Gaussian (exact Haar on the Stiefel
manifold; O(mn²)).  ``method='fast'`` skips the orthonormalization of the
left factor (Gaussian G in place of U₁) — condition number is then κ up to a
Marchenko–Pastur factor ≈ (1+√(n/m))/(1−√(n/m)) ≈ 1 for m ≫ n; used for the
large runtime sweeps where the QR itself would dominate generation time.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["generate", "Problem"]


class Problem(NamedTuple):
    A: jax.Array
    b: jax.Array
    x_true: jax.Array
    r_true: jax.Array
    cond: float
    beta: float


@partial(jax.jit, static_argnames=("m", "n", "method"))
def generate(
    key: jax.Array,
    m: int,
    n: int,
    *,
    cond: float = 1e10,
    beta: float = 1e-10,
    dtype=jnp.float64,
    method: str = "haar",
) -> Problem:
    if not m > n:
        raise ValueError(f"overdetermined problems need m > n, got {m}x{n}")
    k_u, k_v, k_w, k_z = jax.random.split(key, 4)

    G1 = jax.random.normal(k_u, (m, n), dtype)
    if method == "haar":
        U1, _ = jnp.linalg.qr(G1, mode="reduced")
    elif method == "fast":
        U1 = G1 / jnp.sqrt(jnp.asarray(m, dtype))  # ≈ orthonormal columns
    else:
        raise ValueError(f"unknown method {method!r}")

    V, _ = jnp.linalg.qr(jax.random.normal(k_v, (n, n), dtype), mode="reduced")
    log_k = jnp.log10(jnp.asarray(cond, dtype))
    sigma = jnp.logspace(0.0, -log_k, n, dtype=dtype)
    A = (U1 * sigma) @ V.T

    w = jax.random.normal(k_w, (n,), dtype)
    x = w / jnp.linalg.norm(w)

    # r = β · (component of a Gaussian orthogonal to range(A)).  For
    # method='haar', range(A) = range(U1) exactly so the projection makes x
    # the exact minimizer.  For 'fast' (runtime sweeps only, where x_true is
    # not consumed) we skip the O(mn²) projection: r is just a scaled
    # Gaussian and x_true is the minimizer only up to O(β).
    g = jax.random.normal(k_z, (m,), dtype)
    if method == "haar":
        v = g - U1 @ (U1.T @ g)
    else:
        v = g
    r = beta * v / jnp.linalg.norm(v)

    b = A @ x + r
    return Problem(A=A, b=b, x_true=x, r_true=r, cond=cond, beta=beta)
