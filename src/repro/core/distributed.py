"""Distributed sketch-and-solve (the paper's technique at cluster scale).

The tall matrix A (m × n, m ≫ n) is **row-sharded** across a mesh axis (or a
tuple of axes, e.g. ``('pod', 'data')`` on the multi-pod production mesh).
Every scatter-family sketch (CountSketch, sparse-sign, uniform-sparse) is a
linear row map with per-row parameters, so each shard sketches its local
rows into the *global* s-bucket space and one ``psum`` reconstructs
``SA = Σᵢ S A_i`` **exactly** — communication is a single s×(n+1) all-reduce,
independent of m.  (That psum is the collective form of the associative
partial-sketch merge in ``repro.streaming.accumulate``.)  The small QR runs
replicated; LSQR then runs distributed with row-sharded u-space vectors and
psum-reduced inner products (injected via ``lsqr(udot=...)``).

The sketch is the shared ``repro.core.sketch`` operator of the requested
kind: sampled ONCE at global size from ``key``, then its per-row parameter
arrays row-shard with A — each shard rewraps its slice into a local
operator of the same kind and calls the same backend-dispatched ``apply``
(reference segment_sum or the Pallas one-hot-matmul kernel, per
``backend=``).  Note the draw is NOT bit-identical to ``saa_sas(key)``'s:
that solver derives its sketch key via ``split(key, 3)`` (it also needs
perturbation/norm keys for the fallback).

This is the native multi-pod form of SAA-SAS: compute scales 1/P, the
collective term is O(s·n) per solve + O(n) per LSQR iteration.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import NamedSharding, PartitionSpec as P

from ..sharding import shard_map_compat
from . import backend as backend_lib
from . import linop
from . import sketch as sketch_lib
from .lsqr import lsqr
from .precond import SketchedFactor, default_sketch_size
from .result import SolveResult

__all__ = ["sketched_lstsq", "DistributedLSQResult", "shard_rows"]

# Superseded by the unified result type.  The alias keeps attribute access
# working; field order/arity changed (arnorm, used_fallback... added), so
# positional unpacking of the old 4-tuple is not preserved.
DistributedLSQResult = SolveResult


def shard_rows(mesh, axes, A, b):
    """Place (A, b) row-sharded over ``axes`` of ``mesh``."""
    A = jax.device_put(A, NamedSharding(mesh, P(axes, None)))
    b = jax.device_put(b, NamedSharding(mesh, P(axes)))
    return A, b


# Scatter-family kinds: per-row parameter arrays (field names) and the axis
# along which those arrays index rows of A — the axis that shards with A.
_ROW_PARAM_FIELDS = {
    sketch_lib.CountSketch: (("buckets", "signs"), 0),
    sketch_lib.UniformSparseSketch: (("buckets", "values"), 0),
    sketch_lib.SparseSignSketch: (("buckets", "signs"), 1),
}


def sketched_lstsq(
    A,
    b: jax.Array,
    key: jax.Array,
    *,
    mesh,
    axes=("data",),
    sketch: str = "clarkson_woodruff",
    sketch_size: int | None = None,
    atol: float = 0.0,
    btol: float = 0.0,
    steptol: float | None = None,
    iter_lim: int = 100,
    backend: str = "auto",
) -> SolveResult:
    """Distributed SAA-SAS.  ``A``/``b`` must be row-sharded over ``axes``.

    Jit-compatible; lowers to one psum of the s×(n+1) sketch + one psum per
    LSQR iteration (n-vector + 3 scalars).  ``backend`` selects the local
    sketch-apply implementation (see ``repro.core.backend``).

    ``sketch`` may be any scatter-family kind (``clarkson_woodruff`` /
    ``countsketch``, ``sparse_sign``, ``uniform_sparse``) — their per-row
    parameter arrays shard with A, so each shard's slice is itself a valid
    operator into the global bucket space.  The dense-S kinds and SRHT have
    no row-local parameters (S columns or the Hadamard coupling would have
    to replicate); use the single-host or streaming drivers for those.

    The row-sharded shard_map layout needs A's entries on-device, so
    non-dense inputs (BCOO, materializable operators) are densified here;
    dense arrays pass through untouched, preserving their placement.
    Non-materializable operators are rejected — use the single-host
    matrix-free solvers for those.
    """
    A = linop.ensure_dense(A, who="the distributed row-sharded driver")
    backend = backend_lib.resolve(backend).name
    if isinstance(axes, str):
        axes = (axes,)
    m, n = A.shape
    s = sketch_size if sketch_size is not None else default_sketch_size(n, m)
    if steptol is None:
        steptol = 32 * float(jnp.finfo(A.dtype).eps)
    cls = sketch_lib.SKETCH_KINDS.get(sketch)
    if cls is None:
        raise ValueError(
            f"unknown sketch kind {sketch!r}; have "
            f"{sorted(sketch_lib.SKETCH_KINDS)}"
        )
    if cls not in _ROW_PARAM_FIELDS:
        raise ValueError(
            f"sketch {sketch!r} has no per-row parameters to shard; the "
            "distributed driver supports the scatter kinds "
            "(clarkson_woodruff/countsketch, sparse_sign, uniform_sparse)"
        )
    # One global operator draw, shared by every shard; its per-row
    # parameter arrays row-shard with A.
    op = cls.sample(key, s, m, dtype=A.dtype)
    fields, row_axis = _ROW_PARAM_FIELDS[cls]
    params = tuple(getattr(op, f) for f in fields)
    param_spec = P(axes) if row_axis == 0 else P(None, axes)

    def local_solve(A_i, b_i, *params_i):
        # --- sketch locally into global bucket space, psum to assemble ----
        # Each shard's rows form a valid scatter sketch into the SAME
        # s-bucket space: rewrap the local parameter slices and reuse the
        # operator's backend-dispatched apply.  (Only the static d/k
        # metadata is read off the global op — its arrays are all replaced,
        # so nothing m-sized is captured replicated.)
        local_op = dataclasses.replace(
            op, m=A_i.shape[0], **dict(zip(fields, params_i))
        )
        SA = lax.psum(local_op.apply(A_i, backend=backend), axes)
        Sb = lax.psum(local_op.apply(b_i, backend=backend), axes)

        # --- replicated small factorization -------------------------------
        factor = SketchedFactor.from_sketch(SA)
        z0 = factor.warm_start(Sb)

        # --- distributed LSQR on Y = A R⁻¹ (operator form) ----------------
        # mv touches only local rows; rmv psums the shard contributions
        # (R is replicated and the triangular solve is linear, so solving
        # per-shard then psumming equals solving the psummed gradient).
        def mv(z):
            return factor.whiten_mv(A_i, z)

        def rmv(u):
            return lax.psum(factor.whiten_rmv(A_i, u), axes)

        def udot(u, w):
            return lax.psum(jnp.vdot(u, w), axes)

        res = lsqr(
            mv, rmv, b_i, x0=z0, n=n, atol=atol, btol=btol,
            steptol=steptol, iter_lim=iter_lim, udot=udot,
        )
        x = factor.precondition(res.x)
        return x, res.istop, res.itn, res.rnorm, res.arnorm

    row = P(axes)
    fn = shard_map_compat(
        local_solve,
        mesh=mesh,
        in_specs=(P(axes, None), row) + (param_spec,) * len(params),
        out_specs=(P(), P(), P(), P(), P()),
    )
    x, istop, itn, rnorm, arnorm = fn(A, b, *params)
    return SolveResult(
        x=x, istop=istop, itn=itn, rnorm=rnorm, arnorm=arnorm,
        used_fallback=jnp.asarray(False),
    )
