"""Distributed sketch-and-solve (the paper's technique at cluster scale).

The tall matrix A (m × n, m ≫ n) is **row-sharded** across a mesh axis (or a
tuple of axes, e.g. ``('pod', 'data')`` on the multi-pod production mesh).
CountSketch is a linear row-bucketing map, so each shard sketches its local
rows into the *global* s-bucket space and one ``psum`` reconstructs
``SA = Σᵢ S A_i`` **exactly** — communication is a single s×(n+1) all-reduce,
independent of m.  The small QR runs replicated; LSQR then runs distributed
with row-sharded u-space vectors and psum-reduced inner products (injected
via ``lsqr(udot=...)``).

This is the native multi-pod form of SAA-SAS: compute scales 1/P, the
collective term is O(s·n) per solve + O(n) per LSQR iteration.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.scipy.linalg import solve_triangular
from jax.sharding import NamedSharding, PartitionSpec as P

from .lsqr import lsqr
from .saa import default_sketch_size

__all__ = ["sketched_lstsq", "DistributedLSQResult", "shard_rows"]


class DistributedLSQResult(NamedTuple):
    x: jax.Array
    istop: jax.Array
    itn: jax.Array
    rnorm: jax.Array


def shard_rows(mesh, axes, A, b):
    """Place (A, b) row-sharded over ``axes`` of ``mesh``."""
    A = jax.device_put(A, NamedSharding(mesh, P(axes, None)))
    b = jax.device_put(b, NamedSharding(mesh, P(axes)))
    return A, b


def sketched_lstsq(
    A: jax.Array,
    b: jax.Array,
    key: jax.Array,
    *,
    mesh,
    axes=("data",),
    sketch_size: int | None = None,
    atol: float = 0.0,
    btol: float = 0.0,
    steptol: float | None = None,
    iter_lim: int = 100,
) -> DistributedLSQResult:
    """Distributed SAA-SAS.  ``A``/``b`` must be row-sharded over ``axes``.

    Jit-compatible; lowers to one psum of the s×(n+1) sketch + one psum per
    LSQR iteration (n-vector + 3 scalars).
    """
    if isinstance(axes, str):
        axes = (axes,)
    m, n = A.shape
    s = sketch_size if sketch_size is not None else default_sketch_size(n, m)
    if steptol is None:
        steptol = 32 * float(jnp.finfo(A.dtype).eps)
    k1, k2 = jax.random.split(key)
    buckets = jax.random.randint(k1, (m,), 0, s, dtype=jnp.int32)
    signs = jax.random.rademacher(k2, (m,), A.dtype)

    def local_solve(A_i, b_i, h_i, s_i):
        # --- sketch locally into global bucket space, psum to assemble ----
        SA = lax.psum(
            jax.ops.segment_sum(s_i[:, None] * A_i, h_i, num_segments=s), axes
        )
        Sb = lax.psum(jax.ops.segment_sum(s_i * b_i, h_i, num_segments=s), axes)

        # --- replicated small factorization -------------------------------
        Q, R = jnp.linalg.qr(SA, mode="reduced")
        z0 = Q.T @ Sb

        # --- distributed LSQR on Y = A R⁻¹ (operator form) ----------------
        def mv(z):
            return A_i @ solve_triangular(R, z, lower=False)

        def rmv(u):
            return lax.psum(
                solve_triangular(R, A_i.T @ u, trans=1, lower=False), axes
            )

        def udot(u, w):
            return lax.psum(jnp.vdot(u, w), axes)

        res = lsqr(
            mv, rmv, b_i, x0=z0, n=n, atol=atol, btol=btol,
            steptol=steptol, iter_lim=iter_lim, udot=udot,
        )
        x = solve_triangular(R, res.x, lower=False)
        return x, res.istop, res.itn, res.rnorm

    row = P(axes)
    fn = jax.shard_map(
        local_solve,
        mesh=mesh,
        in_specs=(P(axes, None), row, row, row),
        out_specs=(P(), P(), P(), P()),
        check_vma=False,  # outputs are replicated by construction (psum-fed)
    )
    x, istop, itn, rnorm = fn(A, b, buckets, signs)
    return DistributedLSQResult(x=x, istop=istop, itn=itn, rnorm=rnorm)
