"""Matrix-free linear operators — the input protocol of every solver.

The solvers in ``repro.core`` only ever touch the data matrix A through
products: ``A @ x`` (matvec), ``Aᵀ @ u`` (rmatvec) and their blocked
variants.  Nothing in the sketch-and-solve analysis requires A to be a
materialized dense array — sparse and implicitly-defined problems are
exactly where sketching wins biggest.  This module names that contract:

- :class:`LinearOperator` — the protocol: ``shape``, ``dtype``,
  ``matvec``/``rmatvec`` (vectors), ``matmat``/``rmatmat`` (blocks),
  ``materialize`` (dense A, when possible).
- :class:`DenseOperator` — wraps a ``jax.Array`` (the classical path; all
  solvers route dense inputs through it unchanged).
- :class:`SparseOperator` — wraps a ``jax.experimental.sparse`` BCOO
  matrix; products cost O(nnz) and A is never densified by the iterative
  solvers.
- :class:`TikhonovAugmented` — the ridge operator [A; √λ·Iₙ] behind
  ``lstsq(..., reg=λ)``: min‖Ax − b‖² + λ‖x‖² as a pure least-squares
  problem on the augmented system, no new solver code.
- :class:`CustomOperator` — adapts any (matvec, rmatvec) pair, including
  SciPy-style duck-typed operators.

``as_operator`` coerces ``jax.Array | BCOO | LinearOperator | duck-typed``
into the protocol; it is idempotent and is called at the top of every
solver, so user code can pass any of the three forms anywhere.

All concrete operators are registered JAX pytrees (array payloads are
leaves, shapes/dtypes/callables are static), so they pass through ``jit``,
``vmap``, ``lax.cond`` and ``shard_map`` exactly like plain arrays do.

``estimate_2norm`` is the shared power-iteration σ_max estimator (formerly
private copies in the solver modules); it works on anything
``as_operator`` accepts.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental.sparse import BCOO

__all__ = [
    "LinearOperator",
    "DenseOperator",
    "SparseOperator",
    "TikhonovAugmented",
    "CustomOperator",
    "as_operator",
    "ensure_dense",
    "estimate_2norm",
]


def _static(default=dataclasses.MISSING):
    return dataclasses.field(metadata=dict(static=True), default=default)


class LinearOperator:
    """Protocol base: a linear map R^n → R^m known only through products.

    Subclasses define ``shape``/``dtype``/``matvec``/``rmatvec``; the
    blocked ``matmat``/``rmatmat`` default to vmapping the vector products
    (override when a faster blocked form exists).  ``materialize`` returns
    the dense A for operators that can afford it (``materializable`` says
    which) — the direct solver and the distributed driver need it, the
    iterative solvers never call it.
    """

    # -- shape info ---------------------------------------------------------
    @property
    def shape(self) -> tuple[int, int]:
        raise NotImplementedError

    @property
    def dtype(self):
        raise NotImplementedError

    @property
    def ndim(self) -> int:
        return 2

    # -- products -----------------------------------------------------------
    def matvec(self, x: jax.Array) -> jax.Array:
        raise NotImplementedError

    def rmatvec(self, u: jax.Array) -> jax.Array:
        raise NotImplementedError

    def matmat(self, X: jax.Array) -> jax.Array:
        return jax.vmap(self.matvec, in_axes=1, out_axes=1)(X)

    def rmatmat(self, U: jax.Array) -> jax.Array:
        return jax.vmap(self.rmatvec, in_axes=1, out_axes=1)(U)

    def __matmul__(self, other):
        other = jnp.asarray(other)
        if other.ndim == 1:
            return self.matvec(other)
        if other.ndim == 2:
            return self.matmat(other)
        raise ValueError(f"operand must be 1- or 2-D, got ndim={other.ndim}")

    # -- materialization ----------------------------------------------------
    @property
    def materializable(self) -> bool:
        return False

    def materialize(self) -> jax.Array:
        raise TypeError(
            f"{type(self).__name__} cannot be materialized to a dense array; "
            "use a matrix-free solver (lstsq picks one automatically)"
        )


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class DenseOperator(LinearOperator):
    """A dense ``jax.Array`` seen through the operator protocol."""

    A: jax.Array

    @property
    def shape(self):
        return self.A.shape

    @property
    def dtype(self):
        return self.A.dtype

    def matvec(self, x):
        return self.A @ x

    def rmatvec(self, u):
        return self.A.T @ u

    def matmat(self, X):
        return self.A @ X

    def rmatmat(self, U):
        return self.A.T @ U

    @property
    def materializable(self):
        return True

    def materialize(self):
        return self.A


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SparseOperator(LinearOperator):
    """A ``jax.experimental.sparse`` BCOO matrix: O(nnz) products.

    The sparse sketches (CountSketch, sparse-sign, uniform-sparse) sketch a
    ``SparseOperator`` sparse-to-sparse, so A is never densified anywhere
    in the sketched-solver pipeline.
    """

    M: BCOO

    @property
    def shape(self):
        return self.M.shape

    @property
    def dtype(self):
        return self.M.dtype

    @property
    def nse(self) -> int:
        return self.M.nse

    def matvec(self, x):
        return self.M @ x

    def rmatvec(self, u):
        return self.M.T @ u

    def matmat(self, X):
        return self.M @ X

    def rmatmat(self, U):
        return self.M.T @ U

    @property
    def materializable(self):
        return True

    def materialize(self):
        return self.M.todense()


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class TikhonovAugmented(LinearOperator):
    """The ridge operator [A; √λ·Iₙ] of shape (m + n, n).

    min‖Ax − b‖² + λ‖x‖²  ==  min‖[A; √λI] x − [b; 0]‖², so any
    least-squares solver handles Tikhonov regularization through this
    operator with zero new solver code.  ``reg`` (= λ ≥ 0) is a pytree
    leaf, so re-solving with a different λ does not retrace.
    """

    op: LinearOperator
    reg: jax.Array

    @classmethod
    def wrap(cls, A, reg) -> "TikhonovAugmented":
        op = as_operator(A)
        return cls(op=op, reg=jnp.asarray(reg, op.dtype))

    @property
    def shape(self):
        m, n = self.op.shape
        return (m + n, n)

    @property
    def dtype(self):
        return self.op.dtype

    @property
    def _sqrt_reg(self):
        return jnp.sqrt(self.reg.astype(self.dtype))

    def matvec(self, x):
        return jnp.concatenate([self.op.matvec(x), self._sqrt_reg * x])

    def rmatvec(self, u):
        m, n = self.op.shape
        return self.op.rmatvec(u[:m]) + self._sqrt_reg * u[m:]

    def matmat(self, X):
        return jnp.concatenate([self.op.matmat(X), self._sqrt_reg * X], axis=0)

    def rmatmat(self, U):
        m, n = self.op.shape
        return self.op.rmatmat(U[:m]) + self._sqrt_reg * U[m:]

    def augment_rhs(self, b: jax.Array) -> jax.Array:
        """[b; 0ₙ] — the right-hand side of the augmented system."""
        n = self.op.shape[1]
        return jnp.concatenate([b, jnp.zeros((n,), b.dtype)])

    @property
    def materializable(self):
        return self.op.materializable

    def materialize(self):
        n = self.op.shape[1]
        eye = jnp.eye(n, dtype=self.dtype)
        return jnp.concatenate([self.op.materialize(), self._sqrt_reg * eye], axis=0)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class CustomOperator(LinearOperator):
    """Adapter for an arbitrary (matvec, rmatvec) pair.

    The callables are static pytree metadata: arrays they close over are
    baked into the jit trace as constants, so prefer
    :class:`DenseOperator`/:class:`SparseOperator` when the operator is
    just a stored matrix.  ``materialize_fn`` is optional; without it the
    operator is non-materializable and ``lstsq`` routes it to the
    matrix-free solvers.
    """

    matvec_fn: Callable = _static()
    rmatvec_fn: Callable = _static()
    op_shape: tuple[int, int] = _static()
    op_dtype: Any = _static()
    materialize_fn: Callable | None = _static(default=None)

    @property
    def shape(self):
        return self.op_shape

    @property
    def dtype(self):
        return self.op_dtype

    def matvec(self, x):
        return self.matvec_fn(x)

    def rmatvec(self, u):
        return self.rmatvec_fn(u)

    @property
    def materializable(self):
        return self.materialize_fn is not None

    def materialize(self):
        if self.materialize_fn is None:
            return super().materialize()
        return self.materialize_fn()


def as_operator(A) -> LinearOperator:
    """Coerce ``jax.Array | BCOO | LinearOperator | duck-typed`` to the
    protocol.  Idempotent; every solver calls it on its data-matrix input,
    so the whole stack accepts all three public forms interchangeably."""
    if isinstance(A, LinearOperator):
        return A
    if isinstance(A, BCOO):
        if A.ndim != 2:
            raise ValueError(f"need a 2-D matrix, got shape {A.shape}")
        return SparseOperator(A)
    if hasattr(A, "matvec") and hasattr(A, "rmatvec") and hasattr(A, "shape"):
        # SciPy-style duck-typed operator.
        dtype = getattr(A, "dtype", None)
        if dtype is None:
            raise TypeError(f"duck-typed operator {A!r} must expose .dtype")
        mat = getattr(A, "materialize", None)
        return CustomOperator(
            matvec_fn=A.matvec,
            rmatvec_fn=A.rmatvec,
            op_shape=tuple(A.shape),
            op_dtype=dtype,
            materialize_fn=mat,
        )
    A = jnp.asarray(A)
    if A.ndim != 2:
        raise ValueError(f"need a 2-D matrix, got shape {A.shape}")
    return DenseOperator(A)


def ensure_dense(A, *, who: str = "this solver") -> jax.Array:
    """Materialize ``A`` to a dense array or raise with a pointer to the
    matrix-free paths.  Used by the direct solver and the row-sharded
    distributed driver, whose algorithms genuinely need the entries."""
    op = as_operator(A)
    if isinstance(op, DenseOperator):
        return op.A  # no copy — preserves sharding/placement
    if not op.materializable:
        raise TypeError(
            f"{who} needs a materializable matrix, got {type(op).__name__}; "
            "use lstsq(method='iterative'/'fossils'/'saa'/'lsqr') for "
            "matrix-free inputs"
        )
    return op.materialize()


def estimate_2norm(A, key: jax.Array, iters: int = 25) -> jax.Array:
    """σ_max(A) by power iteration on AᵀA — the one shared 2-norm estimator.

    Accepts anything :func:`as_operator` does; only products with A are
    used.  (Supersedes the private per-solver copies: SAA-SAS's fallback σ
    and any future spectral-norm need route through here.)
    """
    A = as_operator(A)
    v = jax.random.normal(key, (A.shape[1],), A.dtype)
    v = v / jnp.linalg.norm(v)

    def body(_, v):
        w = A.rmatvec(A.matvec(v))
        return w / jnp.maximum(jnp.linalg.norm(w), jnp.finfo(A.dtype).tiny)

    v = lax.fori_loop(0, iters, body, v)
    return jnp.linalg.norm(A.matvec(v))
