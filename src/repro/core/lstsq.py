"""``lstsq`` — the one-call driver over every least-squares solver.

``lstsq(A, b, key)`` auto-selects among the package's solvers by shape,
sketch-size regime and requested accuracy, and always returns the unified
:class:`repro.core.result.SolveResult` (with ``.method`` naming the solver
that ran).  ``method=`` forces a specific solver:

=============  ============================================================
method         solver
=============  ============================================================
``direct``     Householder-QR ``qr_solve`` (ground truth; small problems)
``lsqr``       plain LSQR on A (no sketching; works without a key)
``saa``        SAA-SAS, paper Algorithm 1 (fastest sketched path)
``sap``        sketch-and-precondition baseline (paper §4)
``iterative``  iterative sketching with damping + momentum (forward stable)
``fossils``    sketch-and-precondition + iterative refinement (forward
               stable, direct-method accuracy)
=============  ============================================================

``A`` may be a dense ``jax.Array``, a ``jax.experimental.sparse`` BCOO
matrix, or any ``repro.core.linop`` operator (the matrix-free protocol) —
every solver above accepts all three.  ``reg=λ`` solves the Tikhonov/ridge
problem min‖Ax − b‖² + λ‖x‖² through the augmented operator [A; √λ·I]
(``linop.TikhonovAugmented``) with zero solver-specific code; the returned
``rnorm``/``arnorm`` are recomputed for the ORIGINAL system (``arnorm`` is
the ridge gradient norm ‖Aᵀ(b − Ax) − λx‖).

``A`` may ALSO be a ``repro.streaming`` row source (a ``RowSource``
instance) — an out-of-core matrix streamed one row tile at a time.  Those
inputs delegate to :func:`repro.streaming.solve.stream_lstsq` (also
re-exported here as ``stream_lstsq``), whose two-pass solvers never hold
A; ``method`` must then be one of its streaming methods (``"auto"``,
``"saa"``, ``"iterative"``, ``"sketch_and_solve"``).

Auto-selection (``method="auto"``):

- problems too small or too square for sketching to pay off → ``direct``
  (nearly-square and underdetermined shapes, where no sketch can shrink
  the row space, always land here / on ``lsqr``);
- large and strongly overdetermined with a PRNG key → a sketched solver by
  ``accuracy``: ``"fast"`` → ``saa``, ``"balanced"`` (default) →
  ``iterative``, ``"high"`` → ``fossils``;
- large but no key supplied → ``lsqr`` (the only deterministic iterative
  path);
- sparse / matrix-free inputs never select ``direct`` (it would densify
  A): with a key they go to the sketched iterative solvers, without one to
  ``lsqr``;
- with ``reg=λ`` the regime tests run on the ORIGINAL data shape, not the
  augmented ``(m + n, n)`` operator the solver ultimately sees (the
  appended √λ·I rows used to inflate m and mis-classify near-boundary
  ridge problems as sketchable).

``accuracy="certified"`` is the fourth, adaptive tier: solve, then
*certify* the answer with the posterior estimators of
``repro.core.certify`` (embedding-distortion probe, cond(R), a forward
error bound), and on a failed certificate escalate — append rows to the
sketch (the stored B = SA is extended, never recomputed) and climb the
method ladder saa → iterative → fossils → dense-QR fallback.  The result
carries a ``certificate`` with the bound that was finally certified.

The driver is a thin Python-level dispatch — every method underneath is its
own jitted, backend-dispatched solver, so there is no extra trace or
runtime cost over calling the solver directly.

Tolerance forwarding is explicit: each method supports a documented
subset of ``atol``/``btol``/``steptol``/``iter_lim`` (see
``TOL_SUPPORT``).  Forcing a method while passing a knob it does not
consume raises; under ``method="auto"`` unsupported knobs are dropped
(the selected method may legitimately vary with shape).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from . import backend as backend_lib
from . import certify as certify_lib
from . import linop
from ..obs import trace as obs_trace
from .direct import qr_solve
from .iterative import (
    damping_momentum,
    default_inner_iter_lim,
    fossils,
    fossils_refine,
    heavy_ball_refine,
    iterative_sketching,
)
from .lsqr import lsqr_operator
from .precond import SketchedFactor, default_sketch_size
from .result import SolveResult
from .saa import _solve_with_factor, saa_sas
from .sap import sap_sas

__all__ = [
    "lstsq",
    "select_method",
    "stream_lstsq",
    "METHODS",
    "ACCURACIES",
    "TOL_SUPPORT",
]


def __getattr__(name):
    # Lazy re-export: repro.streaming imports repro.core at module scope,
    # so the streaming driver can only be pulled in on first access.
    if name == "stream_lstsq":
        from ..streaming.solve import stream_lstsq

        return stream_lstsq
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

METHODS = ("direct", "lsqr", "saa", "sap", "iterative", "fossils")
ACCURACIES = ("fast", "balanced", "high", "certified")
_ALIASES = {"iterative_sketching": "iterative", "qr": "direct"}

# m·n² flops below which Householder QR is effectively free and sketching
# overhead (operator draw + sketch + small QR) cannot pay for itself.
DIRECT_FLOP_CUTOFF = 1 << 26

_SKETCHED_BY_ACCURACY = {"fast": "saa", "balanced": "iterative", "high": "fossils"}

# Which tolerance knobs each method actually consumes (the explicit
# forwarding audit): ``direct`` takes none (one exact factorization),
# ``fossils`` controls its budget through refinement/inner-loop parameters
# and only honours the step floor.  Forcing a method with a knob outside
# its set raises; under auto-selection unsupported knobs are dropped.
_TOL_KEYS = ("atol", "btol", "steptol", "iter_lim")
TOL_SUPPORT = {
    "direct": frozenset(),
    "lsqr": frozenset(_TOL_KEYS),
    "saa": frozenset(_TOL_KEYS),
    "sap": frozenset(_TOL_KEYS),
    "iterative": frozenset(_TOL_KEYS),
    "fossils": frozenset({"steptol"}),
}

# The certified tier's escalation ladder: each failed certificate both
# grows the sketch (appended rows, stored B reused) and climbs one rung.
CERTIFIED_LADDER = ("saa", "iterative", "fossils", "direct")

# Methods whose factor build honours ``precision=``/``fused=`` (the sketched
# solvers that go through ``SketchedFactor.build``).  ``sap``/``lsqr``/
# ``direct`` never sketch-and-factor this way: forcing one of them together
# with ``precision="mixed"`` raises, auto-selection falls back to full.
PRECISION_SUPPORT = frozenset({"saa", "iterative", "fossils"})


def select_method(
    m: int,
    n: int,
    *,
    has_key: bool = True,
    accuracy: str = "balanced",
    sketch_size: int | None = None,
    matrix_free: bool = False,
) -> str:
    """Pick a solver from shape, sketch-size regime and requested accuracy.

    ``matrix_free=True`` (sparse / operator inputs) rules out ``direct``:
    the iterative sketched solvers only take products with A, which is the
    whole point of those inputs.

    For ridge problems callers must pass the ORIGINAL data shape, not the
    augmented ``(m + n, n)`` one — ``lstsq(reg=λ)`` does so since the
    regime tests would otherwise see an inflated m.  Nearly-square and
    underdetermined shapes (where ``default_sketch_size`` clamps to
    s = m and no embedding can shrink the row space) always fail the
    regime test and route to ``direct``/``lsqr``.
    """
    if accuracy not in _SKETCHED_BY_ACCURACY:
        raise ValueError(
            f"select_method picks a single solver; accuracy must be one of "
            f"{tuple(_SKETCHED_BY_ACCURACY)} (the 'certified' tier runs its "
            f"own escalation ladder), got {accuracy!r}"
        )
    s = sketch_size if sketch_size is not None else default_sketch_size(n, m)
    # The sketched solvers need the embedding to actually shrink the row
    # space: s rows must both dominate n and be a small fraction of m.
    regime_ok = (s >= n + 1) and (m >= 2 * s) and (m >= 4 * n)
    if matrix_free:
        if has_key and regime_ok:
            return _SKETCHED_BY_ACCURACY[accuracy]
        return "lsqr"
    big = m * n * n > DIRECT_FLOP_CUTOFF
    if big and regime_ok and has_key:
        return _SKETCHED_BY_ACCURACY[accuracy]
    if big and not has_key:
        return "lsqr"
    return "direct"


@jax.jit
def _direct_result(A, b):
    x = qr_solve(A, b)
    r = b - A @ x
    return SolveResult(
        x=x,
        istop=jnp.asarray(1, jnp.int32),
        itn=jnp.asarray(0, jnp.int32),
        rnorm=jnp.linalg.norm(r),
        arnorm=jnp.linalg.norm(A.T @ r),
        used_fallback=jnp.asarray(False),
    )


@jax.jit
def _ridge_diagnostics(A, b, x, reg):
    """(rnorm, arnorm) of the ORIGINAL ridge problem at x."""
    r = b - A.matvec(x)
    g = A.rmatvec(r) - reg * x
    return jnp.linalg.norm(r), jnp.linalg.norm(g)


def _certified_lstsq(
    A_in,
    A_op,
    b_solve,
    key,
    *,
    sketch,
    sketch_size,
    backend,
    tol,
    history,
    rtol,
    n_probes,
    precision="full",
    fused=None,
):
    """The adaptive certified driver: solve → certify → escalate.

    One factor is built at the initial sketch size; every escalation
    APPENDS rows to it (``SketchedFactor.extend`` — only the new rows are
    sketched, the stored B is reused bit-for-bit) and climbs one rung of
    :data:`CERTIFIED_LADDER`.  Returns ``(result, method_name)`` for the
    first certificate that passes, else the attempt with the smallest
    posterior error bound (its certificate carries ``passed=False``).

    With ``precision="mixed"`` the FIRST escalation move is a precision
    escalation, not a size/method one: the SAME sketch operator is
    re-applied at full precision (cheap — one sketch apply, no new QR
    rows) and the SAME rung retried.  A bf16-rounded sketch loses the
    embedding only through rounding, so when its certificate fails,
    restoring precision is the targeted repair; only if the full-precision
    retry also fails does the driver resume the size/method ladder.  Each
    certificate records the precision its factor was built at.
    """
    m_data, n = A_in.shape
    dtype = A_op.dtype
    steptol = tol.get("steptol")
    if steptol is None:
        steptol = 32 * float(jnp.finfo(dtype).eps)
    atol = tol.get("atol", 0.0)
    btol = tol.get("btol", 0.0)
    iter_lim = tol.get("iter_lim", 100)
    dense_input = isinstance(A_in, linop.DenseOperator)

    k_build, k_loop = jax.random.split(key)
    s = (
        sketch_size
        if sketch_size is not None
        else default_sketch_size(n, m_data)
    )
    factor, op, B = SketchedFactor.build_full(
        A_op, k_build, sketch=sketch, sketch_size=s, backend=backend,
        precision=precision, fused=fused,
    )
    prec_now = precision
    escalations = 0
    best = None  # (bound, result, method) of the best failed attempt

    rung = 0
    attempt = 0
    while rung < len(CERTIFIED_LADDER):
        meth = CERTIFIED_LADDER[rung]
        k_probe, k_ext = jax.random.split(jax.random.fold_in(k_loop, attempt))
        attempt += 1
        rung_span = obs_trace.span(
            "certified.rung", method=meth, attempt=attempt - 1,
            sketch_rows=s, precision=prec_now,
        )
        with rung_span:
            if meth == "direct":
                if not dense_input:
                    # Sparse and matrix-free inputs stop at the fossils rung —
                    # the whole point of those input forms is that A is never
                    # densified (BCOO is technically materializable, but an
                    # 8 GB todense() is not a fallback).
                    break
                res = _direct_result(
                    linop.ensure_dense(A_op, who="the certified QR fallback"),
                    b_solve,
                )
            elif meth == "saa":
                c = op.apply(b_solve, backend=backend)
                x, inner = _solve_with_factor(
                    A_op, b_solve, factor, c, materialize_y=dense_input,
                    atol=atol, btol=btol, iter_lim=iter_lim, steptol=steptol,
                    history=history,
                )
                res = inner._replace(x=x)
            else:
                alpha, beta = damping_momentum(s, n)
                x0 = factor.sketch_and_solve(op.apply(b_solve, backend=backend))
                if meth == "iterative":
                    res = heavy_ball_refine(
                        A_op, b_solve, factor, x0, alpha, beta,
                        atol=atol, btol=btol, steptol=steptol,
                        iter_lim=iter_lim, history=history,
                    )
                else:  # fossils
                    res = fossils_refine(
                        A_op, b_solve, factor, op, x0, alpha, beta,
                        inner_iter_lim=default_inner_iter_lim(beta, dtype),
                        steptol=steptol, backend=backend, history=history,
                    )
            obs_trace.maybe_block(res.x)
            cert = certify_lib.certify(
                A_op, b_solve, res.x, factor, k_probe, n_probes=n_probes,
                target=rtol, sketch_rows=s, escalations=escalations,
                precision=prec_now,
            )
            res = res._replace(certificate=cert)
            passed = bool(cert.passed)
            if rung_span:
                rung_span.set(
                    passed=passed, bound=float(cert.rel_error_bound)
                )
        if passed:
            return res, meth
        bound = float(cert.rel_error_bound)
        if not math.isfinite(bound):
            bound = math.inf
        if best is None or bound < best[0]:
            best = (bound, res, meth)
        if prec_now == "mixed" and meth != "direct":
            # Precision escalation: re-apply the SAME operator at full
            # precision (one sketch apply, no extra rows) and retry this
            # rung — the cheapest repair when bf16 rounding alone broke
            # the embedding.
            with obs_trace.span("certified.precision_escalate", rows=s):
                B = op.apply_op(A_op, backend=backend)
                factor = SketchedFactor.from_sketch(B)
                obs_trace.maybe_block(factor.R)
            prec_now = "full"
            escalations += 1
            continue
        # Escalate before the next sketched rung: double the sketch by
        # appending rows, capped at the data row count (beyond which a
        # sketch embeds nothing an exact method wouldn't).
        if rung + 1 < len(CERTIFIED_LADDER):
            extra = min(s, max(m_data - s, 0))
            if extra > 0 and CERTIFIED_LADDER[rung + 1] != "direct":
                with obs_trace.span("certified.escalate", extra=extra):
                    factor, op, B = factor.extend(
                        A_op, op, k_ext, extra, B=B, backend=backend
                    )
                    obs_trace.maybe_block(factor.R)
                s += extra
                escalations += 1
        rung += 1

    _, res, meth = best
    return res, meth


def lstsq(
    A,
    b: jax.Array,
    key: jax.Array | None = None,
    *,
    method: str = "auto",
    accuracy: str = "balanced",
    sketch: str = "clarkson_woodruff",
    sketch_size: int | None = None,
    reg: float | jax.Array | None = None,
    atol: float | None = None,
    btol: float | None = None,
    steptol: float | None = None,
    iter_lim: int | None = None,
    backend: str = "auto",
    precision: str = "full",
    fused: bool | None = None,
    history: bool = False,
    certified_rtol: float | None = None,
    certified_probes: int = 8,
    cluster=None,
    trace: bool | None = None,
) -> SolveResult:
    """Solve min‖Ax − b‖₂ (+ λ‖x‖₂² with ``reg=λ``) with an auto-selected
    (or forced) solver.

    ``trace=True`` records a nested wall-clock span timeline for this call
    (method selection, sketch vs QR, refinement, certificate rungs — and,
    through the streaming/cluster delegations, tiles and worker tasks) and
    attaches it as ``SolveResult.timeline`` (a
    :class:`repro.obs.trace.Timeline`; ``str(...)`` renders the tree,
    ``.save(path)`` writes Chrome-trace JSON).  With ``REPRO_TRACE=1`` (or
    inside ``repro.obs.tracing()``) the timeline is attached without the
    flag; ``trace=None`` (default) otherwise records nothing and costs
    nothing.

    ``precision="mixed"`` sketches a bf16-rounded copy of (dense) A with
    ≥ f32 accumulation; refinement stays full-precision and recovers full
    working accuracy for moderately conditioned problems, while the
    ``accuracy="certified"`` tier *verifies* recovery and escalates back
    to full precision when rounding broke the embedding.  ``fused`` routes
    factor builds through the fused sketch→QR pipeline
    (``repro.kernels.tsqr.sketch_qr``; ``None`` → ``REPRO_FUSED_QR`` env,
    default off).  Both knobs apply to the sketched methods
    (:data:`PRECISION_SUPPORT`); forcing any other method with
    ``precision="mixed"`` raises, auto-selection just runs it at full
    precision.

    ``A``: dense array, BCOO sparse matrix, or ``linop.LinearOperator``.
    ``atol``/``btol``/``steptol``/``iter_lim`` left as ``None`` use each
    solver's own defaults.  Forwarding is audited against ``TOL_SUPPORT``:
    forcing a method alongside a knob it does not consume (``direct`` takes
    none; ``fossils`` only ``steptol``) raises ``ValueError``; under
    ``method="auto"`` unsupported knobs are dropped for the selected
    solver.

    ``accuracy="certified"`` (``method="auto"`` only) runs the adaptive
    certified driver: solve, certify with the posterior estimators of
    ``repro.core.certify``, and on failure escalate sketch size and method
    (see :data:`CERTIFIED_LADDER`).  ``certified_rtol`` is the relative
    forward-error target (``None`` → the adaptive QR-attainable default);
    ``certified_probes`` sets the distortion probe count.  The returned
    ``SolveResult.certificate`` carries the final posterior bound.

    ``cluster=ClusterSpec(...)`` runs the streaming path across a
    fault-tolerant multi-worker pool with checkpointable sketch state
    (``repro.cluster``); it implies the streaming path, so a plain array
    ``A`` is coerced to a row source first.
    """
    scope = obs_trace.solve_scope(trace)
    with scope:
        root = obs_trace.span("lstsq", accuracy=accuracy)
        with root:
            res = _lstsq_impl(
                A, b, key, method=method, accuracy=accuracy, sketch=sketch,
                sketch_size=sketch_size, reg=reg, atol=atol, btol=btol,
                steptol=steptol, iter_lim=iter_lim, backend=backend,
                precision=precision, fused=fused, history=history,
                certified_rtol=certified_rtol,
                certified_probes=certified_probes, cluster=cluster,
            )
            if root and res.method:
                root.set(method=res.method)
    return scope.attach(res)


def _lstsq_impl(
    A,
    b,
    key,
    *,
    method,
    accuracy,
    sketch,
    sketch_size,
    reg,
    atol,
    btol,
    steptol,
    iter_lim,
    backend,
    precision,
    fused,
    history,
    certified_rtol,
    certified_probes,
    cluster,
) -> SolveResult:
    if accuracy not in ACCURACIES:
        raise ValueError(f"unknown accuracy {accuracy!r}; have {ACCURACIES}")
    if precision not in backend_lib.PRECISIONS:
        raise ValueError(
            f"unknown precision {precision!r}; have {backend_lib.PRECISIONS}"
        )
    if cluster is not None and not callable(getattr(A, "tiles", None)):
        # cluster solving is a streaming mode: coerce in-memory inputs
        from ..streaming.sources import as_source as _as_source

        A = _as_source(A)
    if callable(getattr(A, "tiles", None)):
        # Row-streamed (out-of-core) input: delegate to the two-pass
        # streaming drivers.  Lazy import — repro.streaming imports this
        # package, so a top-level import would be circular.  A forced
        # method composes with accuracy="certified" here: streams have no
        # escalation ladder, certification just rides along.
        from ..streaming.solve import stream_lstsq as _stream_lstsq

        tol = {
            k: v
            for k, v in dict(atol=atol, btol=btol, steptol=steptol,
                             iter_lim=iter_lim).items()
            if v is not None
        }
        return _stream_lstsq(
            A, b, key, method=method, sketch=sketch,
            sketch_size=sketch_size, reg=reg, backend=backend,
            history=history, certify=accuracy == "certified",
            certified_rtol=certified_rtol, certified_probes=certified_probes,
            cluster=cluster, **tol,
        )
    A_in = linop.as_operator(A)
    if reg is not None:
        A_op = linop.TikhonovAugmented.wrap(A_in, reg)
        b_solve = A_op.augment_rhs(b)
    else:
        A_op, b_solve = A_in, b
    matrix_free = not isinstance(A_in, linop.DenseOperator)

    # Select on the ORIGINAL data shape: with reg=λ the solver sees the
    # augmented (m + n, n) operator, but its extra √λ·I rows are exact
    # (never sketched) and must not inflate m in the regime tests.
    m, n = A_in.shape
    method = _ALIASES.get(method, method)
    forced = method != "auto"

    tol = {
        k: v
        for k, v in dict(atol=atol, btol=btol, steptol=steptol,
                         iter_lim=iter_lim).items()
        if v is not None
    }

    if accuracy == "certified":
        if forced:
            raise ValueError(
                "accuracy='certified' drives its own method ladder "
                f"{CERTIFIED_LADDER}; don't force method={method!r}"
            )
        if key is None:
            raise ValueError("accuracy='certified' needs a PRNG key")
        res, used = _certified_lstsq(
            A_in, A_op, b_solve, key, sketch=sketch,
            sketch_size=sketch_size, backend=backend, tol=tol,
            history=history, rtol=certified_rtol, n_probes=certified_probes,
            precision=precision, fused=fused,
        )
        if reg is not None:
            rnorm, arnorm = _ridge_diagnostics(
                A_in, b, res.x, jnp.asarray(reg, A_in.dtype)
            )
            res = res._replace(rnorm=rnorm, arnorm=arnorm)
        return res._replace(method=used)

    if method == "auto":
        with obs_trace.span(
            "lstsq.select", m=m, n=n, accuracy=accuracy
        ) as sel:
            method = select_method(
                m, n, has_key=key is not None, accuracy=accuracy,
                sketch_size=sketch_size, matrix_free=matrix_free,
            )
            if sel:
                sel.set(method=method)
    if method not in METHODS:
        raise ValueError(f"unknown method {method!r}; have {('auto',) + METHODS}")
    if method in ("saa", "sap", "iterative", "fossils") and key is None:
        raise ValueError(f"method {method!r} needs a PRNG key")

    unsupported = sorted(set(tol) - TOL_SUPPORT[method])
    if unsupported:
        if forced:
            supported = sorted(TOL_SUPPORT[method]) or ["(none)"]
            raise ValueError(
                f"method {method!r} does not consume {unsupported}; it "
                f"supports {supported} — drop the unsupported knobs or let "
                "method='auto' do so"
            )
        # auto-selected: drop explicitly rather than silently absorb
        for k in unsupported:
            tol.pop(k)
    sk = dict(sketch=sketch, sketch_size=sketch_size, backend=backend)
    if method in PRECISION_SUPPORT:
        sk.update(precision=precision, fused=fused)
    elif precision != "full":
        if forced:
            raise ValueError(
                f"method {method!r} does not sketch through "
                "SketchedFactor.build and cannot honour precision="
                f"{precision!r}; supported: {sorted(PRECISION_SUPPORT)}"
            )
        precision = "full"  # auto-selected a non-sketched method: run full

    with obs_trace.span("lstsq.solve", method=method) as sp:
        if method == "direct":
            res = _direct_result(
                linop.ensure_dense(A_op, who="method='direct'"), b_solve
            )
        elif method == "lsqr":
            res = lsqr_operator(A_op, b_solve, history=history, **tol)
        elif method == "saa":
            res = saa_sas(A_op, b_solve, key, history=history, **sk, **tol)
        elif method == "sap":
            res = sap_sas(A_op, b_solve, key, history=history, **sk, **tol)
        elif method == "iterative":
            res = iterative_sketching(
                A_op, b_solve, key, history=history, **sk, **tol
            )
        else:  # fossils (tol holds at most steptol after the audit above)
            res = fossils(A_op, b_solve, key, history=history, **sk, **tol)
        obs_trace.maybe_block(res.x)
        if sp:
            sp.set(itn=int(res.itn))

    if reg is not None:
        # Report diagnostics of the ORIGINAL problem, not the augmented one.
        rnorm, arnorm = _ridge_diagnostics(
            A_in, b, res.x, jnp.asarray(reg, A_in.dtype)
        )
        res = res._replace(rnorm=rnorm, arnorm=arnorm)
    return res._replace(method=method)
