"""``lstsq`` — the one-call driver over every least-squares solver.

``lstsq(A, b, key)`` auto-selects among the package's solvers by shape,
sketch-size regime and requested accuracy, and always returns the unified
:class:`repro.core.result.SolveResult` (with ``.method`` naming the solver
that ran).  ``method=`` forces a specific solver:

=============  ============================================================
method         solver
=============  ============================================================
``direct``     Householder-QR ``qr_solve`` (ground truth; small problems)
``lsqr``       plain LSQR on A (no sketching; works without a key)
``saa``        SAA-SAS, paper Algorithm 1 (fastest sketched path)
``sap``        sketch-and-precondition baseline (paper §4)
``iterative``  iterative sketching with damping + momentum (forward stable)
``fossils``    sketch-and-precondition + iterative refinement (forward
               stable, direct-method accuracy)
=============  ============================================================

``A`` may be a dense ``jax.Array``, a ``jax.experimental.sparse`` BCOO
matrix, or any ``repro.core.linop`` operator (the matrix-free protocol) —
every solver above accepts all three.  ``reg=λ`` solves the Tikhonov/ridge
problem min‖Ax − b‖² + λ‖x‖² through the augmented operator [A; √λ·I]
(``linop.TikhonovAugmented``) with zero solver-specific code; the returned
``rnorm``/``arnorm`` are recomputed for the ORIGINAL system (``arnorm`` is
the ridge gradient norm ‖Aᵀ(b − Ax) − λx‖).

``A`` may ALSO be a ``repro.streaming`` row source (a ``RowSource``
instance) — an out-of-core matrix streamed one row tile at a time.  Those
inputs delegate to :func:`repro.streaming.solve.stream_lstsq` (also
re-exported here as ``stream_lstsq``), whose two-pass solvers never hold
A; ``method`` must then be one of its streaming methods (``"auto"``,
``"saa"``, ``"iterative"``, ``"sketch_and_solve"``).

Auto-selection (``method="auto"``):

- problems too small or too square for sketching to pay off → ``direct``;
- large and strongly overdetermined with a PRNG key → a sketched solver by
  ``accuracy``: ``"fast"`` → ``saa``, ``"balanced"`` (default) →
  ``iterative``, ``"high"`` → ``fossils``;
- large but no key supplied → ``lsqr`` (the only deterministic iterative
  path);
- sparse / matrix-free inputs never select ``direct`` (it would densify
  A): with a key they go to the sketched iterative solvers, without one to
  ``lsqr``.

The driver is a thin Python-level dispatch — every method underneath is its
own jitted, backend-dispatched solver, so there is no extra trace or
runtime cost over calling the solver directly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import linop
from .direct import qr_solve
from .iterative import fossils, iterative_sketching
from .lsqr import lsqr_operator
from .precond import default_sketch_size
from .result import SolveResult
from .saa import saa_sas
from .sap import sap_sas

__all__ = ["lstsq", "select_method", "stream_lstsq", "METHODS", "ACCURACIES"]


def __getattr__(name):
    # Lazy re-export: repro.streaming imports repro.core at module scope,
    # so the streaming driver can only be pulled in on first access.
    if name == "stream_lstsq":
        from ..streaming.solve import stream_lstsq

        return stream_lstsq
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

METHODS = ("direct", "lsqr", "saa", "sap", "iterative", "fossils")
ACCURACIES = ("fast", "balanced", "high")
_ALIASES = {"iterative_sketching": "iterative", "qr": "direct"}

# m·n² flops below which Householder QR is effectively free and sketching
# overhead (operator draw + sketch + small QR) cannot pay for itself.
DIRECT_FLOP_CUTOFF = 1 << 26

_SKETCHED_BY_ACCURACY = {"fast": "saa", "balanced": "iterative", "high": "fossils"}


def select_method(
    m: int,
    n: int,
    *,
    has_key: bool = True,
    accuracy: str = "balanced",
    sketch_size: int | None = None,
    matrix_free: bool = False,
) -> str:
    """Pick a solver from shape, sketch-size regime and requested accuracy.

    ``matrix_free=True`` (sparse / operator inputs) rules out ``direct``:
    the iterative sketched solvers only take products with A, which is the
    whole point of those inputs.
    """
    if accuracy not in ACCURACIES:
        raise ValueError(f"unknown accuracy {accuracy!r}; have {ACCURACIES}")
    s = sketch_size if sketch_size is not None else default_sketch_size(n, m)
    # The sketched solvers need the embedding to actually shrink the row
    # space: s rows must both dominate n and be a small fraction of m.
    regime_ok = (s >= n + 1) and (m >= 2 * s) and (m >= 4 * n)
    if matrix_free:
        if has_key and regime_ok:
            return _SKETCHED_BY_ACCURACY[accuracy]
        return "lsqr"
    big = m * n * n > DIRECT_FLOP_CUTOFF
    if big and regime_ok and has_key:
        return _SKETCHED_BY_ACCURACY[accuracy]
    if big and not has_key:
        return "lsqr"
    return "direct"


@jax.jit
def _direct_result(A, b):
    x = qr_solve(A, b)
    r = b - A @ x
    return SolveResult(
        x=x,
        istop=jnp.asarray(1, jnp.int32),
        itn=jnp.asarray(0, jnp.int32),
        rnorm=jnp.linalg.norm(r),
        arnorm=jnp.linalg.norm(A.T @ r),
        used_fallback=jnp.asarray(False),
    )


@jax.jit
def _ridge_diagnostics(A, b, x, reg):
    """(rnorm, arnorm) of the ORIGINAL ridge problem at x."""
    r = b - A.matvec(x)
    g = A.rmatvec(r) - reg * x
    return jnp.linalg.norm(r), jnp.linalg.norm(g)


def lstsq(
    A,
    b: jax.Array,
    key: jax.Array | None = None,
    *,
    method: str = "auto",
    accuracy: str = "balanced",
    sketch: str = "clarkson_woodruff",
    sketch_size: int | None = None,
    reg: float | jax.Array | None = None,
    atol: float | None = None,
    btol: float | None = None,
    steptol: float | None = None,
    iter_lim: int | None = None,
    backend: str = "auto",
    history: bool = False,
) -> SolveResult:
    """Solve min‖Ax − b‖₂ (+ λ‖x‖₂² with ``reg=λ``) with an auto-selected
    (or forced) solver.

    ``A``: dense array, BCOO sparse matrix, or ``linop.LinearOperator``.
    ``atol``/``btol``/``steptol``/``iter_lim`` left as ``None`` use each
    solver's own defaults; values are forwarded only to solvers that accept
    them (``fossils`` controls its budget via refinement/inner-loop
    parameters, so ``atol``/``btol``/``iter_lim`` do not apply there).
    """
    if callable(getattr(A, "tiles", None)):
        # Row-streamed (out-of-core) input: delegate to the two-pass
        # streaming drivers.  Lazy import — repro.streaming imports this
        # package, so a top-level import would be circular.
        from ..streaming.solve import stream_lstsq as _stream_lstsq

        tol = {
            k: v
            for k, v in dict(atol=atol, btol=btol, steptol=steptol,
                             iter_lim=iter_lim).items()
            if v is not None
        }
        return _stream_lstsq(
            A, b, key, method=method, sketch=sketch,
            sketch_size=sketch_size, reg=reg, backend=backend,
            history=history, **tol,
        )
    A_in = linop.as_operator(A)
    if reg is not None:
        A_op = linop.TikhonovAugmented.wrap(A_in, reg)
        b_solve = A_op.augment_rhs(b)
    else:
        A_op, b_solve = A_in, b
    matrix_free = not isinstance(A_in, linop.DenseOperator)

    m, n = A_op.shape
    method = _ALIASES.get(method, method)
    if method == "auto":
        method = select_method(
            m, n, has_key=key is not None, accuracy=accuracy,
            sketch_size=sketch_size, matrix_free=matrix_free,
        )
    if method not in METHODS:
        raise ValueError(f"unknown method {method!r}; have {('auto',) + METHODS}")
    if method in ("saa", "sap", "iterative", "fossils") and key is None:
        raise ValueError(f"method {method!r} needs a PRNG key")

    tol = {
        k: v
        for k, v in dict(atol=atol, btol=btol, steptol=steptol,
                         iter_lim=iter_lim).items()
        if v is not None
    }
    sk = dict(sketch=sketch, sketch_size=sketch_size, backend=backend)

    if method == "direct":
        res = _direct_result(linop.ensure_dense(A_op, who="method='direct'"),
                             b_solve)
    elif method == "lsqr":
        res = lsqr_operator(A_op, b_solve, history=history, **tol)
    elif method == "saa":
        res = saa_sas(A_op, b_solve, key, history=history, **sk, **tol)
    elif method == "sap":
        res = sap_sas(A_op, b_solve, key, history=history, **sk, **tol)
    elif method == "iterative":
        res = iterative_sketching(A_op, b_solve, key, history=history, **sk, **tol)
    else:  # fossils
        fkw = {"steptol": steptol} if steptol is not None else {}
        res = fossils(A_op, b_solve, key, history=history, **sk, **fkw)

    if reg is not None:
        # Report diagnostics of the ORIGINAL problem, not the augmented one.
        rnorm, arnorm = _ridge_diagnostics(
            A_in, b, res.x, jnp.asarray(reg, A_in.dtype)
        )
        res = res._replace(rnorm=rnorm, arnorm=arnorm)
    return res._replace(method=method)
