"""``lstsq`` — the one-call driver over every least-squares solver.

``lstsq(A, b, key)`` auto-selects among the package's solvers by shape,
sketch-size regime and requested accuracy, and always returns the unified
:class:`repro.core.result.SolveResult` (with ``.method`` naming the solver
that ran).  ``method=`` forces a specific solver:

=============  ============================================================
method         solver
=============  ============================================================
``direct``     Householder-QR ``qr_solve`` (ground truth; small problems)
``lsqr``       plain LSQR on A (no sketching; works without a key)
``saa``        SAA-SAS, paper Algorithm 1 (fastest sketched path)
``sap``        sketch-and-precondition baseline (paper §4)
``iterative``  iterative sketching with damping + momentum (forward stable)
``fossils``    sketch-and-precondition + iterative refinement (forward
               stable, direct-method accuracy)
=============  ============================================================

Auto-selection (``method="auto"``):

- problems too small or too square for sketching to pay off → ``direct``;
- large and strongly overdetermined with a PRNG key → a sketched solver by
  ``accuracy``: ``"fast"`` → ``saa``, ``"balanced"`` (default) →
  ``iterative``, ``"high"`` → ``fossils``;
- large but no key supplied → ``lsqr`` (the only deterministic iterative
  path).

The driver is a thin Python-level dispatch — every method underneath is its
own jitted, backend-dispatched solver, so there is no extra trace or
runtime cost over calling the solver directly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .direct import qr_solve
from .iterative import fossils, iterative_sketching
from .lsqr import lsqr_dense
from .precond import default_sketch_size
from .result import SolveResult
from .saa import saa_sas
from .sap import sap_sas

__all__ = ["lstsq", "select_method", "METHODS", "ACCURACIES"]

METHODS = ("direct", "lsqr", "saa", "sap", "iterative", "fossils")
ACCURACIES = ("fast", "balanced", "high")
_ALIASES = {"iterative_sketching": "iterative", "qr": "direct"}

# m·n² flops below which Householder QR is effectively free and sketching
# overhead (operator draw + sketch + small QR) cannot pay for itself.
DIRECT_FLOP_CUTOFF = 1 << 26


def select_method(
    m: int,
    n: int,
    *,
    has_key: bool = True,
    accuracy: str = "balanced",
    sketch_size: int | None = None,
) -> str:
    """Pick a solver from shape, sketch-size regime and requested accuracy."""
    if accuracy not in ACCURACIES:
        raise ValueError(f"unknown accuracy {accuracy!r}; have {ACCURACIES}")
    s = sketch_size if sketch_size is not None else default_sketch_size(n, m)
    # The sketched solvers need the embedding to actually shrink the row
    # space: s rows must both dominate n and be a small fraction of m.
    regime_ok = (s >= n + 1) and (m >= 2 * s) and (m >= 4 * n)
    big = m * n * n > DIRECT_FLOP_CUTOFF
    if big and regime_ok and has_key:
        return {"fast": "saa", "balanced": "iterative", "high": "fossils"}[accuracy]
    if big and not has_key:
        return "lsqr"
    return "direct"


@jax.jit
def _direct_result(A, b):
    x = qr_solve(A, b)
    r = b - A @ x
    return SolveResult(
        x=x,
        istop=jnp.asarray(1, jnp.int32),
        itn=jnp.asarray(0, jnp.int32),
        rnorm=jnp.linalg.norm(r),
        arnorm=jnp.linalg.norm(A.T @ r),
        used_fallback=jnp.asarray(False),
    )


def lstsq(
    A: jax.Array,
    b: jax.Array,
    key: jax.Array | None = None,
    *,
    method: str = "auto",
    accuracy: str = "balanced",
    sketch: str = "clarkson_woodruff",
    sketch_size: int | None = None,
    atol: float | None = None,
    btol: float | None = None,
    steptol: float | None = None,
    iter_lim: int | None = None,
    backend: str = "auto",
    history: bool = False,
) -> SolveResult:
    """Solve min‖Ax − b‖₂ with an auto-selected (or forced) solver.

    ``atol``/``btol``/``steptol``/``iter_lim`` left as ``None`` use each
    solver's own defaults; values are forwarded only to solvers that accept
    them (``fossils`` controls its budget via refinement/inner-loop
    parameters, so ``atol``/``btol``/``iter_lim`` do not apply there).
    """
    m, n = A.shape
    method = _ALIASES.get(method, method)
    if method == "auto":
        method = select_method(
            m, n, has_key=key is not None, accuracy=accuracy,
            sketch_size=sketch_size,
        )
    if method not in METHODS:
        raise ValueError(f"unknown method {method!r}; have {('auto',) + METHODS}")
    if method in ("saa", "sap", "iterative", "fossils") and key is None:
        raise ValueError(f"method {method!r} needs a PRNG key")

    tol = {
        k: v
        for k, v in dict(atol=atol, btol=btol, steptol=steptol,
                         iter_lim=iter_lim).items()
        if v is not None
    }
    sk = dict(sketch=sketch, sketch_size=sketch_size, backend=backend)

    if method == "direct":
        res = _direct_result(A, b)
    elif method == "lsqr":
        res = lsqr_dense(A, b, history=history, **tol)
    elif method == "saa":
        res = saa_sas(A, b, key, history=history, **sk, **tol)
    elif method == "sap":
        res = sap_sas(A, b, key, history=history, **sk, **tol)
    elif method == "iterative":
        res = iterative_sketching(A, b, key, history=history, **sk, **tol)
    else:  # fossils
        fkw = {"steptol": steptol} if steptol is not None else {}
        res = fossils(A, b, key, history=history, **sk, **fkw)
    return res._replace(method=method)
