"""Logical-axis sharding rules (MaxText-style).

Params and activations are annotated with *logical* axis names; a rules
table maps them to physical mesh axes.  Axes absent from the current mesh
(e.g. 'pod' on the single-pod mesh) are dropped automatically, so the same
model code lowers on any mesh.

Default layout: 2D-sharded weights — tensor-parallel over 'model'
(heads / mlp / vocab / experts dims) and FSDP over 'data' (the weights'
d_model dim); activations batch-sharded over ('pod','data') and
head-sharded over 'model' inside mixer blocks.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "DEFAULT_RULES",
    "OPT_RULES",
    "logical_to_spec",
    "constrain",
    "named_sharding",
    "shard_map_compat",
    "tree_pspecs",
]


def shard_map_compat(f, mesh, in_specs, out_specs):
    """``jax.shard_map(..., check_vma=False)`` across JAX versions.

    Newer JAX exposes ``jax.shard_map`` with the ``check_vma`` knob; older
    releases only have ``jax.experimental.shard_map.shard_map`` with the
    pre-rename ``check_rep``.  Replication checking is disabled either way —
    every caller here produces replicated outputs by construction (psum-fed).
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=False
        )
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=False
    )

# logical axis -> physical mesh axis (or tuple of axes), None = replicated
DEFAULT_RULES: dict[str, object] = {
    # activations
    "batch": ("pod", "data"),
    "seq": None,
    "act_embed": None,
    "act_heads": "model",
    "act_ff": "model",
    "act_experts": "model",
    "cap": ("pod", "data"),
    "cache_seq": "model",  # decode KV caches: sequence-sharded over TP
    # weights
    "embed": "data",  # FSDP dim of every weight
    "vocab": "model",
    "heads": "model",
    "kv_heads": None,  # GQA kv count < model axis -> replicate
    "mlp": "model",
    "experts": "model",
    "expert_mlp": None,
    "rnn": "model",
    "inner": "model",  # ssm d_inner
    "layers": None,
    "head_dim": None,
    "state": None,
    "conv": None,
    "lora": None,
    "patches": None,
    None: None,
}


# Optimizer-state rules: ZeRO-1 — master/m/v additionally sharded over the
# pod axis via the weights' embed dim (on single-pod meshes 'pod' is absent
# and this degenerates to DEFAULT_RULES).
OPT_RULES = dict(DEFAULT_RULES)
OPT_RULES["embed"] = ("pod", "data")


def logical_to_spec(axes: tuple, mesh: Mesh, rules=None, shape=None) -> P:
    """Map a tuple of logical axis names to a PartitionSpec on ``mesh``.

    If ``shape`` is given, any mapping whose mesh-axis product does not
    divide the dimension is dropped (replicated) — e.g. batch=1 long-context
    decode, or vocab sizes not divisible by the model axis.
    """
    rules = rules or DEFAULT_RULES
    mesh_axes = set(mesh.axis_names)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    out = []
    for i, ax in enumerate(axes):
        phys = rules.get(ax, None)
        if phys is None:
            out.append(None)
            continue
        if not isinstance(phys, tuple):
            phys = (phys,)
        present = tuple(a for a in phys if a in mesh_axes)
        if shape is not None and present:
            prod = 1
            for a in present:
                prod *= sizes[a]
            if prod == 0 or shape[i] % prod:
                present = ()
        if not present:
            out.append(None)
        elif len(present) == 1:
            out.append(present[0])
        else:
            out.append(present)

    # Expert-weight fallback: when the expert count does not divide the
    # model axis (e.g. mixtral's 8 experts on 16-way TP), shard the expert
    # FFN dim over 'model' instead — otherwise MoE weights (and their
    # optimizer state) end up replicated across the whole TP axis.
    if shape is not None and "experts" in axes and "model" in mesh_axes:
        e_dim = axes.index("experts")
        if out[e_dim] != "model" and "expert_mlp" in axes:
            f_dim = axes.index("expert_mlp")
            if out[f_dim] is None and shape[f_dim] % sizes["model"] == 0:
                out[f_dim] = "model"
    return P(*out)


def named_sharding(axes: tuple, mesh: Mesh, rules=None) -> NamedSharding:
    return NamedSharding(mesh, logical_to_spec(axes, mesh, rules))


def constrain(x: jax.Array, axes: tuple, mesh: Mesh | None = None, rules=None):
    """with_sharding_constraint by logical axes (no-op without a mesh)."""
    if mesh is None:
        mesh = _current_mesh()
    if mesh is None or mesh.empty:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, logical_to_spec(axes, mesh, rules, shape=x.shape))
    )


def _current_mesh():
    try:
        from jax._src.mesh import thread_resources

        m = thread_resources.env.physical_mesh
        return None if m.empty else m
    except Exception:
        return None


def tree_pspecs(axes_tree, mesh: Mesh, rules=None, shapes_tree=None):
    """Map a pytree of logical-axis tuples to PartitionSpecs.

    ``shapes_tree``: optional matching tree of ShapeDtypeStructs for
    divisibility-aware mapping.
    """
    is_axes = lambda x: isinstance(x, tuple) and all(
        isinstance(a, (str, type(None))) for a in x
    )
    if shapes_tree is None:
        return jax.tree.map(
            lambda axes: logical_to_spec(axes, mesh, rules), axes_tree,
            is_leaf=is_axes,
        )
    return jax.tree.map(
        lambda axes, sh: logical_to_spec(axes, mesh, rules, shape=sh.shape),
        axes_tree, shapes_tree, is_leaf=is_axes,
    )
