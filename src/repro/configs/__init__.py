from .base import (LayerSpec, MLAConfig, ModelConfig, MoEConfig, RGLRUConfig,
                   SHAPES, SSMConfig, ShapeConfig)
from .registry import (ARCHS, LONG_OK, all_cells, cells, get_config,
                       get_shape, list_archs, smoke_config)

__all__ = ["LayerSpec", "MLAConfig", "ModelConfig", "MoEConfig", "RGLRUConfig",
           "SHAPES", "SSMConfig", "ShapeConfig", "ARCHS", "LONG_OK",
           "all_cells", "cells", "get_config", "get_shape", "list_archs",
           "smoke_config"]
