"""Model / run configuration dataclasses.

A model is a *pattern* of layer specs scanned ``n_periods`` times (stacked
params, small HLO), plus optional unrolled ``prefix``/``suffix`` layers.
This single substrate expresses all ten assigned architectures (dense GQA,
MoE, MLA+MoE, SSM, RG-LRU hybrid, cross-attn VLM, audio-token decoder).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int
    n_shared: int = 0
    capacity_factor: float = 1.25
    norm_topk: bool = True
    aux_weight: float = 0.01


@dataclass(frozen=True)
class MLAConfig:
    kv_lora: int = 512
    q_lora: int = 1536
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk: int = 256


@dataclass(frozen=True)
class RGLRUConfig:
    d_rnn: int = 0  # 0 -> d_model
    conv_width: int = 4
    c: float = 8.0  # Griffin's fixed exponent scale


@dataclass(frozen=True)
class LayerSpec:
    """One layer slot in the pattern."""

    mixer: str  # 'attn' | 'mla' | 'ssd' | 'rglru' | 'cross_attn'
    window: int | None = None  # sliding-window size for 'attn'
    moe: bool = False  # MoE FFN instead of dense FFN
    ffn: bool = True  # False -> mixer-only block (mamba2)


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense|moe|ssm|hybrid|audio|vlm
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    pattern: tuple[LayerSpec, ...]
    n_periods: int
    prefix: tuple[LayerSpec, ...] = ()
    suffix: tuple[LayerSpec, ...] = ()
    act: str = "silu_glu"  # 'silu_glu' | 'gelu_glu' | 'sq_relu' | 'gelu'
    qk_norm: bool = False
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    moe: MoEConfig | None = None
    mla: MLAConfig | None = None
    ssm: SSMConfig | None = None
    rglru: RGLRUConfig | None = None
    frontend: str = "token"  # 'token' | 'frames' (audio stub) | 'vision' (vlm stub)
    n_patches: int = 0  # vlm: image patch embeddings per sample
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    # blockwise-attention tile sizes
    attn_q_block: int = 512
    attn_kv_block: int = 1024
    # remat policy for the layer scan: 'none'|'full'|'dots'
    remat: str = "full"
    # MoE dispatch implementation: 'auto' uses the shard_map expert-parallel
    # path when lowering under a mesh with a 'model' axis, else the
    # GSPMD-dispatch path.  'gspmd' forces the baseline (kept for §Perf
    # before/after), 'shard_map' forces the EP path.
    moe_impl: str = "auto"
    # optimizer/accumulator storage dtypes (bf16 for memory-bound giants)
    opt_moments_dtype: str = "float32"
    grad_accum_dtype: str = "float32"
    # cross-entropy vocab chunking (seq chunk size; 0 = unchunked)
    loss_chunk: int = 2048

    @property
    def n_layers(self) -> int:
        return (
            len(self.prefix)
            + self.n_periods * len(self.pattern)
            + len(self.suffix)
        )

    @property
    def d_rnn(self) -> int:
        if self.rglru is None:
            return 0
        return self.rglru.d_rnn or self.d_model

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str  # train_4k | prefill_32k | decode_32k | long_500k
    kind: str  # 'train' | 'prefill' | 'decode'
    seq_len: int
    global_batch: int
    microbatch: int | None = None  # grad-accum microbatch (train only)


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256, microbatch=16),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}
