"""musicgen-medium [audio]: 48L decoder-only over EnCodec tokens.

Backbone only (per assignment): the EnCodec/text-conditioning frontend is a
STUB — ``input_specs`` feeds precomputed (B,S,d_model) frame embeddings.
Single-codebook head (vocab 2048); the 4-codebook delay pattern is frontend
territory and out of scope.  [arXiv:2306.05284; hf]
"""
from .base import LayerSpec, ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="musicgen-medium", family="audio",
        d_model=1536, n_heads=24, n_kv_heads=24, head_dim=64,
        d_ff=6144, vocab=2048,
        pattern=(LayerSpec("attn"),), n_periods=48,
        act="gelu", frontend="frames", rope_theta=10000.0,
    )


def smoke_config() -> ModelConfig:
    return get_config().replace(
        d_model=128, n_heads=4, n_kv_heads=4, head_dim=32,
        d_ff=256, vocab=128, n_periods=2,
        attn_q_block=64, attn_kv_block=64, loss_chunk=64, dtype="float32",
    )
