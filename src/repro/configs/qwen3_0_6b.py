"""qwen3-0.6b [dense]: 28L GQA with per-head qk-norm, head_dim 128.
[hf:Qwen/Qwen3-8B (family); hf]
"""
from .base import LayerSpec, ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-0.6b", family="dense",
        d_model=1024, n_heads=16, n_kv_heads=8, head_dim=128,
        d_ff=3072, vocab=151936,
        pattern=(LayerSpec("attn"),), n_periods=28,
        act="silu_glu", qk_norm=True, rope_theta=1000000.0,
        tie_embeddings=True,
    )


def smoke_config() -> ModelConfig:
    return get_config().replace(
        d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
        d_ff=256, vocab=256, n_periods=2,
        attn_q_block=64, attn_kv_block=64, loss_chunk=64, dtype="float32",
    )
