"""mixtral-8x7b [moe]: 32L GQA + 8-expert top-2 MoE, SWA window 4096.
The 4096 sliding window bounds the decode KV cache -> long_500k cell runs.
[arXiv:2401.04088; hf]
"""
from .base import LayerSpec, ModelConfig, MoEConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x7b", family="moe",
        d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
        d_ff=14336, vocab=32000,
        pattern=(LayerSpec("attn", window=4096, moe=True),), n_periods=32,
        act="silu_glu", rope_theta=1000000.0,
        moe=MoEConfig(n_experts=8, top_k=2, d_expert=14336, norm_topk=True),
    )


def smoke_config() -> ModelConfig:
    return get_config().replace(
        d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
        d_ff=256, vocab=256, n_periods=2,
        pattern=(LayerSpec("attn", window=64, moe=True),),
        moe=MoEConfig(n_experts=4, top_k=2, d_expert=64, norm_topk=True),
        attn_q_block=64, attn_kv_block=64, loss_chunk=64, dtype="float32",
    )
