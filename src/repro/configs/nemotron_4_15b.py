"""nemotron-4-15b [dense]: 32L GQA, squared-ReLU (non-gated) MLP.
Partial-rotary (50%) of the real model simplified to full rotary — noted in
DESIGN.md.  [arXiv:2402.16819; unverified]
"""
from .base import LayerSpec, ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="nemotron-4-15b", family="dense",
        d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
        d_ff=24576, vocab=256000,
        pattern=(LayerSpec("attn"),), n_periods=32,
        act="sq_relu", rope_theta=10000.0,
    )


def smoke_config() -> ModelConfig:
    return get_config().replace(
        d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
        d_ff=256, vocab=256, n_periods=2,
        attn_q_block=64, attn_kv_block=64, loss_chunk=64, dtype="float32",
    )
