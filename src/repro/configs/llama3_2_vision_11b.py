"""llama-3.2-vision-11b [vlm]: 40L text backbone; cross-attention to image
patches at layers 3,8,...,38 (pattern period 5, cross at slot 3).  Vision
tower is a STUB: ``input_specs`` provides precomputed, pre-projected
(B, n_patches, d_model) patch embeddings.
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]
"""
from .base import LayerSpec, ModelConfig

_S = LayerSpec("attn")
_X = LayerSpec("cross_attn")


def get_config() -> ModelConfig:
    return ModelConfig(
        name="llama-3.2-vision-11b", family="vlm",
        d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
        d_ff=14336, vocab=128256,
        pattern=(_S, _S, _S, _X, _S), n_periods=8,
        act="silu_glu", rope_theta=500000.0,
        frontend="vision", n_patches=1600,
    )


def smoke_config() -> ModelConfig:
    return get_config().replace(
        d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
        d_ff=256, vocab=256, n_periods=2, n_patches=16,
        attn_q_block=64, attn_kv_block=64, loss_chunk=64, dtype="float32",
    )
