"""recurrentgemma-9b [hybrid]: Griffin — (RG-LRU, RG-LRU, local-attn) x 12
+ 2 trailing RG-LRU blocks = 38 layers.  MQA local attention, window 2048.
[arXiv:2402.19427; unverified]
"""
from .base import LayerSpec, ModelConfig, RGLRUConfig

_REC = LayerSpec("rglru")
_LOC = LayerSpec("attn", window=2048)


def get_config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-9b", family="hybrid",
        d_model=4096, n_heads=16, n_kv_heads=1, head_dim=256,
        d_ff=12288, vocab=256000,
        pattern=(_REC, _REC, _LOC), n_periods=12, suffix=(_REC, _REC),
        act="gelu_glu", rglru=RGLRUConfig(d_rnn=0, conv_width=4, c=8.0),
        rope_theta=10000.0,
    )


def smoke_config() -> ModelConfig:
    return get_config().replace(
        d_model=128, n_heads=4, n_kv_heads=1, head_dim=32,
        d_ff=256, vocab=256, n_periods=2, suffix=(_REC, _REC),
        attn_q_block=64, attn_kv_block=64, loss_chunk=64, dtype="float32",
        rglru=RGLRUConfig(d_rnn=128, conv_width=4, c=8.0),
    )
