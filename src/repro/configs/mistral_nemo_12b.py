"""mistral-nemo-12b [dense]: 40L GQA, head_dim 128 (H*hd < d_model), 128k ctx.
[hf:mistralai/Mistral-Nemo-Base-2407; hf]
"""
from .base import LayerSpec, ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="mistral-nemo-12b", family="dense",
        d_model=5120, n_heads=32, n_kv_heads=8, head_dim=128,
        d_ff=14336, vocab=131072,
        pattern=(LayerSpec("attn"),), n_periods=40,
        act="silu_glu", rope_theta=1000000.0,
    )


def smoke_config() -> ModelConfig:
    return get_config().replace(
        d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
        d_ff=256, vocab=256, n_periods=2,
        attn_q_block=64, attn_kv_block=64, loss_chunk=64, dtype="float32",
    )
