"""deepseek-v2-236b [moe]: 60L MLA (kv_lora 512) + 160-expert top-6 MoE with
2 shared experts; first layer uses a dense d_ff=12288 MLP (prefix).
Decode uses the weight-absorbed MLA path.  [arXiv:2405.04434; hf]
"""
from .base import LayerSpec, MLAConfig, ModelConfig, MoEConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-236b", family="moe",
        d_model=5120, n_heads=128, n_kv_heads=128, head_dim=128,
        d_ff=12288, vocab=102400,
        prefix=(LayerSpec("mla", moe=False),),
        pattern=(LayerSpec("mla", moe=True),), n_periods=59,
        act="silu_glu", rope_theta=10000.0,
        mla=MLAConfig(kv_lora=512, q_lora=1536, qk_nope_dim=128,
                      qk_rope_dim=64, v_dim=128),
        moe=MoEConfig(n_experts=160, top_k=6, d_expert=1536, n_shared=2,
                      norm_topk=False),
        # 236B on 16 GB/chip: bf16 Adam moments + bf16 grad accumulation
        # (master stays f32); multi-pod adds ZeRO-1 over the pod axis.
        opt_moments_dtype="bfloat16",
        grad_accum_dtype="bfloat16",
    )


def smoke_config() -> ModelConfig:
    return get_config().replace(
        d_model=128, n_heads=4, n_kv_heads=4, head_dim=32,
        d_ff=256, vocab=256, n_periods=2,
        mla=MLAConfig(kv_lora=32, q_lora=48, qk_nope_dim=16,
                      qk_rope_dim=8, v_dim=16),
        moe=MoEConfig(n_experts=4, top_k=2, d_expert=64, n_shared=1,
                      norm_topk=False),
        attn_q_block=64, attn_kv_block=64, loss_chunk=64, dtype="float32",
    )
