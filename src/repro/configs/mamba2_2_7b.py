"""mamba2-2.7b [ssm]: 64 attention-free SSD layers (state-space duality).
d_inner = 2*2560 = 5120, head_dim 64 -> 80 SSD heads, d_state 128.
Constant-size recurrent state -> long_500k decode cell runs.
[arXiv:2405.21060; unverified]
"""
from .base import LayerSpec, ModelConfig, SSMConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-2.7b", family="ssm",
        d_model=2560, n_heads=80, n_kv_heads=80, head_dim=64,
        d_ff=0, vocab=50280,
        pattern=(LayerSpec("ssd", ffn=False),), n_periods=64,
        ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, chunk=128),
    )


def smoke_config() -> ModelConfig:
    return get_config().replace(
        d_model=64, n_heads=16, n_kv_heads=16, head_dim=8,
        vocab=256, n_periods=2,
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=8, chunk=32),
        loss_chunk=64, dtype="float32",
    )
