"""Architecture registry: ``--arch <id>`` resolution + per-arch shape cells."""
from __future__ import annotations

from importlib import import_module

from .base import SHAPES, ModelConfig, ShapeConfig

ARCHS: dict[str, str] = {
    "musicgen-medium": "musicgen_medium",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "llama3.2-1b": "llama3_2_1b",
    "mistral-nemo-12b": "mistral_nemo_12b",
    "nemotron-4-15b": "nemotron_4_15b",
    "qwen3-0.6b": "qwen3_0_6b",
    "mixtral-8x7b": "mixtral_8x7b",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "mamba2-2.7b": "mamba2_2_7b",
    "llama-3.2-vision-11b": "llama3_2_vision_11b",
}

# long_500k needs bounded-state attention: SSM state (mamba2), RG-LRU +
# 2048-window local attn (recurrentgemma), 4096-window SWA (mixtral).
# Pure full-attention archs are skipped per the assignment (see DESIGN.md).
LONG_OK = {"mamba2-2.7b", "recurrentgemma-9b", "mixtral-8x7b"}


def _module(name: str):
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ARCHS)}")
    return import_module(f".{ARCHS[name]}", __package__)


def get_config(name: str) -> ModelConfig:
    return _module(name).get_config()


def smoke_config(name: str) -> ModelConfig:
    return _module(name).smoke_config()


def list_archs() -> list[str]:
    return list(ARCHS)


def cells(name: str) -> list[str]:
    """The assigned (arch x shape) cells that actually lower."""
    out = ["train_4k", "prefill_32k", "decode_32k"]
    if name in LONG_OK:
        out.append("long_500k")
    return out


def all_cells() -> list[tuple[str, str]]:
    return [(a, s) for a in ARCHS for s in cells(a)]


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]
