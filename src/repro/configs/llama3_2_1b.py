"""llama3.2-1b [dense]: 16L GQA, tied embeddings.
[hf:meta-llama/Llama-3.2-1B; unverified]
"""
from .base import LayerSpec, ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="llama3.2-1b", family="dense",
        d_model=2048, n_heads=32, n_kv_heads=8, head_dim=64,
        d_ff=8192, vocab=128256,
        pattern=(LayerSpec("attn"),), n_periods=16,
        act="silu_glu", rope_theta=500000.0, tie_embeddings=True,
    )


def smoke_config() -> ModelConfig:
    return get_config().replace(
        d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
        d_ff=256, vocab=256, n_periods=2,
        attn_q_block=64, attn_kv_block=64, loss_chunk=64, dtype="float32",
    )
