"""Streaming sketch engine: out-of-core least squares, one row tile at a time.

The in-memory solvers in ``repro.core`` assume A fits on the device — the
one regime sketching wins biggest (m·n beyond memory) was unreachable.
This package removes that assumption:

- ``sources``    — the :class:`RowSource` protocol: re-iterable
  ``(row_offset, tile)`` streams over in-memory arrays, callbacks,
  generators, memory-mapped ``.npy`` files and multi-host shard lists.
- ``accumulate`` — mergeable per-kind :class:`SketchAccumulator` partial
  sketches: scatter kinds fold tiles into the (s, n) state bit-for-bit
  equal to the monolithic apply; SRHT buffers D-signed rows and runs the
  Hadamard transform once at finalize; partial sketches from disjoint
  tiles/hosts tree-reduce via ``merge`` (``sharded_sketch`` is the
  shard_map + psum collective form).
- ``solve``      — two-pass drivers: pass 1 streams the sketch (b rides
  along as an extra column), pass 2 re-streams tiles for blocked
  ``A@v`` / ``Aᵀ@u`` products inside preconditioned LSQR (``"saa"``) or
  forward-stable iterative sketching (``"iterative"``), plus the true
  single-pass ``"sketch_and_solve"``.  :func:`stream_lstsq` is the
  driver; :class:`StreamingSolver` the amortizing session.

Same key ⇒ bit-identical S to the in-memory solvers, so streamed results
match ``repro.core.lstsq`` on the materialized A to machine precision.
"""
from . import accumulate, solve, sources
from .accumulate import (
    SketchAccumulator,
    accumulate_source,
    make_accumulator,
    merge_all,
    sharded_sketch,
)
from .solve import STREAM_METHODS, StreamingSolver, stream_lstsq, stream_sketch
from .sources import (
    ArraySource,
    CallbackSource,
    GeneratorSource,
    MemmapSource,
    RowSource,
    ShardedSource,
    as_source,
)

__all__ = [
    "accumulate", "solve", "sources",
    "SketchAccumulator", "accumulate_source", "make_accumulator",
    "merge_all", "sharded_sketch",
    "STREAM_METHODS", "StreamingSolver", "stream_lstsq", "stream_sketch",
    "ArraySource", "CallbackSource", "GeneratorSource", "MemmapSource",
    "RowSource", "ShardedSource", "as_source",
]
