"""Two-pass streaming solvers: least squares without ever holding A.

Pass 1 streams the row tiles once and assembles the sketch B = S·A (and
c = S·b from the same stream — the right-hand side rides along as an
extra column), then QR-factors the small (s, n) B into the shared
:class:`repro.core.precond.SketchedFactor`.  Pass 2 re-streams the tiles
to run the iteration's products with A blockwise — ``A@v`` by placing
per-tile products, ``Aᵀ@u`` by accumulating per-tile adjoint products —
so peak data-matrix memory is one tile, never m·n.

Methods (``stream_lstsq(source, b, key, method=...)``):

- ``"saa"``              — preconditioned LSQR on the whitened operator
  Y = A R⁻¹ with the z₀ = Qᵀ(Sb) warm start; the streaming form of
  ``saa_sas`` (2 streams per iteration: one for Y z, one for Yᵀ u).
- ``"iterative"``        — iterative sketching with damping + momentum
  (Epperly 2024), the forward-stable default: each iteration needs only
  the true gradient Aᵀ(b − Ax), which a single FUSED pass accumulates
  (residual tile → adjoint product tile, 1 stream per iteration).
- ``"sketch_and_solve"`` — pass 1 only: x̂ = R⁻¹Qᵀ(Sb).  True single-pass
  mode for O(ε)-accuracy pipelines; no residual diagnostics are computed
  (that would take a second pass — ``rnorm``/``arnorm`` are nan).

``method="auto"`` picks ``"iterative"``.  ``reg=λ`` solves the ridge
problem through the structured ``[B; √λI]`` / ``[c; 0]`` augmentation of
the *sketched* system (the streaming form of ``sketch.AugmentedSketch`` —
the identity block is exact, never streamed) with diagnostics recomputed
for the original system, matching ``lstsq(reg=...)``.

:class:`StreamingSolver` is the session form (mirroring
``repro.core.session.SketchedSolver``): one pass-1 sketch + QR amortized
over many ``solve``/``solve_many`` calls, with observable ``stats``
counters (``sketches``, ``qr_factorizations``, ``solves``, ``passes``,
``tiles``).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from ..core import certify as certify_lib
from ..core import sketch as sketch_lib
from ..core.backend import resolve as resolve_backend
from ..core.iterative import _IMPROVE_FACTOR, _STALL_LIMIT, damping_momentum
from ..core.precond import SketchedFactor, default_sketch_size
from ..core.result import SolveResult
from ..obs import trace as obs_trace
from ..obs.metrics import REGISTRY
from .accumulate import make_accumulator
from .sources import RowSource, as_source

__all__ = ["stream_lstsq", "stream_sketch", "StreamingSolver", "STREAM_METHODS"]

STREAM_METHODS = ("saa", "iterative", "sketch_and_solve")
_ALIASES = {"sketch": "sketch_and_solve", "single_pass": "sketch_and_solve"}


# --------------------------------------------------------------------------
# Pass 1: streamed sketch assembly
# --------------------------------------------------------------------------


def stream_sketch(
    source,
    key=None,
    *,
    op=None,
    sketch: str = "clarkson_woodruff",
    sketch_size: int | None = None,
    backend: str = "auto",
    rhs: jax.Array | None = None,
):
    """One pass over the tiles → ``(B, op, c)`` with B = S·A, c = S·rhs.

    Draws the operator from ``key`` exactly as the in-memory solvers do
    (same key ⇒ bit-identical S), or reuses a given ``op``.  The Gaussian
    operator is drawn UNmaterialized — its (d, m) matrix is as unstorable
    as A at out-of-core m, and the accumulator regenerates each (d, t)
    column block from the key's counter stream instead.  ``rhs`` (the
    right-hand side) is streamed as an extra column of the same pass, so
    a full sketch-and-solve estimate costs exactly one pass over A.
    """
    source = as_source(source)
    m, n = source.shape
    if op is None:
        if key is None:
            raise ValueError("stream_sketch needs a PRNG key (or an op=)")
        s = sketch_size if sketch_size is not None else default_sketch_size(n, m)
        kw = {"materialize": False} if sketch == "gaussian" else {}
        op = sketch_lib.sample(sketch, key, s, m, **kw)
    if op.m != m:
        raise ValueError(f"operator over m={op.m} rows, source has m={m}")
    ncols = n + (1 if rhs is not None else 0)
    if rhs is not None and rhs.shape != (m,):
        raise ValueError(f"rhs must have shape ({m},), got {rhs.shape}")
    cluster_sketch = getattr(source, "cluster_sketch", None)
    if callable(cluster_sketch):
        # a ClusterEngine source: pass 1 fans out over the worker pool
        # (checkpointed, fault-tolerant) and merges to the same sketch
        with obs_trace.span("stream.pass1", mode="cluster", rows=m):
            Bc = cluster_sketch(op, rhs=rhs, backend=backend)
            obs_trace.maybe_block(Bc)
    else:
        with obs_trace.span("stream.pass1", mode="serial", rows=m):
            acc = make_accumulator(op, ncols, dtype=jnp.dtype(source.dtype),
                                   backend=backend)
            for offset, tile in source.tiles():
                with obs_trace.span("stream.tile", offset=offset):
                    tile = jnp.asarray(tile)
                    if rhs is not None:
                        t = tile.shape[0]
                        tile = jnp.concatenate(
                            [tile,
                             rhs[offset : offset + t][:, None].astype(
                                 tile.dtype
                             )],
                            axis=1,
                        )
                    acc.update(tile, offset)
                    obs_trace.maybe_block(tile)
            Bc = acc.finalize()
            obs_trace.maybe_block(Bc)
    if rhs is None:
        return Bc, op, None
    return Bc[:, :n], op, Bc[:, n]


def _maybe_cluster(source, cluster, backend, counters=None):
    """Wrap ``source`` in a ClusterEngine when a spec/engine was given.

    Returns ``(source, owned)`` where ``owned`` is the engine THIS call
    constructed (the caller must ``close()`` it when done — its worker
    threads and temp checkpoint dir outlive the solve otherwise), or
    ``None`` when the source passed through or the engine was
    caller-provided (caller-provided engines stay open for reuse).

    Lazy import: ``repro.cluster`` imports the streaming layer, so the
    dependency must point one way at module-import time.
    """
    if cluster is None:
        return source, None
    from ..cluster.coordinator import ClusterEngine

    if isinstance(cluster, ClusterEngine):
        if counters is not None and cluster.counters is None:
            cluster.counters = counters
        return cluster, None
    engine = ClusterEngine(source, cluster, backend=backend,
                           counters=counters)
    return engine, engine


# --------------------------------------------------------------------------
# Pass 2: blocked products with A
# --------------------------------------------------------------------------


def _stream_matvec(source, x):
    """A @ x by placing per-tile products (exact placement, no summation).

    Sources that distribute the product themselves (``ClusterEngine``)
    expose a ``matvec`` method, which takes precedence over the serial
    tile loop — same for ``rmatvec`` / ``residual_grad`` below.
    """
    mv = getattr(source, "matvec", None)
    with obs_trace.span("stream.pass2", op="matvec"):
        if callable(mv):
            return obs_trace.maybe_block(mv(x))
        parts = [jnp.asarray(tile) @ x for _, tile in source.tiles()]
        return obs_trace.maybe_block(jnp.concatenate(parts, axis=0))


def _stream_rmatvec(source, u):
    """Aᵀ @ u by accumulating per-tile adjoint products."""
    rmv = getattr(source, "rmatvec", None)
    with obs_trace.span("stream.pass2", op="rmatvec"):
        if callable(rmv):
            return obs_trace.maybe_block(rmv(u))
        n = source.shape[1]
        g = jnp.zeros((n,) + u.shape[1:], u.dtype)
        for offset, tile in source.tiles():
            tile = jnp.asarray(tile)
            g = g + tile.T @ u[offset : offset + tile.shape[0]]
        return obs_trace.maybe_block(g)


def _stream_residual_grad(source, b, x):
    """ONE fused pass: (‖b − Ax‖², Aᵀ(b − Ax)).

    The residual tile feeds the adjoint product before the next tile is
    read — the iterative-sketching step touches A exactly once per
    iteration.  Generic over stacked right-hand sides (b (m, k), x (n, k)):
    the squared norms come back per column.
    """
    rg = getattr(source, "residual_grad", None)
    with obs_trace.span("stream.pass2", op="residual_grad"):
        if callable(rg):
            out = rg(b, x)
            obs_trace.maybe_block(out)
            return out
        n = source.shape[1]
        g = jnp.zeros((n,) + b.shape[1:], b.dtype)
        rn2 = jnp.zeros(b.shape[1:], b.dtype)
        for offset, tile in source.tiles():
            tile = jnp.asarray(tile)
            r_t = b[offset : offset + tile.shape[0]] - tile @ x
            g = g + tile.T @ r_t
            rn2 = rn2 + jnp.sum(r_t * r_t, axis=0)
        obs_trace.maybe_block(g)
        return rn2, g


# --------------------------------------------------------------------------
# Host-loop solvers (the per-iteration products are streamed, so the
# iteration itself is a Python loop — each tile op is a normal jax
# dispatch; there is no while_loop to close A into)
# --------------------------------------------------------------------------


class _StepFloor:
    """Host-side twin of ``repro.core.iterative._StepFloor``: converged when
    three consecutive relative steps sit below ``steptol`` OR the absolute
    step norm stops reaching new minima (numerical-floor stagnation)."""

    def __init__(self):
        self.n_small = 0
        self.min_step = math.inf
        self.n_stall = 0

    def update(self, stepnorm: float, relstep: float, steptol: float) -> bool:
        self.n_small = self.n_small + 1 if (steptol > 0 and relstep <= steptol) else 0
        if stepnorm < _IMPROVE_FACTOR * self.min_step:
            self.n_stall = 0
        else:
            self.n_stall += 1
        self.min_step = min(self.min_step, stepnorm)
        return self.n_small >= 3 or self.n_stall >= _STALL_LIMIT


def _lsqr_streamed(mv, rmv, b, x0, *, atol, btol, steptol, iter_lim,
                   history=False):
    """Column-batched Golub–Kahan LSQR with streamed products.

    Host-loop form of ``repro.core.lsqr.lsqr`` (same stopping tests
    1/2/7/8, warm-started on the correction against r₀ = b − A x₀),
    generalized to stacked right-hand sides: all the bidiagonalization
    scalars become per-column (k,) arrays while the two products per
    iteration stay SHARED matmuls — k solves for the streams of one.
    Converged columns keep iterating harmlessly (their updates are ~0)
    until the slowest column stops; per-column ``istop`` records each
    column's own stopping reason.

    1-D ``b`` is the k = 1 case and returns scalars.
    """
    vec = b.ndim == 1
    B = b[:, None] if vec else b
    X0 = x0[:, None] if vec else x0
    k = B.shape[1]
    dtype = B.dtype
    tiny = float(jnp.finfo(dtype).tiny)

    def cnorm(M):
        return jnp.sqrt(jnp.sum(M * M, axis=0))  # per-column norms (k,)

    def safe(s):
        return jnp.where(s > 0, s, 1.0)

    bnorm = cnorm(B)
    R0 = B - mv(X0)
    beta = cnorm(R0)
    U = R0 / safe(beta)
    V_raw = rmv(U)
    alfa = cnorm(V_raw)
    V = V_raw / safe(alfa)
    W = V
    X = jnp.zeros_like(V)
    rhobar, phibar = alfa, beta
    anorm2 = jnp.zeros((k,), dtype)
    arnorm = alfa * beta
    rnorm = beta

    istop = np.zeros(k, np.int32)
    # columns that are trivially solved (b = 0 or already at the optimum)
    istop[np.asarray((bnorm == 0) | (arnorm == 0))] = -1
    itn = 0
    n_small = np.zeros(k, np.int64)
    min_step = np.full(k, np.inf)
    n_stall = np.zeros(k, np.int64)
    rhist = []
    while (istop == 0).any() and itn < iter_lim:
        itn += 1
        with obs_trace.span("stream.iter", itn=itn, method="saa"):
            U_raw = mv(V) - alfa * U
            beta_k = cnorm(U_raw)
            U = U_raw / safe(beta_k)
            anorm2 = anorm2 + alfa**2 + beta_k**2
            V_raw = rmv(U) - beta_k * V
            alfa_k = cnorm(V_raw)
            V = V_raw / safe(alfa_k)

            rho = jnp.hypot(rhobar, beta_k)
            c = jnp.where(rho > 0, rhobar / safe(rho), 1.0)
            sn = jnp.where(rho > 0, beta_k / safe(rho), 0.0)
            theta = sn * alfa_k
            phi = c * phibar
            arnorm = alfa_k * jnp.abs(sn * phibar)  # pre-update phibar
            t1 = jnp.where(rho > 0, phi / safe(rho), 0.0)
            t2 = jnp.where(rho > 0, -theta / safe(rho), 0.0)
            step = jnp.abs(t1) * cnorm(W)
            X = X + t1 * W
            W = V + t2 * W
            rhobar = -c * alfa_k
            phibar = sn * phibar
            alfa = alfa_k

            rnorm = phibar
            anorm = jnp.sqrt(anorm2)
            xnorm = cnorm(X + X0)
            test1 = np.asarray(rnorm / safe(bnorm))
            test2 = np.asarray(arnorm / safe(anorm * rnorm))
            rtol = np.asarray(btol + atol * anorm * xnorm / safe(bnorm))
            relstep = np.asarray(step / jnp.maximum(xnorm, tiny))
            stepn = np.asarray(step)
            if history:
                rhist.append(float(rnorm[0]) if vec else rnorm)

            n_small = np.where(
                (steptol > 0) & (relstep <= steptol), n_small + 1, 0
            )
            n_stall = np.where(
                stepn < _IMPROVE_FACTOR * min_step, 0, n_stall + 1
            )
            min_step = np.minimum(min_step, stepn)

            new = np.zeros(k, np.int32)
            new[:] = 7 if itn >= iter_lim else 0
            new = np.where((n_small >= 3) | (n_stall >= _STALL_LIMIT), 8, new)
            new = np.where(test2 <= atol, 2, new)
            new = np.where(test1 <= rtol, 1, new)
            istop = np.where(istop == 0, new, istop)

    X = X + X0
    istop = np.where(istop == -1, 0, istop)  # trivial columns: scipy's code 0
    if vec:
        return (
            X[:, 0], int(istop[0]), itn, float(rnorm[0]), float(arnorm[0]),
            rhist,
        )
    return X, istop, itn, rnorm, arnorm, rhist


def _iterative_streamed(source, b, factor, x0, *, alpha, beta, reg, atol,
                        btol, steptol, iter_lim, history=False):
    """Heavy-ball iterative sketching, one fused stream per iteration
    (host-loop form of ``repro.core.iterative.iterative_sketching``)."""
    dtype = b.dtype
    lam = None if reg is None else jnp.asarray(reg, dtype)
    bnorm = float(jnp.linalg.norm(b))
    anorm = float(jnp.linalg.norm(factor.R))  # ‖R‖_F ≈ ‖A‖_F
    tiny = float(jnp.finfo(dtype).tiny)
    x, x_prev = x0, x0
    istop, itn = 0, 0
    floor = _StepFloor()
    rhist = []
    if bnorm == 0.0:
        z = jnp.zeros_like(x0)
        return z, 0, 0, bnorm, 0.0, rhist
    while istop == 0 and itn < iter_lim:
        itn += 1
        with obs_trace.span("stream.iter", itn=itn, method="iterative"):
            rn2, g = _stream_residual_grad(source, b, x)
            if lam is not None:
                # augmented system [A; √λI]x ≈ [b; 0]: the tail contributes
                # −λx to the gradient and λ‖x‖² to the squared residual
                rn2 = rn2 + lam * jnp.sum(x * x, axis=0)
                g = g - lam * x
            # block mode (stacked RHS): all norms are Frobenius — the
            # iteration runs until the slowest column's floor
            rnorm = float(jnp.sqrt(jnp.sum(rn2)))
            arnorm = float(jnp.linalg.norm(g))
            d = factor.normal_solve(g)
            dx = alpha * d + beta * (x - x_prev)
            x_prev, x = x, x + dx

            xnorm = float(jnp.linalg.norm(x))
            stepnorm = float(jnp.linalg.norm(dx))
            relstep = stepnorm / max(xnorm, tiny)
            test1 = rnorm / bnorm if bnorm > 0 else rnorm
            denom = anorm * rnorm if anorm * rnorm > 0 else 1.0
            test2 = arnorm / denom
            rtol = btol + atol * anorm * xnorm / (bnorm if bnorm > 0 else 1.0)
            if history:
                rhist.append(rnorm)
            if itn >= iter_lim:
                istop = 7
            if floor.update(stepnorm, relstep, steptol):
                istop = 8
            if test2 <= atol:
                istop = 2
            if test1 <= rtol:
                istop = 1
    return x, istop, itn, None, None, rhist


# --------------------------------------------------------------------------
# Driver
# --------------------------------------------------------------------------


def _final_diagnostics(source, b, x, reg):
    """(rnorm, arnorm) of the ORIGINAL system at x — one fused pass."""
    rn2, g = _stream_residual_grad(source, b, x)
    if reg is not None:
        g = g - jnp.asarray(reg, b.dtype) * x
    return jnp.sqrt(rn2), jnp.linalg.norm(g)


def _certify_streamed(source, b, x, factor, key, *, lam, sketch_rows,
                      n_probes=8, target=None):
    """Streamed posterior certificate — the pass-1 sketch is REUSED.

    The factor built from the single sketching pass over [A|b] already
    holds everything the estimators need except products with A, which
    stream: one pass evaluates all ``n_probes`` whitened distortion
    probes as a blocked matvec (‖S A R⁻¹w‖ = ‖w‖ exactly, so only
    ‖A R⁻¹w‖ needs A), and one fused pass gives the residual and
    gradient for the forward-error bound.  Ridge certificates are issued
    for the augmented system [A; √λI], whose solution is the ridge
    solution — the √λ terms are exact column arithmetic, never streamed.

    Returns ``(certificate, rnorm, arnorm)`` where the latter two are the
    ORIGINAL-system diagnostics of the same fused pass (the ridge
    gradient ‖Aᵀ(b − Ax) − λx‖, matching ``_final_diagnostics``), so
    certified callers never stream the residual twice.
    """
    n = source.shape[1]
    dtype = b.dtype
    with obs_trace.span("certify.streamed", n_probes=int(n_probes)):
        W = jax.random.normal(key, (n, int(n_probes)), dtype)
        V = factor.precondition(W)
        AV = _stream_matvec(source, V)  # one pass serves every probe
        yn2 = jnp.sum(AV * AV, axis=0)
        if lam is not None:
            yn2 = yn2 + lam * jnp.sum(V * V, axis=0)
        wn = jnp.linalg.norm(W, axis=0)
        ratios = wn / jnp.maximum(jnp.sqrt(yn2), jnp.finfo(dtype).tiny)
        eps_hat = jnp.max(jnp.abs(ratios - 1.0))

        rn2, g = _stream_residual_grad(source, b, x)
        rn2_aug = rn2
        if lam is not None:
            rn2_aug = rn2 + lam * jnp.sum(x * x)
            # the ridge gradient — also the augmented system's
            g = g - lam * x
        wg = factor.rt_solve(g)
        cert = certify_lib.build_certificate(
            factor,
            distortion=eps_hat,
            rnorm=jnp.sqrt(rn2_aug),
            whitened_arnorm=jnp.linalg.norm(wg),
            xnorm=jnp.linalg.norm(x),
            target=target,
            sketch_rows=sketch_rows,
        )
        obs_trace.maybe_block(cert.passed)
    return cert, jnp.sqrt(rn2), jnp.linalg.norm(g)


def stream_lstsq(
    source,
    b: jax.Array,
    key: jax.Array | None = None,
    *,
    method: str = "auto",
    sketch: str = "clarkson_woodruff",
    sketch_size: int | None = None,
    reg: float | jax.Array | None = None,
    atol: float = 0.0,
    btol: float = 0.0,
    steptol: float | None = None,
    iter_lim: int = 100,
    backend: str = "auto",
    history: bool = False,
    tile_rows: int | None = None,
    certify: bool = False,
    certified_rtol: float | None = None,
    certified_probes: int = 8,
    cluster=None,
    trace: bool | None = None,
) -> SolveResult:
    """min‖Ax − b‖ (+ λ‖x‖² with ``reg=λ``) over a row-streamed A.

    ``source``: anything :func:`repro.streaming.sources.as_source` accepts
    — a ``RowSource``, an in-memory array (tiled at ``tile_rows``), or a
    path to a ``.npy`` file (memory-mapped).  The solver holds one tile,
    the (s, n) sketch and a handful of n/m-vectors; A itself is streamed
    once for the sketch and once per iteration (twice for ``"saa"``).

    With the same ``key``, the streamed S is bit-identical to the
    in-memory solvers' draw, so results match ``lstsq`` on the
    materialized A to machine precision.

    ``certify=True`` (the streaming certified mode — also reached via
    ``lstsq(accuracy="certified")`` on a RowSource) attaches a posterior
    :class:`~repro.core.certify.Certificate` built from the SAME pass-1
    sketch of [A|b]: +1 stream for the blocked distortion probes and +1
    fused residual/gradient stream (which also fills the diagnostics the
    single-pass ``"sketch_and_solve"`` method normally skips).  No
    escalation is attempted out-of-core — a failed certificate reports
    ``passed=False`` and the caller chooses between a larger
    ``sketch_size`` re-run or an in-memory method.

    ``cluster=ClusterSpec(...)`` (or a prebuilt
    :class:`~repro.cluster.coordinator.ClusterEngine`) runs every stream —
    the pass-1 sketch and all pass-2 products — across a fault-tolerant
    worker pool with checkpointable sketch state; see ``repro.cluster``.
    An engine built HERE from a spec is torn down again before returning
    (worker threads joined, temp checkpoint dir removed); a prebuilt
    engine is left open for the caller to reuse and ``close()``.
    """
    source = as_source(source, tile_rows)
    scope = obs_trace.solve_scope(trace)
    with scope, obs_trace.span("stream_lstsq"):
        source, owned = _maybe_cluster(source, cluster, backend)
        try:
            res = _stream_lstsq_impl(
                source, b, key, method=method, sketch=sketch,
                sketch_size=sketch_size, reg=reg, atol=atol, btol=btol,
                steptol=steptol, iter_lim=iter_lim, backend=backend,
                history=history, certify=certify,
                certified_rtol=certified_rtol,
                certified_probes=certified_probes,
            )
        finally:
            if owned is not None:
                owned.close()
    return scope.attach(res)


def _stream_lstsq_impl(
    source, b, key, *, method, sketch, sketch_size, reg, atol, btol,
    steptol, iter_lim, backend, history, certify, certified_rtol,
    certified_probes,
) -> SolveResult:
    m, n = source.shape
    b = jnp.asarray(b)
    if b.shape != (m,):
        raise ValueError(f"b must have shape ({m},), got {b.shape}")
    method = _ALIASES.get(method, method)
    if method == "auto":
        # Certified runs default to the whitened LSQR ("saa"): it iterates
        # to the numerical floor, which the heavy-ball tail often leaves
        # short of within the default iter_lim — the certificate would
        # (correctly) refuse to certify that residual accuracy.
        method = "saa" if certify else "iterative"
    if method not in STREAM_METHODS:
        raise ValueError(
            f"unknown streaming method {method!r}; have "
            f"{('auto',) + STREAM_METHODS} "
            "(direct/lsqr/sap/fossils need the in-memory lstsq)"
        )
    if key is None:
        raise ValueError("stream_lstsq needs a PRNG key (all methods sketch)")
    if steptol is None:
        steptol = 32 * float(jnp.finfo(b.dtype).eps)
    s = sketch_size if sketch_size is not None else default_sketch_size(n, m)

    # ---- pass 1: sketch A and b together ------------------------------
    B, op, c = stream_sketch(
        source, key, sketch=sketch, sketch_size=s, backend=backend, rhs=b
    )
    lam = None if reg is None else jnp.asarray(reg, b.dtype)
    if lam is not None:
        # Structured ridge embedding [B; √λI], [c; 0] — the identity block
        # is exact (never sketched, never streamed): sketch.AugmentedSketch.
        sqrt_lam = jnp.sqrt(lam)
        B = jnp.concatenate([B, sqrt_lam * jnp.eye(n, dtype=B.dtype)], axis=0)
        c = jnp.concatenate([c, jnp.zeros((n,), c.dtype)])
    with obs_trace.span("factor.qr", shape=tuple(B.shape)):
        factor = SketchedFactor.from_sketch(B)
        obs_trace.maybe_block(factor.R)
    x0 = factor.sketch_and_solve(c)

    def _maybe_certificate(x):
        """(certificate, rnorm, arnorm) — Nones when not certifying.  The
        diagnostics come from the certificate's own fused pass, so
        certified runs never stream the residual twice."""
        if not certify:
            return None, None, None
        return _certify_streamed(
            source, b, x, factor, jax.random.fold_in(key, 0xCE27),
            lam=lam, sketch_rows=s, n_probes=certified_probes,
            target=certified_rtol,
        )

    # ---- pass 2(+): iterate with streamed products --------------------
    hist = []
    if method == "sketch_and_solve":
        # Single-pass: no second stream, hence no residual diagnostics —
        # unless a certificate was requested, whose fused pass fills them.
        nan = jnp.asarray(jnp.nan, b.dtype)
        cert, rnorm, arnorm = _maybe_certificate(x0)
        if cert is None:
            rnorm = arnorm = nan
        return SolveResult(
            x=x0,
            istop=jnp.asarray(1, jnp.int32),
            itn=jnp.asarray(0, jnp.int32),
            rnorm=rnorm,
            arnorm=arnorm,
            used_fallback=jnp.asarray(False),
            history=jnp.zeros((0,), b.dtype) if history else None,
            method="stream_sketch_and_solve",
            certificate=cert,
        )
    if method == "iterative":
        alpha, beta = damping_momentum(s, n)
        with obs_trace.span("stream.solve", method="iterative"):
            x, istop, itn, _, _, hist = _iterative_streamed(
                source, b, factor, x0, alpha=alpha, beta=beta, reg=lam,
                atol=atol, btol=btol, steptol=steptol, iter_lim=iter_lim,
                history=history,
            )
        cert, rnorm_c, arnorm_c = _maybe_certificate(x)
        if cert is not None:
            rnorm, arnorm = rnorm_c, arnorm_c
        else:
            rnorm, arnorm = _final_diagnostics(source, b, x, lam)
    else:  # saa: preconditioned LSQR on the whitened system, warm-started
        if lam is None:
            def mv(z):
                return _stream_matvec(source, factor.precondition(z))

            def rmv(u):
                return factor.rt_solve(_stream_rmatvec(source, u))

            b_solve = b
        else:
            sqrt_lam = jnp.sqrt(lam)

            def mv(z):
                v = factor.precondition(z)
                return jnp.concatenate([_stream_matvec(source, v), sqrt_lam * v])

            def rmv(u):
                g = _stream_rmatvec(source, u[:m]) + sqrt_lam * u[m:]
                return factor.rt_solve(g)

            b_solve = jnp.concatenate([b, jnp.zeros((n,), b.dtype)])
        z0 = factor.warm_start(c)
        with obs_trace.span("stream.solve", method="saa"):
            z, istop, itn, rnorm, arnorm, hist = _lsqr_streamed(
                mv, rmv, b_solve, z0, atol=atol, btol=btol, steptol=steptol,
                iter_lim=iter_lim, history=history,
            )
        x = factor.precondition(z)
        cert, rnorm_c, arnorm_c = _maybe_certificate(x)
        if cert is not None:
            rnorm, arnorm = rnorm_c, arnorm_c
        elif lam is not None:
            rnorm, arnorm = _final_diagnostics(source, b, x, lam)
        else:
            rnorm = jnp.asarray(rnorm, b.dtype)
            arnorm = jnp.asarray(arnorm, b.dtype)

    return SolveResult(
        x=x,
        istop=jnp.asarray(istop, jnp.int32),
        itn=jnp.asarray(itn, jnp.int32),
        rnorm=jnp.asarray(rnorm, b.dtype),
        arnorm=jnp.asarray(arnorm, b.dtype),
        used_fallback=jnp.asarray(False),
        history=jnp.asarray(hist, b.dtype) if history else None,
        method=f"stream_{method}",
        certificate=cert,
    )


# --------------------------------------------------------------------------
# Session
# --------------------------------------------------------------------------


class _CountingSource(RowSource):
    """Transparent wrapper that counts passes/tiles into a stats dict.

    Unknown attributes forward to the wrapped source, so the dispatch
    probes in ``_stream_matvec`` et al. still find a ``ClusterEngine``'s
    distributed methods through the wrapper (the engine then counts its
    own passes/tiles via its ``counters`` hook — the serial counting here
    only fires on the serial ``tiles()`` path, never both).
    """

    def __init__(self, inner: RowSource, stats: dict):
        self.inner = inner
        self.stats = stats
        self.shape = inner.shape
        self.dtype = inner.dtype

    def __getattr__(self, name):
        return getattr(self.__dict__["inner"], name)

    @property
    def tile_rows(self):
        return self.inner.tile_rows

    @property
    def supports_random_access(self):
        return self.inner.supports_random_access

    def read_rows(self, offset, length):
        return self.inner.read_rows(offset, length)

    def tiles(self):
        self.stats["passes"] += 1
        for offset, tile in self.inner.tiles():
            self.stats["tiles"] += 1
            yield offset, tile


class StreamingSolver:
    """One streamed sketch + QR, amortized over many right-hand sides.

    The out-of-core twin of :class:`repro.core.session.SketchedSolver`:
    construction streams the tiles ONCE to build the sketched factor;
    each ``solve(b)`` then costs one streamed sketch of b (pass over b
    only, not A) plus the pass-2 iteration streams.  ``solve_many(B)``
    runs the column-batched whitened LSQR — k right-hand sides share
    every stream, so the marginal cost per extra RHS is one matmul
    column.

    ``stats`` counts ``sketches`` / ``qr_factorizations`` / ``solves``
    like the in-memory session, plus ``passes`` / ``tiles`` so the
    streaming cost model is observable.
    """

    def __init__(
        self,
        source,
        key: jax.Array,
        *,
        sketch: str = "clarkson_woodruff",
        sketch_size: int | None = None,
        reg: float | jax.Array | None = None,
        tile_rows: int | None = None,
        atol: float = 0.0,
        btol: float = 0.0,
        steptol: float | None = None,
        iter_lim: int = 100,
        backend: str = "auto",
        cluster=None,
    ):
        self.stats = REGISTRY.stats_dict("streaming", {
            "sketches": 0, "qr_factorizations": 0, "solves": 0,
            "passes": 0, "tiles": 0,
        })
        inner, self._owned_engine = _maybe_cluster(
            as_source(source, tile_rows), cluster, backend,
            counters=self.stats,
        )
        try:
            self.source = _CountingSource(inner, self.stats)
            m, n = self.source.shape
            self.shape = (m, n)
            self.reg = reg
            self.sketch_size = (
                sketch_size if sketch_size is not None
                else default_sketch_size(n, m)
            )
            self.backend = resolve_backend(backend).name
            self._dtype = jnp.dtype(self.source.dtype)
            if steptol is None:
                steptol = 32 * float(jnp.finfo(self._dtype).eps)
            self._kw = dict(atol=atol, btol=btol, steptol=steptol,
                            iter_lim=iter_lim)

            B, self._sketch_op, _ = stream_sketch(
                self.source, key, sketch=sketch,
                sketch_size=self.sketch_size, backend=self.backend,
            )
            self.stats["sketches"] += 1
            if reg is not None:
                sqrt_lam = jnp.sqrt(jnp.asarray(reg, B.dtype))
                B = jnp.concatenate(
                    [B, sqrt_lam * jnp.eye(n, dtype=B.dtype)], axis=0
                )
            self.factor = SketchedFactor.from_sketch(B)
            self.stats["qr_factorizations"] += 1
        except BaseException:
            self.close()  # a failed build must not leak the worker pool
            raise

    def close(self):
        """Release a cluster engine this solver built from a ``cluster=``
        spec (worker threads + temp checkpoint dir); no-op otherwise and
        on repeat calls.  A caller-provided engine is never touched."""
        if self._owned_engine is not None:
            self._owned_engine.close()
            self._owned_engine = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    # ------------------------------------------------------------- helpers
    def _sketch_rhs(self, B_rhs: jax.Array) -> jax.Array:
        """S·b (or S·B for stacked columns) — streams b tile-wise through
        the accumulator, so the Gaussian operator never materializes S and
        the sketch of b costs O(m·k), one pass over b only."""
        m, n = self.shape
        cols = B_rhs[:, None] if B_rhs.ndim == 1 else B_rhs
        acc = make_accumulator(
            self._sketch_op, cols.shape[1], dtype=self._dtype,
            backend=self.backend,
        )
        step = self.source.tile_rows
        for o in range(0, m, step):
            acc.update(cols[o : o + step], o)
        c = acc.finalize()
        if self.reg is not None:
            c = jnp.concatenate([c, jnp.zeros((n, c.shape[1]), c.dtype)])
        return c[:, 0] if B_rhs.ndim == 1 else c

    def _diagnose(self, b, x):
        rn, arn = _final_diagnostics(
            self.source, b, x,
            None if self.reg is None else jnp.asarray(self.reg, self._dtype),
        )
        return rn, arn

    def _whitened_ops(self):
        """(mv, rmv) of the whitened — and, under ridge, augmented —
        system; generic over single vectors and stacked columns."""
        factor, source = self.factor, self.source
        m, n = self.shape
        if self.reg is None:
            def mv(z):
                return _stream_matvec(source, factor.precondition(z))

            def rmv(u):
                return factor.rt_solve(_stream_rmatvec(source, u))
        else:
            sqrt_lam = jnp.sqrt(jnp.asarray(self.reg, self._dtype))

            def mv(z):
                v = factor.precondition(z)
                return jnp.concatenate(
                    [_stream_matvec(source, v), sqrt_lam * v]
                )

            def rmv(u):
                g = _stream_rmatvec(source, u[:m]) + sqrt_lam * u[m:]
                return factor.rt_solve(g)
        return mv, rmv

    def _augment_rhs(self, b):
        if self.reg is None:
            return b
        n = self.shape[1]
        tail = jnp.zeros((n,) + b.shape[1:], b.dtype)
        return jnp.concatenate([b, tail])

    # -------------------------------------------------------------- solves
    def solve(self, b: jax.Array, *, method: str = "saa",
              history: bool = False) -> SolveResult:
        """One right-hand side against the stored factor; ``method`` as in
        :func:`stream_lstsq` (``"saa"``, ``"iterative"``,
        ``"sketch_and_solve"``)."""
        m, n = self.shape
        b = jnp.asarray(b)
        if b.shape != (m,):
            raise ValueError(f"b must have shape ({m},), got {b.shape}")
        method = _ALIASES.get(method, method)
        with obs_trace.span("streaming.solve", method=method):
            c = self._sketch_rhs(b)
            x0 = self.factor.sketch_and_solve(c)
            lam = None if self.reg is None else jnp.asarray(self.reg, b.dtype)
            hist = []
            if method == "sketch_and_solve":
                nan = jnp.asarray(jnp.nan, b.dtype)
                self.stats["solves"] += 1
                return SolveResult(
                    x=x0, istop=jnp.asarray(1, jnp.int32),
                    itn=jnp.asarray(0, jnp.int32), rnorm=nan, arnorm=nan,
                    used_fallback=jnp.asarray(False),
                    method="stream_sketch_and_solve",
                )
            if method == "iterative":
                alpha, beta = damping_momentum(self.sketch_size, n)
                x, istop, itn, _, _, hist = _iterative_streamed(
                    self.source, b, self.factor, x0, alpha=alpha, beta=beta,
                    reg=lam, history=history, **self._kw,
                )
            elif method == "saa":
                mv, rmv = self._whitened_ops()
                z, istop, itn, _, _, hist = _lsqr_streamed(
                    mv, rmv, self._augment_rhs(b), self.factor.warm_start(c),
                    history=history, **self._kw,
                )
                x = self.factor.precondition(z)
            else:
                raise ValueError(
                    f"unknown streaming method {method!r}; "
                    f"have {STREAM_METHODS}"
                )
            rnorm, arnorm = self._diagnose(b, x)
        self.stats["solves"] += 1
        return SolveResult(
            x=x, istop=jnp.asarray(istop, jnp.int32),
            itn=jnp.asarray(itn, jnp.int32), rnorm=rnorm, arnorm=arnorm,
            used_fallback=jnp.asarray(False),
            history=jnp.asarray(hist, b.dtype) if history else None,
            method=f"stream_{method}",
        )

    def solve_many(self, B: jax.Array, *, method: str = "saa") -> SolveResult:
        """k stacked right-hand sides (m, k) → x of shape (n, k).

        Every stream serves ALL k columns (the per-tile products become
        matmuls), so k solves cost the iteration streams of one.
        ``method="saa"`` (default) runs the column-batched preconditioned
        LSQR — per-column recurrences, shared streams — and iterates
        until the slowest column stops; ``method="iterative"`` runs the
        block heavy-ball iteration on the overall (Frobenius) step floor.
        """
        m, n = self.shape
        B = jnp.asarray(B)
        if B.ndim != 2 or B.shape[0] != m:
            raise ValueError(
                f"solve_many needs B of shape ({m}, k), got {B.shape}"
            )
        method = _ALIASES.get(method, method)
        with obs_trace.span(
            "streaming.solve_many", method=method, k=int(B.shape[1])
        ):
            C = self._sketch_rhs(B)
            lam = None if self.reg is None else jnp.asarray(self.reg, B.dtype)
            if method == "saa":
                mv, rmv = self._whitened_ops()
                Z, istop, itn, _, _, _ = _lsqr_streamed(
                    mv, rmv, self._augment_rhs(B), self.factor.warm_start(C),
                    **self._kw,
                )
                X = self.factor.precondition(Z)
            elif method == "iterative":
                X0 = self.factor.sketch_and_solve(C)
                alpha, beta = damping_momentum(self.sketch_size, n)
                X, istop, itn, _, _, _ = _iterative_streamed(
                    self.source, B, self.factor, X0, alpha=alpha, beta=beta,
                    reg=lam, **self._kw,
                )
                istop = jnp.full((B.shape[1],), istop, jnp.int32)
            else:
                raise ValueError(
                    f"solve_many supports methods ('saa', 'iterative'); "
                    f"got {method!r}"
                )
            rn2, G = _stream_residual_grad(self.source, B, X)
            if lam is not None:
                G = G - lam * X
        self.stats["solves"] += int(B.shape[1])
        return SolveResult(
            x=X, istop=jnp.asarray(istop, jnp.int32),
            itn=jnp.asarray(itn, jnp.int32),
            rnorm=jnp.sqrt(rn2), arnorm=jnp.linalg.norm(G, axis=0),
            used_fallback=jnp.zeros(B.shape[1], bool),
            method=f"stream_{method}",
        )
