"""Row sources — the input protocol of the streaming sketch engine.

A :class:`RowSource` is a *re-iterable* stream of ``(row_offset, tile)``
chunks that together cover the rows of a conceptually (m, n) data matrix A
that is never materialized in one piece.  ``tiles()`` must yield the tiles
in ascending, contiguous, non-overlapping row order (offset 0 first) and
must be callable any number of times — the two-pass solvers in
``repro.streaming.solve`` stream once to build the sketch and then
re-stream per iteration for the tiled ``A@v`` / ``Aᵀ@u`` products.

Concrete sources:

- :class:`ArraySource`    — an in-memory array, sliced into row tiles
  (the testing/benchmark source; also what plain arrays coerce to).
- :class:`CallbackSource` — ``fn(offset, length) -> tile`` random-access
  producer (a database range query, an object-store read, a feature
  transformer applied on the fly).
- :class:`GeneratorSource`— a zero-argument factory returning a fresh
  iterable of row tiles (for producers that are naturally sequential);
  the factory is re-invoked per pass, which is what makes a one-shot
  generator protocol re-streamable.
- :class:`MemmapSource`   — a memory-mapped ``.npy`` file; tiles are read
  through ``numpy.memmap`` so at most ``tile_rows`` rows are resident.
- :class:`ShardedSource`  — an ordered list of per-shard sources with
  global row offsets (multi-host ingest); each shard can be accumulated
  independently and the partial sketches merged associatively
  (``repro.streaming.accumulate``).

``as_source`` coerces ``RowSource | jax.Array | numpy array | .npy path``
into the protocol and is called at the top of every streaming driver.
"""
from __future__ import annotations

import os
from typing import Callable, Iterable, Iterator, Sequence

import jax
import numpy as np

__all__ = [
    "RowSource",
    "ArraySource",
    "CallbackSource",
    "GeneratorSource",
    "MemmapSource",
    "ShardedSource",
    "as_source",
    "DEFAULT_TILE_ROWS",
]

DEFAULT_TILE_ROWS = 8192


class RowSource:
    """Protocol base: a re-streamable row-tile view of an (m, n) matrix."""

    shape: tuple[int, int]
    dtype: np.dtype

    def tiles(self) -> Iterator[tuple[int, jax.Array]]:
        """Yield ``(row_offset, tile)`` in ascending contiguous order,
        covering every row exactly once.  ``row_offset`` is a Python int
        (tile boundaries are host-side loop state); ``tile`` is a
        ``(t, n)`` array-like with 1 ≤ t ≤ ``tile_rows``."""
        raise NotImplementedError

    # Optional random access: sources that can serve an arbitrary row
    # window implement ``read_rows`` (Array/Memmap/Callback do).  The
    # cluster shard views (``repro.cluster.shard.RowRangeSource``) prefer
    # it — a worker then reads ONLY its own rows; sources without it fall
    # back to filtering ``tiles()``, which is correct but streams the
    # whole parent.  ``None`` here is the "not supported" marker probed
    # via ``supports_random_access``.
    read_rows = None

    @property
    def supports_random_access(self) -> bool:
        return callable(self.read_rows)

    @property
    def tile_rows(self) -> int:
        return DEFAULT_TILE_ROWS

    @property
    def num_tiles(self) -> int:
        return -(-self.shape[0] // self.tile_rows)

    def __repr__(self):
        m, n = self.shape
        return (
            f"{type(self).__name__}(shape=({m}, {n}), "
            f"tile_rows={self.tile_rows})"
        )


def _check_tile_rows(tile_rows: int) -> int:
    tile_rows = int(tile_rows)
    if tile_rows < 1:
        raise ValueError(f"tile_rows must be >= 1, got {tile_rows}")
    return tile_rows


class ArraySource(RowSource):
    """Row tiles sliced from an in-memory (m, n) array.

    The degenerate source: nothing is out-of-core, but it gives every
    consumer one code path and is how the equivalence tests drive the
    accumulators over arbitrary tilings (``boundaries=`` pins an explicit
    uneven tiling).
    """

    def __init__(self, A, tile_rows: int = DEFAULT_TILE_ROWS, *,
                 boundaries: Sequence[int] | None = None):
        if A.ndim != 2:
            raise ValueError(f"need a 2-D matrix, got shape {A.shape}")
        self.A = A
        self.shape = tuple(A.shape)
        self.dtype = A.dtype
        self._tile_rows = _check_tile_rows(tile_rows)
        if boundaries is not None:
            boundaries = sorted(set(int(b) for b in boundaries) | {0, A.shape[0]})
            if boundaries[0] < 0 or boundaries[-1] > A.shape[0]:
                raise ValueError(f"boundaries out of range: {boundaries}")
            self._offsets = boundaries
            self._tile_rows = max(
                b - a for a, b in zip(boundaries[:-1], boundaries[1:])
            )
        else:
            self._offsets = list(range(0, A.shape[0], self._tile_rows))
            self._offsets.append(A.shape[0])

    @property
    def tile_rows(self) -> int:
        return self._tile_rows

    @property
    def num_tiles(self) -> int:
        return len(self._offsets) - 1

    def tiles(self):
        for a, b in zip(self._offsets[:-1], self._offsets[1:]):
            yield a, self.A[a:b]

    def read_rows(self, offset: int, length: int):
        return self.A[offset : offset + length]


class CallbackSource(RowSource):
    """``fn(offset, length) -> (length, n) tile`` random-access producer."""

    def __init__(self, fn: Callable, shape: tuple[int, int], dtype,
                 tile_rows: int = DEFAULT_TILE_ROWS):
        self.fn = fn
        self.shape = (int(shape[0]), int(shape[1]))
        self.dtype = np.dtype(dtype)
        self._tile_rows = _check_tile_rows(tile_rows)

    @property
    def tile_rows(self) -> int:
        return self._tile_rows

    def tiles(self):
        m, n = self.shape
        for o in range(0, m, self._tile_rows):
            yield o, self.read_rows(o, min(self._tile_rows, m - o))

    def read_rows(self, offset: int, length: int):
        tile = self.fn(offset, length)
        if tuple(tile.shape) != (length, self.shape[1]):
            raise ValueError(
                f"callback returned shape {tuple(tile.shape)} for "
                f"(offset={offset}, length={length}); expected "
                f"({length}, {self.shape[1]})"
            )
        return tile


class GeneratorSource(RowSource):
    """A zero-arg ``factory()`` returning a fresh iterable of row tiles.

    The factory indirection is what makes sequential producers (file
    readers, network streams) usable by the TWO-pass solvers: each pass
    calls ``factory()`` again.  Offsets are assigned by running count and
    validated against ``shape`` as the stream is consumed.
    """

    def __init__(self, factory: Callable[[], Iterable], shape: tuple[int, int],
                 dtype, tile_rows: int = DEFAULT_TILE_ROWS):
        self.factory = factory
        self.shape = (int(shape[0]), int(shape[1]))
        self.dtype = np.dtype(dtype)
        self._tile_rows = _check_tile_rows(tile_rows)

    @property
    def tile_rows(self) -> int:
        return self._tile_rows

    def tiles(self):
        m, n = self.shape
        off = 0
        for tile in self.factory():
            if tile.ndim != 2 or tile.shape[1] != n:
                raise ValueError(
                    f"generator tile has shape {tuple(tile.shape)}; "
                    f"expected (t, {n})"
                )
            if off + tile.shape[0] > m:
                raise ValueError(
                    f"generator produced more than m={m} rows"
                )
            yield off, tile
            off += tile.shape[0]
        if off != m:
            raise ValueError(f"generator covered {off} of m={m} rows")


class MemmapSource(RowSource):
    """Row tiles read through a memory-mapped ``.npy`` file.

    ``np.load(mmap_mode="r")`` keeps A on disk; each ``tiles()`` step
    materializes only the current ``(tile_rows, n)`` window, so peak
    data-matrix memory is the tile budget, not m·n.  This is the
    out-of-core workhorse source (see ``examples/streaming_lstsq.py``).
    """

    def __init__(self, path, tile_rows: int = DEFAULT_TILE_ROWS):
        self.path = os.fspath(path)
        mm = np.load(self.path, mmap_mode="r")
        if mm.ndim != 2:
            raise ValueError(f"{self.path}: need a 2-D array, got {mm.shape}")
        self.shape = tuple(mm.shape)
        self.dtype = mm.dtype
        self._tile_rows = _check_tile_rows(tile_rows)
        del mm  # keep no live map between passes

    @property
    def tile_rows(self) -> int:
        return self._tile_rows

    def tiles(self):
        mm = np.load(self.path, mmap_mode="r")
        m, n = self.shape
        for o in range(0, m, self._tile_rows):
            t = min(self._tile_rows, m - o)
            # np.array forces the read of exactly this window; the memmap
            # pages can be dropped by the OS as soon as we move on.
            yield o, np.array(mm[o : o + t])

    def read_rows(self, offset: int, length: int):
        mm = np.load(self.path, mmap_mode="r")
        return np.array(mm[offset : offset + length])


class ShardedSource(RowSource):
    """Ordered concatenation of per-shard sources (multi-host ingest).

    ``tiles()`` walks the shards in row order with globalized offsets, so
    a ``ShardedSource`` drops into any single-host driver unchanged.  For
    genuinely parallel ingest, accumulate each ``shards[i]`` independently
    (offset by ``shard_offsets[i]`` — see ``accumulate.partial_sketch``)
    and tree-merge the partial accumulators; the merge is associative.
    """

    def __init__(self, shards: Sequence[RowSource]):
        shards = [as_source(s) for s in shards]
        if not shards:
            raise ValueError("need at least one shard")
        n = shards[0].shape[1]
        if any(s.shape[1] != n for s in shards):
            raise ValueError(
                f"all shards need {n} columns, got "
                f"{[s.shape for s in shards]}"
            )
        self.shards = shards
        self.shard_offsets = []
        m = 0
        for s in shards:
            self.shard_offsets.append(m)
            m += s.shape[0]
        self.shape = (m, n)
        self.dtype = shards[0].dtype

    @property
    def tile_rows(self) -> int:
        return max(s.tile_rows for s in self.shards)

    def tiles(self):
        for base, shard in zip(self.shard_offsets, self.shards):
            for o, tile in shard.tiles():
                yield base + o, tile

    @property
    def supports_random_access(self) -> bool:
        return all(s.supports_random_access for s in self.shards)

    def read_rows(self, offset: int, length: int):
        if not self.supports_random_access:
            raise TypeError(
                "ShardedSource.read_rows needs every shard to support "
                "random access"
            )
        pieces = []
        for base, shard in zip(self.shard_offsets, self.shards):
            lo = max(offset, base)
            hi = min(offset + length, base + shard.shape[0])
            if lo < hi:
                pieces.append(np.asarray(shard.read_rows(lo - base, hi - lo)))
        if len(pieces) == 1:
            return pieces[0]
        return np.concatenate(pieces, axis=0)


def as_source(A, tile_rows: int | None = None) -> RowSource:
    """Coerce ``RowSource | array | .npy path`` into the protocol.

    Idempotent on sources (``tile_rows`` must then be None — a source owns
    its tiling).  Arrays (jax or numpy) become :class:`ArraySource`,
    ``.npy`` paths become :class:`MemmapSource`.
    """
    if isinstance(A, RowSource):
        if tile_rows is not None:
            raise ValueError(
                "tile_rows cannot override an existing RowSource's tiling; "
                "construct the source with the tiling you want"
            )
        return A
    tile_rows = DEFAULT_TILE_ROWS if tile_rows is None else tile_rows
    if isinstance(A, (str, os.PathLike)):
        return MemmapSource(A, tile_rows)
    if isinstance(A, (jax.Array, np.ndarray)):
        return ArraySource(A, tile_rows)
    raise TypeError(
        f"cannot make a RowSource from {type(A).__name__}; pass a RowSource, "
        "a 2-D array, or a path to a .npy file"
    )
