"""Mergeable partial-sketch accumulators — the heart of the streaming engine.

Every sketch S in ``repro.core.sketch`` is linear in the rows of A, so
``SA`` decomposes over any row tiling and partial sketches from disjoint
tiles combine associatively.  A :class:`SketchAccumulator` holds that
partial state:

    acc = make_accumulator(op, ncols)
    for offset, tile in source.tiles():
        acc.update(tile, offset)       # O(tile) work, O(state) memory
    B = acc.finalize()                 # == op.apply(A) for the full A

``merge`` combines accumulators built over disjoint row ranges (different
tiles, different hosts) and is associative, so partial sketches
tree-reduce; :func:`sharded_sketch` is the collective (shard_map + psum)
form of the same merge for a row-sharded in-memory A.

Exactness (what the property tests pin):

- **countsketch / uniform_sparse** — updates scatter-add *into the state*
  in row order, which is exactly the fold XLA's ``segment_sum`` performs;
  sequential streaming is bit-for-bit equal to the monolithic apply.
- **sparse_sign** — the monolithic apply sums k independent scatter
  passes *before* scaling, so the state keeps the (k, d, ncols) per-pass
  partials and reproduces that exact reduction at finalize: bitwise too.
- **srht** — the Hadamard transform couples every row, so the state is
  the (m_pad, ncols) D-signed row buffer (placement, no summation) and
  FWHT + subsample + 1/√d run once at finalize: bitwise equal, by
  construction, to the reference apply.  Note the buffer is O(m_pad·n) —
  SRHT streams *compute* (single pass, mergeable) but not *memory*;
  prefer the scatter kinds for out-of-core data.
- **gaussian / uniform_dense** — each tile contributes one (d, t)×(t, n)
  block product.  The realized S blocks are bitwise identical to slicing
  the monolithic S (counter-based regeneration for Gaussian), but summing
  block products groups the fp additions differently from one big GEMM,
  so the product agrees to accumulation-order rounding only (same caveat
  as swapping sketch backends).

``merge`` adds partial states, which for the additive kinds introduces the
same accumulation-order rounding; only SRHT merges exactly (disjoint row
placements).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P

from ..core import backend as backend_lib
from ..core import sketch as sketch_lib
from ..sharding import shard_map_compat

__all__ = [
    "SketchAccumulator",
    "make_accumulator",
    "accumulate_source",
    "merge_all",
    "sharded_sketch",
]


class SketchAccumulator:
    """Partial sketch of a row-streamed A: update / merge / finalize.

    ``ncols`` is the column count of the streamed tiles (n, or n+1 when
    the right-hand side rides along as an extra column).  ``rows_seen``
    tracks coverage; ``finalize`` refuses to produce a sketch from a
    stream that missed rows (merge first, then finalize).
    """

    def __init__(self, op, ncols: int, dtype=jnp.float64, backend="auto"):
        self.op = op
        self.ncols = int(ncols)
        self.dtype = jnp.dtype(dtype)
        self.backend = backend_lib.resolve(backend).name
        self.rows_seen = 0
        self.tiles_seen = 0
        self.state = self._init_state()

    # ---------------------------------------------------- per-kind state
    def _init_state(self):
        op = self.op
        if isinstance(op, sketch_lib.SRHTSketch):
            # Placement buffer for the finalize-time Hadamard transform.
            # Kept host-side (numpy) so per-tile updates are in-place
            # writes, not O(m_pad·ncols) device-buffer copies.
            return np.zeros((op.m_pad, self.ncols), np.dtype(self.dtype))
        if isinstance(op, sketch_lib.SparseSignSketch):
            return jnp.zeros((op.k, op.d, self.ncols), self.dtype)
        return jnp.zeros((op.d, self.ncols), self.dtype)

    # ----------------------------------------------------------- update
    def update(self, tile, row_offset: int) -> "SketchAccumulator":
        """Fold rows [row_offset, row_offset + t) of A into the state."""
        op = self.op
        t, ncols = tile.shape
        if ncols != self.ncols:
            raise ValueError(f"tile has {ncols} columns, expected {self.ncols}")
        if row_offset < 0 or row_offset + t > op.m:
            raise ValueError(
                f"tile rows [{row_offset}, {row_offset + t}) outside "
                f"[0, {op.m})"
            )
        sl = slice(row_offset, row_offset + t)
        if isinstance(op, sketch_lib.SRHTSketch):
            self.state[sl] += np.asarray(op.apply_rows(tile, row_offset))
        elif isinstance(op, sketch_lib.CountSketch):
            tile = jnp.asarray(tile)
            contrib = op.signs[sl][:, None].astype(tile.dtype) * tile
            self.state = self.state.at[op.buckets[sl]].add(contrib)
        elif isinstance(op, sketch_lib.UniformSparseSketch):
            tile = jnp.asarray(tile)
            contrib = op.values[sl][:, None].astype(tile.dtype) * tile
            self.state = self.state.at[op.buckets[sl]].add(contrib)
        elif isinstance(op, sketch_lib.SparseSignSketch):
            tile = jnp.asarray(tile)
            contrib = op.signs[:, sl, None].astype(tile.dtype) * tile[None]
            self.state = jax.vmap(lambda s, h, c: s.at[h].add(c))(
                self.state, op.buckets[:, sl], contrib
            )
        else:  # dense-S kinds: one (d, t) × (t, ncols) block product
            self.state = self.state + op.apply_rows(
                jnp.asarray(tile), row_offset, backend=self.backend
            )
        self.rows_seen += t
        self.tiles_seen += 1
        return self

    # ------------------------------------------------------------ merge
    def merge(self, other: "SketchAccumulator") -> "SketchAccumulator":
        """Combine with a partial sketch over a DISJOINT row range.

        Associative (tree-reduce freely across tiles/hosts); both sides
        must have been built from the same operator draw.
        """
        same_shape = type(self.op) is type(other.op) and (
            self.op.d,
            self.op.m,
            self.ncols,
        ) == (other.op.d, other.op.m, other.ncols)
        if same_shape and self.op is not other.op:
            # distinct objects (e.g. independently deserialized per host):
            # verify it is the SAME draw, not merely the same shape —
            # merging two different S's silently poisons the sketch
            def leaf_eq(a, b):
                if jnp.issubdtype(a.dtype, jax.dtypes.prng_key):
                    a, b = jax.random.key_data(a), jax.random.key_data(b)
                return a.shape == b.shape and bool(jnp.array_equal(a, b))

            la, lb = jax.tree.leaves(self.op), jax.tree.leaves(other.op)
            same_shape = len(la) == len(lb) and all(
                leaf_eq(a, b) for a, b in zip(la, lb)
            )
        if not same_shape:
            raise ValueError(
                "can only merge partial sketches of the same operator draw; "
                f"got {type(self.op).__name__}(d={self.op.d}, m={self.op.m}) "
                f"x{self.ncols} vs "
                f"{type(other.op).__name__}(d={other.op.d}, m={other.op.m}) "
                f"x{other.ncols}"
            )
        out = make_accumulator(
            self.op, self.ncols, dtype=self.dtype, backend=self.backend
        )
        out.state = self.state + other.state
        out.rows_seen = self.rows_seen + other.rows_seen
        out.tiles_seen = self.tiles_seen + other.tiles_seen
        return out

    # --------------------------------------------------------- finalize
    def finalize(self) -> jax.Array:
        """The assembled sketch B = S·A — equals ``op.apply`` on the full A."""
        if self.rows_seen != self.op.m:
            raise ValueError(
                f"stream covered {self.rows_seen} of m={self.op.m} rows; "
                "merge the remaining partial sketches before finalize"
            )
        op = self.op
        if isinstance(op, sketch_lib.SRHTSketch):
            HDx = sketch_lib.fwht(jnp.asarray(self.state))
            return HDx[op.rows] / jnp.sqrt(jnp.asarray(op.d, self.dtype))
        if isinstance(op, sketch_lib.SparseSignSketch):
            return self.state.sum(0) / jnp.sqrt(jnp.asarray(op.k, self.dtype))
        return self.state


def make_accumulator(op, ncols: int, dtype=jnp.float64, backend="auto"):
    """Fresh accumulator for one operator draw (see module docstring)."""
    return SketchAccumulator(op, ncols, dtype=dtype, backend=backend)


def accumulate_source(
    op, source, *, base_offset: int = 0, backend="auto", acc=None
) -> SketchAccumulator:
    """Stream every tile of ``source`` into an accumulator.

    ``base_offset`` shifts the source's local offsets into the global row
    space — accumulating shard i of a ``ShardedSource`` uses
    ``base_offset=source.shard_offsets[i]`` so the per-shard partials
    merge into the same global sketch.
    """
    m, ncols = source.shape
    if acc is None:
        acc = make_accumulator(
            op, ncols, dtype=jnp.dtype(source.dtype), backend=backend
        )
    for offset, tile in source.tiles():
        acc.update(tile, base_offset + offset)
    return acc


def merge_all(accs) -> SketchAccumulator:
    """Pairwise tree-reduction of partial accumulators (associative)."""
    accs = list(accs)
    if not accs:
        raise ValueError("nothing to merge")
    while len(accs) > 1:
        nxt = [
            accs[i].merge(accs[i + 1]) if i + 1 < len(accs) else accs[i]
            for i in range(0, len(accs), 2)
        ]
        accs = nxt
    return accs[0]


def sharded_sketch(A, op, *, mesh, axes=("data",), backend="auto"):
    """S·A for a row-sharded in-memory A in ONE collective.

    The shard_map form of :meth:`SketchAccumulator.merge`: every device
    restricts S to its global row slice (``op.restrict_cols``), sketches
    its local rows, and a single psum tree-reduces the (d, n) partial
    sketches across ``axes``.  Communication is O(d·n), independent of m —
    the same assembly ``repro.core.distributed.sketched_lstsq`` performs
    inside its solver.

    Additive kinds only: SRHT couples rows through the Hadamard transform
    and has no independent column restriction — stream it through the
    padded-buffer accumulator instead.
    """
    if op.stream_semantics != "add":
        raise ValueError(
            f"{type(op).__name__} cannot be assembled by per-shard "
            "restriction (stream_semantics="
            f"{op.stream_semantics!r}); use make_accumulator instead"
        )
    backend = backend_lib.resolve(backend).name
    if isinstance(axes, str):
        axes = (axes,)
    idx = jnp.arange(op.m, dtype=jnp.int32)

    def local(A_i, idx_i):
        sub = op.restrict_cols(idx_i)
        return lax.psum(sub.apply(A_i, backend=backend), axes)

    fn = shard_map_compat(
        local, mesh=mesh, in_specs=(P(axes, None), P(axes)), out_specs=P()
    )
    return fn(A, idx)
