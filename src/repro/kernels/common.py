"""Shared utilities for the TPU Pallas kernels.

Includes a pure-jnp threefry2x32 (bit-identical to the algorithm JAX's own
PRNG uses) that is written with uint32 add/xor/shift only, so the *same
function* runs inside a Pallas kernel body (Mosaic) and in the ``ref.py``
oracles — fused generate-and-multiply kernels are therefore bitwise
testable against their references.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["pad_to", "cdiv", "threefry2x32", "bits_to_gaussian", "key_to_u32"]


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


def pad_to(x: jax.Array, multiples: tuple[int, ...], value=0) -> jax.Array:
    """Zero-pad each axis of ``x`` up to the next multiple."""
    pads = []
    for dim, mult in zip(x.shape, multiples):
        target = cdiv(dim, mult) * mult if mult else dim
        pads.append((0, target - dim))
    if all(p == (0, 0) for p in pads):
        return x
    return jnp.pad(x, pads, constant_values=value)


def key_to_u32(key: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Split a jax PRNG key into its two uint32 words."""
    data = jax.random.key_data(key).astype(jnp.uint32)
    return data[..., 0], data[..., 1]


_ROTS_A = (13, 15, 26, 6)
_ROTS_B = (17, 29, 16, 24)


def _rotl(x, r):
    r = np.uint32(r)
    return (x << r) | (x >> np.uint32(32 - r))


def threefry2x32(k0, k1, x0, x1):
    """Threefry-2x32, 20 rounds (the algorithm behind jax.random).

    All inputs uint32 arrays (broadcastable); returns two uint32 arrays.
    Pure uint32 add/xor/rotate — runs identically in jnp and Pallas/Mosaic.
    """
    k0 = k0.astype(jnp.uint32)
    k1 = k1.astype(jnp.uint32)
    x0 = x0.astype(jnp.uint32)
    x1 = x1.astype(jnp.uint32)
    ks = (k0, k1, k0 ^ k1 ^ np.uint32(0x1BD11BDA))
    x0 = x0 + ks[0]
    x1 = x1 + ks[1]
    for g in range(1, 6):
        rots = _ROTS_A if g % 2 == 1 else _ROTS_B
        for r in rots:
            x0 = x0 + x1
            x1 = _rotl(x1, r) ^ x0
        x0 = x0 + ks[g % 3]
        x1 = x1 + ks[(g + 1) % 3] + np.uint32(g)
    return x0, x1


def bits_to_gaussian(b0, b1, dtype=jnp.float32):
    """Box–Muller on two uint32 bit streams -> one N(0,1) stream."""
    # 24-bit mantissa uniforms in (0, 1):
    u1 = (b0 >> np.uint32(8)).astype(dtype) * dtype(2**-24) + dtype(2**-25)
    u2 = (b1 >> np.uint32(8)).astype(dtype) * dtype(2**-24)
    r = jnp.sqrt(-2.0 * jnp.log(u1)).astype(dtype)
    theta = (2.0 * np.pi * u2).astype(dtype)
    return r * jnp.cos(theta)
