from .fused import countsketch_gram, gaussian_gram, matmul_gram, sketch_qr
from .ops import MAX_FUSED_COLS, cholqr_finish, panel_gram, tsqr
from .ref import panel_gram_ref, tsqr_ref

__all__ = [
    "MAX_FUSED_COLS",
    "cholqr_finish",
    "countsketch_gram",
    "gaussian_gram",
    "matmul_gram",
    "panel_gram",
    "panel_gram_ref",
    "sketch_qr",
    "tsqr",
    "tsqr_ref",
]
