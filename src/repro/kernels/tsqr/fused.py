"""``sketch_qr`` — the fused sketch→QR pipeline entry point.

One call produces the sketched factor (Q, R) AND the sketch B = SA for a
``repro.core.sketch`` operator, without the unfused pipeline's HBM
round-trip of B between the sketch kernel and the QR:

- **pallas** backend, dense A, kernel-backed family → a single fused
  Pallas kernel (``countsketch_gram_kernel`` / ``matmul_gram_kernel`` /
  ``gaussian_gram_kernel``) accumulates each B panel in VMEM and folds it
  straight into the Gram G = BᵀB on its last accumulation step; B is
  written to HBM once and never re-read.  SRHT's Hadamard transform has
  its own two-stage kernel, so its fusion is the QR half: the transform
  output feeds ``panel_gram`` directly instead of a Householder QR.
- **reference** backend (and any non-kernel family or non-dense
  operator) → the standard backend-dispatched apply, then ``panel_gram``
  / a jnp Gram.  Still "fused" where it counts on CPU: the factor comes
  from the GEMM-rate shifted-CholeskyQR3 finisher instead of LAPACK
  Householder QR — the measured win ``benchmarks/kernels_bench.py``
  tracks.

Both routes end in ``ops.cholqr_finish`` (shifted CholeskyQR3 — stable
to κ(B) ≈ 1e10 in f64, validated in tests/test_tsqr.py), and both honour
``precision="mixed"``: the apply/Gram run on a bf16-rounded copy of A
with ≥ f32 accumulation, and the factor is returned upcast to A's dtype
for the fp32/fp64 refinement loops to consume.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..common import cdiv, key_to_u32, pad_to
from .kernel import (
    countsketch_gram_kernel,
    make_gaussian_gram_kernel,
    matmul_gram_kernel,
)
from .ops import MAX_FUSED_COLS, cholqr_finish, panel_gram

__all__ = ["sketch_qr", "countsketch_gram", "matmul_gram", "gaussian_gram"]


def _acc_dtype(dtype):
    return jnp.float32 if dtype in (jnp.bfloat16, jnp.float16) else dtype


def _fused_call(kernel, inputs, in_specs, d, n, bd, interpret, acc):
    """Shared pallas_call plumbing: (B (d, n), G (n, n)) in acc dtype."""
    n_p = max(128, n)
    d_p = cdiv(d, bd) * bd
    m_blocks = in_specs.pop("m_blocks")
    grid = (d_p // bd, m_blocks)
    B, G = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs.pop("specs"),
        out_specs=[
            pl.BlockSpec((bd, n_p), lambda di, mi: (di, 0)),
            pl.BlockSpec((n_p, n_p), lambda di, mi: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((d_p, n_p), acc),
            jax.ShapeDtypeStruct((n_p, n_p), acc),
        ],
        interpret=interpret,
    )(*inputs)
    return B[:d, :n], G[:n, :n]


@partial(
    jax.jit,
    static_argnames=("d", "block_m", "block_d", "interpret"),
)
def countsketch_gram(
    A: jax.Array,
    buckets: jax.Array,
    signs: jax.Array,
    d: int,
    *,
    block_m: int = 256,
    block_d: int = 256,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Fused CountSketch apply + Gram: (B = SA, G = BᵀB), one HBM write of B."""
    if interpret is None:
        from ...core.backend import default_interpret

        interpret = default_interpret()
    m, n = A.shape
    acc = _acc_dtype(A.dtype)
    bm = min(block_m, max(8, m))
    bd = min(block_d, max(8, d))

    A_p = pad_to(A, (bm, max(128, n)))
    h_p = pad_to(buckets.astype(jnp.int32)[:, None], (bm, 1))
    s_p = pad_to(signs.astype(A.dtype)[:, None], (bm, 1))
    m_p, n_p = A_p.shape
    specs = dict(
        m_blocks=m_p // bm,
        specs=[
            pl.BlockSpec((bm, 1), lambda di, mi: (mi, 0)),
            pl.BlockSpec((bm, 1), lambda di, mi: (mi, 0)),
            pl.BlockSpec((bm, n_p), lambda di, mi: (mi, 0)),
        ],
    )
    return _fused_call(
        countsketch_gram_kernel, (h_p, s_p, A_p), specs, d, n, bd,
        interpret, acc,
    )


@partial(jax.jit, static_argnames=("block_m", "block_d", "interpret"))
def matmul_gram(
    S: jax.Array,
    A: jax.Array,
    *,
    block_m: int = 512,
    block_d: int = 256,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Fused dense-sketch apply + Gram: (B = SA, G = BᵀB)."""
    if interpret is None:
        from ...core.backend import default_interpret

        interpret = default_interpret()
    d, m = S.shape
    n = A.shape[1]
    acc = _acc_dtype(A.dtype)
    bm = min(block_m, max(8, m))
    bd = min(block_d, max(8, d))

    S_p = pad_to(S, (bd, bm))
    A_p = pad_to(A, (bm, max(128, n)))
    m_p, n_p = A_p.shape
    specs = dict(
        m_blocks=m_p // bm,
        specs=[
            pl.BlockSpec((bd, bm), lambda di, mi: (di, mi)),
            pl.BlockSpec((bm, n_p), lambda di, mi: (mi, 0)),
        ],
    )
    return _fused_call(
        matmul_gram_kernel, (S_p, A_p), specs, d, n, bd, interpret, acc
    )


@partial(
    jax.jit,
    static_argnames=("d", "block_m", "block_d", "interpret"),
)
def gaussian_gram(
    A: jax.Array,
    key: jax.Array,
    d: int,
    *,
    scale: float | None = None,
    block_m: int = 512,
    block_d: int = 256,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Fused in-kernel-PRNG Gaussian apply + Gram — S never exists in HBM."""
    if interpret is None:
        from ...core.backend import default_interpret

        interpret = default_interpret()
    m, n = A.shape
    if scale is None:
        scale = 1.0 / float(d) ** 0.5
    acc = _acc_dtype(A.dtype)
    bm = min(block_m, max(8, m))
    bd = min(block_d, max(8, d))

    A_p = pad_to(A, (bm, max(128, n)))
    m_p, n_p = A_p.shape
    k0, k1 = key_to_u32(key)
    k0 = k0.reshape(1, 1)
    k1 = k1.reshape(1, 1)
    scale_arr = jnp.asarray(scale, jnp.float32).reshape(1, 1)
    specs = dict(
        m_blocks=m_p // bm,
        specs=[
            pl.BlockSpec((1, 1), lambda di, mi: (0, 0)),
            pl.BlockSpec((1, 1), lambda di, mi: (0, 0)),
            pl.BlockSpec((1, 1), lambda di, mi: (0, 0)),
            pl.BlockSpec((bm, n_p), lambda di, mi: (mi, 0)),
        ],
    )
    return _fused_call(
        make_gaussian_gram_kernel(d), (k0, k1, scale_arr, A_p), specs,
        d, n, bd, interpret, acc,
    )


def _lowp(A_arr: jax.Array, use_pallas: bool) -> jax.Array:
    """The mixed-precision data cast: round to bf16; on the reference
    backend upcast to f32 so accumulation runs ≥ f32 there too."""
    A_lp = A_arr.astype(jnp.bfloat16)
    return A_lp if use_pallas else A_lp.astype(jnp.float32)


def sketch_qr(
    op,
    A,
    *,
    backend: str = "auto",
    precision: str = "full",
    rounds: int = 2,
):
    """Fused sketch→QR: ``(Q, R, B)`` with B = S·A = Q·R.

    ``op`` is any ``repro.core.sketch`` operator, ``A`` a dense array or
    ``repro.core.linop`` operator.  Dispatches per family (see module
    docstring); Q, R and B are returned in A's dtype regardless of
    ``precision`` so downstream refinement runs at full working
    precision.  Equivalent to ``SketchedFactor.from_sketch(op.apply_op(A))``
    up to rounding, with a deterministic diag(R) ≥ 0 sign convention.
    """
    from ...core import backend as backend_lib
    from ...core import linop, sketch as sketch_lib

    if precision not in backend_lib.PRECISIONS:
        raise ValueError(
            f"unknown precision {precision!r}; have {backend_lib.PRECISIONS}"
        )
    rb = backend_lib.resolve(backend)
    A_op = linop.as_operator(A)
    working = A_op.dtype
    mixed = precision == "mixed"

    dense = isinstance(A_op, linop.DenseOperator)
    fusable = (
        rb.use_pallas
        and dense
        and A_op.shape[1] <= MAX_FUSED_COLS
        and isinstance(
            op,
            (
                sketch_lib.CountSketch,
                sketch_lib.GaussianSketch,
                sketch_lib.UniformDenseSketch,
                sketch_lib.SRHTSketch,
            ),
        )
    )

    if fusable:
        A_arr = _lowp(A_op.A, True) if mixed else A_op.A
        blocks = backend_lib.kernel_blocks(
            "tsqr", A_arr.shape[0], A_arr.shape[1], op.d, A_arr.dtype
        )
        if isinstance(op, sketch_lib.CountSketch):
            B, G = countsketch_gram(
                A_arr, op.buckets, op.signs.astype(A_arr.dtype), op.d,
                interpret=rb.interpret, **blocks,
            )
        elif isinstance(op, sketch_lib.GaussianSketch):
            B, G = gaussian_gram(
                A_arr, op.key, op.d, interpret=rb.interpret, **blocks
            )
        elif isinstance(op, sketch_lib.UniformDenseSketch):
            B, G = matmul_gram(
                op.S.astype(A_arr.dtype), A_arr, interpret=rb.interpret,
                **blocks,
            )
        else:  # SRHT: transform via its own kernels, Gram-fused QR half
            B = op.apply(A_arr, backend=backend)
            G = panel_gram(B, interpret=rb.interpret)
        B = B.astype(working)
        G = G.astype(working)
    else:
        from ...core.precond import _sketch_apply

        B = _sketch_apply(op, A_op, backend=backend, precision=precision)
        B = B.astype(working)
        G = B.T @ B
    Q, R = cholqr_finish(B, G, rounds=rounds)
    return Q, R, B
