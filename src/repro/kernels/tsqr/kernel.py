"""Fused sketch→Gram Pallas kernels — the HBM-free half of ``sketch_qr``.

The unfused pipeline round-trips B = SA through HBM twice: the sketch
kernel writes B, then the QR reads it back.  These kernels keep each
(bd, n) panel of B resident in VMEM while it is being accumulated over the
m-grid and, on the panel's LAST accumulation step, immediately fold it
into the Gram matrix G = BᵀB — the only n×n quantity the CholeskyQR
finisher (``ops.cholqr_finish``) needs to produce R.  B is still emitted
once (Q-formation and the certified escalation path store it), but it is
never *re-read*: HBM traffic drops from 2·d·n reads + d·n writes to a
single d·n write, and the Gram GEMM runs at MXU rate on tiles that are
already resident.

Grid convention: ``(d_blocks, m_blocks)`` with m innermost, so each B
panel is revisited across sequential m-steps (legal TPU accumulation via
``pl.when(mi == 0)`` init).  The Gram output block is revisited across the
WHOLE grid (index map constant), initialized at the first grid step and
accumulated at every panel's last m-step.  n is not blocked: the fused
path targets the paper's tall-skinny regime n ≤ a few hundred, where one
(bd, n_pad) panel plus the (n_pad, n_pad) Gram fit VMEM comfortably
(``ops.py`` guards the limit and falls back to the unfused path beyond
it).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..common import bits_to_gaussian, threefry2x32


def _accumulate_gram(b_ref, g_ref, di, mi, m_blocks):
    """Fold the finished B panel into G once per d-block (last m-step)."""

    @pl.when((di == 0) & (mi == 0))
    def _init_gram():
        g_ref[...] = jnp.zeros_like(g_ref)

    @pl.when(mi == m_blocks - 1)
    def _fold():
        b = b_ref[...]
        g_ref[...] += jax.lax.dot_general(
            b,
            b,
            dimension_numbers=(((0,), (0,)), ((), ())),  # bᵀ·b
            preferred_element_type=g_ref.dtype,
        )


def panel_gram_kernel(b_ref, g_ref):
    """G = BᵀB accumulated over row panels.  Grid: (p_blocks,)."""
    pi = pl.program_id(0)

    @pl.when(pi == 0)
    def _init():
        g_ref[...] = jnp.zeros_like(g_ref)

    b = b_ref[...]
    g_ref[...] += jax.lax.dot_general(
        b,
        b,
        dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=g_ref.dtype,
    )


def countsketch_gram_kernel(buckets_ref, signs_ref, a_ref, b_ref, g_ref):
    """Fused CountSketch apply + Gram.  Grid: (d_blocks, m_blocks).

    Same one-hot-matmul recast as ``countsketch.kernel`` (padded rows
    carry sign 0, padded d rows receive no bucket — both Gram-neutral).
    """
    di = pl.program_id(0)
    mi = pl.program_id(1)
    m_blocks = pl.num_programs(1)
    bd = b_ref.shape[0]

    @pl.when(mi == 0)
    def _init():
        b_ref[...] = jnp.zeros_like(b_ref)

    h = buckets_ref[...]  # (bm, 1) int32, global bucket ids
    s = signs_ref[...]  # (bm, 1)
    a = a_ref[...]  # (bm, n_pad)
    bm = a.shape[0]

    local = h - di * bd
    cols = jax.lax.broadcasted_iota(jnp.int32, (bm, bd), 1)
    onehot = (cols == local).astype(a.dtype)

    b_ref[...] += jax.lax.dot_general(
        onehot,
        s * a,
        dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=b_ref.dtype,
    )
    _accumulate_gram(b_ref, g_ref, di, mi, m_blocks)


def matmul_gram_kernel(s_ref, a_ref, b_ref, g_ref):
    """Fused dense-sketch apply + Gram.  Grid: (d_blocks, m_blocks).

    Padded rows of S are zero, so padded d rows of B are zero and
    Gram-neutral.
    """
    di = pl.program_id(0)
    mi = pl.program_id(1)
    m_blocks = pl.num_programs(1)

    @pl.when(mi == 0)
    def _init():
        b_ref[...] = jnp.zeros_like(b_ref)

    b_ref[...] += jnp.dot(
        s_ref[...], a_ref[...], preferred_element_type=b_ref.dtype
    )
    _accumulate_gram(b_ref, g_ref, di, mi, m_blocks)


def make_gaussian_gram_kernel(d: int):
    """Fused in-kernel-PRNG Gaussian apply + Gram (d is static).

    Unlike the CountSketch/matmul variants, padded d rows WOULD hold
    garbage Gaussians times real data — they are masked to zero before
    the MAC so the Gram stays exact.  Counter scheme identical to
    ``sketch_matmul.fused_gaussian_kernel`` (element (i, j) ← pair
    (i, j)), so B matches the unfused kernel bit-for-bit per element.
    """

    def gaussian_gram_kernel(k0_ref, k1_ref, scale_ref, a_ref, b_ref, g_ref):
        di = pl.program_id(0)
        mi = pl.program_id(1)
        m_blocks = pl.num_programs(1)

        @pl.when(mi == 0)
        def _init():
            b_ref[...] = jnp.zeros_like(b_ref)

        a = a_ref[...]
        bm = a.shape[0]
        bd = b_ref.shape[0]

        rows = di * bd + jax.lax.broadcasted_iota(jnp.int32, (bd, bm), 0)
        cols = mi * bm + jax.lax.broadcasted_iota(jnp.int32, (bd, bm), 1)
        b0, b1 = threefry2x32(
            k0_ref[0, 0], k1_ref[0, 0],
            rows.astype(jnp.uint32), cols.astype(jnp.uint32),
        )
        s_blk = bits_to_gaussian(b0, b1, jnp.float32) * scale_ref[0, 0]
        s_blk = jnp.where(rows < d, s_blk, 0.0)

        b_ref[...] += jnp.dot(
            s_blk.astype(a.dtype), a, preferred_element_type=b_ref.dtype
        )
        _accumulate_gram(b_ref, g_ref, di, mi, m_blocks)

    return gaussian_gram_kernel
