"""Tall-skinny QR: tree-Householder panels, CholeskyQR finisher, fused Gram.

Two TSQR modes, both returning B = QR with Q (s, n) orthonormal and R
(n, n) upper triangular with a non-negative diagonal (the deterministic
sign convention — ``jnp.linalg.qr`` is free to flip row signs, this
factorization is not):

- ``mode="tree"`` — blocked Householder panels + a binary-tree R-merge:
  each row panel is QR'd independently (vmapped Householder, stable at
  any κ), then the per-panel R factors merge pairwise up a binary tree.
  Only the R factors ever travel between levels; Q is recovered at the
  end as B·R⁻¹ plus one CholeskyQR correction round (κ(B·R⁻¹) ≈ 1, so
  the correction Cholesky is unconditionally safe).
- ``mode="cholqr"`` — shifted CholeskyQR3 (Fukaya et al. 2020): one Gram
  G = BᵀB (the Pallas ``panel_gram`` kernel, or the fused sketch→Gram
  kernels that never re-read B from HBM), a shifted Cholesky for R₁, and
  two correction rounds.  All GEMM-rate math — this is the fast path the
  fused ``sketch_qr`` pipeline uses; the shift keeps the first Cholesky
  positive definite up to κ(B) ≈ 1/√(c·ε) and the correction rounds
  restore full orthogonality (validated at κ = 1e10 in the tests).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.scipy.linalg import solve_triangular

from ..common import cdiv, pad_to
from .kernel import panel_gram_kernel

__all__ = ["panel_gram", "cholqr_finish", "tsqr"]

# The fused kernels keep one (block_d, n_pad) B panel plus the
# (n_pad, n_pad) Gram resident in VMEM; beyond this column count the
# working set outgrows the budget and ``sketch_qr`` falls back to the
# unfused apply + panel_gram path.
MAX_FUSED_COLS = 512


@partial(jax.jit, static_argnames=("block_rows", "interpret"))
def panel_gram(
    B: jax.Array,
    *,
    block_rows: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    """G = BᵀB accumulated over (block_rows, n) panels in VMEM.

    One read of B, no n×s intermediate.  ``interpret=None`` resolves via
    ``repro.core.backend.default_interpret``.
    """
    if interpret is None:
        from ...core.backend import default_interpret

        interpret = default_interpret()
    s, n = B.shape
    acc = jnp.float32 if B.dtype in (jnp.bfloat16, jnp.float16) else B.dtype

    br = min(block_rows, max(8, s))
    bn = max(128, n) if n < 128 else n
    B_p = pad_to(B, (br, bn))
    s_p, n_p = B_p.shape

    G = pl.pallas_call(
        panel_gram_kernel,
        grid=(s_p // br,),
        in_specs=[pl.BlockSpec((br, n_p), lambda pi: (pi, 0))],
        out_specs=pl.BlockSpec((n_p, n_p), lambda pi: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((n_p, n_p), acc),
        interpret=interpret,
    )(B_p)
    return G[:n, :n]


def _positive_diag(Q, R):
    """Flip row signs of R (and matching column signs of Q) so diag(R) ≥ 0."""
    sgn = jnp.where(jnp.diag(R) < 0, -1.0, 1.0).astype(R.dtype)
    return Q * sgn[None, :], R * sgn[:, None]


@partial(jax.jit, static_argnames=("rounds",))
def cholqr_finish(
    B: jax.Array, G: jax.Array, *, rounds: int = 2
) -> tuple[jax.Array, jax.Array]:
    """Shifted CholeskyQR with ``rounds`` correction passes: B = QR from a
    precomputed Gram G = BᵀB.

    The shift σ = 11(sn + n(n+1))·ε·tr(G)/n (Fukaya et al.'s bound with
    the trace as the ‖G‖₂ proxy) guarantees the first Cholesky succeeds
    even when κ(G) overflows 1/ε; each correction round re-orthogonalizes
    Q ← Q·chol(QᵀQ)⁻¹ and absorbs the factor into R, so ``rounds=2``
    (CholeskyQR3 overall) delivers Householder-grade Q and R up to
    κ(B) ≈ 1e10 in f64.  All cost is Gram GEMMs + n×n triangular solves —
    BLAS3-rate, the reason the fused path beats Householder QR.
    """
    s, n = B.shape
    dtype = B.dtype
    eps = jnp.finfo(dtype).eps
    shift = 11.0 * (s * n + n * (n + 1)) * eps * jnp.trace(G) / n
    R = jnp.linalg.cholesky(G + shift * jnp.eye(n, dtype=dtype)).T
    Q = solve_triangular(R, B.T, trans=1, lower=False).T
    for _ in range(rounds):
        G2 = Q.T @ Q
        R2 = jnp.linalg.cholesky(G2).T
        Q = solve_triangular(R2, Q.T, trans=1, lower=False).T
        R = R2 @ R
    return _positive_diag(Q, R)


def _tree_r(B_p: jax.Array, block_rows: int) -> jax.Array:
    """R of B via per-panel Householder QR + binary-tree pairwise merges."""
    s_p, n = B_p.shape
    panels = B_p.reshape(s_p // block_rows, block_rows, n)
    _, Rs = jax.vmap(partial(jnp.linalg.qr, mode="reduced"))(panels)
    while Rs.shape[0] > 1:
        p = Rs.shape[0]
        if p % 2:  # odd level: carry the last R up unmerged
            odd, Rs = Rs[-1:], Rs[:-1]
        else:
            odd = None
        pairs = Rs.reshape(p // 2, 2 * Rs.shape[1], n)
        _, Rs = jax.vmap(partial(jnp.linalg.qr, mode="reduced"))(pairs)
        if odd is not None:
            pad = jnp.zeros(
                (1, Rs.shape[1] - odd.shape[1], n), Rs.dtype
            )
            Rs = jnp.concatenate([Rs, jnp.concatenate([odd, pad], axis=1)])
    return Rs[0][:n]


@partial(jax.jit, static_argnames=("mode", "block_rows", "interpret"))
def tsqr(
    B: jax.Array,
    *,
    mode: str = "tree",
    block_rows: int = 512,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Tall-skinny QR of B (s ≥ n): returns (Q, R), diag(R) ≥ 0.

    ``mode="tree"`` is the stability-first default (Householder panels,
    exact at any κ); ``mode="cholqr"`` routes through the Pallas
    ``panel_gram`` kernel + shifted CholeskyQR3 (GEMM-rate, stable to
    κ ≈ 1e10 in f64 — the same finisher the fused ``sketch_qr`` uses).
    """
    s, n = B.shape
    if s < n:
        raise ValueError(f"tsqr needs a tall matrix, got shape {(s, n)}")
    if mode == "cholqr":
        G = panel_gram(B, block_rows=block_rows, interpret=interpret)
        # half-precision B factors in the f32 accumulation dtype of the Gram
        return cholqr_finish(B.astype(G.dtype), G)
    if mode != "tree":
        raise ValueError(f"unknown tsqr mode {mode!r}; have ('tree', 'cholqr')")

    br = max(min(block_rows, s), n)
    B_p = pad_to(B, (br, 1))
    R = _tree_r(B_p, br)
    _, R = _positive_diag(jnp.empty((0, n), B.dtype), R)
    # Q = B·R⁻¹ (orthogonal to O(κ(B)·ε)) + ONE CholeskyQR correction:
    # κ(B·R⁻¹) ≈ 1, so the correction Cholesky is unconditionally safe and
    # restores ‖QᵀQ − I‖ ≈ ε while keeping QR = B to rounding.
    Q = solve_triangular(R, B.T, trans=1, lower=False).T
    R2 = jnp.linalg.cholesky(Q.T @ Q).T
    Q = solve_triangular(R2, Q.T, trans=1, lower=False).T
    Q, R = _positive_diag(Q, R2 @ R)
    return Q, R
