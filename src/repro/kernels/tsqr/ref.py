"""jnp oracles for the TSQR package — same contracts, no Pallas."""
from __future__ import annotations

import jax.numpy as jnp


def panel_gram_ref(B: jnp.ndarray) -> jnp.ndarray:
    acc = jnp.float32 if B.dtype in (jnp.bfloat16, jnp.float16) else B.dtype
    Bf = B.astype(acc)
    return Bf.T @ Bf


def tsqr_ref(B: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Householder QR with the package's diag(R) ≥ 0 sign convention."""
    Q, R = jnp.linalg.qr(B, mode="reduced")
    sgn = jnp.where(jnp.diag(R) < 0, -1.0, 1.0).astype(R.dtype)
    return Q * sgn[None, :], R * sgn[:, None]
