"""Pure-jnp oracles for the dense-sketch kernels."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..common import bits_to_gaussian, key_to_u32, threefry2x32

__all__ = ["sketch_matmul_ref", "gaussian_matrix_ref", "fused_gaussian_ref"]


def sketch_matmul_ref(S: jax.Array, A: jax.Array) -> jax.Array:
    return S @ A


def gaussian_matrix_ref(key: jax.Array, d: int, m: int, dtype=jnp.float32):
    """The exact S the fused kernel generates (same counters, same bits)."""
    k0, k1 = key_to_u32(key)
    rows = jnp.broadcast_to(jnp.arange(d, dtype=jnp.uint32)[:, None], (d, m))
    cols = jnp.broadcast_to(jnp.arange(m, dtype=jnp.uint32)[None, :], (d, m))
    b0, b1 = threefry2x32(k0, k1, rows, cols)
    return bits_to_gaussian(b0, b1, jnp.float32).astype(dtype)


def fused_gaussian_ref(A: jax.Array, key: jax.Array, d: int, scale=None):
    vec = A.ndim == 1
    A2 = A[:, None] if vec else A
    m = A2.shape[0]
    if scale is None:
        scale = 1.0 / float(d) ** 0.5
    S = gaussian_matrix_ref(key, d, m, A2.dtype) * jnp.asarray(scale, A2.dtype)
    out = S @ A2
    return out[:, 0] if vec else out
