"""Pure-jnp oracles for the dense-sketch kernels."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..common import bits_to_gaussian, key_to_u32, threefry2x32

__all__ = [
    "sketch_matmul_ref",
    "gaussian_matrix_ref",
    "gaussian_cols_ref",
    "fused_gaussian_ref",
]


def sketch_matmul_ref(S: jax.Array, A: jax.Array) -> jax.Array:
    return S @ A


def gaussian_matrix_ref(
    key: jax.Array, d: int, m: int, dtype=jnp.float32, *, col_offset=0
):
    """The exact S the fused kernel generates (same counters, same bits).

    ``col_offset`` shifts the column counters: element (i, j) of the result
    is generated from counter pair (i, col_offset + j), so
    ``gaussian_matrix_ref(key, d, t, col_offset=o)`` is bitwise identical to
    ``gaussian_matrix_ref(key, d, m)[:, o:o+t]`` — the streaming sketch
    engine regenerates per-tile column blocks of S from ``key`` alone
    without ever materializing the full (d, m) matrix.
    """
    return gaussian_cols_ref(
        key, d, col_offset + jnp.arange(m, dtype=jnp.uint32), dtype
    )


def gaussian_cols_ref(key: jax.Array, d: int, cols: jax.Array, dtype=jnp.float32):
    """Arbitrary column subset S[:, cols] of the fused kernel's matrix.

    Counter-based generation makes column gather free: the (d, len(cols))
    block is drawn directly from the (row, cols[j]) counters, bit-identical
    to slicing the fully materialized S.
    """
    cols = jnp.asarray(cols, jnp.uint32)
    (t,) = cols.shape
    k0, k1 = key_to_u32(key)
    rows = jnp.broadcast_to(jnp.arange(d, dtype=jnp.uint32)[:, None], (d, t))
    cols = jnp.broadcast_to(cols[None, :], (d, t))
    b0, b1 = threefry2x32(k0, k1, rows, cols)
    return bits_to_gaussian(b0, b1, jnp.float32).astype(dtype)


def fused_gaussian_ref(A: jax.Array, key: jax.Array, d: int, scale=None):
    vec = A.ndim == 1
    A2 = A[:, None] if vec else A
    m = A2.shape[0]
    if scale is None:
        scale = 1.0 / float(d) ** 0.5
    S = gaussian_matrix_ref(key, d, m, A2.dtype) * jnp.asarray(scale, A2.dtype)
    out = S @ A2
    return out[:, 0] if vec else out
