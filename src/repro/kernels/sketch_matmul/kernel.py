"""Dense-sketch apply kernels.

Two variants:

1. ``matmul_kernel`` — classic VMEM-tiled S·A with MXU-aligned blocks and
   in-place accumulation over the innermost (contraction) grid dimension.
   This is the paper-faithful dense Gaussian/uniform apply: S is read from
   HBM, so HBM traffic is O(d·m + m·n + d·n) — dominated by the d·m sketch
   matrix itself in the overdetermined regime m ≫ n ≈ d.

2. ``fused_gaussian_kernel`` — beyond-paper optimization: S is never
   materialized.  Each (bd, bm) tile of S is *generated inside the kernel*
   from a counter-based threefry2x32 PRNG (uint32 add/xor/rotate only —
   bit-identical to the jnp oracle in ref.py) + Box–Muller, then immediately
   consumed by the MXU.  HBM traffic drops to O(m·n + d·n): the memory-
   roofline term of the dense sketch collapses by a factor ≈ d·m/(m·n) = d/n.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..common import bits_to_gaussian, threefry2x32


def matmul_kernel(s_ref, a_ref, o_ref):
    """Grid (d_blocks, n_blocks, m_blocks); m innermost accumulates."""
    mi = pl.program_id(2)

    @pl.when(mi == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        s_ref[...], a_ref[...], preferred_element_type=o_ref.dtype
    )


def fused_gaussian_kernel(k0_ref, k1_ref, scale_ref, a_ref, o_ref):
    """Generate the S tile on the fly (threefry2x32 + Box–Muller), then MAC.

    Counter scheme: element (i, j) of S uses the uint32 pair (i, j) — unique
    per element and independent of the block decomposition, so any tiling
    produces bitwise-identical S.
    """
    di = pl.program_id(0)
    mi = pl.program_id(2)

    @pl.when(mi == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = a_ref[...]
    bm = a.shape[0]
    bd = o_ref.shape[0]

    rows = (di * bd + jax.lax.broadcasted_iota(jnp.int32, (bd, bm), 0)).astype(
        jnp.uint32
    )
    cols = (mi * bm + jax.lax.broadcasted_iota(jnp.int32, (bd, bm), 1)).astype(
        jnp.uint32
    )
    b0, b1 = threefry2x32(k0_ref[0, 0], k1_ref[0, 0], rows, cols)
    s_blk = bits_to_gaussian(b0, b1, jnp.float32) * scale_ref[0, 0]

    o_ref[...] += jnp.dot(
        s_blk.astype(a.dtype), a, preferred_element_type=o_ref.dtype
    )
