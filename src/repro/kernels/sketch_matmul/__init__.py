from .ops import fused_gaussian_sketch, sketch_matmul
from .ref import (
    fused_gaussian_ref,
    gaussian_cols_ref,
    gaussian_matrix_ref,
    sketch_matmul_ref,
)

__all__ = [
    "fused_gaussian_sketch",
    "sketch_matmul",
    "fused_gaussian_ref",
    "gaussian_cols_ref",
    "gaussian_matrix_ref",
    "sketch_matmul_ref",
]
