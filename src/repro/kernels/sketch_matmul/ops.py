"""jit'd wrappers for the dense-sketch kernels."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..common import cdiv, key_to_u32, pad_to
from .kernel import fused_gaussian_kernel, matmul_kernel

__all__ = ["sketch_matmul", "fused_gaussian_sketch"]


@partial(
    jax.jit, static_argnames=("block_d", "block_m", "block_n", "interpret")
)
def sketch_matmul(
    S: jax.Array,
    A: jax.Array,
    *,
    block_d: int = 256,
    block_m: int = 512,
    block_n: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """S (d, m) @ A (m, n) with VMEM-tiled accumulation.

    ``interpret=None`` resolves via ``repro.core.backend.default_interpret``.
    """
    if interpret is None:
        from ...core.backend import default_interpret

        interpret = default_interpret()
    vec = A.ndim == 1
    A2 = A[:, None] if vec else A
    d, m = S.shape
    n = A2.shape[1]
    acc = jnp.float32 if A2.dtype in (jnp.bfloat16, jnp.float16) else A2.dtype

    bd = min(block_d, max(8, d))
    bm = min(block_m, max(8, m))
    bn = min(block_n, max(128, n)) if n >= 128 else 128

    S_p = pad_to(S, (bd, bm))
    A_p = pad_to(A2, (bm, bn))
    d_p, m_p = S_p.shape
    n_p = A_p.shape[1]

    out = pl.pallas_call(
        matmul_kernel,
        grid=(d_p // bd, n_p // bn, m_p // bm),
        in_specs=[
            pl.BlockSpec((bd, bm), lambda di, ni, mi: (di, mi)),
            pl.BlockSpec((bm, bn), lambda di, ni, mi: (mi, ni)),
        ],
        out_specs=pl.BlockSpec((bd, bn), lambda di, ni, mi: (di, ni)),
        out_shape=jax.ShapeDtypeStruct((d_p, n_p), acc),
        interpret=interpret,
    )(S_p, A_p)
    # half-precision inputs keep the f32 accumulator dtype (mixed-precision
    # contract: bf16 data, >= f32 sketch output for the QR/refinement stages)
    out = out[:d, :n]
    return out[:, 0] if vec else out


@partial(
    jax.jit,
    static_argnames=("d", "block_d", "block_m", "block_n", "interpret"),
)
def fused_gaussian_sketch(
    A: jax.Array,
    key: jax.Array,
    d: int,
    *,
    scale: float | None = None,
    block_d: int = 256,
    block_m: int = 512,
    block_n: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """(1/√d)·G·A with G ~ N(0,1)^{d×m} generated inside the kernel.

    G is never materialized in HBM.  Bitwise-reproducible from ``key`` (see
    ref.py for the matching oracle — ``repro.core.sketch.GaussianSketch``
    draws its S from the same stream, so this kernel IS its pallas backend).
    ``interpret=None`` resolves via ``repro.core.backend.default_interpret``.
    """
    if interpret is None:
        from ...core.backend import default_interpret

        interpret = default_interpret()
    vec = A.ndim == 1
    A2 = A[:, None] if vec else A
    m, n = A2.shape
    if scale is None:
        scale = 1.0 / float(d) ** 0.5
    acc = jnp.float32 if A2.dtype in (jnp.bfloat16, jnp.float16) else A2.dtype

    bd = min(block_d, max(8, d))
    bm = min(block_m, max(8, m))
    bn = min(block_n, max(128, n)) if n >= 128 else 128

    # NOTE: rows beyond m would multiply garbage Gaussians into padded-zero
    # rows of A — padding A with zeros makes those contributions vanish.
    A_p = pad_to(A2, (bm, bn))
    m_p, n_p = A_p.shape
    d_p = cdiv(d, bd) * bd

    k0, k1 = key_to_u32(key)
    k0 = k0.reshape(1, 1)
    k1 = k1.reshape(1, 1)
    scale_arr = jnp.asarray(scale, jnp.float32).reshape(1, 1)

    out = pl.pallas_call(
        fused_gaussian_kernel,
        grid=(d_p // bd, n_p // bn, m_p // bm),
        in_specs=[
            pl.BlockSpec((1, 1), lambda di, ni, mi: (0, 0)),
            pl.BlockSpec((1, 1), lambda di, ni, mi: (0, 0)),
            pl.BlockSpec((1, 1), lambda di, ni, mi: (0, 0)),
            pl.BlockSpec((bm, bn), lambda di, ni, mi: (mi, ni)),
        ],
        out_specs=pl.BlockSpec((bd, bn), lambda di, ni, mi: (di, ni)),
        out_shape=jax.ShapeDtypeStruct((d_p, n_p), acc),
        interpret=interpret,
    )(k0, k1, scale_arr, A_p)
    out = out[:d, :n]  # keep the f32 accumulator dtype for half inputs
    return out[:, 0] if vec else out
