"""Tile-size autotuner for the Pallas sketch kernels.

Picks (block_m, block_d, block_n) per kernel family by sweeping candidate
block shapes against the same roofline cost model ``benchmarks/roofline.py``
reports from: predicted time = max(HBM traffic / bandwidth, flops / peak)
plus a per-grid-step launch overhead.  The traffic term is the one that
actually differentiates block shapes — grids that revisit an input tile
across an outer axis (e.g. the dense sketch re-reads S once per n-block,
every kernel re-reads A once per d-block) pay for each revisit, so larger
blocks along the revisited axes trade VMEM footprint for HBM traffic.
Candidates that overflow the VMEM budget are discarded before costing.

Winners are cached in-repo at ``src/repro/kernels/autotune_cache.json``,
keyed ``"{kind}|m={m}|n={n}|d={d}|{dtype}|{device}"``.  ``best_blocks`` is
the runtime entry point — exact cache hits return the committed winner,
misses fall back to the cost model on the fly (memoized per process).  The
backend policy (``repro.core.backend.kernel_blocks``) consults it for every
kernel dispatch; set ``REPRO_AUTOTUNE=0`` to force the kernels' hand-tuned
defaults.

Regenerate the cache after kernel/geometry changes::

    PYTHONPATH=src python -m repro.kernels.autotune --write
"""
from __future__ import annotations

import argparse
import functools
import json
import logging
from pathlib import Path

import jax
import jax.numpy as jnp

__all__ = ["best_blocks", "predict_cost", "CACHE_PATH", "KINDS"]

CACHE_PATH = Path(__file__).with_name("autotune_cache.json")
CACHE_SCHEMA = 1

_log = logging.getLogger(__name__)
# One warning per unseen (kind, shape, dtype, device) key per process: a
# miss means every dispatch at this shape runs on modeled blocks, which is
# worth knowing once — not once per kernel launch.
_MISS_WARNED: set[str] = set()

# Kernel families the tuner knows, with the block kwargs each accepts.
KINDS = {
    "countsketch": ("block_m", "block_d", "block_n"),
    "sketch_matmul": ("block_d", "block_m", "block_n"),
    "gaussian": ("block_d", "block_m", "block_n"),
    "srht": ("block_n",),
    "tsqr": ("block_m", "block_d"),
}
_ALIASES = {"uniform_dense": "sketch_matmul", "clarkson_woodruff": "countsketch"}

# VMEM working-set budget per grid step.  v5e has ~16 MiB/core; half of it
# keeps double-buffered pipelines honest.
VMEM_BUDGET = 8 * 1024 * 1024
_STEP_OVERHEAD_S = 5e-7  # per-grid-step launch cost; penalizes tiny blocks

_BLOCK_M = (128, 256, 512, 1024, 2048)
_BLOCK_D = (128, 256, 512, 1024)
_BLOCK_N = (128, 256, 512)


def _hw():
    """Roofline constants — shared with benchmarks via repro.launch.mesh."""
    try:
        from ..launch.mesh import HW

        return HW
    except Exception:  # pragma: no cover - mesh module should always import
        return {"peak_flops_bf16": 197e12, "hbm_bw": 819e9}


def _dtype_bytes(dtype) -> int:
    return jnp.dtype(dtype).itemsize


def _cdiv(a: int, b: int) -> int:
    return -(-a // b)


def _peak_flops(dtype) -> float:
    peak = float(_hw().get("peak_flops_bf16", 197e12))
    # MXU fp32 runs at roughly half the bf16 rate; fp64 emulation far slower.
    itemsize = _dtype_bytes(dtype)
    if itemsize <= 2:
        return peak
    if itemsize == 4:
        return peak / 2
    return peak / 8


def predict_cost(kind: str, m: int, n: int, d: int, dtype, blocks: dict) -> float:
    """Roofline-predicted seconds for one kernel launch with these blocks.

    Returns ``inf`` for configs whose VMEM working set exceeds the budget,
    so infeasible candidates lose every comparison.
    """
    kind = _ALIASES.get(kind, kind)
    b = _dtype_bytes(dtype)
    acc_b = max(b, 4)  # half inputs accumulate in f32
    n_p = max(128, n)
    bm = blocks.get("block_m", m)
    bd = blocks.get("block_d", d)
    bn = blocks.get("block_n", n_p)
    m_blocks = _cdiv(m, bm)
    d_blocks = _cdiv(d, bd)
    n_blocks = _cdiv(n_p, bn)

    flops = 2.0 * m * n * d
    if kind == "countsketch":
        # one-hot matmul recast: dense-rate MACs, A re-read per d-block,
        # bucket/sign columns re-read per (d, n) block.
        traffic = m * n * b * d_blocks + m * (4 + b) * d_blocks * n_blocks
        traffic += d * n * b
        vmem = (bm * bn + bm * bd + bd * bn) * b + 2 * bm * 4
        steps = m_blocks * d_blocks * n_blocks
    elif kind == "sketch_matmul":
        traffic = d * m * b * n_blocks + m * n * b * d_blocks + d * n * b
        vmem = (bd * bm + bm * bn + bd * bn) * b
        steps = m_blocks * d_blocks * n_blocks
    elif kind == "gaussian":
        # S is generated in-kernel: no S traffic, but the threefry+Box-Muller
        # pipeline costs ~32 scalar ops per S element, re-done per n-block.
        traffic = m * n * b * d_blocks + d * n * b
        flops += 32.0 * d * m * n_blocks
        vmem = (bd * bm + bm * bn + bd * bn) * b
        steps = m_blocks * d_blocks * n_blocks
    elif kind == "srht":
        # two-stage FWHT over m_pad rows: log2(m) butterfly sweeps, each a
        # read+write of the full (m_pad, bn) working set per column block.
        m_pad = 1 << max(1, (m - 1).bit_length())
        sweeps = max(1, m_pad.bit_length() - 1)
        flops = 2.0 * m_pad * n * sweeps
        traffic = 4.0 * m_pad * n * b + d * n * b
        vmem = min(m_pad, 2048) * bn * b
        steps = n_blocks
    elif kind == "tsqr":
        # fused sketch→Gram: A re-read per d-block, B written once (never
        # re-read), Gram folded from VMEM-resident panels.
        traffic = m * n * b * d_blocks + d * m * b + d * n * acc_b
        flops += 2.0 * d * n * n
        vmem = bd * bm * b + bm * n_p * b + (bd * n_p + n_p * n_p) * acc_b
        steps = m_blocks * d_blocks
    else:
        raise ValueError(f"unknown autotune kind {kind!r}; have {sorted(KINDS)}")

    if vmem > VMEM_BUDGET:
        return float("inf")
    hbm_bw = float(_hw().get("hbm_bw", 819e9))
    return max(traffic / hbm_bw, flops / _peak_flops(dtype)) + steps * _STEP_OVERHEAD_S


def _candidates(kind: str, m: int, n: int, d: int):
    kind = _ALIASES.get(kind, kind)
    n_p = max(128, n)
    bms = sorted({min(v, max(8, m)) for v in _BLOCK_M})
    bds = sorted({min(v, max(8, d)) for v in _BLOCK_D})
    bns = sorted({min(v, n_p) for v in _BLOCK_N})
    if kind == "srht":
        for bn in bns:
            yield {"block_n": bn}
    elif kind == "tsqr":
        for bm in bms:
            for bd in bds:
                yield {"block_m": bm, "block_d": bd}
    else:
        for bm in bms:
            for bd in bds:
                for bn in bns:
                    yield {"block_m": bm, "block_d": bd, "block_n": bn}


def _device_kind() -> str:
    try:
        return jax.devices()[0].device_kind.replace(" ", "_")
    except Exception:  # pragma: no cover - no runtime attached
        return "unknown"


def _key(kind: str, m: int, n: int, d: int, dtype, device: str) -> str:
    return f"{kind}|m={m}|n={n}|d={d}|{jnp.dtype(dtype).name}|{device}"


@functools.lru_cache(maxsize=1)
def _load_cache() -> dict:
    try:
        data = json.loads(CACHE_PATH.read_text())
        if data.get("schema") == CACHE_SCHEMA:
            return data.get("entries", {})
    except (OSError, ValueError):
        pass
    return {}


@functools.lru_cache(maxsize=4096)
def _model_best(kind: str, m: int, n: int, d: int, dtype_name: str) -> tuple:
    best, best_cost = None, float("inf")
    for cand in _candidates(kind, m, n, d):
        c = predict_cost(kind, m, n, d, dtype_name, cand)
        if c < best_cost:
            best, best_cost = cand, c
    # every family has at least one VMEM-feasible candidate at these sizes,
    # but fall back to kernel defaults ({}), never crash, if the model says no
    return tuple(sorted((best or {}).items()))


def best_blocks(
    kind: str, m: int, n: int, d: int, dtype, device: str | None = None
) -> dict:
    """Winning block kwargs for this (kind, shape, dtype, device).

    Committed-cache hit first, cost model on miss.  The returned dict uses
    the kernel wrapper's own kwarg names and can be splatted directly:
    ``countsketch_apply(A, h, s, d, **best_blocks("countsketch", ...))``.
    """
    kind = _ALIASES.get(kind, kind)
    if kind not in KINDS:
        raise ValueError(f"unknown autotune kind {kind!r}; have {sorted(KINDS)}")
    if device is None:
        device = _device_kind()
    key = _key(kind, m, n, d, dtype, device)
    hit = _load_cache().get(key)
    if hit is not None:
        return {k: v for k, v in hit.items() if k in KINDS[kind]}
    blocks = dict(_model_best(kind, m, n, d, jnp.dtype(dtype).name))
    if key not in _MISS_WARNED:
        _MISS_WARNED.add(key)
        _log.warning(
            "autotune cache miss for %s: no committed winner, falling back "
            "to roofline-model blocks %s (run `python -m repro.kernels."
            "autotune` on this device to sweep and pin real winners)",
            key, blocks or "{} (kernel defaults)",
        )
    return blocks


# ---------------------------------------------------------------------------
# cache generation


def _sweep_shapes():
    for n in (64, 128, 256, 512):
        for m in (4096, 16384, 65536):
            d = min(4 * n, m // 2)
            yield m, n, d


def write_cache(device: str | None = None, path: Path | None = None) -> dict:
    """Sweep canonical paper shapes and write the winners JSON."""
    if device is None:
        device = _device_kind()
    entries = {}
    for kind in KINDS:
        for m, n, d in _sweep_shapes():
            for dtype in ("float32", "bfloat16"):
                entries[_key(kind, m, n, d, dtype, device)] = dict(
                    _model_best(kind, m, n, d, dtype)
                )
    payload = {"schema": CACHE_SCHEMA, "entries": entries}
    out = path or CACHE_PATH
    out.write_text(json.dumps(payload, indent=1, sort_keys=True) + "\n")
    _load_cache.cache_clear()
    return entries


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--write", action="store_true", help="regenerate the cache")
    ap.add_argument("--device", default=None, help="override device kind key")
    args = ap.parse_args(argv)
    if args.write:
        entries = write_cache(device=args.device)
        print(f"wrote {len(entries)} entries to {CACHE_PATH}")
        return 0
    device = args.device or _device_kind()
    for m, n, d in _sweep_shapes():
        for kind in KINDS:
            blocks = best_blocks(kind, m, n, d, "float32", device=device)
            print(f"{kind:14s} m={m:6d} n={n:3d} d={d:4d} -> {blocks}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
