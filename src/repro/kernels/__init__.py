"""TPU Pallas kernels for the sketch applies (the paper's compute hot path).

Each subpackage has ``kernel.py`` (pl.pallas_call body + BlockSpec tiling),
``ops.py`` (jit'd public wrapper) and ``ref.py`` (pure-jnp oracle).  The
BlockSpecs target TPU v5e VMEM/MXU geometry (128-lane tiles, ≤2 MiB working
sets).

These kernels are the ``"pallas"`` backend of the sketching operators in
``repro.core.sketch``: ``op.apply(A, backend="pallas")`` routes CountSketch
→ ``countsketch_apply``, SRHT → ``srht_apply``, Gaussian →
``fused_gaussian_sketch`` (regenerating the operator's S in-kernel from its
key) and uniform-dense → ``sketch_matmul``; the solvers (``saa_sas``,
``sap_sas``, ``sketched_lstsq``) expose the same knob as a static
``backend=`` argument.  The per-platform default — and the ``interpret=None``
resolution of every wrapper here (real Mosaic on TPU, ``interpret=True``
elsewhere, so CPU containers still execute the exact kernel semantics) —
lives in one policy module, ``repro.core.backend``.
"""
from .countsketch import countsketch_apply, countsketch_ref
from .sketch_matmul import (
    fused_gaussian_ref,
    fused_gaussian_sketch,
    gaussian_cols_ref,
    gaussian_matrix_ref,
    sketch_matmul,
    sketch_matmul_ref,
)
from .srht import hadamard_matrix, hadamard_transform, srht_apply, srht_ref
from .tsqr import (
    cholqr_finish,
    panel_gram,
    panel_gram_ref,
    sketch_qr,
    tsqr,
    tsqr_ref,
)

__all__ = [
    "cholqr_finish",
    "panel_gram",
    "panel_gram_ref",
    "sketch_qr",
    "tsqr",
    "tsqr_ref",
    "countsketch_apply",
    "countsketch_ref",
    "fused_gaussian_ref",
    "fused_gaussian_sketch",
    "gaussian_cols_ref",
    "gaussian_matrix_ref",
    "sketch_matmul",
    "sketch_matmul_ref",
    "hadamard_matrix",
    "hadamard_transform",
    "srht_apply",
    "srht_ref",
]
