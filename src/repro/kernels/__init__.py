"""TPU Pallas kernels for the sketch applies (the paper's compute hot path).

Each subpackage has ``kernel.py`` (pl.pallas_call body + BlockSpec tiling),
``ops.py`` (jit'd public wrapper) and ``ref.py`` (pure-jnp oracle).  On this
CPU container kernels are validated with ``interpret=True``; the BlockSpecs
target TPU v5e VMEM/MXU geometry (128-lane tiles, ≤2 MiB working sets).
"""
from .countsketch import countsketch_apply, countsketch_ref
from .sketch_matmul import (
    fused_gaussian_ref,
    fused_gaussian_sketch,
    gaussian_matrix_ref,
    sketch_matmul,
    sketch_matmul_ref,
)
from .srht import hadamard_matrix, hadamard_transform, srht_apply, srht_ref

__all__ = [
    "countsketch_apply",
    "countsketch_ref",
    "fused_gaussian_ref",
    "fused_gaussian_sketch",
    "gaussian_matrix_ref",
    "sketch_matmul",
    "sketch_matmul_ref",
    "hadamard_matrix",
    "hadamard_transform",
    "srht_apply",
    "srht_ref",
]
