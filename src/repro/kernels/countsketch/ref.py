"""Pure-jnp oracle for the CountSketch kernel (exact segment-sum)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["countsketch_ref"]


def countsketch_ref(A: jax.Array, buckets: jax.Array, signs: jax.Array, d: int):
    vec = A.ndim == 1
    A2 = A[:, None] if vec else A
    out = jax.ops.segment_sum(
        signs[:, None].astype(A2.dtype) * A2, buckets, num_segments=d
    )
    return out[:, 0] if vec else out
