"""jit'd wrapper for the CountSketch Pallas kernel."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..common import cdiv, pad_to
from .kernel import countsketch_kernel

__all__ = ["countsketch_apply"]


@partial(
    jax.jit,
    static_argnames=("d", "block_m", "block_d", "block_n", "interpret"),
)
def countsketch_apply(
    A: jax.Array,
    buckets: jax.Array,
    signs: jax.Array,
    d: int,
    *,
    block_m: int = 256,
    block_d: int = 256,
    block_n: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """SA for the CountSketch (buckets, signs); A is (m, n) or (m,).

    Returns (d, n) in f32 accumulation dtype, cast back to A.dtype.
    ``interpret=None`` resolves via ``repro.core.backend.default_interpret``
    (real Mosaic on TPU, interpret mode elsewhere).
    """
    if interpret is None:
        from ...core.backend import default_interpret

        interpret = default_interpret()
    vec = A.ndim == 1
    if vec:
        A = A[:, None]
    m, n = A.shape
    acc_dtype = jnp.float32 if A.dtype in (jnp.bfloat16, jnp.float16) else A.dtype

    bm = min(block_m, max(8, m))
    bd = min(block_d, max(8, d))
    bn = min(block_n, max(128, n)) if n >= 128 else 128

    A_p = pad_to(A, (bm, bn))
    # Padded rows get sign 0 -> contribute nothing (bucket 0 is fine).
    h_p = pad_to(buckets.astype(jnp.int32)[:, None], (bm, 1))
    s_p = pad_to(signs.astype(A.dtype)[:, None], (bm, 1))
    m_p, n_p = A_p.shape
    d_p = cdiv(d, bd) * bd

    grid = (n_p // bn, d_p // bd, m_p // bm)
    out = pl.pallas_call(
        countsketch_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, 1), lambda ni, di, mi: (mi, 0)),
            pl.BlockSpec((bm, 1), lambda ni, di, mi: (mi, 0)),
            pl.BlockSpec((bm, bn), lambda ni, di, mi: (mi, ni)),
        ],
        out_specs=pl.BlockSpec((bd, bn), lambda ni, di, mi: (di, ni)),
        out_shape=jax.ShapeDtypeStruct((d_p, n_p), acc_dtype),
        interpret=interpret,
    )(h_p, s_p, A_p)
    # half-precision inputs keep the f32 accumulator dtype (mixed-precision
    # contract: bf16 data, >= f32 sketch output for the QR/refinement stages)
    out = out[:d, :n]
    return out[:, 0] if vec else out
