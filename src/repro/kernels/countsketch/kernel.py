"""CountSketch (Clarkson–Woodruff) apply as a TPU Pallas kernel.

GPU implementations scatter-add rows (`SA[h[i]] += s[i]·A[i]`) with atomics.
TPUs have neither fast VMEM scatter nor atomics, but they have an MXU that
eats 128-aligned tiles — so we recast the bucket scatter as a **blocked
one-hot matmul**:

    SA[d_blk, n_blk] += onehot(h[m_blk], d_blk)ᵀ · (s[m_blk] ⊙ A[m_blk, n_blk])

The one-hot tile is built in VMEM from an iota-compare (never touches HBM),
and the grid's innermost dimension runs over m-blocks so each (d,n) output
tile is accumulated in place across sequential grid steps (TPU grids are
sequential, which makes revisiting an output block a legal accumulation
pattern via ``pl.when(first_step)`` initialization).

HBM traffic: A read once (m·n), SA written once (d·n) — same as the scatter
formulation.  Extra MXU flops (m·d·n vs m·n scattered adds) are free in the
paper's regime d ≈ 4n ≪ m where the apply is memory-bound.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def countsketch_kernel(buckets_ref, signs_ref, a_ref, out_ref):
    """Grid: (n_blocks, d_blocks, m_blocks) — m innermost (accumulation)."""
    di = pl.program_id(1)
    mi = pl.program_id(2)
    bd = out_ref.shape[0]

    @pl.when(mi == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    h = buckets_ref[...]  # (bm, 1) int32, global bucket ids
    s = signs_ref[...]  # (bm, 1)
    a = a_ref[...]  # (bm, bn)
    bm = a.shape[0]

    # One-hot of this m-block's buckets against this d-block's bucket range.
    local = h - di * bd  # (bm, 1)
    cols = jax.lax.broadcasted_iota(jnp.int32, (bm, bd), 1)
    onehot = (cols == local).astype(a.dtype)  # (bm, bd)

    contrib = jax.lax.dot_general(
        onehot,
        s * a,
        dimension_numbers=(((0,), (0,)), ((), ())),  # onehotᵀ · (s⊙a)
        preferred_element_type=out_ref.dtype,
    )
    out_ref[...] += contrib
