from .ops import countsketch_apply
from .ref import countsketch_ref

__all__ = ["countsketch_apply", "countsketch_ref"]
