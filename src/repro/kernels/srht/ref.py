"""Pure-jnp oracle for the SRHT kernels (recursive FWHT from repro.core)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ...core.sketch import fwht

__all__ = ["hadamard_ref", "srht_ref"]


def hadamard_ref(x: jax.Array) -> jax.Array:
    return fwht(x, axis=0)


def srht_ref(A: jax.Array, signs: jax.Array, rows: jax.Array, d: int) -> jax.Array:
    vec = A.ndim == 1
    A2 = A[:, None] if vec else A
    m = A2.shape[0]
    m_pad = signs.shape[0]
    if m_pad != m:
        A2 = jnp.pad(A2, ((0, m_pad - m), (0, 0)))
    out = fwht(signs[:, None].astype(A2.dtype) * A2)[rows] / jnp.sqrt(
        jnp.asarray(d, A2.dtype)
    )
    return out[:, 0] if vec else out
