"""jit'd wrappers: blocked Hadamard transform + full SRHT apply."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..common import cdiv, pad_to
from .kernel import block_hadamard_kernel, cross_hadamard_kernel

__all__ = ["hadamard_transform", "srht_apply", "hadamard_matrix"]


def hadamard_matrix(k: int, dtype=jnp.float32) -> jax.Array:
    """Sylvester Hadamard H_k (k a power of two) via parity of popcount(i&j)."""
    i = jnp.arange(k, dtype=jnp.uint32)
    par = jnp.bitwise_count(i[:, None] & i[None, :]) & 1
    return (1 - 2 * par.astype(jnp.int32)).astype(dtype)


def _split_pow2(m: int) -> tuple[int, int]:
    """m = r * c, both powers of two, c as large as possible ≤ 1024."""
    p = m.bit_length() - 1
    c_bits = min(p, 10)
    return m >> c_bits, 1 << c_bits  # (r, c)


@partial(jax.jit, static_argnames=("block_n", "interpret"))
def hadamard_transform(
    x: jax.Array, *, block_n: int = 256, interpret: bool | None = None
) -> jax.Array:
    """Unnormalized Walsh–Hadamard transform along axis 0 (m a power of 2).

    ``interpret=None`` resolves via ``repro.core.backend.default_interpret``.
    """
    if interpret is None:
        from ...core.backend import default_interpret

        interpret = default_interpret()
    vec = x.ndim == 1
    if vec:
        x = x[:, None]
    m, n = x.shape
    if m & (m - 1):
        raise ValueError(f"m must be a power of two, got {m}")
    dtype = x.dtype
    r, c = _split_pow2(m)

    bn = min(block_n, max(128, n)) if n >= 128 else 128
    x_p = pad_to(x, (1, bn))
    n_p = x_p.shape[1]
    nb = n_p // bn

    # ---- stage 1: (I_r ⊗ H_c) ----
    h_c = hadamard_matrix(c, dtype)
    y = pl.pallas_call(
        block_hadamard_kernel,
        grid=(r, nb),
        in_specs=[
            pl.BlockSpec((c, c), lambda k, ni: (0, 0)),
            pl.BlockSpec((1, c, bn), lambda k, ni: (k, 0, ni)),
        ],
        out_specs=pl.BlockSpec((1, c, bn), lambda k, ni: (k, 0, ni)),
        out_shape=jax.ShapeDtypeStruct((r, c, n_p), dtype),
        interpret=interpret,
    )(h_c, x_p.reshape(r, c, n_p))

    if r == 1:
        out = y.reshape(m, n_p)
    else:
        # ---- stage 2: (H_r ⊗ I_c) ----
        h_r = hadamard_matrix(r, dtype)
        # Sublane block of the c axis, sized so the (r, bs, bn) VMEM tile
        # stays ≤ 2 MiB (H_r itself takes r²·4 bytes, up to 4 MiB at r=1024).
        bs = max(8, (2**21 // (r * bn * 4)) // 8 * 8)
        bs = min(bs, c)
        while c % bs:
            bs //= 2
        bs = max(bs, 1)
        z = pl.pallas_call(
            cross_hadamard_kernel,
            grid=(c // bs, nb),
            in_specs=[
                pl.BlockSpec((r, r), lambda si, ni: (0, 0)),
                pl.BlockSpec((r, bs, bn), lambda si, ni: (0, si, ni)),
            ],
            out_specs=pl.BlockSpec((r, bs, bn), lambda si, ni: (0, si, ni)),
            out_shape=jax.ShapeDtypeStruct((r, c, n_p), dtype),
            interpret=interpret,
        )(h_r, y)
        out = z.reshape(m, n_p)

    out = out[:, :n]
    return out[:, 0] if vec else out


@partial(jax.jit, static_argnames=("d", "block_n", "interpret"))
def srht_apply(
    A: jax.Array,
    signs: jax.Array,
    rows: jax.Array,
    d: int,
    *,
    block_n: int = 256,
    interpret: bool | None = None,
) -> jax.Array:
    """SRHT sketch S·A = (1/√d) · P · H · D · A.

    ``signs`` has length m_pad (power of two ≥ m); ``rows`` are d sampled
    row indices.  The Hadamard transform runs in the Pallas kernels; the
    D-scaling and P-gather stay in XLA (memory-bound, fusable).
    ``interpret=None`` resolves via ``repro.core.backend.default_interpret``.
    """
    if interpret is None:
        from ...core.backend import default_interpret

        interpret = default_interpret()
    vec = A.ndim == 1
    A2 = A[:, None] if vec else A
    m, n = A2.shape
    m_pad = signs.shape[0]
    if m_pad != m:
        A2 = jnp.pad(A2, ((0, m_pad - m), (0, 0)))
    HDx = hadamard_transform(
        signs[:, None].astype(A2.dtype) * A2, block_n=block_n, interpret=interpret
    )
    out = HDx[rows] / jnp.sqrt(jnp.asarray(d, A2.dtype))
    return out[:, 0] if vec else out
