"""Blocked Walsh–Hadamard transform as TPU Pallas kernels.

The classic FHT butterfly has stride-2^k access patterns — hostile to VMEM
tiling.  On TPU we instead use the Kronecker factorization

    H_{r·c} = (H_r ⊗ I_c) · (I_r ⊗ H_c)

(valid for Sylvester Hadamard matrices, H_{2^p} = H_2^{⊗p}), which turns the
transform into two dense ±1 **matmuls** over VMEM-resident tiles — exactly
what the MXU wants:

  stage 1 (`block_hadamard_kernel`):  y[k]  = H_c · x[k]       (within block)
  stage 2 (`cross_hadamard_kernel`):  z[k'] = Σ_k H_r[k',k] y[k] (across blocks)

Flop cost rises from O(m log m) adds to O(m·(r+c)) = O(m·√m) MACs, but both
stages stream each element exactly once from HBM, and for the SRHT's m up to
2^20 the MXU matmul path is faster than a strided butterfly emulation on TPU.
"""
from __future__ import annotations

import jax.numpy as jnp
from jax.experimental import pallas as pl


def block_hadamard_kernel(h_ref, x_ref, o_ref):
    """x block (1, c, bn);  h (c, c);  o = h @ x."""
    o_ref[0, ...] = jnp.dot(
        h_ref[...], x_ref[0, ...], preferred_element_type=o_ref.dtype
    )


def cross_hadamard_kernel(h_ref, x_ref, o_ref):
    """x block (r, bs, bn);  h (r, r);  o[k'] = Σ_k h[k',k] x[k]."""
    r, bs, bn = x_ref.shape
    flat = x_ref[...].reshape(r, bs * bn)
    out = jnp.dot(h_ref[...], flat, preferred_element_type=o_ref.dtype)
    o_ref[...] = out.reshape(r, bs, bn)
