from .ops import hadamard_matrix, hadamard_transform, srht_apply
from .ref import hadamard_ref, srht_ref

__all__ = [
    "hadamard_matrix",
    "hadamard_transform",
    "srht_apply",
    "hadamard_ref",
    "srht_ref",
]
