"""Elastic scaling + failure handling.

Node failure / preemption model (documented for 1000+-node deployments):

1. **Checkpoint/restart** is the base mechanism: `AsyncCheckpointer`
   writes atomically every `ckpt_every` steps; on restart the launcher
   calls `restore_elastic` with whatever mesh the *surviving* slice
   supports.  The data pipeline is stateless-indexable (`batch_at(step)`),
   so the stream resumes bit-identically — no data-order drift.

2. **Elastic re-mesh**: checkpoints store unsharded host arrays; restore
   `device_put`s them against shardings derived from the *new* mesh.  Any
   (data × model) factorization whose axis sizes divide the weight dims
   works — e.g. dropping from (2,16,16) to (16,16) after losing a pod, or
   halving the data axis.  Global batch is preserved by raising
   grad-accumulation microbatches (`rebalance_microbatch`).

3. **Straggler mitigation**: synchronous SPMD steps are gang-scheduled; a
   straggling host stalls the psum.  The practical levers we implement:
   (a) deterministic per-step data indexing lets any host be replaced
   without rewinding the stream; (b) checkpoint cadence bounds lost work;
   (c) the sketched-compression DP path shrinks all-reduce payloads by
   `ratio`, cutting the collective tail that stragglers amplify.

On real TPU fleets, slice failure detection + re-scheduling is the
platform's job (GKE/Borg); this module owns the state logistics.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding

from ..configs.base import ModelConfig
from ..sharding import tree_pspecs
from . import checkpoint as ckpt_lib
from .step import TrainState, state_pspecs, state_shapes

__all__ = ["restore_elastic", "rebalance_microbatch"]


def restore_elastic(
    ckpt_dir: str,
    cfg: ModelConfig,
    mesh: Mesh,
    step: int | None = None,
    rules=None,
):
    """Restore a TrainState checkpoint onto an arbitrary new mesh."""
    shapes = state_shapes(cfg)
    pspecs = state_pspecs(cfg, mesh, rules)
    shardings = jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        pspecs,
        is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec),
    )
    state, found = ckpt_lib.restore(ckpt_dir, shapes, step=step, shardings=shardings)
    return state, found


def rebalance_microbatch(global_batch: int, old_dp: int, new_dp: int, old_micro: int):
    """Keep the global batch fixed when the DP world size changes.

    per-device batch = global/(dp·micro); hold global fixed by scaling the
    microbatch count inversely with dp.
    """
    total_micro_tokens = global_batch // old_dp // old_micro
    new_micro = max(1, global_batch // new_dp // max(total_micro_tokens, 1))
    while global_batch % (new_dp * new_micro):
        new_micro += 1
    return new_micro
