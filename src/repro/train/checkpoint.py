"""Fault-tolerant checkpointing.

- Atomic: write to ``<dir>/tmp.<step>`` then ``os.replace`` into place; a
  crash mid-write never corrupts the latest checkpoint.
- Manifest: ``manifest.json`` records step, wall time and the tree paths,
  so restore can validate structure before touching device memory.
- Async: ``AsyncCheckpointer`` snapshots to host (blocking only on
  device->host copy) and writes in a worker thread — training resumes
  while bytes hit disk.
- Elastic restore: arrays are loaded on host and ``device_put`` against
  the *target* shardings — the restoring job may use a different mesh
  shape or device count than the writer (see repro.train.elastic).
- keep_n garbage collection, plus orphaned-``tmp.*`` cleanup: a writer
  that crashes mid-write leaves its ``tmp.<step>.<pid>`` staging dir
  behind; the next ``save()`` into the directory removes any staging dir
  whose writer pid is gone (in-flight tmps of live writers are kept).
"""
from __future__ import annotations

import json
import os
import queue
import re
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["save", "restore", "latest_step", "AsyncCheckpointer"]

_STEP_RE = re.compile(r"^step_(\d+)$")
_TMP_RE = re.compile(r"^tmp\.(\d+)\.(\d+)$")


def _pid_alive(pid: int) -> bool:
    try:
        os.kill(pid, 0)
    except ProcessLookupError:
        return False
    except PermissionError:
        return True  # exists, owned by someone else
    return True


def _gc_orphan_tmps(ckpt_dir: str) -> list[str]:
    """Remove ``tmp.<step>.<pid>`` staging dirs whose writer died.

    A pid that no longer exists cannot complete its rename, so its
    staging dir is garbage forever; a pid that is still alive may be
    mid-write (another process, or this process's async worker) and its
    tmp is left alone.  Returns the removed directory names.
    """
    removed = []
    for d in os.listdir(ckpt_dir):
        m = _TMP_RE.match(d)
        if m and not _pid_alive(int(m.group(2))):
            _rmtree(os.path.join(ckpt_dir, d))
            removed.append(d)
    return removed


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return {jax.tree_util.keystr(path): leaf for path, leaf in flat}, treedef


def save(ckpt_dir: str, step: int, tree) -> str:
    """Blocking atomic save.  Returns the checkpoint path.

    Also sweeps staging dirs orphaned by crashed writers — the save that
    follows a crash is the natural (and only safe) point to clean up.
    """
    os.makedirs(ckpt_dir, exist_ok=True)
    _gc_orphan_tmps(ckpt_dir)
    named, _ = _flatten(tree)
    host = {k: np.asarray(v) for k, v in named.items()}
    tmp = os.path.join(ckpt_dir, f"tmp.{step}.{os.getpid()}")
    final = os.path.join(ckpt_dir, f"step_{step}")
    os.makedirs(tmp, exist_ok=True)
    np.savez(os.path.join(tmp, "arrays.npz"), **host)
    manifest = {
        "step": int(step),
        "time": time.time(),
        "keys": sorted(host.keys()),
        "nbytes": int(sum(a.nbytes for a in host.values())),
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        _rmtree(final)
    os.replace(tmp, final)
    return final


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [
        int(m.group(1))
        for d in os.listdir(ckpt_dir)
        if (m := _STEP_RE.match(d)) and os.path.exists(
            os.path.join(ckpt_dir, d, "manifest.json")
        )
    ]
    return max(steps) if steps else None


def restore(ckpt_dir: str, target, step: int | None = None, shardings=None):
    """Restore into the structure of ``target`` (arrays or ShapeDtypeStructs).

    ``shardings``: optional matching pytree of Shardings for elastic
    placement on a (possibly different) mesh.
    """
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    named_target, treedef = _flatten(target)
    missing = set(named_target) - set(manifest["keys"])
    if missing:
        raise ValueError(f"checkpoint at step {step} missing keys: {sorted(missing)[:5]}")
    data = np.load(os.path.join(path, "arrays.npz"))
    if shardings is not None:
        named_shard, _ = _flatten(shardings)
    leaves = []
    for key in named_target:
        arr = data[key]
        tgt = named_target[key]
        if tuple(arr.shape) != tuple(tgt.shape):
            raise ValueError(
                f"shape mismatch for {key}: ckpt {arr.shape} vs target {tgt.shape}"
            )
        arr = arr.astype(tgt.dtype)
        if shardings is not None:
            leaves.append(jax.device_put(arr, named_shard[key]))
        else:
            leaves.append(jnp.asarray(arr))
    ordered = [leaves[list(named_target).index(k)] for k in named_target]
    return jax.tree_util.tree_unflatten(treedef, ordered), step


def _rmtree(path):
    for root, dirs, files in os.walk(path, topdown=False):
        for f in files:
            os.remove(os.path.join(root, f))
        for d in dirs:
            os.rmdir(os.path.join(root, d))
    os.rmdir(path)


def gc_checkpoints(ckpt_dir: str, keep_n: int = 3):
    if not os.path.isdir(ckpt_dir):
        return
    steps = sorted(
        int(m.group(1)) for d in os.listdir(ckpt_dir) if (m := _STEP_RE.match(d))
    )
    for s in steps[:-keep_n]:
        _rmtree(os.path.join(ckpt_dir, f"step_{s}"))


class AsyncCheckpointer:
    """Snapshot-to-host + background-thread writer with keep_n GC."""

    def __init__(self, ckpt_dir: str, keep_n: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep_n = keep_n
        self._q: queue.Queue = queue.Queue(maxsize=2)
        self._err: Exception | None = None
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        while True:
            item = self._q.get()
            if item is None:
                return
            step, tree = item
            try:
                save(self.ckpt_dir, step, tree)
                gc_checkpoints(self.ckpt_dir, self.keep_n)
            except Exception as e:  # surfaced on next submit/finalize
                self._err = e

    def submit(self, step: int, tree):
        if self._err:
            raise self._err
        host = jax.tree.map(lambda x: np.asarray(x), tree)  # sync snapshot
        self._q.put((int(step), host))

    def finalize(self):
        self._q.put(None)
        self._thread.join()
        if self._err:
            raise self._err
