"""Training driver: resume -> step loop -> async checkpoints -> metrics."""
from __future__ import annotations

import time

import jax
import numpy as np

from ..configs.base import ModelConfig
from ..data import SyntheticConfig, batch_at
from ..optim import AdamWConfig
from . import checkpoint as ckpt_lib
from .step import TrainState, init_train_state, make_train_step

__all__ = ["train_loop"]


def train_loop(
    cfg: ModelConfig,
    data_cfg: SyntheticConfig,
    opt_cfg: AdamWConfig,
    *,
    steps: int,
    ckpt_dir: str | None = None,
    ckpt_every: int = 50,
    keep_n: int = 3,
    n_micro: int = 1,
    log_every: int = 10,
    seed: int = 0,
    log=print,
):
    """Single-process training loop (examples/tests; launch/train.py adds
    the mesh).  Resumes from the latest checkpoint if one exists."""
    state = init_train_state(cfg, jax.random.key(seed))
    start = 0
    writer = None
    if ckpt_dir:
        found = ckpt_lib.latest_step(ckpt_dir)
        if found is not None:
            state, start = ckpt_lib.restore(ckpt_dir, state, step=found)
            log(f"[resume] restored step {start} from {ckpt_dir}")
        writer = ckpt_lib.AsyncCheckpointer(ckpt_dir, keep_n=keep_n)

    step_fn = jax.jit(make_train_step(cfg, opt_cfg, n_micro=n_micro), donate_argnums=0)
    losses = []
    t0 = time.time()
    for step in range(start, steps):
        batch = batch_at(data_cfg, step)
        state, metrics = step_fn(state, batch)
        if (step + 1) % log_every == 0 or step + 1 == steps:
            loss = float(metrics["loss"])
            losses.append((step + 1, loss))
            dt = (time.time() - t0) / max(step + 1 - start, 1)
            log(
                f"step {step+1:5d} loss {loss:.4f} "
                f"gnorm {float(metrics['grad_norm']):.3f} "
                f"lr {float(metrics['lr']):.2e} ({dt*1e3:.0f} ms/step)"
            )
        if writer and (step + 1) % ckpt_every == 0:
            writer.submit(step + 1, state)
    if writer:
        writer.submit(steps, state)
        writer.finalize()
    return state, losses
