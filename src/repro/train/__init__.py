from . import checkpoint, elastic, loop, serve, step
from .checkpoint import AsyncCheckpointer, latest_step, restore, save
from .elastic import rebalance_microbatch, restore_elastic
from .loop import train_loop
from .serve import generate
from .step import (
    TrainState,
    batch_pspec,
    init_train_state,
    jit_train_step,
    make_decode_step,
    make_dp_train_step,
    make_prefill_step,
    make_train_step,
    state_pspecs,
    state_shapes,
)

__all__ = [
    "checkpoint", "elastic", "loop", "serve", "step",
    "AsyncCheckpointer", "latest_step", "restore", "save",
    "rebalance_microbatch", "restore_elastic", "train_loop", "generate",
    "TrainState", "batch_pspec", "init_train_state", "jit_train_step",
    "make_decode_step", "make_dp_train_step", "make_prefill_step",
    "make_train_step", "state_pspecs", "state_shapes",
]
