"""Batched serving: prefill a prompt batch, then greedy/temperature decode."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..models import transformer as tfm

__all__ = ["generate"]


@partial(jax.jit, static_argnames=("cfg", "max_new"))
def _decode_loop(cfg: ModelConfig, params, cache, first_tokens, start, max_new, key):
    def body(carry, _):
        tokens, cache, step, key = carry
        logits, cache = tfm.decode_step(cfg, params, cache, tokens, step)
        key, sub = jax.random.split(key)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return (nxt, cache, step + 1, key), nxt

    (_, cache, _, _), out = jax.lax.scan(
        body, (first_tokens, cache, start, key), None, length=max_new
    )
    return out.T, cache  # (B, max_new)


def generate(
    cfg: ModelConfig,
    params,
    prompts: jax.Array,  # (B, S_prompt) int32
    *,
    max_new: int = 32,
    cache_len: int | None = None,
    seed: int = 0,
):
    """Prefill + greedy decode.  Returns (B, max_new) generated tokens."""
    B, S = prompts.shape
    cache_len = cache_len or (S + max_new)
    logits, cache = tfm.prefill(cfg, params, {"tokens": prompts}, S_cache=cache_len)
    first = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    out, _ = _decode_loop(
        cfg, params, cache, first, jnp.asarray(S, jnp.int32), max_new, jax.random.key(seed)
    )
    return jnp.concatenate([first[:, None], out[:, :-1]], axis=1)
