"""Train / serve step factories.

``make_train_step`` — production path: pjit with 2D-sharded params
(TP over 'model', FSDP over 'data'), gradient accumulation over
microbatches via lax.scan (+ per-layer remat inside the model), f32
AdamW, donated state.

``make_dp_train_step`` — pure data-parallel shard_map path with optional
**CountSketch gradient compression** (the paper's operator on the DP
all-reduce; see repro.optim.compression).  Used where compression applies:
replicated params, batch sharded over ('pod','data').

``make_prefill_step`` / ``make_decode_step`` — serving entry points.
"""
from __future__ import annotations

from functools import partial
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ModelConfig, ShapeConfig
from ..models import transformer as tfm
from ..models.common import maybe_scan
from ..optim import (
    AdamWConfig,
    CompressionConfig,
    adamw_init,
    adamw_update,
    compress_state_init,
    sketched_psum_grads,
)
from ..sharding import DEFAULT_RULES, OPT_RULES, logical_to_spec, tree_pspecs

__all__ = [
    "TrainState",
    "init_train_state",
    "state_pspecs",
    "state_shapes",
    "batch_pspec",
    "make_train_step",
    "make_dp_train_step",
    "make_prefill_step",
    "make_decode_step",
]


class TrainState(NamedTuple):
    step: jax.Array
    params: Any
    opt: Any


def init_train_state(cfg: ModelConfig, key) -> TrainState:
    params = tfm.init_params(cfg, key)
    from ..models.common import DTYPES

    opt = adamw_init(params, moments_dtype=DTYPES[cfg.opt_moments_dtype])
    return TrainState(step=jnp.zeros((), jnp.int32), params=params, opt=opt)


def state_shapes(cfg: ModelConfig) -> TrainState:
    return jax.eval_shape(lambda: init_train_state(cfg, jax.random.key(0)))


def state_pspecs(cfg: ModelConfig, mesh: Mesh, rules=None) -> TrainState:
    axes = tfm.params_axes(cfg)
    shapes = tfm.params_shapes(cfg)
    pspecs = tree_pspecs(axes, mesh, rules, shapes_tree=shapes)
    ospecs = tree_pspecs(axes, mesh, rules or OPT_RULES, shapes_tree=shapes)
    if rules is None:
        ospecs = tree_pspecs(axes, mesh, OPT_RULES, shapes_tree=shapes)
    return TrainState(
        step=P(),
        params=pspecs,
        opt={"master": ospecs, "m": ospecs, "v": ospecs},
    )


def batch_pspec(mesh: Mesh, rules=None) -> P:
    return logical_to_spec(("batch", "seq"), mesh, rules)


def _constrain_like_opt(grads, cfg):
    """Shard gradient buffers like the optimizer state (ZeRO-2 over pod)."""
    try:
        from jax._src.mesh import thread_resources

        mesh = thread_resources.env.physical_mesh
        if mesh.empty or "pod" not in mesh.axis_names:
            return grads
    except Exception:
        return grads
    axes = tfm.params_axes(cfg)
    specs = tree_pspecs(axes, mesh, OPT_RULES, shapes_tree=grads)
    return jax.tree.map(
        lambda g, s: jax.lax.with_sharding_constraint(
            g, NamedSharding(mesh, s)
        ),
        grads,
        specs,
        is_leaf=lambda x: isinstance(x, jax.Array),
    )


def _microbatch(batch, n_micro: int):
    """(B, ...) -> (n_micro, B/n_micro, ...) for every leaf."""
    return jax.tree.map(
        lambda x: x.reshape((n_micro, x.shape[0] // n_micro) + x.shape[1:]), batch
    )


def make_train_step(
    cfg: ModelConfig,
    opt_cfg: AdamWConfig,
    *,
    n_micro: int = 1,
):
    """Returns train_step(state, batch) -> (state, metrics).  Jit/pjit-ready."""

    def train_step(state: TrainState, batch):
        params = state.params

        def loss_of(p, mb):
            return tfm.loss_fn(cfg, p, mb)

        if n_micro == 1:
            (loss, metrics), grads = jax.value_and_grad(loss_of, has_aux=True)(
                params, batch
            )
            grads = _constrain_like_opt(grads, cfg)
        else:
            mbs = _microbatch(batch, n_micro)

            def acc_body(carry, mb):
                g_acc, l_acc = carry
                (l, _), g = jax.value_and_grad(loss_of, has_aux=True)(params, mb)
                g = _constrain_like_opt(g, cfg)
                g_acc = jax.tree.map(lambda a, b: a + b.astype(a.dtype), g_acc, g)
                return (g_acc, l_acc + l), None

            from ..models.common import DTYPES

            acc_dtype = DTYPES[cfg.grad_accum_dtype]
            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, acc_dtype), params)
            (grads, loss_sum), _ = maybe_scan(acc_body, (g0, jnp.zeros((), jnp.float32)), mbs)
            grads = jax.tree.map(lambda g: g / n_micro, grads)
            loss = loss_sum / n_micro
            metrics = {}

        new_opt, opt_metrics = adamw_update(opt_cfg, grads, state.opt, state.step)
        new_params = jax.tree.map(
            lambda m, p: m.astype(p.dtype), new_opt["master"], params
        )
        metrics = {"loss": loss, **opt_metrics}
        return TrainState(step=state.step + 1, params=new_params, opt=new_opt), metrics

    return train_step


def jit_train_step(cfg, opt_cfg, mesh, *, n_micro=1, rules=None):
    """pjit-wrapped train step with explicit state/batch shardings."""
    step_fn = make_train_step(cfg, opt_cfg, n_micro=n_micro)
    sspec = state_pspecs(cfg, mesh, rules)
    bspec = {"tokens": batch_pspec(mesh, rules), "labels": batch_pspec(mesh, rules)}
    mspec = None  # metrics replicated
    return jax.jit(
        step_fn,
        in_shardings=(
            jax.tree.map(lambda s: NamedSharding(mesh, s), sspec,
                         is_leaf=lambda x: isinstance(x, P)),
            jax.tree.map(lambda s: NamedSharding(mesh, s), bspec,
                         is_leaf=lambda x: isinstance(x, P)),
        ),
        donate_argnums=(0,),
    )


# ===========================================================================
# Pure-DP path with sketched gradient compression
# ===========================================================================


def make_dp_train_step(
    cfg: ModelConfig,
    opt_cfg: AdamWConfig,
    mesh: Mesh,
    *,
    axes=("data",),
    compression: CompressionConfig | None = None,
):
    """shard_map DP train step: params replicated, batch row-sharded.

    Gradients are combined with a plain psum or, when ``compression`` is
    given, with CountSketch-compressed psum + error feedback.
    """
    if isinstance(axes, str):
        axes = (axes,)

    def local_step(state_and_ef, batch):
        state, ef = state_and_ef

        def loss_of(p):
            return tfm.loss_fn(cfg, p, batch)

        (loss, _), grads = jax.value_and_grad(loss_of, has_aux=True)(state.params)
        loss = lax.pmean(loss, axes)
        if compression is None:
            grads = jax.tree.map(lambda g: lax.pmean(g, axes), grads)
            new_ef = ef
        else:
            grads, new_ef = sketched_psum_grads(
                compression, grads, ef, axes, step=state.step
            )
        new_opt, om = adamw_update(opt_cfg, grads, state.opt, state.step)
        new_params = jax.tree.map(
            lambda m, p: m.astype(p.dtype), new_opt["master"], state.params
        )
        new_state = TrainState(step=state.step + 1, params=new_params, opt=new_opt)
        return (new_state, new_ef), {"loss": loss, **om}

    rep = P()
    row = P(axes)

    def specs_like(tree, spec):
        return jax.tree.map(lambda _: spec, tree)

    def step(state, ef, batch):
        from ..sharding import shard_map_compat

        fn = shard_map_compat(
            local_step,
            mesh=mesh,
            in_specs=((specs_like(state, rep), specs_like(ef, rep)),
                      specs_like(batch, row)),
            out_specs=((specs_like(state, rep), specs_like(ef, rep)),
                       {"loss": rep, "grad_norm": rep, "lr": rep}),
        )
        return fn((state, ef), batch)

    return step


# ===========================================================================
# Serving steps
# ===========================================================================


def make_prefill_step(cfg: ModelConfig):
    def prefill_step(params, batch):
        return tfm.prefill(cfg, params, batch)

    return prefill_step


def make_decode_step(cfg: ModelConfig):
    def decode_step(params, cache, tokens, step, embeds=None, img=None):
        return tfm.decode_step(
            cfg, params, cache, tokens, step, embeds=embeds, img=img
        )

    return decode_step
