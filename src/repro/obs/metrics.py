"""Process-wide metrics registry: counters, gauges, bounded histograms.

One registry (:data:`REGISTRY`) serves the whole stack.  Three design
constraints drive the implementation:

- **Thread-safe.**  The cluster engine mutates stats from worker threads,
  the serve pump from its dispatch thread, and the load harness from many
  submitter threads at once.  Every instrument guards its state with its
  own small lock; the registry lock only covers name → instrument lookup.
- **Near-zero cost when disabled.**  ``REPRO_METRICS=0`` swaps every
  instrument for a shared null object whose methods are no-op one-liners:
  a disabled ``counter.inc()`` is one attribute call, no lock, no dict.
- **Backward compatible.**  The five pre-existing ad-hoc ``stats`` dicts
  (session, streaming, cluster, serve, cache) are *real dicts* that tests
  pin by equality; :meth:`MetricsRegistry.stats_dict` returns a ``dict``
  subclass that mirrors every write into registry counters/gauges, so the
  dicts keep their exact keys and values while the registry aggregates
  the same numbers across all instances under ``namespace.key`` names.

Histograms use fixed log-spaced latency buckets (seconds) — bounded
memory regardless of observation count, exported in Prometheus's
cumulative-bucket convention by :mod:`repro.obs.export`.
"""
from __future__ import annotations

import os

from .lockcheck import make_lock

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "REGISTRY",
    "DEFAULT_BUCKETS",
]

# Log-spaced seconds: 100 µs … 30 s, plus +inf implicitly (the overflow
# count lives in ``counts[-1]``).
DEFAULT_BUCKETS = (
    1e-4, 3e-4, 1e-3, 3e-3, 1e-2, 3e-2, 1e-1, 3e-1, 1.0, 3.0, 10.0, 30.0,
)


class Counter:
    """Monotone counter.  ``inc`` only; negative increments are rejected."""

    __slots__ = ("name", "_mu", "_value")
    GUARDED_BY = {"_value": "_mu"}

    def __init__(self, name: str):
        self.name = name
        self._mu = make_lock("Counter._mu")
        self._value = 0

    def inc(self, n: int | float = 1) -> None:
        if n < 0:
            raise ValueError(f"counter {self.name}: negative increment {n}")
        with self._mu:
            self._value += n

    @property
    def value(self):
        with self._mu:
            return self._value


class Gauge:
    """Point-in-time value: ``set`` or ``inc`` (either sign)."""

    __slots__ = ("name", "_mu", "_value")
    GUARDED_BY = {"_value": "_mu"}

    def __init__(self, name: str):
        self.name = name
        self._mu = make_lock("Gauge._mu")
        self._value = 0

    def set(self, v) -> None:
        with self._mu:
            self._value = v

    def inc(self, n=1) -> None:
        with self._mu:
            self._value += n

    @property
    def value(self):
        with self._mu:
            return self._value


class Histogram:
    """Bounded-bucket histogram: fixed upper bounds, O(#buckets) memory.

    ``counts[i]`` counts observations ≤ ``buckets[i]`` (non-cumulative in
    storage; the exporter accumulates); ``counts[-1]`` is the +inf
    overflow bucket.  Tracks ``sum``/``count`` for mean latency.
    """

    __slots__ = ("name", "buckets", "_mu", "_counts", "_sum", "_count")
    GUARDED_BY = {"_counts": "_mu", "_sum": "_mu", "_count": "_mu"}

    def __init__(self, name: str, buckets=DEFAULT_BUCKETS):
        self.name = name
        self.buckets = tuple(sorted(float(b) for b in buckets))
        self._mu = make_lock("Histogram._mu")
        self._counts = [0] * (len(self.buckets) + 1)
        self._sum = 0.0
        self._count = 0

    def observe(self, v: float) -> None:
        v = float(v)
        i = 0
        for b in self.buckets:
            if v <= b:
                break
            i += 1
        with self._mu:
            self._counts[i] += 1
            self._sum += v
            self._count += 1

    def snapshot(self) -> dict:
        with self._mu:
            return {
                "buckets": self.buckets,
                "counts": tuple(self._counts),
                "sum": self._sum,
                "count": self._count,
            }


class _Null:
    """Shared no-op instrument — what a disabled registry hands out."""

    __slots__ = ()
    name = "null"
    value = 0
    buckets = ()

    def inc(self, n=1):
        pass

    def set(self, v):
        pass

    def observe(self, v):
        pass

    def snapshot(self):
        return {"buckets": (), "counts": (), "sum": 0.0, "count": 0}


_NULL = _Null()


class StatsDict(dict):
    """A plain dict that mirrors writes into the registry.

    Reads, equality, iteration — everything tests pin — behave exactly
    like the dict it replaces.  Each ``d[k] = v`` additionally feeds the
    registry: positive deltas go to a shared counter ``namespace.key``
    (aggregating across instances — many sessions, one metric), and the
    latest value to a gauge ``namespace.key.last``.
    """

    __slots__ = ("_registry", "_ns")

    def __init__(self, registry: "MetricsRegistry", namespace: str, initial):
        super().__init__(initial)
        self._registry = registry
        self._ns = namespace
        for k, v in initial.items():
            if v:
                self._mirror(k, 0, v)

    def _mirror(self, k, old, new) -> None:
        name = f"{self._ns}.{k}"
        delta = new - old
        if delta > 0:
            self._registry.counter(name).inc(delta)
        self._registry.gauge(name + ".last").set(new)

    def __setitem__(self, k, v):
        old = dict.get(self, k, 0)
        dict.__setitem__(self, k, v)
        self._mirror(k, old, v)

    def __reduce__(self):  # pickle as a plain dict (checkpoints)
        return (dict, (dict(self),))


class MetricsRegistry:
    """Name → instrument map with get-or-create semantics.

    Disabled (``enabled=False`` or ``REPRO_METRICS=0``) the registry
    hands out a shared null instrument and records nothing.
    """

    # The name tables are created once here and only ever mutated under
    # _mu — note the one deliberate blind spot: _get() writes through its
    # `table` alias, which a lexical checker cannot tie back to these
    # attrs.  The alias write is inside `with self._mu:` all the same.
    GUARDED_BY = {
        "_counters": "_mu",
        "_gauges": "_mu",
        "_histograms": "_mu",
    }

    def __init__(self, enabled: bool | None = None):
        if enabled is None:
            enabled = os.environ.get("REPRO_METRICS", "1") != "0"
        self.enabled = bool(enabled)
        self._mu = make_lock("MetricsRegistry._mu")
        self._counters: dict[str, Counter] = {}
        self._gauges: dict[str, Gauge] = {}
        self._histograms: dict[str, Histogram] = {}

    def _get(self, table, name, factory):
        inst = table.get(name)
        if inst is None:
            with self._mu:
                inst = table.get(name)
                if inst is None:
                    inst = table[name] = factory()
        return inst

    def counter(self, name: str) -> Counter:
        if not self.enabled:
            return _NULL
        return self._get(self._counters, name, lambda: Counter(name))

    def gauge(self, name: str) -> Gauge:
        if not self.enabled:
            return _NULL
        return self._get(self._gauges, name, lambda: Gauge(name))

    def histogram(self, name: str, buckets=DEFAULT_BUCKETS) -> Histogram:
        if not self.enabled:
            return _NULL
        return self._get(
            self._histograms, name, lambda: Histogram(name, buckets)
        )

    def stats_dict(self, namespace: str, initial: dict) -> StatsDict:
        """A dict-compatible stats object mirrored into this registry."""
        return StatsDict(self, namespace, initial)

    # ------------------------------------------------------------- export
    def snapshot(self) -> dict:
        """Consistent-enough point-in-time copy of every instrument.

        Each instrument is read under its own lock; the registry lock
        covers the name tables, so no instrument is lost or torn mid-read
        (cross-instrument skew is inherent to any live snapshot).
        """
        with self._mu:
            counters = list(self._counters.values())
            gauges = list(self._gauges.values())
            histograms = list(self._histograms.values())
        return {
            "counters": {c.name: c.value for c in counters},
            "gauges": {g.name: g.value for g in gauges},
            "histograms": {h.name: h.snapshot() for h in histograms},
        }

    def reset(self) -> None:
        """Drop every instrument (tests; the stats dicts keep working —
        their next write re-creates the mirrored instruments)."""
        with self._mu:
            self._counters.clear()
            self._gauges.clear()
            self._histograms.clear()


REGISTRY = MetricsRegistry()
