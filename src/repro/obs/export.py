"""Exporters: Prometheus text exposition, JSON snapshots, profiler hook.

Everything here is pull-based and dependency-free: :func:`prometheus_text`
renders the registry in the text exposition format (scrape it from any
HTTP handler the embedding app already has), :func:`json_snapshot` is the
same data as a plain dict for logs/tests, and :func:`jax_profile` wraps a
traced region with ``jax.profiler`` so a repro span timeline and an XLA
op-level profile can be captured in one shot.
"""
from __future__ import annotations

import contextlib
import json
import re
import time

from .metrics import REGISTRY, MetricsRegistry
from . import trace as trace_lib

__all__ = [
    "prometheus_text",
    "json_snapshot",
    "save_chrome_trace",
    "jax_profile",
]

_NAME_RE = re.compile(r"[^a-zA-Z0-9_:]")


def _prom_name(name: str) -> str:
    return _NAME_RE.sub("_", f"repro_{name}")


def prometheus_text(registry: MetricsRegistry = REGISTRY) -> str:
    """Render the registry in Prometheus text exposition format 0.0.4."""
    snap = registry.snapshot()
    out: list[str] = []
    for name, value in sorted(snap["counters"].items()):
        p = _prom_name(name)
        out.append(f"# TYPE {p} counter")
        out.append(f"{p} {value}")
    for name, value in sorted(snap["gauges"].items()):
        p = _prom_name(name)
        out.append(f"# TYPE {p} gauge")
        out.append(f"{p} {value}")
    for name, h in sorted(snap["histograms"].items()):
        p = _prom_name(name)
        out.append(f"# TYPE {p} histogram")
        cum = 0
        for bound, count in zip(h["buckets"], h["counts"]):
            cum += count
            out.append(f'{p}_bucket{{le="{bound}"}} {cum}')
        cum += h["counts"][-1] if h["counts"] else 0
        out.append(f'{p}_bucket{{le="+Inf"}} {cum}')
        out.append(f"{p}_sum {h['sum']}")
        out.append(f"{p}_count {h['count']}")
    return "\n".join(out) + "\n"


def json_snapshot(registry: MetricsRegistry = REGISTRY) -> dict:
    """Registry snapshot as a JSON-serializable dict (with a timestamp)."""
    snap = registry.snapshot()
    snap["ts_unix"] = time.time()
    json.dumps(snap)  # guarantee serializability at the source
    return snap


def save_chrome_trace(obj, path: str) -> str:
    """Write a :class:`Tracer` or :class:`Timeline` as Chrome-trace JSON."""
    with open(path, "w") as f:
        json.dump(obj.chrome_trace(), f)
    return path


@contextlib.contextmanager
def jax_profile(logdir: str):
    """Capture a jax/XLA profiler trace around a repro-traced region.

    Best-effort: on builds where ``jax.profiler.trace`` is unavailable or
    fails to start (no TensorBoard plugin, sandboxed filesystem) the
    region still runs — with the repro span recorded — and the profiler
    part is skipped.
    """
    import jax

    with trace_lib.span("jax_profile", logdir=logdir):
        try:
            ctx = jax.profiler.trace(logdir)
            ctx.__enter__()
        except Exception:
            ctx = None
        try:
            yield
        finally:
            if ctx is not None:
                try:
                    ctx.__exit__(None, None, None)
                except Exception:
                    pass
