"""Span tracing: nested wall-clock timelines across the whole stack.

The instrumentation contract is one idiom at every site::

    from ..obs import trace as obs_trace
    with obs_trace.span("sketch.apply", kind=sketch, shape=(m, n)):
        B = ...
        obs_trace.maybe_block(B)

- **Disabled is the default and costs almost nothing**: ``span()`` reads
  one module global, sees no active tracer, and returns a shared no-op
  context manager.  No locks, no allocation beyond the call's kwargs.
- **Enabled** (``REPRO_TRACE=1``, :func:`tracing`, or per-call
  ``lstsq(..., trace=True)``) every span records a Chrome-trace complete
  event — start, duration (µs), thread, nesting depth, attributes — into
  one process-global :class:`Tracer`.  A *module-global* active tracer
  (not a contextvar) is deliberate: cluster worker threads and the serve
  pump thread must land their spans in the same trace as the caller.
- ``maybe_block`` calls ``jax.block_until_ready`` *only while tracing*,
  so span durations are real device wall time; with tracing off JAX's
  async dispatch is untouched.
- Spans started while JAX is *tracing a jit* (abstract values, no real
  work) are suppressed — they would otherwise record one bogus
  compile-time span per cache miss.

:class:`Timeline` is the export surface: ``str(tl)`` renders an indented
per-solve tree, ``tl.chrome_trace()`` / ``tl.save(path)`` produce JSON
loadable in ``chrome://tracing`` or Perfetto.
"""
from __future__ import annotations

import json
import os
import threading
import time

import jax

from .lockcheck import make_lock

try:  # suppress spans during jit tracing (abstract, zero-work "execution")
    from jax.core import trace_state_clean as _trace_state_clean
except ImportError:  # pragma: no cover - older/newer jax layouts
    def _trace_state_clean() -> bool:
        return True

__all__ = [
    "Tracer",
    "Timeline",
    "span",
    "instant",
    "maybe_block",
    "enabled",
    "enable",
    "disable",
    "tracing",
    "stripped",
    "solve_scope",
    "current",
]

_ENV_FLAG = "REPRO_TRACE"

_active: "Tracer | None" = None
_active_mu = threading.Lock()
_tls = threading.local()


def _depth() -> int:
    return getattr(_tls, "depth", 0)


class Tracer:
    """Event sink: an append-only list of Chrome-trace event dicts.

    All event appends and snapshot reads go through ``self._mu``.  The
    old scheme relied on CPython's GIL making ``list.append`` atomic —
    true, but a reader iterating ``events`` concurrently with an append
    could still observe a resize mid-copy, and the GIL contract is
    explicitly not portable (free-threaded builds).  One short lock per
    recorded event is noise next to the ``perf_counter`` calls either
    side of it.
    """

    GUARDED_BY = {"events": "_mu", "_tids": "_mu"}
    GUARDED_READS = frozenset({"events"})

    def __init__(self):
        self.t0 = time.perf_counter()
        self.events: list[dict] = []
        self._mu = make_lock("Tracer._mu")
        self._tids: dict[int, int] = {}

    def now_us(self) -> float:
        return (time.perf_counter() - self.t0) * 1e6

    def record(self, ev: dict) -> None:
        """Append one Chrome-trace event dict (thread-safe)."""
        with self._mu:
            self.events.append(ev)

    def tid(self) -> int:
        ident = threading.get_ident()
        t = self._tids.get(ident)  # racy fast path, settled under _mu below
        if t is None:
            with self._mu:
                t = self._tids.get(ident)
                if t is None:
                    t = len(self._tids)
                    self._tids[ident] = t
                    self.events.append({
                        "name": "thread_name", "ph": "M", "pid": 1, "tid": t,
                        "args": {"name": threading.current_thread().name},
                    })
        return t

    def chrome_trace(self) -> dict:
        with self._mu:
            events = list(self.events)
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.chrome_trace(), f)

    def timeline(self, start: int = 0) -> "Timeline":
        with self._mu:
            return Timeline(list(self.events[start:]))


class Timeline:
    """A slice of trace events scoped to one solve.

    Attached to ``SolveResult.timeline``; renders as an indented tree
    (depth + start-time ordering reconstruct the nesting) and exports the
    same events as Chrome-trace JSON.
    """

    __slots__ = ("events",)

    def __init__(self, events: list[dict]):
        self.events = events

    def spans(self) -> list[dict]:
        return [e for e in self.events if e.get("ph") == "X"]

    def instants(self) -> list[dict]:
        return [e for e in self.events if e.get("ph") == "i"]

    def names(self) -> list[str]:
        return [e["name"] for e in self.events if e.get("ph") in ("X", "i")]

    def chrome_trace(self) -> dict:
        return {"traceEvents": list(self.events), "displayTimeUnit": "ms"}

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.chrome_trace(), f)

    def render(self) -> str:
        rows = [e for e in self.events if e.get("ph") in ("X", "i")]
        rows.sort(key=lambda e: (e["ts"], e.get("depth", 0)))
        lines = []
        for e in rows:
            pad = "  " * e.get("depth", 0)
            args = e.get("args") or {}
            attrs = " ".join(f"{k}={v}" for k, v in args.items())
            attrs = f"  [{attrs}]" if attrs else ""
            if e.get("ph") == "i":
                lines.append(
                    f"{pad}· {e['name']} @ {e['ts'] / 1e3:.3f} ms{attrs}"
                )
            else:
                lines.append(
                    f"{pad}{e['name']}  {e.get('dur', 0) / 1e3:.3f} ms{attrs}"
                )
        return "\n".join(lines)

    def __str__(self) -> str:
        return self.render()

    def __repr__(self) -> str:
        n = len(self.spans())
        return f"Timeline({n} spans, {len(self.instants())} events)"


# ---------------------------------------------------------------------------
# span recording


class _NoopSpan:
    """Shared do-nothing span: the tracing-disabled fast path."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, **kw):
        pass

    def __bool__(self):
        return False


_NOOP = _NoopSpan()


class _Span:
    __slots__ = ("_tracer", "_name", "_args", "_t0", "_depth")

    def __init__(self, tracer: Tracer, name: str, args: dict):
        self._tracer = tracer
        self._name = name
        self._args = args

    def set(self, **kw) -> None:
        """Attach attributes discovered mid-span (method picked, itn...)."""
        self._args.update(kw)

    def __bool__(self):
        return True

    def __enter__(self):
        self._depth = _depth()
        _tls.depth = self._depth + 1
        self._t0 = self._tracer.now_us()
        return self

    def __exit__(self, *exc):
        t1 = self._tracer.now_us()
        _tls.depth = self._depth
        self._tracer.record({
            "name": self._name, "cat": "repro", "ph": "X",
            "ts": self._t0, "dur": t1 - self._t0,
            "pid": 1, "tid": self._tracer.tid(),
            "depth": self._depth, "args": self._args,
        })
        return False


def span(name: str, **args):
    """Context manager timing a region; no-op unless tracing is active."""
    t = _active
    if t is None or not _trace_state_clean():
        return _NOOP
    return _Span(t, name, args)


def instant(name: str, **args) -> None:
    """Point event (eviction, restore, submit...); no-op when disabled."""
    t = _active
    if t is None or not _trace_state_clean():
        return
    t.record({
        "name": name, "cat": "repro", "ph": "i", "s": "t",
        "ts": t.now_us(), "pid": 1, "tid": t.tid(),
        "depth": _depth(), "args": args,
    })


def maybe_block(x):
    """Synchronize JAX async dispatch — only while tracing.

    Keeps span durations honest (device work attributed to the span that
    launched it) without perturbing the untraced pipeline.  Skipped
    outright under jit tracing (abstract values can't be blocked on);
    tolerates non-array pytrees.
    """
    if _active is not None and _trace_state_clean():
        try:
            jax.block_until_ready(x)
        except Exception:
            pass
    return x


# ---------------------------------------------------------------------------
# activation


def enabled() -> bool:
    return _active is not None


def current() -> Tracer | None:
    return _active


def enable() -> Tracer:
    """Activate a fresh process-global tracer (idempotent: returns the
    active one if tracing is already on)."""
    global _active
    with _active_mu:
        if _active is None:
            _active = Tracer()
        return _active


def disable() -> Tracer | None:
    """Deactivate and return the tracer that was collecting (if any)."""
    global _active
    with _active_mu:
        t, _active = _active, None
        return t


class tracing:
    """``with tracing() as tr:`` — enable tracing for a region.

    Joins an already-active tracer rather than stacking a new one; only
    the outermost ``tracing`` deactivates on exit.  The yielded value is
    the :class:`Tracer`; ``tr.timeline(mark)`` / ``tr.chrome_trace()``
    read the events afterwards.
    """

    def __init__(self):
        self._owned = False

    def __enter__(self) -> Tracer:
        global _active
        with _active_mu:
            if _active is None:
                _active = Tracer()
                self._owned = True
            return _active

    def __exit__(self, *exc):
        if self._owned:
            disable()
        return False


class solve_scope:
    """Per-call tracing scope for ``lstsq(..., trace=True)`` and friends.

    - ``flag=True``: ensure a tracer is active for the call (owning — and
      therefore deactivating — it only if none was active before).
    - ``flag=None``/``False``: never activates, but still *observes* an
      already-active tracer (env flag or enclosing :class:`tracing`).

    ``attach(res)`` replaces ``res.timeline`` with the :class:`Timeline`
    of events recorded since ``__enter__`` whenever a tracer was live.
    """

    __slots__ = ("_flag", "_owned", "_tracer", "_mark")

    def __init__(self, flag: bool | None):
        self._flag = flag
        self._owned = False
        self._tracer = None
        self._mark = 0

    def __enter__(self) -> "solve_scope":
        global _active
        with _active_mu:
            if _active is None and self._flag:
                _active = Tracer()
                self._owned = True
            self._tracer = _active
        if self._tracer is not None:
            self._mark = len(self._tracer.events)
        return self

    def __exit__(self, *exc):
        if self._owned:
            disable()
        return False

    def attach(self, res):
        if self._tracer is None:
            return res
        tl = self._tracer.timeline(self._mark)
        try:
            return res._replace(timeline=tl)
        except (AttributeError, ValueError):
            return res


# ---------------------------------------------------------------------------
# benchmark support


class stripped:
    """Replace the instrumentation entry points with bare no-ops.

    The honest baseline for the ≤ 1.05x tracing-disabled overhead gate:
    inside this context every ``obs_trace.span(...)`` call site resolves
    to a function that does *nothing at all*, so timing the same solve
    in and out of the context isolates the cost of the disabled-path
    machinery (global check, no-op context manager) that this module is
    contractually required to keep near zero.
    """

    def __enter__(self):
        g = globals()
        self._saved = (g["span"], g["instant"], g["maybe_block"])
        g["span"] = lambda name, **args: _NOOP
        g["instant"] = lambda name, **args: None
        g["maybe_block"] = lambda x: x
        return self

    def __exit__(self, *exc):
        g = globals()
        g["span"], g["instant"], g["maybe_block"] = self._saved
        return False


if os.environ.get(_ENV_FLAG, "") not in ("", "0"):
    enable()
