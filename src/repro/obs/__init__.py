"""repro.obs — tracing, metrics and export for the whole stack.

One substrate, three layers:

- :mod:`repro.obs.metrics` — the process-wide :data:`~repro.obs.metrics.REGISTRY`
  of counters / gauges / bounded histograms that the session, streaming,
  cluster and serve ``stats`` all feed (their dicts are unchanged;
  the registry aggregates the same numbers across instances).
- :mod:`repro.obs.trace` — nested wall-clock spans across method
  selection, sketch/QR, certification rungs, streaming tiles, cluster
  tasks and serve dispatch; opt-in via ``lstsq(..., trace=True)``,
  ``REPRO_TRACE=1`` or ``with obs.tracing():``, exported as
  Chrome-trace JSON and attached to ``SolveResult.timeline``.
- :mod:`repro.obs.export` — Prometheus text exposition, JSON snapshots
  and an optional ``jax.profiler`` hook.
"""
from .lockcheck import (
    LockOrderError,
    make_lock,
    make_rlock,
    lockcheck_enabled,
)
from .metrics import REGISTRY, MetricsRegistry, DEFAULT_BUCKETS
from .trace import (
    Timeline,
    Tracer,
    enabled,
    enable,
    disable,
    instant,
    maybe_block,
    span,
    tracing,
)
from .export import json_snapshot, prometheus_text, save_chrome_trace, jax_profile

__all__ = [
    "LockOrderError",
    "make_lock",
    "make_rlock",
    "lockcheck_enabled",
    "REGISTRY",
    "MetricsRegistry",
    "DEFAULT_BUCKETS",
    "Timeline",
    "Tracer",
    "enabled",
    "enable",
    "disable",
    "instant",
    "maybe_block",
    "span",
    "tracing",
    "json_snapshot",
    "prometheus_text",
    "save_chrome_trace",
    "jax_profile",
]
