"""Runtime lock-order watchdog (debug-only, ``REPRO_LOCKCHECK=1``).

reprolint's R1 proves acquisition order *statically*; this module
checks the same invariant *dynamically* for the paths static analysis
cannot see (callbacks, locks handed across objects).  Every lock built
through :func:`make_lock` / :func:`make_rlock` — the factories the
annotated classes use — becomes an :class:`OrderedLock` when the
watchdog is enabled, which:

- keeps a per-thread stack of held locks,
- records every ordered pair ``(outer.name, inner.name)`` into a
  process-global edge set, and
- raises :class:`LockOrderError` the moment a thread acquires ``A``
  while holding ``B`` when the reverse path ``A → … → B`` was already
  observed — the inversion is reported on the *second* ordering, with
  both witness stacks, before it can deadlock.

Rules of the game:

- re-entry on the same reentrant lock is ignored (legal);
- pairs of locks with the *same name* are never ordered against each
  other: two ``MicroBatcher._mu`` instances are indistinguishable by
  name and tenant-count is unbounded, so ordering them would flag
  legitimate per-instance locking;
- disabled (the default) the factories return plain
  ``threading.Lock()`` / ``RLock()`` — zero overhead in production.

Enablement is evaluated per factory call: tests flip
:func:`enable` / :func:`disable` (or set ``REPRO_LOCKCHECK=1`` before
building engines) without reimporting anything.
"""
from __future__ import annotations

import os
import threading

__all__ = [
    "LockOrderError",
    "OrderedLock",
    "enable",
    "disable",
    "enabled",
    "lockcheck_enabled",
    "make_lock",
    "make_rlock",
    "observed_edges",
    "reset_observations",
]


class LockOrderError(RuntimeError):
    """Two threads acquired the same pair of locks in opposite orders."""


_forced: bool | None = None
_edges: dict = {}  # name -> {name: witness str}
_edges_mu = threading.Lock()
_held = threading.local()


def enable() -> None:
    global _forced
    _forced = True


def disable() -> None:
    global _forced
    _forced = False


def enabled() -> bool:
    if _forced is not None:
        return _forced
    return os.environ.get("REPRO_LOCKCHECK", "") not in ("", "0", "false")


def reset_observations() -> None:
    with _edges_mu:
        _edges.clear()


def observed_edges() -> dict:
    with _edges_mu:
        return {a: dict(b) for a, b in _edges.items()}


def _stack() -> list:
    st = getattr(_held, "stack", None)
    if st is None:
        st = _held.stack = []
    return st


def _reachable(src: str, dst: str) -> list | None:
    """Path src → … → dst in the observed edge graph (caller holds
    ``_edges_mu``); None when unreachable."""
    seen, frontier = {src: None}, [src]
    while frontier:
        cur = frontier.pop()
        for nxt in _edges.get(cur, ()):
            if nxt in seen:
                continue
            seen[nxt] = cur
            if nxt == dst:
                path, at = [], dst
                while at is not None:
                    path.append(at)
                    at = seen[at]
                return path[::-1]
            frontier.append(nxt)
    return None


class OrderedLock:
    """A named lock that feeds the global acquisition-order graph."""

    def __init__(self, name: str, reentrant: bool):
        self.name = name
        self.reentrant = reentrant
        self._lock = threading.RLock() if reentrant else threading.Lock()

    # -- context manager / lock protocol ---------------------------------
    def acquire(self, blocking: bool = True, timeout: float = -1) -> bool:
        self._before_acquire()
        got = self._lock.acquire(blocking, timeout)
        if got:
            _stack().append(self)
        return got

    def release(self) -> None:
        st = _stack()
        for i in range(len(st) - 1, -1, -1):
            if st[i] is self:
                del st[i]
                break
        self._lock.release()

    def __enter__(self):
        self.acquire()
        return self

    def __exit__(self, *exc):
        self.release()
        return False

    # -- ordering --------------------------------------------------------
    def _before_acquire(self) -> None:
        st = _stack()
        if not st:
            return
        if self.reentrant and any(h is self for h in st):
            return  # legal re-entry; records no new ordering
        me = self.name
        holders = [h.name for h in st if h.name != me]
        if not holders:
            return
        tname = threading.current_thread().name
        with _edges_mu:
            for held_name in holders:
                inverted = _reachable(me, held_name)
                if inverted is not None:
                    order = " -> ".join(inverted)
                    raise LockOrderError(
                        f"lock-order inversion: thread '{tname}' acquires "
                        f"'{me}' while holding {holders}, but the order "
                        f"{order} was already observed "
                        f"({_edges.get(me, {}).get(inverted[1], '?')}); one "
                        "global order per lock pair, or this deadlocks "
                        "under contention"
                    )
            witness = f"thread '{tname}' held {holders} acquiring '{me}'"
            for h in holders:
                _edges.setdefault(h, {}).setdefault(me, witness)

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return f"OrderedLock({self.name!r}, reentrant={self.reentrant})"


# Alias for package-level re-export: ``repro.obs.enabled`` already means
# "is tracing on", so the watchdog's probe ships under a distinct name.
def lockcheck_enabled() -> bool:
    return enabled()


def make_lock(name: str = "lock"):
    """A plain mutex — or an order-checked one when the watchdog is on."""
    if enabled():
        return OrderedLock(name, reentrant=False)
    return threading.Lock()


def make_rlock(name: str = "rlock"):
    """A reentrant mutex — order-checked when the watchdog is on."""
    if enabled():
        return OrderedLock(name, reentrant=True)
    return threading.RLock()
