from .synthetic import SyntheticConfig, batch_at, make_batch_specs

__all__ = ["SyntheticConfig", "batch_at", "make_batch_specs"]
