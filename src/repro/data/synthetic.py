"""Deterministic, stateless-indexable synthetic data pipeline.

``batch_at(cfg, step)`` is a pure function of (seed, step) — no iterator
state — so exact resume after preemption is trivial (restore the step
counter and the stream continues bit-identically), and each data-parallel
shard can materialize only its slice via sharded device_put.

Two stream kinds:
  'uniform' — iid tokens (shape/perf work)
  'bigram'  — tokens follow a seed-derived random bigram chain: a learnable
              distribution with entropy well below ln(V), so training
              examples show real loss curves (H(next|prev) target).
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp


class SyntheticConfig(NamedTuple):
    vocab: int
    seq_len: int
    global_batch: int
    kind: str = "bigram"  # 'bigram' | 'uniform'
    seed: int = 0
    bigram_sharpness: float = 2.0


def _bigram_logits(cfg: SyntheticConfig):
    key = jax.random.key(cfg.seed + 1)
    V = min(cfg.vocab, 4096)  # chain lives in a V_eff-token sub-vocabulary
    return jax.random.normal(key, (V, V)) * cfg.bigram_sharpness, V


@partial(jax.jit, static_argnames=("cfg",))
def batch_at(cfg: SyntheticConfig, step):
    """Returns {'tokens': (B, S) int32, 'labels': (B, S) int32}."""
    B, S = cfg.global_batch, cfg.seq_len
    base = jax.random.fold_in(jax.random.key(cfg.seed), step)
    if cfg.kind == "uniform":
        toks = jax.random.randint(base, (B, S + 1), 0, cfg.vocab, jnp.int32)
    else:
        logits, V = _bigram_logits(cfg)
        k0, kseq = jax.random.split(base)
        first = jax.random.randint(k0, (B,), 0, V, jnp.int32)

        def gen(tok, k):
            nxt = jax.random.categorical(k, logits[tok])
            return nxt.astype(jnp.int32), nxt.astype(jnp.int32)

        keys = jax.random.split(kseq, S)
        _, rest = jax.lax.scan(lambda t, k: gen(t, k), first, keys)
        toks = jnp.concatenate([first[None], rest], axis=0).T  # (B, S+1)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def make_batch_specs(cfg: SyntheticConfig):
    shape = (cfg.global_batch, cfg.seq_len)
    return {
        "tokens": jax.ShapeDtypeStruct(shape, jnp.int32),
        "labels": jax.ShapeDtypeStruct(shape, jnp.int32),
    }
