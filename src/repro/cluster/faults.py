"""Deterministic fault injection for the cluster engine.

Real preemption is a race; tests need the same failure at the same point
every run.  A :class:`FaultPlan` is a list of trigger events keyed by
``(worker, phase, tile)`` — "kill worker 1 the moment it is about to
process its 3rd pass-1 tile" — that the worker loop consults before every
tile.  Each event fires at most once (``fired`` records what actually
triggered, so a test can assert its fault was exercised, not silently
skipped).

Events:

- :class:`KillWorker`     — raise :class:`WorkerKilled` inside the worker:
  the thread dies exactly like a preempted process (no cleanup, no final
  checkpoint, heartbeats stop).
- :class:`DelayWorker`    — sleep ``seconds`` before the tile: long enough
  and the coordinator's heartbeat monitor declares the worker dead while
  the thread still runs — the zombie double-completion path.
- :class:`DuplicateMerge` — after the worker finishes a sketch range, its
  partial accumulator is submitted to the coordinator TWICE; the
  coordinator's per-range dedup must drop the second copy.

``phase`` is ``"sketch"`` (pass 1) or ``"matvec"`` (pass-2 products);
``tile`` counts tiles THIS worker has started in that phase, from 0,
across resumes (a replacement worker gets a fresh count).
"""
from __future__ import annotations

import dataclasses
import time

from ..obs.lockcheck import make_lock

__all__ = [
    "WorkerKilled",
    "KillWorker",
    "DelayWorker",
    "DuplicateMerge",
    "FaultPlan",
    "as_plan",
]


class WorkerKilled(RuntimeError):
    """Injected preemption: the worker thread dies mid-pass."""


@dataclasses.dataclass(frozen=True)
class KillWorker:
    worker: int
    at_tile: int = 0
    phase: str = "sketch"


@dataclasses.dataclass(frozen=True)
class DelayWorker:
    worker: int
    seconds: float
    at_tile: int = 0
    phase: str = "sketch"


@dataclasses.dataclass(frozen=True)
class DuplicateMerge:
    worker: int


class FaultPlan:
    """An immutable event list with fire-once trigger bookkeeping."""

    # Checked by reprolint R1: ``fired`` is the check-then-append state
    # whose unguarded version was the PR 8 double-fire race.
    GUARDED_BY = {"fired": "_lock"}
    GUARDED_READS = frozenset({"fired"})

    def __init__(self, *events):
        self.events = tuple(events)
        self.fired: list = []
        self._lock = make_lock("FaultPlan._lock")  # every worker calls _take

    def __repr__(self):
        return f"FaultPlan({', '.join(map(repr, self.events))})"

    def _take(self, match) -> list:
        # check-then-append must be atomic: a fire-once event polled by
        # two worker threads at the same tile would otherwise fire twice
        with self._lock:
            out = []
            for ev in self.events:
                if ev in self.fired:
                    continue
                if match(ev):
                    self.fired.append(ev)
                    out.append(ev)
            return out

    def before_tile(self, worker: int, phase: str, tile: int) -> None:
        """Called by the worker loop before it starts a tile.  Applies
        delays first (a delayed worker can then be killed), then kills."""
        for ev in self._take(
            lambda e: isinstance(e, DelayWorker)
            and e.worker == worker and e.phase == phase and e.at_tile == tile
        ):
            time.sleep(ev.seconds)
        if self._take(
            lambda e: isinstance(e, KillWorker)
            and e.worker == worker and e.phase == phase and e.at_tile == tile
        ):
            raise WorkerKilled(
                f"injected kill: worker {worker} at {phase} tile {tile}"
            )

    def duplicate_submission(self, worker: int) -> bool:
        """True once per matching DuplicateMerge event: the worker should
        submit its finished partial a second time."""
        return bool(self._take(
            lambda e: isinstance(e, DuplicateMerge) and e.worker == worker
        ))


def as_plan(faults) -> FaultPlan:
    if faults is None:
        return FaultPlan()  # fresh: per-run fired bookkeeping
    if isinstance(faults, FaultPlan):
        return faults
    return FaultPlan(*faults)
