"""repro.cluster — multi-host, fault-tolerant out-of-core solving.

Four layers (see each module's docstring for the full contract):

- ``shard``       row-range partitioning, ownership, reassignment
- ``checkpoint``  mid-pass accumulator save/restore (bit-exact resume)
- ``faults``      deterministic kill/delay/duplicate injection
- ``coordinator`` the worker pool + recovery driver (``ClusterEngine``)

Entry points: build a :class:`ClusterSpec` and hand it to
``repro.lstsq(source, b, key, cluster=spec)`` or
``StreamingSolver(source, cluster=spec)``.
"""
from .checkpoint import (
    CheckpointMismatch,
    latest_watermark,
    op_digest,
    pass_namespace,
    restore_accumulator,
    save_accumulator,
)
from .coordinator import ClusterEngine, ClusterFailure, ClusterSpec
from .faults import (
    DelayWorker,
    DuplicateMerge,
    FaultPlan,
    KillWorker,
    WorkerKilled,
)
from .shard import (
    OwnershipMap,
    RowRange,
    RowRangeSource,
    partition_rows,
    split_range,
)

__all__ = [
    "ClusterSpec",
    "ClusterEngine",
    "ClusterFailure",
    "RowRange",
    "OwnershipMap",
    "RowRangeSource",
    "partition_rows",
    "split_range",
    "op_digest",
    "pass_namespace",
    "save_accumulator",
    "restore_accumulator",
    "latest_watermark",
    "CheckpointMismatch",
    "FaultPlan",
    "KillWorker",
    "DelayWorker",
    "DuplicateMerge",
    "WorkerKilled",
]
