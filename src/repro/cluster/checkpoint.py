"""Mid-pass accumulator checkpoints: preemption loses a few tiles, not a pass.

A :class:`~repro.streaming.accumulate.SketchAccumulator` is a pure fold
over row tiles, so its full recovery state is tiny and exact:

- the per-kind partial-state array (the (d, ncols) additive state, the
  (k, d, ncols) sparse-sign per-pass partials, or SRHT's host-side
  (m_pad, ncols) D-signed placement buffer),
- the ``rows_seen`` / ``tiles_seen`` counters,
- the **watermark** — the global row offset the stream has covered up to
  (checkpoints are cut on tile boundaries, so the watermark is always a
  tile edge and resuming re-reads nothing),
- a digest of the operator draw, so a checkpoint can never be restored
  against a different S (same defence ``SketchAccumulator.merge`` runs,
  amortized into one blake2b at save time).

Writes go through ``repro.train.checkpoint.save`` — the atomic
tmp-then-rename layout with a manifest — under
``<ckpt_dir>/<phase>/range_<start>_<stop>/step_<watermark>``, keyed by the
row RANGE, not the worker: ranges are the unit of reassignment, so a
replacement worker restores a dead worker's checkpoint by range alone.

Resume is bit-exact against the uninterrupted stream for every kind:
``np.savez`` round-trips float64/int32 arrays bitwise, and continuing the
fold from a bitwise-equal partial state over the identical remaining tile
sequence performs the identical arithmetic.  (The dense kinds' caveat vs
the MONOLITHIC apply — blockwise gemm accumulation order — is unchanged;
resume does not add to it.)
"""
from __future__ import annotations

import hashlib
import os

import jax
import jax.numpy as jnp
import numpy as np

from ..streaming.accumulate import SketchAccumulator, make_accumulator
from ..train import checkpoint as ckpt_lib

__all__ = [
    "op_digest",
    "pass_namespace",
    "save_accumulator",
    "restore_accumulator",
    "latest_watermark",
    "CheckpointMismatch",
]


class CheckpointMismatch(ValueError):
    """Checkpoint belongs to a different operator draw / stream layout."""


def op_digest(op) -> bytes:
    """Content digest of an operator DRAW (not just its shape).

    Hashes the pytree structure plus every leaf's bytes — PRNG key leaves
    via ``key_data`` (typed key arrays have no buffer protocol).  Two
    operators digest equal iff they are the same draw, which is exactly
    the merge-safety predicate.
    """
    leaves, treedef = jax.tree_util.tree_flatten(op)
    h = hashlib.blake2b(digest_size=16)
    h.update(str(treedef).encode())
    for leaf in leaves:
        if isinstance(leaf, jax.Array) and jnp.issubdtype(
            leaf.dtype, jax.dtypes.prng_key
        ):
            leaf = jax.random.key_data(leaf)
        arr = np.asarray(leaf)
        h.update(str((arr.shape, arr.dtype.str)).encode())
        h.update(arr.tobytes())
    return h.digest()


def pass_namespace(op, rhs=None) -> str:
    """Checkpoint namespace (a ``phase`` directory name) for ONE pass-1
    sketch: a digest of the operator draw plus the rhs riding along.

    A different draw — or the same draw over a different right-hand side
    — lands in a different namespace, so leftovers from an earlier run in
    a persistent ``ckpt_dir`` restore ``None`` (fresh start) instead of
    raising :class:`CheckpointMismatch` (wrong draw) or, worse, silently
    resuming a partial that folded in someone else's rhs column.
    """
    h = hashlib.blake2b(op_digest(op), digest_size=8)
    if rhs is not None:
        arr = np.asarray(rhs)
        h.update(str((arr.shape, arr.dtype.str)).encode())
        h.update(arr.tobytes())
    return f"pass1-{h.hexdigest()}"


def _range_dir(ckpt_dir: str, start: int, stop: int, phase: str = "pass1") -> str:
    return os.path.join(ckpt_dir, phase, f"range_{start}_{stop}")


def save_accumulator(
    ckpt_dir: str,
    acc: SketchAccumulator,
    watermark: int,
    *,
    range_start: int,
    range_stop: int,
    phase: str = "pass1",
) -> str:
    """Atomic checkpoint of a partial accumulator at a tile boundary.

    ``watermark`` is the exclusive global row offset covered so far; it
    doubles as the checkpoint step, so ``latest_step`` naturally returns
    the furthest-progressed checkpoint of the range.
    """
    tree = {
        "state": np.asarray(acc.state),
        "rows_seen": np.int64(acc.rows_seen),
        "tiles_seen": np.int64(acc.tiles_seen),
        "watermark": np.int64(watermark),
        "range": np.asarray([range_start, range_stop], np.int64),
        "op_digest": np.frombuffer(op_digest(acc.op), np.uint8),
    }
    return ckpt_lib.save(
        _range_dir(ckpt_dir, range_start, range_stop, phase), int(watermark), tree
    )


def latest_watermark(
    ckpt_dir: str, range_start: int, range_stop: int, *, phase: str = "pass1"
) -> int | None:
    """Watermark of the newest checkpoint for the range, or None."""
    return ckpt_lib.latest_step(_range_dir(ckpt_dir, range_start, range_stop, phase))


def restore_accumulator(
    ckpt_dir: str,
    op,
    ncols: int,
    *,
    range_start: int,
    range_stop: int,
    phase: str = "pass1",
    dtype=jnp.float64,
    backend: str = "auto",
) -> tuple[SketchAccumulator, int] | None:
    """(accumulator, watermark) from the range's newest checkpoint, or
    ``None`` when the range has never checkpointed (start from scratch).

    Raises :class:`CheckpointMismatch` when the stored operator digest or
    state shape disagrees with the live draw — restoring someone else's
    partial sketch silently poisons the merge, so it is never best-effort.
    """
    rdir = _range_dir(ckpt_dir, range_start, range_stop, phase)
    if ckpt_lib.latest_step(rdir) is None:
        return None
    fresh = make_accumulator(op, ncols, dtype=dtype, backend=backend)
    template = np.asarray(fresh.state)
    target = {
        "state": jax.ShapeDtypeStruct(template.shape, template.dtype),
        "rows_seen": jax.ShapeDtypeStruct((), np.int64),
        "tiles_seen": jax.ShapeDtypeStruct((), np.int64),
        "watermark": jax.ShapeDtypeStruct((), np.int64),
        "range": jax.ShapeDtypeStruct((2,), np.int64),
        "op_digest": jax.ShapeDtypeStruct((16,), np.uint8),
    }
    try:
        tree, step = ckpt_lib.restore(rdir, target)
    except ValueError as e:
        raise CheckpointMismatch(
            f"checkpoint for range [{range_start}, {range_stop}) does not "
            f"match the live accumulator: {e}"
        ) from e
    stored = bytes(np.asarray(tree["op_digest"]))
    live = op_digest(op)
    if stored != live:
        raise CheckpointMismatch(
            f"checkpoint for range [{range_start}, {range_stop}) was written "
            "by a different operator draw — refusing to resume into it"
        )
    if tuple(int(v) for v in np.asarray(tree["range"])) != (range_start, range_stop):
        raise CheckpointMismatch(
            f"checkpoint range metadata {np.asarray(tree['range'])} does not "
            f"match [{range_start}, {range_stop})"
        )
    if isinstance(fresh.state, np.ndarray):
        # SRHT keeps a host-side placement buffer updated in place — the
        # restored state must be a WRITABLE numpy array, not a jax one.
        fresh.state = np.array(tree["state"])
    else:
        fresh.state = jnp.asarray(tree["state"])
    fresh.rows_seen = int(tree["rows_seen"])
    fresh.tiles_seen = int(tree["tiles_seen"])
    return fresh, int(tree["watermark"])
