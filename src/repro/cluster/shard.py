"""Row-range sharding: who owns which rows of a streamed A.

The cluster engine partitions the row space of a :class:`RowSource` into
contiguous, tile-aligned ranges — one per worker — and tracks ownership
in an :class:`OwnershipMap` that survives worker loss: when a worker
dies, its *unfinished* sub-range is reassigned to a live worker without
touching any range another worker already owns.

Tile alignment is the load-bearing invariant: every range boundary sits
on the parent source's global tile grid, so the sequence of ``(offset,
tile)`` updates a range produces is IDENTICAL no matter which worker
processes it, how the worker set changes mid-pass, or whether the range
is resumed from a checkpoint watermark.  That is what makes kill-and-
resume bit-reproducible for the scatter-kind accumulators.

The balancing arithmetic follows ``repro.train.elastic.
rebalance_microbatch``: hold the global work (tile count) fixed and
redistribute the per-worker share when the worker set changes —
``split_range`` is the same divide-evenly-with-remainder computation on
tiles instead of microbatches.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..streaming.sources import RowSource, as_source

__all__ = [
    "RowRange",
    "OwnershipMap",
    "RowRangeSource",
    "partition_rows",
    "split_range",
]


@dataclasses.dataclass(frozen=True, order=True)
class RowRange:
    """Half-open global row interval [start, stop)."""

    start: int
    stop: int

    def __post_init__(self):
        if not (0 <= self.start <= self.stop):
            raise ValueError(f"bad row range [{self.start}, {self.stop})")

    @property
    def rows(self) -> int:
        return self.stop - self.start

    def tiles(self, tile_rows: int) -> int:
        """Number of global-grid tiles intersecting this range."""
        if self.rows == 0:
            return 0
        first = self.start // tile_rows
        last = (self.stop - 1) // tile_rows
        return last - first + 1

    def __repr__(self):
        return f"[{self.start}:{self.stop})"


def _grid_boundaries(m: int, tile_rows: int) -> list[int]:
    bounds = list(range(0, m, tile_rows))
    bounds.append(m)
    return bounds


def partition_rows(m: int, num_workers: int, tile_rows: int) -> list[RowRange]:
    """Deterministic initial ownership: ``num_workers`` contiguous,
    tile-aligned ranges with tile counts as equal as possible (the first
    ``n_tiles % num_workers`` workers carry one extra tile).

    Workers beyond the tile count get empty ranges — a 16-worker spec on
    a 4-tile problem is legal, 12 workers just idle.
    """
    if num_workers < 1:
        raise ValueError(f"need >= 1 worker, got {num_workers}")
    bounds = _grid_boundaries(m, tile_rows)
    n_tiles = len(bounds) - 1
    base, extra = divmod(n_tiles, num_workers)
    ranges = []
    t = 0
    for w in range(num_workers):
        take = base + (1 if w < extra else 0)
        ranges.append(RowRange(bounds[t], bounds[t + take]))
        t += take
    return ranges


def split_range(rng: RowRange, ways: int, tile_rows: int) -> list[RowRange]:
    """Split a range into ≤ ``ways`` tile-aligned sub-ranges of near-equal
    tile count (empty tails are dropped) — the reassignment arithmetic
    when a dead worker's remainder is spread over the survivors."""
    if ways < 1:
        raise ValueError(f"need >= 1 way, got {ways}")
    if rng.rows == 0:
        return []
    # boundaries of the global grid restricted to [start, stop)
    first_edge = -(-rng.start // tile_rows) * tile_rows
    bounds = [rng.start]
    bounds += [e for e in range(first_edge, rng.stop, tile_rows) if e > rng.start]
    bounds.append(rng.stop)
    n_tiles = len(bounds) - 1
    ways = min(ways, n_tiles)
    base, extra = divmod(n_tiles, ways)
    out, t = [], 0
    for w in range(ways):
        take = base + (1 if w < extra else 0)
        out.append(RowRange(bounds[t], bounds[t + take]))
        t += take
    return out


@dataclasses.dataclass
class OwnershipMap:
    """Mutable worker → row-range assignment for one pass.

    ``assignments`` maps worker id → list of ranges it must still
    complete; ``completed`` collects (range, accumulator-or-result) pairs
    as they finish.  ``reassign`` moves a dead worker's unfinished ranges
    (optionally truncated at a checkpoint watermark) onto the live
    workers with the least remaining work — deterministically, so two
    coordinators replaying the same failure make the same decision.
    """

    m: int
    tile_rows: int
    assignments: dict[int, list[RowRange]]

    @classmethod
    def initial(cls, m: int, workers, tile_rows: int) -> "OwnershipMap":
        workers = list(workers)
        ranges = partition_rows(m, len(workers), tile_rows)
        return cls(
            m=m,
            tile_rows=tile_rows,
            assignments={w: [r] for w, r in zip(workers, ranges)},
        )

    def owner_of(self, rng: RowRange) -> int | None:
        for w, rs in self.assignments.items():
            if rng in rs:
                return w
        return None

    def remaining_tiles(self, worker: int) -> int:
        return sum(r.tiles(self.tile_rows) for r in self.assignments.get(worker, ()))

    def reassign(self, dead: int, live: list[int]) -> list[tuple[int, RowRange]]:
        """Move every range still assigned to ``dead`` onto ``live``
        workers (least-loaded first, ties by worker id).  Returns the
        (new_owner, range) moves; the ranges themselves are unchanged —
        resume watermarks are the coordinator's business."""
        if not live:
            raise RuntimeError("no live workers left to reassign to")
        moves = []
        for rng in self.assignments.pop(dead, []):
            tgt = min(live, key=lambda w: (self.remaining_tiles(w), w))
            self.assignments.setdefault(tgt, []).append(rng)
            moves.append((tgt, rng))
        return moves


class RowRangeSource(RowSource):
    """A contiguous row window [start, stop) of a parent source, tiled on
    the PARENT's global tile grid.

    Local offsets are relative to ``start`` (the ``ShardedSource`` idiom);
    accumulate with ``base_offset=start`` to land in the global row space.
    Random-access parents (``read_rows``) are read window-by-window — a
    worker touches only its own rows; sequential parents fall back to
    filtering the parent stream (correct, but the parent is re-streamed).
    """

    def __init__(self, parent, start: int, stop: int,
                 tile_rows: int | None = None):
        parent = as_source(parent)
        m, n = parent.shape
        if not (0 <= start <= stop <= m):
            raise ValueError(
                f"range [{start}, {stop}) outside the parent's [0, {m})"
            )
        self.parent = parent
        self.start = int(start)
        self.stop = int(stop)
        self.shape = (self.stop - self.start, n)
        self.dtype = parent.dtype
        self._tile_rows = int(tile_rows or parent.tile_rows)

    @property
    def tile_rows(self) -> int:
        return self._tile_rows

    @property
    def num_tiles(self) -> int:
        return RowRange(self.start, self.stop).tiles(self._tile_rows)

    def _windows(self):
        """Global (offset, length) windows on the parent tile grid."""
        o = self.start
        while o < self.stop:
            edge = (o // self._tile_rows + 1) * self._tile_rows
            hi = min(edge, self.stop)
            yield o, hi - o
            o = hi

    def tiles(self):
        if self.parent.supports_random_access:
            for o, t in self._windows():
                yield o - self.start, self.parent.read_rows(o, t)
            return
        # sequential parent: stream it once, slice the overlap — tile
        # boundaries still follow the parent grid because the parent
        # emits grid-aligned tiles and we only ever clip at start/stop
        for o, tile in self.parent.tiles():
            lo = max(o, self.start)
            hi = min(o + np.asarray(tile).shape[0], self.stop)
            if lo < hi:
                yield lo - self.start, tile[lo - o : hi - o]

    def read_rows(self, offset: int, length: int):
        if not self.parent.supports_random_access:
            raise TypeError(
                f"{type(self.parent).__name__} does not support random access"
            )
        if offset < 0 or offset + length > self.shape[0]:
            raise ValueError(
                f"rows [{offset}, {offset + length}) outside [0, {self.shape[0]})"
            )
        return self.parent.read_rows(self.start + offset, length)
