"""Multi-worker, fault-tolerant driver for the two-pass streaming solve.

The :class:`ClusterEngine` owns a pool of workers (threads standing in
for hosts — the state logistics, not the transport, are what this module
implements; see ``repro.train.elastic`` for the same stance on training)
and fans the streaming engine's two passes out across them:

- **pass 1** (``cluster_sketch``): each worker streams its tile-aligned
  row range into its own mergeable
  :class:`~repro.streaming.accumulate.SketchAccumulator`, checkpointing
  the partial state every ``checkpoint_every`` tiles
  (``repro.cluster.checkpoint``).  The coordinator merges the per-range
  partials associatively (``merge_all`` — the same reduction
  ``sharded_sketch`` runs as a psum) in deterministic range order.
- **pass 2** (``matvec`` / ``rmatvec`` / ``residual_grad``): the blocked
  products of the iteration are computed per-range and placed/summed in
  range order — stateless, so a failed range is simply recomputed.

Fault tolerance is first-class, not a retry loop:

- every worker heartbeats per tile; the coordinator's monitor declares a
  worker dead when its beat goes stale (``heartbeat_timeout``) or its
  thread dies (:class:`~repro.cluster.faults.WorkerKilled`),
- a dead worker's unfinished ranges are REASSIGNED to the live worker
  with the least remaining work (``OwnershipMap.reassign`` — the
  ``rebalance_microbatch`` arithmetic on tiles), respawning a fresh
  worker only when nobody is left,
- a reassigned sketch range resumes from its last accumulator
  checkpoint: only the tiles since the watermark are re-streamed, and
  the resumed partial is bit-equal to an uninterrupted one,
- late results from workers that were *declared* dead but are still
  running (network-partition zombies), and deliberate double
  submissions, are dropped by per-range dedup before the merge
  (``duplicates_dropped`` in ``stats``).

The engine quacks like a :class:`~repro.streaming.sources.RowSource`
(shape/dtype/tiles), and the streaming drivers probe for its
``cluster_sketch`` / ``matvec`` / ``rmatvec`` / ``residual_grad``
methods — so ``stream_lstsq(..., cluster=ClusterSpec(...))``,
``StreamingSolver(..., cluster=...)`` and ``lstsq(source, b, key,
cluster=...)`` all run their streams through the pool unchanged.
"""
from __future__ import annotations

import dataclasses
import os
import queue
import shutil
import tempfile
import threading
import time

import jax.numpy as jnp

from ..obs import trace as obs_trace
from ..obs.lockcheck import make_lock
from ..obs.metrics import REGISTRY
from ..streaming.accumulate import make_accumulator, merge_all
from ..streaming.sources import RowSource, as_source
from . import checkpoint as cckpt
from .faults import WorkerKilled, as_plan
from .shard import OwnershipMap, RowRange, RowRangeSource, partition_rows

__all__ = ["ClusterSpec", "ClusterEngine", "ClusterFailure"]


class ClusterFailure(RuntimeError):
    """The pass cannot complete: recovery budget exhausted."""


@dataclasses.dataclass(frozen=True)
class ClusterSpec:
    """Configuration of a cluster run (pass through ``lstsq(cluster=...)``).

    ``num_workers``        worker pool size (≥ 1; 1 degenerates to the
                           single-stream engine plus checkpoints).
    ``tile_rows``          global tile grid (None → the source's tiling).
    ``checkpoint_every``   tiles between mid-range accumulator
                           checkpoints (0/None disables — a killed range
                           then restarts from its first row).
    ``ckpt_dir``           checkpoint root (None → a fresh temp dir per
                           engine, removed again by ``close()``).
    ``heartbeat_timeout``  seconds without a worker heartbeat before the
                           monitor declares it dead.  Staleness is
                           measured from the later of the worker's last
                           beat and the task's dispatch time, so an idle
                           pool between passes never goes stale.
    ``poll_interval``      monitor poll cadence in seconds.
    ``max_recoveries``     worker deaths tolerated per PASS (each
                           fan-out) before :class:`ClusterFailure`;
                           ``stats["recoveries"]`` still counts engine
                           lifetime totals.
    ``faults``             a :class:`~repro.cluster.faults.FaultPlan` (or
                           event list) injected into the worker loops.
    """

    num_workers: int = 2
    tile_rows: int | None = None
    checkpoint_every: int | None = 1
    ckpt_dir: str | None = None
    heartbeat_timeout: float = 10.0
    poll_interval: float = 0.01
    max_recoveries: int = 4
    faults: object = None

    def __post_init__(self):
        if self.num_workers < 1:
            raise ValueError(f"need >= 1 worker, got {self.num_workers}")


_STOP = object()


class _Task:
    __slots__ = ("rng", "fn", "epoch", "status", "result", "error", "done",
                 "dispatched_at")

    def __init__(self, rng: RowRange, fn, epoch: int = 0):
        self.rng = rng
        self.fn = fn
        self.epoch = epoch
        self.status = "pending"
        self.result = None
        self.error = None
        self.done = threading.Event()
        self.dispatched_at = time.monotonic()  # re-stamped on submit


class _Worker:
    """One pool member: a thread draining an inbox of range tasks.

    A :class:`WorkerKilled` raised inside a task kills the THREAD — no
    cleanup, no further tasks, heartbeats stop — which is the preemption
    model the coordinator must recover from.
    """

    def __init__(self, wid: int):
        self.id = wid
        self.inbox: queue.Queue = queue.Queue()
        self.last_beat = time.monotonic()
        self.tasks: list[_Task] = []  # unfinished tasks queued to me
        self.thread = threading.Thread(
            target=self._loop, name=f"repro-cluster-w{wid}", daemon=True
        )
        self.thread.start()

    def beat(self):
        self.last_beat = time.monotonic()

    @property
    def thread_alive(self) -> bool:
        return self.thread.is_alive()

    def submit(self, task: _Task):
        task.dispatched_at = time.monotonic()
        self.tasks.append(task)
        self.inbox.put(task)

    def stop(self):
        self.inbox.put(_STOP)

    def _loop(self):
        while True:
            task = self.inbox.get()
            if task is _STOP:
                return
            if task.status == "abandoned":
                task.done.set()
                continue
            self.beat()
            try:
                task.result = task.fn(self)
                task.status = "done"
            except WorkerKilled as e:
                task.error = e
                task.status = "killed"
                task.done.set()
                return  # the whole worker dies, inbox abandoned
            except Exception as e:  # real bug: surfaced by the monitor
                task.error = e
                task.status = "error"
            task.done.set()


class ClusterEngine(RowSource):
    """Coordinator + worker pool over one row source (see module doc).

    Subclasses :class:`RowSource`, so an engine drops in anywhere a
    source does (``as_source`` passes it through unchanged) — the
    streaming drivers then discover its distributed ``cluster_sketch`` /
    ``matvec`` / ``rmatvec`` / ``residual_grad`` methods by probing.
    """

    # Checked by reprolint R1.  Worker threads and the coordinator both
    # write these; everything else (_workers, _dead, _next_id,
    # _pass_recoveries, _closed) is coordinator-thread-private by
    # construction and deliberately unlisted.
    GUARDED_BY = {
        "stats": "_lock",
        "_tile_counts": "_lock",
        "_submissions": "_lock",
        "_sketch_seq": "_lock",
    }

    def __init__(self, source, spec: ClusterSpec | None = None, *,
                 backend: str = "auto", counters: dict | None = None):
        self.source = as_source(source)
        self.spec = spec or ClusterSpec()
        self.shape = self.source.shape
        self.dtype = self.source.dtype
        self.backend = backend
        self.counters = counters  # optional external pass/tile counters
        self._grid = int(self.spec.tile_rows or self.source.tile_rows)
        self._plan = as_plan(self.spec.faults)
        self._owns_ckpt_dir = self.spec.ckpt_dir is None
        self._ckpt_dir = self.spec.ckpt_dir or tempfile.mkdtemp(
            prefix="repro-cluster-"
        )
        self._closed = False
        self._pass_recoveries = 0  # reset by every _execute fan-out
        self._workers: dict[int, _Worker] = {
            w: _Worker(w) for w in range(self.spec.num_workers)
        }
        self._dead: set[int] = set()
        self._next_id = self.spec.num_workers
        self._lock = make_lock("ClusterEngine._lock")  # counters + submissions
        self._ckpt_lock = make_lock("ClusterEngine._ckpt_lock")  # ckpt writes
        self._tile_counts: dict[tuple[int, str], int] = {}
        self._submissions: list = []
        self._sketch_seq = 0  # guards against zombie submissions from a
        # previous pass leaking into a later one
        self.stats = REGISTRY.stats_dict("cluster", {
            "workers": self.spec.num_workers,
            "recoveries": 0,
            "reassignments": 0,
            "respawns": 0,
            "restores": 0,
            "checkpoints": 0,
            "duplicates_dropped": 0,
            "heartbeat_evictions": 0,
            "passes": 0,
            "tiles": 0,
        })

    # ------------------------------------------------------- RowSource face
    @property
    def tile_rows(self) -> int:
        return self._grid

    @property
    def num_tiles(self) -> int:
        return -(-self.shape[0] // self._grid)

    def tiles(self):
        # serial fallback so the engine drops in anywhere a source does
        yield from self.source.tiles()

    @property
    def supports_random_access(self) -> bool:
        return self.source.supports_random_access

    def read_rows(self, offset, length):
        return self.source.read_rows(offset, length)

    @property
    def ckpt_dir(self) -> str:
        return self._ckpt_dir

    def close(self):
        """Stop the pool; idempotent.  A temp checkpoint dir the engine
        created for itself is removed with it (a caller-provided
        ``spec.ckpt_dir`` is left untouched)."""
        if self._closed:
            return
        self._closed = True
        for w in self._workers.values():
            w.stop()
        for w in self._workers.values():
            # bounded join: healthy workers exit on _STOP instantly;
            # an injected zombie may still be sleeping — don't hang on it
            w.thread.join(timeout=0.5)
        if self._owns_ckpt_dir:
            shutil.rmtree(self._ckpt_dir, ignore_errors=True)

    # ------------------------------------------------------------ plumbing
    def _live_ids(self) -> list[int]:
        return [
            w for w, wk in self._workers.items()
            if w not in self._dead and wk.thread_alive
        ]

    def _fault_gate(self, worker: _Worker, phase: str):
        worker.beat()  # starting a tile is life, even if it computes long
        with self._lock:
            k = (worker.id, phase)
            tile = self._tile_counts.get(k, 0)
            self._tile_counts[k] = tile + 1
        self._plan.before_tile(worker.id, phase, tile)

    def _count_tiles(self, k: int = 1):
        with self._lock:
            self.stats["tiles"] += k
            if self.counters is not None:
                self.counters["tiles"] += k

    def _count_pass(self):
        with self._lock:
            self.stats["passes"] += 1
            if self.counters is not None:
                self.counters["passes"] += 1

    def _recover(self, ownership: OwnershipMap, victim: int, make_fn,
                 pending: dict):
        """Declare ``victim`` dead and reassign its unfinished ranges."""
        obs_trace.instant("cluster.recover", victim=victim)
        with self._lock:
            self.stats["recoveries"] += 1
        self._pass_recoveries += 1
        if self._pass_recoveries > self.spec.max_recoveries:
            raise ClusterFailure(
                f"recovery budget exhausted ({self.spec.max_recoveries} "
                f"per pass); last casualty: worker {victim}"
            )
        self._dead.add(victim)
        wk = self._workers[victim]
        for t in wk.tasks:
            if not t.done.is_set():
                t.status = "abandoned"
        live = self._live_ids()
        if not live:
            nid = self._next_id
            self._next_id += 1
            self._workers[nid] = _Worker(nid)
            obs_trace.instant("cluster.respawn", worker=nid)
            with self._lock:
                self.stats["respawns"] += 1
            live = [nid]
            ownership.assignments.setdefault(nid, [])
        moves = ownership.reassign(victim, live)
        for tgt, rng in moves:
            obs_trace.instant(
                "cluster.reassign", range=(rng.start, rng.stop), to=tgt
            )
            with self._lock:
                self.stats["reassignments"] += 1
            task = _Task(rng, make_fn(rng), epoch=pending[rng].epoch + 1)
            pending[rng] = task
            self._workers[tgt].submit(task)

    def _execute(self, ranges: list[RowRange], make_fn) -> dict:
        """Run ``make_fn(rng)(worker)`` for every range on the pool with
        heartbeat monitoring and kill/timeout recovery.  Returns
        {range: result} once every range has completed somewhere."""
        if self._closed:
            raise ClusterFailure("engine is closed")
        self._pass_recoveries = 0
        live = self._live_ids()
        if not live:
            raise ClusterFailure("no live workers")
        ownership = OwnershipMap(
            m=self.shape[0], tile_rows=self._grid,
            assignments={w: [] for w in live},
        )
        pending: dict[RowRange, _Task] = {}
        for i, rng in enumerate(ranges):
            w = live[i % len(live)]
            ownership.assignments[w].append(rng)
            task = _Task(rng, make_fn(rng))
            pending[rng] = task
            self._workers[w].submit(task)
        results: dict[RowRange, object] = {}
        while any(rng not in results for rng in ranges):
            progressed = False
            for rng in ranges:
                if rng in results:
                    continue
                task = pending[rng]
                owner = ownership.owner_of(rng)
                if task.done.is_set() and task.status == "done":
                    results[rng] = task.result
                    if owner is not None:
                        self._workers[owner].tasks = [
                            t for t in self._workers[owner].tasks if t is not task
                        ]
                        ownership.assignments[owner].remove(rng)
                    progressed = True
                elif task.done.is_set() and task.status == "killed":
                    self._recover(ownership, owner, make_fn, pending)
                    progressed = True
                elif task.done.is_set() and task.status == "error":
                    raise task.error
                elif owner is not None:
                    wk = self._workers[owner]
                    # staleness from the later of the worker's last beat
                    # and this task's dispatch: a pool that sat idle
                    # between passes (or a queued task behind a long
                    # tile) is not dead, it just hasn't started yet
                    alive_ref = max(wk.last_beat, task.dispatched_at)
                    stale = (
                        time.monotonic() - alive_ref
                        > self.spec.heartbeat_timeout
                    )
                    if stale or not wk.thread_alive:
                        if stale and wk.thread_alive:
                            obs_trace.instant(
                                "cluster.eviction", worker=owner,
                                stale_s=time.monotonic() - alive_ref,
                            )
                            with self._lock:
                                self.stats["heartbeat_evictions"] += 1
                        self._recover(ownership, owner, make_fn, pending)
                        progressed = True
            if not progressed:
                time.sleep(self.spec.poll_interval)
        return results

    # -------------------------------------------------------------- pass 1
    def cluster_sketch(self, op, *, rhs=None, backend: str = "auto"):
        """Fan pass-1 sketching out over the pool → the finalized (s,
        ncols) sketch of [A | rhs].  The per-range partial accumulators
        are checkpointed mid-range, restored on reassignment, deduped,
        then merged associatively in range order."""
        m, n = self.shape
        ncols = n + (1 if rhs is not None else 0)
        dtype = jnp.dtype(self.dtype)
        ckpt_every = self.spec.checkpoint_every or 0
        # checkpoints are namespaced by (operator draw, rhs): leftovers in
        # a persistent ckpt_dir from a DIFFERENT draw or rhs restore None
        # (fresh start) instead of failing — or silently poisoning — the
        # new pass
        ns = cckpt.pass_namespace(op, rhs)
        self._count_pass()
        with self._lock:
            self._submissions = []
            self._sketch_seq += 1
            seq = self._sketch_seq

        def submit(rng, acc, wid):
            with self._lock:
                if self._sketch_seq == seq:
                    self._submissions.append((rng, acc, wid))

        def make_fn(rng):
            def fn(worker: _Worker):
                with obs_trace.span(
                    "cluster.task", phase="sketch", worker=worker.id,
                    start=rng.start, stop=rng.stop,
                ):
                    acc, wm = None, rng.start
                    if ckpt_every:
                        got = cckpt.restore_accumulator(
                            self._ckpt_dir, op, ncols,
                            range_start=rng.start, range_stop=rng.stop,
                            phase=ns, dtype=dtype, backend=backend,
                        )
                        if got is not None:
                            acc, wm = got
                            obs_trace.instant(
                                "cluster.restore", worker=worker.id,
                                watermark=wm, start=rng.start, stop=rng.stop,
                            )
                            with self._lock:
                                self.stats["restores"] += 1
                    if acc is None:
                        acc = make_accumulator(op, ncols, dtype=dtype,
                                               backend=backend)
                    sub = RowRangeSource(self.source, wm, rng.stop,
                                         tile_rows=self._grid)
                    since = 0
                    for local_o, tile in sub.tiles():
                        self._fault_gate(worker, "sketch")
                        gl = wm + local_o
                        tile = jnp.asarray(tile)
                        t = tile.shape[0]
                        if rhs is not None:
                            tile = jnp.concatenate(
                                [tile,
                                 rhs[gl : gl + t][:, None].astype(tile.dtype)],
                                axis=1,
                            )
                        acc.update(tile, gl)
                        worker.beat()
                        obs_trace.instant(
                            "cluster.heartbeat", worker=worker.id, row=gl
                        )
                        self._count_tiles()
                        since += 1
                        if (
                            ckpt_every and since >= ckpt_every
                            and gl + t < rng.stop
                        ):
                            with self._ckpt_lock:
                                cckpt.save_accumulator(
                                    self._ckpt_dir, acc, gl + t,
                                    range_start=rng.start,
                                    range_stop=rng.stop,
                                    phase=ns,
                                )
                            obs_trace.instant(
                                "cluster.checkpoint", worker=worker.id,
                                watermark=gl + t,
                            )
                            with self._lock:
                                self.stats["checkpoints"] += 1
                            since = 0
                    submit(rng, acc, worker.id)
                    if self._plan.duplicate_submission(worker.id):
                        submit(rng, acc, worker.id)  # the dedup guard's moment
                    return True
            return fn

        with obs_trace.span(
            "cluster.pass1", rows=m, workers=len(self._live_ids())
        ):
            ranges = self._partition()
            self._execute(ranges, make_fn)
            chosen: dict[RowRange, object] = {}
            with self._lock:
                submissions = list(self._submissions)
            for rng, acc, _wid in submissions:
                if rng in chosen:
                    with self._lock:
                        self.stats["duplicates_dropped"] += 1
                    continue
                chosen[rng] = acc
            covered = 0
            for rng in sorted(chosen):
                if rng.start != covered:
                    raise ClusterFailure(
                        f"pass-1 coverage gap at row {covered} "
                        f"(next range {rng})"
                    )
                covered = rng.stop
            if covered != m:
                raise ClusterFailure(f"pass-1 covered {covered} of {m} rows")
            with obs_trace.span("cluster.merge", ranges=len(chosen)):
                merged = merge_all([chosen[rng] for rng in sorted(chosen)])
                out = merged.finalize()
                obs_trace.maybe_block(out)
        # the pass succeeded: its mid-range checkpoints are spent — clear
        # them so a persistent ckpt_dir doesn't grow without bound
        if ckpt_every:
            shutil.rmtree(os.path.join(self._ckpt_dir, ns),
                          ignore_errors=True)
        return out

    def _partition(self) -> list[RowRange]:
        live = self._live_ids()
        if not live:
            raise ClusterFailure("no live workers")
        ranges = partition_rows(self.shape[0], len(live), self._grid)
        return [r for r in ranges if r.rows > 0]

    # -------------------------------------------------------------- pass 2
    def _map_ranges(self, per_range_fn, phase: str = "map"):
        """Fan a stateless per-range computation out and return the
        results in ascending range order (deterministic reduction)."""
        self._count_pass()

        def make_fn(rng):
            def fn(worker: _Worker):
                with obs_trace.span(
                    "cluster.task", phase=phase, worker=worker.id,
                    start=rng.start, stop=rng.stop,
                ):
                    sub = RowRangeSource(self.source, rng.start, rng.stop,
                                         tile_rows=self._grid)
                    return per_range_fn(rng, sub, worker)
            return fn

        with obs_trace.span(
            "cluster.pass2", phase=phase, workers=len(self._live_ids())
        ):
            ranges = self._partition()
            results = self._execute(ranges, make_fn)
            return [results[rng] for rng in sorted(ranges)]

    def matvec(self, x):
        """A @ x by per-range placement (exact — no cross-range sums)."""
        x = jnp.asarray(x)

        def per_range(rng, sub, worker):
            parts = []
            for _local_o, tile in sub.tiles():
                self._fault_gate(worker, "matvec")
                parts.append(jnp.asarray(tile) @ x)
                worker.beat()
                self._count_tiles()
            return jnp.concatenate(parts, axis=0)

        return jnp.concatenate(
            self._map_ranges(per_range, phase="matvec"), axis=0)

    def rmatvec(self, u):
        """Aᵀ @ u: per-range partial adjoint products summed in range
        order (fixed grouping ⇒ reproducible for a fixed worker set)."""
        u = jnp.asarray(u)
        n = self.shape[1]

        def per_range(rng, sub, worker):
            g = jnp.zeros((n,) + u.shape[1:], u.dtype)
            for local_o, tile in sub.tiles():
                self._fault_gate(worker, "matvec")
                tile = jnp.asarray(tile)
                gl = rng.start + local_o
                g = g + tile.T @ u[gl : gl + tile.shape[0]]
                worker.beat()
                self._count_tiles()
            return g

        parts = self._map_ranges(per_range, phase="rmatvec")
        g = parts[0]
        for p in parts[1:]:
            g = g + p
        return g

    def residual_grad(self, b, x):
        """ONE fused distributed pass: (‖b − Ax‖² per column, Aᵀ(b − Ax))."""
        b = jnp.asarray(b)
        x = jnp.asarray(x)
        n = self.shape[1]

        def per_range(rng, sub, worker):
            g = jnp.zeros((n,) + b.shape[1:], b.dtype)
            rn2 = jnp.zeros(b.shape[1:], b.dtype)
            for local_o, tile in sub.tiles():
                self._fault_gate(worker, "matvec")
                tile = jnp.asarray(tile)
                gl = rng.start + local_o
                r_t = b[gl : gl + tile.shape[0]] - tile @ x
                g = g + tile.T @ r_t
                rn2 = rn2 + jnp.sum(r_t * r_t, axis=0)
                worker.beat()
                self._count_tiles()
            return rn2, g

        parts = self._map_ranges(per_range, phase="residual_grad")
        rn2 = parts[0][0]
        g = parts[0][1]
        for p_rn2, p_g in parts[1:]:
            rn2 = rn2 + p_rn2
            g = g + p_g
        return rn2, g
