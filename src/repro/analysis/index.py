"""AST indexing shared by every reprolint rule.

One parse of the analyzed file set produces:

- **modules** — AST + source + import table (aliases resolved to dotted
  module / symbol names, relative imports resolved against the module's
  own dotted name),
- **classes** — ``GUARDED_BY`` / ``GUARDED_READS`` annotations, the
  lock attributes discovered from ``self._x = threading.Lock()`` /
  ``make_lock(...)`` assignments (with reentrancy), ``@guarded_by``
  method declarations, and ``self.<attr> → class`` type bindings
  inferred from ``__init__`` (constructor calls and annotated
  parameters),
- **functions** — every ``def`` (nested ones included, under their
  lexical scope path) with its resolved call and method-reference
  edges,
- **jit roots + reachability** — functions decorated with ``jax.jit``
  (incl. ``partial(jax.jit, ...)``) or passed to ``jax.jit`` /
  ``jax.vmap`` / the ``lax`` control-flow combinators, closed over the
  call graph.  Reference edges (``self._dispatch_session`` passed as a
  value) are followed too — a bound method handed to a dispatcher runs
  just as surely as one called by name.

Everything is best-effort and *lexical*: aliasing through containers or
higher-order indirection is out of scope by design — the rules target
the disciplined annotation conventions this repo actually uses, and a
blind spot is a missed warning, never a false one.
"""
from __future__ import annotations

import ast
import dataclasses
from pathlib import Path

__all__ = ["RepoIndex", "ModuleInfo", "ClassInfo", "FunctionInfo", "LockInfo"]

LOCK_FACTORIES = {"Lock": False, "RLock": True, "make_lock": False,
                  "make_rlock": True}
LAX_COMBINATORS = {"while_loop", "fori_loop", "scan", "cond", "switch",
                   "map", "associative_scan", "custom_root"}
JIT_WRAPPERS = {"jit", "vmap", "pmap"}


def is_tracing_combinator(mod, chain) -> bool:
    """``lax.while_loop``-family call heads.  Requires the ``lax``
    qualification (or a bare name imported from ``jax.lax``) so that
    unrelated ``.map`` attrs — ``jax.tree.map`` — don't collide."""
    if not chain or chain[-1] not in LAX_COMBINATORS:
        return False
    if len(chain) >= 2:
        return chain[-2] == "lax"
    return mod.imports.get(chain[0], "").startswith("jax.lax")

FuncId = tuple  # (modname, scope path tuple)


@dataclasses.dataclass
class LockInfo:
    attr: str
    reentrant: bool
    line: int


@dataclasses.dataclass
class FunctionInfo:
    fid: FuncId
    node: ast.AST  # FunctionDef / AsyncFunctionDef
    module: "ModuleInfo"
    cls: "ClassInfo | None"  # set when this is a direct method of a class
    guarded_lock: str | None = None  # @guarded_by("<lock>") declaration
    jit_root: bool = False
    calls: set = dataclasses.field(default_factory=set)  # resolved FuncIds
    refs: set = dataclasses.field(default_factory=set)  # method refs passed as values
    param_types: dict = dataclasses.field(default_factory=dict)  # name -> class FQN

    @property
    def name(self) -> str:
        return self.fid[1][-1]

    @property
    def qualname(self) -> str:
        return f"{self.fid[0]}.{'.'.join(self.fid[1])}"


@dataclasses.dataclass
class ClassInfo:
    name: str
    module: "ModuleInfo"
    node: ast.ClassDef
    bases: list
    guarded_by: dict = dataclasses.field(default_factory=dict)
    guarded_reads: set = dataclasses.field(default_factory=set)
    locks: dict = dataclasses.field(default_factory=dict)  # attr -> LockInfo
    attr_types: dict = dataclasses.field(default_factory=dict)  # attr -> FQN
    methods: dict = dataclasses.field(default_factory=dict)  # name -> FunctionInfo
    fields: list = dataclasses.field(default_factory=list)  # annotated names

    @property
    def fqn(self) -> str:
        return f"{self.module.modname}.{self.name}"


@dataclasses.dataclass
class ModuleInfo:
    path: str  # analysis-relative posix path (what findings report)
    modname: str
    tree: ast.Module
    source: str
    imports: dict = dataclasses.field(default_factory=dict)  # alias -> dotted FQN
    classes: dict = dataclasses.field(default_factory=dict)  # name -> ClassInfo
    functions: dict = dataclasses.field(default_factory=dict)  # scope tuple -> FunctionInfo
    parents: dict = dataclasses.field(default_factory=dict)  # node -> parent node


def _module_name(relpath: Path) -> str:
    parts = list(relpath.with_suffix("").parts)
    if parts and parts[-1] == "__init__":
        parts = parts[:-1]
    if "repro" in parts:
        parts = parts[parts.index("repro"):]
    return ".".join(p.replace("-", "_") for p in parts) or "module"


def _resolve_relative(modname: str, level: int, module: str | None) -> str:
    if level == 0:
        return module or ""
    base = modname.split(".")
    base = base[: max(0, len(base) - level)]
    if module:
        base += module.split(".")
    return ".".join(base)


def attr_chain(node: ast.AST) -> list | None:
    """``a.b.c`` → ["a", "b", "c"]; None for non-trivial bases."""
    parts: list = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return parts[::-1]
    return None


def is_self_attr(node: ast.AST, attr: str | None = None):
    """The ``self.<attr>`` pattern; returns the attr name or None."""
    if (
        isinstance(node, ast.Attribute)
        and isinstance(node.value, ast.Name)
        and node.value.id == "self"
    ):
        if attr is None or node.attr == attr:
            return node.attr
    return None


class RepoIndex:
    def __init__(self, files, root: Path):
        self.root = Path(root)
        self.modules: dict[str, ModuleInfo] = {}
        self.classes_by_fqn: dict[str, ClassInfo] = {}
        self.functions: dict[FuncId, FunctionInfo] = {}
        self.parse_errors: list = []  # (path, message)
        for f in files:
            self._index_file(Path(f))
        self._second_pass()
        self.jit_reachable = self._close_jit_reachability()

    # ----------------------------------------------------------- first pass
    def _index_file(self, path: Path) -> None:
        try:
            rel = path.resolve().relative_to(self.root.resolve())
        except ValueError:
            rel = Path(path.name)
        source = path.read_text()
        try:
            tree = ast.parse(source, filename=str(path))
        except SyntaxError as e:
            self.parse_errors.append((rel.as_posix(), f"syntax error: {e}"))
            return
        mod = ModuleInfo(
            path=rel.as_posix(), modname=_module_name(rel), tree=tree,
            source=source,
        )
        for parent in ast.walk(tree):
            for child in ast.iter_child_nodes(parent):
                mod.parents[child] = parent
        self._index_imports(mod)
        self._index_scopes(mod, tree, scope=(), cls=None)
        self.modules[mod.modname] = mod
        for c in mod.classes.values():
            self.classes_by_fqn[c.fqn] = c

    def _index_imports(self, mod: ModuleInfo) -> None:
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    mod.imports[a.asname or a.name.split(".")[0]] = a.name
            elif isinstance(node, ast.ImportFrom):
                base = _resolve_relative(mod.modname, node.level, node.module)
                for a in node.names:
                    if a.name == "*":
                        continue
                    mod.imports[a.asname or a.name] = (
                        f"{base}.{a.name}" if base else a.name
                    )

    def _index_scopes(self, mod, node, scope, cls) -> None:
        for child in ast.iter_child_nodes(node):
            if isinstance(child, ast.ClassDef):
                info = self._index_class(mod, child)
                mod.classes[child.name] = info
                self._index_scopes(mod, child, scope + (child.name,), info)
            elif isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                fid = (mod.modname, scope + (child.name,))
                # cls is the lexically-enclosing class even for closures
                # nested inside methods (their `self` is the method's);
                # only direct methods register in cls.methods below.
                fi = FunctionInfo(fid=fid, node=child, module=mod, cls=cls)
                fi.guarded_lock = self._guarded_by_decorator(child)
                fi.jit_root = self._is_jit_decorated(child)
                fi.param_types = self._param_types(mod, child)
                mod.functions[fid[1]] = fi
                self.functions[fid] = fi
                if cls is not None and isinstance(node, ast.ClassDef):
                    cls.methods[child.name] = fi
                self._index_scopes(mod, child, scope + (child.name,), cls)
            else:
                self._index_scopes(mod, child, scope, cls)

    def _index_class(self, mod, node: ast.ClassDef) -> ClassInfo:
        info = ClassInfo(
            name=node.name, module=mod, node=node,
            bases=[attr_chain(b) or [] for b in node.bases],
        )
        for stmt in node.body:
            if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1:
                t = stmt.targets[0]
                if isinstance(t, ast.Name) and t.id == "GUARDED_BY":
                    info.guarded_by = self._const_dict(stmt.value)
                elif isinstance(t, ast.Name) and t.id == "GUARDED_READS":
                    info.guarded_reads = self._const_set(stmt.value)
            elif isinstance(stmt, ast.AnnAssign) and isinstance(
                stmt.target, ast.Name
            ):
                if stmt.target.id in ("GUARDED_BY", "GUARDED_READS"):
                    continue
                info.fields.append(stmt.target.id)
        return info

    @staticmethod
    def _const_dict(node) -> dict:
        out = {}
        if isinstance(node, ast.Dict):
            for k, v in zip(node.keys, node.values):
                if isinstance(k, ast.Constant) and isinstance(v, ast.Constant):
                    out[str(k.value)] = str(v.value)
        return out

    @staticmethod
    def _const_set(node) -> set:
        if isinstance(node, ast.Call) and node.args:  # frozenset({...})
            node = node.args[0]
        vals = getattr(node, "elts", [])
        return {
            str(e.value) for e in vals if isinstance(e, ast.Constant)
        }

    @staticmethod
    def _guarded_by_decorator(node) -> str | None:
        for dec in node.decorator_list:
            if (
                isinstance(dec, ast.Call)
                and (attr_chain(dec.func) or [""])[-1] == "guarded_by"
                and dec.args
                and isinstance(dec.args[0], ast.Constant)
            ):
                return str(dec.args[0].value)
        return None

    @staticmethod
    def _is_jit_decorated(node) -> bool:
        for dec in node.decorator_list:
            for sub in ast.walk(dec):
                chain = attr_chain(sub)
                if chain and chain[-1] in JIT_WRAPPERS:
                    return True
        return False

    def _param_types(self, mod, node) -> dict:
        out = {}
        args = node.args
        for a in list(args.posonlyargs) + list(args.args) + list(args.kwonlyargs):
            if a.annotation is None:
                continue
            ann = a.annotation
            if isinstance(ann, ast.Constant):  # string annotation
                try:
                    ann = ast.parse(str(ann.value), mode="eval").body
                except SyntaxError:
                    continue
            chain = attr_chain(ann)
            if chain:
                fqn = self._class_fqn_for(mod, chain[-1])
                if fqn:
                    out[a.arg] = fqn
        return out

    def _class_fqn_for(self, mod: ModuleInfo, name: str) -> str | None:
        if name in mod.classes:
            return f"{mod.modname}.{name}"
        target = mod.imports.get(name)
        return target  # verified against classes_by_fqn at use time

    # ---------------------------------------------------------- second pass
    def _second_pass(self) -> None:
        for mod in self.modules.values():
            for cls in mod.classes.values():
                self._infer_init_bindings(mod, cls)
        for fi in self.functions.values():
            self._index_calls(fi)

    def _infer_init_bindings(self, mod, cls: ClassInfo) -> None:
        init = cls.methods.get("__init__")
        scan = [init.node] if init else [cls.node]
        for top in scan:
            for node in ast.walk(top):
                if not isinstance(node, ast.Assign) or len(node.targets) != 1:
                    continue
                attr = is_self_attr(node.targets[0])
                if attr is None:
                    continue
                val = node.value
                if isinstance(val, ast.Call):
                    chain = attr_chain(val.func) or [""]
                    leaf = chain[-1]
                    if leaf in LOCK_FACTORIES:
                        cls.locks[attr] = LockInfo(
                            attr, LOCK_FACTORIES[leaf], node.lineno
                        )
                        continue
                    fqn = self._class_fqn_for(mod, leaf)
                    if fqn and fqn in self.classes_by_fqn:
                        cls.attr_types[attr] = fqn
                elif isinstance(val, ast.Name) and init is not None:
                    fqn = init.param_types.get(val.id)
                    if fqn and fqn in self.classes_by_fqn:
                        cls.attr_types[attr] = fqn

    # ------------------------------------------------------ call resolution
    def resolve_callable(self, fi: FunctionInfo, func) -> FuncId | None:
        """Resolve a call/reference expression to an indexed function."""
        mod = fi.module
        if isinstance(func, ast.Name):
            scope = fi.fid[1]
            for i in range(len(scope), -1, -1):
                cand = scope[:i] + (func.id,)
                if cand in mod.functions:
                    return (mod.modname, cand)
            target = mod.imports.get(func.id)
            if target:
                return self._fqn_to_fid(target)
            return None
        if not isinstance(func, ast.Attribute):
            return None
        base, attr = func.value, func.attr
        if isinstance(base, ast.Name) and base.id == "self" and fi.cls:
            return self._method_fid(fi.cls, attr)
        inner = is_self_attr(base)
        if inner and fi.cls is not None:
            fqn = fi.cls.attr_types.get(inner)
            cls = self.classes_by_fqn.get(fqn or "")
            if cls:
                return self._method_fid(cls, attr)
            return None
        if isinstance(base, ast.Name):
            fqn = fi.param_types.get(base.id)
            cls = self.classes_by_fqn.get(fqn or "")
            if cls:
                return self._method_fid(cls, attr)
            target = mod.imports.get(base.id)
            if target:
                return self._fqn_to_fid(f"{target}.{attr}")
        return None

    def _method_fid(self, cls: ClassInfo, name: str) -> FuncId | None:
        fi = cls.methods.get(name)
        return fi.fid if fi else None

    def _fqn_to_fid(self, fqn: str) -> FuncId | None:
        parts = fqn.split(".")
        for split in range(len(parts) - 1, 0, -1):
            modname = ".".join(parts[:split])
            mod = self.modules.get(modname)
            if mod is None:
                continue
            scope = tuple(parts[split:])
            if scope in mod.functions:
                return (modname, scope)
            if len(scope) == 1 and scope[0] in mod.classes:
                return self._method_fid(mod.classes[scope[0]], "__init__")
        return None

    def resolve_class(self, mod: ModuleInfo, name: str) -> ClassInfo | None:
        fqn = self._class_fqn_for(mod, name)
        return self.classes_by_fqn.get(fqn or "")

    def _index_calls(self, fi: FunctionInfo) -> None:
        for node in self._own_nodes(fi.node):
            if isinstance(node, ast.Call):
                target = self.resolve_callable(fi, node.func)
                if target is not None:
                    fi.calls.add(target)
                self._mark_traced_callees(fi, node)
            elif isinstance(node, ast.Attribute) and isinstance(
                node.ctx, ast.Load
            ):
                if is_self_attr(node) and fi.cls is not None:
                    parent = fi.module.parents.get(node)
                    called = (
                        isinstance(parent, ast.Call) and parent.func is node
                    )
                    if not called:
                        target = self._method_fid(fi.cls, node.attr)
                        if target is not None:
                            fi.refs.add(target)
            elif isinstance(node, ast.Name) and isinstance(node.ctx, ast.Load):
                parent = fi.module.parents.get(node)
                if isinstance(parent, ast.Call) and parent.func is node:
                    continue
                scope = fi.fid[1]
                for i in range(len(scope), -1, -1):
                    cand = scope[:i] + (node.id,)
                    if cand in fi.module.functions and cand != scope:
                        fi.refs.add((fi.module.modname, cand))
                        break

    def _mark_traced_callees(self, fi: FunctionInfo, call: ast.Call) -> None:
        """Functions handed to jit/vmap/lax combinators become jit roots."""
        chain = attr_chain(call.func) or [""]
        leaf = chain[-1]
        if leaf in JIT_WRAPPERS:
            cand = call.args[:1]
        elif is_tracing_combinator(fi.module, chain):
            cand = list(call.args)
        elif leaf == "partial":
            inner = [attr_chain(a) or [""] for a in call.args[:1]]
            cand = call.args[1:2] if inner and inner[0][-1] in JIT_WRAPPERS else []
        else:
            return
        for arg in cand:
            if isinstance(arg, ast.Call):  # partial(body, ...) etc.
                pchain = attr_chain(arg.func) or [""]
                if pchain[-1] == "partial" and arg.args:
                    arg = arg.args[0]
            target = self.resolve_callable(fi, arg) if not isinstance(
                arg, ast.Lambda
            ) else None
            if target is not None and target in self.functions:
                self.functions[target].jit_root = True

    @staticmethod
    def _own_nodes(func_node):
        """Walk a function body without descending into nested defs."""
        stack = list(ast.iter_child_nodes(func_node))
        while stack:
            node = stack.pop()
            yield node
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            stack.extend(ast.iter_child_nodes(node))

    # --------------------------------------------------------- reachability
    def _close_jit_reachability(self) -> set:
        reachable = {
            fid for fid, fi in self.functions.items() if fi.jit_root
        }
        frontier = list(reachable)
        while frontier:
            fi = self.functions.get(frontier.pop())
            if fi is None:
                continue
            for nxt in fi.calls | fi.refs:
                if nxt not in reachable and nxt in self.functions:
                    reachable.add(nxt)
                    frontier.append(nxt)
        return reachable

    # ------------------------------------------------------------- helpers
    def enclosing_function(self, mod: ModuleInfo, node) -> FunctionInfo | None:
        cur = node
        while cur is not None:
            cur = mod.parents.get(cur)
            if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef)):
                for fi in mod.functions.values():
                    if fi.node is cur:
                        return fi
        return None

    def guard_path(self, mod: ModuleInfo, node):
        """Ancestors of ``node`` up to (not crossing) the nearest enclosing
        function definition — the lexical region a ``with`` guard spans."""
        out = []
        cur = mod.parents.get(node)
        while cur is not None and not isinstance(
            cur, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)
        ):
            out.append(cur)
            cur = mod.parents.get(cur)
        return out, cur  # (ancestors, enclosing function node or None)
