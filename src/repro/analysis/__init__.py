"""reprolint — repo-native static analysis for the invariants tests can't see.

The repo's own history motivates every rule: the PR 7/PR 8 review-fix
commits were all concurrency and lifecycle bugs (a check-then-append
race in ``FaultPlan``, serve dispatch under the submission lock, leaked
worker threads, unguarded ``FactorCache`` mutation).  With eight-plus
locks and daemon threads live in one process, those bug classes recur
structurally — so they are caught structurally, by an AST pass that
runs in CI, not by reviewers re-deriving the locking design per PR.

Four rules (see the rule modules for the precise semantics):

- **R1 lock discipline** (:mod:`.locks`) — attributes named in a class's
  ``GUARDED_BY = {"attr": "_lock"}`` map may only be written (and, for
  attrs in ``GUARDED_READS``, read) lexically inside ``with
  self._lock:`` or inside a method declared ``@guarded_by("_lock")``
  (whose call sites are then checked instead).  R1 also builds a static
  lock-acquisition-order graph across modules and fails on cycles — the
  ``_lock`` vs ``_dispatch_lock`` inversion class.
- **R2 jit purity** (:mod:`.jitpurity`) — side-effecting calls
  (``print``, ``np.*`` host ops, ``.item()``, ``time.*``, tracer spans,
  metric increments) are flagged inside any function reachable under
  ``jax.jit`` / ``vmap`` / ``lax.while_loop``-family tracing, unless
  lexically guarded by a ``trace_state_clean()`` check or the callee is
  a declared self-guarding entry point (``obs.trace.span`` checks the
  trace state internally).
- **R3 thread lifecycle** (:mod:`.threads`) — every
  ``threading.Thread(...)`` must be constructed ``daemon=True`` or
  provably joined (``.join`` on the binding name somewhere in the
  owning class / function).
- **R4 pytree completeness** (:mod:`.pytrees`) — a dataclass constructed
  in jit-reachable code must be a registered pytree, registration must
  wrap the ``@dataclass`` decorator in the right order, and an explicit
  ``data_fields``/``meta_fields`` split must cover every declared field.

Suppression syntax (justification is REQUIRED — an ignore without one
is itself reported)::

    self._tally += 1  # reprolint: ignore[R1]: only the monitor thread writes

Run it::

    python -m repro.analysis src/            # gate: exit 1 on findings
    python -m repro.analysis src/ --graph    # print the lock-order graph

``reprolint-baseline.json`` (repo root) carries tolerated pre-existing
findings; ``--write-baseline`` refreshes it.  The package is pure
stdlib — the CI gate needs a Python interpreter and nothing else.
"""
from .driver import AnalysisResult, run_analysis
from .findings import Finding, load_baseline, write_baseline

__all__ = [
    "AnalysisResult",
    "Finding",
    "load_baseline",
    "run_analysis",
    "write_baseline",
]
