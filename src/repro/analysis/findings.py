"""Findings, suppressions and the committed baseline.

A :class:`Finding` identifies itself by ``(rule, path, context,
message)`` — deliberately *not* by line number, so the baseline survives
unrelated edits that shift code up or down a file.

Suppression grammar, checked per physical line (the finding's line or
the line directly above it)::

    # reprolint: ignore[R1]: why this unguarded access is safe
    # reprolint: ignore[R1,R2]: one comment may cover several rules

The justification after the second colon is mandatory: an ignore
without one becomes an ``R0`` finding itself, so every suppression in
the tree documents its reasoning.
"""
from __future__ import annotations

import dataclasses
import json
import re

__all__ = [
    "Finding",
    "Suppression",
    "scan_suppressions",
    "load_baseline",
    "write_baseline",
]

RULES = ("R1", "R2", "R3", "R4")

_IGNORE_RE = re.compile(
    r"#\s*reprolint:\s*ignore\[(?P<rules>[^\]]*)\]\s*(?::\s*(?P<why>.*))?\s*$"
)
# A looser "tried to write a suppression" matcher so typos are reported
# rather than silently doing nothing.
_ATTEMPT_RE = re.compile(r"#\s*reprolint\b")


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str  # "R1".."R4" (or "R0" for a malformed suppression)
    path: str  # repo-relative posix path
    line: int
    context: str  # qualified symbol the finding is anchored to
    message: str

    def key(self) -> tuple:
        return (self.rule, self.path, self.context, self.message)

    def render(self) -> str:
        return f"{self.path}:{self.line}: {self.rule} [{self.context}] {self.message}"


@dataclasses.dataclass(frozen=True)
class Suppression:
    line: int
    rules: tuple  # rule ids, or ("*",) for a bare ignore[]
    justification: str

    def covers(self, rule: str) -> bool:
        return "*" in self.rules or rule in self.rules


def scan_suppressions(source: str):
    """``{line_number: Suppression}`` for one file, plus R0 findings for
    malformed suppressions (unknown rule id / missing justification)."""
    table: dict[int, Suppression] = {}
    bad: list[tuple[int, str]] = []
    for lineno, text in enumerate(source.splitlines(), start=1):
        m = _IGNORE_RE.search(text)
        if m is None:
            if _ATTEMPT_RE.search(text):
                bad.append((lineno, "malformed suppression (expected "
                                    "'reprolint: ignore[<rule>]: why' "
                                    "after a comment marker)"))
            continue
        raw = [r.strip() for r in m.group("rules").split(",") if r.strip()]
        rules = tuple(raw) if raw else ("*",)
        unknown = [r for r in rules if r not in RULES and r != "*"]
        if unknown:
            bad.append((lineno, f"suppression names unknown rule(s) {unknown}"))
            continue
        why = (m.group("why") or "").strip()
        if not why:
            bad.append((lineno, "suppression without a justification — add "
                                "': <why this is safe>'"))
            continue
        table[lineno] = Suppression(lineno, rules, why)
    return table, bad


def suppression_for(table: dict, finding: Finding) -> Suppression | None:
    """A finding is suppressed by an ignore on its own line or the line
    directly above (the conventional comment position)."""
    for ln in (finding.line, finding.line - 1):
        sup = table.get(ln)
        if sup is not None and sup.covers(finding.rule):
            return sup
    return None


def load_baseline(path) -> set:
    with open(path) as f:
        data = json.load(f)
    return {tuple(entry) for entry in data.get("findings", [])}


def write_baseline(path, findings) -> None:
    data = {
        "comment": "reprolint baseline: tolerated pre-existing findings "
                   "(rule, path, context, message). Keep this empty; "
                   "prefer inline 'reprolint: ignore[<rule>]: why' comments.",
        "findings": sorted([list(f.key()) for f in findings]),
    }
    with open(path, "w") as f:
        json.dump(data, f, indent=2, sort_keys=True)
        f.write("\n")
