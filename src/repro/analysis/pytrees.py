"""R4 — pytree completeness for dataclasses crossing the jit boundary.

Three checks:

1. **Registration** — a ``@dataclass`` constructed inside jit-reachable
   code must be a registered pytree: decorated with
   ``register_dataclass``, registered via a module-level
   ``register_pytree_node(_class)`` call, or a ``NamedTuple`` (auto
   pytree).  An unregistered dataclass silently becomes a leaf and jax
   raises (or worse, constant-folds) on first trace.
2. **Decorator order** — ``@register_dataclass`` must sit *above*
   ``@dataclass`` in the decorator list: decorators apply bottom-up, so
   the registration must receive the finished dataclass.  The reversed
   order registers a plain class and the flatten silently sees no
   fields.
3. **Field coverage** — when registration names explicit
   ``data_fields`` / ``meta_fields``, their union must cover every
   annotated field of the class.  A field missing from both lists is
   dropped by flatten/unflatten: it survives construction, then
   vanishes on the first tree_map — the classic silent-state-loss bug.
"""
from __future__ import annotations

import ast

from .findings import Finding
from .index import RepoIndex, ClassInfo, attr_chain

__all__ = ["check_pytrees"]

_REGISTER_DECOS = {"register_dataclass", "register_pytree_node_class"}
_REGISTER_CALLS = {"register_pytree_node", "register_pytree_with_keys"}


def _deco_leaf(dec) -> str:
    node = dec.func if isinstance(dec, ast.Call) else dec
    chain = attr_chain(node)
    return chain[-1] if chain else ""


def _is_dataclass_deco(dec) -> bool:
    return _deco_leaf(dec) == "dataclass"


def _is_register_deco(dec) -> bool:
    return _deco_leaf(dec) in _REGISTER_DECOS


def _is_namedtuple(cls: ClassInfo) -> bool:
    return any(chain and chain[-1] == "NamedTuple" for chain in cls.bases)


def _module_registered_names(mod) -> set:
    """Classes registered via register_pytree_node(Cls, ...) at module level."""
    out = set()
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Call):
            continue
        chain = attr_chain(node.func) or [""]
        if chain[-1] in _REGISTER_CALLS and node.args:
            first = node.args[0]
            if isinstance(first, ast.Name):
                out.add(first.id)
    return out


def _registration(cls: ClassInfo):
    """('deco', idx_register, idx_dataclass, deco_node) | 'call' | 'namedtuple'
    | None."""
    idx_reg = idx_dc = None
    reg_node = None
    for i, dec in enumerate(cls.node.decorator_list):
        if _is_register_deco(dec) and idx_reg is None:
            idx_reg, reg_node = i, dec
        if _is_dataclass_deco(dec) and idx_dc is None:
            idx_dc = i
    if idx_reg is not None:
        return ("deco", idx_reg, idx_dc, reg_node)
    if _is_namedtuple(cls):
        return ("namedtuple", None, None, None)
    if cls.name in _module_registered_names(cls.module):
        return ("call", None, None, None)
    return None


def _explicit_fields(reg_node) -> tuple | None:
    """(data_fields, meta_fields) from register_dataclass kwargs, if given."""
    if not isinstance(reg_node, ast.Call):
        return None
    got = {}
    for kw in reg_node.keywords:
        if kw.arg in ("data_fields", "meta_fields"):
            vals = getattr(kw.value, "elts", None)
            if vals is None:
                return None  # computed — can't check statically
            got[kw.arg] = [
                str(e.value) for e in vals if isinstance(e, ast.Constant)
            ]
    if not got:
        return None
    return (got.get("data_fields", []), got.get("meta_fields", []))


def _is_dataclass(cls: ClassInfo) -> bool:
    return any(_is_dataclass_deco(d) for d in cls.node.decorator_list)


def check_pytrees(index: RepoIndex) -> list:
    out: list = []

    # Checks 2 & 3 run for every registered dataclass, reachable or not —
    # a broken registration is broken wherever it is first traced.
    for cls in index.classes_by_fqn.values():
        reg = _registration(cls)
        if reg is None or reg[0] != "deco":
            continue
        _, idx_reg, idx_dc, reg_node = reg
        if idx_dc is not None and idx_reg > idx_dc:
            out.append(Finding(
                rule="R4", path=cls.module.path, line=cls.node.lineno,
                context=cls.name,
                message=(
                    "@register_dataclass must be listed ABOVE @dataclass "
                    "(decorators apply bottom-up; this order registers the "
                    "bare class and flatten sees no fields)"
                ),
            ))
        explicit = _explicit_fields(reg_node)
        if explicit is not None and cls.fields:
            covered = set(explicit[0]) | set(explicit[1])
            missing = [f for f in cls.fields if f not in covered]
            if missing:
                out.append(Finding(
                    rule="R4", path=cls.module.path, line=cls.node.lineno,
                    context=cls.name,
                    message=(
                        f"pytree registration drops field(s) {missing}: not "
                        "in data_fields or meta_fields — they vanish on the "
                        "first tree_map/unflatten"
                    ),
                ))

    # Check 1: unregistered dataclasses constructed in jit-reachable code.
    for fid in sorted(index.jit_reachable):
        fi = index.functions.get(fid)
        if fi is None:
            continue
        for node in index._own_nodes(fi.node):
            if not isinstance(node, ast.Call):
                continue
            chain = attr_chain(node.func)
            if not chain:
                continue
            cls = index.resolve_class(fi.module, chain[-1])
            if cls is None or not _is_dataclass(cls):
                continue
            if _registration(cls) is not None:
                continue
            out.append(Finding(
                rule="R4", path=fi.module.path, line=node.lineno,
                context=fi.qualname,
                message=(
                    f"dataclass {cls.name} constructed in jit-reachable code "
                    "but is not a registered pytree "
                    "(@jax.tree_util.register_dataclass above @dataclass, or "
                    "register_pytree_node)"
                ),
            ))
    return out
