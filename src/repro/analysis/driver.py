"""Orchestration: discover files, index once, run rules, apply
suppressions and the baseline, return a structured result."""
from __future__ import annotations

import dataclasses
from pathlib import Path

from .findings import (
    Finding,
    load_baseline,
    scan_suppressions,
    suppression_for,
)
from .index import RepoIndex
from .jitpurity import check_jit_purity
from .locks import LockGraph, build_lock_graph, check_locks
from .pytrees import check_pytrees
from .threads import check_threads

__all__ = ["AnalysisResult", "run_analysis", "discover_files"]

RULE_CHECKS = {
    "R1": check_locks,
    "R2": check_jit_purity,
    "R3": check_threads,
    "R4": check_pytrees,
}

_SKIP_PARTS = {"__pycache__", ".git", "fixtures"}


@dataclasses.dataclass
class AnalysisResult:
    findings: list  # active (unsuppressed, un-baselined), render-ordered
    suppressed: list  # (Finding, Suppression)
    baselined: list
    lock_graph: "LockGraph"
    files: list

    @property
    def ok(self) -> bool:
        return not self.findings


def discover_files(paths) -> list:
    files: list = []
    for p in paths:
        p = Path(p)
        if p.is_dir():
            files.extend(
                f for f in sorted(p.rglob("*.py"))
                if not (_SKIP_PARTS & set(f.parts))
            )
        elif p.suffix == ".py":
            files.append(p)
    return files


def run_analysis(paths, rules=None, baseline_path=None, root=None):
    root = Path(root) if root is not None else Path.cwd()
    files = discover_files(paths)
    index = RepoIndex(files, root=root)
    rules = tuple(rules) if rules else tuple(RULE_CHECKS)

    raw: list = []
    for rule in rules:
        raw.extend(RULE_CHECKS[rule](index))
    for path, msg in index.parse_errors:
        raw.append(Finding("R0", path, 1, "parse", msg))

    sup_tables = {
        mod.path: scan_suppressions(mod.source)
        for mod in index.modules.values()
    }
    for path, (_table, bad) in sup_tables.items():
        for line, msg in bad:
            raw.append(Finding("R0", path, line, "suppression", msg))

    baseline = set()
    if baseline_path is not None and Path(baseline_path).exists():
        baseline = load_baseline(baseline_path)

    active, suppressed, baselined = [], [], []
    for f in sorted(set(raw), key=lambda f: (f.path, f.line, f.rule)):
        table = sup_tables.get(f.path, ({}, []))[0]
        sup = suppression_for(table, f) if f.rule != "R0" else None
        if sup is not None:
            suppressed.append((f, sup))
        elif f.key() in baseline:
            baselined.append(f)
        else:
            active.append(f)

    graph = build_lock_graph(index)
    return AnalysisResult(
        findings=active, suppressed=suppressed, baselined=baselined,
        lock_graph=graph, files=[str(f) for f in files],
    )
