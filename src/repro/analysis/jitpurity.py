"""R2 — jit purity: no host side effects in traced code.

A function is *jit-reachable* when it is decorated with ``jax.jit`` /
``vmap`` (incl. via ``partial``), passed to ``jax.jit``/``jax.vmap`` or
a ``lax`` control-flow combinator, or reachable from such a root over
the call/reference graph.  Inside jit-reachable code these are flagged:

- ``print(...)`` — traces once at compile time, silent afterwards;
- ``np.<anything>(...)`` where ``np`` is a numpy import — a host op
  that forces abstract tracers concrete (``TracerArrayConversionError``
  at best, silently-baked constants at worst);
- ``<expr>.item()`` / ``float(tracer)``-style host sync via ``.item``;
- ``time.monotonic()`` & friends — wall clock evaluated at trace time;
- ``<metric>.inc(...)`` / ``<metric>.observe(...)`` — metric writes
  would count traces, not executions;
- tracer spans/instants — **unless** the callee is self-guarding: a
  resolved callee whose own body consults ``trace_state_clean`` (the
  ``obs.trace.span`` pattern) is exempt, as is any call lexically under
  an ``if ... trace_state_clean ...:`` check.

Lambdas passed straight to ``vmap``/``lax`` combinators are scanned as
part of their enclosing function; a banned call inside one is reported
even when the enclosing function is itself unreachable by name.
"""
from __future__ import annotations

import ast

from .findings import Finding
from .index import RepoIndex, FunctionInfo, attr_chain

__all__ = ["check_jit_purity"]

_TIME_FUNCS = {"monotonic", "perf_counter", "time", "time_ns",
               "process_time", "sleep"}
# Scalar dtype constructors are trace-time constant construction
# (np.uint32(0x1BD11BDA) in kernel code) — benign and ubiquitous; a
# tracer passed to one fails loudly on its own, so exempting them
# costs nothing.  Everything else np.* is a host op.
_NP_SCALAR_CTORS = {
    "bool_", "uint8", "uint16", "uint32", "uint64", "int8", "int16",
    "int32", "int64", "float16", "float32", "float64", "complex64",
    "complex128",
}
_METRIC_WRITES = {"inc", "observe"}
_TRACER_EFFECTS = {"span", "instant", "maybe_block"}
_GUARD_NAMES = ("trace_state_clean", "_trace_state_clean")


def _is_numpy_alias(mod, name: str) -> bool:
    fqn = mod.imports.get(name, "")
    return fqn == "numpy" or fqn.startswith("numpy.")


def _is_time_alias(mod, name: str) -> bool:
    return mod.imports.get(name, "") == "time"


def _self_guarding(index: RepoIndex, fid) -> bool:
    """Callee body consults trace_state_clean itself (span/instant do)."""
    fi = index.functions.get(fid)
    if fi is None:
        return False
    for node in ast.walk(fi.node):
        if isinstance(node, ast.Name) and node.id in _GUARD_NAMES:
            return True
        if isinstance(node, ast.Attribute) and node.attr in _GUARD_NAMES:
            return True
    return False


def _lexically_guarded(index: RepoIndex, fi: FunctionInfo, node) -> bool:
    """``node`` sits under an ``if`` whose test mentions trace_state_clean."""
    ancestors, fdef = index.guard_path(fi.module, node)
    if fdef is not fi.node:
        return False
    for anc in ancestors:
        if isinstance(anc, ast.If):
            for sub in ast.walk(anc.test):
                if isinstance(sub, ast.Name) and sub.id in _GUARD_NAMES:
                    return True
                if isinstance(sub, ast.Attribute) and sub.attr in _GUARD_NAMES:
                    return True
    return False


def _classify_call(index: RepoIndex, fi: FunctionInfo, call: ast.Call):
    """Return a finding message for a banned call, or None."""
    func = call.func
    mod = fi.module
    if isinstance(func, ast.Name):
        if func.id == "print":
            return "print() in jit-reachable code runs at trace time only"
        target = index.resolve_callable(fi, func)
        if target is not None and target[1][-1] in _TRACER_EFFECTS:
            if not _self_guarding(index, target):
                return (f"tracer effect {func.id}() in jit-reachable code "
                        "without a trace_state_clean guard")
        return None
    if not isinstance(func, ast.Attribute):
        return None
    attr = func.attr
    if attr == "item" and not call.args:
        return ".item() forces host sync and fails on abstract tracers"
    chain = attr_chain(func)
    if chain and len(chain) >= 2:
        head = chain[0]
        if _is_numpy_alias(mod, head) and not (
            len(chain) == 2 and attr in _NP_SCALAR_CTORS
        ):
            dotted = ".".join(chain)
            return (f"host numpy call {dotted}() in jit-reachable "
                    "code; use jnp or hoist out of the traced region")
        if _is_time_alias(mod, head) and attr in _TIME_FUNCS:
            return (f"time.{attr}() in jit-reachable code is evaluated at "
                    "trace time, not per call")
    if attr in _METRIC_WRITES:
        return (f".{attr}() metric write in jit-reachable code would count "
                "traces, not executions")
    if attr in _TRACER_EFFECTS:
        target = index.resolve_callable(fi, func)
        if target is not None and not _self_guarding(index, target):
            return (f"tracer effect .{attr}() in jit-reachable code "
                    "without a trace_state_clean guard")
    return None


def _scan_function(index: RepoIndex, fi: FunctionInfo, out: list) -> None:
    for node in index._own_nodes(fi.node):
        if not isinstance(node, ast.Call):
            continue
        msg = _classify_call(index, fi, node)
        if msg is None:
            continue
        if _lexically_guarded(index, fi, node):
            continue
        out.append(Finding(
            rule="R2", path=fi.module.path, line=node.lineno,
            context=fi.qualname, message=msg,
        ))


def _lambda_args_of_traced_calls(index: RepoIndex, fi: FunctionInfo):
    """Lambdas passed inline to jit/vmap/lax combinators inside ``fi``."""
    from .index import JIT_WRAPPERS, is_tracing_combinator
    for node in index._own_nodes(fi.node):
        if not isinstance(node, ast.Call):
            continue
        chain = attr_chain(node.func) or [""]
        if chain[-1] not in JIT_WRAPPERS and not is_tracing_combinator(
            fi.module, chain
        ):
            continue
        for arg in node.args:
            if isinstance(arg, ast.Lambda):
                yield arg


def check_jit_purity(index: RepoIndex) -> list:
    out: list = []
    for fid in sorted(index.jit_reachable):
        fi = index.functions.get(fid)
        if fi is not None:
            _scan_function(index, fi, out)
    # Lambdas handed straight to tracing combinators, wherever they live.
    for fi in index.functions.values():
        for lam in _lambda_args_of_traced_calls(index, fi):
            for node in ast.walk(lam.body):
                if isinstance(node, ast.Call):
                    msg = _classify_call(index, fi, node)
                    if msg is not None:
                        out.append(Finding(
                            rule="R2", path=fi.module.path, line=node.lineno,
                            context=f"{fi.qualname}.<lambda>", message=msg,
                        ))
    return out
