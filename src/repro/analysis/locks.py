"""R1 — lock discipline and the static lock-acquisition-order graph.

Write discipline
    Every write to an attribute named in a class's ``GUARDED_BY`` map
    must sit lexically inside ``with self.<lock>:`` for the mapped
    lock, or inside a method declared ``@guarded_by("<lock>")`` (the
    declaration shifts the obligation to call sites: each resolved call
    of such a method must itself be guarded).  Reads are checked too
    for attrs listed in ``GUARDED_READS``.  ``__init__`` is exempt —
    the object is not shared before construction completes.  A ``with``
    guard never extends into a nested ``def``/``lambda``: a closure
    outlives the critical section that created it.

Lock-order graph
    Nodes are ``Class.lockattr``.  An edge A → B is recorded when a
    ``with`` on A lexically contains a ``with`` on B, or contains a
    call (or method reference — bound methods handed to dispatchers run
    too) whose transitive acquisition set includes B.  Acquisition sets
    are a fixpoint over the call graph.  Self-edges are legal on
    reentrant locks (RLock) and a deadlock finding on plain Locks;
    cycles between distinct locks are findings, reported once with the
    full cycle path.
"""
from __future__ import annotations

import ast

from .findings import Finding
from .index import RepoIndex, FunctionInfo, is_self_attr

__all__ = ["check_locks", "build_lock_graph", "LockGraph"]


def _lock_with_target(index: RepoIndex, fi: FunctionInfo, item):
    """If ``with`` item acquires an indexed lock, return (Class, attr)."""
    expr = item.context_expr
    attr = is_self_attr(expr)
    if attr is not None and fi.cls is not None and attr in fi.cls.locks:
        return (fi.cls, attr)
    # cross-object: with other._mu: / with self.cache._mu:
    if isinstance(expr, ast.Attribute):
        base = expr.value
        inner = is_self_attr(base)
        fqn = None
        if inner and fi.cls is not None:
            fqn = fi.cls.attr_types.get(inner)
        elif isinstance(base, ast.Name):
            fqn = fi.param_types.get(base.id)
        cls = index.classes_by_fqn.get(fqn or "")
        if cls is not None and expr.attr in cls.locks:
            return (cls, expr.attr)
    return None


def _owning_class_for_method(index: RepoIndex, fid):
    fi = index.functions.get(fid)
    return fi.cls if fi else None


def _enclosing_locks(index: RepoIndex, fi: FunctionInfo, node):
    """Locks held lexically at ``node`` inside ``fi`` (own-class attrs),
    as a set of lock attr names on ``fi.cls``."""
    held = set()
    ancestors, fdef = index.guard_path(fi.module, node)
    if fdef is not fi.node:  # crossed into/out of a nested def: no guard
        return held
    for anc in ancestors:
        if isinstance(anc, (ast.With, ast.AsyncWith)):
            for item in anc.items:
                tgt = _lock_with_target(index, fi, item)
                if tgt is not None and tgt[0] is fi.cls:
                    held.add(tgt[1])
    return held


def _function_for_node(index: RepoIndex, mod, node) -> FunctionInfo | None:
    cur = node
    while cur is not None:
        for fi in mod.functions.values():
            if fi.node is cur:
                return fi
        cur = mod.parents.get(cur)
    return None


# --------------------------------------------------------------------- R1 core
def check_locks(index: RepoIndex) -> list:
    findings = []
    findings += _check_guarded_attrs(index)
    findings += _check_guarded_by_callsites(index)
    graph = build_lock_graph(index)
    findings += graph.findings
    return findings


def _check_guarded_attrs(index: RepoIndex) -> list:
    out = []
    for mod in index.modules.values():
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Attribute):
                continue
            attr = is_self_attr(node)
            if attr is None:
                continue
            is_write = isinstance(node.ctx, (ast.Store, ast.Del))
            parent = mod.parents.get(node)
            # self.stats["k"] += 1 / self.events.append(...) mutate through
            # a Load of the container; treat subscript-store and known
            # mutator calls as writes.
            if not is_write and isinstance(parent, ast.Subscript):
                gp_ctx = getattr(parent, "ctx", None)
                is_write = isinstance(gp_ctx, (ast.Store, ast.Del))
            if not is_write and isinstance(parent, ast.Attribute):
                gp = mod.parents.get(parent)
                if (
                    isinstance(gp, ast.Call)
                    and gp.func is parent
                    and parent.attr in _MUTATORS
                ):
                    is_write = True
            fi = _function_for_node(index, mod, node)
            if fi is None or fi.cls is None:
                continue
            cls = fi.cls
            lock = cls.guarded_by.get(attr)
            if lock is None:
                continue
            if fi.name == "__init__":
                continue
            if not is_write and attr not in cls.guarded_reads:
                continue
            if fi.guarded_lock == lock:
                continue  # caller-holds contract; call sites are checked
            if lock in _enclosing_locks(index, fi, node):
                continue
            kind = "write to" if is_write else "read of"
            out.append(Finding(
                rule="R1", path=mod.path, line=node.lineno,
                context=f"{cls.name}.{fi.name}",
                message=(
                    f"{kind} guarded attribute 'self.{attr}' outside "
                    f"'with self.{lock}:' (declared in {cls.name}.GUARDED_BY)"
                ),
            ))
    return out


_MUTATORS = {
    "append", "extend", "insert", "pop", "remove", "clear", "add",
    "discard", "update", "popitem", "setdefault", "appendleft",
    "popleft", "sort",
}


def _check_guarded_by_callsites(index: RepoIndex) -> list:
    """Every resolved call of a ``@guarded_by(L)`` method must hold L."""
    out = []
    guarded = {
        fid: fi for fid, fi in index.functions.items()
        if fi.guarded_lock is not None and fi.cls is not None
    }
    if not guarded:
        return out
    for mod in index.modules.values():
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call):
                continue
            fi = _function_for_node(index, mod, node)
            if fi is None:
                continue
            target = index.resolve_callable(fi, node.func)
            tgt = guarded.get(target)
            if tgt is None:
                continue
            lock = tgt.guarded_lock
            if fi.name == "__init__" and fi.cls is tgt.cls:
                continue
            if fi.cls is tgt.cls and fi.guarded_lock == lock:
                continue  # guarded helper calling a sibling helper
            if fi.cls is tgt.cls and lock in _enclosing_locks(index, fi, node):
                continue
            if fi.cls is not tgt.cls:
                # cross-class call: require a lexical with on the target
                # object's lock (e.g. with self.cache._mu: self.cache._drop())
                ancestors, fdef = index.guard_path(fi.module, node)
                held_cross = False
                if fdef is fi.node:
                    for anc in ancestors:
                        if isinstance(anc, (ast.With, ast.AsyncWith)):
                            for item in anc.items:
                                t = _lock_with_target(index, fi, item)
                                if t is not None and t[0] is tgt.cls and t[1] == lock:
                                    held_cross = True
                if held_cross:
                    continue
            out.append(Finding(
                rule="R1", path=mod.path, line=node.lineno,
                context=f"{fi.cls.name + '.' if fi.cls else ''}{fi.name}",
                message=(
                    f"call of {tgt.cls.name}.{tgt.name}() requires "
                    f"'{tgt.cls.name}.{lock}' held "
                    f"(declared @guarded_by(\"{lock}\"))"
                ),
            ))
    return out


# --------------------------------------------------------------- lock ordering
class LockGraph:
    """Static acquisition-order graph.  ``edges[a][b]`` is a list of
    human-readable witness sites for the ordered pair a → b."""

    def __init__(self):
        self.nodes: set = set()
        self.reentrant: dict = {}
        self.edges: dict = {}
        self.findings: list = []

    def add_edge(self, a: str, b: str, site: str) -> None:
        self.nodes.update((a, b))
        self.edges.setdefault(a, {}).setdefault(b, []).append(site)

    def cycles(self) -> list:
        """All elementary cycles found by DFS (deduplicated by node set)."""
        found, seen_sets = [], []
        def dfs(start, node, path, on_path):
            for nxt in sorted(self.edges.get(node, ())):
                if nxt == start and len(path) > 1:
                    key = frozenset(path)
                    if key not in seen_sets:
                        seen_sets.append(key)
                        found.append(path[:] + [start])
                elif nxt not in on_path and nxt > start:
                    on_path.add(nxt)
                    dfs(start, nxt, path + [nxt], on_path)
                    on_path.discard(nxt)
        for start in sorted(self.nodes):
            dfs(start, start, [start], {start})
        return found

    def render(self) -> str:
        lines = ["lock-order graph (A -> B: A held while acquiring B):"]
        for a in sorted(self.edges):
            for b, sites in sorted(self.edges[a].items()):
                lines.append(f"  {a} -> {b}   [{sites[0]}"
                             + (f" +{len(sites) - 1} more]" if len(sites) > 1
                                else "]"))
        lonely = self.nodes - set(self.edges) - {
            b for tgts in self.edges.values() for b in tgts
        }
        for n in sorted(lonely):
            lines.append(f"  {n}   (leaf: never nested)")
        return "\n".join(lines)


def _acquisition_sets(index: RepoIndex):
    """Fixpoint: locks a function may acquire, directly or transitively."""
    direct: dict = {}
    for fid, fi in index.functions.items():
        acq = set()
        for node in index._own_nodes(fi.node):
            if isinstance(node, (ast.With, ast.AsyncWith)):
                for item in node.items:
                    tgt = _lock_with_target(index, fi, item)
                    if tgt is not None:
                        acq.add(f"{tgt[0].name}.{tgt[1]}")
        if fi.guarded_lock is None and fi.cls is not None:
            pass
        direct[fid] = acq
    closed = {fid: set(s) for fid, s in direct.items()}
    changed = True
    while changed:
        changed = False
        for fid, fi in index.functions.items():
            acc = closed[fid]
            before = len(acc)
            for nxt in fi.calls | fi.refs:
                acc |= closed.get(nxt, set())
            if len(acc) != before:
                changed = True
    return direct, closed


def build_lock_graph(index: RepoIndex) -> LockGraph:
    graph = LockGraph()
    for cls in index.classes_by_fqn.values():
        for attr, li in cls.locks.items():
            node = f"{cls.name}.{attr}"
            graph.nodes.add(node)
            graph.reentrant[node] = li.reentrant
    _direct, closed = _acquisition_sets(index)

    for fid, fi in index.functions.items():
        for node in index._own_nodes(fi.node):
            if not isinstance(node, (ast.With, ast.AsyncWith)):
                continue
            for item in node.items:
                tgt = _lock_with_target(index, fi, item)
                if tgt is None:
                    continue
                held = f"{tgt[0].name}.{tgt[1]}"
                site = f"{fi.module.path}:{node.lineno} {fi.qualname}"
                for inner in _body_acquisitions(index, fi, node):
                    if inner == held:
                        if not graph.reentrant.get(held, False):
                            graph.findings.append(Finding(
                                rule="R1", path=fi.module.path,
                                line=node.lineno, context=fi.qualname,
                                message=(
                                    f"non-reentrant lock '{held}' may be "
                                    "re-acquired while held (self-deadlock); "
                                    "use make_rlock() or hoist the inner "
                                    "acquisition"
                                ),
                            ))
                        continue
                    graph.add_edge(held, inner, site)

    for cyc in graph.cycles():
        pretty = " -> ".join(cyc)
        graph.findings.append(Finding(
            rule="R1", path=_cycle_witness(graph, cyc), line=1,
            context="lock-order",
            message=(
                f"lock-acquisition-order cycle: {pretty}; threads taking "
                "these locks in different orders can deadlock — pick one "
                "global order"
            ),
        ))
    return graph


def _body_acquisitions(index: RepoIndex, fi: FunctionInfo, with_node):
    """Locks acquired inside a ``with`` body: nested withs plus the
    transitive acquisition sets of calls/references made in the body
    (not crossing into nested function definitions)."""
    _direct, closed = getattr(index, "_acq_cache", (None, None))
    if closed is None:
        index._acq_cache = _acquisition_sets(index)
        _direct, closed = index._acq_cache
    out = set()
    stack = [n for item in [with_node.body] for n in item]
    while stack:
        node = stack.pop()
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)):
            continue
        if isinstance(node, (ast.With, ast.AsyncWith)):
            for item in node.items:
                tgt = _lock_with_target(index, fi, item)
                if tgt is not None:
                    out.add(f"{tgt[0].name}.{tgt[1]}")
        if isinstance(node, ast.Call):
            target = index.resolve_callable(fi, node.func)
            if target is not None:
                out |= closed.get(target, set())
        if isinstance(node, ast.Attribute) and isinstance(node.ctx, ast.Load):
            if is_self_attr(node) and fi.cls is not None:
                parent = fi.module.parents.get(node)
                if not (isinstance(parent, ast.Call) and parent.func is node):
                    ref = index._method_fid(fi.cls, node.attr)
                    if ref is not None:
                        out |= closed.get(ref, set())
        stack.extend(ast.iter_child_nodes(node))
    return out


def _cycle_witness(graph: LockGraph, cyc) -> str:
    for a, b in zip(cyc, cyc[1:]):
        sites = graph.edges.get(a, {}).get(b)
        if sites:
            return sites[0].split(":", 1)[0]
    return "<graph>"
