"""``python -m repro.analysis`` — the reprolint CLI and CI gate.

Exit status: 0 when every finding is suppressed (with justification) or
baselined, 1 when unsuppressed findings remain, 2 on usage errors.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from .driver import run_analysis
from .findings import RULES, write_baseline

__all__ = ["main"]


def _build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="reprolint: lock discipline (R1), jit purity (R2), "
                    "thread lifecycle (R3), pytree completeness (R4).",
    )
    p.add_argument("paths", nargs="+", help="files or directories to check")
    p.add_argument("--rules", default=",".join(RULES),
                   help="comma-separated subset, e.g. R1,R3")
    p.add_argument("--baseline", default="reprolint-baseline.json",
                   help="baseline file of tolerated findings "
                        "(default: ./reprolint-baseline.json)")
    p.add_argument("--no-baseline", action="store_true",
                   help="ignore the baseline file entirely")
    p.add_argument("--write-baseline", action="store_true",
                   help="write current findings to the baseline and exit 0")
    p.add_argument("--graph", action="store_true",
                   help="print the static lock-order graph")
    p.add_argument("--json", action="store_true", dest="as_json",
                   help="machine-readable output")
    p.add_argument("--root", default=".",
                   help="repo root findings paths are relative to")
    return p


def main(argv=None) -> int:
    args = _build_parser().parse_args(argv)
    rules = tuple(r.strip() for r in args.rules.split(",") if r.strip())
    bad = [r for r in rules if r not in RULES]
    if bad:
        print(f"unknown rule(s): {bad} (known: {', '.join(RULES)})",
              file=sys.stderr)
        return 2

    baseline = None if args.no_baseline else args.baseline
    result = run_analysis(
        args.paths, rules=rules, baseline_path=baseline, root=args.root,
    )

    if args.write_baseline:
        write_baseline(args.baseline, result.findings)
        print(f"wrote {len(result.findings)} finding(s) to {args.baseline}")
        return 0

    if args.as_json:
        print(json.dumps({
            "findings": [f.key() + (f.line,) for f in result.findings],
            "suppressed": len(result.suppressed),
            "baselined": len(result.baselined),
            "files": len(result.files),
            "lock_edges": {
                a: sorted(b) for a, b in result.lock_graph.edges.items()
            },
        }, indent=2, default=list))
        return 0 if result.ok else 1

    for f in result.findings:
        print(f.render())
    if args.graph:
        print(result.lock_graph.render())
    n, ns, nb = len(result.findings), len(result.suppressed), len(result.baselined)
    checked = len(result.files)
    print(
        f"reprolint: {checked} file(s), rules {','.join(rules)}: "
        f"{n} finding(s), {ns} suppressed, {nb} baselined"
        + (" — OK" if result.ok else " — FAIL")
    )
    if not result.ok and not Path(args.baseline).exists():
        print("hint: suppress inline with 'reprolint: ignore[<rule>]: why' "
              "comments or record tolerated findings with --write-baseline",
              file=sys.stderr)
    return 0 if result.ok else 1
