"""R3 — thread lifecycle: no silently leaked threads.

Every ``threading.Thread(...)`` construction must either:

- pass ``daemon=True`` at the constructor (or set ``<binding>.daemon =
  True`` before ``start()``), so the interpreter can exit without the
  thread pinning the process, or
- be *provably joined*: the construction's binding target (``self._t =
  Thread(...)`` or ``t = Thread(...)``) has a ``.join(...)`` call on
  the same name somewhere in the owning class (any method — ``close()``
  / ``stop()`` teardown paths) or, for a local, in the same function.

This is lexical, not flow-sensitive: a ``join`` on an error-free path
only is accepted.  The rule targets the PR 8 bug class — workers
constructed non-daemon and forgotten — not exhaustive escape analysis.
"""
from __future__ import annotations

import ast

from .findings import Finding
from .index import RepoIndex, attr_chain, is_self_attr

__all__ = ["check_threads"]


def _is_thread_ctor(mod, call: ast.Call) -> bool:
    chain = attr_chain(call.func)
    if not chain:
        return False
    if chain[-1] != "Thread":
        return False
    if len(chain) == 1:  # bare Thread — only if imported from threading
        return mod.imports.get("Thread", "").startswith("threading")
    base = mod.imports.get(chain[0], chain[0])
    return base == "threading" or base.startswith("threading.")


def _daemon_kwarg_true(call: ast.Call) -> bool:
    for kw in call.keywords:
        if kw.arg == "daemon":
            return isinstance(kw.value, ast.Constant) and kw.value.value is True
    return False


def _binding_target(mod, call: ast.Call):
    """('self', attr) / ('local', name) binding of the constructed thread."""
    parent = mod.parents.get(call)
    if isinstance(parent, ast.Assign) and len(parent.targets) == 1:
        t = parent.targets[0]
        a = is_self_attr(t)
        if a is not None:
            return ("self", a)
        if isinstance(t, ast.Name):
            return ("local", t.id)
    if isinstance(parent, ast.AnnAssign):
        a = is_self_attr(parent.target)
        if a is not None:
            return ("self", a)
        if isinstance(parent.target, ast.Name):
            return ("local", parent.target.id)
    return None


def _name_has_call(scope_node, kind, name, method) -> bool:
    """Is there a ``<binding>.<method>(...)`` call under ``scope_node``?"""
    for node in ast.walk(scope_node):
        if not isinstance(node, ast.Call):
            continue
        f = node.func
        if not (isinstance(f, ast.Attribute) and f.attr == method):
            continue
        if kind == "self" and is_self_attr(f.value, name):
            return True
        if kind == "local" and isinstance(f.value, ast.Name) and f.value.id == name:
            return True
    return False


def _daemon_set_later(scope_node, kind, name) -> bool:
    """``<binding>.daemon = True`` anywhere in scope."""
    for node in ast.walk(scope_node):
        if not isinstance(node, ast.Assign):
            continue
        if not (isinstance(node.value, ast.Constant) and node.value.value is True):
            continue
        for t in node.targets:
            if isinstance(t, ast.Attribute) and t.attr == "daemon":
                if kind == "self" and is_self_attr(t.value, name):
                    return True
                if (kind == "local" and isinstance(t.value, ast.Name)
                        and t.value.id == name):
                    return True
    return False


def check_threads(index: RepoIndex) -> list:
    out = []
    for mod in index.modules.values():
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call) or not _is_thread_ctor(mod, node):
                continue
            if _daemon_kwarg_true(node):
                continue
            binding = _binding_target(mod, node)
            fi = None
            for cand in mod.functions.values():
                for sub in ast.walk(cand.node):
                    if sub is node:
                        fi = cand if fi is None or _contains(fi.node, cand.node) \
                            else fi
            context = fi.qualname if fi else mod.modname
            if binding is not None:
                kind, name = binding
                if kind == "self" and fi is not None and fi.cls is not None:
                    scope = fi.cls.node
                else:
                    scope = fi.node if fi is not None else mod.tree
                if _daemon_set_later(scope, kind, name):
                    continue
                if _name_has_call(scope, kind, name, "join"):
                    continue
                where = (f"self.{name}" if kind == "self" else name)
                msg = (
                    f"Thread bound to '{where}' is neither daemon=True nor "
                    f"joined anywhere in its owning "
                    f"{'class' if kind == 'self' and fi and fi.cls else 'scope'}"
                    " — a leaked non-daemon thread pins the process at exit"
                )
            else:
                msg = ("unbound threading.Thread(...) without daemon=True "
                       "can never be joined — assign it or daemonize it")
            out.append(Finding(
                rule="R3", path=mod.path, line=node.lineno,
                context=context, message=msg,
            ))
    return out


def _contains(outer, inner) -> bool:
    return any(n is inner for n in ast.walk(outer))
