"""Runtime-side annotation vocabulary reprolint checks against.

Everything here is free at runtime — the annotations exist so the AST
checker (and readers) can see the locking design in the code itself:

- ``GUARDED_BY = {"attr": "_lock"}`` — class attribute mapping shared
  mutable attributes to the lock that must be held to write them.
- ``GUARDED_READS = frozenset({"attr"})`` — attrs whose *reads* must
  also hold the lock (for state where a torn read matters, e.g. a list
  snapshotted while another thread appends).
- ``@guarded_by("_lock")`` — marks a helper method as "caller already
  holds ``self._lock``": writes inside it are considered guarded, and
  reprolint instead checks that every call site of the method sits
  inside ``with self._lock:`` (or another method guarded by the same
  lock).

The decorator is intentionally a no-op wrapper (it only stamps the
function) so annotating a hot path costs nothing.
"""
from __future__ import annotations

__all__ = ["guarded_by"]

GUARDED_BY_ATTR = "__reprolint_guarded_by__"


def guarded_by(lock: str):
    """Declare that a method must only be called with ``self.<lock>`` held."""

    def mark(fn):
        setattr(fn, GUARDED_BY_ATTR, lock)
        return fn

    return mark
