"""Quickstart: solve an ill-conditioned overdetermined least-squares problem
through the unified ``lstsq()`` driver.

    PYTHONPATH=src python examples/quickstart.py [--m 20000] [--n 100]
                                                 [--backend auto]

``lstsq(A, b, key)`` auto-selects a solver from the problem shape, the
sketch-size regime and the requested accuracy ("fast" → SAA-SAS,
"balanced" → iterative sketching, "high" → FOSSILS; small or near-square
problems → direct QR; no key → LSQR).  ``method=`` forces a specific
solver; every method returns the same ``SolveResult``.

The ``--backend`` knob selects the sketch-apply implementation (see
``repro.core.backend``):

- ``auto``      — pallas kernels on TPU, reference jnp elsewhere (default)
- ``reference`` — pure-jnp operator paths (segment_sum / FWHT / matmul)
- ``pallas``    — TPU Pallas kernels from ``repro.kernels``; off-TPU these
  run in interpret mode (exact kernel semantics, much slower — useful for
  validation, not speed)

The same knob threads through every sketched solver, the batched front-end
``saa_sas_batch`` and the distributed ``sketched_lstsq``.
"""
import argparse
import time

import jax

jax.config.update("jax_enable_x64", True)
import jax.numpy as jnp

from repro.core import (
    SketchedSolver,
    generate_problem,
    lstsq,
    saa_sas_batch,
    select_method,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--m", type=int, default=20000)
    ap.add_argument("--n", type=int, default=100)
    ap.add_argument("--cond", type=float, default=1e10)
    ap.add_argument("--beta", type=float, default=1e-10)
    ap.add_argument(
        "--backend",
        choices=("auto", "reference", "pallas"),
        default="auto",
        help="sketch-apply backend (pallas is interpret-mode off-TPU)",
    )
    args = ap.parse_args()

    print(f"generating {args.m}x{args.n} problem with cond={args.cond:.0e} ...")
    prob = generate_problem(
        jax.random.key(0), args.m, args.n, cond=args.cond, beta=args.beta
    )

    def relerr(x):
        return float(jnp.linalg.norm(x - prob.x_true) / jnp.linalg.norm(prob.x_true))

    auto = select_method(args.m, args.n)
    print(f"lstsq auto-selection for this shape: {auto!r}\n")

    key = jax.random.key(1)
    for method in ("auto", "saa", "iterative", "fossils", "direct", "lsqr"):
        solve = lambda: lstsq(
            prob.A, prob.b, key, method=method, backend=args.backend
        )
        res = jax.block_until_ready(solve())  # warm
        t0 = time.perf_counter()
        res = jax.block_until_ready(solve())
        dt = time.perf_counter() - t0
        label = f"lstsq[{method}] -> {res.method}"
        print(
            f"{label:32s} {dt*1e3:8.1f} ms   relative error {relerr(res.x):.3e}"
            f"   itn={int(res.itn):3d}"
        )

    # Serving-style multi-query: many right-hand sides against one design
    # matrix share a single sketch + QR factor via saa_sas_batch.  Column 0
    # is the original b (so its error is comparable to the solves above);
    # the rest are perturbed queries.
    k = 8
    rhs = jnp.concatenate(
        [
            prob.b[:, None],
            prob.b[:, None]
            + 0.01 * jax.random.normal(jax.random.key(2), (args.m, k - 1)),
        ],
        axis=1,
    )
    batch = lambda: saa_sas_batch(
        prob.A, rhs, jax.random.key(1), backend=args.backend
    ).x
    X = jax.block_until_ready(batch())  # warm
    t0 = time.perf_counter()
    X = jax.block_until_ready(batch())
    dt = time.perf_counter() - t0
    print(
        f"{'saa_sas_batch (k=%d rhs)' % k:32s} {dt*1e3:8.1f} ms   "
        f"relative error {relerr(X[:, 0]):.3e}  ({dt/k*1e3:.1f} ms/query)"
    )

    # Stateful serving: SketchedSolver builds the sketch + QR factor ONCE
    # and amortizes it over every later query — right-hand sides do not
    # have to be known up front (unlike saa_sas_batch), and rows of A can
    # be updated in place with a cheap delta-sketch.
    solver = SketchedSolver(prob.A, jax.random.key(1), backend=args.backend)
    solver.solve(prob.b)  # warm (compile)
    t0 = time.perf_counter()
    for i in range(k):
        res = solver.solve(rhs[:, i])
    jax.block_until_ready(res.x)
    dt = time.perf_counter() - t0
    err = relerr(solver.solve(prob.b).x)
    print(
        f"{'SketchedSolver (%d solves)' % k:32s} {dt*1e3:8.1f} ms   "
        f"relative error {err:.3e}  ({dt/k*1e3:.1f} ms/query)"
    )
    print(f"{'':32s} session stats: {solver.stats}")


if __name__ == "__main__":
    main()
