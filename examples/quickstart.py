"""Quickstart: solve an ill-conditioned overdetermined least-squares problem
with Sketch-and-Apply (SAA-SAS, paper Algorithm 1).

    PYTHONPATH=src python examples/quickstart.py [--m 20000] [--n 100]
"""
import argparse
import time

import jax

jax.config.update("jax_enable_x64", True)
import jax.numpy as jnp

from repro.core import generate_problem, lsqr_dense, qr_solve, saa_sas


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--m", type=int, default=20000)
    ap.add_argument("--n", type=int, default=100)
    ap.add_argument("--cond", type=float, default=1e10)
    ap.add_argument("--beta", type=float, default=1e-10)
    args = ap.parse_args()

    print(f"generating {args.m}x{args.n} problem with cond={args.cond:.0e} ...")
    prob = generate_problem(
        jax.random.key(0), args.m, args.n, cond=args.cond, beta=args.beta
    )

    def relerr(x):
        return float(jnp.linalg.norm(x - prob.x_true) / jnp.linalg.norm(prob.x_true))

    for name, solve in [
        ("saa_sas (sketch-and-apply)", lambda: saa_sas(prob.A, prob.b, jax.random.key(1)).x),
        ("qr direct", lambda: qr_solve(prob.A, prob.b)),
        ("lsqr baseline", lambda: lsqr_dense(prob.A, prob.b, iter_lim=2 * args.n).x),
    ]:
        x = jax.block_until_ready(solve())  # warm
        t0 = time.perf_counter()
        x = jax.block_until_ready(solve())
        dt = time.perf_counter() - t0
        print(f"{name:30s} {dt*1e3:8.1f} ms   relative error {relerr(x):.3e}")


if __name__ == "__main__":
    main()
