"""Sparse least squares end-to-end: a BCOO data matrix through ``lstsq``.

    PYTHONPATH=src python examples/sparse_lstsq.py [--m 50000] [--n 64]
                                                   [--density 0.01]

Sparse and implicitly-defined problems are where sketching wins biggest:
the CountSketch apply costs O(nnz(A)), the sketched QR factor is tiny
(s×n), and the iterative solvers only ever take products with A — so A is
NEVER densified anywhere in the pipeline.  This script

1. builds a random sparse A (``jax.experimental.sparse`` BCOO) with a
   known solution,
2. solves it with ``lstsq(A_bcoo, b, key)`` — auto-selection routes
   sparse inputs to the matrix-free sketched solvers (never ``direct``,
   which would densify), and
3. cross-checks forced methods (iterative / fossils / saa / lsqr) against
   the dense ground truth.

The same BCOO matrix can be handed to ``SketchedSolver`` for repeated
right-hand sides, or wrapped in ``repro.core.linop.SparseOperator``
explicitly — ``lstsq`` coerces either form.
"""
import argparse
import time

import jax

jax.config.update("jax_enable_x64", True)
import jax.numpy as jnp
from jax.experimental.sparse import BCOO

from repro.core import lstsq, qr_solve


def random_sparse_problem(key, m, n, density):
    """Sparse A (BCOO), b = A x* + small noise, with x* known."""
    k_mask, k_val, k_x, k_noise = jax.random.split(key, 4)
    mask = jax.random.uniform(k_mask, (m, n)) < density
    dense = jnp.where(mask, jax.random.normal(k_val, (m, n)), 0.0)
    # guard against empty rows making the problem rank-deficient in n
    dense = dense.at[jnp.arange(n), jnp.arange(n)].add(1.0)
    A = BCOO.fromdense(dense)
    x_true = jax.random.normal(k_x, (n,))
    b = A @ x_true + 1e-8 * jax.random.normal(k_noise, (m,))
    return A, b, x_true, dense


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--m", type=int, default=50000)
    ap.add_argument("--n", type=int, default=64)
    ap.add_argument("--density", type=float, default=0.01)
    args = ap.parse_args()

    A, b, x_true, dense = random_sparse_problem(
        jax.random.key(0), args.m, args.n, args.density
    )
    frac = A.nse / (args.m * args.n)
    print(
        f"A: {args.m}x{args.n} BCOO, nnz={A.nse} "
        f"({100 * frac:.2f}% dense, {A.nse / args.m:.1f} nnz/row)"
    )

    x_qr = qr_solve(dense, b)  # dense ground truth (reference only)

    def relerr(x):
        return float(jnp.linalg.norm(x - x_qr) / jnp.linalg.norm(x_qr))

    key = jax.random.key(1)
    auto = lstsq(A, b, key)
    print(f"lstsq(auto) on BCOO selected {auto.method!r}: "
          f"relative error {relerr(auto.x):.3e}, itn={int(auto.itn)}\n")

    for method in ("iterative", "fossils", "saa", "lsqr"):
        solve = lambda: lstsq(A, b, key, method=method)
        res = jax.block_until_ready(solve())  # warm (compile)
        t0 = time.perf_counter()
        res = jax.block_until_ready(solve())
        dt = time.perf_counter() - t0
        print(
            f"lstsq[{method}] (sparse)  {dt * 1e3:9.1f} ms   "
            f"relative error {relerr(res.x):.3e}   itn={int(res.itn):4d}"
        )


if __name__ == "__main__":
    main()
