"""Distributed sketch-and-solve: row-sharded A over 8 (simulated) devices.

Each shard applies the shared ``CountSketch`` operator to its local rows
(into the global bucket space); one s x (n+1) all-reduce assembles the
sketch; LSQR runs distributed with psum-reduced inner products.
Communication is independent of m.  ``--backend pallas`` routes the local
applies through the Pallas kernel (interpret mode off-TPU).

    PYTHONPATH=src python examples/distributed_lsq.py [--backend auto]
"""
import argparse
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax

jax.config.update("jax_enable_x64", True)
import jax.numpy as jnp

from repro.core import generate_problem, qr_solve, sketched_lstsq
from repro.core.distributed import shard_rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", choices=("auto", "reference", "pallas"),
                    default="auto", help="local sketch-apply backend")
    args = ap.parse_args()

    mesh = jax.make_mesh((8,), ("data",))
    m, n = 65536, 128
    prob = generate_problem(jax.random.key(0), m, n, cond=1e8, beta=1e-10)
    A, b = shard_rows(mesh, ("data",), prob.A, prob.b)
    print(f"A: {A.shape} sharded as {A.sharding.spec} over {len(jax.devices())} devices")

    res = sketched_lstsq(A, b, jax.random.key(1), mesh=mesh, backend=args.backend)
    x_ref = qr_solve(prob.A, prob.b)
    err_vs_truth = float(jnp.linalg.norm(res.x - prob.x_true) / jnp.linalg.norm(prob.x_true))
    err_vs_qr = float(jnp.linalg.norm(res.x - x_ref) / jnp.linalg.norm(x_ref))
    s = 4 * n
    print(f"converged istop={int(res.istop)} in {int(res.itn)} LSQR iterations")
    print(f"relative error vs x_true: {err_vs_truth:.3e}   vs QR: {err_vs_qr:.3e}")
    print(f"comm per solve: one all-reduce of {s*(n+1)*8/1e6:.2f} MB (sketch) "
          f"+ {int(res.itn)} x {(n+3)*8} B (LSQR) — independent of m={m}")


if __name__ == "__main__":
    main()
