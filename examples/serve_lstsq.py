"""Multi-tenant certified least-squares serving.

Three tenants share one SolveService: two big-matrix tenants whose
factors live in the fingerprint cache (requests coalesce into vmapped
batches), and a swarm of small mixed-shape problems that route to padded
shape buckets.  Every response carries a posterior certificate for the
tenant's requested tolerance; an impossible SLO is rejected with the
reason rather than answered optimistically.

    PYTHONPATH=src python examples/serve_lstsq.py [--smoke]
"""
import argparse
import time

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402

from repro.core import generate_problem  # noqa: E402
from repro.serve import SolveService  # noqa: E402


def make_tenant(seed, m, n, k):
    prob = generate_problem(jax.random.key(seed), m, n, cond=1e4,
                            beta=1e-8, method="fast")
    kx, kr = jax.random.split(jax.random.key(seed + 100))
    X = jax.random.normal(kx, (n, k), prob.A.dtype)
    B = prob.A @ X + 1e-8 * jax.random.normal(kr, (m, k), prob.A.dtype)
    return prob.A, B


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="small + fast")
    args = ap.parse_args()
    m, n, k = (2000, 32, 8) if args.smoke else (8000, 64, 16)

    svc = SolveService(jax.random.key(0), max_batch=k, max_delay_s=0.002,
                       default_rtol=1e-6)

    # Two session tenants: prewarm builds the factor and compiles the
    # batch-width ladder before traffic arrives.
    A1, B1 = make_tenant(1, m, n, k)
    A2, B2 = make_tenant(2, m, n // 2, k)
    for A in (A1, A2):
        svc.prewarm(A)

    svc.start()
    try:
        t0 = time.perf_counter()
        futs = [svc.submit(A1, B1[:, j], certified_rtol=1e-6,
                           mode="session") for j in range(k)]
        futs += [svc.submit(A2, B2[:, j], certified_rtol=1e-6,
                            mode="session") for j in range(k)]
        # a swarm of small mixed-shape problems -> padded bucket path
        small = []
        for i in range(6):
            kA, kb = jax.random.split(jax.random.key(300 + i))
            ms = 48 + 5 * i
            As = jax.random.normal(kA, (ms, 7))
            bs = jax.random.normal(kb, (ms,))
            small.append(svc.submit(As, bs, certified_rtol=1e-8))
        resps = [f.result(timeout=120.0) for f in futs + small]
        wall = time.perf_counter() - t0
    finally:
        svc.stop()

    ok = [r for r in resps if r.ok]
    assert len(ok) == len(resps), [r.reason for r in resps if not r.ok]
    assert all(bool(r.certificate.passed) for r in ok)
    x_ref = jnp.linalg.lstsq(A1, B1[:, 0])[0]
    rel = float(jnp.linalg.norm(resps[0].x - x_ref)
                / jnp.linalg.norm(x_ref))
    assert rel <= 1e-6, rel

    # an SLO the certification ladder cannot meet is rejected, with the
    # best attained bound in the reason -- never silently mis-served
    bad = svc.solve(A1, B1[:, 0], certified_rtol=1e-308, mode="session")
    assert not bad.ok and "unattainable" in bad.reason

    st = svc.stats()
    print(f"served {len(ok)} requests in {wall:.2f}s "
          f"({len(ok) / wall:.1f} solves/s)")
    print(f"  paths: session={st['session_batches']} batches, "
          f"bucket={st['bucket_batches']} batches "
          f"({st['bucket_executables']} bucket executable(s))")
    print(f"  cache: {st['cache']['entries']} factors, "
          f"hit rate {st['cache']['hit_rate']:.2f}")
    print(f"  occupancy: session={st['session_occupancy']:.2f}")
    print(f"  rejected-by-design: {bad.reason!r}")
    print("OK")


if __name__ == "__main__":
    main()
