"""One flag, four complete solve timelines: repro.obs end to end.

    PYTHONPATH=src python examples/observe_solve.py [--out-dir traces]

Runs the same observability pipeline over every subsystem and writes one
Chrome-trace JSON per scenario (load them in chrome://tracing or
https://ui.perfetto.dev):

1. a certified ``lstsq`` — method selection, sketch/QR factor build,
   certificate probes and the escalation rungs;
2. a streamed out-of-core solve — pass-1 sketch tiles, the factor QR,
   and every pass-2 streamed product of the iteration;
3. a 4-worker cluster solve with an injected mid-pass worker kill — the
   recovery is *visible*: kill → recover → reassign → checkpoint restore
   events, and the restored task resuming from its watermark;
4. a ``SolveService`` micro-batch — submit instants, the queue → dispatch
   → solve → certify breakdown per batch.

Each timeline is also printed as an indented tree, the exported JSON is
re-parsed to prove validity, and the metrics registry the stats dicts
mirror into is dumped in Prometheus text format at the end.
"""
import argparse
import json
import os

import jax

jax.config.update("jax_enable_x64", True)
import jax.numpy as jnp
import numpy as np

from repro.cluster import ClusterSpec
from repro.cluster.faults import FaultPlan, KillWorker
from repro.core.lstsq import lstsq
from repro.obs import prometheus_text, save_chrome_trace
from repro.serve import SolveService
from repro.streaming.solve import stream_lstsq


def _check(path: str) -> int:
    """Re-parse an exported trace; return its event count."""
    with open(path) as f:
        obj = json.load(f)
    events = obj["traceEvents"]
    assert events, f"{path}: empty trace"
    for e in events:
        assert {"name", "ph", "pid", "tid"} <= set(e), f"{path}: bad event {e}"
    return len(events)


def banner(title: str):
    print(f"\n=== {title} " + "=" * max(0, 66 - len(title)))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="traces",
                    help="directory for the Chrome-trace JSON files")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    key = jax.random.PRNGKey(0)
    rng = np.random.default_rng(0)

    # ---------------------------------------------------- 1. certified lstsq
    banner("certified lstsq")
    A = jnp.asarray(rng.standard_normal((4096, 48)))
    b = jnp.asarray(rng.standard_normal(4096))
    res = lstsq(A, b, key, accuracy="certified", trace=True)
    print(res.timeline)
    path = os.path.join(args.out_dir, "certified_lstsq.json")
    res.timeline.save(path)
    print(f"-> {path}: {_check(path)} events, certificate passed="
          f"{bool(res.certificate.passed)}")

    # ---------------------------------------------------- 2. streamed solve
    banner("streamed out-of-core solve")
    res = stream_lstsq(np.asarray(A), np.asarray(b), key, tile_rows=512,
                       trace=True)
    tiles = sum(1 for s in res.timeline.spans() if s["name"] == "stream.tile")
    passes = sum(1 for s in res.timeline.spans()
                 if s["name"] == "stream.pass2")
    print(f"pass-1 tiles: {tiles}, pass-2 streamed products: {passes}")
    path = os.path.join(args.out_dir, "streamed_solve.json")
    res.timeline.save(path)
    print(f"-> {path}: {_check(path)} events")

    # ------------------------------------- 3. cluster solve + injected kill
    banner("4-worker cluster solve with injected kill")
    plan = FaultPlan(KillWorker(worker=1, at_tile=2))
    spec = ClusterSpec(num_workers=4, tile_rows=256, checkpoint_every=1,
                       faults=plan)
    res = stream_lstsq(np.asarray(A), np.asarray(b), key, tile_rows=256,
                       cluster=spec, trace=True)
    fault_events = [e for e in res.timeline.instants()
                    if e["name"] in ("cluster.recover", "cluster.reassign",
                                     "cluster.restore", "cluster.eviction",
                                     "cluster.respawn")]
    assert plan.fired, "the injected kill must have triggered"
    assert any(e["name"] == "cluster.restore" for e in fault_events), \
        "expected a checkpoint restore in the timeline"
    for e in fault_events:
        print(f"  {e['name']:20s} {e['args']}")
    path = os.path.join(args.out_dir, "cluster_kill_solve.json")
    res.timeline.save(path)
    print(f"-> {path}: {_check(path)} events")

    # --------------------------------------------- 4. SolveService batch
    banner("SolveService micro-batch")
    from repro.obs import trace as obs_trace

    svc = SolveService(key, max_delay_s=0.0, default_rtol=1e-8)
    with obs_trace.tracing() as tr:
        futs = [svc.submit(A, jnp.asarray(rng.standard_normal(4096)),
                           mode="session")
                for _ in range(8)]
        svc.flush()
    ok = sum(f.result().ok for f in futs)
    tl = tr.timeline()
    for stage in ("serve.submit", "serve.dispatch.session", "serve.solve",
                  "serve.certify", "cache.build"):
        evs = [e for e in tl.events
               if e["name"] == stage and e["ph"] in ("X", "i")]
        durs = sum(e.get("dur", 0.0) for e in evs) / 1e3
        print(f"  {stage:24s} x{len(evs):<3d} {durs:8.3f} ms")
    print(f"  {ok}/{len(futs)} ok; stats: "
          f"{ {k: v for k, v in svc.stats().items() if k != 'cache'} }")
    path = os.path.join(args.out_dir, "serve_batch.json")
    save_chrome_trace(tr, path)
    print(f"-> {path}: {_check(path)} events")

    # ------------------------------------------------------- metrics dump
    banner("metrics registry (Prometheus text format)")
    print(prometheus_text().strip())
    print("\nall traces parsed OK")


if __name__ == "__main__":
    main()
