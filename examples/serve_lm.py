"""Batched serving: prefill a prompt batch, decode greedily with the KV
cache (ring buffers for sliding-window layers, recurrent states for
SSM/RG-LRU archs).

    PYTHONPATH=src python examples/serve_lm.py [--arch qwen3-0.6b]
(uses the smoke-scale config of the chosen architecture)
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs import list_archs, smoke_config
from repro.models import init_params
from repro.train import generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b", choices=list_archs())
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()

    cfg = smoke_config(args.arch)
    if cfg.frontend != "token":
        raise SystemExit(f"{args.arch} has a stub frontend; use a token arch")
    params = init_params(cfg, jax.random.key(0))
    prompts = jax.random.randint(
        jax.random.key(1), (args.batch, args.prompt_len), 0, cfg.vocab
    )
    out = generate(cfg, params, prompts, max_new=args.max_new)
    print(f"arch={args.arch} (smoke config, {cfg.n_layers} layers)")
    print("prompt tail -> generated:")
    for i in range(args.batch):
        tail = " ".join(str(t) for t in prompts[i, -5:].tolist())
        gen = " ".join(str(t) for t in out[i].tolist())
        print(f"  [{tail}] -> [{gen}]")
    assert out.shape == (args.batch, args.max_new)
    assert bool(jnp.isfinite(out).all())
    print("OK")


if __name__ == "__main__":
    main()
