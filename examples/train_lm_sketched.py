"""End-to-end training driver: decoder LM on the synthetic bigram stream
with checkpoint/restart, and optional CountSketch gradient compression on
the data-parallel axis (the paper's operator as a distributed-training
feature).

    PYTHONPATH=src python examples/train_lm_sketched.py                  # tiny, fast
    PYTHONPATH=src python examples/train_lm_sketched.py --size 100m     # ~100M params
    PYTHONPATH=src python examples/train_lm_sketched.py --compress      # DP + sketched grads

The default config is sized for this 1-core CPU container; --size 100m is
the real driver config (use on actual accelerators).
"""
import argparse
import os

if "--compress" in os.sys.argv:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"

import jax
import jax.numpy as jnp

from repro.configs import LayerSpec, ModelConfig
from repro.data import SyntheticConfig, batch_at
from repro.optim import AdamWConfig, CompressionConfig, compress_state_init
from repro.train import make_dp_train_step, init_train_state, train_loop


def model(size: str) -> ModelConfig:
    if size == "100m":
        return ModelConfig(
            name="lm-100m", family="dense", d_model=768, n_heads=12,
            n_kv_heads=12, head_dim=64, d_ff=3072, vocab=32768,
            pattern=(LayerSpec("attn"),), n_periods=12, act="silu_glu",
            dtype="float32", loss_chunk=512,
        )
    return ModelConfig(
        name="lm-tiny", family="dense", d_model=256, n_heads=4, n_kv_heads=4,
        head_dim=64, d_ff=1024, vocab=2048, pattern=(LayerSpec("attn"),),
        n_periods=4, act="silu_glu", dtype="float32", loss_chunk=256,
        attn_q_block=128, attn_kv_block=128,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--size", default="tiny", choices=["tiny", "100m"])
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--compress", action="store_true")
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt")
    args = ap.parse_args()

    cfg = model(args.size)
    dcfg = SyntheticConfig(vocab=cfg.vocab, seq_len=args.seq,
                           global_batch=args.batch, kind="bigram")
    ocfg = AdamWConfig(lr=3e-3, warmup_steps=20, total_steps=args.steps)

    if not args.compress:
        state, losses = train_loop(
            cfg, dcfg, ocfg, steps=args.steps, ckpt_dir=args.ckpt,
            ckpt_every=50, log_every=10, n_micro=2,
        )
        print(f"final loss {losses[-1][1]:.4f} "
              f"(uniform would be ln V = {jnp.log(cfg.vocab):.2f})")
        return

    # --- DP + CountSketch gradient compression over 4 simulated devices ----
    mesh = jax.make_mesh((4,), ("data",))
    comp = CompressionConfig(ratio=8, min_size=16384)
    state = init_train_state(cfg, jax.random.key(0))
    ef = compress_state_init(comp, state.params)
    step_fn = jax.jit(make_dp_train_step(cfg, ocfg, mesh, compression=comp))
    for step in range(args.steps):
        batch = batch_at(dcfg, step)
        (state, ef), metrics = step_fn(state, ef, batch)
        if (step + 1) % 10 == 0:
            print(f"step {step+1:4d} loss {float(metrics['loss']):.4f} "
                  f"(sketched all-reduce, ratio {comp.ratio}x)")


if __name__ == "__main__":
    main()
