"""Out-of-core least squares: solve a memory-mapped problem bigger than
the tile budget, without ever holding A.

    PYTHONPATH=src python examples/streaming_lstsq.py [--m 40000] [--n 64]
                                                      [--cond 1e10]

This is the workload the in-memory solvers cannot touch: A lives in a
``.npy`` file on disk and is GENERATED tile-by-tile (each tile from its
own fold of the PRNG key), so the full matrix is never resident at any
point — not during generation, not during the solve.  The streaming
drivers read it back through ``numpy.memmap`` one tile at a time:

1. pass 1 streams the tiles once and assembles the sketch B = S·A
   (b rides along as an extra column),
2. pass 2 re-streams the tiles for the blocked ``A@v`` / ``Aᵀ@u``
   products inside the forward-stable iterative-sketching solver.

Peak data-matrix memory is ONE tile — the default tile budget here is
m/8 rows, well under 25% of m·n — yet on the κ=1e10 problem the streamed
forward error matches the dense in-memory path (same key ⇒ bit-identical
sketch operator).  The dense solve at the end is for validation only and
is the one place this script materializes A.

The generated fixture is cached (``--cache-dir``, default
``.cache/streaming``) keyed by its parameters, so repeated runs — and the
CI smoke job — skip the generation pass.
"""
import argparse
import os
import time

import jax

jax.config.update("jax_enable_x64", True)
import jax.numpy as jnp
import numpy as np

from repro.core import lstsq, qr_solve
from repro.streaming import MemmapSource, stream_lstsq


def generate_memmapped_problem(path, key, m, n, cond, beta, tile_rows):
    """Write A (m, n) with cond(A) ≈ ``cond`` to ``path`` tile-by-tile.

    A = (G/√m)·diag(σ)·Vᵀ with iid Gaussian G generated per-tile from
    fold_in(key, tile index), σ log-equispaced in [1, 1/κ], Haar V — the
    'fast' variant of the paper's §5.1 generator (repro.core.problems),
    restructured so no more than one tile of A ever exists in memory.
    Returns (x_true, b); b = A x_true + β·noise accumulates per tile.
    """
    k_v, k_w, k_tiles, k_noise = jax.random.split(key, 4)
    V, _ = jnp.linalg.qr(jax.random.normal(k_v, (n, n)), mode="reduced")
    sigma = jnp.logspace(0.0, -jnp.log10(cond), n)
    w = jax.random.normal(k_w, (n,))
    x_true = w / jnp.linalg.norm(w)
    coeff = (sigma[:, None] * V.T) @ x_true  # diag(σ)Vᵀ x_true, (n,)

    mm = np.lib.format.open_memmap(path, mode="w+", dtype=np.float64,
                                   shape=(m, n))
    b = np.empty((m,), np.float64)
    scale = 1.0 / np.sqrt(m)
    for i, o in enumerate(range(0, m, tile_rows)):
        t = min(tile_rows, m - o)
        G = jax.random.normal(jax.random.fold_in(k_tiles, i), (t, n))
        tile = (scale * G * sigma[None, :]) @ V.T
        noise = beta * jax.random.normal(jax.random.fold_in(k_noise, i), (t,))
        mm[o : o + t] = np.asarray(tile)
        b[o : o + t] = np.asarray(scale * (G @ coeff) + noise)
    mm.flush()
    del mm
    return np.asarray(x_true), b


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--m", type=int, default=40000)
    ap.add_argument("--n", type=int, default=64)
    ap.add_argument("--cond", type=float, default=1e10)
    ap.add_argument("--beta", type=float, default=1e-6)
    ap.add_argument("--tile-rows", type=int, default=None,
                    help="tile budget in rows (default m//8, i.e. 12.5%% "
                         "of A resident at peak)")
    ap.add_argument("--cache-dir", default=os.path.join(".cache", "streaming"))
    args = ap.parse_args()
    m, n = args.m, args.n
    tile_rows = args.tile_rows or max(m // 8, 1)
    if tile_rows * 4 > m:
        raise SystemExit("--tile-rows must keep the tile budget under 25% "
                         "of A (tile_rows <= m/4)")

    os.makedirs(args.cache_dir, exist_ok=True)
    stem = f"lsq_m{m}_n{n}_c{args.cond:.0e}_b{args.beta:.0e}_t{tile_rows}"
    a_path = os.path.join(args.cache_dir, stem + "_A.npy")
    b_path = os.path.join(args.cache_dir, stem + "_bx.npz")
    if os.path.exists(a_path) and os.path.exists(b_path):
        dat = np.load(b_path)
        x_true, b = dat["x_true"], dat["b"]
        print(f"fixture cache hit: {a_path}")
    else:
        t0 = time.perf_counter()
        x_true, b = generate_memmapped_problem(
            a_path, jax.random.key(0), m, n, args.cond, args.beta, tile_rows
        )
        np.savez(b_path, x_true=x_true, b=b)
        print(f"generated fixture in {time.perf_counter() - t0:.1f}s: {a_path}")

    tile_mb = tile_rows * n * 8 / 1e6
    full_mb = m * n * 8 / 1e6
    print(f"A: {m}x{n} float64 on disk ({full_mb:.1f} MB); tile budget "
          f"{tile_rows} rows = {tile_mb:.1f} MB "
          f"({100 * tile_rows / m:.1f}% of A resident at peak)")

    source = MemmapSource(a_path, tile_rows=tile_rows)
    b = jnp.asarray(b)
    key = jax.random.key(1)

    t0 = time.perf_counter()
    res = stream_lstsq(source, b, key, method="iterative")
    dt_stream = time.perf_counter() - t0

    # ---- validation only: the dense path materializes A ----------------
    # Forward error is measured against the Householder-QR minimizer (on a
    # κ=1e10 problem the generator's x_true is itself O(κ·β) away from the
    # true argmin, so x_qr is the reference that isolates SOLVER error).
    A = jnp.asarray(np.load(a_path))
    x_qr = qr_solve(A, b)
    xnorm = float(jnp.linalg.norm(x_qr))
    err_stream = float(jnp.linalg.norm(res.x - x_qr)) / xnorm
    print(f"\nstream_lstsq[iterative]  {dt_stream * 1e3:9.1f} ms   "
          f"forward error {err_stream:.3e}   itn={int(res.itn)}")
    t0 = time.perf_counter()
    res_dense = lstsq(A, b, key, method="iterative")
    dt_dense = time.perf_counter() - t0
    err_dense = float(jnp.linalg.norm(res_dense.x - x_qr)) / xnorm
    print(f"lstsq[iterative] (dense) {dt_dense * 1e3:9.1f} ms   "
          f"forward error {err_dense:.3e}   itn={int(res_dense.itn)}")

    # the acceptance bar: streaming costs no accuracy on the κ=1e10
    # problem (floor term: both paths can sit at the rounding floor)
    floor = 64 * float(jnp.finfo(jnp.float64).eps)
    assert err_stream <= 10 * err_dense + floor, (
        f"streamed forward error {err_stream:.3e} more than 10x the dense "
        f"path ({err_dense:.3e})"
    )
    print("\nOK: streamed forward error within 10x of the dense path, "
          f"with at most {100 * tile_rows / m:.1f}% of A ever resident.")


if __name__ == "__main__":
    main()
