"""Multi-worker, fault-tolerant out-of-core least squares with a
mid-pass worker kill — and the same certificate-passing answer.

    PYTHONPATH=src python examples/cluster_lstsq.py [--m 40000] [--n 64]
                                                    [--workers 4]

The problem is the ``examples/streaming_lstsq.py`` workload — A on disk
in a ``.npy`` file, bigger than any single worker's tile budget — but
here the two streaming passes fan out over a pool of workers
(``repro.cluster``):

1. each worker streams ITS tile-aligned row range into a mergeable
   partial sketch, checkpointing the accumulator state every few tiles;
2. a fault plan KILLS one worker mid-pass-1.  The coordinator notices
   the dead worker, restores its partial sketch from the checkpoint,
   reassigns the remaining tiles to a surviving worker, and merges the
   per-range partials — bit-equal to the run where nobody died;
3. pass-2 products (``A@v`` / ``Aᵀ@u``) are computed per-range and
   reduced in range order; a failed range is simply recomputed.

The dense solve at the end is validation only (the one place A is
materialized), asserting the clustered forward error within 10x of the
dense path exactly like the streaming example.
"""
import argparse
import os
import sys
import time

import jax

jax.config.update("jax_enable_x64", True)
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
from streaming_lstsq import generate_memmapped_problem  # noqa: E402

from repro.cluster import (  # noqa: E402
    ClusterEngine,
    ClusterSpec,
    FaultPlan,
    KillWorker,
)
from repro.core import lstsq, qr_solve  # noqa: E402
from repro.streaming import MemmapSource  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--m", type=int, default=40000)
    ap.add_argument("--n", type=int, default=64)
    ap.add_argument("--cond", type=float, default=1e8)
    ap.add_argument("--beta", type=float, default=1e-6)
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--tile-rows", type=int, default=None,
                    help="tile budget in rows per worker read "
                         "(default m//32)")
    ap.add_argument("--cache-dir", default=os.path.join(".cache", "streaming"))
    args = ap.parse_args()
    m, n, workers = args.m, args.n, args.workers
    tile_rows = args.tile_rows or max(m // 32, 1)

    os.makedirs(args.cache_dir, exist_ok=True)
    stem = f"lsq_m{m}_n{n}_c{args.cond:.0e}_b{args.beta:.0e}_t{tile_rows}"
    a_path = os.path.join(args.cache_dir, stem + "_A.npy")
    b_path = os.path.join(args.cache_dir, stem + "_bx.npz")
    if os.path.exists(a_path) and os.path.exists(b_path):
        b = np.load(b_path)["b"]
        print(f"fixture cache hit: {a_path}")
    else:
        t0 = time.perf_counter()
        _, b = generate_memmapped_problem(
            a_path, jax.random.key(0), m, n, args.cond, args.beta, tile_rows
        )
        np.savez(b_path, b=b)
        print(f"generated fixture in {time.perf_counter() - t0:.1f}s: {a_path}")
    b = jnp.asarray(b)
    key = jax.random.key(1)

    n_tiles = -(-m // tile_rows)
    per_worker = -(-n_tiles // workers)
    print(f"A: {m}x{n} float64 on disk ({m * n * 8 / 1e6:.1f} MB); "
          f"{n_tiles} tiles over {workers} workers "
          f"(~{per_worker} tiles = {per_worker * tile_rows * n * 8 / 1e6:.1f} "
          f"MB per worker — the problem exceeds any single worker's budget)")

    def cluster_solve(label, faults):
        eng = ClusterEngine(
            MemmapSource(a_path, tile_rows=tile_rows),
            ClusterSpec(num_workers=workers, checkpoint_every=2,
                        faults=faults),
        )
        t0 = time.perf_counter()
        res = lstsq(eng, b, key, accuracy="certified", method="auto")
        dt = time.perf_counter() - t0
        eng.close()
        st = eng.stats
        print(f"{label:24s} {dt * 1e3:9.1f} ms   itn={int(res.itn)}   "
              f"recoveries={st['recoveries']} restores={st['restores']} "
              f"checkpoints={st['checkpoints']}")
        return res, st

    res_clean, _ = cluster_solve("cluster solve (clean)", None)
    plan = FaultPlan(KillWorker(worker=1, at_tile=2))
    res_kill, st = cluster_solve("cluster solve (killed)", plan)
    assert plan.fired, "the injected kill never triggered"
    assert st["recoveries"] >= 1 and st["restores"] >= 1
    assert res_clean.certificate is not None
    assert bool(res_clean.certificate.passed), "clean run must certify"
    assert bool(res_kill.certificate.passed), "recovered run must certify"
    # pass-2 reductions regroup once a worker is gone, so the two runs
    # agree to rounding amplified by cond(A) — not bitwise
    agree = float(jnp.linalg.norm(res_kill.x - res_clean.x)
                  / jnp.linalg.norm(res_clean.x))
    print(f"killed-vs-clean solution agreement: {agree:.3e}")
    tol = max(float(res_clean.certificate.rel_error_bound), 1e-7)
    assert agree < tol, "recovered answer drifted from the clean run"

    # ---- validation only: the dense path materializes A ----------------
    A = jnp.asarray(np.load(a_path))
    x_qr = qr_solve(A, b)
    xnorm = float(jnp.linalg.norm(x_qr))
    err_cluster = float(jnp.linalg.norm(res_kill.x - x_qr)) / xnorm
    t0 = time.perf_counter()
    res_dense = lstsq(A, b, key, method="saa")
    dt_dense = time.perf_counter() - t0
    err_dense = float(jnp.linalg.norm(res_dense.x - x_qr)) / xnorm
    print(f"{'lstsq[saa] (dense)':24s} {dt_dense * 1e3:9.1f} ms   "
          f"forward error {err_dense:.3e}")
    print(f"cluster (kill+resume) forward error: {err_cluster:.3e}")

    floor = 64 * float(jnp.finfo(jnp.float64).eps)
    assert err_cluster <= 10 * err_dense + floor, (
        f"clustered forward error {err_cluster:.3e} more than 10x the "
        f"dense path ({err_dense:.3e})"
    )
    print(f"\nOK: worker killed mid-pass-1, recovered from its checkpoint, "
          f"certificate passed, and the answer matches the dense path "
          f"(rel. forward error {err_cluster:.3e}).")


if __name__ == "__main__":
    main()
